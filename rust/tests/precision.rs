//! Acceptance guardrails for contribution-driven adaptive precision.
//!
//! The adaptive policy exists to buy CTU energy with tiles the viewer
//! cannot tell apart from fp32. These tests pin that bargain on the
//! garden + truck evaluation orbits at the **default** thresholds:
//!
//! * coverage — a substantial share (≥ 40%) of populated tiles class
//!   below fp32, otherwise the policy is decorative;
//! * quality — every orbit view renders within 30 dB PSNR of the
//!   global-fp32 reference;
//! * energy — the realized class mix prices cheaper in `sim::energy`
//!   than running the same workload's CTU entirely at fp32.
//!
//! The default thresholds themselves are pinned too: changing them is a
//! deliberate quality/energy retune and must show up in this file.

use flicker::camera::{orbit_path, Camera, Intrinsics};
use flicker::cat::{CatConfig, LeaderMode, Precision};
use flicker::numeric::linalg::v3;
use flicker::render::metrics::psnr;
use flicker::render::plan::FramePlan;
use flicker::render::precision::{PrecisionMode, PrecisionPolicy, PrecisionThresholds};
use flicker::render::raster::RenderOptions;
use flicker::scene::gaussian::Scene;
use flicker::scene::synthetic::{generate_scaled, preset};
use flicker::sim::energy::{frame_energy, EnergyParams};
use flicker::sim::workload::extract_from_plan;
use flicker::sim::HwConfig;

fn orbit(res: u32, frames: usize) -> Vec<Camera> {
    orbit_path(
        Intrinsics::from_fov(res, res, 1.2),
        v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        frames,
    )
}

fn eval_scene(name: &str) -> Scene {
    generate_scaled(&preset(name), 0.02)
}

fn cat(precision: Precision) -> CatConfig {
    CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision,
        stage1: true,
    }
}

#[test]
fn default_thresholds_are_pinned() {
    let t = PrecisionThresholds::default();
    assert_eq!(t.fp32_min, 0.60);
    assert_eq!(t.fp16_min, 0.25);
    let PrecisionMode::Adaptive { thresholds, floor } = PrecisionPolicy::adaptive().mode else {
        panic!("PrecisionPolicy::adaptive() must be Adaptive");
    };
    assert_eq!(thresholds, t);
    assert_eq!(floor, Precision::Mixed);
    // The inert default: no policy configured means global at the CTU's
    // own precision, which renders through the exact pre-policy path.
    assert!(!PrecisionPolicy::default().is_adaptive());
}

#[test]
fn adaptive_orbits_hold_the_coverage_quality_energy_bargain() {
    let views = orbit(96, 3);
    let fp32_opts = RenderOptions::default();
    let adaptive_opts = RenderOptions {
        precision: PrecisionPolicy::adaptive(),
        ..RenderOptions::default()
    };
    let hw_fp32 = HwConfig {
        cat_precision: Precision::Fp32,
        ..HwConfig::flicker32()
    };
    let energy = EnergyParams::default();

    for scene_name in ["garden", "truck"] {
        let scene = eval_scene(scene_name);
        let mut populated = 0usize;
        let mut below_fp32 = 0usize;
        let mut ctu_adaptive_uj = 0.0f64;
        let mut ctu_fp32_uj = 0.0f64;

        for (v, cam) in views.iter().enumerate() {
            let fp32_plan = FramePlan::build(&scene, cam, &fp32_opts);
            let adaptive_plan = FramePlan::build(&scene, cam, &adaptive_opts);
            let classes = adaptive_plan
                .tile_classes()
                .expect("adaptive plans class every tile");

            // Coverage over populated tiles only — empty tiles class at the
            // floor for free and would flatter the ratio.
            for (t, class) in classes.iter().enumerate() {
                if adaptive_plan.lists[t].is_empty() {
                    continue;
                }
                populated += 1;
                if *class != Precision::Fp32 {
                    below_fp32 += 1;
                }
            }

            // Quality: adaptive CAT render vs the global-fp32 CAT render.
            let reference = fp32_plan.render(&cat(Precision::Fp32), None);
            let adaptive = adaptive_plan.render(&cat(Precision::Fp32), None);
            let q = psnr(&reference.image, &adaptive.image);
            assert!(
                q >= 30.0,
                "{scene_name} view {v}: adaptive PSNR {q} dB vs global fp32"
            );

            // Energy: price the realized class mix against an all-fp32 CTU
            // over the same frame (identical cycles/DRAM contributions).
            let wl_adaptive = extract_from_plan(&scene, &adaptive_plan, &hw_fp32);
            let wl_fp32 = extract_from_plan(&scene, &fp32_plan, &hw_fp32);
            ctu_adaptive_uj += frame_energy(&wl_adaptive, &hw_fp32, 0, 0, &energy).ctu_uj;
            ctu_fp32_uj += frame_energy(&wl_fp32, &hw_fp32, 0, 0, &energy).ctu_uj;
        }

        let share = below_fp32 as f64 / populated.max(1) as f64;
        assert!(
            share >= 0.40,
            "{scene_name}: only {share:.2} of {populated} populated tiles classed below fp32"
        );
        assert!(
            ctu_adaptive_uj < ctu_fp32_uj,
            "{scene_name}: adaptive CTU energy {ctu_adaptive_uj} µJ \
             must beat all-fp32 {ctu_fp32_uj} µJ"
        );
    }
}

#[test]
fn rect_orbits_refine_the_bargain_below_the_per_tile_run() {
    // The rect mode's reason to exist: classing quadrant-rectangles
    // inside mid/high-energy tiles converts more pixels to sub-fp32
    // precision than per-tile classing can (≥ 55% of quadrants vs the
    // ≥ 40% tile bar above), at the same PSNR floor, for strictly less
    // CTU energy than the per-tile adaptive run.
    let views = orbit(96, 3);
    let fp32_opts = RenderOptions::default();
    let adaptive_opts = RenderOptions {
        precision: PrecisionPolicy::adaptive(),
        ..RenderOptions::default()
    };
    let rect_opts = RenderOptions {
        precision: PrecisionPolicy::rect(),
        ..RenderOptions::default()
    };
    let hw_fp32 = HwConfig {
        cat_precision: Precision::Fp32,
        ..HwConfig::flicker32()
    };
    let energy = EnergyParams::default();

    for scene_name in ["garden", "truck"] {
        let scene = eval_scene(scene_name);
        let mut quadrants = 0usize;
        let mut below_fp32 = 0usize;
        let mut ctu_rect_uj = 0.0f64;
        let mut ctu_adaptive_uj = 0.0f64;

        for (v, cam) in views.iter().enumerate() {
            let fp32_plan = FramePlan::build(&scene, cam, &fp32_opts);
            let adaptive_plan = FramePlan::build(&scene, cam, &adaptive_opts);
            let rect_plan = FramePlan::build(&scene, cam, &rect_opts);
            let maps = rect_plan
                .tile_rect_classes()
                .expect("rect plans class every tile");

            // Coverage over populated tiles' quadrants only.
            for (t, map) in maps.iter().enumerate() {
                if rect_plan.lists[t].is_empty() {
                    continue;
                }
                for q in 0..4 {
                    quadrants += 1;
                    if map.quad(q) != Precision::Fp32 {
                        below_fp32 += 1;
                    }
                }
            }

            // Quality: rect CAT render vs the global-fp32 CAT render.
            let reference = fp32_plan.render(&cat(Precision::Fp32), None);
            let rect = rect_plan.render(&cat(Precision::Fp32), None);
            let q = psnr(&reference.image, &rect.image);
            assert!(
                q >= 30.0,
                "{scene_name} view {v}: rect PSNR {q} dB vs global fp32"
            );

            // Energy: the quadrant-weighted class mix must price strictly
            // below the per-tile adaptive mix on the same workload.
            let wl_rect = extract_from_plan(&scene, &rect_plan, &hw_fp32);
            let wl_adaptive = extract_from_plan(&scene, &adaptive_plan, &hw_fp32);
            ctu_rect_uj += frame_energy(&wl_rect, &hw_fp32, 0, 0, &energy).ctu_uj;
            ctu_adaptive_uj += frame_energy(&wl_adaptive, &hw_fp32, 0, 0, &energy).ctu_uj;
        }

        let share = below_fp32 as f64 / quadrants.max(1) as f64;
        assert!(
            share >= 0.55,
            "{scene_name}: only {share:.2} of {quadrants} quadrants classed below fp32"
        );
        assert!(
            ctu_rect_uj < ctu_adaptive_uj,
            "{scene_name}: rect CTU energy {ctu_rect_uj} µJ must beat \
             per-tile adaptive {ctu_adaptive_uj} µJ"
        );
    }
}
