//! Parallelism must never change the pixels — or the pruning signal:
//! tile-parallel and frame-parallel rendering are bit-identical to
//! sequential execution for every backend, because tiles and frames are
//! independent work units and the per-tile blending loop is shared between
//! both paths. Contribution scoring obeys the same contract via per-tile
//! (and per-view) partial sums reduced in a fixed order — including the
//! flattened view×tile work-stealing queue, where any worker may compute
//! any tile of any view. Plan reuse obeys it too: a `FramePlan` rendered
//! twice (or through the legacy one-shot wrappers) is bit-identical.
//!
//! The `Session` streaming surface inherits the whole contract:
//! `FrameStream` completion-order collection re-sorted by view index, and
//! the `ordered()` adapter, are bit-identical to sequential
//! `session.frame(i)` for workers 1/2/8/0, and `session.sweep` matches
//! per-backend one-shot renders bitwise while building exactly one
//! `FramePlan` per view regardless of backend count.
//!
//! Temporal plan deltas (`--plan-delta`) inherit it all: a delta-advanced
//! plan is bitwise identical to a cold build (rust/tests/plan_delta.rs),
//! and the plan-cache counters stay exact — sequential orbits report a
//! deterministic cold/delta split, streamed orbits a deterministic total,
//! and `builds + delta_builds + hits == requests` always.
//!
//! Adaptive precision (`--precision adaptive`) joins the contract on its
//! own terms: tile classes are a pure function of the plan (invariant
//! across worker counts and PJRT batch widths), an adaptive render is
//! bit-identical for any worker count / batch width, and forcing every
//! threshold to 0 (all tiles class fp32) reproduces the global-fp32
//! render bitwise. Adaptive is deterministic but — by design — not
//! bitwise-equal to a global policy at reduced tiers.

use flicker::camera::{orbit_path, Camera, Intrinsics};
use flicker::cat::{CatConfig, LeaderMode, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::{FrameMetrics, Golden, GoldenCat, RenderBackend, Session};
use flicker::numeric::linalg::v3;
use flicker::render::plan::FramePlan;
use flicker::render::raster::{render, render_masked, AllOnes, RenderOptions, VanillaMasks};
use flicker::render::tile::Strategy;
use flicker::scene::gaussian::Scene;
use flicker::scene::pruning::score_views;
use flicker::scene::synthetic::{generate_scaled, preset};

fn truck_frame() -> (Scene, Camera) {
    let scene = generate_scaled(&preset("truck"), 0.02);
    let cam = Camera::look_at(
        Intrinsics::from_fov(112, 112, 1.2),
        v3(0.0, 2.5, -12.0),
        v3(0.0, 0.5, 0.0),
        v3(0.0, 1.0, 0.0),
    );
    (scene, cam)
}

fn opts_with_workers(workers: usize) -> RenderOptions {
    RenderOptions {
        workers,
        ..RenderOptions::default()
    }
}

#[test]
fn golden_tile_parallel_is_bit_identical() {
    let (scene, cam) = truck_frame();
    let seq = render(&scene, &cam, &opts_with_workers(1));
    for workers in [2, 3, 8, 0] {
        let par = render(&scene, &cam, &opts_with_workers(workers));
        assert_eq!(seq.image.data, par.image.data, "workers={workers}");
        assert_eq!(seq.stats.pairs_tested, par.stats.pairs_tested, "workers={workers}");
        assert_eq!(seq.stats.pairs_blended, par.stats.pairs_blended, "workers={workers}");
        assert_eq!(seq.stats.tile_pairs, par.stats.tile_pairs, "workers={workers}");
        assert_eq!(
            seq.stats.tiles_early_terminated, par.stats.tiles_early_terminated,
            "workers={workers}"
        );
    }
}

/// Session over a borrowed (scene, camera) pair with explicit options.
fn single_view_session(scene: &Scene, cam: &Camera, workers: usize) -> Session {
    Session::builder(ExperimentConfig::default())
        .scene(scene.clone())
        .cameras(vec![*cam])
        .options(opts_with_workers(workers))
        .build()
        .unwrap()
}

#[test]
fn cat_backend_tile_parallel_is_bit_identical() {
    let (scene, cam) = truck_frame();
    let backend = GoldenCat(CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Mixed,
        stage1: true,
    });
    let seq = single_view_session(&scene, &cam, 1)
        .frame(0, &backend)
        .unwrap();
    let par = single_view_session(&scene, &cam, 4)
        .frame(0, &backend)
        .unwrap();
    assert_eq!(seq.image.data, par.image.data);
    assert_eq!(seq.stats.pairs_tested, par.stats.pairs_tested);
    assert_eq!(seq.backend, "golden+cat");
}

fn orbit_cfg(workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        scene: "truck".into(),
        scene_scale: 0.01,
        resolution: 64,
        frames: 3,
        workers,
        ..Default::default()
    }
}

#[test]
fn stream_is_bit_identical_to_sequential_frames() {
    // The streaming contract: FrameStream completion-order collection
    // re-sorted by view index, and the ordered() adapter, must match
    // sequential session.frame(i) bitwise for workers 1/2/8/0.
    let reference = Session::builder(orbit_cfg(1)).build().unwrap();
    let seq: Vec<FrameMetrics> = (0..reference.num_frames())
        .map(|i| reference.frame(i, &Golden).unwrap())
        .collect();
    for workers in [1, 2, 8, 0] {
        let session = Session::builder(orbit_cfg(workers)).build().unwrap();

        // Completion-order collection, re-sorted by frame index.
        let mut done: Vec<FrameMetrics> = session
            .stream(&Golden)
            .collect::<flicker::util::error::Result<Vec<_>>>()
            .unwrap();
        done.sort_by_key(|m| m.view);
        assert_eq!(seq.len(), done.len(), "workers={workers}");
        for (a, b) in seq.iter().zip(&done) {
            assert_eq!(a.image.data, b.image.data, "workers={workers}");
            assert_eq!(a.stats.pairs_blended, b.stats.pairs_blended, "workers={workers}");
            assert_eq!(b.backend, "golden");
        }

        // The ordered() adapter (fresh session so plans rebuild cold).
        let session = Session::builder(orbit_cfg(workers)).build().unwrap();
        let ordered = session.stream(&Golden).ordered().unwrap();
        for (i, (a, b)) in seq.iter().zip(&ordered).enumerate() {
            assert_eq!(a.image.data, b.image.data, "workers={workers} frame {i}");
            assert_eq!(b.view, i, "ordered() must restore orbit order");
        }
    }
}

#[test]
fn sweep_matches_per_backend_oneshot_renders() {
    // session.sweep: many backends over ONE cached plan — bitwise equal to
    // fresh one-shot renders per backend, with exactly one plan build.
    let (scene, cam) = truck_frame();
    let cat = GoldenCat(CatConfig {
        mode: LeaderMode::UniformDense,
        precision: Precision::Fp32,
        stage1: true,
    });
    let session = single_view_session(&scene, &cam, 1);
    let outs = session.sweep(0, &[&Golden, &cat]).unwrap();
    assert_eq!(
        session.plan_cache_stats().builds,
        1,
        "a sweep builds exactly one FramePlan regardless of backend count"
    );

    let opts = opts_with_workers(1);
    let golden_oneshot = render(&scene, &cam, &opts);
    assert_eq!(outs[0].image.data, golden_oneshot.image.data);
    assert_eq!(outs[0].stats.pairs_tested, golden_oneshot.stats.pairs_tested);
    let cat_oneshot = FramePlan::build(&scene, &cam, &opts).render(&cat.0, None);
    assert_eq!(outs[1].image.data, cat_oneshot.image.data);
    assert_eq!(outs[1].stats.pairs_tested, cat_oneshot.stats.pairs_tested);
}

#[test]
fn plan_cache_builds_once_per_view_for_any_backend_count() {
    // The cmd_quality shape: sweep every view through several backends,
    // then re-render — the cache must report one build per view, ever.
    let session = Session::builder(orbit_cfg(1)).build().unwrap();
    let cat = GoldenCat(CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Mixed,
        stage1: true,
    });
    let backends: [&dyn RenderBackend; 2] = [&Golden, &cat];
    for i in 0..session.num_frames() {
        session.sweep(i, &backends).unwrap();
    }
    assert_eq!(session.plan_cache_stats().builds, session.num_frames());
    for i in 0..session.num_frames() {
        session.frame(i, &Golden).unwrap();
        session.frame(i, &cat).unwrap();
    }
    let stats = session.plan_cache_stats();
    assert_eq!(
        stats.builds,
        session.num_frames(),
        "repeat renders must hit the cache, not rebuild"
    );
    assert!(stats.hits >= 2 * session.num_frames());
}

#[test]
fn plan_cache_delta_counts_exact_sequential_invariant_streamed() {
    // The latent PlanCacheStats gap: counters were only ever checked
    // loosely (builds exact, hits >=). With the delta path in play the
    // accounting must be airtight — every plan() call lands in exactly
    // one of builds / delta_builds / hits.
    let cfg = |workers: usize| ExperimentConfig {
        frames: 24, // 2π/24 ≈ 0.26 rad per step, inside the 0.35 default
        plan_delta: Some(true),
        ..orbit_cfg(workers)
    };

    // Sequential: view 0 cold-builds, every later view advances from its
    // just-built neighbor — the split is exact, not approximate.
    let session = Session::builder(cfg(1)).build().unwrap();
    for i in 0..session.num_frames() {
        session.frame(i, &Golden).unwrap();
    }
    let st = session.plan_cache_stats();
    assert_eq!(st.builds, 1, "only view 0 lacks a built neighbor");
    assert_eq!(st.delta_builds, session.num_frames() - 1);
    assert_eq!(st.hits, 0);
    assert_eq!(st.requests, session.num_frames());
    assert!(st.delta_splats_reprojected > 0, "orbit steps must re-bin some splats");
    assert!(st.delta_tiles_patched > 0, "orbit steps must patch some tiles");

    // Re-rendering the same views is pure cache hits — no new builds of
    // either kind, and the invariant still balances.
    for i in 0..session.num_frames() {
        session.frame(i, &Golden).unwrap();
    }
    let st = session.plan_cache_stats();
    assert_eq!(st.builds, 1);
    assert_eq!(st.delta_builds, session.num_frames() - 1);
    assert_eq!(st.hits, session.num_frames());
    assert_eq!(st.builds + st.delta_builds + st.hits, st.requests);

    // Streamed: completion order decides which views find a built
    // neighbor, so the cold/delta split is scheduling-dependent — but the
    // totals are not, and the invariant must hold regardless.
    for workers in [2usize, 8, 0] {
        let s = Session::builder(cfg(workers)).build().unwrap();
        let frames = s.stream(&Golden).ordered().unwrap();
        assert_eq!(frames.len(), s.num_frames(), "workers={workers}");
        let st = s.plan_cache_stats();
        assert_eq!(
            st.builds + st.delta_builds,
            s.num_frames(),
            "workers={workers}: one plan per view, cold or delta"
        );
        assert_eq!(
            st.builds + st.delta_builds + st.hits,
            st.requests,
            "workers={workers}: counters must balance"
        );
        assert!(st.builds >= 1, "workers={workers}: someone has to go first");
    }
}

#[test]
fn configured_strategy_reaches_orbit_renders() {
    // Regression: the pre-Session orbit helper (removed) hardcoded
    // RenderOptions::default() except workers, silently dropping a
    // configured Strategy::Obb. The session threads the full options.
    let obb_cfg = ExperimentConfig {
        strategy: Some("obb".into()),
        ..orbit_cfg(1)
    };
    let obb = Session::builder(obb_cfg).build().unwrap();
    assert_eq!(obb.options().strategy, Strategy::Obb);
    let obb_frames = obb.stream(&Golden).ordered().unwrap();
    assert_eq!(
        obb.plan(0).opts.strategy,
        Strategy::Obb,
        "the configured strategy must reach the rendered plans"
    );
    let aabb = Session::builder(orbit_cfg(1)).build().unwrap();
    let aabb_frames = aabb.stream(&Golden).ordered().unwrap();
    // OBB binning never inflates tile pairs relative to AABB.
    let obb_pairs: usize = obb_frames.iter().map(|m| m.stats.tile_pairs).sum();
    let aabb_pairs: usize = aabb_frames.iter().map(|m| m.stats.tile_pairs).sum();
    assert!(
        obb_pairs <= aabb_pairs,
        "OBB orbit must not test more tile pairs ({obb_pairs} vs {aabb_pairs})"
    );
}

#[test]
fn frame_plan_matches_legacy_oneshot_bitwise() {
    // FramePlan::render must reproduce the legacy one-shot paths bit for
    // bit — image, stats, and contribution scores — for workers 1/2/8/0.
    let (scene, cam) = truck_frame();
    let legacy = render(&scene, &cam, &opts_with_workers(1));
    let mut legacy_scores = vec![0.0f32; scene.len()];
    let legacy_scored = render_masked(
        &scene,
        &cam,
        &opts_with_workers(1),
        &mut AllOnes,
        Some(&mut legacy_scores),
    );
    assert_eq!(legacy.image.data, legacy_scored.image.data);
    for workers in [1, 2, 8, 0] {
        let plan = FramePlan::build(&scene, &cam, &opts_with_workers(workers));
        let mut scores = vec![0.0f32; scene.len()];
        let out = plan.render(&VanillaMasks, Some(&mut scores));
        assert_eq!(legacy.image.data, out.image.data, "workers={workers}");
        assert_eq!(legacy.stats.pairs_tested, out.stats.pairs_tested, "workers={workers}");
        assert_eq!(legacy.stats.pairs_blended, out.stats.pairs_blended, "workers={workers}");
        assert_eq!(score_bits(&legacy_scores), score_bits(&scores), "workers={workers}");
    }
}

#[test]
fn frame_plan_reuse_is_bit_stable_across_renders() {
    // The sweep pattern: one plan, many renders (vanilla + CAT) — every
    // repetition must be bit-identical to the first.
    let (scene, cam) = truck_frame();
    let plan = FramePlan::build(&scene, &cam, &opts_with_workers(0));
    let v1 = plan.render(&VanillaMasks, None);
    let v2 = plan.render(&VanillaMasks, None);
    assert_eq!(v1.image.data, v2.image.data);
    let cat = CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Mixed,
        stage1: true,
    };
    let c1 = plan.render(&cat, None);
    let c2 = plan.render(&cat, None);
    assert_eq!(c1.image.data, c2.image.data);
    assert_eq!(c1.stats.pairs_tested, c2.stats.pairs_tested);
    // Rendering CAT in between must not perturb the vanilla output.
    let v_again = plan.render(&VanillaMasks, None);
    assert_eq!(v1.image.data, v_again.image.data);
}

#[test]
fn adaptive_forced_fp32_is_bitwise_global_fp32() {
    // Thresholds forced to 0 class every tile fp32; the per-tile adaptive
    // machinery (tile_masks_at providers, per-tile fan-out) must then
    // reproduce the global-fp32 render bit for bit, for any worker count.
    use flicker::render::precision::{PrecisionMode, PrecisionPolicy, PrecisionThresholds};
    let (scene, cam) = truck_frame();
    let cat = CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Fp32,
        stage1: true,
    };
    let global = FramePlan::build(&scene, &cam, &opts_with_workers(1)).render(&cat, None);
    let forced = RenderOptions {
        precision: PrecisionPolicy {
            mode: PrecisionMode::Adaptive {
                thresholds: PrecisionThresholds {
                    fp32_min: 0.0,
                    fp16_min: 0.0,
                },
                floor: Precision::Mixed,
            },
        },
        ..opts_with_workers(1)
    };
    let classes = FramePlan::build(&scene, &cam, &forced)
        .tile_classes()
        .expect("adaptive plans class every tile");
    assert!(classes.iter().all(|&c| c == Precision::Fp32));
    for workers in [1, 2, 8, 0] {
        let plan = FramePlan::build(&scene, &cam, &RenderOptions { workers, ..forced });
        let out = plan.render(&cat, None);
        assert_eq!(global.image.data, out.image.data, "workers={workers}");
        assert_eq!(global.stats.pairs_tested, out.stats.pairs_tested, "workers={workers}");
    }
}

#[test]
fn adaptive_classes_and_renders_are_worker_invariant() {
    use flicker::render::precision::PrecisionPolicy;
    let (scene, cam) = truck_frame();
    let adaptive = |workers, batch| RenderOptions {
        precision: PrecisionPolicy::adaptive(),
        workers,
        batch,
        ..RenderOptions::default()
    };
    let base = FramePlan::build(&scene, &cam, &adaptive(1, 1));
    let reference = base.tile_classes().expect("adaptive plans class every tile");
    let mut present = [false; 4];
    for &c in &reference {
        present[flicker::render::precision::class_index(c)] = true;
    }
    let distinct = present.iter().filter(|&&b| b).count();
    assert!(distinct >= 2, "degenerate class mix: {reference:?}");
    // Class assignment is a pure function of the plan: worker count and
    // batch width must not perturb it.
    for (workers, batch) in [(2usize, 1usize), (8, 3), (0, 8)] {
        let plan = FramePlan::build(&scene, &cam, &adaptive(workers, batch));
        assert_eq!(
            plan.tile_classes().unwrap(),
            reference,
            "workers={workers} batch={batch}"
        );
    }
    // And the adaptive render itself is bit-identical across worker counts
    // (deterministic — though not bitwise-equal to any global policy).
    let cat = CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Mixed,
        stage1: true,
    };
    let seq = base.render(&cat, None);
    for workers in [2, 8, 0] {
        let out = FramePlan::build(&scene, &cam, &adaptive(workers, 1)).render(&cat, None);
        assert_eq!(seq.image.data, out.image.data, "workers={workers}");
        assert_eq!(seq.stats.pairs_tested, out.stats.pairs_tested, "workers={workers}");
    }
}

fn scoring_setup() -> (Scene, Vec<Camera>) {
    let scene = generate_scaled(&preset("truck"), 0.02);
    let views = orbit_path(
        Intrinsics::from_fov(96, 96, 1.2),
        v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        3,
    );
    (scene, views)
}

fn score_bits(scores: &[f32]) -> Vec<u32> {
    scores.iter().map(|s| s.to_bits()).collect()
}

#[test]
fn contribution_scores_bit_identical_across_workers() {
    let (scene, views) = scoring_setup();
    let opts = RenderOptions::default();
    let (base, base_stats) = score_views(&scene, &views, &opts, 1);
    assert!(
        base.iter().any(|&s| s > 0.0),
        "scoring must see the scene"
    );
    for workers in [2, 8, 0] {
        let (scores, stats) = score_views(&scene, &views, &opts, workers);
        assert_eq!(score_bits(&base), score_bits(&scores), "workers={workers}");
        assert_eq!(base_stats.pairs_tested, stats.pairs_tested, "workers={workers}");
        assert_eq!(base_stats.pairs_blended, stats.pairs_blended, "workers={workers}");
        assert_eq!(base_stats.pixels, stats.pixels, "workers={workers}");
        assert_eq!(
            base_stats.tiles_early_terminated, stats.tiles_early_terminated,
            "workers={workers}"
        );
    }
}

#[test]
fn contribution_scores_stable_across_repeated_runs() {
    let (scene, views) = scoring_setup();
    let opts = RenderOptions::default();
    let (a, _) = score_views(&scene, &views, &opts, 0);
    let (b, _) = score_views(&scene, &views, &opts, 0);
    assert_eq!(score_bits(&a), score_bits(&b));
}

#[test]
fn viewtile_scoring_few_views_many_workers_bit_identical() {
    // The regime the flattened (view × tile) queue exists for: fewer views
    // than workers. Every worker drains tiles from both views through one
    // work-stealing counter, yet the view-major/tile-major fold keeps the
    // scores bit-identical to the sequential pass — across workers 1/2/8/0
    // and repeated runs.
    let scene = generate_scaled(&preset("garden"), 0.02);
    let views = orbit_path(
        Intrinsics::from_fov(96, 96, 1.2),
        v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        2,
    );
    let opts = RenderOptions::default();
    let (base, base_stats) = score_views(&scene, &views, &opts, 1);
    assert!(base.iter().any(|&s| s > 0.0), "scoring must see the scene");
    for workers in [2, 8, 0] {
        let (scores, stats) = score_views(&scene, &views, &opts, workers);
        assert_eq!(score_bits(&base), score_bits(&scores), "workers={workers}");
        assert_eq!(base_stats.pairs_tested, stats.pairs_tested, "workers={workers}");
        assert_eq!(base_stats.pairs_blended, stats.pairs_blended, "workers={workers}");
        assert_eq!(
            base_stats.tiles_early_terminated, stats.tiles_early_terminated,
            "workers={workers}"
        );
    }
    // Repeated runs at a fixed worker count are stable too.
    let (a, _) = score_views(&scene, &views, &opts, 8);
    let (b, _) = score_views(&scene, &views, &opts, 8);
    assert_eq!(score_bits(&a), score_bits(&b));
}

#[test]
fn orbit_auto_workers_is_bit_identical() {
    let base = ExperimentConfig {
        scene: "garden".into(),
        scene_scale: 0.008,
        resolution: 48,
        frames: 2,
        ..Default::default()
    };
    let seq = Session::builder(base.clone())
        .build()
        .unwrap()
        .stream(&Golden)
        .ordered()
        .unwrap();
    let auto_cfg = ExperimentConfig {
        workers: 0,
        ..base.clone()
    };
    let auto = Session::builder(auto_cfg)
        .build()
        .unwrap()
        .stream(&Golden)
        .ordered()
        .unwrap();
    for (a, b) in seq.iter().zip(&auto) {
        assert_eq!(a.image.data, b.image.data);
    }
}

/// The PJRT backend inherits the whole contract through the batched
/// executor: `Session::stream` produces identical images for any
/// tiles-per-dispatch batch width, and the rendered orbit matches the
/// golden rasterizer within the CAT tolerance (the PSNR bar the old
/// `golden_vs_masked`-style comparisons used). Runs against the offline
/// stub runtime, so it executes in the default CI lane; a real-XLA build
/// cannot parse the synthesized placeholders and skips.
#[cfg(feature = "pjrt")]
mod pjrt_stream {
    use super::*;
    use flicker::coordinator::Pjrt;
    use flicker::render::metrics::psnr;
    use flicker::runtime::{write_stub_artifacts, Runtime};

    fn stub_runtime() -> Option<Runtime> {
        let dir = std::env::temp_dir().join("flicker_determinism_stub");
        write_stub_artifacts(&dir, 48, 16, 16, 8).unwrap();
        match Runtime::load(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                None
            }
        }
    }

    fn orbit_cfg() -> ExperimentConfig {
        ExperimentConfig {
            scene: "truck".into(),
            scene_scale: 0.01,
            resolution: 64,
            frames: 3,
            ..Default::default()
        }
    }

    #[test]
    fn pjrt_stream_is_batch_invariant_and_tracks_golden() {
        let Some(rt) = stub_runtime() else { return };
        let pjrt = Pjrt::new(&rt);

        // Reference: sequential frames at single-tile dispatch.
        let base = Session::builder(ExperimentConfig {
            batch: 1,
            ..orbit_cfg()
        })
        .build()
        .unwrap();
        let reference: Vec<FrameMetrics> =
            (0..base.num_frames()).map(|i| base.frame(i, &pjrt).unwrap()).collect();

        for batch in [1usize, 2, 8] {
            for workers in [1usize, 2] {
                let s = Session::builder(ExperimentConfig {
                    batch,
                    workers,
                    ..orbit_cfg()
                })
                .build()
                .unwrap();
                let frames = s.stream(&pjrt).ordered().unwrap();
                assert_eq!(frames.len(), reference.len());
                for (a, b) in reference.iter().zip(&frames) {
                    assert_eq!(
                        a.image.data, b.image.data,
                        "batch={batch} workers={workers} view={}",
                        a.view
                    );
                    assert_eq!(b.backend, "pjrt");
                }
            }
        }

        // And the PJRT orbit tracks the golden rasterizer per frame.
        let golden_session = Session::builder(orbit_cfg()).build().unwrap();
        let golden = golden_session.stream(&Golden).ordered().unwrap();
        for (g, p) in golden.iter().zip(&reference) {
            let q = psnr(&g.image, &p.image);
            assert!(q > 30.0, "view {}: PJRT vs golden PSNR {q}", g.view);
        }
    }

    #[test]
    fn pjrt_adaptive_waves_are_batch_invariant() {
        // Adaptive precision forms precision-pure waves through the
        // per-class monomorphized artifacts; width-1 waves (the single-tile
        // adaptive loop) through width-8 waves must be bit-identical, and
        // the orbit must stay close to the golden adaptive render.
        let Some(rt) = stub_runtime() else { return };
        let pjrt = Pjrt::new(&rt);
        let cfg = |batch: usize| ExperimentConfig {
            batch,
            precision: Some("adaptive".into()),
            ..orbit_cfg()
        };
        let base = Session::builder(cfg(1)).build().unwrap();
        let reference: Vec<FrameMetrics> =
            (0..base.num_frames()).map(|i| base.frame(i, &pjrt).unwrap()).collect();
        for batch in [2usize, 3, 8] {
            let s = Session::builder(cfg(batch)).build().unwrap();
            let frames = s.stream(&pjrt).ordered().unwrap();
            assert_eq!(frames.len(), reference.len());
            for (a, b) in reference.iter().zip(&frames) {
                assert_eq!(
                    a.image.data, b.image.data,
                    "batch={batch} view={}",
                    a.view
                );
            }
        }
        let golden_session = Session::builder(cfg(1)).build().unwrap();
        let golden = golden_session.stream(&Golden).ordered().unwrap();
        for (g, p) in golden.iter().zip(&reference) {
            let q = psnr(&g.image, &p.image);
            assert!(q > 30.0, "view {}: adaptive PJRT vs golden PSNR {q}", g.view);
        }
    }
}
