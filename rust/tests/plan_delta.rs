//! Differential harness for temporal plan deltas: an advance-chained
//! `FramePlan` must be *bitwise identical* to a cold `FramePlan::build`
//! of the same `(scene, camera, options)` triple — same tile lists in the
//! same depth order, same pixels, same `RenderStats` — for every backend,
//! with and without the coarse-to-fine gate, at every worker count. The
//! delta path is an optimization with zero observable effect; these tests
//! are the contract that keeps it one.

use flicker::camera::{orbit_path, Camera, Intrinsics};
use flicker::cat::{CatConfig, LeaderMode, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::{FrameMetrics, Golden, GoldenCat, Session};
use flicker::numeric::linalg::{v3, Quat};
use flicker::render::delta::DeltaConfig;
use flicker::render::plan::FramePlan;
use flicker::render::pyramid::GateConfig;
use flicker::render::raster::{RenderOptions, VanillaMasks};
use flicker::scene::gaussian::Scene;
use flicker::scene::synthetic::{generate_scaled, preset};
use flicker::util::rng::Pcg32;

fn orbit(res: u32, frames: usize) -> Vec<Camera> {
    orbit_path(
        Intrinsics::from_fov(res, res, 1.2),
        v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        frames,
    )
}

fn delta_opts(gate: bool) -> RenderOptions {
    RenderOptions {
        plan_delta: DeltaConfig::on(),
        gate: if gate { GateConfig::on() } else { GateConfig::default() },
        ..RenderOptions::default()
    }
}

fn cat() -> CatConfig {
    CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Mixed,
        stage1: true,
    }
}

/// Assert `adv` (delta-advanced) equals `cold` bitwise: the plan structure
/// (lists carry the depth order), the rendered pixels, and the full
/// `RenderStats` (compared via Debug formatting — the struct carries
/// counters, not floats, so the rendering is byte-stable) for both the
/// vanilla and the CAT mask source.
fn assert_plans_bit_identical(adv: &FramePlan, cold: &FramePlan, ctx: &str) {
    assert_eq!(adv.lists, cold.lists, "{ctx}: tile lists / depth order");
    assert_eq!(adv.splats.len(), cold.splats.len(), "{ctx}: splat count");
    for (a, b) in adv.splats.iter().zip(&cold.splats) {
        assert_eq!(a.id, b.id, "{ctx}: splat ids");
        assert_eq!(a.depth.to_bits(), b.depth.to_bits(), "{ctx}: splat depths");
    }
    let (av, cv) = (adv.render(&VanillaMasks, None), cold.render(&VanillaMasks, None));
    assert_eq!(av.image.data, cv.image.data, "{ctx}: vanilla pixels");
    assert_eq!(
        format!("{:?}", av.stats),
        format!("{:?}", cv.stats),
        "{ctx}: vanilla stats"
    );
    let c = cat();
    let (ac, cc) = (adv.render(&c, None), cold.render(&c, None));
    assert_eq!(ac.image.data, cc.image.data, "{ctx}: CAT pixels");
    assert_eq!(
        format!("{:?}", ac.stats),
        format!("{:?}", cc.stats),
        "{ctx}: CAT stats"
    );
}

#[test]
fn randomized_advance_chains_match_cold_builds() {
    // Randomized scenes and orbit step sizes: chain `advance` along the
    // path and diff every link against a cold build, gated and ungated.
    let mut rng = Pcg32::new(0xF11C_0007);
    for case in 0..4 {
        let name = *rng.pick(&["truck", "garden"]);
        let scale = rng.range_f32(0.008, 0.02);
        let scene = generate_scaled(&preset(name), scale);
        let frames = 18 + rng.below(23) as usize; // 18..=40: steps within max_angle
        let cams = orbit(48, frames);
        for gate in [false, true] {
            let opts = delta_opts(gate);
            let mut plan = FramePlan::build(&scene, &cams[0], &opts);
            for step in 1..5usize.min(frames) {
                let out = plan.advance_detailed(&scene, &cams[step], &opts);
                assert!(
                    !out.stats.fell_back,
                    "case {case} ({name} x{frames}) step {step}: unexpected fallback \
                     (angle {})",
                    out.stats.pose_angle
                );
                let cold = FramePlan::build(&scene, &cams[step], &opts);
                assert_plans_bit_identical(
                    &out.plan,
                    &cold,
                    &format!("case {case} ({name} x{frames}) gate={gate} step {step}"),
                );
                plan = out.plan; // chain: next advance starts from the delta plan
            }
        }
    }
}

#[test]
fn session_delta_is_bit_identical_for_all_worker_counts() {
    // The Session surface: plan_delta on vs off must stream identical
    // frames for workers 1/2/8/0, in both completion-order and ordered()
    // collection, and the cache counters must balance.
    let cfg = |workers: usize, delta: bool| ExperimentConfig {
        scene: "truck".into(),
        scene_scale: 0.01,
        resolution: 64,
        frames: 24,
        workers,
        plan_delta: Some(delta),
        ..Default::default()
    };
    let reference = Session::builder(cfg(1, false)).build().unwrap();
    let seq: Vec<FrameMetrics> = (0..reference.num_frames())
        .map(|i| reference.frame(i, &Golden).unwrap())
        .collect();
    for workers in [1usize, 2, 8, 0] {
        let session = Session::builder(cfg(workers, true)).build().unwrap();
        let mut done: Vec<FrameMetrics> = session
            .stream(&Golden)
            .collect::<flicker::util::error::Result<Vec<_>>>()
            .unwrap();
        done.sort_by_key(|m| m.view);
        assert_eq!(seq.len(), done.len(), "workers={workers}");
        for (a, b) in seq.iter().zip(&done) {
            assert_eq!(a.image.data, b.image.data, "workers={workers} view {}", a.view);
            assert_eq!(
                a.stats.pairs_blended, b.stats.pairs_blended,
                "workers={workers} view {}",
                a.view
            );
        }
        let st = session.plan_cache_stats();
        assert_eq!(
            st.builds + st.delta_builds + st.hits,
            st.requests,
            "workers={workers}: cache counters must balance"
        );
        assert_eq!(
            st.builds + st.delta_builds,
            session.num_frames(),
            "workers={workers}: one plan per view, cold or delta"
        );

        // ordered() over a fresh session (plans rebuild, possibly via a
        // different cold/delta split under concurrency — pixels may not).
        let ordered = Session::builder(cfg(workers, true))
            .build()
            .unwrap()
            .stream(&Golden)
            .ordered()
            .unwrap();
        for (i, (a, b)) in seq.iter().zip(&ordered).enumerate() {
            assert_eq!(a.image.data, b.image.data, "workers={workers} ordered frame {i}");
            assert_eq!(b.view, i, "ordered() must restore orbit order");
        }
    }
}

#[test]
fn session_delta_with_gating_matches_cold_session() {
    // Gate + delta together: the carried pyramid geometry must not perturb
    // gated pixels or the gate counters.
    let cfg = |delta: bool| ExperimentConfig {
        scene: "garden".into(),
        scene_scale: 0.01,
        resolution: 64,
        frames: 20,
        workers: 1,
        gate: Some(true),
        plan_delta: Some(delta),
        ..Default::default()
    };
    let cold = Session::builder(cfg(false)).build().unwrap();
    let delta = Session::builder(cfg(true)).build().unwrap();
    assert!(delta.options().gate.active(), "gate must reach the options");
    for i in 0..cold.num_frames() {
        let a = cold.frame(i, &Golden).unwrap();
        let b = delta.frame(i, &Golden).unwrap();
        assert_eq!(a.image.data, b.image.data, "view {i}");
        assert_eq!(
            format!("{:?}", a.stats),
            format!("{:?}", b.stats),
            "view {i}: stats (incl. gate counters)"
        );
    }
    let st = delta.plan_cache_stats();
    assert!(st.delta_builds > 0, "sequential orbit must exercise the delta path");
}

#[test]
fn large_pose_jump_makes_the_session_fall_back_cold() {
    // A 3-frame orbit steps 120° per view — far beyond the 0.35 rad
    // default — so every plan must cold-build even with delta enabled,
    // and the output must still match a delta-off session.
    let cfg = |delta: bool| ExperimentConfig {
        scene: "truck".into(),
        scene_scale: 0.01,
        resolution: 64,
        frames: 3,
        workers: 1,
        plan_delta: Some(delta),
        ..Default::default()
    };
    let cold = Session::builder(cfg(false)).build().unwrap();
    let delta = Session::builder(cfg(true)).build().unwrap();
    for i in 0..cold.num_frames() {
        let a = cold.frame(i, &Golden).unwrap();
        let b = delta.frame(i, &Golden).unwrap();
        assert_eq!(a.image.data, b.image.data, "view {i}");
    }
    let st = delta.plan_cache_stats();
    assert_eq!(st.delta_builds, 0, "every step exceeds max_angle");
    assert_eq!(st.builds, 3);
    assert_eq!(st.builds + st.delta_builds + st.hits, st.requests);
}

#[test]
fn empty_scene_advance_matches_cold() {
    // Degenerate: nothing survives projection (the lone Gaussian sits
    // behind every orbit camera's far plane) — all tile lists are empty
    // and advance must agree with build on the empty structure.
    let mut scene = Scene::with_capacity(1, "empty");
    scene.push(
        v3(0.0, 5000.0, 0.0), // far outside every view frustum
        Quat::IDENTITY,
        v3(0.1, 0.1, 0.1),
        0.9,
        [1.0; 3],
        [[0.0; 3]; 3],
    );
    let cams = orbit(48, 24);
    let opts = delta_opts(false);
    let prev = FramePlan::build(&scene, &cams[0], &opts);
    assert!(prev.lists.iter().all(|l| l.is_empty()), "scene must be culled");
    let out = prev.advance_detailed(&scene, &cams[1], &opts);
    assert!(!out.stats.fell_back);
    assert_eq!(out.stats.entries_carried, 0);
    let cold = FramePlan::build(&scene, &cams[1], &opts);
    assert_plans_bit_identical(&out.plan, &cold, "empty scene");
}

#[test]
fn single_gaussian_scene_advances_around_a_full_orbit() {
    // Degenerate: one Gaussian, chained through a whole 24-view orbit —
    // it enters and leaves tiles (and possibly the frustum) along the way.
    let mut scene = Scene::with_capacity(1, "single");
    scene.push(
        v3(0.4, 0.6, -0.2),
        Quat::from_axis_angle(v3(0.0, 1.0, 0.0), 0.7),
        v3(0.5, 0.3, 0.4),
        0.8,
        [0.9, 0.4, 0.2],
        [[0.0; 3]; 3],
    );
    let cams = orbit(64, 24);
    for gate in [false, true] {
        let opts = delta_opts(gate);
        let mut plan = FramePlan::build(&scene, &cams[0], &opts);
        for (i, cam) in cams.iter().enumerate().skip(1) {
            let out = plan.advance_detailed(&scene, cam, &opts);
            assert!(!out.stats.fell_back, "gate={gate} step {i}");
            let cold = FramePlan::build(&scene, cam, &opts);
            assert_plans_bit_identical(
                &out.plan,
                &cold,
                &format!("single gaussian gate={gate} step {i}"),
            );
            plan = out.plan;
        }
    }
}

/// The PJRT backend inherits the delta contract through the Session: a
/// plan-delta session renders the same pixels as a cold one through the
/// batched tile executor. Runs against the offline stub runtime so it
/// executes in the default CI lane; a real-XLA build cannot parse the
/// synthesized placeholders and skips.
#[cfg(feature = "pjrt")]
mod pjrt_delta {
    use super::*;
    use flicker::coordinator::Pjrt;
    use flicker::runtime::{write_stub_artifacts, Runtime};

    fn stub_runtime() -> Option<Runtime> {
        let dir = std::env::temp_dir().join("flicker_plan_delta_stub");
        write_stub_artifacts(&dir, 64, 16, 16, 8).unwrap();
        match Runtime::load(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn pjrt_session_delta_is_bit_identical_to_cold() {
        let Some(rt) = stub_runtime() else { return };
        let pjrt = Pjrt::new(&rt);
        let cfg = |delta: bool| ExperimentConfig {
            scene: "truck".into(),
            scene_scale: 0.01,
            resolution: 64,
            frames: 20,
            workers: 1,
            batch: 4,
            plan_delta: Some(delta),
            ..Default::default()
        };
        let cold = Session::builder(cfg(false)).build().unwrap();
        let delta = Session::builder(cfg(true)).build().unwrap();
        let a = cold.stream(&pjrt).ordered().unwrap();
        let b = delta.stream(&pjrt).ordered().unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.image.data, y.image.data, "view {}", x.view);
            assert_eq!(y.backend, "pjrt");
        }
        let st = delta.plan_cache_stats();
        assert!(st.delta_builds > 0, "sequential orbit must exercise the delta path");
        assert_eq!(st.builds + st.delta_builds + st.hits, st.requests);
    }
}
