//! Property-based tests over the core invariants, using the in-tree
//! `util::prop` helper (seeded, replayable; FLICKER_PROP_CASES scales
//! coverage).

use flicker::camera::{Camera, Intrinsics};
use flicker::cat::mixed::{pr_weights_quant, Precision};
use flicker::cat::pr::{acu_weight, pr_weights, shared_threshold};
use flicker::numeric::fp16::quantize_f16;
use flicker::numeric::fp8::{quantize_fp8, Fp8Format};
use flicker::numeric::linalg::{v2, v3, Quat, Sym2};
use flicker::render::delta::{motion_bound, DeltaConfig};
use flicker::render::plan::FramePlan;
use flicker::render::project::{project_one, project_scene};
use flicker::render::raster::{RenderOptions, VanillaMasks};
use flicker::render::sort::{depth_key, sort_by_key16};
use flicker::render::tile::{intersects_aabb, min_quad_on_rect, Rect};
use flicker::scene::gaussian::Scene;
use flicker::scene::synthetic::{generate_scaled, preset};
use flicker::sim::pipe::run_subtile;
use flicker::sim::workload::{GaussianJob, SubtileStream};
use flicker::util::prop::{check, ensure, PropConfig};
use flicker::util::rng::Pcg32;

fn random_conic(rng: &mut Pcg32) -> Sym2 {
    let l11 = rng.range_f32(0.03, 1.0);
    let l21 = rng.range_f32(-0.5, 0.5);
    let l22 = rng.range_f32(0.03, 1.0);
    Sym2 {
        a: l11 * l11,
        b: l11 * l21,
        c: l21 * l21 + l22 * l22,
    }
}

#[test]
fn prop_pr_weights_equal_acu_at_corners() {
    check(
        "PR corners == per-pixel ACU",
        PropConfig::default(),
        |rng, size| {
            let mu = v2(rng.range_f32(0.0, 512.0), rng.range_f32(0.0, 512.0));
            let conic = random_conic(rng);
            let span = 1.0 + size * 15.0;
            let pt = v2(rng.range_f32(0.0, 512.0), rng.range_f32(0.0, 512.0));
            let pb = v2(pt.x + span, pt.y + span);
            (mu, conic, pt, pb)
        },
        |&(mu, conic, pt, pb)| {
            let w = pr_weights(mu, conic, pt, pb);
            let corners = [
                v2(pt.x, pt.y),
                v2(pb.x, pt.y),
                v2(pt.x, pb.y),
                v2(pb.x, pb.y),
            ];
            for (k, c) in corners.iter().enumerate() {
                let direct = acu_weight(mu, conic, *c);
                let tol = 1e-3 * (1.0 + direct.abs());
                ensure(
                    (w.e[k] - direct).abs() <= tol,
                    format!("corner {k}: {} vs {direct}", w.e[k]),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantized_weights_preserve_strong_decisions() {
    // Mixed precision may flip borderline decisions but never ones with a
    // wide margin (>25% of the threshold).
    check(
        "mixed precision preserves strong Eq.2 decisions",
        PropConfig::default(),
        |rng, _| {
            let mu = v2(rng.range_f32(50.0, 450.0), rng.range_f32(50.0, 450.0));
            let conic = random_conic(rng);
            let pt = v2(mu.x + rng.range_f32(-12.0, 12.0), mu.y + rng.range_f32(-12.0, 12.0));
            let pb = v2(pt.x + 3.0, pt.y + 3.0);
            let o = rng.range_f32(0.05, 1.0);
            (mu, conic, pt, pb, o)
        },
        |&(mu, conic, pt, pb, o)| {
            let full = pr_weights(mu, conic, pt, pb);
            let mixed = pr_weights_quant(mu, conic, pt, pb, Precision::Mixed);
            let lhs = shared_threshold(o);
            for k in 0..4 {
                let margin = (lhs - full.e[k]).abs();
                if margin > 0.25 * (1.0 + lhs.abs() + full.e[k].abs()) {
                    let want = lhs > full.e[k];
                    let got = quantize_f16(lhs) > mixed.e[k];
                    ensure(
                        want == got,
                        format!(
                            "strong decision flipped at corner {k}: lhs {lhs}, full {}, mixed {}",
                            full.e[k], mixed.e[k]
                        ),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fp16_fp8_roundtrips_are_idempotent_and_monotone() {
    check(
        "quantizers idempotent + monotone",
        PropConfig::default(),
        |rng, _| {
            let a = rng.range_f32(-500.0, 500.0);
            let b = a + rng.range_f32(0.0, 100.0);
            (a, b)
        },
        |&(a, b)| {
            let q16 = quantize_f16(a);
            ensure(quantize_f16(q16) == q16, "fp16 not idempotent")?;
            let q8 = quantize_fp8(a, Fp8Format::E4M3);
            ensure(
                quantize_fp8(q8, Fp8Format::E4M3) == q8,
                "fp8 not idempotent",
            )?;
            ensure(
                quantize_f16(a) <= quantize_f16(b),
                format!("fp16 not monotone: {a} {b}"),
            )?;
            ensure(
                quantize_fp8(a, Fp8Format::E4M3) <= quantize_fp8(b, Fp8Format::E4M3),
                format!("fp8 not monotone: {a} {b}"),
            )?;
            Ok(())
        },
    );
}

#[test]
fn prop_projection_radius_bounds_footprint() {
    // Any pixel farther than `radius` from the projected mean must have
    // E > 4.5 (α below the 3σ cutoff).
    let cam = Camera::look_at(
        Intrinsics::from_fov(256, 256, 1.2),
        v3(0.0, 0.0, -8.0),
        v3(0.0, 0.0, 0.0),
        v3(0.0, 1.0, 0.0),
    );
    check(
        "3σ radius bounds the splat footprint",
        PropConfig::default(),
        |rng, _| {
            let mut s = Scene::with_capacity(1, "p");
            let q = Quat::from_axis_angle(
                v3(rng.normal(), rng.normal(), rng.normal()),
                rng.range_f32(0.0, 3.0),
            );
            s.push(
                v3(rng.range_f32(-1.0, 1.0), rng.range_f32(-1.0, 1.0), rng.range_f32(-2.0, 4.0)),
                q,
                v3(
                    rng.range_f32(0.02, 0.8),
                    rng.range_f32(0.02, 0.8),
                    rng.range_f32(0.02, 0.8),
                ),
                rng.range_f32(0.05, 1.0),
                [1.0; 3],
                [[0.0; 3]; 3],
            );
            let angle = rng.range_f32(0.0, std::f32::consts::TAU);
            (s, angle)
        },
        |(s, angle)| {
            let Some(sp) = project_one(s, 0, &cam) else {
                return Ok(()); // culled is fine
            };
            // Test points just beyond the radius in a random direction.
            let d = 1.05 * sp.radius;
            let px = sp.mean.x + d * angle.cos();
            let py = sp.mean.y + d * angle.sin();
            let dx = px - sp.mean.x;
            let dy = py - sp.mean.y;
            let e = 0.5 * (sp.conic.a * dx * dx + sp.conic.c * dy * dy) + sp.conic.b * dx * dy;
            ensure(e > 4.4, format!("E={e} inside 3σ at 1.05r"))?;
            Ok(())
        },
    );
}

#[test]
fn prop_min_quad_on_rect_is_a_lower_bound() {
    let cam = Camera::look_at(
        Intrinsics::from_fov(256, 256, 1.2),
        v3(0.0, 0.0, -8.0),
        v3(0.0, 0.0, 0.0),
        v3(0.0, 1.0, 0.0),
    );
    let mut base = Scene::with_capacity(1, "p");
    base.push(
        v3(0.0, 0.0, 0.0),
        Quat::from_axis_angle(v3(0.0, 0.0, 1.0), 0.6),
        v3(0.5, 0.08, 0.08),
        0.8,
        [1.0; 3],
        [[0.0; 3]; 3],
    );
    let splat = project_one(&base, 0, &cam).unwrap();
    check(
        "min_quad_on_rect lower-bounds sampled E",
        PropConfig::default(),
        |rng, _| {
            let x0 = rng.range_f32(0.0, 240.0);
            let y0 = rng.range_f32(0.0, 240.0);
            let rect = Rect { x0, y0, x1: x0 + 16.0, y1: y0 + 16.0 };
            let sx = rng.range_f32(rect.x0, rect.x1);
            let sy = rng.range_f32(rect.y0, rect.y1);
            (rect, sx, sy)
        },
        |&(rect, sx, sy)| {
            let lo = min_quad_on_rect(&splat, &rect);
            let dx = sx - splat.mean.x;
            let dy = sy - splat.mean.y;
            let e = 0.5
                * (splat.conic.a * dx * dx + splat.conic.c * dy * dy)
                + splat.conic.b * dx * dy;
            ensure(lo <= e + 1e-3, format!("min {lo} > sample {e}"))?;
            // And AABB containment: if the rect passes min-quad at 0 the
            // splat's mean is inside, so AABB must also pass.
            if lo == 0.0 {
                ensure(intersects_aabb(&splat, &rect), "mean inside but AABB missed")?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_depth_key_sort_agrees_with_depth_order() {
    let cam = Camera::look_at(
        Intrinsics::from_fov(64, 64, 1.2),
        v3(0.0, 0.0, -30.0),
        v3(0.0, 0.0, 0.0),
        v3(0.0, 1.0, 0.0),
    );
    check(
        "radix key sort is depth-ordered",
        PropConfig::default(),
        |rng, size| {
            let n = 2 + (size * 120.0) as usize;
            let mut scene = Scene::with_capacity(n, "p");
            for _ in 0..n {
                scene.push(
                    v3(0.0, 0.0, rng.range_f32(-20.0, 25.0)),
                    Quat::IDENTITY,
                    v3(0.2, 0.2, 0.2),
                    0.5,
                    [0.5; 3],
                    [[0.0; 3]; 3],
                );
            }
            scene
        },
        |scene| {
            let splats: Vec<_> = (0..scene.len())
                .filter_map(|i| project_one(scene, i, &cam))
                .collect();
            if splats.len() < 2 {
                return Ok(());
            }
            let mut order: Vec<u32> = (0..splats.len() as u32).collect();
            sort_by_key16(&mut order, &splats, 0.05, 1000.0);
            for w in order.windows(2) {
                let ka = depth_key(splats[w[0] as usize].depth, 0.05, 1000.0);
                let kb = depth_key(splats[w[1] as usize].depth, 0.05, 1000.0);
                ensure(ka <= kb, format!("keys out of order: {ka} > {kb}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pipe_conserves_work_and_depth_monotone() {
    check(
        "pipe: work conserved across FIFO depths; deeper never slower",
        PropConfig::default(),
        |rng, size| {
            let n = 1 + (size * 80.0) as usize;
            let jobs: Vec<GaussianJob> = (0..n)
                .map(|_| GaussianJob {
                    ctu_cycles: 1 + rng.below(2) as u8,
                    mask: rng.below(16) as u8,
                })
                .collect();
            let sat = [
                rng.below(n as u32 + 1),
                rng.below(n as u32 + 1),
                rng.below(n as u32 + 1),
                rng.below(n as u32 + 1),
            ];
            SubtileStream { jobs, sat }
        },
        |stream| {
            let mut prev_cycles = None;
            let mut work = None;
            for depth in [1usize, 2, 8, 64] {
                let st = run_subtile(stream, depth, 4, 8);
                if let Some((busy, discard)) = work {
                    ensure(
                        st.vru_busy == busy && st.vru_discard == discard,
                        format!("work not conserved at depth {depth}"),
                    )?;
                } else {
                    work = Some((st.vru_busy, st.vru_discard));
                }
                if let Some(p) = prev_cycles {
                    ensure(
                        st.cycles <= p,
                        format!("depth {depth} slower: {} > {p}", st.cycles),
                    )?;
                }
                prev_cycles = Some(st.cycles);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_delta_chain_equals_cold_build() {
    // Temporal plan deltas: chaining `FramePlan::advance` along a random
    // smooth pose path stays bitwise equal to cold builds — tile lists
    // (hence depth order) at every link, pixels at the end of the chain.
    let scene = generate_scaled(&preset("truck"), 0.008);
    let opts = RenderOptions {
        plan_delta: DeltaConfig::on(),
        ..RenderOptions::default()
    };
    check(
        "advance chain == cold builds (bitwise)",
        PropConfig::default(),
        |rng, size| {
            let intr = Intrinsics::from_fov(48, 48, 1.2);
            let target = v3(0.0, 0.5, 0.0);
            let mk = move |az: f32, h: f32| {
                Camera::look_at(
                    intr,
                    v3(12.0 * az.cos(), h, 12.0 * az.sin()),
                    target,
                    v3(0.0, 1.0, 0.0),
                )
            };
            let mut az = rng.range_f32(0.0, std::f32::consts::TAU);
            let mut h = rng.range_f32(1.5, 4.0);
            let len = 1 + (size * 7.0) as usize; // chains of 1..=8 steps
            let mut cams = vec![mk(az, h)];
            for _ in 0..len {
                // Bounded perturbations: each step stays under the default
                // max_angle (0.35 rad) so the delta path must engage.
                az += rng.range_f32(0.02, 0.22) * if rng.chance(0.5) { 1.0 } else { -1.0 };
                h = (h + rng.range_f32(-0.3, 0.3)).clamp(1.0, 4.5);
                cams.push(mk(az, h));
            }
            cams
        },
        |cams| {
            let mut plan = FramePlan::build(&scene, &cams[0], &opts);
            for (i, cam) in cams.iter().enumerate().skip(1) {
                let out = plan.advance_detailed(&scene, cam, &opts);
                ensure(
                    !out.stats.fell_back,
                    format!("step {i} fell back at angle {}", out.stats.pose_angle),
                )?;
                let cold = FramePlan::build(&scene, cam, &opts);
                ensure(
                    out.plan.lists == cold.lists,
                    format!("step {i}: tile lists / depth order diverged"),
                )?;
                plan = out.plan;
            }
            let adv = plan.render(&VanillaMasks, None);
            let cold =
                FramePlan::build(&scene, cams.last().unwrap(), &opts).render(&VanillaMasks, None);
            let a: Vec<u32> = adv.image.data.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = cold.image.data.iter().map(|x| x.to_bits()).collect();
            ensure(a == b, "chain-final pixels diverged from cold build")?;
            Ok(())
        },
    );
}

#[test]
fn prop_motion_bound_is_conservative() {
    // The per-splat motion bound must upper-bound the actual screen-space
    // travel of every id-matched splat under a random bounded pose change
    // — it is the skip threshold a hardware delta pipeline would trust.
    let scene = generate_scaled(&preset("garden"), 0.008);
    check(
        "motion bound covers actual projected motion",
        PropConfig::default(),
        |rng, size| {
            let intr = Intrinsics::from_fov(96, 96, 1.2);
            let target = v3(0.0, 0.5, 0.0);
            let mk = move |az: f32, h: f32| {
                Camera::look_at(
                    intr,
                    v3(12.0 * az.cos(), h, 12.0 * az.sin()),
                    target,
                    v3(0.0, 1.0, 0.0),
                )
            };
            let az = rng.range_f32(0.0, std::f32::consts::TAU);
            let h = rng.range_f32(1.5, 4.0);
            let step = size * rng.range_f32(0.01, 0.3)
                * if rng.chance(0.5) { 1.0 } else { -1.0 };
            let h2 = (h + rng.range_f32(-0.4, 0.4)).clamp(1.0, 4.5);
            (mk(az, h), mk(az + step, h2))
        },
        |(c0, c1)| {
            let a = project_scene(&scene, c0);
            let b = project_scene(&scene, c1);
            let (mut i, mut j) = (0usize, 0usize);
            while i < a.len() && j < b.len() {
                match a[i].id.cmp(&b[j].id) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        let moved = (b[j].mean - a[i].mean).norm();
                        let bound = motion_bound(c0, c1, &a[i]);
                        ensure(
                            moved <= bound,
                            format!("splat {}: moved {moved}px > bound {bound}px", a[i].id),
                        )?;
                        i += 1;
                        j += 1;
                    }
                }
            }
            Ok(())
        },
    );
}

/// Differential properties of the batched PJRT tile executor, run against
/// the offline stub runtime (`write_stub_artifacts` + the functional
/// `rust/xla-stub` fake) so they execute in the default CI lane. Against
/// a real-XLA build the placeholder artifacts fail to parse and the
/// properties skip; the `xla-real` lane covers real artifacts through
/// rust/tests/pjrt_roundtrip.rs instead.
#[cfg(feature = "pjrt")]
mod pjrt_batched {
    use flicker::render::image::Image;
    use flicker::render::project::Splat;
    use flicker::render::tile::TileGrid;
    use flicker::runtime::executor::{ExecStats, SourcedJob, TileExecutor, TileJob, TileSource};
    use flicker::runtime::{write_stub_artifacts, Runtime};
    use flicker::util::prop::{check, ensure, PropConfig};
    use flicker::util::rng::Pcg32;

    /// Stub monomorphization for the properties: tiny N_GAUSS so random
    /// lists straddle the chunk boundary constantly.
    const N_GAUSS: usize = 16;
    const N_BATCH: usize = 8;

    fn stub_runtime(tag: &str) -> Option<Runtime> {
        let dir = std::env::temp_dir().join(format!("flicker_prop_stub_{tag}"));
        write_stub_artifacts(&dir, N_GAUSS, 16, 16, N_BATCH).unwrap();
        match Runtime::load(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                None
            }
        }
    }

    /// One generated frame: random splats, a random tile grid whose tile
    /// count rarely divides the batch size, and random per-tile lists
    /// (empty through several-chunks long).
    #[derive(Debug)]
    struct Frame {
        splats: Vec<Splat>,
        lists: Vec<Vec<u32>>,
        width: u32,
        height: u32,
        batch: usize,
        background: [f32; 3],
    }

    fn random_splat(rng: &mut Pcg32, width: u32, height: u32, i: u32) -> Splat {
        use flicker::numeric::linalg::{v2, Sym2};
        let l11 = rng.range_f32(0.05, 0.9);
        let l21 = rng.range_f32(-0.4, 0.4);
        let l22 = rng.range_f32(0.05, 0.9);
        let conic = Sym2 {
            a: l11 * l11,
            b: l11 * l21,
            c: l21 * l21 + l22 * l22,
        };
        Splat {
            id: i,
            mean: v2(
                rng.range_f32(-8.0, width as f32 + 8.0),
                rng.range_f32(-8.0, height as f32 + 8.0),
            ),
            cov: Sym2 { a: 1.0, b: 0.0, c: 1.0 },
            conic,
            depth: rng.range_f32(0.1, 50.0),
            opacity: rng.range_f32(0.0, 1.0),
            color: [rng.f32(), rng.f32(), rng.f32()],
            radius: 8.0,
            axis_ratio: 1.0,
        }
    }

    fn generate_frame(rng: &mut Pcg32, size: f32) -> Frame {
        let tiles_x = rng.range_u32(1, 4); // 1..=4 tile columns
        let tiles_y = rng.range_u32(1, 4); // tile counts 1..16: most don't divide B
        let (width, height) = (tiles_x * 16, tiles_y * 16);
        let n_splats = 1 + (size * 40.0) as usize;
        let splats: Vec<Splat> = (0..n_splats)
            .map(|i| random_splat(rng, width, height, i as u32))
            .collect();
        // Random list lengths 0..=3×N_GAUSS: empty tiles, exact-chunk
        // tiles, and lists straddling the chunk boundary all occur.
        let lists: Vec<Vec<u32>> = (0..(tiles_x * tiles_y))
            .map(|_| {
                let len = rng.below(3 * N_GAUSS as u32 + 1) as usize;
                (0..len).map(|_| rng.below(n_splats as u32)).collect()
            })
            .collect();
        let batch = *rng.pick(&[1usize, 2, 3, N_BATCH]);
        Frame {
            splats,
            lists,
            width,
            height,
            batch,
            background: [rng.f32(), rng.f32(), rng.f32()],
        }
    }

    #[test]
    fn prop_render_tiles_bit_identical_to_single_tile_loop() {
        let Some(rt) = stub_runtime("bitident") else { return };
        check(
            "render_tiles == looped render_tile (bitwise)",
            PropConfig::default(),
            generate_frame,
            |f| {
                let grid = TileGrid::new(f.width, f.height, 16);
                // Reference: one dispatch per tile-chunk.
                let mut img_one = Image::new(f.width, f.height);
                let mut ex_one = TileExecutor::new(&rt);
                for (t, list) in f.lists.iter().enumerate() {
                    ex_one
                        .render_tile(&grid.rect(t), &f.splats, list, &mut img_one, f.background)
                        .map_err(|e| format!("single-tile render failed: {e}"))?;
                }
                // Batched: up to f.batch tiles per dispatch.
                let jobs = TileJob::for_grid(&grid, &f.lists);
                let mut img_b = Image::new(f.width, f.height);
                let mut ex_b = TileExecutor::new(&rt).with_batch(f.batch);
                ex_b.render_tiles(&jobs, &f.splats, &mut img_b, f.background)
                    .map_err(|e| format!("batched render failed: {e}"))?;

                let a: Vec<u32> = img_one.data.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> = img_b.data.iter().map(|x| x.to_bits()).collect();
                ensure(
                    a == b,
                    format!("image differs at batch {} over {} tiles", f.batch, f.lists.len()),
                )?;
                ensure(
                    ex_b.stats.tiles == ex_one.stats.tiles
                        && ex_b.stats.chunks == ex_one.stats.chunks
                        && ex_b.stats.splats_submitted == ex_one.stats.splats_submitted
                        && ex_b.stats.splats_passed_cat == ex_one.stats.splats_passed_cat,
                    format!(
                        "real-work stats diverged: batched {:?} vs single {:?}",
                        ex_b.stats, ex_one.stats
                    ),
                )?;
                ensure(
                    ex_b.stats.splats_submitted <= ex_b.stats.rows_submitted,
                    "padding accounting went negative",
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn prop_batch_width_never_changes_pixels() {
        // Sweep every batch width over one frame per case: all widths must
        // agree bitwise (transitively pins B∈{1,2,3,8} to each other).
        let Some(rt) = stub_runtime("widths") else { return };
        check(
            "all batch widths agree bitwise",
            PropConfig::default(),
            |rng, size| generate_frame(rng, size),
            |f| {
                let grid = TileGrid::new(f.width, f.height, 16);
                let jobs = TileJob::for_grid(&grid, &f.lists);
                let mut reference: Option<Vec<u32>> = None;
                for b in [1usize, 2, 3, N_BATCH] {
                    let mut img = Image::new(f.width, f.height);
                    let mut ex = TileExecutor::new(&rt).with_batch(b);
                    ensure(ex.effective_batch() == b.min(N_BATCH), "batch clamp")?;
                    ex.render_tiles(&jobs, &f.splats, &mut img, f.background)
                        .map_err(|e| format!("batch {b} failed: {e}"))?;
                    let bits: Vec<u32> = img.data.iter().map(|x| x.to_bits()).collect();
                    match &reference {
                        None => reference = Some(bits),
                        Some(r) => ensure(*r == bits, format!("batch {b} changed pixels"))?,
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_coalesced_fill_dominates_separate_runs() {
        // Cross-client coalescing claim: merging two frames' tile queues
        // into shared waves (a) never changes any pixel, (b) submits the
        // exact same real work, and (c) never pads MORE than the two
        // separate runs combined — the separate runs' waves form a valid
        // partition of the merged queue, and the coalescer's sorted
        // grouping minimizes the summed per-wave maxima over all such
        // partitions. For identical (cloned) clients the same argument
        // bounds the merged padding by twice one run's, so coalesced
        // fill_rate also dominates the per-client value — the symmetric
        // special case of the acceptance property (heterogeneous clients
        // only guarantee dominance over the aggregate, not each client's
        // own fill_rate).
        let Some(rt) = stub_runtime("coalesce") else { return };
        check(
            "coalesced fill_rate >= aggregate of separate runs",
            PropConfig::default(),
            |rng, size| (generate_frame(rng, size), generate_frame(rng, size)),
            |(a, b)| {
                let batch = a.batch; // one wave width for every run in this case
                let run = |f: &Frame| -> Result<(Vec<u32>, ExecStats), String> {
                    let grid = TileGrid::new(f.width, f.height, 16);
                    let jobs = TileJob::for_grid(&grid, &f.lists);
                    let mut img = Image::new(f.width, f.height);
                    let mut ex = TileExecutor::new(&rt).with_batch(batch);
                    ex.render_tiles(&jobs, &f.splats, &mut img, f.background)
                        .map_err(|e| format!("separate render failed: {e}"))?;
                    Ok((img.data.iter().map(|x| x.to_bits()).collect(), ex.stats))
                };
                let coalesce = |frames: &[&Frame]| -> Result<(Vec<Vec<u32>>, ExecStats), String> {
                    let grids: Vec<TileGrid> = frames
                        .iter()
                        .map(|f| TileGrid::new(f.width, f.height, 16))
                        .collect();
                    let per_jobs: Vec<Vec<TileJob>> = frames
                        .iter()
                        .zip(&grids)
                        .map(|(f, g)| TileJob::for_grid(g, &f.lists))
                        .collect();
                    let sources: Vec<TileSource> = frames
                        .iter()
                        .map(|f| TileSource { splats: &f.splats, background: f.background })
                        .collect();
                    let jobs: Vec<SourcedJob> = per_jobs
                        .iter()
                        .enumerate()
                        .flat_map(|(s, js)| {
                            js.iter().map(move |&job| SourcedJob { source: s, job })
                        })
                        .collect();
                    let mut images: Vec<Image> =
                        frames.iter().map(|f| Image::new(f.width, f.height)).collect();
                    let mut ex = TileExecutor::new(&rt).with_batch(batch);
                    ex.render_tiles_coalesced(&sources, &jobs, &mut images)
                        .map_err(|e| format!("coalesced render failed: {e}"))?;
                    let bits = images
                        .iter()
                        .map(|img| img.data.iter().map(|x| x.to_bits()).collect())
                        .collect();
                    Ok((bits, ex.stats))
                };

                let (bits_a, sa) = run(a)?;
                let (bits_b, sb) = run(b)?;
                let (merged_bits, sm) = coalesce(&[a, b])?;
                ensure(merged_bits[0] == bits_a, "coalescing changed frame A's pixels")?;
                ensure(merged_bits[1] == bits_b, "coalescing changed frame B's pixels")?;
                ensure(
                    sm.splats_submitted == sa.splats_submitted + sb.splats_submitted,
                    format!(
                        "real work not conserved: merged {} vs {} + {}",
                        sm.splats_submitted, sa.splats_submitted, sb.splats_submitted
                    ),
                )?;
                ensure(
                    sm.rows_submitted <= sa.rows_submitted + sb.rows_submitted,
                    format!(
                        "coalescing padded more than separate runs: {} vs {} + {}",
                        sm.rows_submitted, sa.rows_submitted, sb.rows_submitted
                    ),
                )?;
                if sa.rows_submitted + sb.rows_submitted > 0 {
                    let aggregate = (sa.splats_submitted + sb.splats_submitted) as f64
                        / (sa.rows_submitted + sb.rows_submitted) as f64;
                    ensure(
                        sm.fill_rate() >= aggregate - 1e-12,
                        format!(
                            "coalesced fill {} below separate aggregate {aggregate}",
                            sm.fill_rate()
                        ),
                    )?;
                }

                // Symmetric clients: coalesced fill dominates the
                // per-client value itself.
                let (twin_bits, st) = coalesce(&[a, a])?;
                ensure(twin_bits[0] == bits_a, "twin coalescing changed pixels (slot 0)")?;
                ensure(twin_bits[1] == bits_a, "twin coalescing changed pixels (slot 1)")?;
                ensure(
                    st.fill_rate() >= sa.fill_rate() - 1e-12,
                    format!(
                        "twin coalesced fill {} below per-client fill {}",
                        st.fill_rate(),
                        sa.fill_rate()
                    ),
                )?;
                Ok(())
            },
        );
    }
}

#[test]
fn prop_gate_rejection_is_conservative() {
    // The coarse-to-fine gate may only drop pairs the fine loop would have
    // skipped anyway: whenever the pyramid rejects a tile (or clears a
    // quadrant), every pixel center in that region must sit below the
    // 1/255 blend floor.
    use flicker::render::project::{Splat, ALPHA_MIN};
    use flicker::render::pyramid::{GateConfig, TilePyramid};
    check(
        "coarse gate never rejects a contributing pair",
        PropConfig::default(),
        |rng, size| {
            let spread = 8.0 + size * 48.0;
            let mean = v2(
                rng.range_f32(24.0 - spread, 24.0 + spread),
                rng.range_f32(24.0 - spread, 24.0 + spread),
            );
            (mean, random_conic(rng), rng.range_f32(0.005, 1.0))
        },
        |&(mean, conic, opacity)| {
            let s = Splat {
                id: 0,
                mean,
                cov: Sym2 { a: 1.0, b: 0.0, c: 1.0 },
                conic,
                depth: 1.0,
                opacity,
                color: [1.0; 3],
                radius: 10.0,
                axis_ratio: 1.0,
            };
            let rect = Rect { x0: 16.0, y0: 16.0, x1: 32.0, y1: 32.0 };
            let pyr = TilePyramid::new(&rect, 16);
            let d = pyr.gate(&s, &GateConfig::on());
            for py in 16u32..32 {
                for px in 16u32..32 {
                    let q = (py >= 24) as u8 * 2 + (px >= 24) as u8;
                    let dead = d.tile_rejected || d.quad_mask & (1 << q) == 0;
                    if !dead {
                        continue;
                    }
                    let a = s.alpha_at(px as f32 + 0.5, py as f32 + 0.5);
                    ensure(
                        a < ALPHA_MIN,
                        format!("gated-out pair contributes alpha={a} at ({px},{py})"),
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quad_energies_partition_the_tile_fold() {
    // Rect-mode classing splits the plan-time Σ T·α fold across the 2×2
    // quadrants. The split must be an exact partition of the per-tile
    // fold: same peak per splat (the minimum over live quadrant minima IS
    // the whole-rect minimum, bitwise), hence the same skip decisions and
    // the same transmittance chain, with every term landing in exactly
    // one accumulator.
    use flicker::render::precision::{quad_energies, quad_energy_total, tile_energy};
    use flicker::render::project::Splat;
    use flicker::render::pyramid::TilePyramid;
    check(
        "quadrant energies are an exact partition of the tile fold",
        PropConfig::default(),
        |rng, size| {
            let tx = rng.range_u32(0, 3) as f32;
            let ty = rng.range_u32(0, 3) as f32;
            let rect = Rect {
                x0: tx * 16.0,
                y0: ty * 16.0,
                x1: tx * 16.0 + *rng.pick(&[16.0f32, 16.0, 11.0, 6.0]),
                y1: ty * 16.0 + *rng.pick(&[16.0f32, 16.0, 9.0, 5.0]),
            };
            let n = 1 + (size * 24.0) as usize;
            let splats: Vec<Splat> = (0..n)
                .map(|i| Splat {
                    id: i as u32,
                    mean: v2(rng.range_f32(-8.0, 72.0), rng.range_f32(-8.0, 72.0)),
                    cov: Sym2 { a: 1.0, b: 0.0, c: 1.0 },
                    conic: random_conic(rng),
                    depth: rng.range_f32(0.1, 50.0),
                    opacity: rng.range_f32(0.0, 1.0),
                    color: [1.0; 3],
                    radius: 8.0,
                    axis_ratio: 1.0,
                })
                .collect();
            let list: Vec<u32> = (0..n as u32).collect();
            (rect, splats, list)
        },
        |(rect, splats, list)| {
            let pyr = TilePyramid::new(rect, 16);
            let qe = quad_energies(splats, list, pyr.quad_rects());

            // Same terms: the peak each splat is scored at in the quadrant
            // fold is the whole-rect peak, bit for bit. This is what makes
            // the quadrant fold "the tile fold, partitioned" rather than a
            // different estimate.
            for &si in list.iter() {
                let s = &splats[si as usize];
                let quad_min = pyr
                    .quad_rects()
                    .iter()
                    .filter(|r| r.x1 > r.x0 && r.y1 > r.y0)
                    .map(|r| min_quad_on_rect(s, r))
                    .fold(f32::INFINITY, f32::min);
                let tile_min = min_quad_on_rect(s, rect);
                ensure(
                    quad_min.to_bits() == tile_min.to_bits(),
                    format!("splat {si}: quadrant min {quad_min} != tile min {tile_min}"),
                )?;
            }

            // Energy lands only in live quadrants.
            for q in 0..4 {
                if pyr.live() & (1 << q) == 0 {
                    ensure(qe[q] == 0.0, format!("dead quadrant {q} absorbed {}", qe[q]))?;
                }
            }

            // The fixed-order sum is the rect policy's tile energy; it can
            // differ from `tile_energy` only by float re-association of the
            // identical term sequence.
            let total = quad_energy_total(&qe);
            let te = tile_energy(splats, list, rect);
            ensure(
                (total - te).abs() <= 1e-5 * (1.0 + te.abs()),
                format!("quadrant total {total} drifted from tile energy {te}"),
            )?;
            // With at most one active accumulator there is nothing to
            // re-associate: the totals agree bitwise.
            if qe.iter().filter(|e| **e != 0.0).count() <= 1 {
                ensure(
                    total.to_bits() == te.to_bits(),
                    format!("single-quadrant total {total} != tile energy {te} bitwise"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rect_class_maps_are_a_pure_function_of_the_plan() {
    // The determinism contract behind `--precision rect`: the class map
    // depends only on (scene, camera, thresholds) — never on the worker
    // count or PJRT batch width — and delta-advanced plans carry the same
    // maps as cold builds of the same pose.
    use flicker::cat::Precision;
    use flicker::render::precision::{PrecisionMode, PrecisionPolicy, PrecisionThresholds};
    let scene = generate_scaled(&preset("truck"), 0.005);
    check(
        "rect class maps ignore workers/batch and survive deltas",
        PropConfig::default(),
        |rng, _| {
            let fp16_min = rng.range_f32(0.0, 0.5);
            let fp32_min = fp16_min + rng.range_f32(0.0, 0.5);
            let angle = rng.range_f32(0.0, std::f32::consts::TAU);
            let step = rng.range_f32(0.0, 0.015);
            let workers = *rng.pick(&[1usize, 2, 8, 0]);
            let batch = *rng.pick(&[1usize, 2, 8]);
            let floor = *rng.pick(&[Precision::Mixed, Precision::Fp8, Precision::Fp16]);
            (fp32_min, fp16_min, angle, step, workers, batch, floor)
        },
        |&(fp32_min, fp16_min, angle, step, workers, batch, floor)| {
            let cam_at = |a: f32| {
                Camera::look_at(
                    Intrinsics::from_fov(64, 64, 1.2),
                    v3(12.0 * a.cos(), 3.0, 12.0 * a.sin()),
                    v3(0.0, 0.5, 0.0),
                    v3(0.0, 1.0, 0.0),
                )
            };
            let base = RenderOptions {
                precision: PrecisionPolicy {
                    mode: PrecisionMode::Rect {
                        thresholds: PrecisionThresholds { fp32_min, fp16_min },
                        floor,
                    },
                },
                plan_delta: DeltaConfig::on(),
                ..RenderOptions::default()
            };
            let cam = cam_at(angle);
            let reference = FramePlan::build(&scene, &cam, &base);
            let ref_maps = reference
                .tile_rect_classes()
                .ok_or("rect plan did not class its tiles")?;
            let alt = RenderOptions { workers, batch, ..base };
            let varied = FramePlan::build(&scene, &cam, &alt);
            ensure(
                varied.tile_rect_classes().as_deref() == Some(&ref_maps[..]),
                format!("workers {workers} / batch {batch} changed the class map"),
            )?;
            let out = reference.advance_detailed(&scene, &cam_at(angle + step), &base);
            let cold = FramePlan::build(&scene, &cam_at(angle + step), &base);
            ensure(
                out.plan.tile_rect_classes() == cold.tile_rect_classes(),
                format!("delta-advanced maps diverged (step {step}, fallback {})",
                    out.stats.fell_back),
            )?;
            Ok(())
        },
    );
}

#[test]
fn prop_quadrant_stitching_claims_each_pixel_exactly_once() {
    // The stitching contract shared by the CAT mask path and the PJRT
    // host compositor: the four quadrant mini-tile masks partition the
    // tile, `quad_of_pixel` sends every pixel to the quadrant owning its
    // mini-tile, and a stitched rect-mask provider reproduces, inside each
    // quadrant, exactly the dedicated single-class engine's bits.
    use flicker::cat::{CatConfig, LeaderMode, Precision};
    use flicker::render::project::Splat;
    use flicker::render::pyramid::{quad_of_pixel, TilePyramid};
    use flicker::render::raster::{MaskSource, MINITILE};
    check(
        "quadrant masks partition; stitched masks claim pixels once",
        PropConfig::default(),
        |rng, _| {
            let tx = rng.range_u32(0, 3) as f32;
            let ty = rng.range_u32(0, 3) as f32;
            let rect = Rect {
                x0: tx * 16.0,
                y0: ty * 16.0,
                x1: tx * 16.0 + *rng.pick(&[16.0f32, 16.0, 11.0, 6.0]),
                y1: ty * 16.0 + *rng.pick(&[16.0f32, 16.0, 9.0, 5.0]),
            };
            let splat = Splat {
                id: 0,
                mean: v2(rng.range_f32(-8.0, 72.0), rng.range_f32(-8.0, 72.0)),
                cov: Sym2 { a: 1.0, b: 0.0, c: 1.0 },
                conic: random_conic(rng),
                depth: 1.0,
                opacity: rng.range_f32(0.05, 1.0),
                color: [1.0; 3],
                radius: 8.0,
                axis_ratio: 1.0,
            };
            let all = [Precision::Fp32, Precision::Fp16, Precision::Mixed, Precision::Fp8];
            let classes: [Precision; 4] = std::array::from_fn(|_| *rng.pick(&all));
            (rect, splat, classes)
        },
        |&(rect, splat, classes)| {
            let pyr = TilePyramid::new(&rect, 16);
            // (1) The quadrant mini-tile masks are pairwise disjoint...
            let mut union = 0u32;
            for q in 0..4 {
                let m = pyr.quad_minitile_mask(q);
                ensure(union & m == 0, format!("quadrant {q} overlaps an earlier one"))?;
                union |= m;
            }
            // ...and cover every pixel of the rect through the quadrant
            // `quad_of_pixel` routes it to.
            let mt_cols = 16u32.div_ceil(MINITILE);
            for py in rect.y0 as u32..rect.y1 as u32 {
                for px in rect.x0 as u32..rect.x1 as u32 {
                    let row = (py - rect.y0 as u32) / MINITILE;
                    let col = (px - rect.x0 as u32) / MINITILE;
                    let bit = 1u32 << (row * mt_cols + col);
                    ensure(union & bit != 0, format!("({px},{py}): mini-tile unowned"))?;
                    let q = quad_of_pixel(&rect, 16, px, py);
                    ensure(
                        pyr.quad_minitile_mask(q) & bit != 0,
                        format!("({px},{py}): routed to quadrant {q}, owned elsewhere"),
                    )?;
                }
            }
            // (2) Stitched masks: inside each quadrant the stitched
            // provider's bits equal the dedicated engine at that
            // quadrant's class — so each pixel is decided by exactly one
            // class engine.
            let cfg = CatConfig {
                mode: LeaderMode::SmoothFocused,
                precision: Precision::Mixed,
                stage1: true,
            };
            let stitched = cfg.tile_masks_rect(16, classes).mask(&rect, &splat);
            ensure(stitched & !union == 0, "stitched mask claims unowned mini-tiles")?;
            for q in 0..4 {
                let own = pyr.quad_minitile_mask(q);
                let dedicated = cfg.tile_masks_at(classes[q]).mask(&rect, &splat);
                ensure(
                    stitched & own == dedicated & own,
                    format!(
                        "quadrant {q} ({:?}): stitched {:#x} != dedicated {:#x} in {own:#x}",
                        classes[q],
                        stitched & own,
                        dedicated & own
                    ),
                )?;
            }
            Ok(())
        },
    );
}
