//! Multi-tenant service differential harness.
//!
//! The `coordinator::service::RenderService` contract: N clients
//! interleaved through one service — shared scene store, cross-session
//! plan cache, shared worker pool, and (stub-pjrt) the cross-client tile
//! coalescer — produce frames **bit-identical** to N isolated `Session`s
//! rendering the same (scene, camera, options). The matrix covers pool
//! sizes 1/2/8/0, gate on/off, temporal plan deltas, adaptive precision,
//! and (pjrt) coalesced executor batches 1/2/8. Counter invariants
//! (`hits + builds + delta_builds == requests`) ride along.

use flicker::camera::{orbit_path, Camera, Intrinsics};
use flicker::config::ExperimentConfig;
use flicker::coordinator::{
    Golden, RenderService, ServiceConfig, ServiceFrame, ServiceStats, Session,
};
use flicker::numeric::linalg::v3;
use flicker::render::delta::DeltaConfig;
use flicker::render::precision::PrecisionPolicy;
use flicker::render::pyramid::GateConfig;
use flicker::render::raster::RenderOptions;
use flicker::scene::gaussian::Scene;
use flicker::scene::synthetic::{generate_scaled, preset};
use std::collections::BTreeMap;

const CLIENTS: usize = 3;

fn scene() -> Scene {
    generate_scaled(&preset("truck"), 0.02)
}

/// Ragged per-client trajectories: client `c` renders `3 + c` views with a
/// client-specific stride around a shared 12-view orbit, so clients differ
/// in frame count AND pose sequence, while some poses recur across (and
/// within) clients — exercising cross-client plan-cache hits.
fn client_orbit(c: usize) -> Vec<Camera> {
    let intr = Intrinsics::from_fov(64, 64, 1.2);
    let full = orbit_path(intr, v3(0.0, 0.5, 0.0), 12.0, 2.5, 12);
    (0..3 + c).map(|i| full[(i * (c + 1)) % full.len()]).collect()
}

/// One isolated `Session` per client, rendered sequentially — the ground
/// truth the service must reproduce bitwise.
fn isolated_frames(
    sc: &Scene,
    opts: RenderOptions,
) -> Vec<Vec<flicker::coordinator::FrameMetrics>> {
    (0..CLIENTS)
        .map(|c| {
            let s = Session::builder(ExperimentConfig::default())
                .scene(sc.clone())
                .cameras(client_orbit(c))
                .options(opts)
                .build()
                .unwrap();
            (0..s.num_frames())
                .map(|i| s.frame(i, &Golden).unwrap())
                .collect()
        })
        .collect()
}

/// Submit every client's orbit round-robin-interleaved (view 0 of each
/// client, then view 1, …) through `Session::service_requests`, then drain
/// through the golden backend.
fn service_frames(
    sc: &Scene,
    opts: RenderOptions,
    workers: usize,
    window: usize,
) -> (Vec<ServiceFrame>, ServiceStats) {
    let svc = RenderService::new(ServiceConfig {
        workers,
        window,
        max_queue: 256,
        ..Default::default()
    });
    let id = svc.register_scene(sc.clone());
    let per_client: Vec<Vec<_>> = (0..CLIENTS)
        .map(|c| {
            let s = Session::builder(ExperimentConfig::default())
                .scene(sc.clone())
                .cameras(client_orbit(c))
                .options(opts)
                .build()
                .unwrap();
            s.service_requests(c, id)
        })
        .collect();
    let longest = per_client.iter().map(Vec::len).max().unwrap();
    for v in 0..longest {
        for reqs in &per_client {
            if let Some(&r) = reqs.get(v) {
                svc.submit(r).unwrap();
            }
        }
    }
    let frames = svc.drain(&Golden).unwrap();
    let stats = svc.stats();
    (frames, stats)
}

/// Index completion-order service output by `(client, view)` — the
/// re-join the `client` tag exists for.
fn rejoin(frames: &[ServiceFrame]) -> BTreeMap<(usize, usize), &ServiceFrame> {
    frames
        .iter()
        .map(|f| ((f.metrics.client, f.metrics.view), f))
        .collect()
}

#[test]
fn interleaved_clients_match_isolated_sessions_bitwise() {
    let sc = scene();
    let configs = [
        ("default", RenderOptions::default()),
        (
            "gate",
            RenderOptions {
                gate: GateConfig::on(),
                ..RenderOptions::default()
            },
        ),
        (
            "gate+delta",
            RenderOptions {
                gate: GateConfig::on(),
                plan_delta: DeltaConfig::on(),
                ..RenderOptions::default()
            },
        ),
        (
            "adaptive",
            RenderOptions {
                precision: PrecisionPolicy::adaptive(),
                ..RenderOptions::default()
            },
        ),
    ];
    for (name, opts) in configs {
        let isolated = isolated_frames(&sc, opts);
        let total: usize = isolated.iter().map(Vec::len).sum();
        for workers in [1usize, 2, 8, 0] {
            let (frames, st) = service_frames(&sc, opts, workers, 0);
            assert_eq!(frames.len(), total, "cfg {name} workers {workers}");
            let joined = rejoin(&frames);
            for (c, client_frames) in isolated.iter().enumerate() {
                for (v, truth) in client_frames.iter().enumerate() {
                    let f = joined[&(c, v)];
                    assert_eq!(
                        f.metrics.image.data, truth.image.data,
                        "cfg {name} workers {workers} client {c} view {v}: \
                         interleaved pixels diverged from the isolated session"
                    );
                    assert_eq!(
                        f.metrics.stats.pairs_blended, truth.stats.pairs_blended,
                        "cfg {name} workers {workers} client {c} view {v}: stats"
                    );
                    assert_eq!(
                        f.metrics.stats.gate_tile_rejected, truth.stats.gate_tile_rejected,
                        "cfg {name} workers {workers} client {c} view {v}: gate"
                    );
                }
            }
            assert_eq!(
                st.plan_requests,
                st.plan_hits + st.plan_builds + st.plan_delta_builds,
                "cfg {name} workers {workers}: plan counter invariant"
            );
            assert_eq!(st.completed, total as u64, "cfg {name} workers {workers}");
            // The ragged orbits visit 7 distinct poses across 12 requests.
            // Sequential draining (workers == 1; 0 resolves to auto, which
            // is parallel) materializes each pose exactly once; parallel
            // workers may race-build the same pose (first publish wins),
            // so there only the counter invariant above holds.
            if workers == 1 {
                assert_eq!(
                    st.plan_builds + st.plan_delta_builds,
                    7,
                    "cfg {name} workers {workers}: one materialization per distinct pose"
                );
                assert_eq!(st.plan_hits, 5, "cfg {name} workers {workers}: repeat poses hit");
            }
        }
    }
}

#[test]
fn shared_pool_reuse_is_bit_identical_to_fresh_inline_workers() {
    // Satellite contract: one persistent WorkerPool serving every drain is
    // bit-identical to inline (pool-free) execution, and a pool reused
    // across drains (warm threads, warm plan cache) changes nothing.
    let sc = scene();
    let opts = RenderOptions::default();
    let (pooled, _) = service_frames(&sc, opts, 4, 2);
    let (inline, _) = service_frames(&sc, opts, 1, 1);
    let (a, b) = (rejoin(&pooled), rejoin(&inline));
    assert_eq!(a.len(), b.len());
    for (key, f) in &a {
        assert_eq!(
            f.metrics.image.data, b[key].metrics.image.data,
            "pooled vs inline diverged at {key:?}"
        );
    }

    let svc = RenderService::new(ServiceConfig {
        workers: 4,
        max_queue: 256,
        ..Default::default()
    });
    let id = svc.register_scene(sc.clone());
    let s = Session::builder(ExperimentConfig::default())
        .scene(sc.clone())
        .cameras(client_orbit(0))
        .options(opts)
        .build()
        .unwrap();
    for r in s.service_requests(0, id) {
        svc.submit(r).unwrap();
    }
    let first = svc.drain(&Golden).unwrap();
    for r in s.service_requests(0, id) {
        svc.submit(r).unwrap();
    }
    let second = svc.drain(&Golden).unwrap();
    let (fa, fb) = (rejoin(&first), rejoin(&second));
    for (key, f) in &fa {
        assert_eq!(
            f.metrics.image.data, fb[key].metrics.image.data,
            "second drain (reused pool, all cache hits) diverged at {key:?}"
        );
    }
    let st = svc.stats();
    assert_eq!(
        st.plan_hits,
        first.len(),
        "the second pass must be served entirely from the plan cache"
    );
}

/// Stub-backed PJRT coalescing: all clients' tiles through shared
/// precision-pure waves, bit-identical to per-client `Pjrt` sessions.
#[cfg(feature = "pjrt")]
mod pjrt_service {
    use super::*;
    use flicker::coordinator::Pjrt;
    use flicker::runtime::{write_stub_artifacts, Runtime};

    fn stub_runtime() -> Option<Runtime> {
        let dir = std::env::temp_dir().join("flicker_service_stub");
        write_stub_artifacts(&dir, 48, 16, 16, 8).unwrap();
        match Runtime::load(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn coalesced_drain_matches_isolated_pjrt_sessions() {
        let Some(rt) = stub_runtime() else { return };
        let sc = scene();
        let configs = [
            ("default", RenderOptions::default()),
            (
                "gate",
                RenderOptions {
                    gate: GateConfig::on(),
                    ..RenderOptions::default()
                },
            ),
            (
                "adaptive",
                RenderOptions {
                    precision: PrecisionPolicy::adaptive(),
                    ..RenderOptions::default()
                },
            ),
        ];
        for (name, opts) in configs {
            let pjrt = Pjrt::new(&rt);
            let isolated: Vec<Vec<_>> = (0..CLIENTS)
                .map(|c| {
                    let s = Session::builder(ExperimentConfig::default())
                        .scene(sc.clone())
                        .cameras(client_orbit(c))
                        .options(opts)
                        .build()
                        .unwrap();
                    (0..s.num_frames())
                        .map(|i| s.frame(i, &pjrt).unwrap())
                        .collect()
                })
                .collect();
            for batch in [1usize, 2, 8] {
                let svc = RenderService::new(ServiceConfig {
                    workers: 1,
                    batch,
                    max_queue: 256,
                    ..Default::default()
                });
                let id = svc.register_scene(sc.clone());
                for c in 0..CLIENTS {
                    let s = Session::builder(ExperimentConfig::default())
                        .scene(sc.clone())
                        .cameras(client_orbit(c))
                        .options(opts)
                        .build()
                        .unwrap();
                    for r in s.service_requests(c, id) {
                        svc.submit(r).unwrap();
                    }
                }
                let (frames, ex) = svc.drain_coalesced(&rt).unwrap();
                let joined = rejoin(&frames);
                for (c, client_frames) in isolated.iter().enumerate() {
                    for (v, truth) in client_frames.iter().enumerate() {
                        let f = joined[&(c, v)];
                        assert_eq!(
                            f.metrics.image.data, truth.image.data,
                            "cfg {name} batch {batch} client {c} view {v}: \
                             coalesced waves changed pixels"
                        );
                        assert_eq!(
                            f.metrics.stats.splats_submitted, truth.stats.splats_submitted,
                            "cfg {name} batch {batch} client {c} view {v}: stats"
                        );
                        assert_eq!(f.metrics.backend, "pjrt+coalesced");
                    }
                }
                assert!(
                    ex.splats_submitted <= ex.rows_submitted,
                    "cfg {name} batch {batch}: padding accounting"
                );
            }
        }
    }
}
