//! Differential harness for rect-mode (quadrant-rectangle) precision
//! classing: when the thresholds force every quadrant to a single class,
//! the rect pipeline must collapse to the per-tile adaptive path **at that
//! class, bitwise** — same class maps, same pixels, same stats — through
//! the golden rasterizer, the CAT-masked rasterizer, and the batched PJRT
//! executor, for every worker count and batch width, and across
//! delta-advanced plans. Classing is a pure function of the plan; these
//! tests are the contract that keeps the rect refinement inside the
//! worker/batch/delta invariance envelope PR 8 established for tiles.

use flicker::camera::{orbit_path, Camera, Intrinsics};
use flicker::cat::{CatConfig, LeaderMode, Precision};
use flicker::numeric::linalg::v3;
use flicker::render::delta::DeltaConfig;
use flicker::render::plan::FramePlan;
use flicker::render::precision::{
    PrecisionMode, PrecisionPolicy, PrecisionThresholds, TileClassMap,
};
use flicker::render::raster::{RenderOptions, VanillaMasks};
use flicker::scene::synthetic::{generate_scaled, preset};

fn orbit(res: u32, frames: usize) -> Vec<Camera> {
    orbit_path(
        Intrinsics::from_fov(res, res, 1.2),
        v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        frames,
    )
}

fn rect_policy(thresholds: PrecisionThresholds, floor: Precision) -> PrecisionPolicy {
    PrecisionPolicy {
        mode: PrecisionMode::Rect { thresholds, floor },
    }
}

fn tile_policy(thresholds: PrecisionThresholds, floor: Precision) -> PrecisionPolicy {
    PrecisionPolicy {
        mode: PrecisionMode::Adaptive { thresholds, floor },
    }
}

fn cat() -> CatConfig {
    CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Mixed,
        stage1: true,
    }
}

/// Threshold settings that force every tile — and therefore every quadrant
/// (the quadrant ladder caps at the tile level) — to one class:
/// `(thresholds, floor, the forced class)`.
fn forced_cases() -> [(PrecisionThresholds, Precision, Precision); 3] {
    [
        // Everything clears a zero fp32 bar.
        (
            PrecisionThresholds { fp32_min: 0.0, fp16_min: 0.0 },
            Precision::Mixed,
            Precision::Fp32,
        ),
        // Nothing reaches 9.0, everything clears the zero fp16 bar.
        (
            PrecisionThresholds { fp32_min: 9.0, fp16_min: 0.0 },
            Precision::Mixed,
            Precision::Fp16,
        ),
        // Nothing clears either bar: everything floors.
        (
            PrecisionThresholds { fp32_min: 9.0, fp16_min: 9.0 },
            Precision::Fp8,
            Precision::Fp8,
        ),
    ]
}

/// Every rect map must have collapsed to `Uniform(expect)`.
fn assert_maps_forced(plan: &FramePlan, expect: Precision, ctx: &str) {
    let maps = plan.tile_rect_classes().expect("rect plans class every tile");
    for (t, m) in maps.iter().enumerate() {
        assert_eq!(
            *m,
            TileClassMap::Uniform(expect),
            "{ctx}: tile {t} did not collapse to the forced class"
        );
    }
}

#[test]
fn forced_rect_matches_per_tile_class_for_golden_paths() {
    let scene = generate_scaled(&preset("truck"), 0.01);
    let cams = orbit(64, 2);
    for (thresholds, floor, expect) in forced_cases() {
        for workers in [1usize, 2, 8, 0] {
            let rect_opts = RenderOptions {
                precision: rect_policy(thresholds, floor),
                workers,
                ..RenderOptions::default()
            };
            let tile_opts = RenderOptions {
                precision: tile_policy(thresholds, floor),
                workers,
                ..RenderOptions::default()
            };
            for (v, cam) in cams.iter().enumerate() {
                let ctx = format!("class {expect:?} workers {workers} view {v}");
                let rp = FramePlan::build(&scene, cam, &rect_opts);
                let tp = FramePlan::build(&scene, cam, &tile_opts);
                assert_maps_forced(&rp, expect, &ctx);
                // The per-tile plan classes every tile at the same class.
                for (t, c) in tp.tile_classes().unwrap().iter().enumerate() {
                    assert_eq!(*c, expect, "{ctx}: adaptive tile {t}");
                }
                // Golden: class-blind masks — bitwise regardless of class.
                let (rv, tv) = (rp.render(&VanillaMasks, None), tp.render(&VanillaMasks, None));
                assert_eq!(rv.image.data, tv.image.data, "{ctx}: vanilla pixels");
                assert_eq!(
                    format!("{:?}", rv.stats),
                    format!("{:?}", tv.stats),
                    "{ctx}: vanilla stats"
                );
                // GoldenCat: the engine runs at the forced class in both.
                let c = cat();
                let (rc, tc) = (rp.render(&c, None), tp.render(&c, None));
                assert_eq!(rc.image.data, tc.image.data, "{ctx}: CAT pixels");
                assert_eq!(
                    format!("{:?}", rc.stats),
                    format!("{:?}", tc.stats),
                    "{ctx}: CAT stats"
                );
            }
        }
    }
}

#[test]
fn forced_rect_survives_delta_advanced_plans() {
    // `--plan-delta on`: an advance-chained rect plan must carry the same
    // forced maps and render bitwise like the cold per-tile build.
    let scene = generate_scaled(&preset("garden"), 0.01);
    let cams = orbit(64, 12);
    for (thresholds, floor, expect) in forced_cases() {
        let rect_opts = RenderOptions {
            precision: rect_policy(thresholds, floor),
            plan_delta: DeltaConfig::on(),
            ..RenderOptions::default()
        };
        let tile_opts = RenderOptions {
            precision: tile_policy(thresholds, floor),
            ..RenderOptions::default()
        };
        let mut plan = FramePlan::build(&scene, &cams[0], &rect_opts);
        for step in 1..4usize {
            let out = plan.advance_detailed(&scene, &cams[step], &rect_opts);
            assert!(!out.stats.fell_back, "class {expect:?} step {step}: fallback");
            let ctx = format!("class {expect:?} delta step {step}");
            assert_maps_forced(&out.plan, expect, &ctx);
            let cold_rect = FramePlan::build(&scene, &cams[step], &rect_opts);
            assert_eq!(
                out.plan.tile_rect_classes(),
                cold_rect.tile_rect_classes(),
                "{ctx}: advanced maps != cold maps"
            );
            let cold_tile = FramePlan::build(&scene, &cams[step], &tile_opts);
            let c = cat();
            let (a, b) = (out.plan.render(&c, None), cold_tile.render(&c, None));
            assert_eq!(a.image.data, b.image.data, "{ctx}: CAT pixels");
            plan = out.plan;
        }
    }
}

#[test]
fn rect_maps_are_a_pure_function_of_the_view() {
    // At the real default thresholds (genuinely mixed maps), the class map
    // must not depend on worker count, and rendering must be bit-identical
    // across the worker matrix — classing happens strictly before fan-out.
    let scene = generate_scaled(&preset("truck"), 0.01);
    let cam = &orbit(96, 2)[0];
    let opts = |workers: usize| RenderOptions {
        precision: PrecisionPolicy::rect(),
        workers,
        ..RenderOptions::default()
    };
    let reference = FramePlan::build(&scene, cam, &opts(1));
    let ref_maps = reference.tile_rect_classes().unwrap();
    let mixed = ref_maps
        .iter()
        .filter(|m| matches!(m, TileClassMap::Mixed(_)))
        .count();
    assert!(mixed > 0, "default thresholds must produce some mixed tiles");
    let c = cat();
    let ref_out = reference.render(&c, None);
    for workers in [2usize, 8, 0] {
        let plan = FramePlan::build(&scene, cam, &opts(workers));
        assert_eq!(plan.tile_rect_classes().unwrap(), ref_maps, "workers {workers}");
        let out = plan.render(&c, None);
        assert_eq!(out.image.data, ref_out.image.data, "workers {workers}: pixels");
        assert_eq!(
            format!("{:?}", out.stats),
            format!("{:?}", ref_out.stats),
            "workers {workers}: stats"
        );
    }
}

/// The PJRT half of the contract, against the offline stub runtime (skips
/// on real-XLA builds that cannot parse the placeholder artifacts).
#[cfg(feature = "pjrt")]
mod pjrt_rect {
    use super::*;
    use flicker::coordinator::{Pjrt, RenderBackend};
    use flicker::render::image::Image;
    use flicker::render::project::project_scene;
    use flicker::render::sort::sort_by_depth;
    use flicker::render::tile::{build_tile_lists, Strategy, TileGrid};
    use flicker::runtime::executor::{TileExecutor, TileJob};
    use flicker::runtime::{write_stub_artifacts, Runtime};
    use flicker::scene::gaussian::Scene;

    fn stub_runtime(tag: &str, n_gauss: usize, n_batch: usize) -> Option<Runtime> {
        let dir = std::env::temp_dir().join(format!("flicker_precision_rect_stub_{tag}"));
        write_stub_artifacts(&dir, n_gauss, 16, 16, n_batch).unwrap();
        match Runtime::load(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn forced_rect_matches_per_tile_class_through_batched_waves() {
        let Some(rt) = stub_runtime("forced", 64, 8) else { return };
        let pjrt = Pjrt::new(&rt);
        let scene = generate_scaled(&preset("truck"), 0.01);
        let cam = &orbit(64, 2)[0];
        for (thresholds, floor, expect) in forced_cases() {
            for batch in [1usize, 2, 8] {
                let rect_opts = RenderOptions {
                    precision: rect_policy(thresholds, floor),
                    batch,
                    ..RenderOptions::default()
                };
                let tile_opts = RenderOptions {
                    precision: tile_policy(thresholds, floor),
                    batch,
                    ..RenderOptions::default()
                };
                let ctx = format!("class {expect:?} batch {batch}");
                let rp = FramePlan::build(&scene, cam, &rect_opts);
                assert_maps_forced(&rp, expect, &ctx);
                let tp = FramePlan::build(&scene, cam, &tile_opts);
                let a = pjrt.render_plan(&rp).unwrap();
                let b = pjrt.render_plan(&tp).unwrap();
                assert_eq!(a.image.data, b.image.data, "{ctx}: pjrt pixels");
            }
        }
    }

    /// The latent seam bug class: a Gaussian straddling two rects of
    /// different class must blend identically whether its chunks are
    /// dispatched through the fp32 wave first or the fp16 wave first.
    /// Each class's wave runs the tile's full chunk sequence against its
    /// own accumulator and the compositor stitches disjoint quadrant
    /// pixels, so wave order must be unobservable.
    #[test]
    fn quadrant_seam_blend_is_wave_order_independent() {
        use flicker::numeric::linalg::Quat;
        // n_gauss 2 < the 3-splat list: each wave re-walks the full
        // multi-chunk sequence with its own transmittance carry.
        let Some(rt) = stub_runtime("seam", 2, 8) else { return };
        let cam = Camera::look_at(
            Intrinsics::from_fov(32, 32, 1.2),
            v3(0.0, 0.0, -6.0),
            v3(0.0, 0.0, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        // A wide splat centered at the image midpoint: it straddles every
        // tile's quadrant seams; two dimmer ones force multi-splat chunks.
        let mut scene = Scene::with_capacity(3, "seam");
        let sh0 = [[0.0; 3]; 3];
        scene.push(v3(0.0, 0.0, 0.0), Quat::IDENTITY, v3(0.7, 0.7, 0.7), 0.9, [1.5, 0.2, 0.1], sh0);
        scene.push(v3(0.3, 0.1, 1.0), Quat::IDENTITY, v3(0.4, 0.4, 0.4), 0.6, [0.1, 1.4, 0.2], sh0);
        scene.push(v3(-0.3, -0.1, 2.0), Quat::IDENTITY, v3(0.5, 0.5, 0.5), 0.5, [0.1, 0.2, 1.4], sh0);
        let splats = project_scene(&scene, &cam);
        let grid = TileGrid::new(32, 32, 16);
        let mut lists = build_tile_lists(&splats, &grid, Strategy::Aabb);
        for l in &mut lists {
            sort_by_depth(l, &splats);
        }
        assert!(!lists[0].is_empty(), "seam splat must bin into tile 0");
        // Tile 0: fp32 TL, fp16 elsewhere — the seam splits the splat.
        let quads = [Precision::Fp32, Precision::Fp16, Precision::Fp16, Precision::Fp16];
        let job_at = |class: Precision| TileJob {
            rect: grid.rect(0),
            order: &lists[0],
            class: Some(class),
            quads: Some(quads),
        };
        let bg = [0.02, 0.02, 0.02];
        let mut fp32_first = Image::new(32, 32);
        let mut ex1 = TileExecutor::new(&rt);
        ex1.render_tiles(&[job_at(Precision::Fp32)], &splats, &mut fp32_first, bg).unwrap();
        ex1.render_tiles(&[job_at(Precision::Fp16)], &splats, &mut fp32_first, bg).unwrap();
        let mut fp16_first = Image::new(32, 32);
        let mut ex2 = TileExecutor::new(&rt);
        ex2.render_tiles(&[job_at(Precision::Fp16)], &splats, &mut fp16_first, bg).unwrap();
        ex2.render_tiles(&[job_at(Precision::Fp32)], &splats, &mut fp16_first, bg).unwrap();
        assert_eq!(
            fp32_first.data, fp16_first.data,
            "stitched tile depends on wave dispatch order"
        );
        // The straddling splat really lands on both sides of the seam.
        let lit = |img: &Image, x: u32, y: u32| img.get(x, y) != [bg[0], bg[1], bg[2]];
        assert!(lit(&fp32_first, 7, 7), "TL side of the seam is dark");
        assert!(lit(&fp32_first, 8, 7), "TR side of the seam is dark");
        // And the one-queue path (CLASSES-ordered waves) agrees with both.
        let mut one_call = Image::new(32, 32);
        let mut ex3 = TileExecutor::new(&rt);
        ex3.render_tiles(
            &[job_at(Precision::Fp32), job_at(Precision::Fp16)],
            &splats,
            &mut one_call,
            bg,
        )
        .unwrap();
        assert_eq!(one_call.data, fp32_first.data, "one-queue render diverges");
    }
}
