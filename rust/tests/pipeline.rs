//! Cross-module integration tests: the full functional pipeline
//! (scene → projection → tiling → CAT → raster → metrics), the simulator
//! on top of it, and the agreement contracts between configurations.

use flicker::camera::{orbit_path, Camera, Intrinsics};
use flicker::cat::{CatConfig, CatEngine, LeaderMode, ObbSubtileMask, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::{Golden, GoldenCat, RenderBackend, Session};
use flicker::numeric::linalg::v3;
use flicker::render::metrics::{psnr, ssim};
use flicker::render::plan::FramePlan;
use flicker::render::raster::{render, RenderOptions, VanillaMasks};
use flicker::scene::clustering::cluster;
use flicker::scene::pruning::{prune, PruneConfig};
use flicker::scene::synthetic::{generate_scaled, preset};
use flicker::sim::top::simulate_frame;
use flicker::sim::workload::extract;
use flicker::sim::{HwConfig, SubtileTest};

fn scene(name: &str) -> flicker::scene::gaussian::Scene {
    generate_scaled(&preset(name), 0.015)
}

fn cam(res: u32) -> Camera {
    Camera::look_at(
        Intrinsics::from_fov(res, res, 1.2),
        v3(0.0, 2.5, -12.0),
        v3(0.0, 0.5, 0.0),
        v3(0.0, 1.0, 0.0),
    )
}

#[test]
fn full_quality_ladder_ordering() {
    // Vanilla ≥ dense-CAT ≥ adaptive-CAT ≥ sparse-CAT in per-pixel work,
    // while PSNR never falls off a cliff for dense.
    let s = scene("garden");
    let c = cam(128);
    let opts = RenderOptions::default();
    // The sweep pattern: one FramePlan reused across the golden render and
    // every CAT mode.
    let plan = FramePlan::build(&s, &c, &opts);
    let golden = plan.render(&VanillaMasks, None);

    let run = |mode| {
        let mut e = CatEngine::new(CatConfig {
            mode,
            precision: Precision::Fp32,
            stage1: true,
        });
        plan.render_with(&mut e, None)
    };
    let dense = run(LeaderMode::UniformDense);
    let adaptive = run(LeaderMode::SmoothFocused);
    let sparse = run(LeaderMode::UniformSparse);

    assert!(dense.stats.pairs_tested <= golden.stats.pairs_tested);
    assert!(adaptive.stats.pairs_tested <= dense.stats.pairs_tested);
    assert!(sparse.stats.pairs_tested <= adaptive.stats.pairs_tested);
    assert!(psnr(&golden.image, &dense.image) > 33.0);
}

#[test]
fn cat_beats_obb_subtile_on_work_at_similar_quality() {
    let s = scene("bicycle");
    let c = cam(128);
    let opts = RenderOptions::default();
    let plan = FramePlan::build(&s, &c, &opts);
    let golden = plan.render(&VanillaMasks, None);

    let mut obb = ObbSubtileMask::new();
    let obb_out = plan.render_with(&mut obb, None);
    let mut catp = CatEngine::new(CatConfig::default());
    let cat_out = plan.render_with(&mut catp, None);

    assert!(
        cat_out.stats.pairs_tested < obb_out.stats.pairs_tested,
        "CAT {} vs OBB {}",
        cat_out.stats.pairs_tested,
        obb_out.stats.pairs_tested
    );
    // OBB-subtile only drops whole no-contribution sub-tiles so it is
    // near-lossless; CAT trades a bounded PSNR cost for the far larger
    // work cut. Require an absolute quality bar instead of parity.
    let p_cat = psnr(&golden.image, &cat_out.image);
    let p_obb = psnr(&golden.image, &obb_out.image);
    assert!(p_cat > 32.0, "cat {p_cat} (obb {p_obb})");
}

#[test]
fn prune_then_cluster_then_simulate_composes() {
    let mut s = scene("truck");
    let views = orbit_path(Intrinsics::from_fov(96, 96, 1.2), v3(0.0, 0.5, 0.0), 12.0, 3.0, 3);
    prune(&mut s, &views, &PruneConfig::default());
    let cl = cluster(&s, 32);
    assert!(cl.num_clusters() > 0);
    let r = simulate_frame(&s, &views[0], &HwConfig::flicker32());
    assert!(r.render_cycles > 0);
    assert!(r.traffic.cull_bytes > 0, "clustered config must read descriptors");
    assert!(r.energy.total_uj() > 0.0);
}

#[test]
fn simulator_work_scales_with_scene_size() {
    let c = cam(128);
    let small = generate_scaled(&preset("garden"), 0.008);
    let large = generate_scaled(&preset("garden"), 0.03);
    let rs = simulate_frame(&small, &c, &HwConfig::flicker32());
    let rl = simulate_frame(&large, &c, &HwConfig::flicker32());
    assert!(
        rl.render_cycles > rs.render_cycles,
        "large {} vs small {}",
        rl.render_cycles,
        rs.render_cycles
    );
}

#[test]
fn all_eight_scenes_render_and_simulate() {
    let c = cam(96);
    for p in flicker::scene::synthetic::presets() {
        let s = generate_scaled(&p, 0.006);
        let out = render(&s, &c, &RenderOptions::default());
        assert!(out.stats.splats > 0, "{}: no visible splats", p.name);
        let r = simulate_frame(&s, &c, &HwConfig::flicker32());
        assert!(r.fps > 0.0, "{}: bad fps", p.name);
        assert!(r.frame_cycles >= r.render_cycles.min(r.preprocess_cycles));
    }
}

#[test]
fn backend_parity_golden_vs_cat_modes() {
    // One Session, one cached plan, four backends: the cmd_quality shape.
    let session = Session::builder(ExperimentConfig::default())
        .scene(scene("playroom"))
        .cameras(vec![cam(96)])
        .build()
        .unwrap();
    let precisions = [Precision::Fp32, Precision::Fp16, Precision::Mixed];
    let cats: Vec<GoldenCat> = precisions
        .iter()
        .map(|&precision| {
            GoldenCat(CatConfig {
                mode: LeaderMode::UniformDense,
                precision,
                stage1: true,
            })
        })
        .collect();
    let mut backends: Vec<&dyn RenderBackend> = vec![&Golden];
    backends.extend(cats.iter().map(|b| b as &dyn RenderBackend));
    let outs = session.sweep(0, &backends).unwrap();
    assert_eq!(
        session.plan_cache_stats().builds,
        1,
        "the sweep must share one FramePlan across all backends"
    );
    let golden = &outs[0];
    for (precision, m) in precisions.iter().zip(&outs[1..]) {
        let p = psnr(&golden.image, &m.image);
        assert!(p > 30.0, "{precision:?}: PSNR {p}");
        let sm = ssim(&golden.image, &m.image);
        assert!(sm > 0.9, "{precision:?}: SSIM {sm}");
    }
}

#[test]
fn workload_counters_are_internally_consistent() {
    let s = scene("stump");
    let c = cam(128);
    let wl = extract(&s, &c, &HwConfig::flicker32());
    // Funnel: stage1 ≥ stage2 ≥ (jobs with nonzero masks).
    assert!(wl.stage1_pairs >= wl.stage2_pairs);
    assert_eq!(wl.stage2_pairs, wl.dense_jobs + wl.sparse_jobs);
    // Every mini-tile pair implies its job passed stage 2 (≤ 4 per pair).
    assert!(wl.minitile_pairs <= wl.stage2_pairs * 4);
    // PRs: dense jobs contribute 4, sparse 2.
    assert_eq!(wl.ctu_prs, wl.dense_jobs * 4 + wl.sparse_jobs * 2);
    // Blends can't exceed mini-tile pairs × 16 pixels.
    assert!(wl.blended_pairs <= wl.minitile_pairs * 16);
}

#[test]
fn subtile_none_is_superset_of_aabb_of_obb() {
    let s = scene("flowers");
    let c = cam(128);
    let none = extract(&s, &c, &HwConfig { subtile_test: SubtileTest::None, ..HwConfig::flicker32() });
    let aabb = extract(&s, &c, &HwConfig::flicker32());
    let obb = extract(&s, &c, &HwConfig { subtile_test: SubtileTest::Obb, ..HwConfig::flicker32() });
    assert!(none.stage2_pairs >= aabb.stage2_pairs);
    assert!(aabb.stage2_pairs >= obb.stage2_pairs);
}

#[test]
fn experiment_config_end_to_end() {
    let cfg = ExperimentConfig {
        scene: "drjohnson".into(),
        scene_scale: 0.008,
        resolution: 64,
        frames: 2,
        hardware: "flicker32-sparse".into(),
        ..Default::default()
    };
    let s = cfg.build_scene().unwrap();
    let hw = cfg.build_hw().unwrap();
    assert_eq!(hw.cat_mode, LeaderMode::UniformSparse);
    let cams = cfg.build_cameras();
    let r = simulate_frame(&s, &cams[0], &hw);
    assert_eq!(r.workload.dense_jobs, 0, "sparse mode must not issue dense jobs");
}

#[test]
fn scene_io_preserves_render() {
    let s = scene("train");
    let c = cam(96);
    let img_a = render(&s, &c, &RenderOptions::default()).image;
    let dir = std::env::temp_dir().join("flicker_pipeline_io");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("train.gsz");
    flicker::scene::io::save(&s, &p).unwrap();
    let s2 = flicker::scene::io::load(&p).unwrap();
    let img_b = render(&s2, &c, &RenderOptions::default()).image;
    assert_eq!(img_a.mad(&img_b), 0.0, "IO roundtrip must be bit-exact");
}
