//! L1↔L3 consistency: the AOT JAX/Pallas artifacts executed through PJRT
//! must agree with the Rust golden implementations of the same math
//! (cat::pr for Alg. 1, the rasterizer for tile blending, render::project
//! for EWA projection). The whole file only compiles with `--features
//! pjrt`, and every test skips gracefully when `make artifacts` has not
//! run or when the `xla` dependency is the offline stub.
#![cfg(feature = "pjrt")]

use flicker::cat::pr::{pr_weights, shared_threshold};
use flicker::numeric::linalg::{v2, Sym2};
use flicker::runtime::{default_artifact_dir, Runtime};
use flicker::util::rng::Pcg32;

fn runtime() -> Option<Runtime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: pjrt runtime unavailable ({e})");
            None
        }
    }
}

fn random_conic(rng: &mut Pcg32) -> Sym2 {
    let l11 = rng.range_f32(0.05, 0.9);
    let l21 = rng.range_f32(-0.4, 0.4);
    let l22 = rng.range_f32(0.05, 0.9);
    Sym2 {
        a: l11 * l11,
        b: l11 * l21,
        c: l21 * l21 + l22 * l22,
    }
}

#[test]
fn pr_weight_artifact_matches_rust_alg1() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n_gauss;
    let m = rt.manifest.n_pr;
    let mut rng = Pcg32::new(0xA01);

    let mut mu = vec![0.0f32; n * 2];
    let mut conic = vec![0.0f32; n * 3];
    let mut conics = Vec::with_capacity(n);
    for i in 0..n {
        mu[i * 2] = rng.range_f32(0.0, 256.0);
        mu[i * 2 + 1] = rng.range_f32(0.0, 256.0);
        let c = random_conic(&mut rng);
        conic[i * 3] = c.a;
        conic[i * 3 + 1] = c.b;
        conic[i * 3 + 2] = c.c;
        conics.push(c);
    }
    let mut p_top = vec![0.0f32; m * 2];
    let mut p_bot = vec![0.0f32; m * 2];
    for k in 0..m {
        p_top[k * 2] = rng.range_f32(0.0, 250.0);
        p_top[k * 2 + 1] = rng.range_f32(0.0, 250.0);
        p_bot[k * 2] = p_top[k * 2] + rng.range_f32(1.0, 7.0);
        p_bot[k * 2 + 1] = p_top[k * 2 + 1] + rng.range_f32(1.0, 7.0);
    }

    let out = rt
        .exec_f32(
            "pr_weight",
            &[
                (&mu, &[n as i64, 2]),
                (&conic, &[n as i64, 3]),
                (&p_top, &[m as i64, 2]),
                (&p_bot, &[m as i64, 2]),
            ],
        )
        .unwrap();
    let e = &out[0]; // (M, N, 4)

    for k in 0..m {
        for i in (0..n).step_by(17) {
            let w = pr_weights(
                v2(mu[i * 2], mu[i * 2 + 1]),
                conics[i],
                v2(p_top[k * 2], p_top[k * 2 + 1]),
                v2(p_bot[k * 2], p_bot[k * 2 + 1]),
            );
            for c in 0..4 {
                let got = e[(k * n + i) * 4 + c];
                let want = w.e[c];
                let tol = 1e-3 * (1.0 + want.abs());
                assert!(
                    (got - want).abs() <= tol,
                    "PR {k} gaussian {i} corner {c}: pjrt {got} vs rust {want}"
                );
            }
        }
    }
}

#[test]
fn cat_masks_artifact_matches_rust_decision() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n_gauss;
    let m = rt.manifest.n_pr;
    let mut rng = Pcg32::new(0xA02);

    let mut mu = vec![0.0f32; n * 2];
    let mut conic = vec![0.0f32; n * 3];
    let mut opacity = vec![0.0f32; n];
    let mut conics = Vec::with_capacity(n);
    for i in 0..n {
        // Means near the PR region so both outcomes occur.
        mu[i * 2] = rng.range_f32(0.0, 64.0);
        mu[i * 2 + 1] = rng.range_f32(0.0, 64.0);
        let c = random_conic(&mut rng);
        conic[i * 3] = c.a;
        conic[i * 3 + 1] = c.b;
        conic[i * 3 + 2] = c.c;
        opacity[i] = rng.range_f32(0.01, 1.0);
        conics.push(c);
    }
    let mut p_top = vec![0.0f32; m * 2];
    let mut p_bot = vec![0.0f32; m * 2];
    for k in 0..m {
        p_top[k * 2] = rng.range_f32(0.0, 60.0);
        p_top[k * 2 + 1] = rng.range_f32(0.0, 60.0);
        p_bot[k * 2] = p_top[k * 2] + 3.0;
        p_bot[k * 2 + 1] = p_top[k * 2 + 1] + 3.0;
    }

    let out = rt
        .exec_f32(
            "cat_masks",
            &[
                (&mu, &[n as i64, 2]),
                (&conic, &[n as i64, 3]),
                (&opacity, &[n as i64]),
                (&p_top, &[m as i64, 2]),
                (&p_bot, &[m as i64, 2]),
            ],
        )
        .unwrap();
    let masks = &out[0]; // (M, N, 4) in {0,1}

    let mut pass = 0usize;
    let mut fail = 0usize;
    let mut disagree = 0usize;
    let mut total = 0usize;
    for k in 0..m {
        for i in 0..n {
            let w = pr_weights(
                v2(mu[i * 2], mu[i * 2 + 1]),
                conics[i],
                v2(p_top[k * 2], p_top[k * 2 + 1]),
                v2(p_bot[k * 2], p_bot[k * 2 + 1]),
            );
            let lhs = shared_threshold(opacity[i]);
            for c in 0..4 {
                let want = lhs > w.e[c];
                let got = masks[(k * n + i) * 4 + c] > 0.5;
                if want {
                    pass += 1;
                } else {
                    fail += 1;
                }
                if want != got {
                    disagree += 1;
                }
                total += 1;
            }
        }
    }
    // Both outcomes must be represented, and disagreement at float-noise
    // level only.
    assert!(pass > 0 && fail > 0, "degenerate case: pass {pass} fail {fail}");
    assert!(
        (disagree as f64) < 0.002 * total as f64,
        "disagreement {disagree}/{total}"
    );
}

#[test]
fn project_artifact_matches_rust_projection_math() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n_gauss;
    let mut rng = Pcg32::new(0xA03);
    let (fx, fy, cx, cy) = (200.0f32, 200.0f32, 96.0f32, 96.0f32);

    // Camera-space positions and packed symmetric covariances.
    let mut pos = vec![0.0f32; n * 3];
    let mut cov6 = vec![0.0f32; n * 6];
    for i in 0..n {
        pos[i * 3] = rng.range_f32(-2.0, 2.0);
        pos[i * 3 + 1] = rng.range_f32(-2.0, 2.0);
        pos[i * 3 + 2] = rng.range_f32(2.0, 20.0);
        // PSD via L·Lᵀ with small entries.
        let l = [
            rng.range_f32(0.02, 0.3),
            rng.range_f32(-0.1, 0.1),
            rng.range_f32(0.02, 0.3),
            rng.range_f32(-0.1, 0.1),
            rng.range_f32(-0.1, 0.1),
            rng.range_f32(0.02, 0.3),
        ];
        // full L = [[l0,0,0],[l1,l2,0],[l3,l4,l5]]
        let xx = l[0] * l[0];
        let xy = l[0] * l[1];
        let xz = l[0] * l[3];
        let yy = l[1] * l[1] + l[2] * l[2];
        let yz = l[1] * l[3] + l[2] * l[4];
        let zz = l[3] * l[3] + l[4] * l[4] + l[5] * l[5];
        cov6[i * 6..i * 6 + 6].copy_from_slice(&[xx, xy, xz, yy, yz, zz]);
    }
    let cam = [fx, fy, cx, cy];
    let out = rt
        .exec_f32(
            "project",
            &[
                (&pos, &[n as i64, 3]),
                (&cov6, &[n as i64, 6]),
                (&cam, &[4]),
            ],
        )
        .unwrap();
    let (mean, conic, depth, radius) = (&out[0], &out[1], &out[2], &out[3]);

    for i in (0..n).step_by(13) {
        let (x, y, z) = (pos[i * 3], pos[i * 3 + 1], pos[i * 3 + 2]);
        // Mean.
        let ex = fx * x / z + cx;
        let ey = fy * y / z + cy;
        assert!((mean[i * 2] - ex).abs() < 1e-2, "mean.x {} vs {ex}", mean[i * 2]);
        assert!((mean[i * 2 + 1] - ey).abs() < 1e-2);
        assert!((depth[i] - z).abs() < 1e-4);
        assert!(radius[i] > 0.0);
        // Conic must invert the dilated 2D covariance: recompute in Rust.
        let inv_z = 1.0 / z;
        let j00 = fx * inv_z;
        let j02 = -fx * x * inv_z * inv_z;
        let j11 = fy * inv_z;
        let j12 = -fy * y * inv_z * inv_z;
        let (xx, xy, xz, yy, yz, zz) = (
            cov6[i * 6],
            cov6[i * 6 + 1],
            cov6[i * 6 + 2],
            cov6[i * 6 + 3],
            cov6[i * 6 + 4],
            cov6[i * 6 + 5],
        );
        let a = j00 * j00 * xx + 2.0 * j00 * j02 * xz + j02 * j02 * zz + 0.3;
        let b = j00 * j11 * xy + j00 * j12 * xz + j02 * j11 * yz + j02 * j12 * zz;
        let c = j11 * j11 * yy + 2.0 * j11 * j12 * yz + j12 * j12 * zz + 0.3;
        let (ia, ib, ic) = (conic[i * 3], conic[i * 3 + 1], conic[i * 3 + 2]);
        assert!((a * ia + b * ib - 1.0).abs() < 1e-2, "conic not inverse (row 1)");
        assert!((b * ia + c * ib).abs() < 1e-2, "conic not inverse (cross)");
        assert!((b * ib + c * ic - 1.0).abs() < 1e-2, "conic not inverse (row 2)");
    }
}

#[test]
fn render_tile_artifact_blends_like_golden_math() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n_gauss;
    let m = rt.manifest.n_pr;

    // One big opaque red splat dead-center of tile at origin (passes CAT),
    // everything else zero-padded.
    let mut mu = vec![0.0f32; n * 2];
    let mut conic = vec![0.0f32; n * 3];
    let mut opacity = vec![0.0f32; n];
    let mut color = vec![0.0f32; n * 3];
    mu[0] = 8.0;
    mu[1] = 8.0;
    conic[0] = 0.02;
    conic[2] = 0.02;
    opacity[0] = 0.9;
    color[0] = 1.0;
    for i in 1..n {
        conic[i * 3] = 1.0;
        conic[i * 3 + 2] = 1.0;
    }
    let origin = [0.0f32, 0.0];
    // Dense PRs over the tile's sub-tiles.
    let layouts = flicker::cat::leader::dense_layout();
    let mut p_top = vec![0.0f32; m * 2];
    let mut p_bot = vec![0.0f32; m * 2];
    for k in 0..m {
        let sub = k / 4;
        let (sx, sy) = ((sub % 2) as f32 * 8.0, (sub / 2) as f32 * 8.0);
        let pr = &layouts[k % 4];
        p_top[k * 2] = sx + pr.x_top;
        p_top[k * 2 + 1] = sy + pr.y_top;
        p_bot[k * 2] = sx + pr.x_bot;
        p_bot[k * 2 + 1] = sy + pr.y_bot;
    }

    let out = rt
        .exec_f32(
            "render_tile",
            &[
                (&mu, &[n as i64, 2]),
                (&conic, &[n as i64, 3]),
                (&opacity, &[n as i64]),
                (&color, &[n as i64, 3]),
                (&origin, &[2]),
                (&p_top, &[m as i64, 2]),
                (&p_bot, &[m as i64, 2]),
            ],
        )
        .unwrap();
    let rgb = &out[0];
    let trans = &out[1];
    let passes = &out[2];
    assert!(passes[0] > 0.5, "central splat must pass CAT");

    // Center pixel (8,8): α = 0.9·exp(-½·0.02·(0.25+0.25)) ≈ 0.8955.
    let dx = 8.5 - 8.0;
    let e = 0.5 * (0.02 * dx * dx + 0.02 * dx * dx);
    let alpha = 0.9 * (-e as f32).exp();
    let center = (8 * 16 + 8) * 3;
    assert!(
        (rgb[center] - alpha).abs() < 1e-3,
        "center red {} vs α {alpha}",
        rgb[center]
    );
    assert!((trans[8 * 16 + 8] - (1.0 - alpha)).abs() < 1e-3);
    // Green/blue stay zero.
    assert!(rgb[center + 1].abs() < 1e-6);
}
