//! L1↔L3 consistency: the AOT JAX/Pallas artifacts executed through PJRT
//! must agree with the Rust golden implementations of the same math
//! (cat::pr for Alg. 1, the rasterizer for tile blending, render::project
//! for EWA projection). The whole file only compiles with `--features
//! pjrt`.
//!
//! Two runtime sources feed the tests:
//! * [`runtime`] — real AOT artifacts from `make artifacts`
//!   (`default_artifact_dir`); tests skip when they were never built.
//!   Against the offline stub these run too: the stub interprets the
//!   artifacts with built-in reference kernels.
//! * [`stub_runtime`] — a synthesized `write_stub_artifacts` set, which
//!   needs no jax at all, so the batched-equivalence tests below run in
//!   the **default** CI lane. Real-XLA builds cannot parse the
//!   placeholder files and skip (the `xla-real` lane covers them through
//!   `runtime()` instead).
#![cfg(feature = "pjrt")]

use flicker::cat::pr::{pr_weights, shared_threshold};
use flicker::numeric::linalg::{v2, Sym2};
use flicker::render::tile::Rect;
use flicker::runtime::executor::TileExecutor;
use flicker::runtime::{default_artifact_dir, write_stub_artifacts, Runtime};
use flicker::util::rng::Pcg32;

fn runtime() -> Option<Runtime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: pjrt runtime unavailable ({e})");
            None
        }
    }
}

/// Load a runtime over a synthesized stub artifact set (small N for cheap
/// chunk-boundary coverage). `None` when the `xla` dependency is the real
/// crate (placeholders don't parse as HLO) — callers skip.
fn stub_runtime(tag: &str, n_gauss: usize, n_batch: usize) -> Option<Runtime> {
    let dir = std::env::temp_dir().join(format!("flicker_roundtrip_stub_{tag}"));
    write_stub_artifacts(&dir, n_gauss, 16, 16, n_batch).unwrap();
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: stub runtime unavailable ({e})");
            None
        }
    }
}

fn random_conic(rng: &mut Pcg32) -> Sym2 {
    let l11 = rng.range_f32(0.05, 0.9);
    let l21 = rng.range_f32(-0.4, 0.4);
    let l22 = rng.range_f32(0.05, 0.9);
    Sym2 {
        a: l11 * l11,
        b: l11 * l21,
        c: l21 * l21 + l22 * l22,
    }
}

#[test]
fn pr_weight_artifact_matches_rust_alg1() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n_gauss;
    let m = rt.manifest.n_pr;
    let mut rng = Pcg32::new(0xA01);

    let mut mu = vec![0.0f32; n * 2];
    let mut conic = vec![0.0f32; n * 3];
    let mut conics = Vec::with_capacity(n);
    for i in 0..n {
        mu[i * 2] = rng.range_f32(0.0, 256.0);
        mu[i * 2 + 1] = rng.range_f32(0.0, 256.0);
        let c = random_conic(&mut rng);
        conic[i * 3] = c.a;
        conic[i * 3 + 1] = c.b;
        conic[i * 3 + 2] = c.c;
        conics.push(c);
    }
    let mut p_top = vec![0.0f32; m * 2];
    let mut p_bot = vec![0.0f32; m * 2];
    for k in 0..m {
        p_top[k * 2] = rng.range_f32(0.0, 250.0);
        p_top[k * 2 + 1] = rng.range_f32(0.0, 250.0);
        p_bot[k * 2] = p_top[k * 2] + rng.range_f32(1.0, 7.0);
        p_bot[k * 2 + 1] = p_top[k * 2 + 1] + rng.range_f32(1.0, 7.0);
    }

    let out = rt
        .exec_f32(
            "pr_weight",
            &[
                (&mu, &[n as i64, 2]),
                (&conic, &[n as i64, 3]),
                (&p_top, &[m as i64, 2]),
                (&p_bot, &[m as i64, 2]),
            ],
        )
        .unwrap();
    let e = &out[0]; // (M, N, 4)

    for k in 0..m {
        for i in (0..n).step_by(17) {
            let w = pr_weights(
                v2(mu[i * 2], mu[i * 2 + 1]),
                conics[i],
                v2(p_top[k * 2], p_top[k * 2 + 1]),
                v2(p_bot[k * 2], p_bot[k * 2 + 1]),
            );
            for c in 0..4 {
                let got = e[(k * n + i) * 4 + c];
                let want = w.e[c];
                let tol = 1e-3 * (1.0 + want.abs());
                assert!(
                    (got - want).abs() <= tol,
                    "PR {k} gaussian {i} corner {c}: pjrt {got} vs rust {want}"
                );
            }
        }
    }
}

#[test]
fn cat_masks_artifact_matches_rust_decision() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n_gauss;
    let m = rt.manifest.n_pr;
    let mut rng = Pcg32::new(0xA02);

    let mut mu = vec![0.0f32; n * 2];
    let mut conic = vec![0.0f32; n * 3];
    let mut opacity = vec![0.0f32; n];
    let mut conics = Vec::with_capacity(n);
    for i in 0..n {
        // Means near the PR region so both outcomes occur.
        mu[i * 2] = rng.range_f32(0.0, 64.0);
        mu[i * 2 + 1] = rng.range_f32(0.0, 64.0);
        let c = random_conic(&mut rng);
        conic[i * 3] = c.a;
        conic[i * 3 + 1] = c.b;
        conic[i * 3 + 2] = c.c;
        opacity[i] = rng.range_f32(0.01, 1.0);
        conics.push(c);
    }
    let mut p_top = vec![0.0f32; m * 2];
    let mut p_bot = vec![0.0f32; m * 2];
    for k in 0..m {
        p_top[k * 2] = rng.range_f32(0.0, 60.0);
        p_top[k * 2 + 1] = rng.range_f32(0.0, 60.0);
        p_bot[k * 2] = p_top[k * 2] + 3.0;
        p_bot[k * 2 + 1] = p_top[k * 2 + 1] + 3.0;
    }

    let out = rt
        .exec_f32(
            "cat_masks",
            &[
                (&mu, &[n as i64, 2]),
                (&conic, &[n as i64, 3]),
                (&opacity, &[n as i64]),
                (&p_top, &[m as i64, 2]),
                (&p_bot, &[m as i64, 2]),
            ],
        )
        .unwrap();
    let masks = &out[0]; // (M, N, 4) in {0,1}

    let mut pass = 0usize;
    let mut fail = 0usize;
    let mut disagree = 0usize;
    let mut total = 0usize;
    for k in 0..m {
        for i in 0..n {
            let w = pr_weights(
                v2(mu[i * 2], mu[i * 2 + 1]),
                conics[i],
                v2(p_top[k * 2], p_top[k * 2 + 1]),
                v2(p_bot[k * 2], p_bot[k * 2 + 1]),
            );
            let lhs = shared_threshold(opacity[i]);
            for c in 0..4 {
                let want = lhs > w.e[c];
                let got = masks[(k * n + i) * 4 + c] > 0.5;
                if want {
                    pass += 1;
                } else {
                    fail += 1;
                }
                if want != got {
                    disagree += 1;
                }
                total += 1;
            }
        }
    }
    // Both outcomes must be represented, and disagreement at float-noise
    // level only.
    assert!(pass > 0 && fail > 0, "degenerate case: pass {pass} fail {fail}");
    assert!(
        (disagree as f64) < 0.002 * total as f64,
        "disagreement {disagree}/{total}"
    );
}

#[test]
fn project_artifact_matches_rust_projection_math() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n_gauss;
    let mut rng = Pcg32::new(0xA03);
    let (fx, fy, cx, cy) = (200.0f32, 200.0f32, 96.0f32, 96.0f32);

    // Camera-space positions and packed symmetric covariances.
    let mut pos = vec![0.0f32; n * 3];
    let mut cov6 = vec![0.0f32; n * 6];
    for i in 0..n {
        pos[i * 3] = rng.range_f32(-2.0, 2.0);
        pos[i * 3 + 1] = rng.range_f32(-2.0, 2.0);
        pos[i * 3 + 2] = rng.range_f32(2.0, 20.0);
        // PSD via L·Lᵀ with small entries.
        let l = [
            rng.range_f32(0.02, 0.3),
            rng.range_f32(-0.1, 0.1),
            rng.range_f32(0.02, 0.3),
            rng.range_f32(-0.1, 0.1),
            rng.range_f32(-0.1, 0.1),
            rng.range_f32(0.02, 0.3),
        ];
        // full L = [[l0,0,0],[l1,l2,0],[l3,l4,l5]]
        let xx = l[0] * l[0];
        let xy = l[0] * l[1];
        let xz = l[0] * l[3];
        let yy = l[1] * l[1] + l[2] * l[2];
        let yz = l[1] * l[3] + l[2] * l[4];
        let zz = l[3] * l[3] + l[4] * l[4] + l[5] * l[5];
        cov6[i * 6..i * 6 + 6].copy_from_slice(&[xx, xy, xz, yy, yz, zz]);
    }
    let cam = [fx, fy, cx, cy];
    let out = rt
        .exec_f32(
            "project",
            &[
                (&pos, &[n as i64, 3]),
                (&cov6, &[n as i64, 6]),
                (&cam, &[4]),
            ],
        )
        .unwrap();
    let (mean, conic, depth, radius) = (&out[0], &out[1], &out[2], &out[3]);

    for i in (0..n).step_by(13) {
        let (x, y, z) = (pos[i * 3], pos[i * 3 + 1], pos[i * 3 + 2]);
        // Mean.
        let ex = fx * x / z + cx;
        let ey = fy * y / z + cy;
        assert!((mean[i * 2] - ex).abs() < 1e-2, "mean.x {} vs {ex}", mean[i * 2]);
        assert!((mean[i * 2 + 1] - ey).abs() < 1e-2);
        assert!((depth[i] - z).abs() < 1e-4);
        assert!(radius[i] > 0.0);
        // Conic must invert the dilated 2D covariance: recompute in Rust.
        let inv_z = 1.0 / z;
        let j00 = fx * inv_z;
        let j02 = -fx * x * inv_z * inv_z;
        let j11 = fy * inv_z;
        let j12 = -fy * y * inv_z * inv_z;
        let (xx, xy, xz, yy, yz, zz) = (
            cov6[i * 6],
            cov6[i * 6 + 1],
            cov6[i * 6 + 2],
            cov6[i * 6 + 3],
            cov6[i * 6 + 4],
            cov6[i * 6 + 5],
        );
        let a = j00 * j00 * xx + 2.0 * j00 * j02 * xz + j02 * j02 * zz + 0.3;
        let b = j00 * j11 * xy + j00 * j12 * xz + j02 * j11 * yz + j02 * j12 * zz;
        let c = j11 * j11 * yy + 2.0 * j11 * j12 * yz + j12 * j12 * zz + 0.3;
        let (ia, ib, ic) = (conic[i * 3], conic[i * 3 + 1], conic[i * 3 + 2]);
        assert!((a * ia + b * ib - 1.0).abs() < 1e-2, "conic not inverse (row 1)");
        assert!((b * ia + c * ib).abs() < 1e-2, "conic not inverse (cross)");
        assert!((b * ib + c * ic - 1.0).abs() < 1e-2, "conic not inverse (row 2)");
    }
}

#[test]
fn render_tile_artifact_blends_like_golden_math() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.n_gauss;
    let m = rt.manifest.n_pr;

    // One big opaque red splat dead-center of tile at origin (passes CAT),
    // everything else zero-padded.
    let mut mu = vec![0.0f32; n * 2];
    let mut conic = vec![0.0f32; n * 3];
    let mut opacity = vec![0.0f32; n];
    let mut color = vec![0.0f32; n * 3];
    mu[0] = 8.0;
    mu[1] = 8.0;
    conic[0] = 0.02;
    conic[2] = 0.02;
    opacity[0] = 0.9;
    color[0] = 1.0;
    for i in 1..n {
        conic[i * 3] = 1.0;
        conic[i * 3 + 2] = 1.0;
    }
    let origin = [0.0f32, 0.0];
    // Dense PRs over the tile's sub-tiles.
    let layouts = flicker::cat::leader::dense_layout();
    let mut p_top = vec![0.0f32; m * 2];
    let mut p_bot = vec![0.0f32; m * 2];
    for k in 0..m {
        let sub = k / 4;
        let (sx, sy) = ((sub % 2) as f32 * 8.0, (sub / 2) as f32 * 8.0);
        let pr = &layouts[k % 4];
        p_top[k * 2] = sx + pr.x_top;
        p_top[k * 2 + 1] = sy + pr.y_top;
        p_bot[k * 2] = sx + pr.x_bot;
        p_bot[k * 2 + 1] = sy + pr.y_bot;
    }

    let out = rt
        .exec_f32(
            "render_tile",
            &[
                (&mu, &[n as i64, 2]),
                (&conic, &[n as i64, 3]),
                (&opacity, &[n as i64]),
                (&color, &[n as i64, 3]),
                (&origin, &[2]),
                (&p_top, &[m as i64, 2]),
                (&p_bot, &[m as i64, 2]),
            ],
        )
        .unwrap();
    let rgb = &out[0];
    let trans = &out[1];
    let passes = &out[2];
    assert!(passes[0] > 0.5, "central splat must pass CAT");

    // Center pixel (8,8): α = 0.9·exp(-½·0.02·(0.25+0.25)) ≈ 0.8955.
    let dx = 8.5 - 8.0;
    let e = 0.5 * (0.02 * dx * dx + 0.02 * dx * dx);
    let alpha = 0.9 * (-e as f32).exp();
    let center = (8 * 16 + 8) * 3;
    assert!(
        (rgb[center] - alpha).abs() < 1e-3,
        "center red {} vs α {alpha}",
        rgb[center]
    );
    assert!((trans[8 * 16 + 8] - (1.0 - alpha)).abs() < 1e-3);
    // Green/blue stay zero.
    assert!(rgb[center + 1].abs() < 1e-6);
}

/// Fill random single-tile inputs for one batch slot. Means hover around
/// the slot's tile so CAT passes and fails both occur; the PR corners
/// come from the executor's own [`TileExecutor::dense_prs`] layout, so
/// the roundtrip exercises exactly the geometry the executor ships.
#[allow(clippy::type_complexity)]
fn random_tile_inputs(
    rt: &Runtime,
    rng: &mut Pcg32,
    origin: [f32; 2],
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = rt.manifest.n_gauss;
    let tile = rt.manifest.tile as f32;
    let mut mu = vec![0.0f32; n * 2];
    let mut conic = vec![0.0f32; n * 3];
    let mut opacity = vec![0.0f32; n];
    let mut color = vec![0.0f32; n * 3];
    for i in 0..n {
        mu[i * 2] = origin[0] + rng.range_f32(-8.0, 24.0);
        mu[i * 2 + 1] = origin[1] + rng.range_f32(-8.0, 24.0);
        let c = random_conic(rng);
        conic[i * 3] = c.a;
        conic[i * 3 + 1] = c.b;
        conic[i * 3 + 2] = c.c;
        opacity[i] = rng.range_f32(0.0, 1.0);
        color[i * 3] = rng.range_f32(0.0, 1.0);
        color[i * 3 + 1] = rng.range_f32(0.0, 1.0);
        color[i * 3 + 2] = rng.range_f32(0.0, 1.0);
    }
    let rect = Rect {
        x0: origin[0],
        y0: origin[1],
        x1: origin[0] + tile,
        y1: origin[1] + tile,
    };
    let (p_top, p_bot) = TileExecutor::new(rt).dense_prs(&rect);
    (mu, conic, opacity, color, p_top, p_bot)
}

/// The batched artifact must reproduce B independent single-tile
/// dispatches (the executor's batching contract). `bitwise` is asserted
/// only against the stub runtime, whose batched kernel is the single
/// kernel per slot by construction; real XLA gives no cross-program
/// bit-identity guarantee (vmap may fuse differently), so the xla-real
/// lane checks within a tight float tolerance instead.
fn check_batched_matches_single(rt: &Runtime, seed: u64, bitwise: bool) {
    let n = rt.manifest.n_gauss;
    let m = rt.manifest.n_pr;
    let b = rt.manifest.n_batch;
    assert!(b > 1, "manifest has no tile batching (n_batch = {b})");
    let mut rng = Pcg32::new(seed);

    let mut slots = Vec::with_capacity(b);
    for s in 0..b {
        let origin = [16.0 * s as f32, 8.0 * s as f32];
        slots.push((origin, random_tile_inputs(rt, &mut rng, origin)));
    }

    // Batched: stack every slot along the leading dim.
    let mut mu = Vec::new();
    let mut conic = Vec::new();
    let mut opacity = Vec::new();
    let mut color = Vec::new();
    let mut origin = Vec::new();
    let mut p_top = Vec::new();
    let mut p_bot = Vec::new();
    for (o, (smu, sconic, sopacity, scolor, spt, spb)) in &slots {
        mu.extend_from_slice(smu);
        conic.extend_from_slice(sconic);
        opacity.extend_from_slice(sopacity);
        color.extend_from_slice(scolor);
        origin.extend_from_slice(o);
        p_top.extend_from_slice(spt);
        p_bot.extend_from_slice(spb);
    }
    let out = rt
        .exec_f32(
            "render_tile_batched",
            &[
                (&mu, &[b as i64, n as i64, 2]),
                (&conic, &[b as i64, n as i64, 3]),
                (&opacity, &[b as i64, n as i64]),
                (&color, &[b as i64, n as i64, 3]),
                (&origin, &[b as i64, 2]),
                (&p_top, &[b as i64, m as i64, 2]),
                (&p_bot, &[b as i64, m as i64, 2]),
            ],
        )
        .unwrap();
    assert_eq!(out[0].len(), b * 16 * 16 * 3, "batched rgb shape");
    assert_eq!(out[1].len(), b * 16 * 16, "batched trans shape");
    assert_eq!(out[2].len(), b * n, "batched passes shape");

    for (s, (o, (smu, sconic, sopacity, scolor, spt, spb))) in slots.iter().enumerate() {
        let single = rt
            .exec_f32(
                "render_tile",
                &[
                    (smu, &[n as i64, 2]),
                    (sconic, &[n as i64, 3]),
                    (sopacity, &[n as i64]),
                    (scolor, &[n as i64, 3]),
                    (o, &[2]),
                    (spt, &[m as i64, 2]),
                    (spb, &[m as i64, 2]),
                ],
            )
            .unwrap();
        let px = 16 * 16;
        let pairs = [
            ("rgb", &single[0], &out[0][s * px * 3..(s + 1) * px * 3]),
            ("transmittance", &single[1], &out[1][s * px..(s + 1) * px]),
            ("CAT passes", &single[2], &out[2][s * n..(s + 1) * n]),
        ];
        for (what, want, got) in pairs {
            assert_eq!(want.len(), got.len(), "slot {s}: {what} shape");
            for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
                if bitwise {
                    assert_eq!(
                        w.to_bits(),
                        g.to_bits(),
                        "slot {s}: {what}[{i}] differs from single-tile dispatch"
                    );
                } else {
                    let tol = 1e-5 * (1.0 + w.abs());
                    assert!(
                        (w - g).abs() <= tol,
                        "slot {s}: {what}[{i}] {g} vs single-tile {w}"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_artifact_matches_single_tile_artifact() {
    // Real artifacts (xla-real lane, or a local `make artifacts` build).
    let Some(rt) = runtime() else { return };
    if !rt.has("render_tile_batched") {
        eprintln!("skipping: artifacts predate the batched render (re-run `make artifacts`)");
        return;
    }
    check_batched_matches_single(&rt, 0xBA7C, false);
}

#[test]
fn batched_stub_artifact_matches_single_tile_stub() {
    // Synthesized stub artifacts — no jax needed, runs in default CI.
    let Some(rt) = stub_runtime("batched_eq", 24, 4) else { return };
    check_batched_matches_single(&rt, 0xBA7D, true);
}

#[test]
fn stub_runtime_loads_and_reports_batch_width() {
    let Some(rt) = stub_runtime("manifest", 24, 4) else { return };
    assert_eq!(rt.platform(), "stub");
    assert_eq!(rt.manifest.n_gauss, 24);
    assert_eq!(rt.manifest.n_batch, 4);
    for name in flicker::runtime::ARTIFACT_NAMES {
        assert!(rt.has(name), "artifact {name} not compiled");
    }
}

#[test]
fn stub_pr_weight_matches_rust_alg1_bitwise() {
    // The stub's built-in kernel mirrors cat::pr::pr_weights term for
    // term, so the roundtrip is exact — the offline anchor for the
    // tolerance-based real-XLA comparison above.
    let Some(rt) = stub_runtime("prw", 24, 4) else { return };
    let n = rt.manifest.n_gauss;
    let m = rt.manifest.n_pr;
    let mut rng = Pcg32::new(0xA77);
    let mut mu = vec![0.0f32; n * 2];
    let mut conic = vec![0.0f32; n * 3];
    let mut conics = Vec::with_capacity(n);
    for i in 0..n {
        mu[i * 2] = rng.range_f32(0.0, 64.0);
        mu[i * 2 + 1] = rng.range_f32(0.0, 64.0);
        let c = random_conic(&mut rng);
        conic[i * 3] = c.a;
        conic[i * 3 + 1] = c.b;
        conic[i * 3 + 2] = c.c;
        conics.push(c);
    }
    let mut p_top = vec![0.0f32; m * 2];
    let mut p_bot = vec![0.0f32; m * 2];
    for k in 0..m {
        p_top[k * 2] = rng.range_f32(0.0, 60.0);
        p_top[k * 2 + 1] = rng.range_f32(0.0, 60.0);
        p_bot[k * 2] = p_top[k * 2] + 3.0;
        p_bot[k * 2 + 1] = p_top[k * 2 + 1] + 3.0;
    }
    let out = rt
        .exec_f32(
            "pr_weight",
            &[
                (&mu, &[n as i64, 2]),
                (&conic, &[n as i64, 3]),
                (&p_top, &[m as i64, 2]),
                (&p_bot, &[m as i64, 2]),
            ],
        )
        .unwrap();
    let e = &out[0];
    for k in 0..m {
        for i in 0..n {
            let w = pr_weights(
                v2(mu[i * 2], mu[i * 2 + 1]),
                conics[i],
                v2(p_top[k * 2], p_top[k * 2 + 1]),
                v2(p_bot[k * 2], p_bot[k * 2 + 1]),
            );
            for c in 0..4 {
                assert_eq!(
                    e[(k * n + i) * 4 + c].to_bits(),
                    w.e[c].to_bits(),
                    "PR {k} gaussian {i} corner {c}"
                );
            }
        }
    }
}
