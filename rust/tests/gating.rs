//! The coarse-to-fine gating contract (paper §"hierarchical Gaussian
//! testing"):
//!
//! 1. **Off is really off.** `GateConfig { enabled: false }` renders
//!    bit-identically to the pre-gate pipeline and leaves every gate
//!    counter at zero — the gate is a pure opt-in.
//! 2. **The default threshold is lossless.** At the 1/255 blend floor the
//!    gate rejects exactly the Gaussian×tile / Gaussian×quadrant pairs the
//!    fine loop would have skipped pixel-by-pixel, so the gated image (and
//!    `pairs_blended`) is bitwise identical to the ungated one — for the
//!    vanilla rasterizer, for CAT masks, and for every worker count.
//! 3. **Counters add up.** `splats_submitted + gate_tile_rejected ==
//!    tile_pairs`, quadrant counters only move when level 2 runs, and all
//!    of it is worker- and batch-invariant.
//! 4. **The cut is real.** On the synthetic orbit scenes the lossless
//!    default removes ≥30% of submitted pairs (the acceptance bar for this
//!    stage of the paper's hierarchy) at PSNR > 30 dB vs golden — in fact
//!    identical pixels.

use flicker::camera::{orbit_path, Camera, Intrinsics};
use flicker::cat::{CatConfig, LeaderMode, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::{Golden, Session};
use flicker::numeric::linalg::v3;
use flicker::render::metrics::psnr;
use flicker::render::plan::FramePlan;
use flicker::render::project::ALPHA_MIN;
use flicker::render::pyramid::GateConfig;
use flicker::render::raster::{RenderOptions, VanillaMasks};
use flicker::scene::gaussian::Scene;
use flicker::scene::synthetic::{generate_scaled, preset};

fn scene_and_orbit(name: &str, frames: usize) -> (Scene, Vec<Camera>) {
    let scene = generate_scaled(&preset(name), 0.01);
    let cams = orbit_path(
        Intrinsics::from_fov(96, 96, 1.2),
        v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        frames,
    );
    (scene, cams)
}

fn gate_opts(gate: GateConfig, workers: usize) -> RenderOptions {
    RenderOptions {
        gate,
        workers,
        ..RenderOptions::default()
    }
}

#[test]
fn gate_off_matches_default_bitwise() {
    let (scene, cams) = scene_and_orbit("garden", 1);
    let base = FramePlan::build(&scene, &cams[0], &RenderOptions::default())
        .render(&VanillaMasks, None);
    let off = GateConfig {
        enabled: false,
        levels: 2,
        threshold: ALPHA_MIN,
    };
    let explicit =
        FramePlan::build(&scene, &cams[0], &gate_opts(off, 1)).render(&VanillaMasks, None);
    assert_eq!(base.image.data, explicit.image.data);
    assert_eq!(base.stats.pairs_tested, explicit.stats.pairs_tested);
    assert_eq!(base.stats.pairs_blended, explicit.stats.pairs_blended);
    // Off leaves the gate counters untouched: everything processed is
    // "submitted" (early-terminated tiles may skip their list tails).
    assert_eq!(base.stats.splats_submitted, explicit.stats.splats_submitted);
    for s in [&base.stats, &explicit.stats] {
        assert_eq!(s.gate_tile_tested, 0);
        assert_eq!(s.gate_tile_rejected, 0);
        assert_eq!(s.gate_quad_tested, 0);
        assert_eq!(s.gate_quad_rejected, 0);
        assert!(s.splats_submitted <= s.tile_pairs as u64);
    }
}

#[test]
fn lossless_gate_is_bitwise_identical_for_vanilla_and_cat() {
    let (scene, cams) = scene_and_orbit("truck", 1);
    let base_opts = RenderOptions::default();
    let base = FramePlan::build(&scene, &cams[0], &base_opts).render(&VanillaMasks, None);
    let cat_cfg = CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Mixed,
        stage1: true,
    };
    let base_cat = FramePlan::build(&scene, &cams[0], &base_opts).render(&cat_cfg, None);

    for levels in [1u32, 2] {
        let gate = GateConfig {
            enabled: true,
            levels,
            threshold: ALPHA_MIN,
        };
        let plan = FramePlan::build(&scene, &cams[0], &gate_opts(gate, 1));
        let gated = plan.render(&VanillaMasks, None);
        assert_eq!(base.image.data, gated.image.data, "levels={levels}");
        assert_eq!(base.stats.pairs_blended, gated.stats.pairs_blended, "levels={levels}");
        // The gate can only remove per-pixel tests, never add them.
        assert!(gated.stats.pairs_tested <= base.stats.pairs_tested);

        let gated_cat = plan.render(&cat_cfg, None);
        assert_eq!(base_cat.image.data, gated_cat.image.data, "cat levels={levels}");
        assert_eq!(
            base_cat.stats.pairs_blended, gated_cat.stats.pairs_blended,
            "cat levels={levels}"
        );
    }
}

#[test]
fn gated_render_is_worker_invariant() {
    let (scene, cams) = scene_and_orbit("garden", 1);
    let gate = GateConfig::on();
    let seq = FramePlan::build(&scene, &cams[0], &gate_opts(gate, 1)).render(&VanillaMasks, None);
    for workers in [2usize, 8, 0] {
        let par =
            FramePlan::build(&scene, &cams[0], &gate_opts(gate, workers)).render(&VanillaMasks, None);
        assert_eq!(seq.image.data, par.image.data, "workers={workers}");
        assert_eq!(seq.stats.splats_submitted, par.stats.splats_submitted, "workers={workers}");
        assert_eq!(
            seq.stats.gate_tile_rejected, par.stats.gate_tile_rejected,
            "workers={workers}"
        );
        assert_eq!(
            seq.stats.gate_quad_rejected, par.stats.gate_quad_rejected,
            "workers={workers}"
        );
    }
}

#[test]
fn gate_counters_sum_consistently() {
    let (scene, cams) = scene_and_orbit("truck", 1);
    for levels in [1u32, 2] {
        let gate = GateConfig {
            enabled: true,
            levels,
            threshold: ALPHA_MIN,
        };
        let out =
            FramePlan::build(&scene, &cams[0], &gate_opts(gate, 1)).render(&VanillaMasks, None);
        let s = &out.stats;
        assert!(s.gate_tile_tested <= s.tile_pairs as u64, "levels={levels}");
        assert!(s.gate_tile_tested > 0, "levels={levels}");
        assert_eq!(
            s.splats_submitted + s.gate_tile_rejected,
            s.gate_tile_tested,
            "levels={levels}"
        );
        assert!(s.gate_tile_rejected > 0, "levels={levels}: tile gate never fired");
        if levels == 1 {
            assert_eq!(s.gate_quad_tested, 0);
            assert_eq!(s.gate_quad_rejected, 0);
        } else {
            // Level 2 only sees survivors of level 1: at most 4 quadrant
            // tests per submitted pair.
            assert!(s.gate_quad_tested > 0);
            assert!(s.gate_quad_tested <= 4 * s.splats_submitted);
            assert!(s.gate_quad_rejected <= s.gate_quad_tested);
        }
    }
}

/// The acceptance bar: at the lossless default threshold the gate removes
/// at least 30% of Gaussian×tile submissions on the synthetic orbit
/// scenes while the rendered orbit stays above 30 dB vs golden (identical
/// pixels give infinite PSNR, which passes).
#[test]
fn default_gate_cuts_submitted_work_on_orbit_scenes() {
    for name in ["garden", "truck"] {
        let base = Session::builder(ExperimentConfig {
            scene: name.into(),
            scene_scale: 0.01,
            resolution: 96,
            frames: 3,
            ..Default::default()
        })
        .build()
        .unwrap();
        let gated = Session::builder(ExperimentConfig {
            scene: name.into(),
            scene_scale: 0.01,
            resolution: 96,
            frames: 3,
            gate: Some(true),
            ..Default::default()
        })
        .build()
        .unwrap();
        let (mut submitted_off, mut submitted_on) = (0u64, 0u64);
        for i in 0..3 {
            let a = base.frame(i, &Golden).unwrap();
            let b = gated.frame(i, &Golden).unwrap();
            let q = psnr(&a.image, &b.image);
            assert!(q > 30.0, "{name} view {i}: gated PSNR {q}");
            submitted_off += a.stats.splats_submitted;
            submitted_on += b.stats.splats_submitted;
        }
        let cut = 1.0 - submitted_on as f64 / submitted_off.max(1) as f64;
        assert!(
            cut >= 0.30,
            "{name}: gate cut only {:.1}% of submissions ({submitted_off} → {submitted_on})",
            cut * 100.0
        );
    }
}

/// The PJRT path drops whole-tile rejects from the dispatch lists
/// (`FramePlan::gated_lists`); because the device kernel zeroes α < 1/255
/// itself, the gated stream must stay bit-identical to the ungated one for
/// every batch width. Stub-backed, so it runs in the default `--features
/// pjrt` CI lane.
#[cfg(feature = "pjrt")]
mod pjrt_gating {
    use super::*;
    use flicker::coordinator::Pjrt;
    use flicker::runtime::{write_stub_artifacts, Runtime};

    fn stub_runtime() -> Option<Runtime> {
        let dir = std::env::temp_dir().join("flicker_gating_stub");
        write_stub_artifacts(&dir, 48, 16, 16, 8).unwrap();
        match Runtime::load(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                None
            }
        }
    }

    fn cfg(gate: bool, batch: usize) -> ExperimentConfig {
        ExperimentConfig {
            scene: "truck".into(),
            scene_scale: 0.01,
            resolution: 64,
            frames: 2,
            batch,
            gate: Some(gate),
            ..Default::default()
        }
    }

    #[test]
    fn gated_pjrt_is_lossless_and_batch_invariant() {
        let Some(rt) = stub_runtime() else { return };
        let pjrt = Pjrt::new(&rt);

        let base = Session::builder(cfg(false, 1)).build().unwrap();
        let reference: Vec<_> =
            (0..base.num_frames()).map(|i| base.frame(i, &pjrt).unwrap()).collect();

        for batch in [1usize, 2, 8] {
            let s = Session::builder(cfg(true, batch)).build().unwrap();
            for (i, r) in reference.iter().enumerate() {
                let g = s.frame(i, &pjrt).unwrap();
                assert_eq!(r.image.data, g.image.data, "batch={batch} view={i}");
                // The gate shrank the dispatched lists…
                assert!(g.stats.gate_tile_rejected > 0, "batch={batch} view={i}");
                assert_eq!(
                    g.stats.splats_submitted + g.stats.gate_tile_rejected,
                    g.stats.tile_pairs as u64
                );
                // …while the ungated reference submitted everything.
                assert_eq!(r.stats.splats_submitted, r.stats.tile_pairs as u64);
            }
        }
    }
}
