//! Table II — (a) hardware configuration and area breakdown of FLICKER;
//! (b) area comparison against the 64-VRU simplified baseline.
//!
//! Paper shape: CTU < 10% of the rendering-core area; FLICKER-32+CTU saves
//! ~14% total area vs scaling the simplified design to 64 VRUs.

mod common;

use flicker::coordinator::report::Report;
use flicker::sim::area::{area, AreaParams};
use flicker::sim::HwConfig;

fn main() {
    let p = AreaParams::default();
    let flicker = HwConfig::flicker32();
    let r = area(&flicker, &p);

    let mut ta = Report::new("table2a", "Table II(a): FLICKER area breakdown");
    for (component, mm2, share) in r.rows() {
        ta.row(component, &[("mm2", mm2), ("share_pct", share * 100.0)]);
    }
    ta.row("TOTAL", &[("mm2", r.total_mm2()), ("share_pct", 100.0)]);
    ta.emit();

    let base = area(&HwConfig::simplified64(), &p);
    let mut tb = Report::new("table2b", "Table II(b): area vs 64-VRU baseline");
    tb.row("flicker32+ctu", &[("mm2", r.total_mm2())]);
    tb.row("simplified64", &[("mm2", base.total_mm2())]);
    let saving = 1.0 - r.total_mm2() / base.total_mm2();
    tb.row("saving", &[("fraction", saving)]);
    tb.emit();

    let ctu_ratio = r.ctu_mm2 / r.rendering_core_mm2();
    assert!(ctu_ratio < 0.10, "CTU/core {ctu_ratio}");
    assert!(
        (0.05..0.30).contains(&saving),
        "total saving {saving} out of band"
    );
    println!(
        "table2 OK: CTU {:.1}% of rendering core; {:.1}% total saving vs 64-VRU baseline",
        ctu_ratio * 100.0,
        saving * 100.0
    );
}
