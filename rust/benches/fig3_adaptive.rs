//! Fig. 3 — Mini-Tile CAT algorithm optimization:
//! (a) adaptive leader pixels: PSNR and leader-pixel savings of
//!     Uniform-Dense / Uniform-Sparse / Smooth-Focused / Spiky-Focused;
//! (b) pixel-rectangle grouping: op-count saving vs per-pixel ACU.
//!
//! Paper shape: Uniform-Dense ≈ vanilla; adaptive recovers most of
//! Uniform-Sparse's PSNR loss while keeping much of its leader savings;
//! PR grouping nearly halves CAT multiplies.

mod common;

use flicker::cat::pr::{acu_op_cost_4px, pr_op_cost};
use flicker::cat::{CatConfig, CatEngine, LeaderMode, Precision};
use flicker::coordinator::report::Report;
use flicker::coordinator::Golden;
use flicker::render::metrics::psnr;

fn main() {
    // One session-cached FramePlan for the whole mode sweep: the golden
    // reference and all four leader-pixel configs re-render the same
    // prepared view.
    let session = common::bench_session("garden");
    let golden = session.frame(common::BENCH_VIEW, &Golden).expect("golden render");
    let plan = session.plan(common::BENCH_VIEW);

    let mut report = Report::new("fig3", "Fig.3(a): adaptive leader pixels");
    let mut results = Vec::new();
    for (name, mode) in [
        ("uniform-dense", LeaderMode::UniformDense),
        ("uniform-sparse", LeaderMode::UniformSparse),
        ("smooth-focused", LeaderMode::SmoothFocused),
        ("spiky-focused", LeaderMode::SpikyFocused),
    ] {
        let mut engine = CatEngine::new(CatConfig {
            mode,
            precision: Precision::Fp32,
            stage1: true,
        });
        let out = plan.render_with(&mut engine, None);
        let p = psnr(&golden.image, &out.image);
        let leaders_used = engine.stats.dense_pairs * 16 + engine.stats.sparse_pairs * 8;
        report.row(
            name,
            &[
                ("psnr", p),
                ("leaders", leaders_used as f64),
                ("leader_saving", engine.stats.leader_saving_vs_dense()),
                ("pp_tested", out.stats.per_pixel_tested()),
            ],
        );
        results.push((name, p, leaders_used));
    }
    report.emit();

    // Fig. 3(b): op accounting for PR grouping.
    let mut opr = Report::new(
        "fig3b",
        "Fig.3(b): pixel-rectangle grouping op cost (4 leader px)",
    );
    let pr = pr_op_cost();
    let acu = acu_op_cost_4px();
    opr.row(
        "PRTU (Alg.1)",
        &[
            ("mul", pr.mul as f64),
            ("add", (pr.add + pr.sub) as f64),
            ("total", pr.total() as f64),
        ],
    );
    opr.row(
        "ACU x4",
        &[
            ("mul", acu.mul as f64),
            ("add", (acu.add + acu.sub) as f64),
            ("total", acu.total() as f64),
        ],
    );
    let mul_saving = 1.0 - pr.mul as f64 / acu.mul as f64;
    opr.row("saving", &[("mul", mul_saving)]);
    opr.emit();

    // Shape assertions.
    let dense = results.iter().find(|r| r.0 == "uniform-dense").unwrap();
    let sparse = results.iter().find(|r| r.0 == "uniform-sparse").unwrap();
    let adaptive = results.iter().find(|r| r.0 == "smooth-focused").unwrap();
    assert!(dense.1 > sparse.1, "dense must beat sparse on PSNR");
    assert!(
        adaptive.1 >= sparse.1,
        "adaptive {:.2} must recover sparse loss {:.2}",
        adaptive.1,
        sparse.1
    );
    assert!(adaptive.2 < dense.2, "adaptive must save leaders vs dense");
    assert!(mul_saving > 0.3, "PR saving {mul_saving}");
    println!(
        "fig3 OK: dense {:.2} dB, sparse {:.2} dB, adaptive {:.2} dB ({}/{} leaders)",
        dense.1, sparse.1, adaptive.1, adaptive.2, dense.2
    );
}
