//! Temporal plan-delta bench (paper §"frame-to-frame coherence"):
//! amortized frame-preparation cost vs orbit step size. For each orbit
//! granularity the bench times cold `FramePlan::build` per view against
//! chained `FramePlan::advance`, and records the reuse accounting behind
//! the ratio — how many splats changed tiles, how many tiles were patched,
//! how many (tile, splat) entries were carried. Coarse orbits (steps past
//! `DeltaConfig::max_angle`) show the fallback regime: `advance` degrades
//! to a cold build and the ratio goes to ~1.
//!
//! Emitted as `target/bench-reports/fig12_temporal.json`; the
//! `bench-record` CI lane merges it with the other reports into
//! `BENCH_7.json`.

mod common;

use flicker::render::delta::DeltaConfig;
use flicker::render::plan::FramePlan;
use flicker::render::raster::{RenderOptions, VanillaMasks};
use flicker::util::bench::{black_box, quick_mode, Bencher};

fn main() {
    let res = common::bench_resolution();
    let scene = common::bench_scene("garden");
    let opts = RenderOptions {
        plan_delta: DeltaConfig::on(),
        ..RenderOptions::default()
    };
    // Views advanced per timed iteration: enough to amortize, small enough
    // that the coarse-orbit (cold-fallback) rows stay cheap.
    let window = if quick_mode() { 3 } else { 6 };
    let mut b = Bencher::new("fig12_temporal");

    for frames in [8usize, 16, 32, 64] {
        let cams = common::bench_orbit(res, frames);
        let step = std::f32::consts::TAU / frames as f32;
        b.record(&format!("orbit{frames}/step_rad"), step as f64);

        let base = FramePlan::build(&scene, &cams[0], &opts);
        let cold_p50 = b
            .bench(&format!("orbit{frames}/plan_cold"), || {
                for cam in cams.iter().skip(1).take(window) {
                    black_box(FramePlan::build(&scene, cam, &opts));
                }
            })
            .summary
            .p50;
        let delta_p50 = b
            .bench(&format!("orbit{frames}/plan_delta"), || {
                let mut plan = base.advance(&scene, &cams[1], &opts);
                for cam in cams.iter().skip(2).take(window - 1) {
                    plan = plan.advance(&scene, cam, &opts);
                }
                black_box(plan);
            })
            .summary
            .p50;
        b.record(
            &format!("orbit{frames}/amortized_ratio"),
            delta_p50 / cold_p50.max(1e-12),
        );

        // Reuse accounting for one representative step, plus the pixels
        // check every row of this figure rests on: delta == cold, bitwise.
        let out = base.advance_detailed(&scene, &cams[1], &opts);
        b.record(
            &format!("orbit{frames}/fell_back"),
            out.stats.fell_back as u8 as f64,
        );
        if !out.stats.fell_back {
            let total = out.plan.splats.len().max(1);
            b.record(
                &format!("orbit{frames}/rebinned_frac"),
                out.stats.splats_reprojected as f64 / total as f64,
            );
            b.record(
                &format!("orbit{frames}/entries_carried"),
                out.stats.entries_carried as f64,
            );
            b.record(
                &format!("orbit{frames}/tiles_patched"),
                out.stats.tiles_patched as f64,
            );
            b.record(
                &format!("orbit{frames}/sort_fallbacks"),
                out.stats.sort_fallbacks as f64,
            );
        }
        let cold = FramePlan::build(&scene, &cams[1], &opts);
        let (a, c) = (
            out.plan.render(&VanillaMasks, None),
            cold.render(&VanillaMasks, None),
        );
        assert_eq!(
            a.image.data, c.image.data,
            "orbit{frames}: delta plan must render bit-identically"
        );
    }

    b.finish("temporal plan deltas: amortized plan cost vs orbit step");
}
