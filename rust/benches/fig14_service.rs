//! Multi-tenant serving bench (`coordinator::service`): golden-backend
//! latency under interleaved clients, cross-tenant plan-cache
//! amortization, and — with the `pjrt` feature, stub-backed — the
//! cross-client tile coalescer's fill-rate advantage over each client
//! dispatching its own uncoalesced waves. The coalesced fill row landing
//! strictly above the uncoalesced aggregate/per-client rows is the
//! padding-amortization claim in machine-checkable form.
//!
//! Emitted as `target/bench-reports/fig14_service.json`; the
//! `bench-record` CI lane merges it with the other reports into
//! `BENCH_10.json`.

mod common;

use flicker::camera::Camera;
use flicker::coordinator::{Golden, RenderRequest, RenderService, SceneId, ServiceConfig};
use flicker::render::metrics::latency_summary;
use flicker::render::raster::RenderOptions;
use flicker::util::bench::{black_box, Bencher};

/// Ragged interleaved request trace: client `c` renders `orbit.len() - c`
/// views phase-shifted by `c`, submitted round-robin (view 0 of every
/// client, then view 1, …). Assumes `clients < orbit.len()`.
fn requests(
    clients: usize,
    id: SceneId,
    orbit: &[Camera],
    opts: RenderOptions,
) -> Vec<RenderRequest> {
    let mut reqs = Vec::new();
    for v in 0..orbit.len() {
        for c in 0..clients {
            if v < orbit.len() - c {
                reqs.push(RenderRequest {
                    client: c,
                    view: v,
                    scene: id,
                    camera: orbit[(v + c) % orbit.len()],
                    options: opts,
                });
            }
        }
    }
    reqs
}

fn golden_rows(b: &mut Bencher, res: u32) {
    let scene = common::bench_scene("garden");
    let orbit = common::bench_orbit(res, 8);
    let opts = RenderOptions::default();
    for clients in [1usize, 2, 4] {
        let svc = RenderService::new(ServiceConfig {
            workers: 0,
            max_queue: 1024,
            ..Default::default()
        });
        let id = svc.register_scene(scene.clone());
        let reqs = requests(clients, id, &orbit, opts);
        for &r in &reqs {
            svc.submit(r).unwrap();
        }
        let frames = svc.drain(&Golden).unwrap();
        let lat: Vec<f64> = frames.iter().map(|f| f.metrics.wall_ms).collect();
        let s = latency_summary(&lat);
        b.record(&format!("clients{clients}/frames"), frames.len() as f64);
        b.record(&format!("clients{clients}/p50_ms"), s.p50);
        b.record(&format!("clients{clients}/p99_ms"), s.p99);
        let st = svc.stats();
        b.record(
            &format!("clients{clients}/plans_materialized"),
            (st.plan_builds + st.plan_delta_builds) as f64,
        );
        b.record(&format!("clients{clients}/plan_hits"), st.plan_hits as f64);
        // Warm-cache serving throughput: every pose is already cached, so
        // this times admission + queue + render, not plan building.
        b.bench(&format!("clients{clients}/drain_warm"), || {
            for &r in &reqs {
                svc.submit(r).unwrap();
            }
            black_box(svc.drain(&Golden).unwrap());
        });
    }
}

/// Stub-backed coalescer fill rates: three ragged clients, batch-8 waves.
#[cfg(feature = "pjrt")]
fn pjrt_rows(b: &mut Bencher, res: u32) {
    use flicker::render::image::Image;
    use flicker::render::plan::FramePlan;
    use flicker::runtime::executor::{TileExecutor, TileJob};
    use flicker::runtime::{write_stub_artifacts, Runtime};

    let dir = std::env::temp_dir().join("flicker_fig14_stub");
    write_stub_artifacts(&dir, 48, 16, 16, 8).unwrap();
    let rt = match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("fig14_service: stub runtime unavailable ({e}) - skipping pjrt rows");
            return;
        }
    };
    let scene = common::bench_scene("garden");
    let orbit = common::bench_orbit(res, 8);
    let opts = RenderOptions::default();
    let (clients, batch) = (3usize, 8usize);

    // Uncoalesced baseline: each client's frames dispatch their own waves,
    // so every client pays its own ragged-tail padding.
    let mut agg = (0usize, 0usize);
    let mut best = 0.0f64;
    for c in 0..clients {
        let mut ex = TileExecutor::new(&rt).with_batch(batch);
        for v in 0..orbit.len() - c {
            let cam = orbit[(v + c) % orbit.len()];
            let plan = FramePlan::build(&scene, &cam, &opts);
            let jobs = TileJob::for_grid(&plan.grid, &plan.lists);
            let mut img = Image::new(res, res);
            ex.render_tiles(&jobs, &plan.splats, &mut img, opts.background).unwrap();
            black_box(&img);
        }
        b.record(&format!("pjrt/fill_rate_client{c}"), ex.stats.fill_rate());
        best = best.max(ex.stats.fill_rate());
        agg.0 += ex.stats.splats_submitted;
        agg.1 += ex.stats.rows_submitted;
    }

    // Coalesced: the same trace through the service daemon, all clients'
    // tiles merged into shared waves.
    let svc = RenderService::new(ServiceConfig {
        workers: 0,
        batch,
        max_queue: 1024,
        ..Default::default()
    });
    let id = svc.register_scene(scene.clone());
    for r in requests(clients, id, &orbit, opts) {
        svc.submit(r).unwrap();
    }
    let (frames, ex) = svc.drain_coalesced(&rt).unwrap();
    black_box(frames);
    b.record("pjrt/fill_rate_coalesced", ex.fill_rate());
    let aggregate = if agg.1 > 0 { agg.0 as f64 / agg.1 as f64 } else { 0.0 };
    b.record("pjrt/fill_rate_uncoalesced_aggregate", aggregate);
    b.record("pjrt/fill_rate_per_client_best", best);
    b.record("pjrt/rows_saved", agg.1.saturating_sub(ex.rows_submitted) as f64);
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_rows(_b: &mut Bencher, _res: u32) {
    eprintln!("fig14_service: pjrt feature off - skipping coalescer fill rows");
}

fn main() {
    let res = common::bench_resolution();
    let mut b = Bencher::new("fig14_service");
    golden_rows(&mut b, res);
    pjrt_rows(&mut b, res);
    b.finish("multi-tenant service: latency, plan sharing, coalesced fill");
}
