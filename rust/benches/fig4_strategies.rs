//! Fig. 4 — per-pixel processed Gaussians across intersection strategies,
//! and duplicated Gaussians across tile sizes.
//!
//! Paper shape: Mini-Tile CAT processes ~10% of AABB-16×16's per-pixel
//! Gaussians (lowest of all strategies); shrinking tiles 16→4 multiplies
//! duplicates ~4×; Stage-1 sub-tile AABB cuts CTU load ~30%.

mod common;

use flicker::cat::{CatConfig, CatEngine, LeaderMode, ObbSubtileMask, Precision};
use flicker::coordinator::report::Report;
use flicker::render::plan::FramePlan;
use flicker::render::raster::{RenderOptions, VanillaMasks};
use flicker::render::tile::{build_tile_lists, duplicate_count, Strategy, TileGrid};
use flicker::sim::workload::extract;
use flicker::sim::{HwConfig, SubtileTest};

fn main() {
    let res = common::bench_resolution();
    let cam = common::bench_camera(res);
    let scene = common::bench_scene("garden");
    let opts = RenderOptions::default();

    // Per-pixel processed Gaussians by strategy. One AABB FramePlan serves
    // the vanilla, OBB-subtile, and Mini-Tile CAT rows (same tile lists,
    // different masks); only the OBB binning needs its own plan.
    let mut report = Report::new("fig4", "Fig.4: per-pixel processed Gaussians by strategy");
    let plan = FramePlan::build(&scene, &cam, &opts);
    let aabb16 = plan.render(&VanillaMasks, None);
    let pp_aabb = aabb16.stats.per_pixel_tested();
    report.row("aabb-16x16", &[("pp", pp_aabb), ("rel", 1.0)]);

    let obb16 = FramePlan::build(
        &scene,
        &cam,
        &RenderOptions {
            strategy: Strategy::Obb,
            ..opts
        },
    )
    .render(&VanillaMasks, None);
    report.row(
        "obb-16x16",
        &[
            ("pp", obb16.stats.per_pixel_tested()),
            ("rel", obb16.stats.per_pixel_tested() / pp_aabb),
        ],
    );

    let mut obb_sub = ObbSubtileMask::new();
    let obb8 = plan.render_with(&mut obb_sub, None);
    report.row(
        "obb-8x8-subtile",
        &[
            ("pp", obb8.stats.per_pixel_tested()),
            ("rel", obb8.stats.per_pixel_tested() / pp_aabb),
        ],
    );

    let mut cat = CatEngine::new(CatConfig {
        mode: LeaderMode::UniformDense,
        precision: Precision::Fp32,
        stage1: true,
    });
    let minitile = plan.render_with(&mut cat, None);
    let pp_cat = minitile.stats.per_pixel_tested();
    report.row("minitile-cat", &[("pp", pp_cat), ("rel", pp_cat / pp_aabb)]);
    report.emit();

    // Duplicates vs tile size — reuse the plan's projected splats instead
    // of re-projecting the scene.
    let splats = &plan.splats;
    let mut dup = Report::new("fig4b", "Fig.4: duplicated Gaussians vs tile size");
    let mut d16 = 0usize;
    for ts in [16u32, 8, 4] {
        let grid = TileGrid::new(res, res, ts);
        let d = duplicate_count(&build_tile_lists(splats, &grid, Strategy::Aabb));
        if ts == 16 {
            d16 = d;
        }
        dup.row(
            &format!("tile-{ts}x{ts}"),
            &[("duplicates", d as f64), ("rel", d as f64 / d16 as f64)],
        );
    }
    dup.emit();

    // Stage-1 CTU-load reduction.
    let wl_none = extract(
        &scene,
        &cam,
        &HwConfig {
            subtile_test: SubtileTest::None,
            ..HwConfig::flicker32()
        },
    );
    let wl_aabb = extract(&scene, &cam, &HwConfig::flicker32());
    let cut = 1.0 - wl_aabb.stage2_pairs as f64 / wl_none.stage2_pairs as f64;
    let mut s1 = Report::new("fig4c", "Fig.4: Stage-1 sub-tile AABB CTU-load cut");
    s1.row("no-stage1", &[("ctu_pairs", wl_none.stage2_pairs as f64)]);
    s1.row("with-stage1", &[("ctu_pairs", wl_aabb.stage2_pairs as f64), ("cut", cut)]);
    s1.emit();

    // Shape assertions.
    assert!(
        pp_cat < 0.35 * pp_aabb,
        "CAT should cut per-pixel Gaussians sharply: {pp_cat} vs {pp_aabb}"
    );
    assert!(pp_cat < obb8.stats.per_pixel_tested(), "CAT below OBB-subtile");
    let grid4 = TileGrid::new(res, res, 4);
    let d4 = duplicate_count(&build_tile_lists(splats, &grid4, Strategy::Aabb));
    assert!(d4 as f64 > 2.0 * d16 as f64, "4px tiles must inflate duplicates");
    assert!(cut > 0.10, "stage-1 cut {cut}");
    println!(
        "fig4 OK: CAT {:.1}% of AABB per-pixel work; 4px dup {:.1}x; stage1 cut {:.0}%",
        100.0 * pp_cat / pp_aabb,
        d4 as f64 / d16 as f64,
        cut * 100.0
    );
}
