//! Fig. 4 — per-pixel processed Gaussians across intersection strategies,
//! and duplicated Gaussians across tile sizes.
//!
//! Paper shape: Mini-Tile CAT processes ~10% of AABB-16×16's per-pixel
//! Gaussians (lowest of all strategies); shrinking tiles 16→4 multiplies
//! duplicates ~4×; Stage-1 sub-tile AABB cuts CTU load ~30%.

mod common;

use flicker::cat::{CatConfig, CatEngine, LeaderMode, ObbSubtileMask, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::report::Report;
use flicker::coordinator::{Golden, Session};
use flicker::render::tile::{build_tile_lists, duplicate_count, Strategy, TileGrid};
use flicker::sim::workload::extract_from_plan;
use flicker::sim::{HwConfig, SubtileTest};

fn main() {
    let res = common::bench_resolution();

    // Per-pixel processed Gaussians by strategy. One session-cached AABB
    // FramePlan serves the vanilla, OBB-subtile, and Mini-Tile CAT rows
    // (same tile lists, different masks); the OBB binning gets its own
    // session with the strategy threaded through the config — the
    // options-aware path the coordinator used to drop.
    let mut report = Report::new("fig4", "Fig.4: per-pixel processed Gaussians by strategy");
    let session = common::bench_session("garden");
    let scene = session.scene();
    let plan = session.plan(common::BENCH_VIEW);
    let aabb16 = session.frame(common::BENCH_VIEW, &Golden).expect("aabb render");
    let pp_aabb = aabb16.stats.per_pixel_tested();
    report.row("aabb-16x16", &[("pp", pp_aabb), ("rel", 1.0)]);

    let obb_session = Session::builder(ExperimentConfig {
        scene: "garden".into(),
        resolution: res,
        frames: 8,
        strategy: Some("obb".into()),
        ..Default::default()
    })
    .build()
    .expect("obb session");
    let obb16 = obb_session
        .frame(common::BENCH_VIEW, &Golden)
        .expect("obb render");
    report.row(
        "obb-16x16",
        &[
            ("pp", obb16.stats.per_pixel_tested()),
            ("rel", obb16.stats.per_pixel_tested() / pp_aabb),
        ],
    );

    let mut obb_sub = ObbSubtileMask::new();
    let obb8 = plan.render_with(&mut obb_sub, None);
    report.row(
        "obb-8x8-subtile",
        &[
            ("pp", obb8.stats.per_pixel_tested()),
            ("rel", obb8.stats.per_pixel_tested() / pp_aabb),
        ],
    );

    let mut cat = CatEngine::new(CatConfig {
        mode: LeaderMode::UniformDense,
        precision: Precision::Fp32,
        stage1: true,
    });
    let minitile = plan.render_with(&mut cat, None);
    let pp_cat = minitile.stats.per_pixel_tested();
    report.row("minitile-cat", &[("pp", pp_cat), ("rel", pp_cat / pp_aabb)]);
    report.emit();

    // Duplicates vs tile size — reuse the plan's projected splats instead
    // of re-projecting the scene.
    let splats = &plan.splats;
    let mut dup = Report::new("fig4b", "Fig.4: duplicated Gaussians vs tile size");
    let mut d16 = 0usize;
    for ts in [16u32, 8, 4] {
        let grid = TileGrid::new(res, res, ts);
        let d = duplicate_count(&build_tile_lists(splats, &grid, Strategy::Aabb));
        if ts == 16 {
            d16 = d;
        }
        dup.row(
            &format!("tile-{ts}x{ts}"),
            &[("duplicates", d as f64), ("rel", d as f64 / d16 as f64)],
        );
    }
    dup.emit();

    // Stage-1 CTU-load reduction. The workload extractor reuses the
    // session's cached plan instead of re-deriving frame preparation.
    let wl_none = extract_from_plan(
        scene,
        plan,
        &HwConfig {
            subtile_test: SubtileTest::None,
            ..HwConfig::flicker32()
        },
    );
    let wl_aabb = extract_from_plan(scene, plan, &HwConfig::flicker32());
    let cut = 1.0 - wl_aabb.stage2_pairs as f64 / wl_none.stage2_pairs as f64;
    let mut s1 = Report::new("fig4c", "Fig.4: Stage-1 sub-tile AABB CTU-load cut");
    s1.row("no-stage1", &[("ctu_pairs", wl_none.stage2_pairs as f64)]);
    s1.row("with-stage1", &[("ctu_pairs", wl_aabb.stage2_pairs as f64), ("cut", cut)]);
    s1.emit();

    // Shape assertions.
    assert!(
        pp_cat < 0.35 * pp_aabb,
        "CAT should cut per-pixel Gaussians sharply: {pp_cat} vs {pp_aabb}"
    );
    assert!(pp_cat < obb8.stats.per_pixel_tested(), "CAT below OBB-subtile");
    let grid4 = TileGrid::new(res, res, 4);
    let d4 = duplicate_count(&build_tile_lists(splats, &grid4, Strategy::Aabb));
    assert!(d4 as f64 > 2.0 * d16 as f64, "4px tiles must inflate duplicates");
    assert!(cut > 0.10, "stage-1 cut {cut}");
    println!(
        "fig4 OK: CAT {:.1}% of AABB per-pixel work; 4px dup {:.1}x; stage1 cut {:.0}%",
        100.0 * pp_cat / pp_aabb,
        d4 as f64 / d16 as f64,
        cut * 100.0
    );
}
