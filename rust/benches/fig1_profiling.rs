//! Fig. 1 — vanilla 3DGS profiling: (a) FPS on a desktop GPU (RTX 3090)
//! vs an edge GPU (Jetson XNX), (b) compute-unit vs achieved-FP32
//! utilization on the edge GPU.
//!
//! Paper shape: 3090 well above real-time, XNX collapses to single-digit
//! FPS; CU utilization high (~85%) while achieved FP32 stays low (~29%).

mod common;

use flicker::coordinator::report::Report;
use flicker::sim::gpu::{estimate, GpuParams};
use flicker::sim::workload::extract;
use flicker::sim::HwConfig;
use flicker::util::stats::harmonic_mean;

fn main() {
    let res = common::bench_resolution();
    let cam = common::bench_camera(res);
    // Vanilla 3DGS workload: tile-level AABB only, no CTU.
    let hw = HwConfig {
        subtile_test: flicker::sim::SubtileTest::None,
        ..HwConfig::simplified32()
    };

    let mut report = Report::new("fig1", "Fig.1: vanilla 3DGS on desktop vs edge GPU");
    let mut fps_3090 = Vec::new();
    let mut fps_xnx = Vec::new();
    let mut cu = Vec::new();
    let mut fp = Vec::new();

    for name in common::all_scene_names() {
        let scene = common::bench_scene(name);
        let wl = extract(&scene, &cam, &hw);
        let d = estimate(&wl, &GpuParams::rtx3090());
        let e = estimate(&wl, &GpuParams::xavier_nx());
        fps_3090.push(d.fps);
        fps_xnx.push(e.fps);
        cu.push(e.cu_util);
        fp.push(e.fp_util);
        report.row(
            name,
            &[
                ("fps_3090", d.fps),
                ("fps_xnx", e.fps),
                ("cu_util", e.cu_util),
                ("fp_util", e.fp_util),
            ],
        );
    }
    report.row(
        "AVERAGE",
        &[
            ("fps_3090", harmonic_mean(&fps_3090)),
            ("fps_xnx", harmonic_mean(&fps_xnx)),
            ("cu_util", cu.iter().sum::<f64>() / cu.len() as f64),
            ("fp_util", fp.iter().sum::<f64>() / fp.len() as f64),
        ],
    );
    report.emit();

    // Shape assertions (paper: desktop real-time, edge collapses; CU ≫ FP).
    // At CI scene scale the absolute gap compresses (fixed per-frame costs
    // dominate the under-loaded desktop); paper-scale runs
    // (FLICKER_SCENE_SCALE=1.0, FLICKER_BENCH_RES=800) show the full ~20×.
    let d = harmonic_mean(&fps_3090);
    let e = harmonic_mean(&fps_xnx);
    assert!(d / e > 4.0, "desktop/edge gap {d}/{e}");
    let cu_avg = cu.iter().sum::<f64>() / cu.len() as f64;
    let fp_avg = fp.iter().sum::<f64>() / fp.len() as f64;
    assert!(cu_avg > 2.0 * fp_avg, "CU {cu_avg} vs FP {fp_avg}");
    println!("fig1 OK: desktop {d:.0} fps, edge {e:.1} fps, CU {cu_avg:.2}, FP {fp_avg:.2}");
}
