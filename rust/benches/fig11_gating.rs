//! Coarse-to-fine gating bench (paper §"hierarchical Gaussian testing"):
//! how many Gaussian×tile pairs the pyramid gate removes before the fine
//! per-pixel loop, at what quality cost. At the default threshold (the
//! 1/255 blend floor) the gate is lossless — PSNR rows print as 99 (the
//! JSON-safe cap for infinite PSNR) and `splats_submitted` is the whole
//! story. The threshold sweep shows the lossy knee: raising `--gate-
//! threshold` trades PSNR for extra culling.
//!
//! Emitted as `target/bench-reports/fig11_gating.json`; the `bench-record`
//! CI lane merges it with the other reports into `BENCH_7.json`.

mod common;

use flicker::render::metrics::psnr;
use flicker::render::plan::FramePlan;
use flicker::render::project::ALPHA_MIN;
use flicker::render::pyramid::GateConfig;
use flicker::render::raster::{RenderOptions, VanillaMasks};
use flicker::util::bench::{black_box, Bencher};

fn main() {
    let res = common::bench_resolution();
    let cam = common::bench_camera(res);
    let mut b = Bencher::new("fig11_gating");

    for scene_name in ["garden", "truck"] {
        let scene = common::bench_scene(scene_name);
        let off_plan = FramePlan::build(&scene, &cam, &RenderOptions::default());
        let on_plan = FramePlan::build(
            &scene,
            &cam,
            &RenderOptions {
                gate: GateConfig::on(),
                ..RenderOptions::default()
            },
        );
        let off = off_plan.render(&VanillaMasks, None);
        let on = on_plan.render(&VanillaMasks, None);

        b.record(
            &format!("{scene_name}/gate_off/splats_submitted"),
            off.stats.splats_submitted as f64,
        );
        b.record(
            &format!("{scene_name}/gate_on/splats_submitted"),
            on.stats.splats_submitted as f64,
        );
        b.record(
            &format!("{scene_name}/gate_on/tile_rejected"),
            on.stats.gate_tile_rejected as f64,
        );
        b.record(
            &format!("{scene_name}/gate_on/quad_rejected"),
            on.stats.gate_quad_rejected as f64,
        );
        b.record(
            &format!("{scene_name}/gate_on/tile_reject_rate"),
            on.stats.gate_tile_reject_rate(),
        );
        b.record(
            &format!("{scene_name}/gate_on/quad_reject_rate"),
            on.stats.gate_quad_reject_rate(),
        );
        let cut = 1.0 - on.stats.splats_submitted as f64 / off.stats.splats_submitted.max(1) as f64;
        b.record(&format!("{scene_name}/gate_on/submitted_cut"), cut);
        // Identical images give infinite PSNR; cap at 99 so the JSON report
        // stays finite.
        b.record(
            &format!("{scene_name}/gate_on/psnr_vs_off"),
            psnr(&off.image, &on.image).min(99.0),
        );

        // Lossy knee: coarser thresholds (in units of the 1/255 floor).
        for mult in [2.0f32, 4.0] {
            let cfg = GateConfig {
                enabled: true,
                levels: 2,
                threshold: ALPHA_MIN * mult,
            };
            let plan = FramePlan::build(
                &scene,
                &cam,
                &RenderOptions {
                    gate: cfg,
                    ..RenderOptions::default()
                },
            );
            let out = plan.render(&VanillaMasks, None);
            let cut =
                1.0 - out.stats.splats_submitted as f64 / off.stats.splats_submitted.max(1) as f64;
            b.record(&format!("{scene_name}/thr{mult}x/submitted_cut"), cut);
            b.record(
                &format!("{scene_name}/thr{mult}x/psnr_vs_off"),
                psnr(&off.image, &out.image).min(99.0),
            );
        }

        // Wall-clock: the gate must pay for itself — rejected pairs skip
        // both masking and the fine loop.
        b.bench(&format!("{scene_name}/render_gate_off"), || {
            black_box(off_plan.render(&VanillaMasks, None));
        });
        b.bench(&format!("{scene_name}/render_gate_on"), || {
            black_box(on_plan.render(&VanillaMasks, None));
        });
    }

    b.finish("coarse-to-fine gating: submitted-work cut vs quality");
}
