//! Shared helpers for the figure/table benches.
//!
//! Every bench regenerates one paper artifact. Scenes default to a small
//! scale so `cargo bench` finishes on CI hardware; set
//! `FLICKER_SCENE_SCALE=1.0` for paper-scale runs (same code path).
#![allow(dead_code)] // each bench target compiles its own copy and uses a subset

use flicker::camera::{orbit_path, Camera, Intrinsics};
use flicker::config::{default_scene_scale, ExperimentConfig};
use flicker::coordinator::Session;
use flicker::scene::gaussian::Scene;
use flicker::scene::synthetic::{generate_scaled, preset, presets};

/// Evaluation resolution for benches (paper uses dataset-native; the shape
/// of every comparison is resolution-independent because all configs see the
/// same workload). Under the smoke knob (`--quick` /
/// `FLICKER_BENCH_QUICK`, see `util::bench::quick_mode`) the default drops
/// so every bench target runs end-to-end in seconds.
pub fn bench_resolution() -> u32 {
    std::env::var("FLICKER_BENCH_RES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if flicker::util::bench::quick_mode() {
            96
        } else {
            192
        })
}

/// Build a bench scene at the CI scale.
pub fn bench_scene(name: &str) -> Scene {
    generate_scaled(&preset(name), default_scene_scale())
}

/// All eight evaluation scenes.
pub fn all_scene_names() -> Vec<&'static str> {
    presets().iter().map(|p| p.name).collect()
}

/// The orbit view index `bench_camera` corresponds to inside the standard
/// 8-view bench orbit (see [`bench_session`]).
pub const BENCH_VIEW: usize = 1;

/// A prepared `coordinator::Session` over the standard 8-view bench orbit
/// for `name` at the bench resolution. `session.camera(BENCH_VIEW)` is
/// exactly [`bench_camera`], and `session.plan(BENCH_VIEW)` is the cached
/// FramePlan the figure sweeps re-render.
pub fn bench_session(name: &str) -> Session {
    let cfg = ExperimentConfig {
        scene: name.into(),
        resolution: bench_resolution(),
        frames: 8,
        ..Default::default()
    };
    Session::builder(cfg).build().expect("bench session")
}

/// The standard evaluation camera for a scene.
pub fn bench_camera(res: u32) -> Camera {
    orbit_path(
        Intrinsics::from_fov(res, res, 1.2),
        flicker::numeric::linalg::v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        8,
    )[1]
}

/// A short orbit for multi-view quality numbers.
pub fn bench_orbit(res: u32, frames: usize) -> Vec<Camera> {
    orbit_path(
        Intrinsics::from_fov(res, res, 1.2),
        flicker::numeric::linalg::v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        frames,
    )
}
