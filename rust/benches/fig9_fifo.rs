//! Fig. 9 — sensitivity of rendering-stage speedup and CTU stall rate to
//! the feature-FIFO depth (1..128), on *Garden*.
//!
//! Paper shape: speedup saturates around 1.36× at depth 128; depth 16
//! already reaches ~96% of the maximum with 12.5% of the memory; stall
//! rate falls monotonically.

mod common;

use flicker::coordinator::report::Report;
use flicker::sim::top::simulate_workload;
use flicker::sim::workload::extract;
use flicker::sim::HwConfig;

fn main() {
    let res = common::bench_resolution();
    let cam = common::bench_camera(res);
    let scene = common::bench_scene("garden");
    let base = HwConfig {
        clustering: false,
        ..HwConfig::flicker32()
    };
    // One functional pass, replayed against each depth.
    let wl = extract(&scene, &cam, &base);

    let depths = [1usize, 2, 4, 8, 16, 32, 64, 128];
    let mut cycles = Vec::new();
    let mut stalls = Vec::new();
    for &d in &depths {
        let hw = HwConfig {
            fifo_depth: d,
            ..base.clone()
        };
        let r = simulate_workload(&scene, &cam, &hw, wl.clone());
        cycles.push(r.render_cycles as f64);
        stalls.push(r.pipe.stall_rate());
    }

    let depth1 = cycles[0];
    let mut report = Report::new("fig9", "Fig.9: FIFO depth vs speedup & CTU stall rate (Garden)");
    for (i, &d) in depths.iter().enumerate() {
        report.row(
            &format!("depth={d}"),
            &[
                ("speedup", depth1 / cycles[i]),
                ("stall_rate", stalls[i]),
                ("cycles", cycles[i]),
            ],
        );
    }
    report.emit();

    // Shape assertions.
    let max_speedup = depth1 / cycles[cycles.len() - 1];
    let sp16 = depth1 / cycles[4];
    assert!(max_speedup >= 1.0);
    assert!(
        sp16 >= 0.90 * max_speedup,
        "depth16 {sp16} should reach most of max {max_speedup}"
    );
    for w in stalls.windows(2) {
        assert!(w[1] <= w[0] + 1e-9, "stall rate must fall with depth: {stalls:?}");
    }
    println!(
        "fig9 OK: max speedup {max_speedup:.3}, depth-16 at {:.1}% of max, stall d1 {:.1}% → d128 {:.1}%",
        100.0 * sp16 / max_speedup,
        stalls[0] * 100.0,
        stalls[stalls.len() - 1] * 100.0
    );
}
