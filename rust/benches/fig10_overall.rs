//! Fig. 10 — overall system speedup and energy efficiency across all eight
//! scenes, normalized to the edge GPU (Jetson XNX), with pruning and
//! clustering enabled (the paper's full-system configuration).
//!
//! Paper shape: FLICKER averages ~1.1× GSCore's speedup (14.4× vs XNX)
//! and wins energy efficiency on every dataset (up to 2.6× GSCore,
//! 26.7× vs XNX).

mod common;

use flicker::coordinator::report::Report;
use flicker::scene::pruning::{prune, PruneConfig};
use flicker::sim::gpu::{estimate, GpuParams};
use flicker::sim::top::simulate_frame;
use flicker::sim::workload::extract;
use flicker::sim::{HwConfig, SubtileTest};
use flicker::util::stats::geomean;

fn main() {
    let res = common::bench_resolution();
    let cam = common::bench_camera(res);
    let views = common::bench_orbit(res, 3);

    let mut report = Report::new("fig10", "Fig.10: overall speedup & energy vs XNX");
    let mut sp_flicker = Vec::new();
    let mut sp_gscore = Vec::new();
    let mut ee_flicker = Vec::new();
    let mut ee_gscore = Vec::new();

    for name in common::all_scene_names() {
        let mut scene = common::bench_scene(name);
        // Full-system configuration: pruned + clustered models.
        prune(&mut scene, &views, &PruneConfig::default());

        // GPU baseline (vanilla tile lists).
        let wl_gpu = extract(
            &scene,
            &cam,
            &HwConfig {
                subtile_test: SubtileTest::None,
                ..HwConfig::simplified32()
            },
        );
        let xnx = estimate(&wl_gpu, &GpuParams::xavier_nx());

        let fl = simulate_frame(&scene, &cam, &HwConfig::flicker32());
        let gs = simulate_frame(&scene, &cam, &HwConfig::gscore64());

        let xnx_ms = xnx.frame_ms;
        let xnx_mj = xnx.energy_mj_per_frame;
        let s_f = xnx_ms / fl.frame_ms;
        let s_g = xnx_ms / gs.frame_ms;
        let e_f = xnx_mj / (fl.energy.total_uj() / 1e3);
        let e_g = xnx_mj / (gs.energy.total_uj() / 1e3);
        sp_flicker.push(s_f);
        sp_gscore.push(s_g);
        ee_flicker.push(e_f);
        ee_gscore.push(e_g);
        report.row(
            name,
            &[
                ("sp_flicker", s_f),
                ("sp_gscore", s_g),
                ("ee_flicker", e_f),
                ("ee_gscore", e_g),
            ],
        );
    }
    report.row(
        "GEOMEAN",
        &[
            ("sp_flicker", geomean(&sp_flicker)),
            ("sp_gscore", geomean(&sp_gscore)),
            ("ee_flicker", geomean(&ee_flicker)),
            ("ee_gscore", geomean(&ee_gscore)),
        ],
    );
    report.emit();

    // Shape assertions: both accelerators far above the edge GPU; FLICKER
    // at least on par with GSCore on speedup and ahead on energy.
    let (sf, sg) = (geomean(&sp_flicker), geomean(&sp_gscore));
    let (ef, eg) = (geomean(&ee_flicker), geomean(&ee_gscore));
    assert!(sf > 3.0, "flicker vs xnx speedup {sf}");
    assert!(sf > 0.8 * sg, "flicker {sf} vs gscore {sg}");
    assert!(ef > eg, "flicker energy eff {ef} vs gscore {eg}");
    println!(
        "fig10 OK: speedup vs XNX — flicker {sf:.1}x, gscore {sg:.1}x; energy — flicker {ef:.1}x, gscore {eg:.1}x"
    );
}
