//! Hot-path microbenchmarks (the §Perf instrument): wall-clock timings of
//! the L3 pipeline stages so the optimization pass has a stable baseline.
//! Not a paper figure — this is the profiling harness.

mod common;

use flicker::camera::{orbit_path, Intrinsics};
use flicker::cat::{CatConfig, CatEngine, LeaderMode, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::{Golden, Session};
use flicker::numeric::linalg::v3;
use flicker::render::delta::DeltaConfig;
use flicker::render::plan::FramePlan;
use flicker::render::project::project_scene;
use flicker::render::raster::{render, render_masked, RenderOptions, VanillaMasks};
use flicker::render::sort::sort_by_depth;
use flicker::render::tile::{build_tile_lists, Strategy, TileGrid};
use flicker::scene::pruning::score_views;
use flicker::sim::top::simulate_workload;
use flicker::sim::workload::extract;
use flicker::sim::HwConfig;
use flicker::util::bench::{black_box, Bencher};

fn main() {
    let res = common::bench_resolution();
    let cam = common::bench_camera(res);
    let scene = common::bench_scene("garden");
    let mut b = Bencher::new("hotpath");

    b.bench("project_scene", || {
        black_box(project_scene(&scene, &cam));
    });

    let splats = project_scene(&scene, &cam);
    let grid = TileGrid::new(res, res, 16);
    b.bench("tile_binning_aabb", || {
        black_box(build_tile_lists(&splats, &grid, Strategy::Aabb));
    });
    b.bench("tile_binning_obb", || {
        black_box(build_tile_lists(&splats, &grid, Strategy::Obb));
    });

    let mut lists = build_tile_lists(&splats, &grid, Strategy::Aabb);
    b.bench("depth_sort", || {
        let mut ls = lists.clone();
        for l in &mut ls {
            sort_by_depth(l, &splats);
        }
        black_box(ls);
    });
    for l in &mut lists {
        sort_by_depth(l, &splats);
    }

    // Rebuild-per-call baseline: the one-shot wrapper re-derives the plan
    // (project → bin → sort) on every render — what quality sweeps paid
    // before FramePlan.
    b.bench("raster_vanilla", || {
        black_box(render(&scene, &cam, &RenderOptions::default()));
    });

    // FramePlan reuse: the fig3/fig7/Table-I sweep pattern — one view
    // re-rendered under many configs. `plan_build` is the amortized cost,
    // `plan_reuse` the steady-state per-render cost; plan_reuse must beat
    // raster_vanilla by roughly plan_build per call.
    let plan_build_p50 = b
        .bench("plan_build", || {
            black_box(FramePlan::build(&scene, &cam, &RenderOptions::default()));
        })
        .summary
        .p50;
    let plan = FramePlan::build(&scene, &cam, &RenderOptions::default());
    b.bench("plan_reuse", || {
        black_box(plan.render(&VanillaMasks, None));
    });

    // Temporal plan delta: advancing a cached plan one fine orbit step vs
    // cold-building the next view. Both paths pay the full re-projection
    // (bit-identity requires it); the delta saves tile binning and most of
    // the depth sort. `plan_delta/cost_vs_build` records the amortized
    // per-step ratio fig12_temporal sweeps across orbit step sizes.
    let delta_opts = RenderOptions {
        plan_delta: DeltaConfig::on(),
        ..RenderOptions::default()
    };
    let fine_orbit = common::bench_orbit(res, 64); // ~0.1 rad per step
    let prev = FramePlan::build(&scene, &fine_orbit[0], &delta_opts);
    let plan_delta_p50 = b
        .bench("plan_delta", || {
            black_box(prev.advance(&scene, &fine_orbit[1], &delta_opts));
        })
        .summary
        .p50;
    b.bench("plan_delta_chain", || {
        let mut p = prev.advance(&scene, &fine_orbit[1], &delta_opts);
        for c in &fine_orbit[2..6] {
            p = p.advance(&scene, c, &delta_opts);
        }
        black_box(p);
    });
    b.record(
        "plan_delta/cost_vs_build",
        plan_delta_p50 / plan_build_p50.max(1e-12),
    );

    // Same cached-plan render with the coarse-to-fine gate on (lossless
    // default threshold): whole-tile rejects skip masking + the fine loop,
    // so this should track or beat plan_reuse.
    let gated_plan = FramePlan::build(
        &scene,
        &cam,
        &RenderOptions {
            gate: flicker::render::pyramid::GateConfig::on(),
            ..RenderOptions::default()
        },
    );
    b.bench("plan_reuse_gated", || {
        black_box(gated_plan.render(&VanillaMasks, None));
    });

    // Session steady state: the cached-plan render behind session.frame —
    // must track plan_reuse (the cache adds only two atomic bumps).
    let session = common::bench_session("garden");
    session.frame(common::BENCH_VIEW, &Golden).unwrap(); // warm the cache
    b.bench("session_frame_cached", || {
        black_box(session.frame(common::BENCH_VIEW, &Golden).unwrap());
    });

    // Streaming a short orbit across the full pool (completion-order
    // fan-out + orbit-order re-sort), plans cached after the first pass.
    let stream_session = Session::builder(ExperimentConfig {
        scene: "garden".into(),
        resolution: res,
        frames: 4,
        workers: 0, // auto
        ..Default::default()
    })
    .build()
    .unwrap();
    b.bench("session_stream_orbit", || {
        black_box(stream_session.stream(&Golden).ordered().unwrap());
    });

    // Tile fan-out across all cores (bit-identical output, wall-clock win).
    let par_opts = RenderOptions {
        workers: 0, // auto
        ..RenderOptions::default()
    };
    b.bench("raster_vanilla_parallel", || {
        black_box(render(&scene, &cam, &par_opts));
    });

    b.bench("raster_cat", || {
        let mut engine = CatEngine::new(CatConfig {
            mode: LeaderMode::SmoothFocused,
            precision: Precision::Mixed,
            stage1: true,
        });
        black_box(render_masked(
            &scene,
            &cam,
            &RenderOptions::default(),
            &mut engine,
            None,
        ));
    });

    // Rebuilds the plan per call (like raster_cat above) so the
    // sequential-vs-parallel comparison stays apples-to-apples; the
    // plan-reuse saving is measured separately by plan_build/plan_reuse.
    let cat_cfg = CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Mixed,
        stage1: true,
    };
    b.bench("raster_cat_parallel", || {
        black_box(FramePlan::build(&scene, &cam, &par_opts).render(&cat_cfg, None));
    });

    // Pruning contribution scoring (Σ T·α over scoring views) — the pass
    // FLICKER's premise says dominates edge 3DGS cost. Sequential vs
    // full-pool fan-out; scores are bit-identical either way.
    let score_cams = orbit_path(
        Intrinsics::from_fov(res, res, 1.2),
        v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        4,
    );
    b.bench("prune_scoring", || {
        black_box(score_views(&scene, &score_cams, &RenderOptions::default(), 1));
    });
    b.bench("prune_scoring_parallel", || {
        black_box(score_views(&scene, &score_cams, &RenderOptions::default(), 0));
    });

    // The view×tile work-stealing regime: FEWER views than cores. The old
    // views-first budget split would strand all but two workers here; the
    // flattened (view × tile) queue drains every tile of both views across
    // the whole pool. Bit-identical to prune_scoring for the same views.
    let few_cams = orbit_path(
        Intrinsics::from_fov(res, res, 1.2),
        v3(0.0, 0.5, 0.0),
        12.0,
        3.0,
        2,
    );
    b.bench("score_views_viewtile", || {
        black_box(score_views(&scene, &few_cams, &RenderOptions::default(), 0));
    });

    // PJRT dispatch overhead: one exec per tile-chunk (exec_tile_single)
    // vs the batched artifact draining n_batch tiles per dispatch
    // (exec_tile_batched). Runs against the offline stub runtime —
    // identical pixels, fewer invocations; with real XLA the batched row
    // additionally amortizes PJRT call overhead.
    #[cfg(feature = "pjrt")]
    {
        use flicker::render::image::Image;
        use flicker::runtime::executor::{TileExecutor, TileJob};
        use flicker::runtime::{write_stub_artifacts, Runtime};

        let dir = std::env::temp_dir().join("flicker_hotpath_stub_artifacts");
        write_stub_artifacts(&dir, 64, 16, 16, 8).unwrap();
        match Runtime::load(&dir) {
            Ok(rt) => {
                let plan = FramePlan::build(&scene, &cam, &RenderOptions::default());
                let jobs = TileJob::for_grid(&plan.grid, &plan.lists);
                b.bench("exec_tile_single", || {
                    let mut img = Image::new(plan.grid.width, plan.grid.height);
                    let mut ex = TileExecutor::new(&rt);
                    for job in &jobs {
                        ex.render_tile(&job.rect, &plan.splats, job.order, &mut img, [0.0; 3])
                            .unwrap();
                    }
                    black_box(img);
                });
                b.bench("exec_tile_batched", || {
                    let mut img = Image::new(plan.grid.width, plan.grid.height);
                    let mut ex = TileExecutor::new(&rt);
                    ex.render_tiles(&jobs, &plan.splats, &mut img, [0.0; 3]).unwrap();
                    black_box(img);
                });
            }
            Err(e) => eprintln!("skipping exec_tile rows: pjrt runtime unavailable ({e})"),
        }
    }

    let hw = HwConfig::flicker32();
    b.bench("workload_extract", || {
        black_box(extract(&scene, &cam, &hw));
    });

    let wl = extract(&scene, &cam, &hw);
    b.bench("cycle_sim_replay", || {
        black_box(simulate_workload(&scene, &cam, &hw, wl.clone()));
    });

    b.finish("hot-path stage timings");
}
