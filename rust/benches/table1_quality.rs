//! Table I — rendering quality (PSNR/SSIM) across the three dataset groups:
//! Baseline (vanilla render), Pruned, and Ours (pruned + adaptive Mini-Tile
//! CAT at mixed precision).
//!
//! Paper shape: pruning costs ~0.5 dB on average; CAT adds only ~0.1 dB on
//! top of pruning; SSIM essentially unchanged.

mod common;

use flicker::cat::{CatConfig, CatEngine, LeaderMode, Precision};
use flicker::coordinator::report::Report;
use flicker::coordinator::Golden;
use flicker::render::metrics::{psnr, ssim};
use flicker::render::plan::FramePlan;
use flicker::render::raster::VanillaMasks;
use flicker::scene::pruning::{prune, PruneConfig};

fn main() {
    let res = common::bench_resolution();
    let views = common::bench_orbit(res, 3);

    let mut report = Report::new("table1", "Table I: PSNR/SSIM across approaches");
    let mut deltas_prune = Vec::new();
    let mut deltas_ours = Vec::new();

    for name in common::all_scene_names() {
        // One session per scene: the unpruned baseline render and the
        // standard evaluation camera come from the session.
        let session = common::bench_session(name);
        let cam = session.camera(common::BENCH_VIEW);
        // "Baseline" reference image: vanilla render of the unpruned model.
        let gt = session
            .frame(common::BENCH_VIEW, &Golden)
            .expect("baseline render")
            .image;

        // Pruned model (explicit 3-view scoring orbit, Table I's setup):
        // one FramePlan serves both the "Prun." and "Ours" rows (same
        // scene + view, different masks).
        let mut pruned = session.scene().clone();
        prune(&mut pruned, &views, &PruneConfig::default());
        let pruned_plan = FramePlan::build(&pruned, cam, session.options());
        let img_pruned = pruned_plan.render(&VanillaMasks, None).image;

        // Ours: pruned + adaptive CAT at mixed precision.
        let mut engine = CatEngine::new(CatConfig {
            mode: LeaderMode::SmoothFocused,
            precision: Precision::Mixed,
            stage1: true,
        });
        let img_ours = pruned_plan.render_with(&mut engine, None).image;

        let p_prune = psnr(&gt, &img_pruned);
        let p_ours = psnr(&gt, &img_ours);
        deltas_prune.push(p_prune);
        deltas_ours.push(p_ours);
        report.row(
            name,
            &[
                ("psnr_prune", p_prune),
                ("psnr_ours", p_ours),
                ("ssim_prune", ssim(&gt, &img_pruned)),
                ("ssim_ours", ssim(&gt, &img_ours)),
            ],
        );
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    report.row(
        "AVERAGE",
        &[
            ("psnr_prune", avg(&deltas_prune)),
            ("psnr_ours", avg(&deltas_ours)),
        ],
    );
    report.emit();

    // Shape: CAT costs little on top of pruning (paper: −0.11 dB).
    let delta = avg(&deltas_prune) - avg(&deltas_ours);
    assert!(
        delta < 1.5,
        "CAT should cost ≲1 dB over pruning, got {delta}"
    );
    assert!(avg(&deltas_ours) > 22.0, "ours avg PSNR {}", avg(&deltas_ours));
    println!(
        "table1 OK: prune avg {:.2} dB, ours avg {:.2} dB (Δ {:.2} dB)",
        avg(&deltas_prune),
        avg(&deltas_ours),
        delta
    );
}
