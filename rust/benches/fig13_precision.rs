//! Adaptive-precision bench (paper §IV-C applied per tile): what the
//! contribution-driven tile classing buys. For each evaluation scene it
//! reports the realized class mix (tile counts and CTU PR counts per
//! class), the quality cost against a global-fp32 CAT render, and the
//! CTU energy of the realized mix priced next to running the same frame
//! entirely at fp32. The per-PR op-mix prices (`sim::energy::pr_pj`) are
//! recorded once so the JSON is self-describing.
//!
//! The per-rect rows put the second-level quadrant classing next to the
//! per-tile run: quadrant class mix, rect-vs-fp32 quality, and the
//! quadrant-weighted CTU price, so the tile-vs-rect coverage/quality/
//! energy tradeoff reads off one report.
//!
//! Emitted as `target/bench-reports/fig13_precision.json`; the
//! `bench-record` CI lane merges it with the other reports into
//! `BENCH_10.json`.

mod common;

use flicker::cat::{CatConfig, LeaderMode, Precision};
use flicker::render::metrics::psnr;
use flicker::render::plan::FramePlan;
use flicker::render::precision::{class_index, PrecisionPolicy, CLASSES};
use flicker::render::raster::RenderOptions;
use flicker::sim::energy::{frame_energy, pr_pj, EnergyParams};
use flicker::sim::workload::extract_from_plan;
use flicker::sim::HwConfig;
use flicker::util::bench::{black_box, Bencher};

fn main() {
    let res = common::bench_resolution();
    let cam = common::bench_camera(res);
    let mut b = Bencher::new("fig13_precision");

    let cat = CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Fp32,
        stage1: true,
    };
    let fp32_opts = RenderOptions::default();
    let adaptive_opts = RenderOptions {
        precision: PrecisionPolicy::adaptive(),
        ..RenderOptions::default()
    };
    let rect_opts = RenderOptions {
        precision: PrecisionPolicy::rect(),
        ..RenderOptions::default()
    };
    let hw = HwConfig {
        cat_precision: Precision::Fp32,
        ..HwConfig::flicker32()
    };
    let energy = EnergyParams::default();

    for c in CLASSES {
        b.record(&format!("pr_pj/{}", c.name()), pr_pj(&energy, c));
    }

    for scene_name in ["garden", "truck"] {
        let scene = common::bench_scene(scene_name);
        let fp32_plan = FramePlan::build(&scene, &cam, &fp32_opts);
        let adaptive_plan = FramePlan::build(&scene, &cam, &adaptive_opts);
        let classes = adaptive_plan
            .tile_classes()
            .expect("adaptive plans class every tile");

        // Realized class mix over populated tiles (empty tiles class at
        // the floor for free and would flatter the shares).
        let mut tiles = [0usize; 4];
        let mut populated = 0usize;
        for (t, class) in classes.iter().enumerate() {
            if adaptive_plan.lists[t].is_empty() {
                continue;
            }
            populated += 1;
            tiles[class_index(*class)] += 1;
        }
        for c in CLASSES {
            b.record(
                &format!("{scene_name}/tiles/{}", c.name()),
                tiles[class_index(c)] as f64,
            );
        }
        let below = populated - tiles[class_index(Precision::Fp32)];
        b.record(
            &format!("{scene_name}/tiles/below_fp32_share"),
            below as f64 / populated.max(1) as f64,
        );

        // Quality: adaptive CAT render vs global-fp32 CAT render.
        let reference = fp32_plan.render(&cat, None);
        let adaptive = adaptive_plan.render(&cat, None);
        b.record(
            &format!("{scene_name}/psnr_vs_fp32"),
            psnr(&reference.image, &adaptive.image).min(99.0),
        );

        // CTU energy: realized class mix vs the same frame all-fp32.
        let wl_adaptive = extract_from_plan(&scene, &adaptive_plan, &hw);
        let wl_fp32 = extract_from_plan(&scene, &fp32_plan, &hw);
        for c in CLASSES {
            b.record(
                &format!("{scene_name}/ctu_prs/{}", c.name()),
                wl_adaptive.ctu_prs_by_class[class_index(c)] as f64,
            );
        }
        let e_adaptive = frame_energy(&wl_adaptive, &hw, 0, 0, &energy).ctu_uj;
        let e_fp32 = frame_energy(&wl_fp32, &hw, 0, 0, &energy).ctu_uj;
        b.record(&format!("{scene_name}/ctu_uj/adaptive"), e_adaptive);
        b.record(&format!("{scene_name}/ctu_uj/all_fp32"), e_fp32);
        b.record(
            &format!("{scene_name}/ctu_uj/saving"),
            1.0 - e_adaptive / e_fp32.max(1e-30),
        );

        // Per-rect rows: the same mix/quality/energy columns one level
        // down, over quadrant-rectangles of populated tiles.
        let rect_plan = FramePlan::build(&scene, &cam, &rect_opts);
        let maps = rect_plan
            .tile_rect_classes()
            .expect("rect plans class every tile");
        let mut quads = [0usize; 4];
        let mut quads_total = 0usize;
        for (t, map) in maps.iter().enumerate() {
            if rect_plan.lists[t].is_empty() {
                continue;
            }
            for q in 0..4 {
                quads_total += 1;
                quads[class_index(map.quad(q))] += 1;
            }
        }
        for c in CLASSES {
            b.record(
                &format!("{scene_name}/quads/{}", c.name()),
                quads[class_index(c)] as f64,
            );
        }
        let quads_below = quads_total - quads[class_index(Precision::Fp32)];
        b.record(
            &format!("{scene_name}/quads/below_fp32_share"),
            quads_below as f64 / quads_total.max(1) as f64,
        );
        let rect = rect_plan.render(&cat, None);
        b.record(
            &format!("{scene_name}/psnr_rect_vs_fp32"),
            psnr(&reference.image, &rect.image).min(99.0),
        );
        let wl_rect = extract_from_plan(&scene, &rect_plan, &hw);
        for c in CLASSES {
            b.record(
                &format!("{scene_name}/ctu_prs_rect/{}", c.name()),
                wl_rect.ctu_prs_by_class[class_index(c)] as f64,
            );
        }
        let e_rect = frame_energy(&wl_rect, &hw, 0, 0, &energy).ctu_uj;
        b.record(&format!("{scene_name}/ctu_uj/rect"), e_rect);
        b.record(
            &format!("{scene_name}/ctu_uj/rect_saving_vs_adaptive"),
            1.0 - e_rect / e_adaptive.max(1e-30),
        );

        // Wall-clock: classing happens at plan time, so the render loop
        // itself must not pay for the policy.
        b.bench(&format!("{scene_name}/render_fp32"), || {
            black_box(fp32_plan.render(&cat, None));
        });
        b.bench(&format!("{scene_name}/render_adaptive"), || {
            black_box(adaptive_plan.render(&cat, None));
        });
        b.bench(&format!("{scene_name}/render_rect"), || {
            black_box(rect_plan.render(&cat, None));
        });
    }

    b.finish("adaptive + rect precision: class mix, quality, CTU energy");
}
