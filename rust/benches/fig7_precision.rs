//! Fig. 7(c) — CTU precision schemes: Full FP16 vs Full FP8 vs Mixed.
//!
//! Paper shape: FP16 and Mixed preserve quality; Full FP8 collapses
//! (blocky artifacts) because absolute pixel/μ coordinates lose relative
//! position at FP8.

mod common;

use flicker::cat::{CatConfig, CatEngine, LeaderMode, Precision};
use flicker::coordinator::report::Report;
use flicker::coordinator::Golden;
use flicker::render::metrics::{psnr, ssim};

fn main() {
    // One session-cached FramePlan reused across the golden reference and
    // all four precision configs (the fig-sweep plan-reuse pattern).
    let session = common::bench_session("garden");
    let golden = session.frame(common::BENCH_VIEW, &Golden).expect("golden render");
    let plan = session.plan(common::BENCH_VIEW);

    let mut report = Report::new("fig7c", "Fig.7(c): CTU precision schemes");
    let mut vals = Vec::new();
    for (name, prec) in [
        ("fp32", Precision::Fp32),
        ("fp16", Precision::Fp16),
        ("mixed", Precision::Mixed),
        ("fp8", Precision::Fp8),
    ] {
        let mut engine = CatEngine::new(CatConfig {
            mode: LeaderMode::SmoothFocused,
            precision: prec,
            stage1: true,
        });
        let out = plan.render_with(&mut engine, None);
        let p = psnr(&golden.image, &out.image);
        let s = ssim(&golden.image, &out.image);
        report.row(name, &[("psnr", p), ("ssim", s)]);
        vals.push((name, p));
    }
    report.emit();

    let get = |n: &str| vals.iter().find(|v| v.0 == n).unwrap().1;
    let (p32, p16, pmix, p8) = (get("fp32"), get("fp16"), get("mixed"), get("fp8"));
    // Paper shape: fp16 ≈ fp32; mixed stays usable (a few dB under fp16 —
    // the FP8 quadratic stage); full-FP8 collapses with blocky artifacts.
    assert!((p32 - p16).abs() < 2.0, "fp16 {p16} must track fp32 {p32}");
    assert!(
        pmix > p8 + 5.0,
        "mixed {pmix} must clearly beat fp8 {p8} (paper's blocky-artifact collapse)"
    );
    assert!(
        p16 - pmix < 8.0,
        "mixed {pmix} should stay within a few dB of fp16 {p16}"
    );
    println!("fig7c OK: fp16 {p16:.2} dB, mixed {pmix:.2} dB, fp8 {p8:.2} dB");
}
