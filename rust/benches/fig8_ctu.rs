//! Fig. 8 — rendering-stage speedup and energy efficiency from the CTU,
//! evaluated on the scene *Garden* only (baseline model, no pruning or
//! clustering), normalized to the simplified FLICKER (no CTU, 32 VRUs).
//!
//! Paper shape: GSCore (OBB + 64 VRUs) ≈ 4× the simplified version;
//! FLICKER+CTU matches GSCore with half the VRUs; Uniform-Sparse adds
//! ~1.1×; FLICKER's energy efficiency reaches ~1.6× GSCore's.

mod common;

use flicker::coordinator::report::Report;
use flicker::sim::top::simulate_frame;
use flicker::sim::HwConfig;

fn main() {
    let res = common::bench_resolution();
    let cam = common::bench_camera(res);
    let scene = common::bench_scene("garden");

    let configs = [
        HwConfig::simplified32(),
        HwConfig::gscore64(),
        HwConfig::flicker32(),
        HwConfig::flicker32_sparse(),
    ];
    let mut reports = Vec::new();
    for hw in &configs {
        // Fig. 8 isolates the rendering stage on the unpruned baseline
        // model without clustering.
        let hw = HwConfig {
            clustering: false,
            ..hw.clone()
        };
        reports.push(simulate_frame(&scene, &cam, &hw));
    }

    let base_cycles = reports[0].render_cycles as f64;
    let base_energy = reports[0].energy.total_uj();
    let mut report = Report::new("fig8", "Fig.8: rendering-stage speedup & energy (Garden)");
    for r in &reports {
        report.row(
            &r.config,
            &[
                ("speedup", base_cycles / r.render_cycles as f64),
                ("energy_eff", base_energy / r.energy.total_uj()),
                ("cycles", r.render_cycles as f64),
                ("energy_uj", r.energy.total_uj()),
                ("stall_rate", r.pipe.stall_rate()),
            ],
        );
    }
    report.emit();

    let sp = |i: usize| base_cycles / reports[i].render_cycles as f64;
    let ee = |i: usize| base_energy / reports[i].energy.total_uj();
    // Shape assertions: gscore ≫ simplified; flicker32 within 2× of
    // gscore64 despite half the VRUs; sparse ≥ adaptive throughput;
    // flicker more energy-efficient than gscore.
    assert!(sp(1) > 2.0, "gscore speedup {}", sp(1));
    assert!(sp(2) > 0.5 * sp(1), "flicker {} vs gscore {}", sp(2), sp(1));
    assert!(sp(3) >= sp(2) * 0.98, "sparse {} vs adaptive {}", sp(3), sp(2));
    assert!(ee(2) > ee(1), "flicker energy {} vs gscore {}", ee(2), ee(1));
    println!(
        "fig8 OK: gscore {:.2}x, flicker32 {:.2}x, sparse {:.2}x; energy eff flicker/gscore {:.2}",
        sp(1),
        sp(2),
        sp(3),
        ee(2) / ee(1)
    );
}
