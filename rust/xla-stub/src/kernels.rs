//! Pure-Rust reference kernels for the flicker artifact set.
//!
//! The stub's "compiler" does not parse HLO — it recognizes each artifact
//! by its file stem and `execute` dispatches here. Every function mirrors
//! the corresponding JAX/Pallas kernel in `python/compile` **operation for
//! operation** (same formulas, same association order), so the stub is a
//! faithful functional fake of the AOT artifacts: the Rust differential
//! and property harness (batched vs single-tile execution, PJRT vs golden
//! rasterizer) runs offline in default CI, and the opt-in `xla-real` lane
//! re-validates the same tests against real XLA.
//!
//! Shapes are taken from the input literals, so the stub serves any
//! monomorphization (tests synthesize small-N manifests for speed). The
//! tile edge is fixed at 16 like the Pallas kernels.

use crate::quant::{quantize_f16, quantize_fp8_e4m3};
use crate::{Error, Literal, Result};

/// Tile edge the blend kernel is written for (python blend.py TILE).
const TILE: usize = 16;
/// Blending alpha cutoff (python blend.py ALPHA_MIN).
const ALPHA_MIN: f32 = 1.0 / 255.0;
/// Early-termination transmittance threshold (blend_tile default t_min).
const T_MIN: f32 = 1e-4;

/// Dispatch artifact `name` over input literals. Returns the output
/// literals in the artifact's tuple order.
pub(crate) fn run(name: &str, inputs: &[&Literal]) -> Result<Vec<Literal>> {
    match name {
        "project" => project(inputs),
        "pr_weight" => pr_weight(inputs),
        "cat_masks" => cat_masks_entry(inputs),
        "render_tile" => render_tile(inputs),
        "render_tile_batched" => render_tile_batched(inputs, Prec::Fp32),
        "render_tile_batched_fp16" => render_tile_batched(inputs, Prec::Fp16),
        "render_tile_batched_fp8" => render_tile_batched(inputs, Prec::Fp8),
        "render_tile_batched_mixed" => render_tile_batched(inputs, Prec::Mixed),
        other => Err(Error::Message(format!(
            "xla stub: no built-in kernel for artifact '{other}'"
        ))),
    }
}

/// CTU precision class a blend artifact is monomorphized for. The three
/// reduced schemes quantize only the CAT decision datapath (corner weights
/// + shared threshold) — compositing itself stays fp32, exactly like the
/// software `GoldenCat` backend, whose precision knob also touches the
/// mask engine only. Mirrors `flicker::cat::mixed::Precision`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Prec {
    /// Reference: no quantization anywhere (the historical kernel).
    Fp32,
    /// All CAT operands + ops at binary16.
    Fp16,
    /// All CAT operands at E4M3, including absolute coordinates.
    Fp8,
    /// FP16 deltas → FP8 products → FP16 accumulation (paper Sec. IV-C).
    Mixed,
}

fn arg<'a>(inputs: &[&'a Literal], i: usize, name: &str) -> Result<(&'a [f32], &'a [i64])> {
    inputs
        .get(i)
        .map(|l| l.f32_view())
        .transpose()?
        .ok_or_else(|| Error::Message(format!("{name}: missing input {i}")))
}

fn dim(dims: &[i64], i: usize) -> usize {
    dims.get(i).copied().unwrap_or(0) as usize
}

fn expect_rank(dims: &[i64], rank: usize, what: &str) -> Result<()> {
    if dims.len() == rank {
        Ok(())
    } else {
        Err(Error::Message(format!(
            "{what}: expected rank {rank}, got shape {dims:?}"
        )))
    }
}

/// `project.hlo.txt`: EWA projection datapath (python kernels/project.py).
/// (N,3) pos, (N,6) packed cov, (4,) [fx,fy,cx,cy] ->
/// mean (N,2), conic (N,3), depth (N,), radius (N,).
fn project(inputs: &[&Literal]) -> Result<Vec<Literal>> {
    let (pos, pd) = arg(inputs, 0, "project")?;
    let (cov, _) = arg(inputs, 1, "project")?;
    let (cam, _) = arg(inputs, 2, "project")?;
    expect_rank(pd, 2, "project pos")?;
    let n = dim(pd, 0);
    let (fx, fy, cx, cy) = (cam[0], cam[1], cam[2], cam[3]);
    const DILATION: f32 = 0.3;

    let mut mean = vec![0.0f32; n * 2];
    let mut conic = vec![0.0f32; n * 3];
    let mut depth = vec![0.0f32; n];
    let mut radius = vec![0.0f32; n];
    for i in 0..n {
        let (x, y, z) = (pos[i * 3], pos[i * 3 + 1], pos[i * 3 + 2]);
        let inv_z = 1.0 / z;
        mean[i * 2] = fx * x * inv_z + cx;
        mean[i * 2 + 1] = fy * y * inv_z + cy;
        depth[i] = z;

        let j00 = fx * inv_z;
        let j02 = -fx * x * inv_z * inv_z;
        let j11 = fy * inv_z;
        let j12 = -fy * y * inv_z * inv_z;
        let (cxx, cxy, cxz) = (cov[i * 6], cov[i * 6 + 1], cov[i * 6 + 2]);
        let (cyy, cyz, czz) = (cov[i * 6 + 3], cov[i * 6 + 4], cov[i * 6 + 5]);

        let a = j00 * j00 * cxx + 2.0 * j00 * j02 * cxz + j02 * j02 * czz + DILATION;
        let b = j00 * j11 * cxy + j00 * j12 * cxz + j02 * j11 * cyz + j02 * j12 * czz;
        let c = j11 * j11 * cyy + 2.0 * j11 * j12 * cyz + j12 * j12 * czz + DILATION;
        let det = a * c - b * b;
        let inv_det = 1.0 / det;
        conic[i * 3] = c * inv_det;
        conic[i * 3 + 1] = -b * inv_det;
        conic[i * 3 + 2] = a * inv_det;

        let mid = 0.5 * (a + c);
        let lam1 = mid + (mid * mid - det).max(0.0).sqrt();
        radius[i] = 3.0 * lam1.sqrt();
    }
    Ok(vec![
        Literal::from_parts(mean, vec![n as i64, 2]),
        Literal::from_parts(conic, vec![n as i64, 3]),
        Literal::from_parts(depth, vec![n as i64]),
        Literal::from_parts(radius, vec![n as i64]),
    ])
}

/// Alg. 1 corner weights for one (PR, Gaussian) pair — the shared core of
/// `pr_weight` and the CAT decision. Mirrors kernels/pr_weight.py (and
/// `cat::pr::pr_weights`) term for term.
fn corner_weights(mu: &[f32], conic: &[f32], i: usize, pt: [f32; 2], pb: [f32; 2]) -> [f32; 4] {
    let (mx, my) = (mu[i * 2], mu[i * 2 + 1]);
    let (ca, cb, cc) = (conic[i * 3], conic[i * 3 + 1], conic[i * 3 + 2]);
    let dtx = pt[0] - mx;
    let dty = pt[1] - my;
    let dbx = pb[0] - mx;
    let dby = pb[1] - my;
    let s_tx = 0.5 * dtx * dtx * ca;
    let s_ty = 0.5 * dty * dty * cc;
    let s_bx = 0.5 * dbx * dbx * ca;
    let s_by = 0.5 * dby * dby * cc;
    let t0 = dtx * dty * cb;
    let t1 = dbx * dty * cb;
    let t2 = dtx * dby * cb;
    let t3 = dbx * dby * cb;
    [
        s_tx + s_ty + t0,
        s_bx + s_ty + t1,
        s_tx + s_by + t2,
        s_bx + s_by + t3,
    ]
}

/// Lines 2–7 of Alg. 1 with injectable rounding for the multiply stage
/// (`qm`) and the accumulate stage (`qa`) — the quantized twin of
/// [`corner_weights`], mirroring `cat::mixed::weights_from_deltas` term
/// for term.
#[allow(clippy::too_many_arguments)]
fn weights_from_deltas(
    dtx: f32,
    dty: f32,
    dbx: f32,
    dby: f32,
    ca: f32,
    cb: f32,
    cc: f32,
    qm: fn(f32) -> f32,
    qa: fn(f32) -> f32,
) -> [f32; 4] {
    // lines 2–3
    let s_tx = qm(qm(0.5 * dtx * dtx) * ca);
    let s_ty = qm(qm(0.5 * dty * dty) * cc);
    let s_bx = qm(qm(0.5 * dbx * dbx) * ca);
    let s_by = qm(qm(0.5 * dby * dby) * cc);
    // lines 4–5
    let t0 = qm(qm(dtx * dty) * cb);
    let t1 = qm(qm(dbx * dty) * cb);
    let t2 = qm(qm(dtx * dby) * cb);
    let t3 = qm(qm(dbx * dby) * cb);
    // lines 6–7 (accumulate precision)
    [
        qa(qa(s_tx + s_ty) + t0),
        qa(qa(s_bx + s_ty) + t1),
        qa(qa(s_tx + s_by) + t2),
        qa(qa(s_bx + s_by) + t3),
    ]
}

/// [`corner_weights`] under a CTU precision scheme: quantization inserted
/// at the exact points `cat::mixed::pr_weights_quant` converts, so the
/// per-class artifacts reproduce the software CTU's mask decisions bit
/// for bit. `Fp32` takes the historical exact path.
fn corner_weights_quant(
    mu: &[f32],
    conic: &[f32],
    i: usize,
    pt: [f32; 2],
    pb: [f32; 2],
    prec: Prec,
) -> [f32; 4] {
    let q16 = quantize_f16;
    let q8 = quantize_fp8_e4m3;
    let (mx, my) = (mu[i * 2], mu[i * 2 + 1]);
    let (ca, cb, cc) = (conic[i * 3], conic[i * 3 + 1], conic[i * 3 + 2]);
    match prec {
        Prec::Fp32 => corner_weights(mu, conic, i, pt, pb),
        Prec::Fp16 => {
            // All operands + ops at FP16.
            let dtx = q16(q16(pt[0]) - q16(mx));
            let dty = q16(q16(pt[1]) - q16(my));
            let dbx = q16(q16(pb[0]) - q16(mx));
            let dby = q16(q16(pb[1]) - q16(my));
            weights_from_deltas(dtx, dty, dbx, dby, q16(ca), q16(cb), q16(cc), q16, q16)
        }
        Prec::Fp8 => {
            // Everything at E4M3 — including the absolute coordinates.
            let dtx = q8(q8(pt[0]) - q8(mx));
            let dty = q8(q8(pt[1]) - q8(my));
            let dbx = q8(q8(pb[0]) - q8(mx));
            let dby = q8(q8(pb[1]) - q8(my));
            weights_from_deltas(dtx, dty, dbx, dby, q8(ca), q8(cb), q8(cc), q8, q8)
        }
        Prec::Mixed => {
            // Deltas exact at FP16, then converted to FP8; products at FP8,
            // accumulation at FP16 (QAU).
            let dtx = q8(q16(q16(pt[0]) - q16(mx)));
            let dty = q8(q16(q16(pt[1]) - q16(my)));
            let dbx = q8(q16(q16(pb[0]) - q16(mx)));
            let dby = q8(q16(q16(pb[1]) - q16(my)));
            weights_from_deltas(dtx, dty, dbx, dby, q8(ca), q8(cb), q8(cc), q8, q16)
        }
    }
}

/// The Eq. 2 left-hand side ln(255·o) at the precision's shared unit —
/// FP16 in all reduced schemes except Fp8 (mirrors
/// `cat::mixed::shared_threshold_quant`).
fn cat_lhs(opacity: f32, prec: Prec) -> f32 {
    let t = (255.0 * opacity.max(1e-12)).ln();
    match prec {
        Prec::Fp32 => t,
        Prec::Fp16 | Prec::Mixed => quantize_f16(t),
        Prec::Fp8 => quantize_fp8_e4m3(t),
    }
}

/// `pr_weight.hlo.txt`: (N,2), (N,3), (M,2), (M,2) -> (M,N,4) weights.
fn pr_weight(inputs: &[&Literal]) -> Result<Vec<Literal>> {
    let (mu, md) = arg(inputs, 0, "pr_weight")?;
    let (conic, _) = arg(inputs, 1, "pr_weight")?;
    let (p_top, td) = arg(inputs, 2, "pr_weight")?;
    let (p_bot, _) = arg(inputs, 3, "pr_weight")?;
    expect_rank(md, 2, "pr_weight mu")?;
    let n = dim(md, 0);
    let m = dim(td, 0);
    let mut out = vec![0.0f32; m * n * 4];
    for k in 0..m {
        let pt = [p_top[k * 2], p_top[k * 2 + 1]];
        let pb = [p_bot[k * 2], p_bot[k * 2 + 1]];
        for i in 0..n {
            let e = corner_weights(mu, conic, i, pt, pb);
            out[(k * n + i) * 4..(k * n + i) * 4 + 4].copy_from_slice(&e);
        }
    }
    Ok(vec![Literal::from_parts(out, vec![m as i64, n as i64, 4])])
}

/// Eq. 2 pass masks: ln(255·max(o, 1e-12)) > E, as {0,1} f32 (M,N,4),
/// with both sides evaluated at `prec`.
#[allow(clippy::too_many_arguments)]
fn cat_mask_values(
    mu: &[f32],
    conic: &[f32],
    opacity: &[f32],
    p_top: &[f32],
    p_bot: &[f32],
    n: usize,
    m: usize,
    prec: Prec,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n * 4];
    for k in 0..m {
        let pt = [p_top[k * 2], p_top[k * 2 + 1]];
        let pb = [p_bot[k * 2], p_bot[k * 2 + 1]];
        for i in 0..n {
            let lhs = cat_lhs(opacity[i], prec);
            let e = corner_weights_quant(mu, conic, i, pt, pb, prec);
            for c in 0..4 {
                out[(k * n + i) * 4 + c] = if lhs > e[c] { 1.0 } else { 0.0 };
            }
        }
    }
    out
}

/// `cat_masks.hlo.txt`: (N,2), (N,3), (N,), (M,2), (M,2) -> (M,N,4) masks.
fn cat_masks_entry(inputs: &[&Literal]) -> Result<Vec<Literal>> {
    let (mu, md) = arg(inputs, 0, "cat_masks")?;
    let (conic, _) = arg(inputs, 1, "cat_masks")?;
    let (opacity, _) = arg(inputs, 2, "cat_masks")?;
    let (p_top, td) = arg(inputs, 3, "cat_masks")?;
    let (p_bot, _) = arg(inputs, 4, "cat_masks")?;
    expect_rank(md, 2, "cat_masks mu")?;
    let n = dim(md, 0);
    let m = dim(td, 0);
    let out = cat_mask_values(mu, conic, opacity, p_top, p_bot, n, m, Prec::Fp32);
    Ok(vec![Literal::from_parts(out, vec![m as i64, n as i64, 4])])
}

/// The single-tile render: CAT-gated front-to-back blend over a 16×16
/// tile (python model.render_tile_entry + kernels/blend.py). Writes rgb
/// (T,T,3), trans (T,T), passes (N,) into caller-provided slices. `prec`
/// selects the CAT gate's numeric scheme; the blend itself is fp32 for
/// every class.
#[allow(clippy::too_many_arguments)]
fn render_tile_into(
    mu: &[f32],
    conic: &[f32],
    opacity: &[f32],
    color: &[f32],
    origin: &[f32],
    p_top: &[f32],
    p_bot: &[f32],
    n: usize,
    m: usize,
    prec: Prec,
    rgb: &mut [f32],
    trans: &mut [f32],
    passes: &mut [f32],
) {
    // CAT gate: a splat passes if any corner of any PR passes Eq. 2.
    let masks = cat_mask_values(mu, conic, opacity, p_top, p_bot, n, m, prec);
    for (i, p) in passes.iter_mut().enumerate() {
        let mut any = 0.0f32;
        for k in 0..m {
            for c in 0..4 {
                any = any.max(masks[(k * n + i) * 4 + c]);
            }
        }
        *p = any;
    }

    // Blend with CAT-gated opacities, exactly like blend.py's fori_loop:
    // per pixel, walk splats in order; a saturated pixel (T < t_min)
    // stops changing rather than breaking the loop.
    let (ox, oy) = (origin[0], origin[1]);
    trans.fill(1.0);
    rgb.fill(0.0);
    for i in 0..n {
        let gated = opacity[i] * passes[i];
        let (mx, my) = (mu[i * 2], mu[i * 2 + 1]);
        let (ca, cb, cc) = (conic[i * 3], conic[i * 3 + 1], conic[i * 3 + 2]);
        let col = [color[i * 3], color[i * 3 + 1], color[i * 3 + 2]];
        for py in 0..TILE {
            let dy = oy + py as f32 + 0.5 - my;
            for px in 0..TILE {
                let dx = ox + px as f32 + 0.5 - mx;
                let e = 0.5 * (ca * dx * dx + cc * dy * dy) + cb * dx * dy;
                let mut alpha = (gated * (-e).exp()).min(0.999);
                if alpha < ALPHA_MIN {
                    alpha = 0.0;
                }
                let idx = py * TILE + px;
                let t_cur = trans[idx];
                if t_cur >= T_MIN {
                    let w = alpha * t_cur;
                    rgb[idx * 3] += w * col[0];
                    rgb[idx * 3 + 1] += w * col[1];
                    rgb[idx * 3 + 2] += w * col[2];
                    trans[idx] = t_cur * (1.0 - alpha);
                }
            }
        }
    }
}

/// `render_tile.hlo.txt`: the full single-tile composition.
fn render_tile(inputs: &[&Literal]) -> Result<Vec<Literal>> {
    let (mu, md) = arg(inputs, 0, "render_tile")?;
    let (conic, _) = arg(inputs, 1, "render_tile")?;
    let (opacity, _) = arg(inputs, 2, "render_tile")?;
    let (color, _) = arg(inputs, 3, "render_tile")?;
    let (origin, _) = arg(inputs, 4, "render_tile")?;
    let (p_top, td) = arg(inputs, 5, "render_tile")?;
    let (p_bot, _) = arg(inputs, 6, "render_tile")?;
    expect_rank(md, 2, "render_tile mu")?;
    let n = dim(md, 0);
    let m = dim(td, 0);
    let mut rgb = vec![0.0f32; TILE * TILE * 3];
    let mut trans = vec![0.0f32; TILE * TILE];
    let mut passes = vec![0.0f32; n];
    render_tile_into(
        mu,
        conic,
        opacity,
        color,
        origin,
        p_top,
        p_bot,
        n,
        m,
        Prec::Fp32,
        &mut rgb,
        &mut trans,
        &mut passes,
    );
    let t = TILE as i64;
    Ok(vec![
        Literal::from_parts(rgb, vec![t, t, 3]),
        Literal::from_parts(trans, vec![t, t]),
        Literal::from_parts(passes, vec![n as i64]),
    ])
}

/// `render_tile_batched[_fp16|_fp8|_mixed].hlo.txt`: `render_tile` over a
/// leading batch dim, monomorphized per CAT precision class. Each slot
/// runs the identical single-tile computation (the vmap semantics of
/// python model.render_tiles_entry), which is what makes the batched
/// executor path bit-identical to looped single-tile dispatches — and why
/// a precision-pure wave of width 1 is bit-identical to the wider waves
/// the adaptive executor forms.
fn render_tile_batched(inputs: &[&Literal], prec: Prec) -> Result<Vec<Literal>> {
    let (mu, md) = arg(inputs, 0, "render_tile_batched")?;
    let (conic, _) = arg(inputs, 1, "render_tile_batched")?;
    let (opacity, _) = arg(inputs, 2, "render_tile_batched")?;
    let (color, _) = arg(inputs, 3, "render_tile_batched")?;
    let (origin, _) = arg(inputs, 4, "render_tile_batched")?;
    let (p_top, td) = arg(inputs, 5, "render_tile_batched")?;
    let (p_bot, _) = arg(inputs, 6, "render_tile_batched")?;
    expect_rank(md, 3, "render_tile_batched mu")?;
    let b = dim(md, 0);
    let n = dim(md, 1);
    let m = dim(td, 1);
    let mut rgb = vec![0.0f32; b * TILE * TILE * 3];
    let mut trans = vec![0.0f32; b * TILE * TILE];
    let mut passes = vec![0.0f32; b * n];
    for s in 0..b {
        render_tile_into(
            &mu[s * n * 2..(s + 1) * n * 2],
            &conic[s * n * 3..(s + 1) * n * 3],
            &opacity[s * n..(s + 1) * n],
            &color[s * n * 3..(s + 1) * n * 3],
            &origin[s * 2..(s + 1) * 2],
            &p_top[s * m * 2..(s + 1) * m * 2],
            &p_bot[s * m * 2..(s + 1) * m * 2],
            n,
            m,
            prec,
            &mut rgb[s * TILE * TILE * 3..(s + 1) * TILE * TILE * 3],
            &mut trans[s * TILE * TILE..(s + 1) * TILE * TILE],
            &mut passes[s * n..(s + 1) * n],
        );
    }
    let (bi, t) = (b as i64, TILE as i64);
    Ok(vec![
        Literal::from_parts(rgb, vec![bi, t, t, 3]),
        Literal::from_parts(trans, vec![bi, t, t]),
        Literal::from_parts(passes, vec![bi, n as i64]),
    ])
}
