//! Offline stub of the `xla` crate (xla_extension 0.5.1 bindings).
//!
//! The flicker build must stay pure-Rust and offline, but the `pjrt`
//! feature's runtime code is written against the published `xla` crate's
//! API. This stub mirrors exactly the surface `flicker::runtime` uses so
//! `cargo build --features pjrt` type-checks and links with no network and
//! no native XLA library present.
//!
//! Every entry point that would touch a real PJRT client fails at runtime
//! with [`Error::StubUnavailable`]; callers (tests, examples, the CLI)
//! treat that as "PJRT runtime unavailable" and skip. To execute real AOT
//! artifacts, point the `xla` dependency in `rust/Cargo.toml` at the
//! published crate instead of this path.

use std::fmt;

/// Error surface of the real bindings; the stub only ever produces
/// `StubUnavailable`.
pub enum Error {
    /// The stub cannot create a PJRT client.
    StubUnavailable,
    /// Catch-all mirroring the real crate's error payloads.
    Message(String),
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::StubUnavailable => f.write_str(
                "xla stub: PJRT runtime not linked (swap rust/xla-stub for the real `xla` crate)",
            ),
            Error::Message(m) => f.write_str(m),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. The stub's constructor always fails, so no method
/// past construction is ever reached at runtime.
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU PJRT client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::StubUnavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::StubUnavailable)
    }
}

/// Parsed HLO module (text form in the real crate).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::StubUnavailable)
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host-side literal (tensor) value.
pub struct Literal;

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::StubUnavailable)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::StubUnavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::StubUnavailable)
    }
}

/// Device-side buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::StubUnavailable)
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute on the given argument literals; one result buffer list per
    /// device (the runtime uses `result[0][0]`).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::StubUnavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        let msg = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"));
    }

    #[test]
    fn literal_surface_is_inert() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
    }
}
