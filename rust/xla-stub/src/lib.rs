//! Offline functional fake of the `xla` crate (xla_extension 0.5.1
//! bindings).
//!
//! The flicker build must stay pure-Rust and offline, but the `pjrt`
//! feature's runtime code is written against the published `xla` crate's
//! API. This crate mirrors exactly the surface `flicker::runtime` uses so
//! `cargo build --features pjrt` type-checks and links with no network and
//! no native XLA library present — and, since the batched-execution PR, it
//! **executes** the flicker artifact set too: instead of parsing HLO,
//! [`HloModuleProto::from_text_file`] records the artifact's file stem and
//! [`PjRtLoadedExecutable::execute`] dispatches to a built-in pure-Rust
//! reference kernel (see [`kernels`]) that mirrors the JAX/Pallas source
//! in `python/compile` operation for operation.
//!
//! That upgrade is what lets the PJRT differential/property harness —
//! batched vs single-tile tile execution, executor vs golden rasterizer —
//! run in default CI with no jax, no network, and no native XLA. Artifacts
//! whose stem has no built-in kernel compile fine and fail at `execute`
//! with a clear error. To execute real AOT artifacts, point the `xla`
//! dependency in `rust/Cargo.toml` at the published crate instead of this
//! path (the opt-in `xla-real` CI lane does exactly that).

mod kernels;
mod quant;

use std::fmt;

/// Error surface of the real bindings; every fake failure (missing
/// artifact file, unknown kernel, shape mismatch) carries its own
/// message.
pub enum Error {
    /// Catch-all mirroring the real crate's error payloads.
    Message(String),
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Message(m) => f.write_str(m),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. The fake client "compiles" by capturing the
/// artifact name recorded at parse time.
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU PJRT client. Always succeeds in the functional fake.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {
            name: computation.name.clone(),
        })
    }
}

/// Parsed HLO module (text form in the real crate). The fake records the
/// artifact name (the file stem, minus a trailing `.hlo`) instead of
/// parsing — artifact files written by `python/compile/aot.py` are named
/// `{name}.hlo.txt`, and placeholder files synthesized by
/// `flicker::runtime::write_stub_artifacts` follow the same convention.
pub struct HloModuleProto {
    name: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let p = std::path::Path::new(path);
        if !p.is_file() {
            return Err(Error::Message(format!("xla stub: no such artifact file: {path}")));
        }
        let stem = p
            .file_stem()
            .and_then(|s| s.to_str())
            .ok_or_else(|| Error::Message(format!("xla stub: bad artifact path: {path}")))?;
        let name = stem.strip_suffix(".hlo").unwrap_or(stem).to_string();
        Ok(HloModuleProto { name })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            name: proto.name.clone(),
        }
    }
}

/// Host-side literal (tensor) value: f32 data with a shape, or a tuple of
/// literals (artifact results arrive as one tuple).
pub struct Literal {
    repr: Repr,
}

enum Repr {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            repr: Repr::F32 {
                data: data.to_vec(),
                dims: vec![data.len() as i64],
            },
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match &self.repr {
            Repr::F32 { data, .. } => {
                let expect: i64 = dims.iter().product();
                if expect as usize != data.len() {
                    return Err(Error::Message(format!(
                        "xla stub: cannot reshape {} elements to {dims:?}",
                        data.len()
                    )));
                }
                Ok(Literal {
                    repr: Repr::F32 {
                        data: data.clone(),
                        dims: dims.to_vec(),
                    },
                })
            }
            Repr::Tuple(_) => Err(Error::Message("xla stub: cannot reshape a tuple".into())),
        }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.repr {
            Repr::Tuple(parts) => Ok(parts),
            Repr::F32 { .. } => {
                Err(Error::Message("xla stub: literal is not a tuple".into()))
            }
        }
    }

    pub fn to_vec<T: Clone + 'static>(&self) -> Result<Vec<T>> {
        match &self.repr {
            Repr::F32 { data, .. } => {
                let any: &dyn std::any::Any = data;
                any.downcast_ref::<Vec<T>>().cloned().ok_or_else(|| {
                    Error::Message("xla stub: only f32 element reads are supported".into())
                })
            }
            Repr::Tuple(_) => Err(Error::Message("xla stub: cannot to_vec a tuple".into())),
        }
    }

    /// Internal kernel view: (data, dims) of an f32 literal.
    pub(crate) fn f32_view(&self) -> Result<(&[f32], &[i64])> {
        match &self.repr {
            Repr::F32 { data, dims } => Ok((data, dims)),
            Repr::Tuple(_) => Err(Error::Message("xla stub: tuple passed as input".into())),
        }
    }

    pub(crate) fn from_parts(data: Vec<f32>, dims: Vec<i64>) -> Literal {
        Literal {
            repr: Repr::F32 { data, dims },
        }
    }

    fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            repr: Repr::Tuple(parts),
        }
    }
}

/// Device-side buffer returned by an execution (the fake keeps the result
/// literal inline).
pub struct PjRtBuffer {
    result: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match &self.result.repr {
            Repr::Tuple(parts) => {
                let cloned = parts
                    .iter()
                    .map(|p| match &p.repr {
                        Repr::F32 { data, dims } => {
                            Ok(Literal::from_parts(data.clone(), dims.clone()))
                        }
                        Repr::Tuple(_) => {
                            Err(Error::Message("xla stub: nested tuple result".into()))
                        }
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Literal::tuple(cloned))
            }
            Repr::F32 { data, dims } => Ok(Literal::from_parts(data.clone(), dims.clone())),
        }
    }
}

/// A compiled, loaded executable: dispatches to the built-in reference
/// kernel matching the artifact name.
pub struct PjRtLoadedExecutable {
    name: String,
}

impl PjRtLoadedExecutable {
    /// Execute on the given argument literals; one result buffer list per
    /// device (the runtime uses `result[0][0]`). The generic parameter
    /// mirrors the real crate's surface; the fake only accepts
    /// [`Literal`] arguments.
    pub fn execute<T: 'static>(&self, args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let mut lits: Vec<&Literal> = Vec::with_capacity(args.len());
        for a in args {
            let any: &dyn std::any::Any = a;
            lits.push(any.downcast_ref::<Literal>().ok_or_else(|| {
                Error::Message("xla stub: execute only accepts Literal arguments".into())
            })?);
        }
        let outs = kernels::run(&self.name, &lits)?;
        Ok(vec![vec![PjRtBuffer {
            result: Literal::tuple(outs),
        }]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_and_literals_are_functional() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub");
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let re = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(re.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn parse_records_the_artifact_stem() {
        let dir = std::env::temp_dir().join("xla_stub_parse_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pr_weight.hlo.txt");
        std::fs::write(&path, "placeholder").unwrap();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        assert_eq!(proto.name, "pr_weight");
        assert!(HloModuleProto::from_text_file("/no/such/file.hlo.txt").is_err());
    }

    #[test]
    fn unknown_artifact_fails_at_execute_not_compile() {
        let dir = std::env::temp_dir().join("xla_stub_unknown_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mystery.hlo.txt");
        std::fs::write(&path, "placeholder").unwrap();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let exe = PjRtClient::cpu().unwrap().compile(&comp).unwrap();
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(format!("{err:?}").contains("no built-in kernel"));
    }

    #[test]
    fn pr_weight_kernel_runs_end_to_end() {
        // One Gaussian with a diagonal conic; PR corners at mu and mu+3.
        let dir = std::env::temp_dir().join("xla_stub_prw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pr_weight.hlo.txt");
        std::fs::write(&path, "placeholder").unwrap();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let exe = PjRtClient::cpu()
            .unwrap()
            .compile(&XlaComputation::from_proto(&proto))
            .unwrap();
        let mu = Literal::vec1(&[10.0, 10.0]).reshape(&[1, 2]).unwrap();
        let conic = Literal::vec1(&[0.5, 0.0, 0.5]).reshape(&[1, 3]).unwrap();
        let pt = Literal::vec1(&[10.0, 10.0]).reshape(&[1, 2]).unwrap();
        let pb = Literal::vec1(&[13.0, 13.0]).reshape(&[1, 2]).unwrap();
        let out = exe.execute::<Literal>(&[mu, conic, pt, pb]).unwrap();
        let parts = out[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
        let e = parts[0].to_vec::<f32>().unwrap();
        assert_eq!(e.len(), 4);
        assert!(e[0].abs() < 1e-6, "E0 at mu must be 0: {}", e[0]);
        assert!((e[3] - 4.5).abs() < 1e-5, "E3 = {}", e[3]);
    }
}
