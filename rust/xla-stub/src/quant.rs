//! Quantization primitives for the per-precision blend kernels.
//!
//! These are verbatim copies of the flicker crate's software float
//! emulation (`numeric::fp16::quantize_f16`, `numeric::fp8::quantize_fp8`
//! at E4M3): the stub cannot depend on the flicker crate (the dependency
//! points the other way), but the per-precision artifact kernels must
//! produce bit-identical CAT decisions to the CTU model in `cat::mixed`.
//! Both sides implement IEEE round-to-nearest-even, so any divergence
//! would be a bug; the duplication is covered by the kernels' differential
//! tests against `GoldenCat` in `flicker::runtime::executor`.

/// Round-trip an f32 through IEEE binary16 (RNE, subnormals, saturating
/// to ±∞ like hardware FCVT).
#[inline]
pub(crate) fn quantize_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return if man == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }

    // Unbiased exponent, rebiased for half (bias 15).
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow → infinity
    }
    if e <= 0 {
        // Subnormal or underflow to zero.
        if e < -10 {
            return sign;
        }
        let man = man | 0x80_0000; // implicit leading 1
        let shift = (14 - e) as u32; // 14..24
        let half_ulp = 1u32 << (shift - 1);
        let rounded = man + half_ulp - 1 + ((man >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }
    // Normal: round mantissa from 23 to 10 bits, RNE.
    let half_ulp = 0x0FFF + ((man >> 13) & 1);
    let man_r = man + half_ulp;
    if man_r & 0x80_0000 != 0 {
        // Mantissa overflow bumps exponent.
        let e2 = e + 1;
        if e2 >= 0x1F {
            return sign | 0x7C00;
        }
        return sign | ((e2 as u16) << 10);
    }
    sign | ((e as u16) << 10) | (man_r >> 13) as u16
}

fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: value = man · 2⁻²⁴, exact in f32.
            let v = man as f32 * 2.0f32.powi(-24);
            return if sign != 0 { -v } else { v };
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip an f32 through FP8 E4M3 (OCP: bias 7, no infinities,
/// saturating at ±448 like accelerator convert units), RNE.
#[inline]
pub(crate) fn quantize_fp8_e4m3(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let ax = x.abs();
    if ax >= 448.0 {
        return 448.0_f32.copysign(x);
    }
    const MIN_NORMAL: f32 = 0.015625; // 2⁻⁶
    if ax < MIN_NORMAL {
        // Subnormals: multiples of 2⁻⁹; RNE via round_ties_even.
        let q = (ax * 512.0).round_ties_even() * (1.0 / 512.0);
        return q.copysign(x);
    }
    // Normals: RNE the f32 mantissa down to 3 bits; carries propagate into
    // the exponent naturally through the integer add.
    const SHIFT: u32 = 23 - 3;
    let bits = ax.to_bits();
    let half = (1u32 << (SHIFT - 1)) - 1 + ((bits >> SHIFT) & 1);
    let r = (bits + half) & !((1u32 << SHIFT) - 1);
    let q = f32::from_bits(r).min(448.0);
    q.copysign(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_reference_values() {
        for x in [0.0f32, 1.0, 0.5, 0.25, 1.5, -1.5, 2048.0, 65504.0] {
            assert_eq!(quantize_f16(x), x, "{x}");
        }
        // RNE ties: 1 + 2⁻¹¹ is halfway to 1 + 2⁻¹⁰ → rounds to even (1.0).
        assert_eq!(quantize_f16(1.0 + 2.0f32.powi(-11)), 1.0);
        assert_eq!(
            quantize_f16(1.0 + 3.0 * 2.0f32.powi(-11)),
            1.0 + 2.0f32.powi(-9)
        );
        // Overflow saturates to infinity, subnormals survive.
        assert!(quantize_f16(1e6).is_infinite());
        let min_sub = 2.0f32.powi(-24);
        assert_eq!(quantize_f16(min_sub * 3.0), min_sub * 3.0);
        assert_eq!(quantize_f16(min_sub * 0.4), 0.0);
    }

    #[test]
    fn fp8_reference_values() {
        for p in -6..=8 {
            let x = 2.0f32.powi(p);
            assert_eq!(quantize_fp8_e4m3(x), x, "2^{p}");
        }
        assert_eq!(quantize_fp8_e4m3(1.5), 1.5);
        assert_eq!(quantize_fp8_e4m3(500.0), 448.0);
        assert_eq!(quantize_fp8_e4m3(-1e9), -448.0);
        // RNE ties at the 1/8 step around 1.0.
        assert_eq!(quantize_fp8_e4m3(1.0625), 1.0);
        assert_eq!(quantize_fp8_e4m3(1.1875), 1.25);
        // E4M3 steps near 500 are 32 px wide — absolute coords collapse.
        assert_eq!(quantize_fp8_e4m3(500.0), quantize_fp8_e4m3(503.0));
    }

    #[test]
    fn idempotent() {
        let mut x = 0.01f32;
        while x < 600.0 {
            let q16 = quantize_f16(x);
            assert_eq!(quantize_f16(q16), q16);
            let q8 = quantize_fp8_e4m3(x);
            assert_eq!(quantize_fp8_e4m3(q8), q8);
            x *= 1.37;
        }
    }
}
