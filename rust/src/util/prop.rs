//! Minimal property-based testing helper (the offline image has no
//! `proptest`). Runs a closure over many seeded-random cases; on failure it
//! reports the failing seed so the case can be replayed deterministically.
//! Shrinking is approximated by retrying the failing predicate with scaled-
//! down size hints where the generator supports it.

use super::rng::Pcg32;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    /// Number of generated cases per property.
    pub cases: usize,
    /// Base seed; each case derives its own replayable seed from it.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // FLICKER_PROP_CASES lets CI dial coverage up without code changes.
        let cases = std::env::var("FLICKER_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        PropConfig { cases, seed: 0xF11C }
    }
}

/// Run `prop` over `cfg.cases` generated inputs. `gen` receives an RNG and a
/// size hint in [0,1] that grows over the run (small cases first, which makes
/// early failures easy to read).
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: PropConfig,
    mut generate: impl FnMut(&mut Pcg32, f32) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ ((case as u64) << 32) ^ 0x5EED;
        let mut rng = Pcg32::new(case_seed);
        let size = (case as f32 + 1.0) / cfg.cases as f32;
        let input = generate(&mut rng, size);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {case_seed:#x}, size {size:.2}):\n  \
                 {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Relative-tolerance equality check for property bodies.
pub fn approx_eq(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(
            "reverse-reverse is identity",
            PropConfig::default(),
            |rng, size| {
                let n = (size * 32.0) as usize + 1;
                (0..n).map(|_| rng.next_u32()).collect::<Vec<_>>()
            },
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                ensure(w == *v, "mismatch")
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            PropConfig { cases: 3, seed: 1 },
            |rng, _| rng.next_u32(),
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    fn approx_eq_tolerance() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(approx_eq(1.0, 1.1, 1e-6, "x").is_err());
    }
}
