//! Small statistics toolkit used by the bench harness and report writers.

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Summary {
    /// Summarize a non-empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Percentile (linear interpolation) over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Geometric mean (used for cross-scene speedup aggregation, as in the paper).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Harmonic mean (used for FPS aggregation).
pub fn harmonic_mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.len() as f64 / xs.iter().map(|x| 1.0 / x).sum::<f64>()
}

/// Simple linear regression; returns (slope, intercept, r2).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (slope, intercept, r2)
}

/// Online mean/variance accumulator (Welford). Used by cycle counters where
/// storing every sample would be wasteful.
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    /// Fresh accumulator with no samples.
    pub fn new() -> Self {
        Online {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample into the running moments.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of samples pushed.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 for fewer than two samples).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest sample seen.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen.
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn harmonic_mean_fps() {
        let h = harmonic_mean(&[30.0, 60.0]);
        assert!((h - 40.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 1.0).collect();
        let (m, b, r2) = linreg(&xs, &ys);
        assert!((m - 3.0).abs() < 1e-9);
        assert!((b - 1.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs.iter().copied().collect::<Vec<_>>());
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.std() - s.std).abs() < 1e-9);
        assert_eq!(o.min(), s.min);
        assert_eq!(o.max(), s.max);
        assert_eq!(o.count(), 8);
    }
}
