//! Scoped thread pool for tile-parallel work (no tokio/rayon offline).
//!
//! The coordinator splits a frame into tiles and fans them across worker
//! threads. On this CI image there is a single core, so the pool defaults to
//! `available_parallelism()` and degrades gracefully to sequential execution.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of workers to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a `workers` knob: `0` means "auto" (`default_workers()`), any
/// other value is taken literally.
pub fn resolve_workers(workers: usize) -> usize {
    if workers == 0 {
        default_workers()
    } else {
        workers
    }
}

/// Run `f(i)` for every index in `0..n`, distributing indices across
/// `workers` threads via an atomic work-stealing counter. `f` must be
/// `Sync` (it only gets shared access). This is the side-effect variant
/// of the pool API — callers write results through interior mutability
/// (atomics, pre-sliced buffers). Use [`map_indexed`] when each index
/// produces an owned value; it carries its own drain loop because its
/// workers also accumulate thread-local result buffers.
pub fn for_each_index<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map preserving order: `out[i] = f(i)` for `i in 0..n`, with
/// indices drained through one atomic work-stealing counter.
///
/// `T` needs no `Default`/`Clone` and there is no per-element locking on
/// the hot fan-out path: each worker collects its `(index, value)` results
/// locally, and the caller thread scatters them into index order after the
/// joins. Every slot is produced exactly once (the counter hands each
/// index to one worker), so the scatter is collision-free.
pub fn map_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let chunks: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("pool worker panicked"))
            .collect()
    });
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    for (i, v) in chunks.into_iter().flatten() {
        debug_assert!(slots[i].is_none(), "index {i} produced twice");
        slots[i] = Some(v);
    }
    slots
        .into_iter()
        .map(|s| s.expect("pool fills every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        for_each_index(100, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn sequential_fallback() {
        let sum = AtomicU64::new(0);
        for_each_index(10, 1, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn map_preserves_order() {
        let v = map_indexed(16, 4, |i| i * i);
        assert_eq!(v, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_needs_neither_default_nor_clone() {
        // Regression for the old `T: Default + Clone` bounds (per-element
        // `Mutex<T>` double-initialized every slot).
        struct Opaque(usize);
        let v = map_indexed(9, 3, Opaque);
        for (i, o) in v.iter().enumerate() {
            assert_eq!(o.0, i);
        }
    }

    #[test]
    fn resolve_workers_auto_and_literal() {
        assert!(resolve_workers(0) >= 1);
        assert_eq!(resolve_workers(1), 1);
        assert_eq!(resolve_workers(7), 7);
    }

    #[test]
    fn empty_is_noop() {
        for_each_index(0, 4, |_| panic!("should not run"));
        let v: Vec<usize> = map_indexed(0, 4, |i| i);
        assert!(v.is_empty());
    }
}
