//! Scoped thread pool for tile-parallel work (no tokio/rayon offline).
//!
//! The coordinator splits a frame into tiles and fans them across worker
//! threads. On this CI image there is a single core, so the pool defaults to
//! `available_parallelism()` and degrades gracefully to sequential execution.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

/// Number of workers to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a `workers` knob: `0` means "auto" (`default_workers()`), any
/// other value is taken literally.
pub fn resolve_workers(workers: usize) -> usize {
    if workers == 0 {
        default_workers()
    } else {
        workers
    }
}

/// Run `f(i)` for every index in `0..n`, distributing indices across
/// `workers` threads via an atomic work-stealing counter. `f` must be
/// `Sync` (it only gets shared access). This is the side-effect variant
/// of the pool API — callers write results through interior mutability
/// (atomics, pre-sliced buffers). Use [`map_indexed`] when each index
/// produces an owned value; it carries its own drain loop because its
/// workers also accumulate thread-local result buffers.
pub fn for_each_index<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map preserving order: `out[i] = f(i)` for `i in 0..n`, with
/// indices drained through one atomic work-stealing counter.
///
/// `T` needs no `Default`/`Clone` and there is no per-element locking on
/// the hot fan-out path: each worker collects its `(index, value)` results
/// locally, and the caller thread scatters them into index order after the
/// joins. Every slot is produced exactly once (the counter hands each
/// index to one worker), so the scatter is collision-free.
pub fn map_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let chunks: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("pool worker panicked"))
            .collect()
    });
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    for (i, v) in chunks.into_iter().flatten() {
        debug_assert!(slots[i].is_none(), "index {i} produced twice");
        slots[i] = Some(v);
    }
    slots
        .into_iter()
        .map(|s| s.expect("pool fills every slot"))
        .collect()
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One submitted fan-out: a lifetime-erased `Fn(usize)` plus the atomic
/// work-stealing counter and a completion latch.
///
/// The raw pointer erases the caller's stack lifetime; soundness rests on
/// [`WorkerPool::run`] blocking until every queued participation has
/// signalled `done`, after which no worker dereferences `f` again (workers
/// only hold the `Arc` past that point, never the closure).
struct TaskShared {
    f: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    n: usize,
    done: Mutex<usize>,
    finished: Condvar,
    panicked: AtomicBool,
}

// SAFETY: `f` points at a `Sync` closure that outlives all dereferences
// (see `run`); the remaining fields are themselves Send + Sync.
unsafe impl Send for TaskShared {}
unsafe impl Sync for TaskShared {}

struct PoolQueue {
    jobs: VecDeque<Arc<TaskShared>>,
    closed: bool,
}

struct PoolShared {
    q: Mutex<PoolQueue>,
    cv: Condvar,
}

/// A persistent worker pool: `workers` parked OS threads draining
/// index-parallel jobs, in contrast to the free [`for_each_index`] /
/// [`map_indexed`] functions which spawn scoped threads per call.
///
/// The render service keeps one `WorkerPool` shared across all clients so
/// steady-state serving pays no thread spawn/join per drained request
/// window. Scheduling is identical to the free functions — one atomic
/// counter hands each index to exactly one worker — so results are
/// bit-identical to fresh scoped workers (pinned by the service test
/// suite). Do not submit pool work from inside a pool task: a worker
/// waiting on its own pool deadlocks.
pub struct WorkerPool {
    workers: usize,
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `workers` threads (`0` = auto, via
    /// [`resolve_workers`]). A one-worker pool spawns no threads and runs
    /// every job inline on the caller.
    pub fn new(workers: usize) -> WorkerPool {
        let workers = resolve_workers(workers);
        let shared = Arc::new(PoolShared {
            q: Mutex::new(PoolQueue {
                jobs: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        });
        let handles = if workers <= 1 {
            Vec::new()
        } else {
            (0..workers)
                .map(|_| {
                    let shared = shared.clone();
                    std::thread::spawn(move || worker_loop(&shared))
                })
                .collect()
        };
        WorkerPool {
            workers,
            shared,
            handles,
        }
    }

    /// Resolved worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(i)` for every index in `0..n` across the pool's threads.
    /// Blocks until all indices complete; panics (after completion of the
    /// latch) if any worker participation panicked.
    pub fn for_each_index<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run(n, &f);
    }

    /// Parallel map preserving order: `out[i] = f(i)`. Same scheduling as
    /// the free [`map_indexed`], but on the persistent threads.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if self.workers <= 1 || n == 1 {
            return (0..n).map(f).collect();
        }
        // Each index writes its own pre-allocated slot exactly once (the
        // counter hands indices out uniquely), so unsynchronized interior
        // writes are collision-free; the completion latch in `run` orders
        // them before the caller's reads.
        struct Slots<T>(Vec<UnsafeCell<Option<T>>>);
        // SAFETY: disjoint per-index writes, read only after the latch.
        unsafe impl<T: Send> Sync for Slots<T> {}
        let slots = Slots((0..n).map(|_| UnsafeCell::new(None)).collect());
        self.run(n, &|i| {
            let v = f(i);
            unsafe { *slots.0[i].get() = Some(v) };
        });
        slots
            .0
            .into_iter()
            .map(|c| c.into_inner().expect("pool fills every slot"))
            .collect()
    }

    fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        if self.workers <= 1 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let participations = self.workers.min(n);
        let task = Arc::new(TaskShared {
            f: f as *const (dyn Fn(usize) + Sync),
            next: AtomicUsize::new(0),
            n,
            done: Mutex::new(0),
            finished: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut q = lock(&self.shared.q);
            for _ in 0..participations {
                q.jobs.push_back(task.clone());
            }
        }
        self.shared.cv.notify_all();
        let mut done = lock(&task.done);
        while *done < participations {
            done = task
                .finished
                .wait(done)
                .unwrap_or_else(PoisonError::into_inner);
        }
        drop(done);
        if task.panicked.load(Ordering::Relaxed) {
            panic!("worker pool task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = lock(&self.shared.q);
            q.closed = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut q = lock(&shared.q);
            loop {
                if let Some(t) = q.jobs.pop_front() {
                    break t;
                }
                if q.closed {
                    return;
                }
                q = shared.cv.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
        };
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // SAFETY: the submitting `run` call blocks until this
            // participation signals `done` below, so `f` is still alive.
            let f = unsafe { &*task.f };
            loop {
                let i = task.next.fetch_add(1, Ordering::Relaxed);
                if i >= task.n {
                    break;
                }
                f(i);
            }
        }));
        if res.is_err() {
            task.panicked.store(true, Ordering::Relaxed);
        }
        let mut d = lock(&task.done);
        *d += 1;
        task.finished.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        for_each_index(100, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn sequential_fallback() {
        let sum = AtomicU64::new(0);
        for_each_index(10, 1, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn map_preserves_order() {
        let v = map_indexed(16, 4, |i| i * i);
        assert_eq!(v, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_needs_neither_default_nor_clone() {
        // Regression for the old `T: Default + Clone` bounds (per-element
        // `Mutex<T>` double-initialized every slot).
        struct Opaque(usize);
        let v = map_indexed(9, 3, Opaque);
        for (i, o) in v.iter().enumerate() {
            assert_eq!(o.0, i);
        }
    }

    #[test]
    fn resolve_workers_auto_and_literal() {
        assert!(resolve_workers(0) >= 1);
        assert_eq!(resolve_workers(1), 1);
        assert_eq!(resolve_workers(7), 7);
    }

    #[test]
    fn empty_is_noop() {
        for_each_index(0, 4, |_| panic!("should not run"));
        let v: Vec<usize> = map_indexed(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn worker_pool_covers_all_indices() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.workers(), 4);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.for_each_index(100, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn worker_pool_map_matches_free_map_across_reuses() {
        // The pool is persistent: the same threads serve many submissions,
        // and each must match the scoped-thread free function exactly.
        let pool = WorkerPool::new(3);
        for round in 0..5usize {
            let fresh = map_indexed(33, 3, |i| (i * 7 + round) % 13);
            let pooled = pool.map_indexed(33, |i| (i * 7 + round) % 13);
            assert_eq!(fresh, pooled, "round {round}");
        }
    }

    #[test]
    fn worker_pool_single_worker_runs_inline() {
        let pool = WorkerPool::new(1);
        assert!(pool.handles.is_empty());
        let v = pool.map_indexed(8, |i| i * 2);
        assert_eq!(v, vec![0, 2, 4, 6, 8, 10, 12, 14]);
    }

    #[test]
    fn worker_pool_empty_and_tiny_jobs() {
        let pool = WorkerPool::new(4);
        let v: Vec<usize> = pool.map_indexed(0, |i| i);
        assert!(v.is_empty());
        // n < workers queues fewer participations than threads.
        let v = pool.map_indexed(2, |i| i + 1);
        assert_eq!(v, vec![1, 2]);
    }

    #[test]
    fn worker_pool_survives_a_panicking_task() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.for_each_index(4, |i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "task panic must propagate to the submitter");
        // The pool threads stay alive and keep serving work.
        let v = pool.map_indexed(4, |i| i);
        assert_eq!(v, vec![0, 1, 2, 3]);
    }
}
