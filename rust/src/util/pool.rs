//! Scoped thread pool for tile-parallel work (no tokio/rayon offline).
//!
//! The coordinator splits a frame into tiles and fans them across worker
//! threads. On this CI image there is a single core, so the pool defaults to
//! `available_parallelism()` and degrades gracefully to sequential execution.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Number of workers to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a `workers` knob: `0` means "auto" (`default_workers()`), any
/// other value is taken literally.
pub fn resolve_workers(workers: usize) -> usize {
    if workers == 0 {
        default_workers()
    } else {
        workers
    }
}

/// Run `f(i)` for every index in `0..n`, distributing indices across
/// `workers` threads via an atomic work-stealing counter. `f` must be
/// `Sync` (it only gets shared access); results are written through
/// interior mutability or returned via `map_indexed`.
pub fn for_each_index<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map preserving order.
pub fn map_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let out: Arc<Vec<std::sync::Mutex<T>>> =
        Arc::new((0..n).map(|_| std::sync::Mutex::new(T::default())).collect());
    {
        let out = Arc::clone(&out);
        for_each_index(n, workers, move |i| {
            *out[i].lock().unwrap() = f(i);
        });
    }
    Arc::try_unwrap(out)
        .unwrap_or_else(|_| panic!("pool: outstanding refs"))
        .into_iter()
        .map(|m| m.into_inner().unwrap())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        for_each_index(100, 4, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn sequential_fallback() {
        let sum = AtomicU64::new(0);
        for_each_index(10, 1, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn map_preserves_order() {
        let v = map_indexed(16, 4, |i| i * i);
        assert_eq!(v, (0..16).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn resolve_workers_auto_and_literal() {
        assert!(resolve_workers(0) >= 1);
        assert_eq!(resolve_workers(1), 1);
        assert_eq!(resolve_workers(7), 7);
    }

    #[test]
    fn empty_is_noop() {
        for_each_index(0, 4, |_| panic!("should not run"));
        let v: Vec<usize> = map_indexed(0, 4, |i| i);
        assert!(v.is_empty());
    }
}
