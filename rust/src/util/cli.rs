//! Tiny CLI argument parser (the offline image has no `clap`).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` pairs.
    opts: BTreeMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

/// CLI parse/validation error.
#[derive(Debug)]
pub struct CliError(
    /// Human-readable message.
    pub String,
);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    /// `known_flags` lists options that take NO value; anything else starting
    /// with `--` is treated as `--key value` unless written as `--key=value`.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    // trailing --key with no value: treat as flag
                    out.flags.push(rest.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    /// Was the bare `--name` flag given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String value of `--key`, or `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer value of `--key`, or `default`; errors on a non-integer.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
        }
    }

    /// Like [`Args::u64_or`], narrowed to `usize`.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        self.u64_or(key, default as u64).map(|x| x as usize)
    }

    /// Float value of `--key`, or `default`; errors on a non-number.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected number, got '{v}'"))),
        }
    }

    /// Comma-separated list of integers, e.g. `--depths 1,2,4,8`.
    pub fn u64_list_or(&self, key: &str, default: &[u64]) -> Result<Vec<u64>, CliError> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{key}: bad integer '{t}'")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], flags: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn subcommand_and_opts() {
        let a = parse(&["render", "--scene", "garden", "--width=640"], &[]);
        assert_eq!(a.command.as_deref(), Some("render"));
        assert_eq!(a.get("scene"), Some("garden"));
        assert_eq!(a.u64_or("width", 0).unwrap(), 640);
    }

    #[test]
    fn flags_vs_valued() {
        let a = parse(&["sim", "--verbose", "--depth", "16"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("depth", 0).unwrap(), 16);
        assert!(!a.flag("depth"));
    }

    #[test]
    fn positional_args() {
        let a = parse(&["quality", "scene1", "scene2"], &[]);
        assert_eq!(a.positional, vec!["scene1", "scene2"]);
    }

    #[test]
    fn list_option() {
        let a = parse(&["sweep", "--depths", "1,2, 4,128"], &[]);
        assert_eq!(a.u64_list_or("depths", &[]).unwrap(), vec![1, 2, 4, 128]);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--n", "abc"], &[]);
        assert!(a.u64_or("n", 1).is_err());
        assert!(a.f64_or("n", 1.0).is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["x", "--dry-run"], &[]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"], &[]);
        assert_eq!(a.str_or("mode", "adaptive"), "adaptive");
        assert_eq!(a.f64_or("scale", 1.5).unwrap(), 1.5);
    }
}
