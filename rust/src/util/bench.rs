//! Micro-bench harness (the offline image has no `criterion`).
//!
//! Every `[[bench]]` target uses `harness = false` and drives this module:
//! warmup, timed iterations, outlier-robust summary, and a machine-readable
//! JSON sidecar next to the human table so EXPERIMENTS.md can be regenerated.

use super::json::{jarr, jnum, jstr, Json};
use super::stats::Summary;
use std::time::Instant;

/// One timed measurement series.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Measurement name (one row in the output table).
    pub name: String,
    /// seconds per iteration
    pub samples: Vec<f64>,
    /// Summary statistics over `samples`.
    pub summary: Summary,
}

impl BenchResult {
    /// Items per second at the median iteration time.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.summary.p50
    }
}

/// Runs closures with warmup + sampling.
pub struct Bencher {
    /// Untimed iterations before sampling starts.
    pub warmup_iters: usize,
    /// Timed iterations per measurement.
    pub sample_iters: usize,
    results: Vec<BenchResult>,
    /// Figure/table id, e.g. "fig9"; used for the JSON sidecar filename.
    pub id: String,
}

/// True when the bench-smoke knob is on: the `FLICKER_BENCH_QUICK` env var
/// or a `--quick` CLI argument (what `make bench-smoke` / the CI
/// bench-smoke lane pass via `cargo bench -- --quick`). Quick mode runs
/// every measurement once-ish at a reduced default resolution so bench
/// targets are exercised end-to-end without paying for full sampling.
pub fn quick_mode() -> bool {
    std::env::var("FLICKER_BENCH_QUICK").is_ok() || std::env::args().any(|a| a == "--quick")
}

impl Bencher {
    /// New harness for the figure/table `id` (sidecar filename).
    pub fn new(id: &str) -> Self {
        // Keep runs short: single-core machine, many bench targets.
        let quick = quick_mode();
        Bencher {
            warmup_iters: if quick { 1 } else { 2 },
            sample_iters: if quick { 3 } else { 7 },
            results: Vec::new(),
            id: id.to_string(),
        }
    }

    /// Time `f` (called once per iteration) and record under `name`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let summary = Summary::of(&samples);
        self.results.push(BenchResult {
            name: name.to_string(),
            samples,
            summary,
        });
        self.results.last().unwrap()
    }

    /// Record an externally computed scalar metric (cycles, PSNR, joules…):
    /// benches in this repo mostly report *simulated* quantities, which are
    /// deterministic — one "sample".
    pub fn record(&mut self, name: &str, value: f64) {
        self.results.push(BenchResult {
            name: name.to_string(),
            samples: vec![value],
            summary: Summary::of(&[value]),
        });
    }

    /// All measurements recorded so far, in order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Pretty-print a table and write `target/bench-reports/<id>.json`.
    pub fn finish(&self, header: &str) {
        println!("\n== {} ==", header);
        let wname = self
            .results
            .iter()
            .map(|r| r.name.len())
            .max()
            .unwrap_or(10)
            .max(10);
        println!("{:<wname$}  {:>14}  {:>12}  {:>12}", "case", "median", "mean", "std");
        for r in &self.results {
            if r.samples.len() == 1 {
                println!("{:<wname$}  {:>14.6}", r.name, r.summary.p50);
            } else {
                println!(
                    "{:<wname$}  {:>12.3}ms  {:>10.3}ms  {:>10.3}ms",
                    r.name,
                    r.summary.p50 * 1e3,
                    r.summary.mean * 1e3,
                    r.summary.std * 1e3
                );
            }
        }
        let mut obj = Json::obj();
        obj.insert("id", jstr(&self.id));
        obj.insert("header", jstr(header));
        let rows: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.insert("name", jstr(&r.name));
                o.insert("median", jnum(r.summary.p50));
                o.insert("mean", jnum(r.summary.mean));
                o.insert("std", jnum(r.summary.std));
                o.insert("n", jnum(r.summary.n as f64));
                Json::Obj(o)
            })
            .collect();
        obj.insert("results", jarr(rows));
        let dir = std::path::Path::new("target/bench-reports");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.id));
        if let Err(e) = std::fs::write(&path, Json::Obj(obj).pretty()) {
            eprintln!("warn: could not write {}: {e}", path.display());
        } else {
            println!("(report: {})", path.display());
        }
    }
}

/// Black-box to stop the optimizer deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bencher::new("test");
        b.sample_iters = 3;
        b.warmup_iters = 1;
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(r.samples.len(), 3);
        assert!(r.summary.p50 >= 0.0);
    }

    #[test]
    fn record_scalar() {
        let mut b = Bencher::new("test2");
        b.record("speedup", 1.36);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].summary.p50, 1.36);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![0.5],
            summary: Summary::of(&[0.5]),
        };
        assert!((r.throughput(100.0) - 200.0).abs() < 1e-9);
    }
}
