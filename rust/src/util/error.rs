//! In-tree error substrate replacing the `anyhow` dependency (consistent
//! with the JSON/RNG/CLI substrates — the offline image has no external
//! crates).
//!
//! A string-backed [`Error`], a [`Result`] alias, an `anyhow::Context`-style
//! [`Context`] extension for `Result`/`Option`, and the [`crate::err!`] /
//! [`crate::bail!`] macros.

use std::fmt;

/// String-backed error. Conversions from the error types produced inside
/// the crate (`std::io`, the CLI parser) let `?` flow through the driver
/// layers without an external error-trait object.
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Crate-wide result alias (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<crate::util::cli::CliError> for Error {
    fn from(e: crate::util::cli::CliError) -> Error {
        Error::msg(e.0)
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error::msg(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

/// Attach context to a failure, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or empty option) with a fixed message.
    fn context(self, msg: impl Into<String>) -> Result<T>;

    /// Wrap with a lazily-built message (use when formatting is not free).
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", msg.into())))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f().into())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`](crate::util::error::Error) from a format string
/// (drop-in for `anyhow::anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return a formatted [`Error`](crate::util::error::Error) (drop-in
/// for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(err!("broke at {}", 42))
    }

    #[test]
    fn macro_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke at 42");
    }

    #[test]
    fn bail_early_returns() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero input");
            }
            Ok(x * 2)
        }
        assert_eq!(f(3).unwrap(), 6);
        assert_eq!(f(0).unwrap_err().to_string(), "zero input");
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_wraps_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("writing report").unwrap_err();
        assert!(e.to_string().starts_with("writing report: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing key '{}'", "tile")).unwrap_err();
        assert_eq!(e.to_string(), "missing key 'tile'");
        assert_eq!(Some(7).context("present").unwrap(), 7);
    }

    #[test]
    fn cli_error_converts() {
        fn f() -> Result<u64> {
            let args = crate::util::cli::Args::parse(
                ["x", "--n", "abc"].iter().map(|s| s.to_string()),
                &[],
            );
            Ok(args.u64_or("n", 1)?)
        }
        assert!(f().unwrap_err().to_string().contains("expected integer"));
    }
}
