//! Deterministic pseudo-random number generation.
//!
//! All synthetic data in the repo (scenes, workloads, property tests) is
//! driven by this module so every experiment is exactly reproducible from a
//! seed. We implement PCG32 (O'Neill 2014) seeded through SplitMix64 —
//! small state, good statistical quality, no external crates.

/// SplitMix64: used to expand a single `u64` seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG32 (XSH-RR variant): 64-bit state, 32-bit output.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1; // stream must be odd
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for parallel generators).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let s = (self.next_u32() as u64) << 32 | self.next_u32() as u64;
        Pcg32::new(s ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits (two PCG32 draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (caches the second value).
    pub fn normal(&mut self) -> f32 {
        // Non-caching Box-Muller: simpler, the extra cos is cheap here.
        let u1 = (1.0 - self.f64()) as f32; // avoid ln(0)
        let u2 = self.f32();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * core::f32::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)).
    #[inline]
    pub fn lognormal(&mut self, mu: f32, sigma: f32) -> f32 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Bernoulli with probability p.
    #[inline]
    pub fn chance(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::new(3);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Pcg32::new(9);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn range_u32_bounds() {
        let mut r = Pcg32::new(13);
        for _ in 0..1000 {
            let x = r.range_u32(5, 9);
            assert!((5..=9).contains(&x));
        }
    }
}
