//! Minimal JSON parser + writer.
//!
//! The offline image has no `serde`; configs and machine-readable reports use
//! this small, well-tested implementation instead. It supports the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, bool, null) and
//! pretty printing. Object key order is preserved (insertion order), which
//! keeps emitted reports diffable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Objects keep insertion order via a parallel key list.
    Obj(JsonObj),
}

/// Insertion-ordered string→Json map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    /// Empty object.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (or overwrite) `key`; first insertion fixes its print order.
    pub fn insert(&mut self, key: impl Into<String>, val: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, val);
    }

    /// Value under `key`, if present.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// Is the object empty?
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Iterate entries in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }
}

impl Json {
    /// Empty object builder (wrap with [`Json::Obj`] when done).
    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value truncated to u64, if this is a number.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    /// String slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object reference, if this is an object.
    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["sim", "fifo_depth"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.as_obj()?.get(p)?;
        }
        Some(cur)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, val)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // UTF-8 continuation: copy raw bytes of the multibyte char.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Convenience builders used all over the report writers.
pub fn jnum(x: f64) -> Json {
    Json::Num(x)
}

/// Build a [`Json::Str`].
pub fn jstr(s: impl Into<String>) -> Json {
    Json::Str(s.into())
}

/// Build a [`Json::Arr`].
pub fn jarr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e4", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]).unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn object_preserves_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&String> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo wörld 中文\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld 中文");
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn rejects_garbage() {
        for src in ["{", "[1,", "\"x", "tru", "{\"a\" 1}", "01x", "[1 2]", "{}, extra"] {
            assert!(Json::parse(src).is_err(), "{src} should fail");
        }
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        let v2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(JsonObj::new()));
        assert_eq!(Json::parse("[]").unwrap().to_string(), "[]");
    }

    #[test]
    fn deep_path_lookup() {
        let v = Json::parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(v.at(&["a", "b", "c"]).unwrap().as_f64(), Some(7.0));
        assert!(v.at(&["a", "x"]).is_none());
    }
}
