//! In-tree substrates replacing unavailable external crates (offline image):
//! deterministic RNG, JSON, statistics, CLI parsing, error handling, bench
//! harness, property-testing helper, and a scoped thread pool.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
