//! Experiment configuration: JSON-backed settings for scenes, cameras,
//! algorithm modes, and hardware presets, with CLI overrides.
//!
//! The config system is the single entry point benches, examples, and the
//! CLI use to construct consistent (scene, camera set, hardware) triples, so
//! every experiment in EXPERIMENTS.md is reproducible from a config dump.

use crate::camera::{orbit_path, Camera, Intrinsics};
use crate::cat::{LeaderMode, Precision};
use crate::err;
use crate::numeric::linalg::v3;
use crate::render::precision::{PrecisionMode, PrecisionPolicy, PrecisionThresholds};
use crate::render::raster::RenderOptions;
use crate::render::tile::Strategy;
use crate::scene::gaussian::Scene;
use crate::scene::synthetic::{generate_scaled, preset};
use crate::sim::HwConfig;
use crate::util::error::Result;
use crate::util::json::{jnum, jstr, Json};

/// One experiment setup.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Scene preset name ("garden", "truck", …) or a .gsz path.
    pub scene: String,
    /// Fraction of the full-size synthetic scene to generate (CI scale).
    pub scene_scale: f32,
    /// Render resolution (square).
    pub resolution: u32,
    /// Number of orbit views to evaluate.
    pub frames: usize,
    /// Hardware preset name (see `sim::HwConfig::by_name`).
    pub hardware: String,
    /// Leader mode override ("dense", "sparse", "adaptive", "spiky-focused").
    pub cat_mode: Option<String>,
    /// Precision override ("fp32", "fp16", "fp8", "mixed", "adaptive" for
    /// contribution-driven per-tile classing, or "rect" to refine
    /// mid/high-energy tiles per quadrant-rectangle; case-insensitive).
    pub precision: Option<String>,
    /// Thresholds spec `"FP32MIN,FP16MIN[,FLOOR]"` (e.g. `"0.6,0.25"` or
    /// `"0.5,0.2,fp16"`). Requires `precision: adaptive` or `rect` (both
    /// share the threshold vocabulary).
    pub precision_thresholds: Option<String>,
    /// FIFO depth override.
    pub fifo_depth: Option<usize>,
    /// Tile edge override in pixels (None = the paper's 16).
    pub tile_size: Option<u32>,
    /// Tile-intersection strategy override ("aabb", "obb"; None = aabb).
    pub strategy: Option<String>,
    /// Apply contribution pruning before evaluation.
    pub prune: bool,
    /// Worker threads for frame/tile parallel rendering and pruning's
    /// contribution scoring (0 = auto, 1 = sequential; parallel output is
    /// bit-identical to sequential).
    pub workers: usize,
    /// Tiles per PJRT dispatch (0 = the batched artifact's full
    /// `n_batch`, 1 = single-tile-artifact dispatch; intermediate values
    /// serve the differential tests). Only the `pjrt` backend reads it.
    /// Output is bit-identical across values under the stub-interpreted
    /// artifacts (CI-enforced); real XLA agrees within float tolerance
    /// (vmap lowering carries no cross-program bit-identity guarantee).
    pub batch: usize,
    /// Coarse-to-fine contribution gate switch (`render::pyramid`):
    /// `Some(true)` enables it, `Some(false)` forces it off, `None` keeps
    /// the renderer default (off). At the default threshold the gate is
    /// lossless — identical pixels, fewer submitted splats.
    pub gate: Option<bool>,
    /// Gate levels override (1 = whole-tile only, 2 = tile + quadrants).
    pub gate_levels: Option<u32>,
    /// Gate alpha threshold override (default 1/255 = lossless; higher
    /// trades quality for a deeper cut).
    pub gate_threshold: Option<f32>,
    /// Temporal plan-delta switch (`render::delta`): `Some(true)` lets the
    /// session advance plans from already-built neighbor views instead of
    /// cold-building, `Some(false)` forces it off, `None` keeps the
    /// renderer default (off). Advanced plans are bitwise identical to
    /// cold builds — this only changes preparation cost.
    pub plan_delta: Option<bool>,
    /// Largest pose step (radians) the delta path accepts before falling
    /// back to a cold build (None = the renderer default, ~0.35).
    pub plan_delta_angle: Option<f32>,
    /// RNG seed for synthetic scene generation.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            scene: "garden".into(),
            scene_scale: default_scene_scale(),
            resolution: 256,
            frames: 3,
            hardware: "flicker32".into(),
            cat_mode: None,
            precision: None,
            precision_thresholds: None,
            fifo_depth: None,
            tile_size: None,
            strategy: None,
            prune: false,
            workers: 1,
            batch: 0,
            gate: None,
            gate_levels: None,
            gate_threshold: None,
            plan_delta: None,
            plan_delta_angle: None,
            seed: 0xF11C,
        }
    }
}

/// CI-friendly default: FLICKER_SCENE_SCALE overrides (1.0 = paper scale).
pub fn default_scene_scale() -> f32 {
    std::env::var("FLICKER_SCENE_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05)
}

impl ExperimentConfig {
    /// Build the scene (synthetic preset or .gsz file).
    pub fn build_scene(&self) -> Result<Scene> {
        if self.scene.ends_with(".gsz") {
            return crate::scene::io::load(std::path::Path::new(&self.scene));
        }
        Ok(generate_scaled(&preset(&self.scene), self.scene_scale))
    }

    /// Evaluation cameras: an orbit whose radius adapts to the scene kind.
    pub fn build_cameras(&self) -> Vec<Camera> {
        let intr = Intrinsics::from_fov(self.resolution, self.resolution, 1.2);
        orbit_path(intr, v3(0.0, 0.5, 0.0), 12.0, 3.0, self.frames.max(1))
    }

    /// Resolve the **full** rasterization options this experiment asked
    /// for: tile size, intersection strategy, and the worker budget. Every
    /// render driven from a config (the CLI, `coordinator::Session`,
    /// benches) must thread options through here — the pre-`Session`
    /// coordinator hardcoded `RenderOptions::default()` for orbits and
    /// silently dropped a configured strategy/tile size.
    pub fn render_options(&self) -> Result<RenderOptions> {
        let mut o = RenderOptions {
            workers: self.workers,
            batch: self.batch,
            ..RenderOptions::default()
        };
        if let Some(ts) = self.tile_size {
            if ts == 0 {
                return Err(err!("tile_size must be positive"));
            }
            o.tile_size = ts;
        }
        if let Some(s) = &self.strategy {
            o.strategy =
                Strategy::parse(s).ok_or_else(|| err!("unknown strategy '{s}' (aabb|obb)"))?;
        }
        if let Some(g) = self.gate {
            o.gate.enabled = g;
        }
        if let Some(l) = self.gate_levels {
            if !(1..=2).contains(&l) {
                return Err(err!("gate_levels must be 1 or 2 (got {l})"));
            }
            o.gate.levels = l;
        }
        if let Some(t) = self.gate_threshold {
            if !(t > 0.0 && t <= 1.0) {
                return Err(err!("gate_threshold must be in (0, 1] (got {t})"));
            }
            o.gate.threshold = t;
        }
        if let Some(p) = &self.precision {
            o.precision = PrecisionPolicy::parse(p).ok_or_else(|| {
                err!("unknown precision '{p}' (valid: fp32|fp16|fp8|mixed|adaptive|rect)")
            })?;
        }
        if let Some(spec) = &self.precision_thresholds {
            let (PrecisionMode::Adaptive { thresholds, floor }
            | PrecisionMode::Rect { thresholds, floor }) = &mut o.precision.mode
            else {
                return Err(err!("precision_thresholds requires precision = adaptive or rect"));
            };
            let (t, fl) = PrecisionThresholds::parse(spec).ok_or_else(|| {
                err!("precision_thresholds: expected 'FP32MIN,FP16MIN[,FLOOR]', got '{spec}'")
            })?;
            *thresholds = t;
            if let Some(f) = fl {
                *floor = f;
            }
        }
        if let Some(pd) = self.plan_delta {
            o.plan_delta.enabled = pd;
        }
        if let Some(a) = self.plan_delta_angle {
            if !(a > 0.0 && a.is_finite()) {
                return Err(err!("plan_delta_angle must be a positive angle in radians (got {a})"));
            }
            o.plan_delta.max_angle = a;
        }
        Ok(o)
    }

    /// Resolve the hardware config with overrides applied.
    pub fn build_hw(&self) -> Result<HwConfig> {
        let mut hw = HwConfig::by_name(&self.hardware)
            .ok_or_else(|| err!("unknown hardware preset '{}'", self.hardware))?;
        if let Some(m) = &self.cat_mode {
            hw.cat_mode = LeaderMode::parse(m).ok_or_else(|| err!("unknown cat mode '{m}'"))?;
        }
        if let Some(p) = &self.precision {
            // "adaptive" and "rect" keep the preset's global CTU precision
            // — the realized per-tile (or per-quadrant) class mix is
            // reported by `sim::workload` instead of a single
            // hardware-wide knob.
            if !p.eq_ignore_ascii_case("adaptive") && !p.eq_ignore_ascii_case("rect") {
                hw.cat_precision = Precision::parse(p).ok_or_else(|| {
                    err!("unknown precision '{p}' (valid: fp32|fp16|fp8|mixed|adaptive|rect)")
                })?;
            }
        }
        if let Some(d) = self.fifo_depth {
            hw.fifo_depth = d;
        }
        Ok(hw)
    }

    /// Parse overrides from CLI args.
    pub fn from_args(args: &crate::util::cli::Args) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        if let Some(path) = args.get("config") {
            cfg = Self::from_json_file(std::path::Path::new(path))?;
        }
        if let Some(s) = args.get("scene") {
            cfg.scene = s.to_string();
        }
        cfg.scene_scale = args.f64_or("scene-scale", cfg.scene_scale as f64)? as f32;
        cfg.resolution = args.u64_or("resolution", cfg.resolution as u64)? as u32;
        cfg.frames = args.usize_or("frames", cfg.frames)?;
        if let Some(h) = args.get("hardware") {
            cfg.hardware = h.to_string();
        }
        cfg.cat_mode = args.get("cat-mode").map(|s| s.to_string()).or(cfg.cat_mode);
        cfg.precision = args.get("precision").map(|s| s.to_string()).or(cfg.precision);
        cfg.precision_thresholds = args
            .get("precision-thresholds")
            .map(|s| s.to_string())
            .or(cfg.precision_thresholds);
        if let Some(d) = args.get("fifo-depth") {
            cfg.fifo_depth =
                Some(d.parse().map_err(|_| err!("--fifo-depth: bad integer '{d}'"))?);
        }
        if let Some(t) = args.get("tile-size") {
            cfg.tile_size =
                Some(t.parse().map_err(|_| err!("--tile-size: bad integer '{t}'"))?);
        }
        cfg.strategy = args.get("strategy").map(|s| s.to_string()).or(cfg.strategy);
        if args.flag("prune") {
            cfg.prune = true;
        }
        cfg.workers = args.usize_or("workers", cfg.workers)?;
        cfg.batch = args.usize_or("batch", cfg.batch)?;
        if let Some(g) = args.get("gate") {
            cfg.gate = Some(match g {
                "on" | "true" | "1" => true,
                "off" | "false" | "0" => false,
                _ => return Err(err!("--gate: expected on|off, got '{g}'")),
            });
        }
        if let Some(l) = args.get("gate-levels") {
            cfg.gate_levels =
                Some(l.parse().map_err(|_| err!("--gate-levels: bad integer '{l}'"))?);
        }
        if let Some(t) = args.get("gate-threshold") {
            cfg.gate_threshold =
                Some(t.parse().map_err(|_| err!("--gate-threshold: bad number '{t}'"))?);
        }
        if let Some(pd) = args.get("plan-delta") {
            cfg.plan_delta = Some(match pd {
                "on" | "true" | "1" => true,
                "off" | "false" | "0" => false,
                _ => return Err(err!("--plan-delta: expected on|off, got '{pd}'")),
            });
        }
        if let Some(a) = args.get("plan-delta-angle") {
            cfg.plan_delta_angle =
                Some(a.parse().map_err(|_| err!("--plan-delta-angle: bad number '{a}'"))?);
        }
        cfg.seed = args.u64_or("seed", cfg.seed)?;
        Ok(cfg)
    }

    /// Load a config from a JSON file (keys mirror [`ExperimentConfig`]).
    pub fn from_json_file(path: &std::path::Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| err!("{}: {e}", path.display()))?;
        let mut cfg = ExperimentConfig::default();
        let s = |k: &str| j.at(&[k]).and_then(Json::as_str).map(str::to_string);
        let n = |k: &str| j.at(&[k]).and_then(Json::as_f64);
        if let Some(v) = s("scene") {
            cfg.scene = v;
        }
        if let Some(v) = n("scene_scale") {
            cfg.scene_scale = v as f32;
        }
        if let Some(v) = n("resolution") {
            cfg.resolution = v as u32;
        }
        if let Some(v) = n("frames") {
            cfg.frames = v as usize;
        }
        if let Some(v) = s("hardware") {
            cfg.hardware = v;
        }
        cfg.cat_mode = s("cat_mode").or(cfg.cat_mode);
        cfg.precision = s("precision").or(cfg.precision);
        cfg.precision_thresholds = s("precision_thresholds").or(cfg.precision_thresholds);
        if let Some(v) = n("fifo_depth") {
            cfg.fifo_depth = Some(v as usize);
        }
        if let Some(v) = n("tile_size") {
            cfg.tile_size = Some(v as u32);
        }
        cfg.strategy = s("strategy").or(cfg.strategy);
        if let Some(v) = j.at(&["prune"]).and_then(Json::as_bool) {
            cfg.prune = v;
        }
        if let Some(v) = n("workers") {
            cfg.workers = v as usize;
        }
        if let Some(v) = n("batch") {
            cfg.batch = v as usize;
        }
        if let Some(v) = j.at(&["gate"]).and_then(Json::as_bool) {
            cfg.gate = Some(v);
        }
        if let Some(v) = n("gate_levels") {
            cfg.gate_levels = Some(v as u32);
        }
        if let Some(v) = n("gate_threshold") {
            cfg.gate_threshold = Some(v as f32);
        }
        if let Some(v) = j.at(&["plan_delta"]).and_then(Json::as_bool) {
            cfg.plan_delta = Some(v);
        }
        if let Some(v) = n("plan_delta_angle") {
            cfg.plan_delta_angle = Some(v as f32);
        }
        if let Some(v) = n("seed") {
            cfg.seed = v as u64;
        }
        Ok(cfg)
    }

    /// Serialize (for report provenance).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("scene", jstr(&self.scene));
        o.insert("scene_scale", jnum(self.scene_scale as f64));
        o.insert("resolution", jnum(self.resolution as f64));
        o.insert("frames", jnum(self.frames as f64));
        o.insert("hardware", jstr(&self.hardware));
        if let Some(m) = &self.cat_mode {
            o.insert("cat_mode", jstr(m));
        }
        if let Some(p) = &self.precision {
            o.insert("precision", jstr(p));
        }
        if let Some(t) = &self.precision_thresholds {
            o.insert("precision_thresholds", jstr(t));
        }
        if let Some(d) = self.fifo_depth {
            o.insert("fifo_depth", jnum(d as f64));
        }
        if let Some(t) = self.tile_size {
            o.insert("tile_size", jnum(t as f64));
        }
        if let Some(s) = &self.strategy {
            o.insert("strategy", jstr(s));
        }
        o.insert("prune", Json::Bool(self.prune));
        o.insert("workers", jnum(self.workers as f64));
        o.insert("batch", jnum(self.batch as f64));
        if let Some(g) = self.gate {
            o.insert("gate", Json::Bool(g));
        }
        if let Some(l) = self.gate_levels {
            o.insert("gate_levels", jnum(l as f64));
        }
        if let Some(t) = self.gate_threshold {
            o.insert("gate_threshold", jnum(t as f64));
        }
        if let Some(pd) = self.plan_delta {
            o.insert("plan_delta", Json::Bool(pd));
        }
        if let Some(a) = self.plan_delta_angle {
            o.insert("plan_delta_angle", jnum(a as f64));
        }
        o.insert("seed", jnum(self.seed as f64));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), &["prune"])
    }

    #[test]
    fn defaults_build() {
        let cfg = ExperimentConfig::default();
        let scene = cfg.build_scene().unwrap();
        assert!(scene.len() > 100);
        assert_eq!(cfg.build_cameras().len(), 3);
        assert_eq!(cfg.build_hw().unwrap().name, "flicker32");
    }

    #[test]
    fn cli_overrides() {
        let a = args(&[
            "simulate",
            "--scene",
            "truck",
            "--resolution",
            "128",
            "--hardware",
            "gscore64",
            "--cat-mode",
            "sparse",
            "--fifo-depth",
            "4",
            "--workers",
            "4",
            "--prune",
        ]);
        let cfg = ExperimentConfig::from_args(&a).unwrap();
        assert_eq!(cfg.scene, "truck");
        assert_eq!(cfg.resolution, 128);
        assert_eq!(cfg.workers, 4);
        assert!(cfg.prune);
        let hw = cfg.build_hw().unwrap();
        assert_eq!(hw.fifo_depth, 4);
        assert_eq!(hw.cat_mode, crate::cat::LeaderMode::UniformSparse);
    }

    #[test]
    fn render_options_thread_strategy_and_tile_size() {
        let a = args(&[
            "render", "--strategy", "obb", "--tile-size", "16", "--workers", "3", "--batch", "4",
        ]);
        let cfg = ExperimentConfig::from_args(&a).unwrap();
        let o = cfg.render_options().unwrap();
        assert_eq!(o.strategy, Strategy::Obb);
        assert_eq!(o.tile_size, 16);
        assert_eq!(o.workers, 3);
        assert_eq!(o.batch, 4);
        // Defaults stay the paper's geometry (batch 0 = artifact width).
        let d = ExperimentConfig::default().render_options().unwrap();
        assert_eq!(d.strategy, Strategy::Aabb);
        assert_eq!(d.tile_size, 16);
        assert_eq!(d.batch, 0);
    }

    #[test]
    fn gate_flags_thread_to_render_options() {
        let a = args(&[
            "render",
            "--gate",
            "on",
            "--gate-levels",
            "1",
            "--gate-threshold",
            "0.0157",
        ]);
        let cfg = ExperimentConfig::from_args(&a).unwrap();
        assert_eq!(cfg.gate, Some(true));
        let o = cfg.render_options().unwrap();
        assert!(o.gate.enabled);
        assert_eq!(o.gate.levels, 1);
        assert!((o.gate.threshold - 0.0157).abs() < 1e-6);
        // Off by default, and `--gate off` parses too.
        let d = ExperimentConfig::default().render_options().unwrap();
        assert!(!d.gate.enabled);
        let off = ExperimentConfig::from_args(&args(&["render", "--gate", "off"])).unwrap();
        assert_eq!(off.gate, Some(false));
        assert!(ExperimentConfig::from_args(&args(&["render", "--gate", "maybe"])).is_err());
    }

    #[test]
    fn plan_delta_flags_thread_to_render_options() {
        let a = args(&["render", "--plan-delta", "on", "--plan-delta-angle", "0.1"]);
        let cfg = ExperimentConfig::from_args(&a).unwrap();
        assert_eq!(cfg.plan_delta, Some(true));
        let o = cfg.render_options().unwrap();
        assert!(o.plan_delta.enabled);
        assert!((o.plan_delta.max_angle - 0.1).abs() < 1e-6);
        // Off by default; `--plan-delta off` parses; junk is an error.
        let d = ExperimentConfig::default().render_options().unwrap();
        assert!(!d.plan_delta.enabled);
        let off = ExperimentConfig::from_args(&args(&["render", "--plan-delta", "off"])).unwrap();
        assert_eq!(off.plan_delta, Some(false));
        assert!(ExperimentConfig::from_args(&args(&["render", "--plan-delta", "maybe"])).is_err());
        // Bad angles are config errors, not silent clamps.
        let bad = ExperimentConfig {
            plan_delta_angle: Some(0.0),
            ..Default::default()
        };
        assert!(bad.render_options().is_err());
        let bad = ExperimentConfig {
            plan_delta_angle: Some(-1.0),
            ..Default::default()
        };
        assert!(bad.render_options().is_err());
    }

    #[test]
    fn precision_flags_thread_to_render_options() {
        use crate::render::precision::PrecisionMode;
        let a = args(&[
            "render",
            "--precision",
            "adaptive",
            "--precision-thresholds",
            "0.5,0.2,fp16",
        ]);
        let cfg = ExperimentConfig::from_args(&a).unwrap();
        let o = cfg.render_options().unwrap();
        assert!(o.precision.is_adaptive());
        match o.precision.mode {
            PrecisionMode::Adaptive { thresholds, floor } => {
                assert_eq!(thresholds.fp32_min, 0.5);
                assert_eq!(thresholds.fp16_min, 0.2);
                assert_eq!(floor, Precision::Fp16);
            }
            _ => unreachable!(),
        }
        // Adaptive leaves the hardware preset's global CTU precision alone.
        assert_eq!(cfg.build_hw().unwrap().cat_precision, Precision::Mixed);
        // Rect shares the threshold vocabulary and the hardware behavior.
        let r = ExperimentConfig::from_args(&args(&[
            "render",
            "--precision",
            "rect",
            "--precision-thresholds",
            "0.5,0.2,fp16",
        ]))
        .unwrap();
        let ro = r.render_options().unwrap();
        assert!(ro.precision.is_rect());
        match ro.precision.mode {
            PrecisionMode::Rect { thresholds, floor } => {
                assert_eq!(thresholds.fp32_min, 0.5);
                assert_eq!(thresholds.fp16_min, 0.2);
                assert_eq!(floor, Precision::Fp16);
            }
            _ => unreachable!(),
        }
        assert_eq!(r.build_hw().unwrap().cat_precision, Precision::Mixed);
        // A global name threads to both the options and the hardware,
        // case-insensitively.
        let g = ExperimentConfig::from_args(&args(&["render", "--precision", "FP16"])).unwrap();
        assert_eq!(
            g.render_options().unwrap().precision,
            PrecisionPolicy::global(Precision::Fp16)
        );
        assert_eq!(g.build_hw().unwrap().cat_precision, Precision::Fp16);
        // Default stays the inert global policy.
        let d = ExperimentConfig::default().render_options().unwrap();
        assert!(!d.precision.is_adaptive());
        assert_eq!(d.precision, PrecisionPolicy::default());
    }

    #[test]
    fn bad_precision_settings_are_errors() {
        // Unknown names are errors listing the valid set, not silent
        // fallbacks — in render options and hardware resolution both.
        let bogus = ExperimentConfig {
            precision: Some("int4".into()),
            ..Default::default()
        };
        let msg = format!("{}", bogus.render_options().unwrap_err());
        assert!(msg.contains("fp32|fp16|fp8|mixed|adaptive|rect"), "{msg}");
        assert!(bogus.build_hw().is_err());
        // Thresholds demand the adaptive mode and a well-formed spec.
        let orphan = ExperimentConfig {
            precision_thresholds: Some("0.6,0.25".into()),
            ..Default::default()
        };
        assert!(orphan.render_options().is_err());
        let malformed = ExperimentConfig {
            precision: Some("adaptive".into()),
            precision_thresholds: Some("0.2,0.6".into()),
            ..Default::default()
        };
        assert!(malformed.render_options().is_err());
    }

    #[test]
    fn bad_gate_settings_are_errors() {
        let levels = ExperimentConfig {
            gate_levels: Some(3),
            ..Default::default()
        };
        assert!(levels.render_options().is_err());
        let thr = ExperimentConfig {
            gate_threshold: Some(0.0),
            ..Default::default()
        };
        assert!(thr.render_options().is_err());
        let thr2 = ExperimentConfig {
            gate_threshold: Some(1.5),
            ..Default::default()
        };
        assert!(thr2.render_options().is_err());
    }

    #[test]
    fn bad_strategy_is_error() {
        let cfg = ExperimentConfig {
            strategy: Some("bogus".into()),
            ..Default::default()
        };
        assert!(cfg.render_options().is_err());
        let zero = ExperimentConfig {
            tile_size: Some(0),
            ..Default::default()
        };
        assert!(zero.render_options().is_err());
    }

    #[test]
    fn bad_hardware_is_error() {
        let a = args(&["x", "--hardware", "bogus"]);
        let cfg = ExperimentConfig::from_args(&a).unwrap();
        assert!(cfg.build_hw().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ExperimentConfig {
            cat_mode: Some("sparse".into()),
            precision: Some("adaptive".into()),
            precision_thresholds: Some("0.5,0.2,fp16".into()),
            fifo_depth: Some(8),
            strategy: Some("obb".into()),
            tile_size: Some(16),
            workers: 3,
            batch: 4,
            gate: Some(true),
            gate_levels: Some(2),
            gate_threshold: Some(0.0078),
            plan_delta: Some(true),
            plan_delta_angle: Some(0.25),
            ..Default::default()
        };
        let dir = std::env::temp_dir().join("flicker_cfg");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, cfg.to_json().pretty()).unwrap();
        let back = ExperimentConfig::from_json_file(&p).unwrap();
        assert_eq!(back.scene, cfg.scene);
        assert_eq!(back.cat_mode, cfg.cat_mode);
        assert_eq!(back.precision, cfg.precision);
        assert_eq!(back.precision_thresholds, cfg.precision_thresholds);
        assert_eq!(back.fifo_depth, cfg.fifo_depth);
        assert_eq!(back.strategy, cfg.strategy);
        assert_eq!(back.tile_size, cfg.tile_size);
        assert_eq!(back.workers, cfg.workers);
        assert_eq!(back.batch, cfg.batch);
        assert_eq!(back.gate, cfg.gate);
        assert_eq!(back.gate_levels, cfg.gate_levels);
        let (a, b) = (back.gate_threshold.unwrap(), cfg.gate_threshold.unwrap());
        assert!((a - b).abs() < 1e-6);
        assert_eq!(back.plan_delta, cfg.plan_delta);
        let (a, b) = (back.plan_delta_angle.unwrap(), cfg.plan_delta_angle.unwrap());
        assert!((a - b).abs() < 1e-6);
    }
}
