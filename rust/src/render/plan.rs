//! `FramePlan`: the staged frame pipeline built once, rendered many times.
//!
//! FLICKER's frame preparation — projection, tile binning, depth sorting —
//! is a pure function of `(scene, camera, options)`. Every consumer that
//! re-renders the same view (quality sweeps over CAT configs, pruning's
//! scoring views, the PJRT backend, the workload extractor) used to redo
//! that work per call. [`FramePlan::build`] runs the stages once and the
//! plan's render/score/extract consumers reuse the intermediates:
//!
//! ```text
//!   build:  project_scene ─► build_tile_lists ─► sort_by_depth
//!                       (splats)          (lists)        (lists, sorted)
//!   render: for each tile: gate ─► mask ─► blend ─► composite    (per consumer)
//!   score:  for each tile: gate ─► mask ─► blend ─► fold partials
//! ```
//!
//! The optional **gate** stage (`opts.gate`, see [`super::pyramid`])
//! rejects (tile, splat) and (quadrant, splat) pairs on conservative
//! contribution bounds before any mask provider or per-pixel work runs;
//! at the default threshold it is lossless and off by default.
//!
//! **Determinism contract.** A plan is immutable after `build`, tiles are
//! independent work units, and every consumer shares the one blending loop
//! (`render_tile`), so repeated renders of one plan — sequential, tile-
//! parallel, or drained through an external work queue like pruning's
//! view×tile scheduler — are bit-identical. Contribution scores accumulate
//! into tile-local list-aligned partial buffers and fold in ascending tile
//! index whether tiles ran on one thread or many.

use super::image::Image;
use super::precision::{self, TileClassMap};
use super::project::{project_scene, Splat, ALPHA_MIN};
use super::pyramid::{GateConfig, TilePyramid};
use super::raster::{
    MaskProvider, MaskSource, RenderOptions, RenderOutput, RenderStats, MINITILE,
};
use super::sort::sort_by_depth;
use super::tile::{build_tile_lists, Rect, TileGrid};
use crate::camera::Camera;
use crate::cat::Precision;
use crate::scene::gaussian::Scene;
use crate::util::pool;
use std::sync::Arc;

/// The reusable frame-preparation product: projected splats, the tile grid,
/// and depth-sorted per-tile splat lists for one `(scene, camera, options)`
/// triple. Build once with [`FramePlan::build`], then render or score any
/// number of times — each render walks the prebuilt lists instead of
/// re-deriving them.
pub struct FramePlan {
    /// Splats surviving frustum culling + EWA projection.
    pub splats: Vec<Splat>,
    /// Tile grid geometry for the target image.
    pub grid: TileGrid,
    /// Depth-sorted splat index list per tile (row-major tile order).
    pub lists: Vec<Vec<u32>>,
    /// The render options the plan was built with. `tile_size` and
    /// `strategy` are baked into `grid`/`lists`; `t_min`, `background`,
    /// and `workers` apply at render time.
    pub opts: RenderOptions,
    /// The camera the plan was prepared for — the pose anchor
    /// [`FramePlan::advance`](crate::render::delta) measures the next
    /// view's step against.
    pub cam: Camera,
    // Per-tile gate pyramids (`Some` ⇔ `opts.gate.active()`). A pure
    // function of the tile grid — camera-invariant — so delta-advanced
    // descendants share this one allocation instead of rebuilding per
    // tile per render.
    pub(crate) pyramids: Option<Arc<Vec<TilePyramid>>>,
}

/// Build the per-tile gate pyramid cache for `grid`, or `None` when the
/// gate is inactive. Pyramid geometry depends only on the tile rects, so
/// one cache serves every render of the plan — and every plan a delta
/// chain derives from it.
pub(crate) fn build_pyramids(grid: &TileGrid, gate: &GateConfig) -> Option<Arc<Vec<TilePyramid>>> {
    if !gate.active() {
        return None;
    }
    Some(Arc::new(
        (0..grid.num_tiles())
            .map(|t| TilePyramid::new(&grid.rect(t), grid.tile))
            .collect(),
    ))
}

impl FramePlan {
    /// Run the preparation stages (project → tile-bin → depth-sort) once.
    ///
    /// # Examples
    ///
    /// ```
    /// use flicker::camera::{Camera, Intrinsics};
    /// use flicker::numeric::linalg::v3;
    /// use flicker::render::plan::FramePlan;
    /// use flicker::render::raster::{RenderOptions, VanillaMasks};
    /// use flicker::scene::synthetic::{generate_scaled, preset};
    ///
    /// let scene = generate_scaled(&preset("truck"), 0.01);
    /// let cam = Camera::look_at(
    ///     Intrinsics::from_fov(64, 64, 1.2),
    ///     v3(0.0, 2.5, -12.0),
    ///     v3(0.0, 0.5, 0.0),
    ///     v3(0.0, 1.0, 0.0),
    /// );
    /// // Build once, render twice (e.g. a config sweep) — bit-identical.
    /// let plan = FramePlan::build(&scene, &cam, &RenderOptions::default());
    /// let a = plan.render(&VanillaMasks, None);
    /// let b = plan.render(&VanillaMasks, None);
    /// assert_eq!(a.image.data, b.image.data);
    /// ```
    pub fn build(scene: &Scene, cam: &Camera, opts: &RenderOptions) -> FramePlan {
        let splats = project_scene(scene, cam);
        let grid = TileGrid::new(cam.intr.width, cam.intr.height, opts.tile_size);
        let mut lists = build_tile_lists(&splats, &grid, opts.strategy);
        for list in &mut lists {
            sort_by_depth(list, &splats);
        }
        let pyramids = build_pyramids(&grid, &opts.gate);
        FramePlan {
            splats,
            grid,
            lists,
            opts: *opts,
            cam: *cam,
            pyramids,
        }
    }

    /// Tile `t`'s gate pyramid, when the gate is active.
    fn pyramid(&self, t: usize) -> Option<&TilePyramid> {
        self.pyramids.as_ref().map(|p| &p[t])
    }

    /// Number of tiles in the plan (== `lists.len()`).
    pub fn num_tiles(&self) -> usize {
        self.lists.len()
    }

    /// Tile `t`'s precision class under `opts.precision`, or `None` when
    /// the policy is `Global` (the inert default — every consumer falls
    /// through to its pre-policy code path, bit for bit). The classifier
    /// is a pure function of the plan (depth-sorted list + tile rect), so
    /// the class never depends on worker count or batch width.
    pub fn tile_class(&self, t: usize) -> Option<Precision> {
        if !self.opts.precision.is_adaptive() {
            return None;
        }
        let e = precision::tile_energy(&self.splats, &self.lists[t], &self.grid.rect(t));
        self.opts.precision.classify(e)
    }

    /// Per-tile precision classes for the whole plan (row-major tile
    /// order), or `None` when the policy is `Global`. Consumers that form
    /// their own work queues (the PJRT executor, the workload extractor)
    /// read this once and index it by tile.
    pub fn tile_classes(&self) -> Option<Vec<Precision>> {
        if !self.opts.precision.is_adaptive() {
            return None;
        }
        Some(
            (0..self.lists.len())
                .map(|t| self.tile_class(t).expect("adaptive policy classes every tile"))
                .collect(),
        )
    }

    /// Tile `t`'s quadrant class map under the rect precision policy, or
    /// `None` for every other mode. Like [`FramePlan::tile_class`], a pure
    /// function of the plan (depth-sorted list + quadrant rects): the map
    /// is identical for any worker count, PJRT batch width, or
    /// delta-advanced plan — the invariance `tests/properties.rs` pins.
    pub fn tile_rect_class(&self, t: usize) -> Option<TileClassMap> {
        if !self.opts.precision.is_rect() {
            return None;
        }
        // The gate's pyramid cache carries the quadrant rects when it
        // exists; rect classing must not depend on the gate switch, so
        // build the (cheap) geometry on demand otherwise.
        let energies = match self.pyramid(t) {
            Some(pyr) => precision::quad_energies(&self.splats, &self.lists[t], pyr.quad_rects()),
            None => {
                let pyr = TilePyramid::new(&self.grid.rect(t), self.grid.tile);
                precision::quad_energies(&self.splats, &self.lists[t], pyr.quad_rects())
            }
        };
        self.opts.precision.classify_quads(&energies)
    }

    /// Per-tile quadrant class maps for the whole plan (row-major tile
    /// order), or `None` unless the policy is `Rect`. The second-level
    /// analog of [`FramePlan::tile_classes`]: the PJRT executor and the
    /// workload extractor read this once and index it by tile.
    pub fn tile_rect_classes(&self) -> Option<Vec<TileClassMap>> {
        if !self.opts.precision.is_rect() {
            return None;
        }
        Some(
            (0..self.lists.len())
                .map(|t| {
                    self.tile_rect_class(t)
                        .expect("rect policy classes every tile")
                })
                .collect(),
        )
    }

    /// The class map the mask-provider selection keys on: adaptive tiles
    /// are uniform maps at their tile class, rect tiles carry their
    /// quadrant map, global policies have none. One helper so rendering
    /// and scoring pick providers identically.
    fn tile_map(&self, t: usize) -> Option<TileClassMap> {
        if self.opts.precision.is_adaptive() {
            self.tile_class(t).map(TileClassMap::Uniform)
        } else {
            self.tile_rect_class(t)
        }
    }

    /// All tiles' provider-selection maps ([`FramePlan::tile_map`] for the
    /// whole plan), or `None` under global policies.
    fn tile_maps(&self) -> Option<Vec<TileClassMap>> {
        if self.opts.precision.is_adaptive() {
            self.tile_classes()
                .map(|cs| cs.into_iter().map(TileClassMap::Uniform).collect())
        } else {
            self.tile_rect_classes()
        }
    }

    /// Frame-level stats skeleton: the per-tile loops only touch the pair
    /// and early-termination counters, so these totals are fixed at build
    /// time. Consumers that drain tiles themselves (PJRT, the view×tile
    /// scoring queue) start from this and absorb per-tile counters.
    pub fn frame_stats(&self) -> RenderStats {
        RenderStats {
            splats: self.splats.len(),
            tile_pairs: self.lists.iter().map(|l| l.len()).sum(),
            pixels: (self.grid.width * self.grid.height) as u64,
            ..Default::default()
        }
    }

    /// Render the planned frame through `source`, optionally accumulating
    /// per-Gaussian contribution scores (Σ T·α, the pruning signal) into
    /// `scores` — indexed by Gaussian id, `scene.len()` long.
    ///
    /// Tiles (and their mask generation) fan across the worker pool when
    /// `self.opts.workers != 1`; images, stats, and scores are
    /// bit-identical for any worker count because every path shares the
    /// blending loop and folds score partials in ascending tile index.
    pub fn render(&self, source: &dyn MaskSource, mut scores: Option<&mut [f32]>) -> RenderOutput {
        let workers = pool::resolve_workers(self.opts.workers).min(self.lists.len().max(1));
        // Adaptive/rect precision needs a per-tile (per-class, or
        // per-quadrant-stitched) mask provider, so classing policies always
        // take the per-tile fan-out below — `map_indexed` runs it
        // sequentially at one worker. Global policies keep the original
        // shared-provider path, bit for bit.
        let maps = self.tile_maps();
        if workers <= 1 && maps.is_none() {
            let mut masks = source.tile_masks();
            return self.render_with(masks.as_mut(), scores.as_deref_mut());
        }
        let ts = self.grid.tile as usize;
        let want_scores = scores.is_some();
        let opts = &self.opts;
        let maps = maps.as_deref();
        let tiles: Vec<(Vec<f32>, Vec<f32>, RenderStats)> =
            pool::map_indexed(self.lists.len(), workers, |t| {
                let run = self.run_tile(t, source, want_scores, maps.map(|m| m[t]));
                // Composite over background into a w×h tile pixel block.
                let mut pixels = vec![0.0f32; run.w * run.h * 3];
                for py in 0..run.h {
                    for px in 0..run.w {
                        let idx = py * ts + px;
                        let tr = run.trans[idx];
                        let c = run.color[idx];
                        let o = (py * run.w + px) * 3;
                        pixels[o] = c[0] + tr * opts.background[0];
                        pixels[o + 1] = c[1] + tr * opts.background[1];
                        pixels[o + 2] = c[2] + tr * opts.background[2];
                    }
                }
                (pixels, run.partial, run.stats)
            });

        let mut img = Image::new(self.grid.width, self.grid.height);
        let mut stats = self.frame_stats();
        for (t, (pixels, partial, tile_stats)) in tiles.iter().enumerate() {
            stats.absorb(tile_stats);
            if let Some(sc) = scores.as_deref_mut() {
                fold_tile_scores(sc, &self.splats, &self.lists[t], partial);
            }
            let rect = self.grid.rect(t);
            let x_lo = rect.x0 as u32;
            let y_lo = rect.y0 as u32;
            let w = (self.grid.width - x_lo).min(self.grid.tile) as usize;
            let h = (self.grid.height - y_lo).min(self.grid.tile) as usize;
            for py in 0..h {
                for px in 0..w {
                    let o = (py * w + px) * 3;
                    img.set(
                        x_lo + px as u32,
                        y_lo + py as u32,
                        [pixels[o], pixels[o + 1], pixels[o + 2]],
                    );
                }
            }
        }
        RenderOutput { image: img, stats }
    }

    /// Render the planned frame sequentially through a caller-owned
    /// (possibly stateful) mask provider — the CAT-instrumentation path:
    /// callers keep the provider and read its counters afterwards.
    ///
    /// Scores accumulate through the same per-tile partial-sum fold as the
    /// parallel path, so the result is bit-identical to [`FramePlan::render`]
    /// at any worker count.
    pub fn render_with(
        &self,
        masks: &mut dyn MaskProvider,
        mut contributions: Option<&mut [f32]>,
    ) -> RenderOutput {
        let (splats, grid, lists, opts) = (&self.splats, &self.grid, &self.lists, &self.opts);
        let mut img = Image::new(grid.width, grid.height);
        let mut stats = self.frame_stats();
        let ts = grid.tile as usize;
        // Per-tile scratch, reused across tiles (no allocation in the loop).
        let mut trans = vec![1.0f32; ts * ts];
        let mut color = vec![[0.0f32; 3]; ts * ts];
        let scoring = contributions.is_some();
        let mut partial: Vec<f32> = Vec::new();

        for (t, list) in lists.iter().enumerate() {
            let rect = grid.rect(t);
            if scoring {
                partial.clear();
                partial.resize(list.len(), 0.0);
            }
            let (w, h) = render_tile(
                splats,
                list,
                &rect,
                grid,
                opts,
                self.pyramid(t),
                masks,
                &mut trans,
                &mut color,
                if scoring { Some(partial.as_mut_slice()) } else { None },
                &mut stats,
            );
            if let Some(sc) = contributions.as_deref_mut() {
                fold_tile_scores(sc, splats, list, &partial);
            }
            // Composite over background.
            let x_lo = rect.x0 as u32;
            let y_lo = rect.y0 as u32;
            for py in 0..h {
                for px in 0..w {
                    let idx = py * ts + px;
                    let tr = trans[idx];
                    let c = color[idx];
                    img.set(
                        x_lo + px as u32,
                        y_lo + py as u32,
                        [
                            c[0] + tr * opts.background[0],
                            c[1] + tr * opts.background[1],
                            c[2] + tr * opts.background[2],
                        ],
                    );
                }
            }
        }
        RenderOutput { image: img, stats }
    }

    /// Run the blending loop for one tile and return its list-aligned
    /// contribution partials (Σ T·α of `lists[t][li]` over the tile's
    /// pixels) plus the tile's workload counters — without compositing any
    /// pixels. This is the drain unit of pruning's flattened view×tile
    /// work queue: any worker can score any `(plan, tile)` pair, and the
    /// caller folds partials in a fixed order via [`FramePlan::fold_scores`].
    pub fn score_tile(&self, t: usize, source: &dyn MaskSource) -> (Vec<f32>, RenderStats) {
        let run = self.run_tile(t, source, true, self.tile_map(t));
        (run.partial, run.stats)
    }

    /// The one per-tile drain shared by the parallel render fan-out and
    /// [`FramePlan::score_tile`]: fresh provider from `source`, fresh
    /// tile-local scratch, one [`render_tile`] call. Keeping a single
    /// entry keeps the rendering and scoring paths structurally identical
    /// — the bit-identity contract cannot drift between them.
    ///
    /// Provider selection honors the class map: uniform maps take the
    /// exact single-class path (`tile_masks_at`), so a rect-mode tile
    /// whose quadrants agree renders bit-identically to the per-tile
    /// policy at that class; only genuinely mixed tiles pay for the
    /// per-quadrant stitched provider.
    fn run_tile(
        &self,
        t: usize,
        source: &dyn MaskSource,
        want_scores: bool,
        map: Option<TileClassMap>,
    ) -> TileRun {
        let ts = self.grid.tile as usize;
        let mut masks = match map {
            Some(TileClassMap::Uniform(c)) => source.tile_masks_at(c),
            Some(TileClassMap::Mixed(quads)) => source.tile_masks_rect(self.grid.tile, quads),
            None => source.tile_masks(),
        };
        let mut trans = vec![1.0f32; ts * ts];
        let mut color = vec![[0.0f32; 3]; ts * ts];
        let mut stats = RenderStats::default();
        // Private per-tile score partials, aligned to this tile's list.
        let mut partial = vec![0.0f32; if want_scores { self.lists[t].len() } else { 0 }];
        let rect = self.grid.rect(t);
        let (w, h) = render_tile(
            &self.splats,
            &self.lists[t],
            &rect,
            &self.grid,
            &self.opts,
            self.pyramid(t),
            masks.as_mut(),
            &mut trans,
            &mut color,
            if want_scores { Some(partial.as_mut_slice()) } else { None },
            &mut stats,
        );
        TileRun {
            trans,
            color,
            partial,
            stats,
            w,
            h,
        }
    }

    /// The plan's per-tile lists after the level-1 (whole-tile) coarse
    /// gate — for consumers that ship splat **lists** to a backend
    /// instead of masking pixels (the PJRT executor). Returns `None` when
    /// the gate is inactive; otherwise the filtered lists plus the number
    /// of rejected (tile, splat) pairs. At the default threshold the
    /// removed entries are exactly pairs the fine kernel would have
    /// zeroed (its α < 1/255 clamp), so rendering the gated lists is
    /// bit-identical to rendering `self.lists`.
    pub fn gated_lists(&self) -> Option<(Vec<Vec<u32>>, u64)> {
        if !self.opts.gate.active() {
            return None;
        }
        let pyramids = self
            .pyramids
            .as_ref()
            .expect("gate active ⇒ pyramids built (build/advance invariant)");
        let mut rejected = 0u64;
        let mut out = Vec::with_capacity(self.lists.len());
        for (t, list) in self.lists.iter().enumerate() {
            let pyr = &pyramids[t];
            let mut kept = Vec::with_capacity(list.len());
            for &si in list {
                if pyr.rejects_tile(&self.splats[si as usize], &self.opts.gate) {
                    rejected += 1;
                } else {
                    kept.push(si);
                }
            }
            out.push(kept);
        }
        Some((out, rejected))
    }

    /// Fold tile `t`'s list-aligned contribution partials into the global
    /// per-Gaussian score array (indexed by Gaussian id). Callers must fold
    /// in ascending tile index (and, across plans, ascending view index) —
    /// the fixed reduce order that keeps scoring bit-identical to the
    /// sequential pass for any worker count.
    pub fn fold_scores(&self, t: usize, partial: &[f32], scores: &mut [f32]) {
        fold_tile_scores(scores, &self.splats, &self.lists[t], partial);
    }
}

/// One tile's blending products: tile-local transmittance/color scratch,
/// list-aligned contribution partials, and workload counters (valid region
/// `w × h` — edge tiles are cropped by the image bounds).
struct TileRun {
    trans: Vec<f32>,
    color: Vec<[f32; 3]>,
    partial: Vec<f32>,
    stats: RenderStats,
    w: usize,
    h: usize,
}

/// Fold one tile's list-aligned contribution partials into the global
/// per-Gaussian score array (indexed by Gaussian id), iterating in list
/// order. Sequential and parallel scoring both reduce through this helper
/// in ascending tile index, which is what makes the accumulated scores
/// bit-identical for any worker count.
fn fold_tile_scores(scores: &mut [f32], splats: &[Splat], list: &[u32], partial: &[f32]) {
    for (li, &si) in list.iter().enumerate() {
        scores[splats[si as usize].id as usize] += partial[li];
    }
}

/// Render one tile's depth-sorted list into tile-local scratch buffers
/// (`trans`/`color`, `tile_size²` entries, reset on entry). Returns the
/// valid `(w, h)` region — edge tiles are cropped by the image bounds.
/// This is the one blending loop shared by every consumer (sequential,
/// tile-parallel, and the view×tile scoring queue), which is what makes
/// them bit-identical.
///
/// `contributions`, when present, is a **tile-local** partial-sum buffer
/// aligned to `list` (entry `li` accumulates Σ T·α of splat `list[li]`
/// over this tile's pixels). Callers fold partials into the global
/// per-Gaussian score array via [`fold_tile_scores`] in tile order — the
/// fixed reduce order that keeps parallel scoring bit-identical to the
/// sequential pass.
#[allow(clippy::too_many_arguments)]
fn render_tile(
    splats: &[Splat],
    list: &[u32],
    rect: &Rect,
    grid: &TileGrid,
    opts: &RenderOptions,
    pyramid: Option<&TilePyramid>,
    masks: &mut dyn MaskProvider,
    trans: &mut [f32],
    color: &mut [[f32; 3]],
    mut contributions: Option<&mut [f32]>,
    stats: &mut RenderStats,
) -> (usize, usize) {
    let ts = grid.tile as usize;
    let mt_cols = grid.tile.div_ceil(MINITILE) as usize;
    let x_lo = rect.x0 as u32;
    let y_lo = rect.y0 as u32;
    let w = (grid.width - x_lo).min(grid.tile) as usize;
    let h = (grid.height - y_lo).min(grid.tile) as usize;
    trans[..ts * ts].fill(1.0);
    for c in color.iter_mut() {
        *c = [0.0; 3];
    }
    let mut active = (w * h) as u32;
    // Coarse-to-fine gate (render::pyramid): the plan-owned pyramid for
    // this tile (`Some` ⇔ the gate is active), consulted per splat ahead
    // of mask generation. Inactive ⇒ the pre-gate code path, bit for bit.

    'splat_loop: for (li, &si) in list.iter().enumerate() {
        let s = &splats[si as usize];
        let mask = match pyramid {
            Some(pyr) => {
                stats.gate_tile_tested += 1;
                let d = pyr.gate(s, &opts.gate);
                if d.tile_rejected {
                    stats.gate_tile_rejected += 1;
                    continue;
                }
                stats.splats_submitted += 1;
                stats.gate_quad_tested += d.quads_tested as u64;
                stats.gate_quad_rejected += d.quads_rejected as u64;
                masks.mask_gated(rect, s, d.quad_mask) & pyr.minitile_mask(d.quad_mask)
            }
            None => {
                stats.splats_submitted += 1;
                masks.mask(rect, s)
            }
        };
        if mask == 0 {
            continue;
        }
        // Hot-loop locals (§Perf): hoist splat fields and precompute the
        // Eq.-2 threshold so the (majority) sub-threshold pixels skip the
        // exp() entirely: α = o·e^{−E} ≥ 1/255 ⇔ E ≤ ln(255·o).
        let (ca, cb, cc) = (s.conic.a, s.conic.b, s.conic.c);
        let (mx, my) = (s.mean.x, s.mean.y);
        let opacity = s.opacity;
        let e_max = (255.0 * opacity).max(1e-12).ln();
        let col = s.color;
        for py in 0..h {
            let gy = y_lo as f32 + py as f32 + 0.5;
            let dy = gy - my;
            let half_cc_dy2 = 0.5 * cc * dy * dy;
            let cb_dy = cb * dy;
            let mt_row = py / MINITILE as usize;
            for px in 0..w {
                let mt = mt_row * mt_cols + px / MINITILE as usize;
                if mask & (1 << mt) == 0 {
                    continue;
                }
                let idx = py * ts + px;
                let t_cur = trans[idx];
                if t_cur < opts.t_min {
                    continue;
                }
                stats.pairs_tested += 1;
                let gx = x_lo as f32 + px as f32 + 0.5;
                let dx = gx - mx;
                let e = 0.5 * ca * dx * dx + half_cc_dy2 + cb_dy * dx;
                if e >= e_max || e < 0.0 {
                    continue; // α below 1/255 — no exp needed
                }
                let a = (opacity * (-e).exp()).min(0.999);
                if a < ALPHA_MIN {
                    continue;
                }
                stats.pairs_blended += 1;
                let wgt = a * t_cur;
                color[idx][0] += wgt * col[0];
                color[idx][1] += wgt * col[1];
                color[idx][2] += wgt * col[2];
                if let Some(sc) = contributions.as_deref_mut() {
                    sc[li] += wgt;
                }
                let t_new = t_cur * (1.0 - a);
                trans[idx] = t_new;
                if t_new < opts.t_min {
                    active -= 1;
                    if active == 0 {
                        stats.tiles_early_terminated += 1;
                        break 'splat_loop;
                    }
                }
            }
        }
    }
    (w, h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::v3;
    use crate::render::raster::{render, render_masked, AllOnes, VanillaMasks};
    use crate::scene::synthetic::{generate_scaled, preset};

    fn cam(px: u32) -> Camera {
        Camera::look_at(
            Intrinsics::from_fov(px, px, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        )
    }

    #[test]
    fn plan_matches_oneshot_wrappers_bitwise() {
        let scene = generate_scaled(&preset("truck"), 0.01);
        let c = cam(96);
        let opts = RenderOptions::default();
        let oneshot = render(&scene, &c, &opts);
        let plan = FramePlan::build(&scene, &c, &opts);
        let planned = plan.render(&VanillaMasks, None);
        assert_eq!(oneshot.image.data, planned.image.data);
        assert_eq!(oneshot.stats.pairs_tested, planned.stats.pairs_tested);
        assert_eq!(oneshot.stats.tile_pairs, planned.stats.tile_pairs);
    }

    #[test]
    fn plan_reuse_is_bit_stable() {
        let scene = generate_scaled(&preset("garden"), 0.01);
        let c = cam(96);
        let plan = FramePlan::build(&scene, &c, &RenderOptions::default());
        let a = plan.render(&VanillaMasks, None);
        let b = plan.render(&VanillaMasks, None);
        assert_eq!(a.image.data, b.image.data);
        assert_eq!(a.stats.pairs_blended, b.stats.pairs_blended);
    }

    #[test]
    fn scored_parallel_matches_sequential_bitwise() {
        let scene = generate_scaled(&preset("truck"), 0.01);
        let c = cam(96);
        // Sequential reference: render_masked folds the same per-tile
        // partial sums in tile order.
        let mut seq = vec![0.0f32; scene.len()];
        let opts = RenderOptions::default();
        let seq_out = render_masked(&scene, &c, &opts, &mut AllOnes, Some(&mut seq));
        assert!(seq.iter().any(|&s| s > 0.0), "scene must contribute");
        for workers in [2, 4, 0] {
            let mut par = vec![0.0f32; scene.len()];
            let popts = RenderOptions {
                workers,
                ..RenderOptions::default()
            };
            let plan = FramePlan::build(&scene, &c, &popts);
            let par_out = plan.render(&VanillaMasks, Some(&mut par));
            let seq_bits: Vec<u32> = seq.iter().map(|s| s.to_bits()).collect();
            let par_bits: Vec<u32> = par.iter().map(|s| s.to_bits()).collect();
            assert_eq!(seq_bits, par_bits, "workers={workers}");
            assert_eq!(seq_out.image.data, par_out.image.data, "workers={workers}");
            assert_eq!(seq_out.stats.pairs_blended, par_out.stats.pairs_blended);
        }
    }

    #[test]
    fn scoring_does_not_change_the_image() {
        let scene = generate_scaled(&preset("garden"), 0.01);
        let c = cam(96);
        let opts = RenderOptions {
            workers: 0,
            ..RenderOptions::default()
        };
        let plan = FramePlan::build(&scene, &c, &opts);
        let plain = plan.render(&VanillaMasks, None);
        let mut scores = vec![0.0f32; scene.len()];
        let scored = plan.render(&VanillaMasks, Some(&mut scores));
        assert_eq!(plain.image.data, scored.image.data);
        assert_eq!(plain.stats.pairs_tested, scored.stats.pairs_tested);
    }

    #[test]
    fn gated_render_is_bitwise_identical_and_cuts_submissions() {
        use crate::render::pyramid::GateConfig;
        let scene = generate_scaled(&preset("garden"), 0.01);
        let c = cam(96);
        let off = FramePlan::build(&scene, &c, &RenderOptions::default());
        let on = FramePlan::build(
            &scene,
            &c,
            &RenderOptions {
                gate: GateConfig::on(),
                ..RenderOptions::default()
            },
        );
        let a = off.render(&VanillaMasks, None);
        let b = on.render(&VanillaMasks, None);
        // Lossless at the default threshold: pixels and blends identical,
        // strictly less per-pixel testing.
        assert_eq!(a.image.data, b.image.data);
        assert_eq!(a.stats.pairs_blended, b.stats.pairs_blended);
        assert!(b.stats.pairs_tested <= a.stats.pairs_tested);
        // Counter consistency: every gate-tested list entry is either
        // submitted or tile-rejected; early-terminated tiles may skip the
        // tail of their lists, gate included.
        assert_eq!(
            b.stats.splats_submitted + b.stats.gate_tile_rejected,
            b.stats.gate_tile_tested
        );
        assert!(b.stats.gate_tile_tested <= b.stats.tile_pairs as u64);
        if b.stats.tiles_early_terminated == 0 {
            assert_eq!(b.stats.gate_tile_tested, b.stats.tile_pairs as u64);
        }
        assert!(b.stats.gate_tile_rejected > 0, "gate never fired");
        assert!(b.stats.gate_quad_rejected <= b.stats.gate_quad_tested);
        // Ungated renders submit everything they process and never touch
        // gate counters.
        assert!(a.stats.splats_submitted <= a.stats.tile_pairs as u64);
        assert_eq!(a.stats.gate_tile_tested, 0);
        // gated_lists scans full lists (no early termination), so its
        // reject count can only meet or exceed the render's.
        let (lists, rejected) = on.gated_lists().unwrap();
        assert!(rejected >= b.stats.gate_tile_rejected);
        let kept: usize = lists.iter().map(|l| l.len()).sum();
        assert_eq!(kept as u64 + rejected, b.stats.tile_pairs as u64);
        if b.stats.tiles_early_terminated == 0 {
            assert_eq!(rejected, b.stats.gate_tile_rejected);
            assert_eq!(kept as u64, b.stats.splats_submitted);
        }
        assert!(off.gated_lists().is_none());
    }

    #[test]
    fn score_tile_drain_matches_full_render() {
        // Draining tiles one by one through score_tile + fold_scores (the
        // view×tile queue's unit) must reproduce the full render's scores.
        let scene = generate_scaled(&preset("truck"), 0.01);
        let c = cam(96);
        let plan = FramePlan::build(&scene, &c, &RenderOptions::default());
        let mut full = vec![0.0f32; scene.len()];
        let full_out = plan.render(&VanillaMasks, Some(&mut full));
        let mut drained = vec![0.0f32; scene.len()];
        let mut stats = plan.frame_stats();
        for t in 0..plan.num_tiles() {
            let (partial, tstats) = plan.score_tile(t, &VanillaMasks);
            plan.fold_scores(t, &partial, &mut drained);
            stats.absorb(&tstats);
        }
        let a: Vec<u32> = full.iter().map(|s| s.to_bits()).collect();
        let b: Vec<u32> = drained.iter().map(|s| s.to_bits()).collect();
        assert_eq!(a, b);
        assert_eq!(full_out.stats.pairs_tested, stats.pairs_tested);
        assert_eq!(full_out.stats.pairs_blended, stats.pairs_blended);
        assert_eq!(
            full_out.stats.tiles_early_terminated,
            stats.tiles_early_terminated
        );
    }
}
