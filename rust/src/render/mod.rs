//! Functional 3DGS rendering pipeline (golden model): projection, tiling,
//! depth sort, the staged [`plan::FramePlan`] pipeline, reference
//! rasterizer entry points, framebuffer, and quality metrics.

pub mod delta;
pub mod image;
pub mod metrics;
pub mod plan;
pub mod precision;
pub mod project;
pub mod pyramid;
pub mod raster;
pub mod sort;
pub mod tile;
