//! Functional 3DGS rendering pipeline (golden model): projection, tiling,
//! depth sort, reference rasterizer, framebuffer, and quality metrics.

pub mod image;
pub mod metrics;
pub mod project;
pub mod raster;
pub mod sort;
pub mod tile;
