//! Rasterizer entry points and mask-provider contracts (paper Step (3)).
//!
//! The actual staged pipeline — project → tile-bin → depth-sort → blend —
//! lives in [`super::plan::FramePlan`]; this module holds the shared types
//! (`RenderOptions`, `RenderStats`, the mask-provider traits) and two thin
//! one-shot wrappers ([`render`], [`render_masked`]) that build a plan and
//! render it once. Consumers that re-render one view (config sweeps,
//! scoring, the PJRT backend) should build a `FramePlan` and reuse it.
//!
//! Splat-major alpha blending within each tile follows the vanilla 3DGS
//! kernel semantics exactly: per pixel, iterate the depth-sorted tile list,
//! skip Gaussians with α < 1/255, accumulate color with transmittance, and
//! stop when transmittance drops below `t_min` ("early termination").
//!
//! The rasterizer accepts an optional **mini-tile mask provider** so the
//! same code path renders: vanilla (mask = all ones), GSCore-style
//! OBB-filtered lists, or FLICKER's Mini-Tile CAT (mask from `crate::cat`).
//! It also optionally accumulates per-Gaussian contribution scores (used by
//! pruning) and tracks the per-pixel workload counters behind paper Fig. 4.
//!
//! **Determinism contract.** Tiles are independent work units and share one
//! blending loop between the sequential and parallel paths, so images are
//! bit-identical for any worker count. Contribution scores obey the same
//! contract: each tile accumulates into a private list-aligned partial
//! buffer, and partials are reduced into the global per-Gaussian array in
//! ascending tile index, whether tiles ran on one thread or many.

use super::delta::DeltaConfig;
use super::image::Image;
use super::plan::FramePlan;
use super::precision::PrecisionPolicy;
use super::project::Splat;
use super::pyramid::{GateConfig, TilePyramid};
use super::tile::{Rect, Strategy};
use crate::camera::Camera;
use crate::cat::Precision;
use crate::scene::gaussian::Scene;

/// Mini-tile edge in pixels (paper: 4×4 mini-tiles inside 16×16 tiles).
pub const MINITILE: u32 = 4;

/// Rendering configuration.
#[derive(Clone, Copy, Debug)]
pub struct RenderOptions {
    /// Tile edge in pixels (paper: 16×16).
    pub tile_size: u32,
    /// Tile-intersection strategy (AABB or OBB).
    pub strategy: Strategy,
    /// Transmittance threshold for early termination (3DGS: 1e-4).
    pub t_min: f32,
    /// Background color composited under the residual transmittance.
    pub background: [f32; 3],
    /// Worker threads for the tile fan-out (0 = auto, 1 = sequential).
    /// Tiles are independent, so any value yields bit-identical images.
    pub workers: usize,
    /// Tiles per PJRT dispatch: 0 = the batched artifact's full
    /// `n_batch` width (best fill rate), 1 = the monomorphic single-tile
    /// artifact (one `exec_f32` per tile-chunk, no batch padding).
    /// Intermediate values still ship `n_batch`-wide tensors with fewer
    /// real slots — they exist for the differential test matrix, not as
    /// a performance setting. Only the `Pjrt` backend reads it; rendered
    /// pixels are identical for every setting (bit-identical under the
    /// stub-interpreted artifacts, enforced in CI).
    pub batch: usize,
    /// Coarse-to-fine contribution gate (`render::pyramid`): whole-tile
    /// and quadrant rejection ahead of the CAT leader tests and the
    /// per-pixel loop. Off by default; at the default threshold (1/255)
    /// enabling it is lossless — bit-identical images with fewer
    /// submitted splats.
    pub gate: GateConfig,
    /// Temporal plan deltas (`render::delta`): when enabled, the
    /// `Session` plan cache advances plans from already-built neighbor
    /// views within `plan_delta.max_angle` instead of cold-building.
    /// Off by default; advanced plans are bitwise identical to cold
    /// builds, so this is purely a preparation-cost knob.
    pub plan_delta: DeltaConfig,
    /// Per-tile CTU precision policy (`render::precision`). The default
    /// (`Global(Mixed)`) is inert — global precision keeps flowing through
    /// `cat::CatConfig`/`sim::HwConfig` exactly as before, bitwise.
    /// `Adaptive` classes every tile by its absorbed-energy bound before
    /// rendering; classes are identical for any worker count or PJRT
    /// batch width.
    pub precision: PrecisionPolicy,
}

impl Default for RenderOptions {
    fn default() -> Self {
        RenderOptions {
            tile_size: 16,
            strategy: Strategy::Aabb,
            t_min: 1e-4,
            background: [0.0, 0.0, 0.0],
            workers: 1,
            batch: 0,
            gate: GateConfig::default(),
            plan_delta: DeltaConfig::default(),
            precision: PrecisionPolicy::default(),
        }
    }
}

/// Workload counters (inputs to Fig. 4 and the simulator's workload trace).
#[derive(Clone, Debug, Default)]
pub struct RenderStats {
    /// Splats surviving projection/culling.
    pub splats: usize,
    /// Σ per-tile list lengths ("duplicated Gaussians", Fig. 4 right).
    pub tile_pairs: usize,
    /// Per-pixel α evaluations attempted (pixel × splat pairs entering Eq. 1).
    pub pairs_tested: u64,
    /// Pairs that actually blended (α ≥ 1/255 and pixel still active).
    pub pairs_blended: u64,
    /// Pixels rendered.
    pub pixels: u64,
    /// Tiles whose loop ended early on full opacity.
    pub tiles_early_terminated: usize,
    /// (tile, splat) list entries that reached the fine pipeline — i.e.
    /// survived the coarse gate. Equals `tile_pairs` when gating is off
    /// (minus any list tail skipped by early-terminated tiles); the gating
    /// benches track the cut in this number.
    pub splats_submitted: u64,
    /// (tile, splat) pairs offered to the level-1 (whole-tile) gate.
    /// At most `tile_pairs`: a tile that saturates to full opacity stops
    /// consuming its list, gate included.
    pub gate_tile_tested: u64,
    /// Pairs the level-1 gate rejected. The invariant `splats_submitted +
    /// gate_tile_rejected == gate_tile_tested` always holds when the gate
    /// ran (with equality to `tile_pairs` when no tile early-terminated).
    pub gate_tile_rejected: u64,
    /// (quadrant, splat) pairs offered to the level-2 gate.
    pub gate_quad_tested: u64,
    /// Quadrant pairs the level-2 gate rejected.
    pub gate_quad_rejected: u64,
}

impl RenderStats {
    /// Average Gaussians *processed per pixel* — the paper's Fig. 4 metric.
    pub fn per_pixel_tested(&self) -> f64 {
        self.pairs_tested as f64 / self.pixels.max(1) as f64
    }

    /// Average Gaussians *blended per pixel* (pairs that passed the α gate).
    pub fn per_pixel_blended(&self) -> f64 {
        self.pairs_blended as f64 / self.pixels.max(1) as f64
    }

    /// Fraction of (tile, splat) pairs removed by the whole-tile gate
    /// (level 1) — the coarse analog of [`crate::cat::CatStats`]'s
    /// `stage1_reject_rate`.
    pub fn gate_tile_reject_rate(&self) -> f64 {
        self.gate_tile_rejected as f64 / self.gate_tile_tested.max(1) as f64
    }

    /// Fraction of (quadrant, splat) pairs removed by the quadrant gate
    /// (level 2), among pairs that survived level 1.
    pub fn gate_quad_reject_rate(&self) -> f64 {
        self.gate_quad_rejected as f64 / self.gate_quad_tested.max(1) as f64
    }

    /// Fold another tile's counters into this one. Integer sums are
    /// order-independent, so parallel tile stats match sequential exactly.
    pub fn absorb(&mut self, other: &RenderStats) {
        self.splats += other.splats;
        self.tile_pairs += other.tile_pairs;
        self.pairs_tested += other.pairs_tested;
        self.pairs_blended += other.pairs_blended;
        self.pixels += other.pixels;
        self.tiles_early_terminated += other.tiles_early_terminated;
        self.splats_submitted += other.splats_submitted;
        self.gate_tile_tested += other.gate_tile_tested;
        self.gate_tile_rejected += other.gate_tile_rejected;
        self.gate_quad_tested += other.gate_quad_tested;
        self.gate_quad_rejected += other.gate_quad_rejected;
    }
}

/// Mini-tile mask provider: given a tile rect and a splat, return one bit per
/// mini-tile (row-major, bit 0 = top-left) saying whether the splat must be
/// processed by that mini-tile's pixels. `u32` leaves room for tiles up to
/// 16 mini-tiles (16×16 px tile → 16 bits).
pub trait MaskProvider {
    /// Mini-tile bits for `splat` within `tile` (1 = process).
    fn mask(&mut self, tile: &Rect, splat: &Splat) -> u32;

    /// Like [`MaskProvider::mask`], with the coarse gate's surviving
    /// quadrants as a hint (bit `q = row·2 + col`, [TL, TR, BL, BR] —
    /// `render::pyramid`'s order). Providers that test per sub-tile (the
    /// CAT engine) skip the dead quadrants' work; the default ignores the
    /// hint. Callers AND the result with the surviving quadrants'
    /// mini-tile bits, so the hint can only remove work, never pixels.
    fn mask_gated(&mut self, tile: &Rect, splat: &Splat, quad_live: u8) -> u32 {
        let _ = quad_live;
        self.mask(tile, splat)
    }

    /// Number of mini-tile columns for a tile of `tile_size`.
    fn minitiles_per_row(&self, tile_size: u32) -> u32 {
        tile_size.div_ceil(MINITILE)
    }
}

/// Vanilla: every mini-tile processes every listed splat.
pub struct AllOnes;

impl MaskProvider for AllOnes {
    fn mask(&mut self, _tile: &Rect, _splat: &Splat) -> u32 {
        u32::MAX
    }
}

/// Thread-safe factory handing each tile worker its own [`MaskProvider`].
///
/// Providers may be stateful (caches, counters), but the mask bits must be
/// a pure function of `(tile, splat)` — that is what keeps tile-parallel
/// rendering bit-identical to the sequential loop. `cat::CatConfig`
/// implements this by building a fresh `CatEngine` per tile, so CAT mask
/// generation fans across the pool together with rasterization.
pub trait MaskSource: Sync {
    /// Hand out a fresh per-tile mask provider for one worker.
    fn tile_masks(&self) -> Box<dyn MaskProvider + '_>;

    /// Hand out a provider for one tile of the given precision class —
    /// the adaptive-precision hook. The default ignores the class (mask
    /// sources without a precision datapath, like [`VanillaMasks`], are
    /// class-blind); `cat::CatConfig` overrides it to build its per-tile
    /// `CatEngine` at the tile's class instead of the config's global
    /// precision.
    fn tile_masks_at(&self, class: Precision) -> Box<dyn MaskProvider + '_> {
        let _ = class;
        self.tile_masks()
    }

    /// Hand out a provider for one *mixed-class* tile under the rect
    /// precision policy: one [`MaskSource::tile_masks_at`] provider per
    /// distinct quadrant class (so `cat::CatConfig` runs a `CatEngine` per
    /// class — the engine's one-entry cache is precision-specific), with
    /// each class's mask bits stitched back onto its own quadrants'
    /// mini-tiles. The stitched bits cover each mini-tile exactly once
    /// (the quadrant masks partition the tile), so a uniform class map
    /// reproduces the single-provider mask bit-for-bit — which is why the
    /// render paths only call this for genuinely mixed tiles.
    fn tile_masks_rect(
        &self,
        tile_size: u32,
        classes: [Precision; 4],
    ) -> Box<dyn MaskProvider + '_> {
        let mut providers: Vec<Box<dyn MaskProvider + '_>> = Vec::new();
        let mut class_of: Vec<Precision> = Vec::new();
        let mut by_quad = [0usize; 4];
        for (q, &c) in classes.iter().enumerate() {
            by_quad[q] = match class_of.iter().position(|&seen| seen == c) {
                Some(i) => i,
                None => {
                    class_of.push(c);
                    providers.push(self.tile_masks_at(c));
                    class_of.len() - 1
                }
            };
        }
        Box::new(RectStitchMasks {
            tile_size,
            by_quad,
            providers,
            pyramid: None,
        })
    }
}

/// Per-quadrant mask stitching for mixed-class tiles (rect precision
/// mode): each quadrant's class provider contributes only the mini-tile
/// bits of its own quadrants. Built by [`MaskSource::tile_masks_rect`];
/// like every provider it serves a single tile, so the quadrant geometry
/// (a [`TilePyramid`]) is built lazily on first use and reused.
struct RectStitchMasks<'a> {
    tile_size: u32,
    /// Quadrant → index into `providers` ([TL, TR, BL, BR] order).
    by_quad: [usize; 4],
    /// One provider per distinct class, in first-quadrant-seen order.
    providers: Vec<Box<dyn MaskProvider + 'a>>,
    pyramid: Option<TilePyramid>,
}

impl RectStitchMasks<'_> {
    /// Quadrant mini-tile bits and per-provider quadrant ownership for
    /// `tile`, (re)building the pyramid when the tile changes.
    fn geometry(&mut self, tile: &Rect) -> ([u32; 4], [u8; 4]) {
        if self.pyramid.as_ref().map(|p| p.tile() != tile).unwrap_or(true) {
            self.pyramid = Some(TilePyramid::new(tile, self.tile_size));
        }
        let p = self.pyramid.as_ref().unwrap();
        let bits = std::array::from_fn(|q| p.quad_minitile_mask(q));
        let mut owned = [0u8; 4];
        for q in 0..4 {
            owned[self.by_quad[q]] |= 1 << q;
        }
        (bits, owned)
    }

    fn stitch(&mut self, tile: &Rect, splat: &Splat, quad_live: u8, gated: bool) -> u32 {
        let (bits, owned) = self.geometry(tile);
        let mut out = 0u32;
        for (pi, provider) in self.providers.iter_mut().enumerate() {
            let live = owned[pi] & quad_live;
            if live == 0 {
                continue;
            }
            let mut region = 0u32;
            for q in 0..4 {
                if live & (1 << q) != 0 {
                    region |= bits[q];
                }
            }
            let mask = if gated {
                provider.mask_gated(tile, splat, live)
            } else {
                provider.mask(tile, splat)
            };
            out |= mask & region;
        }
        out
    }
}

impl MaskProvider for RectStitchMasks<'_> {
    fn mask(&mut self, tile: &Rect, splat: &Splat) -> u32 {
        self.stitch(tile, splat, 0xF, false)
    }

    fn mask_gated(&mut self, tile: &Rect, splat: &Splat, quad_live: u8) -> u32 {
        self.stitch(tile, splat, quad_live, true)
    }
}

/// Mask source for the vanilla pipeline: every mini-tile processes every
/// listed splat.
pub struct VanillaMasks;

impl MaskSource for VanillaMasks {
    fn tile_masks(&self) -> Box<dyn MaskProvider + '_> {
        Box::new(AllOnes)
    }
}

/// Full render product: image + stats (+ optional per-Gaussian scores).
pub struct RenderOutput {
    /// The composited framebuffer.
    pub image: Image,
    /// Workload counters for the frame.
    pub stats: RenderStats,
}

/// One-shot render through the reference pipeline: build a [`FramePlan`]
/// and render it once with vanilla masks. Tiles (and their mask
/// generation) fan across the worker pool when `opts.workers != 1`; the
/// output is bit-identical for any worker count.
///
/// Re-rendering the same view (sweeps, scoring)? Build the plan once with
/// [`FramePlan::build`] and call [`FramePlan::render`] per config instead.
pub fn render(scene: &Scene, cam: &Camera, opts: &RenderOptions) -> RenderOutput {
    FramePlan::build(scene, cam, opts).render(&VanillaMasks, None)
}

/// One-shot render with a caller-owned mini-tile mask provider (CAT
/// instrumentation point) and an optional per-Gaussian contribution
/// accumulator (pruning integration). `contributions` is indexed by
/// Gaussian id and must be `scene.len()` long. Tiles run sequentially (the
/// provider is borrowed mutably), but scores accumulate through the same
/// per-tile partial-sum fold as the parallel path, so the result is
/// bit-identical to [`FramePlan::render`] at any worker count.
///
/// # Examples
///
/// ```
/// use flicker::camera::{Camera, Intrinsics};
/// use flicker::numeric::linalg::v3;
/// use flicker::render::raster::{render_masked, AllOnes, RenderOptions};
/// use flicker::scene::synthetic::{generate_scaled, preset};
///
/// let scene = generate_scaled(&preset("truck"), 0.01);
/// let cam = Camera::look_at(
///     Intrinsics::from_fov(64, 64, 1.2),
///     v3(0.0, 2.5, -12.0),
///     v3(0.0, 0.5, 0.0),
///     v3(0.0, 1.0, 0.0),
/// );
/// let mut scores = vec![0.0f32; scene.len()];
/// let out = render_masked(
///     &scene,
///     &cam,
///     &RenderOptions::default(),
///     &mut AllOnes,
///     Some(&mut scores),
/// );
/// assert_eq!(out.image.width, 64);
/// assert!(scores.iter().any(|&s| s > 0.0), "something must contribute");
/// ```
pub fn render_masked(
    scene: &Scene,
    cam: &Camera,
    opts: &RenderOptions,
    masks: &mut dyn MaskProvider,
    contributions: Option<&mut [f32]>,
) -> RenderOutput {
    FramePlan::build(scene, cam, opts).render_with(masks, contributions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::{v3, Quat};
    use crate::scene::synthetic::{generate_scaled, preset};

    fn cam(px: u32) -> Camera {
        Camera::look_at(
            Intrinsics::from_fov(px, px, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        )
    }

    fn single_gaussian_scene(opacity: f32) -> Scene {
        let mut s = Scene::with_capacity(1, "t");
        s.push(
            v3(0.0, 0.5, 0.0),
            Quat::IDENTITY,
            v3(0.8, 0.8, 0.8),
            opacity,
            [2.0, -1.77, -1.77], // bright red after +0.5 shift
            [[0.0; 3]; 3],
        );
        s
    }

    #[test]
    fn single_gaussian_renders_centered_blob() {
        let scene = single_gaussian_scene(0.95);
        let out = render(&scene, &cam(64), &RenderOptions::default());
        let center = out.image.get(32, 32);
        let corner = out.image.get(0, 0);
        assert!(center[0] > 0.4, "center red {}", center[0]);
        assert!(center[0] > center[1] * 2.0);
        assert!(corner[0] < 0.05, "corner should be ~background");
    }

    #[test]
    fn opacity_zero_renders_background() {
        let mut scene = single_gaussian_scene(0.95);
        scene.opacity[0] = 0.0019; // below 1/255 at peak ⇒ invisible: α = o
        let opts = RenderOptions {
            background: [0.2, 0.3, 0.4],
            ..Default::default()
        };
        let out = render(&scene, &cam(32), &opts);
        let c = out.image.get(16, 16);
        assert!((c[0] - 0.2).abs() < 1e-3);
        assert!((c[2] - 0.4).abs() < 1e-3);
        assert_eq!(out.stats.pairs_blended, 0);
    }

    #[test]
    fn front_occludes_back() {
        let mut scene = Scene::with_capacity(2, "t");
        // Opaque red in front, green behind.
        scene.push(v3(0.0, 0.5, -2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.999, [2.0, -1.77, -1.77], [[0.0; 3]; 3]);
        scene.push(v3(0.0, 0.5, 2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.999, [-1.77, 2.0, -1.77], [[0.0; 3]; 3]);
        let out = render(&scene, &cam(64), &RenderOptions::default());
        let c = out.image.get(32, 32);
        assert!(c[0] > 5.0 * c[1], "front red must dominate: {c:?}");
    }

    #[test]
    fn order_independence_of_input() {
        // Same scene, reversed insertion order → same image (depth sort).
        let mut a = Scene::with_capacity(2, "t");
        a.push(v3(0.0, 0.5, -2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.9, [2.0, -1.77, -1.77], [[0.0; 3]; 3]);
        a.push(v3(0.0, 0.5, 2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.9, [-1.77, 2.0, -1.77], [[0.0; 3]; 3]);
        let mut b = Scene::with_capacity(2, "t");
        b.push(v3(0.0, 0.5, 2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.9, [-1.77, 2.0, -1.77], [[0.0; 3]; 3]);
        b.push(v3(0.0, 0.5, -2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.9, [2.0, -1.77, -1.77], [[0.0; 3]; 3]);
        let ia = render(&a, &cam(48), &RenderOptions::default()).image;
        let ib = render(&b, &cam(48), &RenderOptions::default()).image;
        assert!(ia.mad(&ib) < 1e-6);
    }

    #[test]
    fn early_termination_fires_behind_opaque_wall() {
        let mut scene = Scene::with_capacity(40, "t");
        // Six huge fully-opaque Gaussians cover the whole view: even at the
        // image corners (α ≈ 0.94 each) transmittance drops below t_min
        // after all six blend.
        for k in 0..6 {
            scene.push(
                v3(0.0, 0.5, -3.0 - 0.1 * k as f32),
                Quat::IDENTITY,
                v3(30.0, 30.0, 30.0),
                0.999,
                [1.0, 1.0, 1.0],
                [[0.0; 3]; 3],
            );
        }
        // ...and many behind it.
        for i in 0..20 {
            scene.push(
                v3(-2.0 + 0.2 * i as f32, 0.5, 3.0),
                Quat::IDENTITY,
                v3(0.5, 0.5, 0.5),
                0.9,
                [0.0, 1.0, 0.0],
                [[0.0; 3]; 3],
            );
        }
        let out = render(&scene, &cam(64), &RenderOptions::default());
        assert!(
            out.stats.tiles_early_terminated > 0,
            "expected early termination: {:?}",
            out.stats
        );
    }

    #[test]
    fn mask_zero_skips_everything() {
        struct NoneMask;
        impl MaskProvider for NoneMask {
            fn mask(&mut self, _t: &Rect, _s: &Splat) -> u32 {
                0
            }
        }
        let scene = single_gaussian_scene(0.9);
        let opts = RenderOptions::default();
        let out = render_masked(&scene, &cam(32), &opts, &mut NoneMask, None);
        assert_eq!(out.stats.pairs_tested, 0);
        assert!(out.image.get(16, 16)[0] < 1e-6);
    }

    #[test]
    fn contributions_accumulate_where_visible() {
        let scene = single_gaussian_scene(0.9);
        let mut scores = vec![0.0f32; 1];
        let opts = RenderOptions::default();
        render_masked(&scene, &cam(32), &opts, &mut AllOnes, Some(&mut scores));
        assert!(scores[0] > 1.0, "visible gaussian should score: {}", scores[0]);
    }

    #[test]
    fn obb_and_aabb_agree_visually() {
        // OBB only removes tiles whose pixels all have α < threshold, so the
        // image difference must be tiny (bounded by ALPHA_MIN leakage).
        let scene = generate_scaled(&preset("truck"), 0.01);
        let c = cam(96);
        let a = render(&scene, &c, &RenderOptions { strategy: Strategy::Aabb, ..Default::default() });
        let o = render(&scene, &c, &RenderOptions { strategy: Strategy::Obb, ..Default::default() });
        let p = super::super::metrics::psnr(&a.image, &o.image);
        assert!(p > 38.0, "OBB vs AABB PSNR {p}");
        // And OBB must do less per-pixel work.
        assert!(o.stats.pairs_tested <= a.stats.pairs_tested);
        assert!(o.stats.tile_pairs <= a.stats.tile_pairs);
    }

    #[test]
    fn tile_parallel_matches_sequential_bitwise() {
        let scene = generate_scaled(&preset("truck"), 0.01);
        let c = cam(96);
        let seq = render(&scene, &c, &RenderOptions::default());
        for workers in [0, 2, 4] {
            let par = render(&scene, &c, &RenderOptions { workers, ..Default::default() });
            assert_eq!(seq.image.data, par.image.data, "workers={workers}");
            assert_eq!(seq.stats.pairs_tested, par.stats.pairs_tested);
            assert_eq!(seq.stats.pairs_blended, par.stats.pairs_blended);
            assert_eq!(
                seq.stats.tiles_early_terminated,
                par.stats.tiles_early_terminated
            );
        }
    }

    #[test]
    fn stats_sane_on_synthetic_scene() {
        let scene = generate_scaled(&preset("garden"), 0.01);
        let out = render(&scene, &cam(128), &RenderOptions::default());
        assert!(out.stats.splats > 100);
        assert!(out.stats.tile_pairs >= out.stats.splats / 4);
        assert!(out.stats.pairs_tested > out.stats.pairs_blended);
        assert_eq!(out.stats.pixels, 128 * 128);
    }
}
