//! Reference tile rasterizer (paper Step (3)) — the golden functional model.
//!
//! Splat-major alpha blending within each tile, exactly the vanilla 3DGS
//! kernel semantics: per pixel, iterate the depth-sorted tile list, skip
//! Gaussians with α < 1/255, accumulate color with transmittance, and stop
//! when transmittance drops below `t_min` ("early termination").
//!
//! The rasterizer accepts an optional **mini-tile mask provider** so the same
//! code path renders: vanilla (mask = all ones), GSCore-style OBB-filtered
//! lists, or FLICKER's Mini-Tile CAT (mask from `crate::cat`). It also
//! optionally accumulates per-Gaussian contribution scores (used by pruning)
//! and tracks the per-pixel workload counters behind paper Fig. 4.
//!
//! **Determinism contract.** Tiles are independent work units and share one
//! blending loop (`render_tile`) between the sequential and parallel
//! paths, so images are bit-identical for any worker count. Contribution
//! scores obey the same contract: each tile accumulates into a private
//! list-aligned partial buffer, and partials are reduced into the global
//! per-Gaussian array in ascending tile index, whether tiles ran on one
//! thread or many.

use super::image::Image;
use super::project::{project_scene, Splat, ALPHA_MIN};
use super::sort::sort_by_depth;
use super::tile::{build_tile_lists, Rect, Strategy, TileGrid};
use crate::camera::Camera;
use crate::scene::gaussian::Scene;
use crate::util::pool;

/// Mini-tile edge in pixels (paper: 4×4 mini-tiles inside 16×16 tiles).
pub const MINITILE: u32 = 4;

/// Rendering configuration.
#[derive(Clone, Copy, Debug)]
pub struct RenderOptions {
    /// Tile edge in pixels (paper: 16×16).
    pub tile_size: u32,
    /// Tile-intersection strategy (AABB or OBB).
    pub strategy: Strategy,
    /// Transmittance threshold for early termination (3DGS: 1e-4).
    pub t_min: f32,
    /// Background color composited under the residual transmittance.
    pub background: [f32; 3],
    /// Worker threads for the tile fan-out (0 = auto, 1 = sequential).
    /// Tiles are independent, so any value yields bit-identical images.
    pub workers: usize,
}

impl Default for RenderOptions {
    fn default() -> Self {
        RenderOptions {
            tile_size: 16,
            strategy: Strategy::Aabb,
            t_min: 1e-4,
            background: [0.0, 0.0, 0.0],
            workers: 1,
        }
    }
}

/// Workload counters (inputs to Fig. 4 and the simulator's workload trace).
#[derive(Clone, Debug, Default)]
pub struct RenderStats {
    /// Splats surviving projection/culling.
    pub splats: usize,
    /// Σ per-tile list lengths ("duplicated Gaussians", Fig. 4 right).
    pub tile_pairs: usize,
    /// Per-pixel α evaluations attempted (pixel × splat pairs entering Eq. 1).
    pub pairs_tested: u64,
    /// Pairs that actually blended (α ≥ 1/255 and pixel still active).
    pub pairs_blended: u64,
    /// Pixels rendered.
    pub pixels: u64,
    /// Tiles whose loop ended early on full opacity.
    pub tiles_early_terminated: usize,
}

impl RenderStats {
    /// Average Gaussians *processed per pixel* — the paper's Fig. 4 metric.
    pub fn per_pixel_tested(&self) -> f64 {
        self.pairs_tested as f64 / self.pixels.max(1) as f64
    }

    /// Average Gaussians *blended per pixel* (pairs that passed the α gate).
    pub fn per_pixel_blended(&self) -> f64 {
        self.pairs_blended as f64 / self.pixels.max(1) as f64
    }

    /// Fold another tile's counters into this one. Integer sums are
    /// order-independent, so parallel tile stats match sequential exactly.
    pub fn absorb(&mut self, other: &RenderStats) {
        self.splats += other.splats;
        self.tile_pairs += other.tile_pairs;
        self.pairs_tested += other.pairs_tested;
        self.pairs_blended += other.pairs_blended;
        self.pixels += other.pixels;
        self.tiles_early_terminated += other.tiles_early_terminated;
    }
}

/// Mini-tile mask provider: given a tile rect and a splat, return one bit per
/// mini-tile (row-major, bit 0 = top-left) saying whether the splat must be
/// processed by that mini-tile's pixels. `u32` leaves room for tiles up to
/// 16 mini-tiles (16×16 px tile → 16 bits).
pub trait MaskProvider {
    /// Mini-tile bits for `splat` within `tile` (1 = process).
    fn mask(&mut self, tile: &Rect, splat: &Splat) -> u32;

    /// Number of mini-tile columns for a tile of `tile_size`.
    fn minitiles_per_row(&self, tile_size: u32) -> u32 {
        tile_size.div_ceil(MINITILE)
    }
}

/// Vanilla: every mini-tile processes every listed splat.
pub struct AllOnes;

impl MaskProvider for AllOnes {
    fn mask(&mut self, _tile: &Rect, _splat: &Splat) -> u32 {
        u32::MAX
    }
}

/// Thread-safe factory handing each tile worker its own [`MaskProvider`].
///
/// Providers may be stateful (caches, counters), but the mask bits must be
/// a pure function of `(tile, splat)` — that is what keeps tile-parallel
/// rendering bit-identical to the sequential loop. `cat::CatConfig`
/// implements this by building a fresh `CatEngine` per tile, so CAT mask
/// generation fans across the pool together with rasterization.
pub trait MaskSource: Sync {
    /// Hand out a fresh per-tile mask provider for one worker.
    fn tile_masks(&self) -> Box<dyn MaskProvider + '_>;
}

/// Mask source for the vanilla pipeline: every mini-tile processes every
/// listed splat.
pub struct VanillaMasks;

impl MaskSource for VanillaMasks {
    fn tile_masks(&self) -> Box<dyn MaskProvider + '_> {
        Box::new(AllOnes)
    }
}

/// Full render product: image + stats (+ optional per-Gaussian scores).
pub struct RenderOutput {
    /// The composited framebuffer.
    pub image: Image,
    /// Workload counters for the frame.
    pub stats: RenderStats,
}

/// Render the scene through the reference pipeline. Tiles (and their mask
/// generation) fan across the worker pool when `opts.workers != 1`; the
/// output is bit-identical for any worker count.
pub fn render(scene: &Scene, cam: &Camera, opts: &RenderOptions) -> RenderOutput {
    render_with_source(scene, cam, opts, &VanillaMasks)
}

/// Render with a mini-tile mask provider (CAT integration point) and an
/// optional per-Gaussian contribution accumulator (pruning integration).
/// `contributions` is indexed by Gaussian id and must be `scene.len()`
/// long. Tiles run sequentially (the provider is borrowed mutably), but
/// scores accumulate through the same per-tile partial-sum fold as the
/// parallel path, so the result is bit-identical to [`render_scored`] at
/// any worker count. Use [`render_with_source`] / [`render_scored`] for
/// the tile-parallel paths.
///
/// # Examples
///
/// ```
/// use flicker::camera::{Camera, Intrinsics};
/// use flicker::numeric::linalg::v3;
/// use flicker::render::raster::{render_masked, AllOnes, RenderOptions};
/// use flicker::scene::synthetic::{generate_scaled, preset};
///
/// let scene = generate_scaled(&preset("truck"), 0.01);
/// let cam = Camera::look_at(
///     Intrinsics::from_fov(64, 64, 1.2),
///     v3(0.0, 2.5, -12.0),
///     v3(0.0, 0.5, 0.0),
///     v3(0.0, 1.0, 0.0),
/// );
/// let mut scores = vec![0.0f32; scene.len()];
/// let out = render_masked(
///     &scene,
///     &cam,
///     &RenderOptions::default(),
///     &mut AllOnes,
///     Some(&mut scores),
/// );
/// assert_eq!(out.image.width, 64);
/// assert!(scores.iter().any(|&s| s > 0.0), "something must contribute");
/// ```
pub fn render_masked(
    scene: &Scene,
    cam: &Camera,
    opts: &RenderOptions,
    masks: &mut dyn MaskProvider,
    mut contributions: Option<&mut [f32]>,
) -> RenderOutput {
    let splats = project_scene(scene, cam);
    let grid = TileGrid::new(cam.intr.width, cam.intr.height, opts.tile_size);
    let mut lists = build_tile_lists(&splats, &grid, opts.strategy);
    for list in &mut lists {
        sort_by_depth(list, &splats);
    }
    render_lists(
        &splats,
        &lists,
        &grid,
        opts,
        masks,
        contributions.as_deref_mut(),
    )
}

/// Project → tile-bin → depth-sort → render through `source`, fanning the
/// per-tile work (rasterization and mask generation) across
/// `util::pool::for_each_index` when `opts.workers != 1`.
pub fn render_with_source(
    scene: &Scene,
    cam: &Camera,
    opts: &RenderOptions,
    source: &dyn MaskSource,
) -> RenderOutput {
    let splats = project_scene(scene, cam);
    let grid = TileGrid::new(cam.intr.width, cam.intr.height, opts.tile_size);
    let mut lists = build_tile_lists(&splats, &grid, opts.strategy);
    for list in &mut lists {
        sort_by_depth(list, &splats);
    }
    render_lists_parallel(&splats, &lists, &grid, opts, source)
}

/// Project → tile-bin → depth-sort → render through `source`, accumulating
/// per-Gaussian contribution scores (Σ T·α over all pixels, the pruning
/// signal) into `scores` — indexed by Gaussian id, so it must be
/// `scene.len()` long. Tiles (and their mask generation) fan across the
/// worker pool exactly like [`render_with_source`]; the per-tile score
/// partials reduce in ascending tile order, so both the image **and** the
/// scores are bit-identical for any `opts.workers` value.
pub fn render_scored(
    scene: &Scene,
    cam: &Camera,
    opts: &RenderOptions,
    source: &dyn MaskSource,
    scores: &mut [f32],
) -> RenderOutput {
    let splats = project_scene(scene, cam);
    let grid = TileGrid::new(cam.intr.width, cam.intr.height, opts.tile_size);
    let mut lists = build_tile_lists(&splats, &grid, opts.strategy);
    for list in &mut lists {
        sort_by_depth(list, &splats);
    }
    render_lists_scored(&splats, &lists, &grid, opts, source, scores)
}

/// Render one tile's depth-sorted list into tile-local scratch buffers
/// (`trans`/`color`, `tile_size²` entries, reset on entry). Returns the
/// valid `(w, h)` region — edge tiles are cropped by the image bounds.
/// This is the one blending loop shared by the sequential and parallel
/// paths, which is what makes them bit-identical.
///
/// `contributions`, when present, is a **tile-local** partial-sum buffer
/// aligned to `list` (entry `li` accumulates Σ T·α of splat `list[li]`
/// over this tile's pixels). Callers fold partials into the global
/// per-Gaussian score array via [`fold_tile_scores`] in tile order — the
/// fixed reduce order that keeps parallel scoring bit-identical to the
/// sequential pass.
#[allow(clippy::too_many_arguments)]
fn render_tile(
    splats: &[Splat],
    list: &[u32],
    rect: &Rect,
    grid: &TileGrid,
    opts: &RenderOptions,
    masks: &mut dyn MaskProvider,
    trans: &mut [f32],
    color: &mut [[f32; 3]],
    mut contributions: Option<&mut [f32]>,
    stats: &mut RenderStats,
) -> (usize, usize) {
    let ts = grid.tile as usize;
    let mt_cols = grid.tile.div_ceil(MINITILE) as usize;
    let x_lo = rect.x0 as u32;
    let y_lo = rect.y0 as u32;
    let w = (grid.width - x_lo).min(grid.tile) as usize;
    let h = (grid.height - y_lo).min(grid.tile) as usize;
    trans[..ts * ts].fill(1.0);
    for c in color.iter_mut() {
        *c = [0.0; 3];
    }
    let mut active = (w * h) as u32;

    'splat_loop: for (li, &si) in list.iter().enumerate() {
        let s = &splats[si as usize];
        let mask = masks.mask(rect, s);
        if mask == 0 {
            continue;
        }
        // Hot-loop locals (§Perf): hoist splat fields and precompute the
        // Eq.-2 threshold so the (majority) sub-threshold pixels skip the
        // exp() entirely: α = o·e^{−E} ≥ 1/255 ⇔ E ≤ ln(255·o).
        let (ca, cb, cc) = (s.conic.a, s.conic.b, s.conic.c);
        let (mx, my) = (s.mean.x, s.mean.y);
        let opacity = s.opacity;
        let e_max = (255.0 * opacity).max(1e-12).ln();
        let col = s.color;
        for py in 0..h {
            let gy = y_lo as f32 + py as f32 + 0.5;
            let dy = gy - my;
            let half_cc_dy2 = 0.5 * cc * dy * dy;
            let cb_dy = cb * dy;
            let mt_row = py / MINITILE as usize;
            for px in 0..w {
                let mt = mt_row * mt_cols + px / MINITILE as usize;
                if mask & (1 << mt) == 0 {
                    continue;
                }
                let idx = py * ts + px;
                let t_cur = trans[idx];
                if t_cur < opts.t_min {
                    continue;
                }
                stats.pairs_tested += 1;
                let gx = x_lo as f32 + px as f32 + 0.5;
                let dx = gx - mx;
                let e = 0.5 * ca * dx * dx + half_cc_dy2 + cb_dy * dx;
                if e >= e_max || e < 0.0 {
                    continue; // α below 1/255 — no exp needed
                }
                let a = (opacity * (-e).exp()).min(0.999);
                if a < ALPHA_MIN {
                    continue;
                }
                stats.pairs_blended += 1;
                let wgt = a * t_cur;
                color[idx][0] += wgt * col[0];
                color[idx][1] += wgt * col[1];
                color[idx][2] += wgt * col[2];
                if let Some(sc) = contributions.as_deref_mut() {
                    sc[li] += wgt;
                }
                let t_new = t_cur * (1.0 - a);
                trans[idx] = t_new;
                if t_new < opts.t_min {
                    active -= 1;
                    if active == 0 {
                        stats.tiles_early_terminated += 1;
                        break 'splat_loop;
                    }
                }
            }
        }
    }
    (w, h)
}

/// Frame-level stats skeleton: the per-tile loops only touch the pair and
/// early-termination counters, so these totals are set once up front.
fn frame_stats(splats: &[Splat], lists: &[Vec<u32>], grid: &TileGrid) -> RenderStats {
    RenderStats {
        splats: splats.len(),
        tile_pairs: lists.iter().map(|l| l.len()).sum(),
        pixels: (grid.width * grid.height) as u64,
        ..Default::default()
    }
}

/// Fold one tile's list-aligned contribution partials into the global
/// per-Gaussian score array (indexed by Gaussian id), iterating in list
/// order. Sequential and parallel scoring both reduce through this helper
/// in ascending tile index, which is what makes the accumulated scores
/// bit-identical for any worker count.
fn fold_tile_scores(scores: &mut [f32], splats: &[Splat], list: &[u32], partial: &[f32]) {
    for (li, &si) in list.iter().enumerate() {
        scores[splats[si as usize].id as usize] += partial[li];
    }
}

/// Core loop over prebuilt, depth-sorted tile lists (sequential).
/// `contributions`, when present, is the global per-Gaussian score array
/// (indexed by Gaussian id); each tile accumulates into a tile-local
/// partial buffer which is folded in ascending tile order — the same
/// reduce order as the parallel path.
pub fn render_lists(
    splats: &[Splat],
    lists: &[Vec<u32>],
    grid: &TileGrid,
    opts: &RenderOptions,
    masks: &mut dyn MaskProvider,
    mut contributions: Option<&mut [f32]>,
) -> RenderOutput {
    let mut img = Image::new(grid.width, grid.height);
    let mut stats = frame_stats(splats, lists, grid);
    let ts = grid.tile as usize;
    // Per-tile scratch, reused across tiles (no allocation in the loop).
    let mut trans = vec![1.0f32; ts * ts];
    let mut color = vec![[0.0f32; 3]; ts * ts];
    let scoring = contributions.is_some();
    let mut partial: Vec<f32> = Vec::new();

    for (t, list) in lists.iter().enumerate() {
        let rect = grid.rect(t);
        if scoring {
            partial.clear();
            partial.resize(list.len(), 0.0);
        }
        let (w, h) = render_tile(
            splats,
            list,
            &rect,
            grid,
            opts,
            masks,
            &mut trans,
            &mut color,
            if scoring { Some(partial.as_mut_slice()) } else { None },
            &mut stats,
        );
        if let Some(sc) = contributions.as_deref_mut() {
            fold_tile_scores(sc, splats, list, &partial);
        }
        // Composite over background.
        let x_lo = rect.x0 as u32;
        let y_lo = rect.y0 as u32;
        for py in 0..h {
            for px in 0..w {
                let idx = py * ts + px;
                let tr = trans[idx];
                let c = color[idx];
                img.set(
                    x_lo + px as u32,
                    y_lo + py as u32,
                    [
                        c[0] + tr * opts.background[0],
                        c[1] + tr * opts.background[1],
                        c[2] + tr * opts.background[2],
                    ],
                );
            }
        }
    }
    RenderOutput { image: img, stats }
}

/// Tile-parallel core: each tile renders independently (fresh mask provider
/// from `source`, tile-local scratch) on the scoped worker pool, then the
/// composited tiles are stitched in index order. Falls back to
/// [`render_lists`] when one worker resolves.
pub fn render_lists_parallel(
    splats: &[Splat],
    lists: &[Vec<u32>],
    grid: &TileGrid,
    opts: &RenderOptions,
    source: &dyn MaskSource,
) -> RenderOutput {
    render_lists_core(splats, lists, grid, opts, source, None)
}

/// Tile-parallel render that also accumulates per-Gaussian contribution
/// scores (Σ T·α, the pruning signal) into `scores` — the global score
/// array indexed by Gaussian id. Each tile accumulates into a private
/// list-aligned partial buffer on its worker, and partials are reduced in
/// ascending tile order after the fan-out, so `scores` is bit-identical to
/// the sequential [`render_lists`] pass for any worker count.
pub fn render_lists_scored(
    splats: &[Splat],
    lists: &[Vec<u32>],
    grid: &TileGrid,
    opts: &RenderOptions,
    source: &dyn MaskSource,
    scores: &mut [f32],
) -> RenderOutput {
    render_lists_core(splats, lists, grid, opts, source, Some(scores))
}

/// Shared tile-parallel implementation behind [`render_lists_parallel`] and
/// [`render_lists_scored`]: fan tiles across the pool, then stitch pixels,
/// absorb stats, and fold score partials in ascending tile index.
fn render_lists_core(
    splats: &[Splat],
    lists: &[Vec<u32>],
    grid: &TileGrid,
    opts: &RenderOptions,
    source: &dyn MaskSource,
    mut scores: Option<&mut [f32]>,
) -> RenderOutput {
    let workers = pool::resolve_workers(opts.workers).min(lists.len().max(1));
    if workers <= 1 {
        let mut masks = source.tile_masks();
        return render_lists(splats, lists, grid, opts, masks.as_mut(), scores.as_deref_mut());
    }
    let ts = grid.tile as usize;
    let want_scores = scores.is_some();
    let tiles: Vec<(Vec<f32>, Vec<f32>, RenderStats)> =
        pool::map_indexed(lists.len(), workers, |t| {
            let mut masks = source.tile_masks();
            let mut trans = vec![1.0f32; ts * ts];
            let mut color = vec![[0.0f32; 3]; ts * ts];
            let mut stats = RenderStats::default();
            // Private per-tile score partials, aligned to this tile's list.
            let mut partial = vec![0.0f32; if want_scores { lists[t].len() } else { 0 }];
            let rect = grid.rect(t);
            let (w, h) = render_tile(
                splats,
                &lists[t],
                &rect,
                grid,
                opts,
                masks.as_mut(),
                &mut trans,
                &mut color,
                if want_scores { Some(partial.as_mut_slice()) } else { None },
                &mut stats,
            );
            // Composite over background into a w×h tile pixel block.
            let mut pixels = vec![0.0f32; w * h * 3];
            for py in 0..h {
                for px in 0..w {
                    let idx = py * ts + px;
                    let tr = trans[idx];
                    let c = color[idx];
                    let o = (py * w + px) * 3;
                    pixels[o] = c[0] + tr * opts.background[0];
                    pixels[o + 1] = c[1] + tr * opts.background[1];
                    pixels[o + 2] = c[2] + tr * opts.background[2];
                }
            }
            (pixels, partial, stats)
        });

    let mut img = Image::new(grid.width, grid.height);
    let mut stats = frame_stats(splats, lists, grid);
    for (t, (pixels, partial, tile_stats)) in tiles.iter().enumerate() {
        stats.absorb(tile_stats);
        if let Some(sc) = scores.as_deref_mut() {
            fold_tile_scores(sc, splats, &lists[t], partial);
        }
        let rect = grid.rect(t);
        let x_lo = rect.x0 as u32;
        let y_lo = rect.y0 as u32;
        let w = (grid.width - x_lo).min(grid.tile) as usize;
        let h = (grid.height - y_lo).min(grid.tile) as usize;
        for py in 0..h {
            for px in 0..w {
                let o = (py * w + px) * 3;
                img.set(
                    x_lo + px as u32,
                    y_lo + py as u32,
                    [pixels[o], pixels[o + 1], pixels[o + 2]],
                );
            }
        }
    }
    RenderOutput { image: img, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::{v3, Quat};
    use crate::scene::synthetic::{generate_scaled, preset};

    fn cam(px: u32) -> Camera {
        Camera::look_at(
            Intrinsics::from_fov(px, px, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        )
    }

    fn single_gaussian_scene(opacity: f32) -> Scene {
        let mut s = Scene::with_capacity(1, "t");
        s.push(
            v3(0.0, 0.5, 0.0),
            Quat::IDENTITY,
            v3(0.8, 0.8, 0.8),
            opacity,
            [2.0, -1.77, -1.77], // bright red after +0.5 shift
            [[0.0; 3]; 3],
        );
        s
    }

    #[test]
    fn single_gaussian_renders_centered_blob() {
        let scene = single_gaussian_scene(0.95);
        let out = render(&scene, &cam(64), &RenderOptions::default());
        let center = out.image.get(32, 32);
        let corner = out.image.get(0, 0);
        assert!(center[0] > 0.4, "center red {}", center[0]);
        assert!(center[0] > center[1] * 2.0);
        assert!(corner[0] < 0.05, "corner should be ~background");
    }

    #[test]
    fn opacity_zero_renders_background() {
        let mut scene = single_gaussian_scene(0.95);
        scene.opacity[0] = 0.0019; // below 1/255 at peak ⇒ invisible: α = o
        let opts = RenderOptions {
            background: [0.2, 0.3, 0.4],
            ..Default::default()
        };
        let out = render(&scene, &cam(32), &opts);
        let c = out.image.get(16, 16);
        assert!((c[0] - 0.2).abs() < 1e-3);
        assert!((c[2] - 0.4).abs() < 1e-3);
        assert_eq!(out.stats.pairs_blended, 0);
    }

    #[test]
    fn front_occludes_back() {
        let mut scene = Scene::with_capacity(2, "t");
        // Opaque red in front, green behind.
        scene.push(v3(0.0, 0.5, -2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.999, [2.0, -1.77, -1.77], [[0.0; 3]; 3]);
        scene.push(v3(0.0, 0.5, 2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.999, [-1.77, 2.0, -1.77], [[0.0; 3]; 3]);
        let out = render(&scene, &cam(64), &RenderOptions::default());
        let c = out.image.get(32, 32);
        assert!(c[0] > 5.0 * c[1], "front red must dominate: {c:?}");
    }

    #[test]
    fn order_independence_of_input() {
        // Same scene, reversed insertion order → same image (depth sort).
        let mut a = Scene::with_capacity(2, "t");
        a.push(v3(0.0, 0.5, -2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.9, [2.0, -1.77, -1.77], [[0.0; 3]; 3]);
        a.push(v3(0.0, 0.5, 2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.9, [-1.77, 2.0, -1.77], [[0.0; 3]; 3]);
        let mut b = Scene::with_capacity(2, "t");
        b.push(v3(0.0, 0.5, 2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.9, [-1.77, 2.0, -1.77], [[0.0; 3]; 3]);
        b.push(v3(0.0, 0.5, -2.0), Quat::IDENTITY, v3(1.0, 1.0, 1.0), 0.9, [2.0, -1.77, -1.77], [[0.0; 3]; 3]);
        let ia = render(&a, &cam(48), &RenderOptions::default()).image;
        let ib = render(&b, &cam(48), &RenderOptions::default()).image;
        assert!(ia.mad(&ib) < 1e-6);
    }

    #[test]
    fn early_termination_fires_behind_opaque_wall() {
        let mut scene = Scene::with_capacity(40, "t");
        // Six huge fully-opaque Gaussians cover the whole view: even at the
        // image corners (α ≈ 0.94 each) transmittance drops below t_min
        // after all six blend.
        for k in 0..6 {
            scene.push(
                v3(0.0, 0.5, -3.0 - 0.1 * k as f32),
                Quat::IDENTITY,
                v3(30.0, 30.0, 30.0),
                0.999,
                [1.0, 1.0, 1.0],
                [[0.0; 3]; 3],
            );
        }
        // ...and many behind it.
        for i in 0..20 {
            scene.push(
                v3(-2.0 + 0.2 * i as f32, 0.5, 3.0),
                Quat::IDENTITY,
                v3(0.5, 0.5, 0.5),
                0.9,
                [0.0, 1.0, 0.0],
                [[0.0; 3]; 3],
            );
        }
        let out = render(&scene, &cam(64), &RenderOptions::default());
        assert!(
            out.stats.tiles_early_terminated > 0,
            "expected early termination: {:?}",
            out.stats
        );
    }

    #[test]
    fn mask_zero_skips_everything() {
        struct NoneMask;
        impl MaskProvider for NoneMask {
            fn mask(&mut self, _t: &Rect, _s: &Splat) -> u32 {
                0
            }
        }
        let scene = single_gaussian_scene(0.9);
        let opts = RenderOptions::default();
        let out = render_masked(&scene, &cam(32), &opts, &mut NoneMask, None);
        assert_eq!(out.stats.pairs_tested, 0);
        assert!(out.image.get(16, 16)[0] < 1e-6);
    }

    #[test]
    fn contributions_accumulate_where_visible() {
        let scene = single_gaussian_scene(0.9);
        let mut scores = vec![0.0f32; 1];
        let opts = RenderOptions::default();
        render_masked(&scene, &cam(32), &opts, &mut AllOnes, Some(&mut scores));
        assert!(scores[0] > 1.0, "visible gaussian should score: {}", scores[0]);
    }

    #[test]
    fn obb_and_aabb_agree_visually() {
        // OBB only removes tiles whose pixels all have α < threshold, so the
        // image difference must be tiny (bounded by ALPHA_MIN leakage).
        let scene = generate_scaled(&preset("truck"), 0.01);
        let c = cam(96);
        let a = render(&scene, &c, &RenderOptions { strategy: Strategy::Aabb, ..Default::default() });
        let o = render(&scene, &c, &RenderOptions { strategy: Strategy::Obb, ..Default::default() });
        let p = super::super::metrics::psnr(&a.image, &o.image);
        assert!(p > 38.0, "OBB vs AABB PSNR {p}");
        // And OBB must do less per-pixel work.
        assert!(o.stats.pairs_tested <= a.stats.pairs_tested);
        assert!(o.stats.tile_pairs <= a.stats.tile_pairs);
    }

    #[test]
    fn tile_parallel_matches_sequential_bitwise() {
        let scene = generate_scaled(&preset("truck"), 0.01);
        let c = cam(96);
        let seq = render(&scene, &c, &RenderOptions::default());
        for workers in [0, 2, 4] {
            let par = render(&scene, &c, &RenderOptions { workers, ..Default::default() });
            assert_eq!(seq.image.data, par.image.data, "workers={workers}");
            assert_eq!(seq.stats.pairs_tested, par.stats.pairs_tested);
            assert_eq!(seq.stats.pairs_blended, par.stats.pairs_blended);
            assert_eq!(
                seq.stats.tiles_early_terminated,
                par.stats.tiles_early_terminated
            );
        }
    }

    #[test]
    fn scored_parallel_matches_sequential_bitwise() {
        let scene = generate_scaled(&preset("truck"), 0.01);
        let c = cam(96);
        // Sequential reference: render_masked folds the same per-tile
        // partial sums in tile order.
        let mut seq = vec![0.0f32; scene.len()];
        let opts = RenderOptions::default();
        let seq_out = render_masked(&scene, &c, &opts, &mut AllOnes, Some(&mut seq));
        assert!(seq.iter().any(|&s| s > 0.0), "scene must contribute");
        for workers in [2, 4, 0] {
            let mut par = vec![0.0f32; scene.len()];
            let popts = RenderOptions {
                workers,
                ..RenderOptions::default()
            };
            let par_out = render_scored(&scene, &c, &popts, &VanillaMasks, &mut par);
            let seq_bits: Vec<u32> = seq.iter().map(|s| s.to_bits()).collect();
            let par_bits: Vec<u32> = par.iter().map(|s| s.to_bits()).collect();
            assert_eq!(seq_bits, par_bits, "workers={workers}");
            assert_eq!(seq_out.image.data, par_out.image.data, "workers={workers}");
            assert_eq!(seq_out.stats.pairs_blended, par_out.stats.pairs_blended);
        }
    }

    #[test]
    fn scoring_does_not_change_the_image() {
        let scene = generate_scaled(&preset("garden"), 0.01);
        let c = cam(96);
        let opts = RenderOptions {
            workers: 0,
            ..RenderOptions::default()
        };
        let plain = render(&scene, &c, &opts);
        let mut scores = vec![0.0f32; scene.len()];
        let scored = render_scored(&scene, &c, &opts, &VanillaMasks, &mut scores);
        assert_eq!(plain.image.data, scored.image.data);
        assert_eq!(plain.stats.pairs_tested, scored.stats.pairs_tested);
    }

    #[test]
    fn stats_sane_on_synthetic_scene() {
        let scene = generate_scaled(&preset("garden"), 0.01);
        let out = render(&scene, &cam(128), &RenderOptions::default());
        assert!(out.stats.splats > 100);
        assert!(out.stats.tile_pairs >= out.stats.splats / 4);
        assert!(out.stats.pairs_tested > out.stats.pairs_blended);
        assert_eq!(out.stats.pixels, 128 * 128);
    }
}
