//! Depth sorting of per-tile splat lists, paper Step (2).
//!
//! The reference path uses a stable sort by camera-space depth (near→far).
//! We also provide counting-sort over quantized depth keys — the form a
//! hardware bitonic/merge sorting unit produces — so the simulator's sorter
//! model and the functional path agree on ordering semantics.

use super::project::Splat;

/// Sort indices of `splats` (near → far) using exact f32 depth, stable.
pub fn sort_by_depth(list: &mut [u32], splats: &[Splat]) {
    list.sort_by(|&a, &b| {
        splats[a as usize]
            .depth
            .partial_cmp(&splats[b as usize].depth)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
}

/// Quantize a depth to the 16-bit key a hardware sorter would use.
/// Linear in 1/z between near and far gives better near-field resolution.
pub fn depth_key(depth: f32, near: f32, far: f32) -> u16 {
    let inv = 1.0 / depth.max(near);
    let inv_near = 1.0 / near;
    let inv_far = 1.0 / far;
    let t = ((inv_near - inv) / (inv_near - inv_far)).clamp(0.0, 1.0);
    (t * 65535.0) as u16
}

/// Counting sort on 16-bit quantized keys (stable). This is the ordering the
/// simulator's sorting unit produces; ties keep submission order, matching a
/// merge network's stability.
pub fn sort_by_key16(list: &mut Vec<u32>, splats: &[Splat], near: f32, far: f32) {
    if list.len() <= 1 {
        return;
    }
    let keys: Vec<u16> = list
        .iter()
        .map(|&i| depth_key(splats[i as usize].depth, near, far))
        .collect();
    // Radix-2×8, stable: low byte pass into tmp, high byte pass back.
    let n = list.len();
    let mut tmp: Vec<u32> = vec![0; n];
    let mut tmp_keys: Vec<u16> = vec![0; n];

    // Pass 1: low byte, list → tmp (carry keys along).
    let mut counts = [0usize; 257];
    for &k in &keys {
        counts[(k & 0xFF) as usize + 1] += 1;
    }
    for b in 1..257 {
        counts[b] += counts[b - 1];
    }
    for pos in 0..n {
        let b = (keys[pos] & 0xFF) as usize;
        tmp[counts[b]] = list[pos];
        tmp_keys[counts[b]] = keys[pos];
        counts[b] += 1;
    }

    // Pass 2: high byte, tmp → list.
    let mut counts = [0usize; 257];
    for &k in &tmp_keys {
        counts[(k >> 8) as usize + 1] += 1;
    }
    for b in 1..257 {
        counts[b] += counts[b - 1];
    }
    for pos in 0..n {
        let b = (tmp_keys[pos] >> 8) as usize;
        list[counts[b]] = tmp[pos];
        counts[b] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::{v3, Quat};
    use crate::render::project::project_one;
    use crate::scene::gaussian::Scene;
    use crate::util::rng::Pcg32;

    fn splats_with_depths(depths: &[f32]) -> Vec<Splat> {
        let cam = Camera::look_at(
            Intrinsics::from_fov(128, 128, 1.2),
            v3(0.0, 0.0, -10.0),
            v3(0.0, 0.0, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        depths
            .iter()
            .map(|&d| {
                let mut sc = Scene::with_capacity(1, "t");
                sc.push(
                    v3(0.0, 0.0, d - 10.0),
                    Quat::IDENTITY,
                    v3(0.2, 0.2, 0.2),
                    0.5,
                    [0.5; 3],
                    [[0.0; 3]; 3],
                );
                project_one(&sc, 0, &cam).unwrap()
            })
            .collect()
    }

    #[test]
    fn exact_sort_orders_near_to_far() {
        let splats = splats_with_depths(&[5.0, 2.0, 9.0, 3.0]);
        let mut list: Vec<u32> = vec![0, 1, 2, 3];
        sort_by_depth(&mut list, &splats);
        assert_eq!(list, vec![1, 3, 0, 2]);
    }

    #[test]
    fn key16_monotone_in_depth() {
        let mut prev = 0u16;
        for i in 1..100 {
            let d = 0.1 + i as f32 * 0.5;
            let k = depth_key(d, 0.05, 1000.0);
            assert!(k >= prev, "depth {d}");
            prev = k;
        }
    }

    #[test]
    fn radix_matches_exact_up_to_key_ties() {
        let mut rng = Pcg32::new(99);
        let depths: Vec<f32> = (0..300).map(|_| rng.range_f32(1.0, 50.0)).collect();
        let splats = splats_with_depths(&depths);
        let mut exact: Vec<u32> = (0..300).collect();
        sort_by_depth(&mut exact, &splats);
        let mut radix: Vec<u32> = (0..300).collect();
        sort_by_key16(&mut radix, &splats, 0.05, 1000.0);
        // Keys are monotone in depth, so sequences of keys must agree.
        let k = |i: u32| depth_key(splats[i as usize].depth, 0.05, 1000.0);
        let ek: Vec<u16> = exact.iter().map(|&i| k(i)).collect();
        let rk: Vec<u16> = radix.iter().map(|&i| k(i)).collect();
        assert_eq!(ek, rk);
    }

    #[test]
    fn radix_is_stable() {
        // Equal depths keep submission order.
        let splats = splats_with_depths(&[4.0, 4.0, 4.0]);
        let mut list = vec![2u32, 0, 1];
        sort_by_key16(&mut list, &splats, 0.05, 1000.0);
        assert_eq!(list, vec![2, 0, 1]);
    }

    #[test]
    fn empty_and_single() {
        let splats = splats_with_depths(&[4.0]);
        let mut empty: Vec<u32> = vec![];
        sort_by_key16(&mut empty, &splats, 0.05, 1000.0);
        assert!(empty.is_empty());
        let mut one = vec![0u32];
        sort_by_key16(&mut one, &splats, 0.05, 1000.0);
        assert_eq!(one, vec![0]);
    }
}
