//! Framebuffer and simple image IO (PPM/PGM — no external codecs offline).

/// RGB float framebuffer, row-major, values nominally in [0, 1].
#[derive(Clone, Debug)]
pub struct Image {
    /// Width in pixels.
    pub width: u32,
    /// Height in pixels.
    pub height: u32,
    /// width*height*3 floats, RGB interleaved.
    pub data: Vec<f32>,
}

impl Image {
    /// Black image of the given size.
    pub fn new(width: u32, height: u32) -> Image {
        Image {
            width,
            height,
            data: vec![0.0; (width * height * 3) as usize],
        }
    }

    /// Constant-color image of the given size.
    pub fn filled(width: u32, height: u32, rgb: [f32; 3]) -> Image {
        let mut img = Image::new(width, height);
        for px in img.data.chunks_exact_mut(3) {
            px.copy_from_slice(&rgb);
        }
        img
    }

    /// Flat index of pixel `(x, y)` into [`Image::data`].
    #[inline]
    pub fn idx(&self, x: u32, y: u32) -> usize {
        debug_assert!(x < self.width && y < self.height);
        ((y * self.width + x) * 3) as usize
    }

    /// RGB at pixel `(x, y)`.
    #[inline]
    pub fn get(&self, x: u32, y: u32) -> [f32; 3] {
        let i = self.idx(x, y);
        [self.data[i], self.data[i + 1], self.data[i + 2]]
    }

    /// Overwrite RGB at pixel `(x, y)`.
    #[inline]
    pub fn set(&mut self, x: u32, y: u32, rgb: [f32; 3]) {
        let i = self.idx(x, y);
        self.data[i..i + 3].copy_from_slice(&rgb);
    }

    /// Mean absolute difference against another image (quick diagnostics).
    pub fn mad(&self, other: &Image) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        let s: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum();
        s / self.data.len() as f64
    }

    /// Write binary PPM (P6), sRGB-ish clamp to 8 bit.
    pub fn write_ppm(&self, path: &std::path::Path) -> std::io::Result<()> {
        let mut buf = format!("P6\n{} {}\n255\n", self.width, self.height).into_bytes();
        buf.reserve(self.data.len());
        for &v in &self.data {
            buf.push((v.clamp(0.0, 1.0) * 255.0 + 0.5) as u8);
        }
        std::fs::write(path, buf)
    }

    /// Read binary PPM (P6) written by `write_ppm`.
    pub fn read_ppm(path: &std::path::Path) -> std::io::Result<Image> {
        let bytes = std::fs::read(path)?;
        let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
        // Parse header: magic, width, height, maxval — whitespace separated.
        let mut pos = 0usize;
        let mut fields: Vec<String> = Vec::new();
        while fields.len() < 4 && pos < bytes.len() {
            while pos < bytes.len() && bytes[pos].is_ascii_whitespace() {
                pos += 1;
            }
            if pos < bytes.len() && bytes[pos] == b'#' {
                while pos < bytes.len() && bytes[pos] != b'\n' {
                    pos += 1;
                }
                continue;
            }
            let start = pos;
            while pos < bytes.len() && !bytes[pos].is_ascii_whitespace() {
                pos += 1;
            }
            fields.push(String::from_utf8_lossy(&bytes[start..pos]).into_owned());
        }
        if fields.len() < 4 || fields[0] != "P6" {
            return Err(err("not a P6 ppm"));
        }
        let width: u32 = fields[1].parse().map_err(|_| err("bad width"))?;
        let height: u32 = fields[2].parse().map_err(|_| err("bad height"))?;
        pos += 1; // single whitespace after maxval
        let need = (width * height * 3) as usize;
        if bytes.len() < pos + need {
            return Err(err("truncated pixel data"));
        }
        let data = bytes[pos..pos + need]
            .iter()
            .map(|&b| b as f32 / 255.0)
            .collect();
        Ok(Image { width, height, data })
    }

    /// Luma (Rec.601) plane, used by SSIM.
    pub fn luma(&self) -> Vec<f32> {
        self.data
            .chunks_exact(3)
            .map(|px| 0.299 * px[0] + 0.587 * px[1] + 0.114 * px[2])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut img = Image::new(8, 4);
        img.set(3, 2, [0.1, 0.5, 0.9]);
        assert_eq!(img.get(3, 2), [0.1, 0.5, 0.9]);
        assert_eq!(img.get(0, 0), [0.0, 0.0, 0.0]);
    }

    #[test]
    fn ppm_roundtrip() {
        let mut img = Image::new(5, 3);
        for y in 0..3 {
            for x in 0..5 {
                img.set(x, y, [x as f32 / 4.0, y as f32 / 2.0, 0.25]);
            }
        }
        let dir = std::env::temp_dir().join("flicker_test_ppm");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ppm");
        img.write_ppm(&p).unwrap();
        let back = Image::read_ppm(&p).unwrap();
        assert_eq!(back.width, 5);
        assert_eq!(back.height, 3);
        // 8-bit quantization error only.
        assert!(img.mad(&back) < 1.0 / 255.0);
    }

    #[test]
    fn mad_zero_for_identical() {
        let img = Image::filled(4, 4, [0.3, 0.3, 0.3]);
        assert_eq!(img.mad(&img.clone()), 0.0);
    }

    #[test]
    fn luma_weights() {
        let img = Image::filled(2, 2, [1.0, 0.0, 0.0]);
        let l = img.luma();
        assert!((l[0] - 0.299).abs() < 1e-6);
    }

    #[test]
    fn clamp_on_write() {
        let mut img = Image::new(1, 1);
        img.set(0, 0, [2.0, -1.0, 0.5]);
        let dir = std::env::temp_dir().join("flicker_test_ppm2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.ppm");
        img.write_ppm(&p).unwrap();
        let back = Image::read_ppm(&p).unwrap();
        assert_eq!(back.get(0, 0)[0], 1.0);
        assert_eq!(back.get(0, 0)[1], 0.0);
    }
}
