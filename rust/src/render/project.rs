//! 3D → 2D Gaussian projection (EWA splatting), paper Step (1).
//!
//! Produces the per-view 2D features the rest of the pipeline consumes:
//! mean μ′, covariance Σ′ (and its inverse, the conic), depth, view-dependent
//! color, the 3σ radius, and the projected axis ratio used by the adaptive
//! leader-pixel classifier.

use crate::camera::Camera;
use crate::numeric::linalg::{Mat3, Sym2, Vec2};
use crate::scene::gaussian::Scene;

/// A projected 2D splat.
#[derive(Clone, Copy, Debug)]
pub struct Splat {
    /// Index of the source Gaussian in the scene.
    pub id: u32,
    /// Mean in pixel coordinates.
    pub mean: Vec2,
    /// 2D covariance.
    pub cov: Sym2,
    /// Inverse covariance (conic) — what Eq. (1) consumes.
    pub conic: Sym2,
    /// Camera-space depth (z).
    pub depth: f32,
    /// Base opacity `o` in Eq. (1).
    pub opacity: f32,
    /// View-dependent RGB (SH evaluated at the view direction).
    pub color: [f32; 3],
    /// 3σ radius along the major axis (pixels).
    pub radius: f32,
    /// Projected axis ratio sqrt(λmax/λmin) — spiky classifier input.
    pub axis_ratio: f32,
}

impl Splat {
    /// Is this Gaussian "spiky" under the paper's threshold (ratio ≥ 3)?
    #[inline]
    pub fn is_spiky(&self, threshold: f32) -> bool {
        self.axis_ratio >= threshold
    }

    /// Evaluate α at pixel `p` (Eq. 1), full precision.
    #[inline]
    pub fn alpha_at(&self, px: f32, py: f32) -> f32 {
        let dx = px - self.mean.x;
        let dy = py - self.mean.y;
        let e = 0.5 * (self.conic.a * dx * dx + self.conic.c * dy * dy)
            + self.conic.b * dx * dy;
        if e < 0.0 {
            // Numerically impossible for PSD conic; guard anyway.
            return self.opacity;
        }
        (self.opacity * (-e).exp()).min(0.999)
    }
}

/// Low-pass dilation added to the projected covariance diagonal, as in the
/// reference 3DGS rasterizer (anti-aliasing guard: every splat covers at
/// least ~1 pixel).
pub const COV_DILATION: f32 = 0.3;

/// Minimum α for a Gaussian to count as contributing (1/255).
pub const ALPHA_MIN: f32 = 1.0 / 255.0;

/// Project Gaussian `i` of `scene` into `cam`. Returns `None` if culled
/// (behind near plane, outside frustum, or degenerate projection).
pub fn project_one(scene: &Scene, i: usize, cam: &Camera) -> Option<Splat> {
    let p = scene.pos[i];
    let t = cam.to_camera(p);
    if t.z < cam.near || t.z > cam.far {
        return None;
    }
    if !cam.sphere_in_frustum(p, scene.bounding_radius(i)) {
        return None;
    }

    // 3D covariance Σ = R S Sᵀ Rᵀ.
    let r = scene.rot[i].to_mat3();
    let s = scene.scale[i];
    let rs = r.mul(&Mat3::scale(s));
    let sigma3 = rs.mul(&rs.transpose());

    // Jacobian of the perspective projection at t (EWA approximation),
    // with the camera rotation W folded in: Σ′ = J W Σ Wᵀ Jᵀ.
    let (fx, fy) = (cam.intr.fx, cam.intr.fy);
    let inv_z = 1.0 / t.z;
    let inv_z2 = inv_z * inv_z;
    // Clamp the in-plane offsets like the reference implementation does to
    // bound the linearization error for splats near the frustum border.
    let lim_x = 1.3 * (cam.intr.width as f32 * 0.5 / fx);
    let lim_y = 1.3 * (cam.intr.height as f32 * 0.5 / fy);
    let txz = (t.x * inv_z).clamp(-lim_x, lim_x) * t.z;
    let tyz = (t.y * inv_z).clamp(-lim_y, lim_y) * t.z;
    let j = Mat3([
        fx * inv_z, 0.0, -fx * txz * inv_z2, //
        0.0, fy * inv_z, -fy * tyz * inv_z2, //
        0.0, 0.0, 0.0,
    ]);
    let jw = j.mul(&cam.r_wc);
    let cov3 = jw.mul(&sigma3).mul(&jw.transpose());
    let cov = Sym2 {
        a: cov3.at(0, 0) + COV_DILATION,
        b: cov3.at(0, 1),
        c: cov3.at(1, 1) + COV_DILATION,
    };
    let conic = cov.inverse()?;

    let (l1, l2) = cov.eigenvalues();
    if l1 <= 0.0 {
        return None;
    }
    let radius = 3.0 * l1.sqrt();
    let axis_ratio = (l1 / l2.max(1e-9)).sqrt();

    let mean = cam.project_cam(t);
    // Off-screen beyond the radius guard → cull.
    let (w, h) = (cam.intr.width as f32, cam.intr.height as f32);
    if mean.x + radius < 0.0 || mean.x - radius > w || mean.y + radius < 0.0 || mean.y - radius > h
    {
        return None;
    }

    Some(Splat {
        id: i as u32,
        mean,
        cov,
        conic,
        depth: t.z,
        opacity: scene.opacity[i],
        color: scene.eval_color(i, cam.view_dir(p)),
        radius,
        axis_ratio,
    })
}

/// Project the whole scene; culled Gaussians are dropped.
pub fn project_scene(scene: &Scene, cam: &Camera) -> Vec<Splat> {
    (0..scene.len())
        .filter_map(|i| project_one(scene, i, cam))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::{v3, Quat, Vec3};

    fn cam() -> Camera {
        Camera::look_at(
            Intrinsics::from_fov(256, 256, 1.2),
            v3(0.0, 0.0, -6.0),
            v3(0.0, 0.0, 0.0),
            v3(0.0, 1.0, 0.0),
        )
    }

    fn one_gaussian(scale: Vec3, rot: Quat) -> Scene {
        let mut s = Scene::with_capacity(1, "t");
        s.push(v3(0.0, 0.0, 0.0), rot, scale, 0.8, [1.0, 1.0, 1.0], [[0.0; 3]; 3]);
        s
    }

    #[test]
    fn isotropic_projects_isotropic() {
        let s = one_gaussian(v3(0.2, 0.2, 0.2), Quat::IDENTITY);
        let sp = project_one(&s, 0, &cam()).unwrap();
        assert!((sp.mean.x - 128.0).abs() < 1e-2);
        assert!((sp.mean.y - 128.0).abs() < 1e-2);
        assert!((sp.axis_ratio - 1.0).abs() < 0.05, "ratio {}", sp.axis_ratio);
        assert!((sp.depth - 6.0).abs() < 1e-4);
    }

    #[test]
    fn anisotropic_is_spiky() {
        // Long axis along x (perpendicular to view) → projected ratio ≈ 3D ratio.
        let s = one_gaussian(v3(1.0, 0.1, 0.1), Quat::IDENTITY);
        let sp = project_one(&s, 0, &cam()).unwrap();
        assert!(sp.is_spiky(3.0), "ratio {}", sp.axis_ratio);
    }

    #[test]
    fn behind_camera_culled() {
        let mut s = Scene::with_capacity(1, "t");
        s.push(v3(0.0, 0.0, -20.0), Quat::IDENTITY, v3(0.3, 0.3, 0.3), 0.5, [0.5; 3], [[0.0; 3]; 3]);
        assert!(project_one(&s, 0, &cam()).is_none());
    }

    #[test]
    fn alpha_peaks_at_mean() {
        let s = one_gaussian(v3(0.3, 0.3, 0.3), Quat::IDENTITY);
        let sp = project_one(&s, 0, &cam()).unwrap();
        let a0 = sp.alpha_at(sp.mean.x, sp.mean.y);
        assert!((a0 - 0.8).abs() < 1e-4);
        let a1 = sp.alpha_at(sp.mean.x + 5.0, sp.mean.y);
        assert!(a1 < a0);
        let a2 = sp.alpha_at(sp.mean.x + 20.0, sp.mean.y);
        assert!(a2 < a1);
    }

    #[test]
    fn alpha_matches_closed_form() {
        let s = one_gaussian(v3(0.3, 0.3, 0.3), Quat::IDENTITY);
        let sp = project_one(&s, 0, &cam()).unwrap();
        let (dx, dy) = (4.0f32, -2.5f32);
        let e = 0.5 * sp.conic.quad(crate::numeric::linalg::v2(dx, dy));
        let expect = sp.opacity * (-e).exp();
        let got = sp.alpha_at(sp.mean.x + dx, sp.mean.y + dy);
        assert!((got - expect).abs() < 1e-5);
    }

    #[test]
    fn radius_covers_3sigma() {
        let s = one_gaussian(v3(0.5, 0.1, 0.1), Quat::IDENTITY);
        let sp = project_one(&s, 0, &cam()).unwrap();
        // α at distance radius along the major axis should be ≤ e^{-4.5}·o.
        let ax = sp.cov.major_axis();
        let a = sp.alpha_at(sp.mean.x + ax.x * sp.radius, sp.mean.y + ax.y * sp.radius);
        assert!(a <= sp.opacity * (-4.4f32).exp(), "a={a}");
    }

    #[test]
    fn closer_gaussian_is_bigger() {
        let mut s = Scene::with_capacity(2, "t");
        s.push(v3(0.0, 0.0, 0.0), Quat::IDENTITY, v3(0.2, 0.2, 0.2), 0.5, [0.5; 3], [[0.0; 3]; 3]);
        s.push(v3(0.0, 0.0, 6.0), Quat::IDENTITY, v3(0.2, 0.2, 0.2), 0.5, [0.5; 3], [[0.0; 3]; 3]);
        let c = cam();
        let near = project_one(&s, 0, &c).unwrap(); // depth 6
        let far = project_one(&s, 1, &c).unwrap(); // depth 12
        assert!(near.radius > far.radius * 1.5);
        assert!(far.depth > near.depth);
    }

    #[test]
    fn dilation_bounds_minimum_size() {
        // A vanishingly small Gaussian still covers ≳1 px (cov ≥ dilation).
        let s = one_gaussian(v3(1e-4, 1e-4, 1e-4), Quat::IDENTITY);
        let sp = project_one(&s, 0, &cam()).unwrap();
        assert!(sp.cov.a >= COV_DILATION);
        assert!(sp.radius >= 3.0 * COV_DILATION.sqrt() * 0.99);
    }

    #[test]
    fn project_scene_culls_and_keeps() {
        let mut s = Scene::with_capacity(2, "t");
        s.push(v3(0.0, 0.0, 0.0), Quat::IDENTITY, v3(0.2, 0.2, 0.2), 0.5, [0.5; 3], [[0.0; 3]; 3]);
        s.push(v3(0.0, 0.0, -30.0), Quat::IDENTITY, v3(0.2, 0.2, 0.2), 0.5, [0.5; 3], [[0.0; 3]; 3]);
        let splats = project_scene(&s, &cam());
        assert_eq!(splats.len(), 1);
        assert_eq!(splats[0].id, 0);
    }
}
