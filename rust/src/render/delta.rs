//! Temporal plan deltas: advance a [`FramePlan`] to the next view of a
//! smooth camera path instead of rebuilding it from scratch.
//!
//! Adjacent views on an orbit share almost all of the frame-preparation
//! structure — the per-tile membership of most splats is unchanged and the
//! depth order is only locally perturbed. [`FramePlan::advance`] exploits
//! that:
//!
//! ```text
//!   advance:  project_scene (full, new view)        — exactness anchor
//!             ├─ id-match old ↔ new splats          — O(P) two-pointer walk
//!             ├─ tile-membership diff               — exact integer ranges
//!             │    unmoved: carry the old tile entries (index-remapped)
//!             │    moved/arrived: re-bin into their new candidate range
//!             └─ per-tile depth repair              — bounded insertion pass
//!   carry-forward: the gate's per-tile pyramid geometry (camera-invariant)
//! ```
//!
//! **Bit-identity contract.** An advanced plan is *bitwise identical* to a
//! cold [`FramePlan::build`] of the same `(scene, camera, options)` triple:
//! same splat vector, same per-tile lists in the same depth order, hence
//! the same pixels and the same [`RenderStats`](super::raster::RenderStats)
//! for every backend, gated or not. Two facts make this possible:
//!
//! 1. Projection is a pure per-view map, so `advance` always re-projects
//!    the full scene — a camera move changes *every* splat's screen-space
//!    parameters, and reusing stale ones would change pixels. The
//!    incremental savings are in binning and sorting, not projection.
//!    (The conservative per-splat [`motion_bound`] models the skip
//!    threshold a hardware pipeline would use; here it is property-tested
//!    and reported, while correctness-critical work is never skipped.)
//! 2. For [`Strategy::Aabb`], a splat's tile membership equals its clamped
//!    integer candidate range exactly (`build_tile_lists` tests
//!    `intersects_aabb` only inside `candidate_range`, where it cannot
//!    fail), so "did this splat change tiles?" is an exact integer
//!    comparison, and the carried entries are exactly the cold lists'
//!    entries. Cold depth order is a stable sort by depth over
//!    ascending-index lists — i.e. ascending `(depth, index)`, a *unique*
//!    total key — so [`repair_depth_order`] reproduces it bit-for-bit from
//!    the carried near-sorted order.
//!
//! When the pose step is too large ([`DeltaConfig::max_angle`]), the grid
//! geometry differs, or the strategy is not AABB, `advance` falls back to
//! a cold build (reported in [`DeltaStats::fell_back`]). The
//! [`Session`](crate::coordinator::session::Session) plan cache uses this
//! via `RenderOptions::plan_delta` / `--plan-delta` (off by default).

use super::plan::{build_pyramids, FramePlan};
use super::project::{project_scene, Splat};
use super::raster::RenderOptions;
use super::tile::{Strategy, TileGrid};
use crate::camera::Camera;
use crate::scene::gaussian::Scene;
use std::cmp::Ordering;

/// Temporal plan-delta configuration (`RenderOptions::plan_delta`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeltaConfig {
    /// Let [`Session::plan`](crate::coordinator::session::Session::plan)
    /// advance plans from already-built neighbor views instead of always
    /// cold-building. Off by default; output is bit-identical either way.
    pub enabled: bool,
    /// Largest relative pose rotation (radians) `advance` accepts before
    /// falling back to a cold build. Direct `advance` calls honor it too.
    pub max_angle: f32,
}

impl Default for DeltaConfig {
    fn default() -> Self {
        DeltaConfig {
            enabled: false,
            // ~20°: generous for real orbit steps, small enough that the
            // carried lists are still near-sorted.
            max_angle: 0.35,
        }
    }
}

impl DeltaConfig {
    /// The default delta configuration with the session path enabled.
    pub fn on() -> DeltaConfig {
        DeltaConfig {
            enabled: true,
            ..DeltaConfig::default()
        }
    }
}

/// What one [`FramePlan::advance_detailed`] call reused vs recomputed.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaStats {
    /// The delta path was not applicable (pose jump beyond
    /// [`DeltaConfig::max_angle`], grid geometry mismatch, or a non-AABB
    /// strategy) and a cold [`FramePlan::build`] ran instead. All other
    /// counters are zero when set.
    pub fell_back: bool,
    /// Relative pose rotation between the plans' cameras, radians.
    pub pose_angle: f32,
    /// Splats whose tile membership was recomputed from scratch: newly
    /// visible ones plus those whose candidate tile range changed.
    pub splats_reprojected: usize,
    /// Tiles whose lists changed membership (lost or gained entries).
    /// Every non-empty tile still gets a depth-repair pass — depths move
    /// with the camera even when membership does not.
    pub tiles_patched: usize,
    /// (tile, splat) entries carried over from the previous plan.
    pub entries_carried: usize,
    /// Tiles whose bounded insertion repair exceeded its move budget and
    /// fell back to a full (identical-result) sort.
    pub sort_fallbacks: usize,
}

/// A delta-advanced plan plus the reuse accounting behind it.
pub struct DeltaOutcome {
    /// The next frame's plan — bitwise identical to a cold build.
    pub plan: FramePlan,
    /// What was reused vs recomputed.
    pub stats: DeltaStats,
}

/// Relative rotation angle (radians) between two camera poses, from the
/// trace of `R_b · R_aᵀ`. Zero for identical orientations, π for opposed.
pub fn pose_angle(a: &Camera, b: &Camera) -> f32 {
    let rel = b.r_wc.mul(&a.r_wc.transpose());
    let trace = rel.at(0, 0) + rel.at(1, 1) + rel.at(2, 2);
    ((trace - 1.0) * 0.5).clamp(-1.0, 1.0).acos()
}

/// Conservative bound (pixels) on how far `s`'s projected mean can move
/// when the camera goes from `prev` to `next` — the tile-crossing test a
/// skip-reprojection hardware pipeline would use, derived purely from the
/// pose delta and the splat's *previous* projection.
///
/// Derivation: with `t0 = R0(p−c0)` and `t1 = R1(p−c1) = ΔR·t0 + d`
/// (`ΔR = R1·R0ᵀ`, `d = R1(c0−c1)`), the camera-space displacement is
/// `‖t1−t0‖ ≤ 2·sin(θ/2)·‖t0‖ + ‖d‖ = ε`. Per image axis,
/// `|Δ(x/z)| ≤ ε·(1+|x0/z0|)/(z0−ε)` for `z0 > ε`, and `x0/z0` is
/// recovered from the stored mean via the shared intrinsics. The result
/// is inflated by a small safety margin so it stays an upper bound under
/// f32 rounding; `prop_motion_bound_is_conservative` checks it against
/// actual projections. Returns `f32::INFINITY` when the intrinsics differ
/// or the camera-space motion `ε` reaches the splat's depth.
pub fn motion_bound(prev: &Camera, next: &Camera, s: &Splat) -> f32 {
    if prev.intr != next.intr {
        return f32::INFINITY;
    }
    let theta = pose_angle(prev, next);
    let d = next.r_wc.mul_vec(prev.position - next.position);
    let z0 = s.depth;
    let xz = (s.mean.x - prev.intr.cx) / prev.intr.fx;
    let yz = (s.mean.y - prev.intr.cy) / prev.intr.fy;
    let t0_norm = z0 * (1.0 + xz * xz + yz * yz).sqrt();
    let eps = 2.0 * (theta * 0.5).sin() * t0_norm + d.norm();
    if !(eps < z0) {
        return f32::INFINITY;
    }
    let bu = prev.intr.fx * eps * (1.0 + xz.abs()) / (z0 - eps);
    let bv = prev.intr.fy * eps * (1.0 + yz.abs()) / (z0 - eps);
    (bu * bu + bv * bv).sqrt() * 1.05 + 0.5
}

/// Restore a tile list to the canonical cold-build depth order — ascending
/// `(depth, index)`, the unique total key equal to `sort_by_depth`'s stable
/// result — with a bounded insertion pass. Near-sorted lists (the smooth
/// camera-path case) finish in `O(n + inversions)`; a list that blows the
/// move budget falls back to a full unstable sort on the same key, which
/// produces the identical order (the key has no ties). Returns `false` iff
/// the fallback ran.
pub fn repair_depth_order(list: &mut [u32], splats: &[Splat]) -> bool {
    let n = list.len();
    if n <= 1 {
        return true;
    }
    let budget = 8 * n + 32;
    let mut moves = 0usize;
    for i in 1..n {
        let v = list[i];
        let dv = splats[v as usize].depth;
        let mut j = i;
        while j > 0 {
            let u = list[j - 1];
            let du = splats[u as usize].depth;
            // Stop once the predecessor's (depth, index) key is below v's.
            if du < dv || (du == dv && u < v) {
                break;
            }
            list[j] = u;
            j -= 1;
            moves += 1;
        }
        list[j] = v;
        if moves > budget {
            list.sort_unstable_by(|&a, &b| {
                let (da, db) = (splats[a as usize].depth, splats[b as usize].depth);
                da.partial_cmp(&db).unwrap_or(Ordering::Equal).then(a.cmp(&b))
            });
            return false;
        }
    }
    true
}

impl FramePlan {
    /// Advance this plan to `new_cam`, reusing tile membership and
    /// near-sorted depth order where the view change allows it. The result
    /// is **bitwise identical** to `FramePlan::build(scene, new_cam, opts)`
    /// — see the [module docs](self) for why. Falls back to a cold build
    /// on large pose jumps (`opts.plan_delta.max_angle`), grid geometry
    /// changes, or non-AABB strategies.
    pub fn advance(&self, scene: &Scene, new_cam: &Camera, opts: &RenderOptions) -> FramePlan {
        self.advance_detailed(scene, new_cam, opts).plan
    }

    /// [`FramePlan::advance`] plus the reuse accounting ([`DeltaStats`]) —
    /// the entry the `Session` plan cache uses for its delta counters.
    pub fn advance_detailed(
        &self,
        scene: &Scene,
        new_cam: &Camera,
        opts: &RenderOptions,
    ) -> DeltaOutcome {
        let angle = pose_angle(&self.cam, new_cam);
        let compatible = opts.tile_size == self.opts.tile_size
            && opts.strategy == Strategy::Aabb
            && self.opts.strategy == Strategy::Aabb
            && new_cam.intr.width == self.cam.intr.width
            && new_cam.intr.height == self.cam.intr.height
            && angle.is_finite()
            && angle <= opts.plan_delta.max_angle;
        if !compatible {
            return DeltaOutcome {
                plan: FramePlan::build(scene, new_cam, opts),
                stats: DeltaStats {
                    fell_back: true,
                    pose_angle: angle,
                    ..DeltaStats::default()
                },
            };
        }

        // Stage 1 — full re-projection with the new camera. This is the
        // exactness anchor: every splat's screen parameters depend on the
        // view, so the delta savings live downstream of here.
        let new_splats = project_scene(scene, new_cam);
        let grid = TileGrid::new(new_cam.intr.width, new_cam.intr.height, opts.tile_size);
        debug_assert_eq!(grid.num_tiles(), self.grid.num_tiles());

        // Stage 2 — id-match old and new splats (both ascending by id) and
        // diff tile membership. `rebin[j]` marks new splats that must be
        // re-binned: newly visible ones, or survivors whose exact integer
        // candidate range changed (for AABB, range == membership).
        let old = &self.splats;
        let ranges: Vec<(u32, u32, u32, u32)> =
            new_splats.iter().map(|s| grid.candidate_range(s)).collect();
        let mut old_to_new = vec![u32::MAX; old.len()];
        let mut rebin = vec![true; new_splats.len()];
        {
            let (mut i, mut j) = (0usize, 0usize);
            while i < old.len() && j < new_splats.len() {
                match old[i].id.cmp(&new_splats[j].id) {
                    Ordering::Less => i += 1, // culled this frame: entries drop below
                    Ordering::Greater => j += 1, // newly visible: stays marked
                    Ordering::Equal => {
                        old_to_new[i] = j as u32;
                        rebin[j] = grid.candidate_range(&old[i]) != ranges[j];
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
        let splats_reprojected = rebin.iter().filter(|&&b| b).count();

        // Stage 3 — patch tile lists: carry unmoved entries (remapped to
        // new indices, preserving the old near-sorted order), drop departed
        // and moved ones.
        let mut lists: Vec<Vec<u32>> = Vec::with_capacity(self.lists.len());
        let mut patched = vec![false; self.lists.len()];
        let mut entries_carried = 0usize;
        for (t, old_list) in self.lists.iter().enumerate() {
            let mut nl = Vec::with_capacity(old_list.len() + 2);
            for &oi in old_list {
                let j = old_to_new[oi as usize];
                if j != u32::MAX && !rebin[j as usize] {
                    nl.push(j);
                }
            }
            if nl.len() != old_list.len() {
                patched[t] = true;
            }
            entries_carried += nl.len();
            lists.push(nl);
        }
        // ... and insert the re-binned splats into their new ranges.
        for (j, r) in ranges.iter().enumerate() {
            if !rebin[j] {
                continue;
            }
            for ty in r.1..r.3 {
                for tx in r.0..r.2 {
                    let t = (ty * grid.tiles_x + tx) as usize;
                    lists[t].push(j as u32);
                    patched[t] = true;
                }
            }
        }
        let tiles_patched = patched.iter().filter(|&&p| p).count();

        // Stage 4 — local depth repair. Every non-empty tile needs it
        // (depths moved with the camera even where membership did not),
        // but the carried order is near-sorted so the pass is cheap.
        let mut sort_fallbacks = 0usize;
        for l in &mut lists {
            if !repair_depth_order(l, &new_splats) {
                sort_fallbacks += 1;
            }
        }

        // Stage 5 — carry forward the gate's per-tile pyramid geometry:
        // it is a pure function of the (unchanged) tile grid, so the whole
        // delta chain shares one copy. Per-splat gate *verdicts* are NOT
        // carried — they depend on the new view's geometry and re-deriving
        // them is what keeps gated rendering bit-identical.
        let pyramids = if opts.gate.active() {
            match &self.pyramids {
                Some(p) => Some(p.clone()),
                None => build_pyramids(&grid, &opts.gate),
            }
        } else {
            None
        };

        DeltaOutcome {
            plan: FramePlan {
                splats: new_splats,
                grid,
                lists,
                opts: *opts,
                cam: *new_cam,
                pyramids,
            },
            stats: DeltaStats {
                fell_back: false,
                pose_angle: angle,
                splats_reprojected,
                tiles_patched,
                entries_carried,
                sort_fallbacks,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{orbit_path, Intrinsics};
    use crate::numeric::linalg::v3;
    use crate::render::raster::VanillaMasks;
    use crate::render::sort::sort_by_depth;
    use crate::scene::synthetic::{generate_scaled, preset};
    use crate::util::rng::Pcg32;

    fn orbit(frames: usize) -> Vec<Camera> {
        orbit_path(
            Intrinsics::from_fov(64, 64, 1.2),
            v3(0.0, 0.5, 0.0),
            12.0,
            3.0,
            frames,
        )
    }

    #[test]
    fn pose_angle_basics() {
        let cams = orbit(8);
        assert!(pose_angle(&cams[0], &cams[0]).abs() < 1e-4);
        let step = pose_angle(&cams[0], &cams[1]);
        // Adjacent orbit views differ by roughly the orbit step (2π/8).
        assert!(step > 0.3 && step < 1.2, "step {step}");
        // Symmetric.
        assert!((step - pose_angle(&cams[1], &cams[0])).abs() < 1e-4);
    }

    #[test]
    fn repair_matches_cold_sort_from_any_permutation() {
        let scene = generate_scaled(&preset("truck"), 0.02);
        let cam = orbit(16)[1];
        let plan = FramePlan::build(&scene, &cam, &RenderOptions::default());
        let mut rng = Pcg32::new(0xDE17A);
        for (t, cold) in plan.lists.iter().enumerate().filter(|(_, l)| l.len() > 1) {
            let mut shuffled = cold.clone();
            rng.shuffle(&mut shuffled);
            repair_depth_order(&mut shuffled, &plan.splats);
            let mut resorted = shuffled.clone();
            sort_by_depth(&mut resorted, &plan.splats);
            assert_eq!(&shuffled, cold, "tile {t}");
            assert_eq!(shuffled, resorted, "tile {t} vs stable re-sort");
        }
    }

    #[test]
    fn advance_is_bit_identical_to_cold_build() {
        let scene = generate_scaled(&preset("garden"), 0.02);
        let cams = orbit(24);
        let opts = RenderOptions {
            plan_delta: DeltaConfig::on(),
            ..RenderOptions::default()
        };
        let prev = FramePlan::build(&scene, &cams[0], &opts);
        let out = prev.advance_detailed(&scene, &cams[1], &opts);
        assert!(!out.stats.fell_back, "24-view orbit step must be in range");
        let cold = FramePlan::build(&scene, &cams[1], &opts);
        assert_eq!(out.plan.lists, cold.lists);
        assert_eq!(out.plan.splats.len(), cold.splats.len());
        let a = out.plan.render(&VanillaMasks, None);
        let b = cold.render(&VanillaMasks, None);
        assert_eq!(a.image.data, b.image.data);
        assert_eq!(format!("{:?}", a.stats), format!("{:?}", b.stats));
        assert!(out.stats.entries_carried > 0, "nothing was reused");
    }

    #[test]
    fn large_pose_jump_falls_back() {
        let scene = generate_scaled(&preset("truck"), 0.02);
        let cams = orbit(3); // 120° steps — far beyond max_angle
        let opts = RenderOptions::default();
        let prev = FramePlan::build(&scene, &cams[0], &opts);
        let out = prev.advance_detailed(&scene, &cams[1], &opts);
        assert!(out.stats.fell_back);
        let cold = FramePlan::build(&scene, &cams[1], &opts);
        assert_eq!(out.plan.lists, cold.lists);
    }

    #[test]
    fn obb_strategy_falls_back() {
        let scene = generate_scaled(&preset("truck"), 0.02);
        let cams = orbit(32);
        let opts = RenderOptions {
            strategy: Strategy::Obb,
            ..RenderOptions::default()
        };
        let prev = FramePlan::build(&scene, &cams[0], &opts);
        let out = prev.advance_detailed(&scene, &cams[1], &opts);
        assert!(out.stats.fell_back, "OBB membership is not range-exact");
        assert_eq!(out.plan.lists, FramePlan::build(&scene, &cams[1], &opts).lists);
    }

    #[test]
    fn motion_bound_covers_an_orbit_step() {
        let scene = generate_scaled(&preset("garden"), 0.02);
        let cams = orbit(48);
        let a = project_scene(&scene, &cams[0]);
        let b = project_scene(&scene, &cams[1]);
        let (mut i, mut j) = (0usize, 0usize);
        let mut checked = 0usize;
        while i < a.len() && j < b.len() {
            match a[i].id.cmp(&b[j].id) {
                Ordering::Less => i += 1,
                Ordering::Greater => j += 1,
                Ordering::Equal => {
                    let moved = (b[j].mean - a[i].mean).norm();
                    let bound = motion_bound(&cams[0], &cams[1], &a[i]);
                    assert!(
                        moved <= bound,
                        "splat {}: moved {moved}px > bound {bound}px",
                        a[i].id
                    );
                    checked += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        assert!(checked > 50, "too few shared splats ({checked})");
    }
}
