//! Contribution-driven per-tile precision classing (paper Sec. IV-C made
//! adaptive).
//!
//! The paper's mixed-precision CTU (Fig. 7) is a single global knob: every
//! tile pays the same datapath cost regardless of how much it contributes
//! to the frame. This module turns that static scheme into
//! contribution-driven precision: before rendering, each tile is classed
//! by a conservative bound on the energy it can absorb — the same
//! `min_quad_on_rect` bound the coarse gate uses, folded front-to-back the
//! way the blending loop folds Σ T·α — and low-contribution tiles run the
//! cheap fp8/mixed CTU path while leader/high-energy tiles keep fp32.
//!
//! **Determinism.** [`tile_energy`] is a pure function of the prepared
//! [`super::plan::FramePlan`] (projected splats, per-tile depth-sorted
//! lists, tile rects), so the class assignment is identical for any worker
//! count and any PJRT batch width — classing happens strictly before tile
//! execution fans out.
//!
//! **Compatibility.** [`PrecisionMode::Global`] is *inert*:
//! [`PrecisionPolicy::classify`] returns `None` and every render path
//! falls through to the exact pre-policy code (global precision remains a
//! `cat::CatConfig` / `sim::HwConfig` construction-site concern), so the
//! default options are bitwise identical to a build without this module.
//! `Adaptive` is deterministic but intentionally *not* bitwise-equal to
//! any `Global` mode unless the thresholds force a single class.
//!
//! **Rect mode (paper: pixel-rectangle grouping).** [`PrecisionMode::Rect`]
//! pushes the class decision one level below the tile: the energy fold runs
//! once per tile but attributes every splat's absorbed term to the quadrant
//! rect holding its peak ([`quad_energies`]), and mid-energy tiles carry a
//! per-quadrant class map ([`TileClassMap`]) instead of one class. Low
//! tiles floor as a whole and tiles whose quadrants agree collapse back to
//! a single class, so uniform tiles render through the exact per-tile fast
//! path. Quadrant classes never exceed the tile-level class (refinement
//! only removes precision from quiet corners), which keeps the realized
//! CTU mix priced at or below the per-tile adaptive run by construction.

use super::project::{Splat, ALPHA_MIN};
use super::tile::{min_quad_on_rect, Rect};
use crate::cat::Precision;

/// The four CTU precision classes in **wave-dispatch order**: the batched
/// PJRT executor drains same-class tiles together, one class at a time, in
/// this fixed order (cheapest-last), so wave formation is deterministic.
/// Also the index order of every per-class counter array
/// (`ExecStats::fill_rate_by_class`, `FrameWorkload::ctu_prs_by_class`).
pub const CLASSES: [Precision; 4] = [
    Precision::Fp32,
    Precision::Fp16,
    Precision::Mixed,
    Precision::Fp8,
];

/// Index of a precision class into per-class counter arrays (the
/// [`CLASSES`] order).
pub fn class_index(p: Precision) -> usize {
    match p {
        Precision::Fp32 => 0,
        Precision::Fp16 => 1,
        Precision::Mixed => 2,
        Precision::Fp8 => 3,
    }
}

/// Absorbed-energy thresholds splitting the class ladder. Energies are the
/// [`tile_energy`] bound in [0, 1): a tile must be able to absorb at least
/// `fp32_min` to earn the full-precision datapath, at least `fp16_min` for
/// fp16; everything below runs the policy's floor class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionThresholds {
    /// Minimum absorbed-energy bound for an fp32-classed tile.
    pub fp32_min: f32,
    /// Minimum absorbed-energy bound for an fp16-classed tile (must not
    /// exceed `fp32_min`).
    pub fp16_min: f32,
}

impl Default for PrecisionThresholds {
    /// Defaults pinned by `rust/tests/precision.rs` on the garden/truck
    /// orbits: ≥ 40% of tiles classed below fp32 at PSNR ≥ 30 dB against
    /// the all-fp32 reference. The orbit camera keeps the object well
    /// inside the frame, so only the tiles over its dense core can absorb
    /// more than ~0.6 of the incoming light.
    fn default() -> Self {
        PrecisionThresholds {
            fp32_min: 0.60,
            fp16_min: 0.25,
        }
    }
}

impl PrecisionThresholds {
    /// Parse the CLI `--precision-thresholds` spec:
    /// `"FP32MIN,FP16MIN[,FLOOR]"` (e.g. `"0.6,0.25"` or
    /// `"0.5,0.2,fp16"`). Returns the thresholds plus the optional floor
    /// override. Rejects non-finite, negative, or mis-ordered values
    /// (`fp32_min < fp16_min`) and unknown floor names.
    pub fn parse(spec: &str) -> Option<(PrecisionThresholds, Option<Precision>)> {
        let parts: Vec<&str> = spec.split(',').map(str::trim).collect();
        if parts.len() < 2 || parts.len() > 3 {
            return None;
        }
        let fp32_min: f32 = parts[0].parse().ok()?;
        let fp16_min: f32 = parts[1].parse().ok()?;
        if !fp32_min.is_finite() || !fp16_min.is_finite() {
            return None;
        }
        if fp16_min < 0.0 || fp32_min < fp16_min {
            return None;
        }
        let floor = match parts.get(2) {
            Some(name) => Some(Precision::parse(name)?),
            None => None,
        };
        Some((PrecisionThresholds { fp32_min, fp16_min }, floor))
    }
}

/// How tiles pick their CTU precision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PrecisionMode {
    /// One global class — the paper's static scheme. Inert in the render
    /// paths ([`PrecisionPolicy::classify`] returns `None`): the global
    /// class keeps flowing through `cat::CatConfig`/`sim::HwConfig`
    /// exactly as before this module existed, so `Global` reproduces the
    /// pre-policy behavior bitwise.
    Global(Precision),
    /// Per-tile classes from the absorbed-energy bound: `≥ fp32_min` →
    /// fp32, `≥ fp16_min` → fp16, below → `floor`.
    Adaptive {
        /// The class-ladder split points.
        thresholds: PrecisionThresholds,
        /// Class for tiles below every threshold. Defaults to
        /// [`Precision::Mixed`] — the paper's FP16-delta/FP8-product
        /// datapath — because pure fp8 quantizes absolute pixel
        /// coordinates and collapses quality (Fig. 7).
        floor: Precision,
    },
    /// Second-level classing at quadrant-rectangle granularity (the
    /// paper's pixel-rectangle grouping): the tile-level ladder still runs
    /// on the total absorbed energy, but mid/high-energy tiles refine each
    /// 2×2 quadrant by its own attributed energy against the thresholds
    /// scaled to quadrant area (`fp32_min/4`, `fp16_min/4`), capped at the
    /// tile-level class. A tile with one bright splat keeps fp32 only in
    /// the quadrant that absorbs it; its dark corners drop to fp16 or the
    /// floor.
    Rect {
        /// The class-ladder split points (same vocabulary as `Adaptive`;
        /// quadrants compare at a quarter of each threshold).
        thresholds: PrecisionThresholds,
        /// Class for tiles/quadrants below every threshold.
        floor: Precision,
    },
}

/// Per-tile outcome of rect-mode classing: either one class for the whole
/// tile (the single-class fast path — low-energy tiles, saturated tiles,
/// and any tile whose four quadrants agree) or a per-quadrant map in
/// `render::pyramid` order ([TL, TR, BL, BR], bit `q = row·2 + col`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileClassMap {
    /// All four quadrants share one class; renders through the exact
    /// per-tile single-class path (bitwise, which the
    /// `tests/precision_rect.rs` differential harness pins).
    Uniform(Precision),
    /// Genuinely mixed tile: one class per quadrant.
    Mixed([Precision; 4]),
}

impl TileClassMap {
    /// Collapse a quadrant array, detecting the uniform fast path.
    pub fn from_quads(q: [Precision; 4]) -> TileClassMap {
        if q[1] == q[0] && q[2] == q[0] && q[3] == q[0] {
            TileClassMap::Uniform(q[0])
        } else {
            TileClassMap::Mixed(q)
        }
    }

    /// The single class, if the map is uniform.
    pub fn uniform(self) -> Option<Precision> {
        match self {
            TileClassMap::Uniform(c) => Some(c),
            TileClassMap::Mixed(_) => None,
        }
    }

    /// Class of quadrant `q` (pyramid order).
    pub fn quad(self, q: usize) -> Precision {
        match self {
            TileClassMap::Uniform(c) => c,
            TileClassMap::Mixed(m) => m[q],
        }
    }

    /// The four quadrant classes (pyramid order).
    pub fn quads(self) -> [Precision; 4] {
        match self {
            TileClassMap::Uniform(c) => [c; 4],
            TileClassMap::Mixed(m) => m,
        }
    }

    /// Does any quadrant run class `c`?
    pub fn has(self, c: Precision) -> bool {
        self.quads().contains(&c)
    }
}

/// The precision policy carried by `render::raster::RenderOptions` and
/// threaded to every backend (golden CAT masks, the batched PJRT
/// executor, and the `sim` workload models).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionPolicy {
    /// Global-vs-adaptive selection.
    pub mode: PrecisionMode,
}

impl Default for PrecisionPolicy {
    /// Global at the paper's default CTU precision (`Mixed`) — inert, so
    /// default options render bit-identically to earlier builds.
    fn default() -> Self {
        PrecisionPolicy::global(Precision::Mixed)
    }
}

impl PrecisionPolicy {
    /// Global policy at a fixed class.
    pub fn global(p: Precision) -> Self {
        PrecisionPolicy {
            mode: PrecisionMode::Global(p),
        }
    }

    /// Adaptive policy at the default thresholds with the `Mixed` floor.
    pub fn adaptive() -> Self {
        PrecisionPolicy {
            mode: PrecisionMode::Adaptive {
                thresholds: PrecisionThresholds::default(),
                floor: Precision::Mixed,
            },
        }
    }

    /// Rect policy (quadrant-rectangle classing) at the default thresholds
    /// with the `Mixed` floor — the same ladder vocabulary as
    /// [`PrecisionPolicy::adaptive`], refined one level down.
    pub fn rect() -> Self {
        PrecisionPolicy {
            mode: PrecisionMode::Rect {
                thresholds: PrecisionThresholds::default(),
                floor: Precision::Mixed,
            },
        }
    }

    /// Does this policy assign per-tile classes?
    pub fn is_adaptive(&self) -> bool {
        matches!(self.mode, PrecisionMode::Adaptive { .. })
    }

    /// Does this policy assign per-quadrant class maps?
    pub fn is_rect(&self) -> bool {
        matches!(self.mode, PrecisionMode::Rect { .. })
    }

    /// Parse a CLI/config policy name: `"adaptive"` or `"rect"` (any
    /// case) or a global class name accepted by [`Precision::parse`].
    pub fn parse(s: &str) -> Option<PrecisionPolicy> {
        if s.eq_ignore_ascii_case("adaptive") {
            return Some(PrecisionPolicy::adaptive());
        }
        if s.eq_ignore_ascii_case("rect") {
            return Some(PrecisionPolicy::rect());
        }
        Precision::parse(s).map(PrecisionPolicy::global)
    }

    /// Stable policy name for reports and errors.
    pub fn name(&self) -> &'static str {
        match self.mode {
            PrecisionMode::Adaptive { .. } => "adaptive",
            PrecisionMode::Rect { .. } => "rect",
            PrecisionMode::Global(Precision::Fp32) => "fp32",
            PrecisionMode::Global(Precision::Fp16) => "fp16",
            PrecisionMode::Global(Precision::Fp8) => "fp8",
            PrecisionMode::Global(Precision::Mixed) => "mixed",
        }
    }

    /// Class a tile by its absorbed-energy bound. `None` under `Global` —
    /// the caller must fall through to its pre-policy path (that
    /// fall-through is what keeps `Global` bitwise-identical to builds
    /// without the policy). Under `Rect` this is the tile-*level* class:
    /// the cap no quadrant may exceed (used by list-level consumers that
    /// need one class per tile, e.g. contribution scoring).
    pub fn classify(&self, energy: f32) -> Option<Precision> {
        match self.mode {
            PrecisionMode::Global(_) => None,
            PrecisionMode::Adaptive { thresholds, floor }
            | PrecisionMode::Rect { thresholds, floor } => {
                Some(level_class(ladder_level(energy, &thresholds), floor))
            }
        }
    }

    /// Class one tile's quadrants from their attributed energies
    /// ([`quad_energies`]). `None` unless the mode is `Rect`.
    ///
    /// The tile-level ladder runs on the fixed-order total
    /// ([`quad_energy_total`]): tiles below `fp16_min` floor as a whole
    /// (the low-energy fast path). Otherwise each quadrant is laddered at
    /// a quarter of the thresholds — a quadrant holding a full
    /// tile-quarter's worth of the split point earns the class — and
    /// capped at the tile-level class, so refinement only moves precision
    /// *down* relative to the per-tile adaptive policy. Saturated tiles
    /// whose every quadrant clears the scaled fp32 bar collapse back to
    /// `Uniform(Fp32)` (the high-energy fast path).
    pub fn classify_quads(&self, quad_energies: &[f32; 4]) -> Option<TileClassMap> {
        let PrecisionMode::Rect { thresholds, floor } = self.mode else {
            return None;
        };
        let total = quad_energy_total(quad_energies);
        let tile_level = ladder_level(total, &thresholds);
        if tile_level == 0 {
            return Some(TileClassMap::Uniform(floor));
        }
        let quads = std::array::from_fn(|q| {
            let level = ladder_level(quad_energies[q] * 4.0, &thresholds).min(tile_level);
            level_class(level, floor)
        });
        Some(TileClassMap::from_quads(quads))
    }
}

/// The shared class ladder as a rung index: 2 = fp32, 1 = fp16, 0 = floor.
fn ladder_level(energy: f32, t: &PrecisionThresholds) -> u8 {
    if energy >= t.fp32_min {
        2
    } else if energy >= t.fp16_min {
        1
    } else {
        0
    }
}

/// Map a ladder rung back to its precision class.
fn level_class(level: u8, floor: Precision) -> Precision {
    match level {
        2 => Precision::Fp32,
        1 => Precision::Fp16,
        _ => floor,
    }
}

/// Conservative bound on the energy a tile can absorb: fold the tile's
/// depth-sorted splat list front-to-back, giving every splat its **peak**
/// in-tile alpha `min(0.999, o·e^{-min E})` — the same
/// [`min_quad_on_rect`] bound the coarse gate uses — and accumulate
/// Σ T·α exactly the way the blending loop folds contribution scores.
/// Splats whose peak alpha sits below the 1/255 blend floor contribute
/// nothing (they are exactly the pairs the lossless gate drops), and the
/// fold stops at the loop's `T < 1e-4` early-termination point.
///
/// The result lies in [0, 1): 0 for empty/dead tiles, approaching 1 for
/// tiles whose splat stack saturates every pixel. It over-estimates real
/// absorption (every splat is scored at its best pixel), which is the safe
/// direction: tiles are promoted toward fp32, never demoted past it.
pub fn tile_energy(splats: &[Splat], list: &[u32], rect: &Rect) -> f32 {
    let mut trans = 1.0f32;
    let mut energy = 0.0f32;
    for &si in list {
        let s = &splats[si as usize];
        let peak = (s.opacity * (-min_quad_on_rect(s, rect)).exp()).min(0.999);
        if peak < ALPHA_MIN {
            continue;
        }
        energy += trans * peak;
        trans *= 1.0 - peak;
        if trans < 1e-4 {
            break;
        }
    }
    energy
}

/// Per-quadrant absorbed-energy bounds for rect-mode classing: the same
/// single front-to-back fold as [`tile_energy`], but each surviving
/// splat's whole `T·α` term is attributed to the **first quadrant (pyramid
/// order) achieving the tile-minimum** of the quadratic form — the
/// quadrant holding the splat's peak. Because the quadrants tile the rect
/// exactly, the minimum over the four (non-degenerate) quadrant minima *is*
/// the minimum over the tile, so the peak alphas, the skip decisions, and
/// the transmittance sequence are those of a whole-tile fold.
///
/// **Exactness invariant** (pinned by `tests/properties.rs`): every term
/// lands in exactly one accumulator, so the quadrant energies sum to the
/// tile's total *in the same fold order* — [`quad_energy_total`] is the
/// rect policy's tile energy, and it equals the sum of the four entries
/// bitwise, by construction.
///
/// Degenerate quadrants of edge tiles (zero-area rects) are skipped in the
/// min scan and stay at 0: the live quadrants still cover the whole tile.
pub fn quad_energies(splats: &[Splat], list: &[u32], quads: &[Rect; 4]) -> [f32; 4] {
    let mut trans = 1.0f32;
    let mut energy = [0.0f32; 4];
    for &si in list {
        let s = &splats[si as usize];
        let mut min_e = f32::INFINITY;
        let mut at = 0usize;
        for (q, rect) in quads.iter().enumerate() {
            if rect.x1 <= rect.x0 || rect.y1 <= rect.y0 {
                continue;
            }
            let e = min_quad_on_rect(s, rect);
            if e < min_e {
                min_e = e;
                at = q;
            }
        }
        let peak = (s.opacity * (-min_e).exp()).min(0.999);
        if peak < ALPHA_MIN {
            continue;
        }
        energy[at] += trans * peak;
        trans *= 1.0 - peak;
        if trans < 1e-4 {
            break;
        }
    }
    energy
}

/// The rect policy's tile energy: the four quadrant energies summed in
/// fixed pyramid order. This is the quantity the tile-level ladder runs on
/// in [`PrecisionPolicy::classify_quads`], and by construction it equals
/// the sum of [`quad_energies`]'s entries bitwise — the "quadrant energies
/// sum to the tile energy exactly" property.
pub fn quad_energy_total(quads: &[f32; 4]) -> f32 {
    ((quads[0] + quads[1]) + quads[2]) + quads[3]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::linalg::{v2, Sym2};

    fn splat(mx: f32, my: f32, opacity: f32) -> Splat {
        Splat {
            id: 0,
            mean: v2(mx, my),
            cov: Sym2 { a: 1.0, b: 0.0, c: 1.0 },
            conic: Sym2 { a: 0.5, b: 0.0, c: 0.5 },
            depth: 1.0,
            opacity,
            color: [1.0; 3],
            radius: 10.0,
            axis_ratio: 1.0,
        }
    }

    fn rect() -> Rect {
        Rect { x0: 0.0, y0: 0.0, x1: 16.0, y1: 16.0 }
    }

    #[test]
    fn global_policy_is_inert() {
        for p in CLASSES {
            let policy = PrecisionPolicy::global(p);
            assert!(!policy.is_adaptive());
            assert_eq!(policy.classify(0.0), None);
            assert_eq!(policy.classify(0.99), None);
        }
    }

    #[test]
    fn adaptive_ladder_orders_classes() {
        let policy = PrecisionPolicy::adaptive();
        assert!(policy.is_adaptive());
        assert_eq!(policy.classify(0.95), Some(Precision::Fp32));
        assert_eq!(policy.classify(0.60), Some(Precision::Fp32));
        assert_eq!(policy.classify(0.40), Some(Precision::Fp16));
        assert_eq!(policy.classify(0.25), Some(Precision::Fp16));
        assert_eq!(policy.classify(0.10), Some(Precision::Mixed));
        assert_eq!(policy.classify(0.0), Some(Precision::Mixed));
    }

    #[test]
    fn thresholds_forced_to_zero_class_everything_fp32() {
        let policy = PrecisionPolicy {
            mode: PrecisionMode::Adaptive {
                thresholds: PrecisionThresholds { fp32_min: 0.0, fp16_min: 0.0 },
                floor: Precision::Fp8,
            },
        };
        for e in [0.0f32, 0.1, 0.5, 0.999] {
            assert_eq!(policy.classify(e), Some(Precision::Fp32), "e={e}");
        }
    }

    #[test]
    fn parse_accepts_names_and_rejects_junk() {
        assert_eq!(PrecisionPolicy::parse("adaptive"), Some(PrecisionPolicy::adaptive()));
        assert_eq!(PrecisionPolicy::parse("ADAPTIVE"), Some(PrecisionPolicy::adaptive()));
        assert_eq!(
            PrecisionPolicy::parse("fp32"),
            Some(PrecisionPolicy::global(Precision::Fp32))
        );
        assert_eq!(
            PrecisionPolicy::parse("Mixed"),
            Some(PrecisionPolicy::global(Precision::Mixed))
        );
        assert_eq!(PrecisionPolicy::parse("int4"), None);
        assert_eq!(PrecisionPolicy::parse(""), None);
    }

    #[test]
    fn policy_names_roundtrip() {
        for name in ["fp32", "fp16", "fp8", "mixed", "adaptive", "rect"] {
            let p = PrecisionPolicy::parse(name).unwrap();
            assert_eq!(p.name(), name);
        }
        assert!(PrecisionPolicy::parse("rect").unwrap().is_rect());
        assert!(!PrecisionPolicy::parse("rect").unwrap().is_adaptive());
        assert!(!PrecisionPolicy::parse("adaptive").unwrap().is_rect());
    }

    #[test]
    fn rect_tile_ladder_matches_adaptive() {
        // The tile-*level* class under rect is the same ladder adaptive
        // runs — it is the cap quadrants may not exceed.
        let rect = PrecisionPolicy::rect();
        let adaptive = PrecisionPolicy::adaptive();
        for e in [0.0f32, 0.1, 0.25, 0.4, 0.6, 0.95] {
            assert_eq!(rect.classify(e), adaptive.classify(e), "e={e}");
        }
    }

    #[test]
    fn rect_low_band_floors_uniformly() {
        let p = PrecisionPolicy::rect();
        // Total below fp16_min: uniform floor even with one concentrated
        // quadrant (a dark tile's bright corner still cannot matter).
        assert_eq!(
            p.classify_quads(&[0.2, 0.0, 0.0, 0.0]),
            Some(TileClassMap::Uniform(Precision::Mixed))
        );
        // Global/adaptive policies never produce maps.
        assert_eq!(PrecisionPolicy::default().classify_quads(&[0.9; 4]), None);
        assert_eq!(PrecisionPolicy::adaptive().classify_quads(&[0.9; 4]), None);
    }

    #[test]
    fn rect_refines_mid_and_high_tiles_per_quadrant() {
        let p = PrecisionPolicy::rect();
        // High tile (total 0.8 ≥ 0.60), one bright quadrant: fp32 stays
        // only where the energy is; the dark corners drop.
        let m = p.classify_quads(&[0.7, 0.08, 0.02, 0.0]).unwrap();
        assert_eq!(
            m,
            TileClassMap::Mixed([
                Precision::Fp32,  // 0.7·4 = 2.8 ≥ 0.60
                Precision::Fp16,  // 0.08·4 = 0.32 ≥ 0.25
                Precision::Mixed, // 0.02·4 = 0.08 < 0.25
                Precision::Mixed,
            ])
        );
        // Saturated everywhere: collapses to the uniform fp32 fast path.
        assert_eq!(
            p.classify_quads(&[0.24; 4]),
            Some(TileClassMap::Uniform(Precision::Fp32))
        );
        // Mid tile (fp16 band): quadrants are capped at fp16 even when one
        // concentrates enough energy to ladder fp32 on its own.
        let m = p.classify_quads(&[0.4, 0.05, 0.0, 0.0]).unwrap();
        assert_eq!(
            m,
            TileClassMap::Mixed([
                Precision::Fp16, // capped by the tile-level fp16 band
                Precision::Mixed,
                Precision::Mixed,
                Precision::Mixed,
            ])
        );
    }

    #[test]
    fn class_map_accessors_roundtrip() {
        let u = TileClassMap::from_quads([Precision::Fp16; 4]);
        assert_eq!(u, TileClassMap::Uniform(Precision::Fp16));
        assert_eq!(u.uniform(), Some(Precision::Fp16));
        assert_eq!(u.quads(), [Precision::Fp16; 4]);
        assert!(u.has(Precision::Fp16) && !u.has(Precision::Fp32));
        let quads = [
            Precision::Fp32,
            Precision::Fp16,
            Precision::Mixed,
            Precision::Fp16,
        ];
        let m = TileClassMap::from_quads(quads);
        assert_eq!(m, TileClassMap::Mixed(quads));
        assert_eq!(m.uniform(), None);
        for q in 0..4 {
            assert_eq!(m.quad(q), quads[q]);
        }
        assert!(m.has(Precision::Fp32) && m.has(Precision::Mixed) && !m.has(Precision::Fp8));
    }

    #[test]
    fn quad_energies_attribute_terms_to_the_peak_quadrant() {
        use crate::render::pyramid::TilePyramid;
        let r = rect();
        let pyr = TilePyramid::new(&r, 16);
        // A splat centered in the TL quadrant: its whole term lands there.
        let s = vec![splat(4.0, 4.0, 0.7)];
        let q = quad_energies(&s, &[0], pyr.quad_rects());
        assert!((q[0] - 0.7).abs() < 1e-6, "q={q:?}");
        assert_eq!(q[1], 0.0);
        assert_eq!(q[2], 0.0);
        assert_eq!(q[3], 0.0);
        // The fixed-order total is the bitwise sum by construction, and it
        // tracks the whole-tile fold closely (same peaks, same skips).
        let total = quad_energy_total(&q);
        assert_eq!(total, ((q[0] + q[1]) + q[2]) + q[3]);
        let tile = tile_energy(&s, &[0], &r);
        assert!((total - tile).abs() < 1e-6, "total={total} tile={tile}");
        // Two splats in different quadrants: front-to-back transmittance is
        // shared across quadrants — the BR term is scaled by TL's absorb.
        let s2 = vec![splat(4.0, 4.0, 0.5), splat(12.0, 12.0, 0.5)];
        let q2 = quad_energies(&s2, &[0, 1], pyr.quad_rects());
        assert!((q2[0] - 0.5).abs() < 1e-6, "q2={q2:?}");
        assert!((q2[3] - 0.25).abs() < 1e-6, "q2={q2:?}");
        // Empty list: all zeros.
        assert_eq!(quad_energies(&s2, &[], pyr.quad_rects()), [0.0; 4]);
    }

    #[test]
    fn threshold_spec_parses_and_validates() {
        let (t, floor) = PrecisionThresholds::parse("0.6,0.25").unwrap();
        assert_eq!(t, PrecisionThresholds::default());
        assert_eq!(floor, None);
        let (t, floor) = PrecisionThresholds::parse("0.5, 0.2, fp16").unwrap();
        assert_eq!(t.fp32_min, 0.5);
        assert_eq!(t.fp16_min, 0.2);
        assert_eq!(floor, Some(Precision::Fp16));
        // Zeroed thresholds (the force-fp32 property config) are valid.
        assert!(PrecisionThresholds::parse("0,0").is_some());
        // Mis-ordered, negative, non-finite, junk floor, wrong arity.
        assert!(PrecisionThresholds::parse("0.2,0.6").is_none());
        assert!(PrecisionThresholds::parse("-0.1,-0.2").is_none());
        assert!(PrecisionThresholds::parse("nan,0.1").is_none());
        assert!(PrecisionThresholds::parse("0.6,0.25,int4").is_none());
        assert!(PrecisionThresholds::parse("0.6").is_none());
        assert!(PrecisionThresholds::parse("0.6,0.3,fp16,extra").is_none());
    }

    #[test]
    fn tile_energy_bounds_and_monotonicity() {
        let r = rect();
        assert_eq!(tile_energy(&[], &[], &r), 0.0);
        // One splat centered in the tile: energy == its (clamped) opacity.
        let s = vec![splat(8.0, 8.0, 0.7)];
        let e1 = tile_energy(&s, &[0], &r);
        assert!((e1 - 0.7).abs() < 1e-6, "e1={e1}");
        // Stacking a second absorber raises the bound, but never past 1.
        let s2 = vec![splat(8.0, 8.0, 0.7), splat(8.0, 8.0, 0.7)];
        let e2 = tile_energy(&s2, &[0, 1], &r);
        assert!(e2 > e1 && e2 < 1.0, "e2={e2}");
        // A far-away splat is gated by its peak alpha and contributes 0.
        let far = vec![splat(500.0, 500.0, 0.9)];
        assert_eq!(tile_energy(&far, &[0], &r), 0.0);
        // Sub-floor opacity contributes 0 as well.
        let dim = vec![splat(8.0, 8.0, 0.5 / 255.0)];
        assert_eq!(tile_energy(&dim, &[0], &r), 0.0);
    }

    #[test]
    fn class_index_matches_dispatch_order() {
        for (i, c) in CLASSES.iter().enumerate() {
            assert_eq!(class_index(*c), i);
        }
    }
}
