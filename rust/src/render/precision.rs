//! Contribution-driven per-tile precision classing (paper Sec. IV-C made
//! adaptive).
//!
//! The paper's mixed-precision CTU (Fig. 7) is a single global knob: every
//! tile pays the same datapath cost regardless of how much it contributes
//! to the frame. This module turns that static scheme into
//! contribution-driven precision: before rendering, each tile is classed
//! by a conservative bound on the energy it can absorb — the same
//! `min_quad_on_rect` bound the coarse gate uses, folded front-to-back the
//! way the blending loop folds Σ T·α — and low-contribution tiles run the
//! cheap fp8/mixed CTU path while leader/high-energy tiles keep fp32.
//!
//! **Determinism.** [`tile_energy`] is a pure function of the prepared
//! [`super::plan::FramePlan`] (projected splats, per-tile depth-sorted
//! lists, tile rects), so the class assignment is identical for any worker
//! count and any PJRT batch width — classing happens strictly before tile
//! execution fans out.
//!
//! **Compatibility.** [`PrecisionMode::Global`] is *inert*:
//! [`PrecisionPolicy::classify`] returns `None` and every render path
//! falls through to the exact pre-policy code (global precision remains a
//! `cat::CatConfig` / `sim::HwConfig` construction-site concern), so the
//! default options are bitwise identical to a build without this module.
//! `Adaptive` is deterministic but intentionally *not* bitwise-equal to
//! any `Global` mode unless the thresholds force a single class.

use super::project::{Splat, ALPHA_MIN};
use super::tile::{min_quad_on_rect, Rect};
use crate::cat::Precision;

/// The four CTU precision classes in **wave-dispatch order**: the batched
/// PJRT executor drains same-class tiles together, one class at a time, in
/// this fixed order (cheapest-last), so wave formation is deterministic.
/// Also the index order of every per-class counter array
/// (`ExecStats::fill_rate_by_class`, `FrameWorkload::ctu_prs_by_class`).
pub const CLASSES: [Precision; 4] = [
    Precision::Fp32,
    Precision::Fp16,
    Precision::Mixed,
    Precision::Fp8,
];

/// Index of a precision class into per-class counter arrays (the
/// [`CLASSES`] order).
pub fn class_index(p: Precision) -> usize {
    match p {
        Precision::Fp32 => 0,
        Precision::Fp16 => 1,
        Precision::Mixed => 2,
        Precision::Fp8 => 3,
    }
}

/// Absorbed-energy thresholds splitting the class ladder. Energies are the
/// [`tile_energy`] bound in [0, 1): a tile must be able to absorb at least
/// `fp32_min` to earn the full-precision datapath, at least `fp16_min` for
/// fp16; everything below runs the policy's floor class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionThresholds {
    /// Minimum absorbed-energy bound for an fp32-classed tile.
    pub fp32_min: f32,
    /// Minimum absorbed-energy bound for an fp16-classed tile (must not
    /// exceed `fp32_min`).
    pub fp16_min: f32,
}

impl Default for PrecisionThresholds {
    /// Defaults pinned by `rust/tests/precision.rs` on the garden/truck
    /// orbits: ≥ 40% of tiles classed below fp32 at PSNR ≥ 30 dB against
    /// the all-fp32 reference. The orbit camera keeps the object well
    /// inside the frame, so only the tiles over its dense core can absorb
    /// more than ~0.6 of the incoming light.
    fn default() -> Self {
        PrecisionThresholds {
            fp32_min: 0.60,
            fp16_min: 0.25,
        }
    }
}

impl PrecisionThresholds {
    /// Parse the CLI `--precision-thresholds` spec:
    /// `"FP32MIN,FP16MIN[,FLOOR]"` (e.g. `"0.6,0.25"` or
    /// `"0.5,0.2,fp16"`). Returns the thresholds plus the optional floor
    /// override. Rejects non-finite, negative, or mis-ordered values
    /// (`fp32_min < fp16_min`) and unknown floor names.
    pub fn parse(spec: &str) -> Option<(PrecisionThresholds, Option<Precision>)> {
        let parts: Vec<&str> = spec.split(',').map(str::trim).collect();
        if parts.len() < 2 || parts.len() > 3 {
            return None;
        }
        let fp32_min: f32 = parts[0].parse().ok()?;
        let fp16_min: f32 = parts[1].parse().ok()?;
        if !fp32_min.is_finite() || !fp16_min.is_finite() {
            return None;
        }
        if fp16_min < 0.0 || fp32_min < fp16_min {
            return None;
        }
        let floor = match parts.get(2) {
            Some(name) => Some(Precision::parse(name)?),
            None => None,
        };
        Some((PrecisionThresholds { fp32_min, fp16_min }, floor))
    }
}

/// How tiles pick their CTU precision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PrecisionMode {
    /// One global class — the paper's static scheme. Inert in the render
    /// paths ([`PrecisionPolicy::classify`] returns `None`): the global
    /// class keeps flowing through `cat::CatConfig`/`sim::HwConfig`
    /// exactly as before this module existed, so `Global` reproduces the
    /// pre-policy behavior bitwise.
    Global(Precision),
    /// Per-tile classes from the absorbed-energy bound: `≥ fp32_min` →
    /// fp32, `≥ fp16_min` → fp16, below → `floor`.
    Adaptive {
        /// The class-ladder split points.
        thresholds: PrecisionThresholds,
        /// Class for tiles below every threshold. Defaults to
        /// [`Precision::Mixed`] — the paper's FP16-delta/FP8-product
        /// datapath — because pure fp8 quantizes absolute pixel
        /// coordinates and collapses quality (Fig. 7).
        floor: Precision,
    },
}

/// The precision policy carried by `render::raster::RenderOptions` and
/// threaded to every backend (golden CAT masks, the batched PJRT
/// executor, and the `sim` workload models).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrecisionPolicy {
    /// Global-vs-adaptive selection.
    pub mode: PrecisionMode,
}

impl Default for PrecisionPolicy {
    /// Global at the paper's default CTU precision (`Mixed`) — inert, so
    /// default options render bit-identically to earlier builds.
    fn default() -> Self {
        PrecisionPolicy::global(Precision::Mixed)
    }
}

impl PrecisionPolicy {
    /// Global policy at a fixed class.
    pub fn global(p: Precision) -> Self {
        PrecisionPolicy {
            mode: PrecisionMode::Global(p),
        }
    }

    /// Adaptive policy at the default thresholds with the `Mixed` floor.
    pub fn adaptive() -> Self {
        PrecisionPolicy {
            mode: PrecisionMode::Adaptive {
                thresholds: PrecisionThresholds::default(),
                floor: Precision::Mixed,
            },
        }
    }

    /// Does this policy assign per-tile classes?
    pub fn is_adaptive(&self) -> bool {
        matches!(self.mode, PrecisionMode::Adaptive { .. })
    }

    /// Parse a CLI/config policy name: `"adaptive"` (any case) or a
    /// global class name accepted by [`Precision::parse`].
    pub fn parse(s: &str) -> Option<PrecisionPolicy> {
        if s.eq_ignore_ascii_case("adaptive") {
            return Some(PrecisionPolicy::adaptive());
        }
        Precision::parse(s).map(PrecisionPolicy::global)
    }

    /// Stable policy name for reports and errors.
    pub fn name(&self) -> &'static str {
        match self.mode {
            PrecisionMode::Adaptive { .. } => "adaptive",
            PrecisionMode::Global(Precision::Fp32) => "fp32",
            PrecisionMode::Global(Precision::Fp16) => "fp16",
            PrecisionMode::Global(Precision::Fp8) => "fp8",
            PrecisionMode::Global(Precision::Mixed) => "mixed",
        }
    }

    /// Class a tile by its absorbed-energy bound. `None` under `Global` —
    /// the caller must fall through to its pre-policy path (that
    /// fall-through is what keeps `Global` bitwise-identical to builds
    /// without the policy).
    pub fn classify(&self, energy: f32) -> Option<Precision> {
        match self.mode {
            PrecisionMode::Global(_) => None,
            PrecisionMode::Adaptive { thresholds, floor } => Some(if energy >= thresholds.fp32_min
            {
                Precision::Fp32
            } else if energy >= thresholds.fp16_min {
                Precision::Fp16
            } else {
                floor
            }),
        }
    }
}

/// Conservative bound on the energy a tile can absorb: fold the tile's
/// depth-sorted splat list front-to-back, giving every splat its **peak**
/// in-tile alpha `min(0.999, o·e^{-min E})` — the same
/// [`min_quad_on_rect`] bound the coarse gate uses — and accumulate
/// Σ T·α exactly the way the blending loop folds contribution scores.
/// Splats whose peak alpha sits below the 1/255 blend floor contribute
/// nothing (they are exactly the pairs the lossless gate drops), and the
/// fold stops at the loop's `T < 1e-4` early-termination point.
///
/// The result lies in [0, 1): 0 for empty/dead tiles, approaching 1 for
/// tiles whose splat stack saturates every pixel. It over-estimates real
/// absorption (every splat is scored at its best pixel), which is the safe
/// direction: tiles are promoted toward fp32, never demoted past it.
pub fn tile_energy(splats: &[Splat], list: &[u32], rect: &Rect) -> f32 {
    let mut trans = 1.0f32;
    let mut energy = 0.0f32;
    for &si in list {
        let s = &splats[si as usize];
        let peak = (s.opacity * (-min_quad_on_rect(s, rect)).exp()).min(0.999);
        if peak < ALPHA_MIN {
            continue;
        }
        energy += trans * peak;
        trans *= 1.0 - peak;
        if trans < 1e-4 {
            break;
        }
    }
    energy
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::linalg::{v2, Sym2};

    fn splat(mx: f32, my: f32, opacity: f32) -> Splat {
        Splat {
            id: 0,
            mean: v2(mx, my),
            cov: Sym2 { a: 1.0, b: 0.0, c: 1.0 },
            conic: Sym2 { a: 0.5, b: 0.0, c: 0.5 },
            depth: 1.0,
            opacity,
            color: [1.0; 3],
            radius: 10.0,
            axis_ratio: 1.0,
        }
    }

    fn rect() -> Rect {
        Rect { x0: 0.0, y0: 0.0, x1: 16.0, y1: 16.0 }
    }

    #[test]
    fn global_policy_is_inert() {
        for p in CLASSES {
            let policy = PrecisionPolicy::global(p);
            assert!(!policy.is_adaptive());
            assert_eq!(policy.classify(0.0), None);
            assert_eq!(policy.classify(0.99), None);
        }
    }

    #[test]
    fn adaptive_ladder_orders_classes() {
        let policy = PrecisionPolicy::adaptive();
        assert!(policy.is_adaptive());
        assert_eq!(policy.classify(0.95), Some(Precision::Fp32));
        assert_eq!(policy.classify(0.60), Some(Precision::Fp32));
        assert_eq!(policy.classify(0.40), Some(Precision::Fp16));
        assert_eq!(policy.classify(0.25), Some(Precision::Fp16));
        assert_eq!(policy.classify(0.10), Some(Precision::Mixed));
        assert_eq!(policy.classify(0.0), Some(Precision::Mixed));
    }

    #[test]
    fn thresholds_forced_to_zero_class_everything_fp32() {
        let policy = PrecisionPolicy {
            mode: PrecisionMode::Adaptive {
                thresholds: PrecisionThresholds { fp32_min: 0.0, fp16_min: 0.0 },
                floor: Precision::Fp8,
            },
        };
        for e in [0.0f32, 0.1, 0.5, 0.999] {
            assert_eq!(policy.classify(e), Some(Precision::Fp32), "e={e}");
        }
    }

    #[test]
    fn parse_accepts_names_and_rejects_junk() {
        assert_eq!(PrecisionPolicy::parse("adaptive"), Some(PrecisionPolicy::adaptive()));
        assert_eq!(PrecisionPolicy::parse("ADAPTIVE"), Some(PrecisionPolicy::adaptive()));
        assert_eq!(
            PrecisionPolicy::parse("fp32"),
            Some(PrecisionPolicy::global(Precision::Fp32))
        );
        assert_eq!(
            PrecisionPolicy::parse("Mixed"),
            Some(PrecisionPolicy::global(Precision::Mixed))
        );
        assert_eq!(PrecisionPolicy::parse("int4"), None);
        assert_eq!(PrecisionPolicy::parse(""), None);
    }

    #[test]
    fn policy_names_roundtrip() {
        for name in ["fp32", "fp16", "fp8", "mixed", "adaptive"] {
            let p = PrecisionPolicy::parse(name).unwrap();
            assert_eq!(p.name(), name);
        }
    }

    #[test]
    fn threshold_spec_parses_and_validates() {
        let (t, floor) = PrecisionThresholds::parse("0.6,0.25").unwrap();
        assert_eq!(t, PrecisionThresholds::default());
        assert_eq!(floor, None);
        let (t, floor) = PrecisionThresholds::parse("0.5, 0.2, fp16").unwrap();
        assert_eq!(t.fp32_min, 0.5);
        assert_eq!(t.fp16_min, 0.2);
        assert_eq!(floor, Some(Precision::Fp16));
        // Zeroed thresholds (the force-fp32 property config) are valid.
        assert!(PrecisionThresholds::parse("0,0").is_some());
        // Mis-ordered, negative, non-finite, junk floor, wrong arity.
        assert!(PrecisionThresholds::parse("0.2,0.6").is_none());
        assert!(PrecisionThresholds::parse("-0.1,-0.2").is_none());
        assert!(PrecisionThresholds::parse("nan,0.1").is_none());
        assert!(PrecisionThresholds::parse("0.6,0.25,int4").is_none());
        assert!(PrecisionThresholds::parse("0.6").is_none());
        assert!(PrecisionThresholds::parse("0.6,0.3,fp16,extra").is_none());
    }

    #[test]
    fn tile_energy_bounds_and_monotonicity() {
        let r = rect();
        assert_eq!(tile_energy(&[], &[], &r), 0.0);
        // One splat centered in the tile: energy == its (clamped) opacity.
        let s = vec![splat(8.0, 8.0, 0.7)];
        let e1 = tile_energy(&s, &[0], &r);
        assert!((e1 - 0.7).abs() < 1e-6, "e1={e1}");
        // Stacking a second absorber raises the bound, but never past 1.
        let s2 = vec![splat(8.0, 8.0, 0.7), splat(8.0, 8.0, 0.7)];
        let e2 = tile_energy(&s2, &[0, 1], &r);
        assert!(e2 > e1 && e2 < 1.0, "e2={e2}");
        // A far-away splat is gated by its peak alpha and contributes 0.
        let far = vec![splat(500.0, 500.0, 0.9)];
        assert_eq!(tile_energy(&far, &[0], &r), 0.0);
        // Sub-floor opacity contributes 0 as well.
        let dim = vec![splat(8.0, 8.0, 0.5 / 255.0)];
        assert_eq!(tile_energy(&dim, &[0], &r), 0.0);
    }

    #[test]
    fn class_index_matches_dispatch_order() {
        for (i, c) in CLASSES.iter().enumerate() {
            assert_eq!(class_index(*c), i);
        }
    }
}
