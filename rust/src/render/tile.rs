//! Tiling and tile↔splat intersection tests, paper Fig. 2(b).
//!
//! Three intersection strategies are implemented:
//! * **AABB** — vanilla 3DGS: axis-aligned 3σ bounding box vs tile rect.
//! * **OBB**  — GSCore [7]: oriented bounding box aligned to the splat's
//!   eigenbasis, tested with the separating-axis theorem; much tighter for
//!   spiky splats.
//! * sub-tile refinement — GSCore splits tiles into 8×8 sub-tiles; FLICKER's
//!   hierarchical Stage-1 uses the same AABB-at-sub-tile-granularity test.
//!
//! The contribution-level test (Mini-Tile CAT) lives in `crate::cat`.

use super::project::Splat;
use crate::numeric::linalg::{v2, Vec2};

/// Pixel rectangle [x0, x1) × [y0, y1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rect {
    /// Left edge (inclusive).
    pub x0: f32,
    /// Top edge (inclusive).
    pub y0: f32,
    /// Right edge (exclusive).
    pub x1: f32,
    /// Bottom edge (exclusive).
    pub y1: f32,
}

impl Rect {
    /// Rect of tile `(tx, ty)` in a grid of `size`-pixel tiles.
    pub fn tile(tx: u32, ty: u32, size: u32) -> Rect {
        Rect {
            x0: (tx * size) as f32,
            y0: (ty * size) as f32,
            x1: ((tx + 1) * size) as f32,
            y1: ((ty + 1) * size) as f32,
        }
    }

    /// Center point.
    pub fn center(&self) -> Vec2 {
        v2(0.5 * (self.x0 + self.x1), 0.5 * (self.y0 + self.y1))
    }

    /// Half width/height.
    pub fn half_extent(&self) -> Vec2 {
        v2(0.5 * (self.x1 - self.x0), 0.5 * (self.y1 - self.y0))
    }
}

/// Grid geometry for an image tiled at `tile` pixels.
#[derive(Clone, Copy, Debug)]
pub struct TileGrid {
    /// Image width (pixels).
    pub width: u32,
    /// Image height (pixels).
    pub height: u32,
    /// Tile edge (pixels).
    pub tile: u32,
    /// Number of tile columns.
    pub tiles_x: u32,
    /// Number of tile rows.
    pub tiles_y: u32,
}

impl TileGrid {
    /// Grid covering a `width`×`height` image with `tile`-pixel tiles.
    pub fn new(width: u32, height: u32, tile: u32) -> TileGrid {
        TileGrid {
            width,
            height,
            tile,
            tiles_x: width.div_ceil(tile),
            tiles_y: height.div_ceil(tile),
        }
    }

    /// Total tile count.
    pub fn num_tiles(&self) -> usize {
        (self.tiles_x * self.tiles_y) as usize
    }

    /// Pixel rect of tile index `t` (row-major).
    pub fn rect(&self, t: usize) -> Rect {
        let tx = t as u32 % self.tiles_x;
        let ty = t as u32 / self.tiles_x;
        Rect::tile(tx, ty, self.tile)
    }

    /// Tiles whose AABB-range the splat's 3σ box touches (the candidate set
    /// every strategy starts from).
    pub fn candidate_range(&self, s: &Splat) -> (u32, u32, u32, u32) {
        let r = s.radius;
        let x0 = ((s.mean.x - r) / self.tile as f32).floor().max(0.0) as u32;
        let y0 = ((s.mean.y - r) / self.tile as f32).floor().max(0.0) as u32;
        let x1 = (((s.mean.x + r) / self.tile as f32).ceil() as u32).min(self.tiles_x);
        let y1 = (((s.mean.y + r) / self.tile as f32).ceil() as u32).min(self.tiles_y);
        (x0, y0, x1.max(x0), y1.max(y0))
    }
}

/// AABB test: splat's axis-aligned 3σ box vs tile rect (vanilla 3DGS).
#[inline]
pub fn intersects_aabb(s: &Splat, rect: &Rect) -> bool {
    s.mean.x + s.radius >= rect.x0
        && s.mean.x - s.radius < rect.x1
        && s.mean.y + s.radius >= rect.y0
        && s.mean.y - s.radius < rect.y1
}

/// OBB test (GSCore): oriented 3σ box in the splat eigenbasis vs tile rect,
/// separating-axis theorem over the 4 candidate axes (2 box axes are enough
/// for rect-vs-rect in 2D: the tile's axes and the OBB's axes).
pub fn intersects_obb(s: &Splat, rect: &Rect) -> bool {
    let (l1, l2) = s.cov.eigenvalues();
    let major = s.cov.major_axis();
    let minor = v2(-major.y, major.x);
    let e1 = 3.0 * l1.sqrt(); // half-length along major
    let e2 = 3.0 * l2.max(0.0).sqrt();

    let c = rect.center();
    let h = rect.half_extent();
    let d = s.mean - c;

    // Axes of the tile (x, y): project OBB onto them.
    for (axis, tile_h) in [(v2(1.0, 0.0), h.x), (v2(0.0, 1.0), h.y)] {
        let obb_r = e1 * major.dot(axis).abs() + e2 * minor.dot(axis).abs();
        if d.dot(axis).abs() > tile_h + obb_r {
            return false;
        }
    }
    // Axes of the OBB: project tile onto them.
    for (axis, obb_h) in [(major, e1), (minor, e2)] {
        let tile_r = h.x * axis.x.abs() + h.y * axis.y.abs();
        if d.dot(axis).abs() > obb_h + tile_r {
            return false;
        }
    }
    true
}

/// Exact "does any point of the rect have α ≥ 1/255" test — the oracle the
/// cheaper tests approximate. Finds the rect point minimizing the quadratic
/// form (clamped Newton on the box) — for a convex quadratic the minimum
/// over a box is at the clamped unconstrained minimum for each fixed
/// coordinate; we evaluate the clamped mean plus the 4 edges' minimizers.
pub fn intersects_exact(s: &Splat, rect: &Rect, alpha_min: f32) -> bool {
    if s.opacity < alpha_min {
        return false;
    }
    // Threshold on the quadratic form E: α = o·e^{-E} ≥ αmin  ⇔  E ≤ ln(o/αmin).
    let e_max = (s.opacity / alpha_min).ln();
    min_quad_on_rect(s, rect) <= e_max
}

/// Minimum of E(p) = ½ (p-μ)ᵀ Σ⁻¹ (p-μ) over the rect.
pub fn min_quad_on_rect(s: &Splat, rect: &Rect) -> f32 {
    let cx = s.mean.x.clamp(rect.x0, rect.x1);
    let cy = s.mean.y.clamp(rect.y0, rect.y1);
    // If μ inside rect, min is 0.
    if cx == s.mean.x && cy == s.mean.y {
        return 0.0;
    }
    let q = |x: f32, y: f32| {
        let dx = x - s.mean.x;
        let dy = y - s.mean.y;
        0.5 * (s.conic.a * dx * dx + 2.0 * s.conic.b * dx * dy + s.conic.c * dy * dy)
    };
    // Candidate minimizers: for each edge, minimize the 1-D restriction.
    let mut best = f32::INFINITY;
    // Vertical edges x = x0, x1: dE/dy = 0 → y* = μy - b/c (x-μx)
    for x in [rect.x0, rect.x1] {
        let y_star = s.mean.y - s.conic.b / s.conic.c * (x - s.mean.x);
        let y = y_star.clamp(rect.y0, rect.y1);
        best = best.min(q(x, y));
    }
    // Horizontal edges y = y0, y1.
    for y in [rect.y0, rect.y1] {
        let x_star = s.mean.x - s.conic.b / s.conic.a * (y - s.mean.y);
        let x = x_star.clamp(rect.x0, rect.x1);
        best = best.min(q(x, y));
    }
    best
}

/// Tile↔splat intersection strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Vanilla 3DGS: axis-aligned 3σ box vs tile rect.
    Aabb,
    /// GSCore-style oriented bounding box (separating-axis test).
    Obb,
}

impl Strategy {
    /// Parse a config/CLI name ("aabb" | "obb").
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "aabb" => Some(Strategy::Aabb),
            "obb" => Some(Strategy::Obb),
            _ => None,
        }
    }

    /// The stable config/CLI name ([`Strategy::parse`]'s inverse).
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Aabb => "aabb",
            Strategy::Obb => "obb",
        }
    }
}

/// Build per-tile splat index lists with the chosen strategy. Splat order
/// is preserved (callers depth-sort afterwards). Returns
/// `lists[tile] -> Vec<splat idx>`.
pub fn build_tile_lists(splats: &[Splat], grid: &TileGrid, strategy: Strategy) -> Vec<Vec<u32>> {
    let mut lists = vec![Vec::new(); grid.num_tiles()];
    for (si, s) in splats.iter().enumerate() {
        let (x0, y0, x1, y1) = grid.candidate_range(s);
        for ty in y0..y1 {
            for tx in x0..x1 {
                let rect = Rect::tile(tx, ty, grid.tile);
                let hit = match strategy {
                    Strategy::Aabb => intersects_aabb(s, &rect),
                    Strategy::Obb => intersects_obb(s, &rect),
                };
                if hit {
                    lists[(ty * grid.tiles_x + tx) as usize].push(si as u32);
                }
            }
        }
    }
    lists
}

/// Total number of (splat, tile) pairs — the "duplicated Gaussians" metric
/// of paper Fig. 4 (right).
pub fn duplicate_count(lists: &[Vec<u32>]) -> usize {
    lists.iter().map(|l| l.len()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::{v3, Quat};
    use crate::render::project::project_one;
    use crate::scene::gaussian::Scene;

    fn splat_at(mx: f32, my: f32, scale: crate::numeric::linalg::Vec3, rot: Quat) -> Splat {
        // Build via real projection so conic/cov stay consistent.
        let cam = Camera::look_at(
            Intrinsics::from_fov(256, 256, 1.2),
            v3(0.0, 0.0, -6.0),
            v3(0.0, 0.0, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        let mut sc = Scene::with_capacity(1, "t");
        sc.push(v3(0.0, 0.0, 0.0), rot, scale, 0.9, [1.0; 3], [[0.0; 3]; 3]);
        let mut s = project_one(&sc, 0, &cam).unwrap();
        s.mean = v2(mx, my);
        s
    }

    #[test]
    fn grid_geometry() {
        let g = TileGrid::new(256, 256, 16);
        assert_eq!(g.tiles_x, 16);
        assert_eq!(g.num_tiles(), 256);
        let r = g.rect(17); // tile (1,1)
        assert_eq!(r.x0, 16.0);
        assert_eq!(r.y0, 16.0);
    }

    #[test]
    fn grid_non_divisible() {
        let g = TileGrid::new(250, 130, 16);
        assert_eq!(g.tiles_x, 16);
        assert_eq!(g.tiles_y, 9);
    }

    #[test]
    fn aabb_hits_overlapping_tile() {
        let s = splat_at(24.0, 24.0, v3(0.3, 0.3, 0.3), Quat::IDENTITY);
        assert!(intersects_aabb(&s, &Rect::tile(1, 1, 16)));
        // Far-away tile misses.
        assert!(!intersects_aabb(&s, &Rect::tile(10, 10, 16)));
    }

    #[test]
    fn obb_is_subset_of_aabb() {
        // OBB can only reject more than AABB (it's tighter).
        let s = splat_at(100.0, 100.0, v3(1.5, 0.05, 0.05), Quat::from_axis_angle(v3(0.0, 0.0, 1.0), 0.8));
        let g = TileGrid::new(256, 256, 16);
        for t in 0..g.num_tiles() {
            let r = g.rect(t);
            if intersects_obb(&s, &r) {
                assert!(intersects_aabb(&s, &r), "OBB hit but AABB miss at tile {t}");
            }
        }
    }

    #[test]
    fn obb_tighter_for_diagonal_spiky() {
        // 45°-oriented elongated splat: AABB covers a big square, OBB a thin
        // diagonal band.
        let s = splat_at(
            128.0,
            128.0,
            v3(2.0, 0.05, 0.05),
            Quat::from_axis_angle(v3(0.0, 0.0, 1.0), std::f32::consts::FRAC_PI_4),
        );
        let g = TileGrid::new(256, 256, 16);
        let aabb = build_tile_lists(&[s], &g, Strategy::Aabb);
        let obb = build_tile_lists(&[s], &g, Strategy::Obb);
        let (na, no) = (duplicate_count(&aabb), duplicate_count(&obb));
        assert!(
            no * 2 < na,
            "expected OBB to at least halve tiles: aabb {na}, obb {no}"
        );
    }

    #[test]
    fn exact_is_subset_of_obb() {
        // The OBB truncates at 3σ (E = 4.5), so containment of the exact
        // α-threshold test only holds when ln(255·o) ≤ 4.5, i.e. o ≲ 0.353
        // — contributions beyond 3σ are dropped by convention in 3DGS.
        let mut s = splat_at(
            77.0,
            133.0,
            v3(1.0, 0.08, 0.08),
            Quat::from_axis_angle(v3(0.0, 0.0, 1.0), 1.1),
        );
        s.opacity = 0.3;
        let g = TileGrid::new(256, 256, 16);
        for t in 0..g.num_tiles() {
            let r = g.rect(t);
            if intersects_exact(&s, &r, 1.0 / 255.0) {
                assert!(intersects_obb(&s, &r), "exact hit but OBB miss at {t}");
            }
        }
    }

    #[test]
    fn exact_matches_dense_sampling_oracle() {
        let s = splat_at(
            90.0,
            90.0,
            v3(0.6, 0.1, 0.1),
            Quat::from_axis_angle(v3(0.0, 0.0, 1.0), 0.5),
        );
        let g = TileGrid::new(192, 192, 16);
        let alpha_min = 1.0 / 255.0;
        for t in 0..g.num_tiles() {
            let r = g.rect(t);
            // Brute-force: sample every pixel center in the tile.
            let mut any = false;
            let mut y = r.y0 + 0.5;
            while y < r.y1 {
                let mut x = r.x0 + 0.5;
                while x < r.x1 {
                    if s.alpha_at(x, y) >= alpha_min {
                        any = true;
                    }
                    x += 1.0;
                }
                y += 1.0;
            }
            let exact = intersects_exact(&s, &r, alpha_min);
            // `exact` uses the continuous rect so it can only over-include
            // relative to pixel centers.
            if any {
                assert!(exact, "tile {t}: pixel hit but exact miss");
            }
        }
    }

    #[test]
    fn min_quad_zero_inside() {
        let s = splat_at(50.0, 50.0, v3(0.3, 0.3, 0.3), Quat::IDENTITY);
        let r = Rect { x0: 48.0, y0: 48.0, x1: 64.0, y1: 64.0 };
        assert_eq!(min_quad_on_rect(&s, &r), 0.0);
    }

    #[test]
    fn candidate_range_clipped_to_grid() {
        let s = splat_at(2.0, 2.0, v3(2.0, 2.0, 2.0), Quat::IDENTITY);
        let g = TileGrid::new(64, 64, 16);
        let (x0, y0, x1, y1) = g.candidate_range(&s);
        assert_eq!(x0, 0);
        assert_eq!(y0, 0);
        assert!(x1 <= g.tiles_x && y1 <= g.tiles_y);
    }

    #[test]
    fn duplicates_grow_as_tiles_shrink() {
        let splats: Vec<Splat> = (0..20)
            .map(|i| {
                splat_at(
                    20.0 + 10.0 * i as f32,
                    128.0,
                    v3(0.5, 0.2, 0.2),
                    Quat::from_axis_angle(v3(0.0, 0.0, 1.0), i as f32 * 0.3),
                )
            })
            .collect();
        let d16 = duplicate_count(&build_tile_lists(
            &splats,
            &TileGrid::new(256, 256, 16),
            Strategy::Aabb,
        ));
        let d4 = duplicate_count(&build_tile_lists(
            &splats,
            &TileGrid::new(256, 256, 4),
            Strategy::Aabb,
        ));
        assert!(d4 > d16 * 2, "d4={d4} d16={d16}");
    }
}
