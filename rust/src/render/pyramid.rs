//! Coarse-to-fine contribution gating: the per-tile mip pyramid (paper:
//! "hierarchical Gaussian testing", coarse half).
//!
//! The CAT engine tests at leader-pixel granularity, but every tile-binned
//! Gaussian still reaches it — and then the per-pixel loop — even when its
//! whole contribution to the tile is provably below the blending threshold.
//! This module adds the two coarse levels above CAT:
//!
//! ```text
//!   level 1: whole tile      — reject the (tile, splat) pair outright
//!   level 2: 2×2 quadrants   — reject (quadrant, splat) pairs
//!   level 3: pixel-rectangles — the existing CatEngine leader tests
//!   fine:    per-pixel loop  — render_tile's Eq.-1 evaluation
//! ```
//!
//! Each level uses the same conservative bound: the exact minimum of the
//! quadratic form E over the rectangle ([`min_quad_on_rect`]), so the
//! maximum achievable alpha anywhere in the rect is `o·e^{−minE}`. A rect
//! is rejected when that maximum falls below the gate threshold — the
//! `shared_threshold`-style cutoff of Eq. 2 ([`shared_threshold_at`]),
//! generalized from 1/255 to a configurable `GateConfig::threshold`.
//!
//! **Losslessness.** At the default threshold (`ALPHA_MIN` = 1/255) the
//! gate removes only pairs whose every pixel the blending loop would have
//! skipped anyway (`E ≥ ln(255·o)` ⇒ α < 1/255 ⇒ no blend), so images,
//! contribution scores, and `pairs_blended` are bit-identical with the
//! gate on or off; only the tested-pair counters shrink. Raising the
//! threshold trades quality for work like a coarser CAT would.
//!
//! Quadrants are split on mini-tile boundaries and ordered [TL, TR, BL,
//! BR] — bit `q = row·2 + col` — matching both `CatEngine`'s sub-tile
//! iteration order and `sim::workload::subtile_rects`, so a quadrant bit
//! maps 1:1 onto an 8×8 sub-tile for the paper's 16×16 tiles.

use super::project::{Splat, ALPHA_MIN};
use super::raster::MINITILE;
use super::tile::{min_quad_on_rect, Rect};
use crate::cat::pr::shared_threshold_at;

/// Coarse-gate configuration, threaded through `RenderOptions` /
/// `ExperimentConfig` / the CLI (`--gate on`, `--gate-levels`,
/// `--gate-threshold`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GateConfig {
    /// Master switch. Off (the default) renders through the exact pre-gate
    /// code path — bit-identical to a build without this module.
    pub enabled: bool,
    /// Coarse levels to apply when enabled: 1 = whole-tile only,
    /// 2 = tile + quadrants (the default).
    pub levels: u32,
    /// Minimum alpha a splat must be able to reach inside a rect to
    /// survive it. The default, `ALPHA_MIN` (1/255), is exactly the
    /// blending loop's skip threshold, which makes the gate lossless.
    pub threshold: f32,
}

impl Default for GateConfig {
    fn default() -> Self {
        GateConfig {
            enabled: false,
            levels: 2,
            threshold: ALPHA_MIN,
        }
    }
}

impl GateConfig {
    /// Default thresholds with the master switch on.
    pub fn on() -> GateConfig {
        GateConfig {
            enabled: true,
            ..GateConfig::default()
        }
    }

    /// Does any coarse level run?
    pub fn active(&self) -> bool {
        self.enabled && self.levels > 0
    }

    /// The E-space cutoff for a splat: a rect whose minimum E reaches this
    /// value cannot contribute α ≥ `threshold` anywhere inside it. At the
    /// default threshold this is computed with the **same expression** as
    /// the blending loop's `e_max` (`ln(255·o)`), so the gate's reject
    /// region and the loop's skip region agree bit-for-bit.
    pub fn cutoff(&self, opacity: f32) -> f32 {
        if self.threshold == ALPHA_MIN {
            (255.0 * opacity).max(1e-12).ln()
        } else {
            shared_threshold_at(opacity, self.threshold)
        }
    }
}

/// Per-level outcome of gating one splat against one tile.
#[derive(Clone, Copy, Debug, Default)]
pub struct GateDecision {
    /// Level 1 rejected the whole (tile, splat) pair.
    pub tile_rejected: bool,
    /// Surviving quadrants, bit `q = row·2 + col` ([TL, TR, BL, BR]).
    /// All live quadrants when `levels < 2`.
    pub quad_mask: u8,
    /// Quadrants the level-2 bound was evaluated on.
    pub quads_tested: u8,
    /// Quadrants level 2 rejected.
    pub quads_rejected: u8,
}

/// The per-tile pyramid: the tile rect, its 2×2 mini-tile-aligned
/// quadrants, and each quadrant's mini-tile bits (for masking the fine
/// loop). Built once per tile and reused for every splat in the tile's
/// list — construction is a handful of adds, no per-splat state.
pub struct TilePyramid {
    tile: Rect,
    quads: [Rect; 4],
    /// Mini-tile bits (bit = `row·mt_cols + col`) covered by each quadrant.
    quad_masks: [u32; 4],
    /// Bits of non-degenerate quadrants (small tiles can have empty ones).
    live: u8,
}

impl TilePyramid {
    /// Build the pyramid for one tile rect. The quadrant split lands on a
    /// mini-tile boundary (for 16×16 tiles: exact 8×8 sub-tiles), so every
    /// mini-tile belongs to exactly one quadrant.
    pub fn new(tile: &Rect, tile_size: u32) -> TilePyramid {
        let mt_cols = tile_size.div_ceil(MINITILE) as usize;
        let half = mt_cols.div_ceil(2);
        let sx = (tile.x0 + (half as u32 * MINITILE) as f32).min(tile.x1);
        let sy = (tile.y0 + (half as u32 * MINITILE) as f32).min(tile.y1);
        let quads = [
            Rect { x0: tile.x0, y0: tile.y0, x1: sx, y1: sy },
            Rect { x0: sx, y0: tile.y0, x1: tile.x1, y1: sy },
            Rect { x0: tile.x0, y0: sy, x1: sx, y1: tile.y1 },
            Rect { x0: sx, y0: sy, x1: tile.x1, y1: tile.y1 },
        ];
        let mut quad_masks = [0u32; 4];
        for row in 0..mt_cols {
            for col in 0..mt_cols {
                let q = (row >= half) as usize * 2 + (col >= half) as usize;
                quad_masks[q] |= 1 << (row * mt_cols + col);
            }
        }
        let mut live = 0u8;
        for q in 0..4 {
            if quads[q].x1 > quads[q].x0 && quads[q].y1 > quads[q].y0 && quad_masks[q] != 0 {
                live |= 1 << q;
            }
        }
        TilePyramid {
            tile: *tile,
            quads,
            quad_masks,
            live,
        }
    }

    /// Level 1 alone: can the splat contribute α ≥ threshold anywhere in
    /// the tile? Used by list-level consumers (`FramePlan::gated_lists`)
    /// that ship filtered lists to a backend instead of masking pixels.
    pub fn rejects_tile(&self, s: &Splat, cfg: &GateConfig) -> bool {
        min_quad_on_rect(s, &self.tile) >= cfg.cutoff(s.opacity)
    }

    /// Run the configured coarse levels for one splat.
    pub fn gate(&self, s: &Splat, cfg: &GateConfig) -> GateDecision {
        let cutoff = cfg.cutoff(s.opacity);
        if min_quad_on_rect(s, &self.tile) >= cutoff {
            return GateDecision {
                tile_rejected: true,
                ..GateDecision::default()
            };
        }
        if cfg.levels < 2 {
            return GateDecision {
                quad_mask: self.live,
                ..GateDecision::default()
            };
        }
        let mut d = GateDecision::default();
        for q in 0..4 {
            if self.live & (1 << q) == 0 {
                continue;
            }
            d.quads_tested += 1;
            if min_quad_on_rect(s, &self.quads[q]) >= cutoff {
                d.quads_rejected += 1;
            } else {
                d.quad_mask |= 1 << q;
            }
        }
        d
    }

    /// Mini-tile bits covered by the surviving quadrants — ANDed with the
    /// mask provider's bits so the fine loop never visits a rejected
    /// quadrant's pixels.
    pub fn minitile_mask(&self, quad_mask: u8) -> u32 {
        let mut m = 0u32;
        for q in 0..4 {
            if quad_mask & (1 << q) != 0 {
                m |= self.quad_masks[q];
            }
        }
        m
    }

    /// The tile rect this pyramid was built for.
    pub fn tile(&self) -> &Rect {
        &self.tile
    }

    /// Quadrant rects in [TL, TR, BL, BR] order (bit `q = row·2 + col`).
    /// The quadrants tile the rect exactly, so the minimum of the quadratic
    /// form over the tile equals the minimum over the four quadrant minima
    /// — the invariant the rect-precision energy fold relies on.
    pub fn quad_rects(&self) -> &[Rect; 4] {
        &self.quads
    }

    /// Mini-tile bits (bit = `row·mt_cols + col`) covered by quadrant `q`.
    /// The four masks are pairwise disjoint and together cover every
    /// mini-tile of the tile, so per-quadrant mask stitching touches each
    /// pixel exactly once.
    pub fn quad_minitile_mask(&self, q: usize) -> u32 {
        self.quad_masks[q]
    }

    /// Bits of non-degenerate quadrants (small edge tiles can have dead
    /// ones — their rects are empty and their mini-tile masks zero).
    pub fn live(&self) -> u8 {
        self.live
    }
}

/// Quadrant index ([TL, TR, BL, BR], bit `q = row·2 + col`) of an absolute
/// pixel inside `tile` — the pixel-space inverse of [`TilePyramid`]'s
/// mini-tile split, used by the PJRT host compositor to stitch per-quadrant
/// outputs. Splits at the same `half`-mini-tile boundary as
/// `TilePyramid::new`, so a pixel's quadrant always agrees with the
/// quadrant whose `quad_minitile_mask` covers its mini-tile.
pub fn quad_of_pixel(tile: &Rect, tile_size: u32, px: u32, py: u32) -> usize {
    let mt_cols = tile_size.div_ceil(MINITILE);
    let half_px = (mt_cols.div_ceil(2) * MINITILE) as f32;
    let row = (py as f32 - tile.y0 >= half_px) as usize;
    let col = (px as f32 - tile.x0 >= half_px) as usize;
    row * 2 + col
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::linalg::{v2, Sym2};
    use crate::util::rng::Pcg32;

    fn splat(mx: f32, my: f32, conic: Sym2, opacity: f32) -> Splat {
        Splat {
            id: 0,
            mean: v2(mx, my),
            cov: Sym2 { a: 1.0, b: 0.0, c: 1.0 },
            conic,
            depth: 1.0,
            opacity,
            color: [1.0; 3],
            radius: 10.0,
            axis_ratio: 1.0,
        }
    }

    fn random_conic(rng: &mut Pcg32) -> Sym2 {
        // Positive-definite via LLᵀ (same construction as cat::pr tests).
        let l11 = rng.range_f32(0.05, 1.0);
        let l21 = rng.range_f32(-0.5, 0.5);
        let l22 = rng.range_f32(0.05, 1.0);
        Sym2 {
            a: l11 * l11,
            b: l11 * l21,
            c: l21 * l21 + l22 * l22,
        }
    }

    fn tile() -> Rect {
        Rect { x0: 32.0, y0: 48.0, x1: 48.0, y1: 64.0 }
    }

    #[test]
    fn quadrants_tile_the_rect_in_subtile_order() {
        let p = TilePyramid::new(&tile(), 16);
        // [TL, TR, BL, BR]: same order as CatEngine's sy/sx sweep.
        assert_eq!(p.quads[0], Rect { x0: 32.0, y0: 48.0, x1: 40.0, y1: 56.0 });
        assert_eq!(p.quads[1], Rect { x0: 40.0, y0: 48.0, x1: 48.0, y1: 56.0 });
        assert_eq!(p.quads[2], Rect { x0: 32.0, y0: 56.0, x1: 40.0, y1: 64.0 });
        assert_eq!(p.quads[3], Rect { x0: 40.0, y0: 56.0, x1: 48.0, y1: 64.0 });
        assert_eq!(p.live, 0xF);
        // Mini-tile bits: disjoint, and together the full 4×4 grid.
        let mut seen = 0u32;
        for q in 0..4 {
            assert_eq!(seen & p.quad_masks[q], 0, "overlapping quadrant bits");
            seen |= p.quad_masks[q];
            assert_eq!(p.quad_masks[q].count_ones(), 4);
        }
        assert_eq!(seen, 0xFFFF);
        assert_eq!(p.minitile_mask(0xF), 0xFFFF);
        assert_eq!(p.minitile_mask(0b0001), p.quad_masks[0]);
        // TL quadrant = mini-tile rows 0–1 × cols 0–1.
        assert_eq!(p.quad_masks[0], 0b0000_0000_0011_0011);
    }

    #[test]
    fn rejection_is_conservative_at_pixel_centers() {
        // A rejected rect (tile or quadrant) must have every pixel-center
        // alpha strictly below the threshold — the losslessness invariant.
        let mut rng = Pcg32::new(91);
        let t = tile();
        let cfg = GateConfig::on();
        let p = TilePyramid::new(&t, 16);
        let mut tile_rejects = 0;
        let mut quad_rejects = 0;
        for _ in 0..2000 {
            let s = splat(
                rng.range_f32(0.0, 80.0),
                rng.range_f32(16.0, 96.0),
                random_conic(&mut rng),
                rng.range_f32(0.001, 1.0),
            );
            let d = p.gate(&s, &cfg);
            let check_rect = |r: &Rect| {
                let mut py = r.y0 + 0.5;
                while py < r.y1 {
                    let mut px = r.x0 + 0.5;
                    while px < r.x1 {
                        assert!(
                            s.alpha_at(px, py) < ALPHA_MIN,
                            "rejected rect contains visible pixel ({px},{py})"
                        );
                        px += 1.0;
                    }
                    py += 1.0;
                }
            };
            if d.tile_rejected {
                tile_rejects += 1;
                check_rect(&t);
                continue;
            }
            for q in 0..4 {
                if d.quad_mask & (1 << q) == 0 {
                    quad_rejects += 1;
                    check_rect(&p.quads[q]);
                }
            }
        }
        assert!(tile_rejects > 100, "gate never fired at tile level: {tile_rejects}");
        assert!(quad_rejects > 100, "gate never fired at quadrant level: {quad_rejects}");
    }

    #[test]
    fn tile_pass_keeps_at_least_one_quadrant_for_interior_means() {
        // min over the tile == min over some quadrant, so a splat whose
        // mean lies inside the tile (minE = 0) and passes level 1 must
        // keep the quadrant containing the mean.
        let mut rng = Pcg32::new(92);
        let t = tile();
        let cfg = GateConfig::on();
        let p = TilePyramid::new(&t, 16);
        for _ in 0..500 {
            let s = splat(
                rng.range_f32(t.x0, t.x1),
                rng.range_f32(t.y0, t.y1),
                random_conic(&mut rng),
                rng.range_f32(0.01, 1.0),
            );
            let d = p.gate(&s, &cfg);
            if !d.tile_rejected {
                assert_ne!(d.quad_mask, 0, "tile passed but every quadrant rejected");
            }
        }
    }

    #[test]
    fn sub_threshold_opacity_rejects_everywhere() {
        // o < threshold ⇒ max alpha = o < threshold even at the mean.
        let p = TilePyramid::new(&tile(), 16);
        let s = splat(40.0, 56.0, Sym2 { a: 0.5, b: 0.0, c: 0.5 }, 0.5 / 255.0);
        assert!(p.rejects_tile(&s, &GateConfig::on()));
        assert!(p.gate(&s, &GateConfig::on()).tile_rejected);
    }

    #[test]
    fn levels_one_skips_quadrant_tests() {
        let p = TilePyramid::new(&tile(), 16);
        let cfg = GateConfig { levels: 1, ..GateConfig::on() };
        // Far-off splat: tile-level reject still fires.
        let far = splat(500.0, 500.0, Sym2 { a: 0.5, b: 0.0, c: 0.5 }, 0.9);
        assert!(p.gate(&far, &cfg).tile_rejected);
        // Passing splat: all live quadrants survive untested.
        let near = splat(40.0, 56.0, Sym2 { a: 0.5, b: 0.0, c: 0.5 }, 0.9);
        let d = p.gate(&near, &cfg);
        assert_eq!(d.quad_mask, 0xF);
        assert_eq!(d.quads_tested, 0);
        assert_eq!(d.quads_rejected, 0);
    }

    #[test]
    fn higher_threshold_rejects_more() {
        let p = TilePyramid::new(&tile(), 16);
        // Mean two pixels outside the tile edge: peak in-tile alpha ≈ 0.009.
        let s = splat(30.0, 56.0, Sym2 { a: 1.2, b: 0.0, c: 1.2 }, 0.1);
        let lossless = GateConfig::on();
        let lossy = GateConfig { threshold: 16.0 / 255.0, ..GateConfig::on() };
        assert!(!p.rejects_tile(&s, &lossless));
        assert!(p.rejects_tile(&s, &lossy));
    }

    #[test]
    fn inactive_configs() {
        let off = GateConfig::default();
        assert!(!off.active());
        assert!(!GateConfig { levels: 0, ..GateConfig::on() }.active());
        assert!(GateConfig::on().active());
    }

    #[test]
    fn quad_of_pixel_agrees_with_the_minitile_split() {
        let t = tile();
        let p = TilePyramid::new(&t, 16);
        for py in 48..64u32 {
            for px in 32..48u32 {
                let q = quad_of_pixel(&t, 16, px, py);
                let mt = ((py - 48) / MINITILE) * 4 + (px - 32) / MINITILE;
                assert_ne!(
                    p.quad_minitile_mask(q) & (1 << mt),
                    0,
                    "pixel ({px},{py}) mapped to quadrant {q} outside its mini-tile mask"
                );
            }
        }
    }

    #[test]
    fn edge_sized_tiles_have_degenerate_quadrants() {
        // A 4-px tile has one mini-tile column: everything lands in TL and
        // the other quadrants are dead.
        let r = Rect { x0: 0.0, y0: 0.0, x1: 4.0, y1: 4.0 };
        let p = TilePyramid::new(&r, 4);
        assert_eq!(p.live, 0b0001);
        assert_eq!(p.quad_masks[0], 0b1);
        assert_eq!(p.minitile_mask(0xF), 0b1);
        let s = splat(2.0, 2.0, Sym2 { a: 0.5, b: 0.0, c: 0.5 }, 0.9);
        let d = p.gate(&s, &GateConfig::on());
        assert!(!d.tile_rejected);
        assert_eq!(d.quad_mask, 0b0001);
    }
}
