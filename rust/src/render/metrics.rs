//! Image quality metrics (PSNR, SSIM — paper Table I) and serving-latency
//! summaries (percentiles over frame wall times, used by the render
//! service's stats and the `fig14_service` bench).

use super::image::Image;

/// Interpolated percentile of a sample set: `q` in `[0, 1]`, linear
/// interpolation between order statistics (the same convention as numpy's
/// default). Empty input returns 0.0.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("non-NaN samples"));
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Latency summary over a batch of frame wall times: the service and the
/// `fig14_service` bench report p50/p99 alongside the mean and worst case.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    /// Number of samples.
    pub n: usize,
    /// Median (50th percentile).
    pub p50: f64,
    /// Tail latency (99th percentile).
    pub p99: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Worst sample.
    pub max: f64,
}

/// Summarize `samples` (e.g. per-frame wall milliseconds) into a
/// [`LatencySummary`]. Empty input yields the all-zero summary.
pub fn latency_summary(samples: &[f64]) -> LatencySummary {
    if samples.is_empty() {
        return LatencySummary::default();
    }
    LatencySummary {
        n: samples.len(),
        p50: percentile(samples, 0.50),
        p99: percentile(samples, 0.99),
        mean: samples.iter().sum::<f64>() / samples.len() as f64,
        max: samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// PSNR in dB over all RGB channels (peak = 1.0).
pub fn psnr(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.data.len(), b.data.len(), "image size mismatch");
    let mse: f64 = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.data.len() as f64;
    if mse <= 0.0 {
        return f64::INFINITY;
    }
    10.0 * (1.0 / mse).log10()
}

/// Mean SSIM over the luma plane, 8×8 windows with stride 4, standard
/// constants (K1=0.01, K2=0.03, L=1).
pub fn ssim(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.width, b.width);
    assert_eq!(a.height, b.height);
    let la = a.luma();
    let lb = b.luma();
    let (w, h) = (a.width as usize, a.height as usize);
    let win = 8usize;
    let stride = 4usize;
    const C1: f64 = 0.01 * 0.01;
    const C2: f64 = 0.03 * 0.03;

    let mut total = 0.0f64;
    let mut count = 0usize;
    let mut y = 0;
    while y + win <= h {
        let mut x = 0;
        while x + win <= w {
            let (mut sa, mut sb, mut saa, mut sbb, mut sab) = (0f64, 0f64, 0f64, 0f64, 0f64);
            for dy in 0..win {
                let row = (y + dy) * w + x;
                for dx in 0..win {
                    let va = la[row + dx] as f64;
                    let vb = lb[row + dx] as f64;
                    sa += va;
                    sb += vb;
                    saa += va * va;
                    sbb += vb * vb;
                    sab += va * vb;
                }
            }
            let n = (win * win) as f64;
            let ma = sa / n;
            let mb = sb / n;
            let va = (saa / n - ma * ma).max(0.0);
            let vb = (sbb / n - mb * mb).max(0.0);
            let cov = sab / n - ma * mb;
            let s = ((2.0 * ma * mb + C1) * (2.0 * cov + C2))
                / ((ma * ma + mb * mb + C1) * (va + vb + C2));
            total += s;
            count += 1;
            x += stride;
        }
        y += stride;
    }
    if count == 0 {
        1.0
    } else {
        total / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn noisy(img: &Image, sigma: f32, seed: u64) -> Image {
        let mut rng = Pcg32::new(seed);
        let mut out = img.clone();
        for v in &mut out.data {
            *v = (*v + rng.normal_ms(0.0, sigma)).clamp(0.0, 1.0);
        }
        out
    }

    fn test_pattern(w: u32, h: u32) -> Image {
        let mut img = Image::new(w, h);
        for y in 0..h {
            for x in 0..w {
                img.set(
                    x,
                    y,
                    [
                        (x as f32 / w as f32),
                        (y as f32 / h as f32),
                        ((x + y) % 7) as f32 / 7.0,
                    ],
                );
            }
        }
        img
    }

    #[test]
    fn psnr_identical_is_infinite() {
        let img = test_pattern(32, 32);
        assert!(psnr(&img, &img).is_infinite());
    }

    #[test]
    fn psnr_of_known_mse() {
        let a = Image::filled(16, 16, [0.5, 0.5, 0.5]);
        let b = Image::filled(16, 16, [0.6, 0.6, 0.6]);
        // MSE = 0.01 → PSNR = 20 dB (f32 rounding of 0.6−0.5 allows ~1e-3).
        assert!((psnr(&a, &b) - 20.0).abs() < 1e-3);
    }

    #[test]
    fn psnr_decreases_with_noise() {
        let img = test_pattern(64, 64);
        let p1 = psnr(&img, &noisy(&img, 0.01, 1));
        let p2 = psnr(&img, &noisy(&img, 0.05, 2));
        assert!(p1 > p2);
        assert!(p1 > 35.0);
        assert!(p2 > 20.0 && p2 < 35.0);
    }

    #[test]
    fn ssim_identical_is_one() {
        let img = test_pattern(64, 64);
        assert!((ssim(&img, &img) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ssim_penalizes_structure_loss() {
        let img = test_pattern(64, 64);
        let blurred = Image::filled(64, 64, [0.5, 0.5, 0.5]);
        let s_noise = ssim(&img, &noisy(&img, 0.02, 3));
        let s_flat = ssim(&img, &blurred);
        assert!(s_noise > s_flat);
        assert!(s_noise > 0.8);
        assert!(s_flat < 0.5);
    }

    #[test]
    fn ssim_symmetric() {
        let a = test_pattern(48, 48);
        let b = noisy(&a, 0.03, 4);
        assert!((ssim(&a, &b) - ssim(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates_order_statistics() {
        let s = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 1.0), 4.0);
        assert!((percentile(&s, 0.5) - 2.5).abs() < 1e-12);
        // Single sample: every percentile is that sample.
        assert_eq!(percentile(&[7.5], 0.99), 7.5);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn latency_summary_orders_p50_p99_max() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let l = latency_summary(&s);
        assert_eq!(l.n, 100);
        assert!((l.p50 - 50.5).abs() < 1e-9);
        assert!(l.p50 <= l.p99 && l.p99 <= l.max);
        assert!((l.max - 100.0).abs() < 1e-12);
        assert!((l.mean - 50.5).abs() < 1e-9);
        assert_eq!(latency_summary(&[]), LatencySummary::default());
    }
}
