//! Precision schemes for the PR weight datapath (paper Sec. IV-C, Fig. 7).
//!
//! * `Fp32` — reference (what the software rasterizer uses).
//! * `Fp16` — every operand and operation at binary16.
//! * `Fp8`  — every operand (including absolute pixel/μ coordinates!) at
//!   E4M3 before the subtraction. Absolute coordinates up to ~10³ quantize
//!   with steps of tens of pixels, destroying relative position — the
//!   mechanism behind the paper's "blocky artifacts" finding.
//! * `Mixed` — the paper's scheme: line 1 of Alg. 1 (the deltas) in FP16,
//!   results converted to FP8, lines 2–7 on FP8 operands with FP16
//!   accumulation in the Quadratic Accumulation Unit.

use super::pr::PrWeights;
use crate::numeric::fp16::quantize_f16;
use crate::numeric::fp8::{quantize_fp8, Fp8Format};
use crate::numeric::linalg::{Sym2, Vec2};

/// CTU numeric scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full single precision (software reference).
    Fp32,
    /// All operands and operations at binary16.
    Fp16,
    /// All operands at E4M3, including absolute coordinates.
    Fp8,
    /// The paper's scheme: FP16 deltas → FP8 products → FP16 accumulation.
    Mixed,
}

impl Precision {
    /// Parse a CLI/config precision name ("fp32", "fp16", "fp8", "mixed"),
    /// case-insensitively ("FP16" and "Mixed" are accepted).
    pub fn parse(s: &str) -> Option<Precision> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fp32" => Precision::Fp32,
            "fp16" => Precision::Fp16,
            "fp8" => Precision::Fp8,
            "mixed" => Precision::Mixed,
            _ => return None,
        })
    }

    /// The canonical CLI/config name of this precision.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::Fp8 => "fp8",
            Precision::Mixed => "mixed",
        }
    }
}

const FMT: Fp8Format = Fp8Format::E4M3;

#[inline]
fn q16(x: f32) -> f32 {
    quantize_f16(x)
}

#[inline]
fn q8(x: f32) -> f32 {
    quantize_fp8(x, FMT)
}

/// PR weights under a precision scheme. Mirrors `pr::pr_weights` (Alg. 1)
/// with quantization inserted at the exact points the hardware converts.
pub fn pr_weights_quant(
    mu: Vec2,
    conic: Sym2,
    p_top: Vec2,
    p_bot: Vec2,
    prec: Precision,
) -> PrWeights {
    match prec {
        Precision::Fp32 => super::pr::pr_weights(mu, conic, p_top, p_bot),
        Precision::Fp16 => {
            // All operands + ops at FP16.
            let dtx = q16(q16(p_top.x) - q16(mu.x));
            let dty = q16(q16(p_top.y) - q16(mu.y));
            let dbx = q16(q16(p_bot.x) - q16(mu.x));
            let dby = q16(q16(p_bot.y) - q16(mu.y));
            let (ca, cb, cc) = (q16(conic.a), q16(conic.b), q16(conic.c));
            weights_from_deltas(dtx, dty, dbx, dby, ca, cb, cc, q16, q16)
        }
        Precision::Fp8 => {
            // Everything at E4M3 — including the absolute coordinates.
            let dtx = q8(q8(p_top.x) - q8(mu.x));
            let dty = q8(q8(p_top.y) - q8(mu.y));
            let dbx = q8(q8(p_bot.x) - q8(mu.x));
            let dby = q8(q8(p_bot.y) - q8(mu.y));
            let (ca, cb, cc) = (q8(conic.a), q8(conic.b), q8(conic.c));
            weights_from_deltas(dtx, dty, dbx, dby, ca, cb, cc, q8, q8)
        }
        Precision::Mixed => {
            // Deltas exact at FP16, then converted to FP8; products at FP8,
            // accumulation at FP16 (QAU).
            let dtx = q8(q16(q16(p_top.x) - q16(mu.x)));
            let dty = q8(q16(q16(p_top.y) - q16(mu.y)));
            let dbx = q8(q16(q16(p_bot.x) - q16(mu.x)));
            let dby = q8(q16(q16(p_bot.y) - q16(mu.y)));
            let (ca, cb, cc) = (q8(conic.a), q8(conic.b), q8(conic.c));
            weights_from_deltas(dtx, dty, dbx, dby, ca, cb, cc, q8, q16)
        }
    }
}

/// Lines 2–7 of Alg. 1 with injectable rounding for the multiply stage
/// (`qm`) and the accumulate stage (`qa`).
#[allow(clippy::too_many_arguments)]
fn weights_from_deltas(
    dtx: f32,
    dty: f32,
    dbx: f32,
    dby: f32,
    ca: f32,
    cb: f32,
    cc: f32,
    qm: fn(f32) -> f32,
    qa: fn(f32) -> f32,
) -> PrWeights {
    // lines 2–3
    let s_top_x = qm(qm(0.5 * dtx * dtx) * ca);
    let s_top_y = qm(qm(0.5 * dty * dty) * cc);
    let s_bot_x = qm(qm(0.5 * dbx * dbx) * ca);
    let s_bot_y = qm(qm(0.5 * dby * dby) * cc);
    // lines 4–5
    let t0 = qm(qm(dtx * dty) * cb);
    let t1 = qm(qm(dbx * dty) * cb);
    let t2 = qm(qm(dtx * dby) * cb);
    let t3 = qm(qm(dbx * dby) * cb);
    // lines 6–7 (accumulate precision)
    PrWeights {
        e: [
            qa(qa(s_top_x + s_top_y) + t0),
            qa(qa(s_bot_x + s_top_y) + t1),
            qa(qa(s_top_x + s_bot_y) + t2),
            qa(qa(s_bot_x + s_bot_y) + t3),
        ],
    }
}

/// Pre-quantized Gaussian operands (§Perf): μ and the conic are constant
/// across every PR tested against the same Gaussian, so the engine
/// quantizes them once per (Gaussian, tile) instead of per PR — the same
/// sharing the hardware gets from registering the Gaussian's features at
/// the CTU input.
#[derive(Clone, Copy, Debug)]
pub struct PreQuant {
    /// The precision the operands were quantized for.
    pub prec: Precision,
    mu: Vec2,
    conic: Sym2,
}

impl PreQuant {
    /// Quantize μ and the conic once for `prec`.
    pub fn new(mu: Vec2, conic: Sym2, prec: Precision) -> PreQuant {
        let (mu, conic) = match prec {
            Precision::Fp32 => (mu, conic),
            // Mixed keeps μ at FP16 (line 1 runs in FP16) and narrows the
            // conic to FP8 (it feeds the FP8 multiply stage directly).
            Precision::Fp16 => (
                Vec2 { x: q16(mu.x), y: q16(mu.y) },
                Sym2 { a: q16(conic.a), b: q16(conic.b), c: q16(conic.c) },
            ),
            Precision::Mixed => (
                Vec2 { x: q16(mu.x), y: q16(mu.y) },
                Sym2 { a: q8(conic.a), b: q8(conic.b), c: q8(conic.c) },
            ),
            Precision::Fp8 => (
                Vec2 { x: q8(mu.x), y: q8(mu.y) },
                Sym2 { a: q8(conic.a), b: q8(conic.b), c: q8(conic.c) },
            ),
        };
        PreQuant { prec, mu, conic }
    }

    /// Alg. 1 on pre-quantized operands. Identical numerics to
    /// `pr_weights_quant` (quantizers are idempotent, verified by test).
    pub fn weights(&self, p_top: Vec2, p_bot: Vec2) -> PrWeights {
        let (mu, conic) = (self.mu, self.conic);
        match self.prec {
            Precision::Fp32 => super::pr::pr_weights(mu, conic, p_top, p_bot),
            Precision::Fp16 => {
                let dtx = q16(q16(p_top.x) - mu.x);
                let dty = q16(q16(p_top.y) - mu.y);
                let dbx = q16(q16(p_bot.x) - mu.x);
                let dby = q16(q16(p_bot.y) - mu.y);
                weights_from_deltas(dtx, dty, dbx, dby, conic.a, conic.b, conic.c, q16, q16)
            }
            Precision::Fp8 => {
                let dtx = q8(q8(p_top.x) - mu.x);
                let dty = q8(q8(p_top.y) - mu.y);
                let dbx = q8(q8(p_bot.x) - mu.x);
                let dby = q8(q8(p_bot.y) - mu.y);
                weights_from_deltas(dtx, dty, dbx, dby, conic.a, conic.b, conic.c, q8, q8)
            }
            Precision::Mixed => {
                let dtx = q8(q16(q16(p_top.x) - mu.x));
                let dty = q8(q16(q16(p_top.y) - mu.y));
                let dbx = q8(q16(q16(p_bot.x) - mu.x));
                let dby = q8(q16(q16(p_bot.y) - mu.y));
                weights_from_deltas(dtx, dty, dbx, dby, conic.a, conic.b, conic.c, q8, q16)
            }
        }
    }
}

/// Shared-term ln(255·o) at the CTU's FP16 shared unit.
pub fn shared_threshold_quant(opacity: f32, prec: Precision) -> f32 {
    let t = super::pr::shared_threshold(opacity);
    match prec {
        Precision::Fp32 => t,
        // The shared unit is FP16 in all reduced schemes (it's one op per
        // Gaussian; the paper's area savings come from the per-pixel path).
        Precision::Fp16 | Precision::Mixed => q16(t),
        Precision::Fp8 => q8(t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cat::pr::pr_weights;
    use crate::numeric::linalg::v2;
    use crate::util::rng::Pcg32;

    #[test]
    fn prequant_matches_direct_quant_path() {
        // PreQuant::weights must be bit-identical to pr_weights_quant for
        // every precision (quantizer idempotence makes hoisting safe).
        let mut rng = Pcg32::new(91);
        for _ in 0..500 {
            let (mu, conic, pt, pb) = case(&mut rng);
            for prec in [Precision::Fp32, Precision::Fp16, Precision::Mixed, Precision::Fp8] {
                let direct = pr_weights_quant(mu, conic, pt, pb, prec);
                let pre = PreQuant::new(mu, conic, prec).weights(pt, pb);
                assert_eq!(direct, pre, "{prec:?}");
            }
        }
    }

    fn case(rng: &mut Pcg32) -> (Vec2, Sym2, Vec2, Vec2) {
        // μ near the PR (the regime that decides mask bits).
        let mu = v2(rng.range_f32(100.0, 900.0), rng.range_f32(100.0, 900.0));
        let p_top = v2(mu.x + rng.range_f32(-12.0, 12.0), mu.y + rng.range_f32(-12.0, 12.0));
        let p_bot = v2(p_top.x + 3.0, p_top.y + 3.0);
        let l11 = rng.range_f32(0.05, 0.8);
        let l21 = rng.range_f32(-0.3, 0.3);
        let l22 = rng.range_f32(0.05, 0.8);
        let conic = Sym2 {
            a: l11 * l11,
            b: l11 * l21,
            c: l21 * l21 + l22 * l22,
        };
        (mu, conic, p_top, p_bot)
    }

    #[test]
    fn fp32_equals_reference() {
        let mut rng = Pcg32::new(81);
        for _ in 0..100 {
            let (mu, conic, pt, pb) = case(&mut rng);
            let a = pr_weights_quant(mu, conic, pt, pb, Precision::Fp32);
            let b = pr_weights(mu, conic, pt, pb);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn error_ordering_fp16_mixed_fp8() {
        // Mean relative error must satisfy fp16 ≤ mixed ≪ fp8 — the paper's
        // Fig. 7(c) mechanism.
        let mut rng = Pcg32::new(82);
        let mut err = [0.0f64; 3];
        let mut n = 0usize;
        for _ in 0..2000 {
            let (mu, conic, pt, pb) = case(&mut rng);
            let reference = pr_weights(mu, conic, pt, pb);
            for (k, prec) in [Precision::Fp16, Precision::Mixed, Precision::Fp8]
                .iter()
                .enumerate()
            {
                let w = pr_weights_quant(mu, conic, pt, pb, *prec);
                for c in 0..4 {
                    let denom = 1.0 + reference.e[c].abs() as f64;
                    err[k] += ((w.e[c] - reference.e[c]).abs() as f64) / denom;
                }
            }
            n += 4;
        }
        let (e16, emix, e8) = (err[0] / n as f64, err[1] / n as f64, err[2] / n as f64);
        assert!(e16 <= emix + 1e-9, "fp16 {e16} vs mixed {emix}");
        assert!(emix * 3.0 < e8, "mixed {emix} should be ≪ fp8 {e8}");
    }

    #[test]
    fn fp8_destroys_absolute_coordinates() {
        // At x≈500, E4M3 steps are 32 px: two pixels 3 px apart collapse.
        let a = quantize_fp8(500.0, Fp8Format::E4M3);
        let b = quantize_fp8(503.0, Fp8Format::E4M3);
        assert_eq!(a, b, "FP8 cannot distinguish nearby absolute coordinates");
    }

    #[test]
    fn mixed_preserves_small_deltas() {
        // Same two pixels via the mixed path keep distinct deltas.
        let mu = v2(500.0, 500.0);
        let conic = Sym2 { a: 0.1, b: 0.0, c: 0.1 };
        let w = pr_weights_quant(mu, conic, v2(500.5, 500.5), v2(503.5, 503.5), Precision::Mixed);
        assert!(w.e[0] < w.e[3], "E should grow with distance: {:?}", w.e);
    }

    #[test]
    fn decision_agreement_rates() {
        // Mask-bit agreement with FP32, mixed must beat fp8 decisively.
        let mut rng = Pcg32::new(83);
        let mut agree_mixed = 0usize;
        let mut agree_fp8 = 0usize;
        let mut total = 0usize;
        for _ in 0..3000 {
            let (mu, conic, pt, pb) = case(&mut rng);
            let o = rng.range_f32(0.05, 1.0);
            let refw = pr_weights(mu, conic, pt, pb);
            let lhs = super::super::pr::shared_threshold(o);
            for prec in [Precision::Mixed, Precision::Fp8] {
                let w = pr_weights_quant(mu, conic, pt, pb, prec);
                let lhs_q = shared_threshold_quant(o, prec);
                for c in 0..4 {
                    let want = lhs > refw.e[c];
                    let got = lhs_q > w.e[c];
                    if want == got {
                        if prec == Precision::Mixed {
                            agree_mixed += 1;
                        } else {
                            agree_fp8 += 1;
                        }
                    }
                }
            }
            total += 4;
        }
        let am = agree_mixed as f64 / total as f64;
        let a8 = agree_fp8 as f64 / total as f64;
        assert!(am > 0.97, "mixed agreement {am}");
        assert!(am > a8, "mixed {am} must beat fp8 {a8}");
    }

    #[test]
    fn precision_parse() {
        assert_eq!(Precision::parse("mixed"), Some(Precision::Mixed));
        assert_eq!(Precision::parse("fp8"), Some(Precision::Fp8));
        assert_eq!(Precision::parse("x"), None);
        // Case-insensitive: config files and CLIs disagree about casing.
        assert_eq!(Precision::parse("FP32"), Some(Precision::Fp32));
        assert_eq!(Precision::parse("Mixed"), Some(Precision::Mixed));
        assert_eq!(Precision::parse("fP16"), Some(Precision::Fp16));
        for p in [Precision::Fp32, Precision::Fp16, Precision::Fp8, Precision::Mixed] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
    }
}
