//! Mini-Tile Contribution-Aware Test (paper Sec. III): adaptive leader
//! pixels, pixel-rectangle grouping (Alg. 1), mixed-precision datapath, and
//! the hierarchical two-stage engine that produces mini-tile skip masks.

pub mod engine;
pub mod leader;
pub mod mixed;
pub mod pr;

pub use engine::{CatConfig, CatEngine, CatStats, ExactMinitileMask, ObbSubtileMask};
pub use leader::{LeaderMode, Sampling};
pub use mixed::Precision;
