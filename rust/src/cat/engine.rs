//! Mini-Tile CAT engine: the two-stage hierarchical test (paper Sec. IV-B)
//! producing per-mini-tile skip masks, with op accounting.
//!
//! Stage 1 — sub-tile AABB (preprocessing core): cheap rejection at 8×8
//! granularity; rejected sub-tiles never reach the CTU.
//! Stage 2 — Mini-Tile CAT (CTU): leader pixels via pixel-rectangles at the
//! configured sampling mode and precision; a mini-tile is marked intersected
//! if **any** of its leader pixels receives α ≥ 1/255 (Eq. 2).
//!
//! The engine implements `render::raster::MaskProvider` so the golden
//! rasterizer consumes its masks directly — quality experiments (Table I,
//! Fig. 3, Fig. 7c) render through exactly this path.

use super::leader::{dense_layout, prs_per_subtile, sparse_layout, LeaderMode, PrLayout, Sampling};
use super::mixed::{shared_threshold_quant, PreQuant, Precision};
use super::pr::{pr_op_cost, OpCount};
use crate::numeric::linalg::v2;
use crate::render::project::Splat;
use crate::render::raster::{MaskProvider, MaskSource};
use crate::render::tile::{intersects_aabb, intersects_exact, intersects_obb, Rect};

/// CAT configuration.
#[derive(Clone, Copy, Debug)]
pub struct CatConfig {
    /// Leader-pixel sampling mode.
    pub mode: LeaderMode,
    /// Arithmetic precision of the contribution test.
    pub precision: Precision,
    /// Enable hierarchical Stage 1 (sub-tile AABB pre-filter).
    pub stage1: bool,
}

impl Default for CatConfig {
    fn default() -> Self {
        CatConfig {
            mode: LeaderMode::SmoothFocused,
            precision: Precision::Mixed,
            stage1: true,
        }
    }
}

/// Counters over a frame (drives Fig. 4 and feeds the CTU cycle model).
#[derive(Clone, Debug, Default)]
pub struct CatStats {
    /// (Gaussian, sub-tile) pairs offered to Stage 1.
    pub stage1_tested: u64,
    /// Pairs rejected by the sub-tile AABB.
    pub stage1_rejected: u64,
    /// Pairs reaching the CTU (Stage 2).
    pub ctu_tested: u64,
    /// PRs evaluated.
    pub prs: u64,
    /// Dense-sampled pairs (vs sparse) — the adaptive-mode split.
    pub dense_pairs: u64,
    /// Sparse-sampled pairs.
    pub sparse_pairs: u64,
    /// Mini-tile bits set.
    pub minitiles_passed: u64,
    /// Mini-tile bits examined.
    pub minitiles_tested: u64,
    /// Sub-tiles never offered to Stage 1 because the coarse gate
    /// (`render::pyramid`) already rejected their quadrant — work the CTU
    /// hierarchy saves on top of its own stage-1/stage-2 rejection.
    pub gate_skipped_subtiles: u64,
    /// Arithmetic ops spent on CAT itself (the "overhead" side).
    pub ops: OpCount,
}

impl CatStats {
    /// Fraction of CTU work removed by Stage 1.
    pub fn stage1_reject_rate(&self) -> f64 {
        self.stage1_rejected as f64 / self.stage1_tested.max(1) as f64
    }

    /// Fraction of examined mini-tiles that pass.
    pub fn minitile_pass_rate(&self) -> f64 {
        self.minitiles_passed as f64 / self.minitiles_tested.max(1) as f64
    }

    /// Leader pixels saved by the adaptive mode vs Uniform-Dense.
    pub fn leader_saving_vs_dense(&self) -> f64 {
        let total = self.dense_pairs + self.sparse_pairs;
        if total == 0 {
            return 0.0;
        }
        let used = self.dense_pairs * 16 + self.sparse_pairs * 8;
        1.0 - used as f64 / (total * 16) as f64
    }
}

/// The Mini-Tile CAT engine.
pub struct CatEngine {
    /// The configuration this engine runs.
    pub cfg: CatConfig,
    /// Counters accumulated over the engine's lifetime.
    pub stats: CatStats,
    /// One-entry pre-quantization cache: (splat id, operands, ln(255·o)).
    /// Sub-tiles of the same Gaussian arrive consecutively, so this hits
    /// on 3 of every 4 calls (§Perf).
    cache: Option<(u32, PreQuant, f32)>,
}

impl CatEngine {
    /// New engine with zeroed counters.
    pub fn new(cfg: CatConfig) -> CatEngine {
        CatEngine {
            cfg,
            stats: CatStats::default(),
            cache: None,
        }
    }

    fn prepared(&mut self, splat: &Splat) -> (PreQuant, f32) {
        if let Some((id, pq, lhs)) = self.cache {
            if id == splat.id {
                return (pq, lhs);
            }
        }
        let pq = PreQuant::new(splat.mean, splat.conic, self.cfg.precision);
        let lhs = shared_threshold_quant(splat.opacity, self.cfg.precision);
        self.cache = Some((splat.id, pq, lhs));
        (pq, lhs)
    }

    /// Run Stage 2 on one 8×8 sub-tile; returns a 4-bit mini-tile mask
    /// (bit m = mini-tile m row-major inside the sub-tile).
    pub fn subtile_mask(&mut self, sub: &Rect, splat: &Splat) -> u8 {
        let sampling = self.cfg.mode.sampling(splat);
        match sampling {
            Sampling::Dense => self.stats.dense_pairs += 1,
            Sampling::Sparse => self.stats.sparse_pairs += 1,
        }
        let (pq, lhs) = self.prepared(splat);
        // ln + mul for the shared term, amortized per Gaussian·sub-tile.
        self.stats.ops.mul += 1;
        let mut mask = 0u8;
        let run_pr = |engine: &mut CatEngine, pr: &PrLayout, mask: &mut u8| {
            engine.stats.prs += 1;
            engine.stats.ops.accumulate(pr_op_cost());
            let w = pq.weights(
                v2(sub.x0 + pr.x_top, sub.y0 + pr.y_top),
                v2(sub.x0 + pr.x_bot, sub.y0 + pr.y_bot),
            );
            for k in 0..4 {
                if lhs > w.e[k] {
                    *mask |= 1 << pr.corner_minitile[k];
                }
            }
        };
        match sampling {
            Sampling::Dense => {
                for pr in dense_layout().iter() {
                    run_pr(self, pr, &mut mask);
                }
            }
            Sampling::Sparse => {
                for pr in sparse_layout().iter() {
                    run_pr(self, pr, &mut mask);
                }
            }
        }
        self.stats.minitiles_tested += 4;
        self.stats.minitiles_passed += mask.count_ones() as u64;
        mask
    }

    /// Expected PR count for a splat under the current mode (used by the
    /// cycle model without re-running the mask).
    pub fn prs_for(&self, splat: &Splat) -> usize {
        prs_per_subtile(self.cfg.mode.sampling(splat))
    }

    /// Full-tile mask restricted to the quadrants in `quad_live` (bit
    /// `q = sy·2 + sx` — the coarse gate's [TL, TR, BL, BR] order, which
    /// is exactly this sweep's order). Dead quadrants skip Stage 1 and the
    /// CTU entirely and are tallied in `stats.gate_skipped_subtiles`;
    /// `quad_live = 0xF` is the ungated full-tile mask.
    fn tile_mask(&mut self, tile: &Rect, splat: &Splat, quad_live: u8) -> u32 {
        let mut out = 0u32;
        for sy in 0..2u32 {
            for sx in 0..2u32 {
                if quad_live & (1 << (sy * 2 + sx)) == 0 {
                    self.stats.gate_skipped_subtiles += 1;
                    continue;
                }
                let sub = Rect {
                    x0: tile.x0 + (sx * 8) as f32,
                    y0: tile.y0 + (sy * 8) as f32,
                    x1: tile.x0 + (sx * 8 + 8) as f32,
                    y1: tile.y0 + (sy * 8 + 8) as f32,
                };
                self.stats.stage1_tested += 1;
                if self.cfg.stage1 && !intersects_aabb(splat, &sub) {
                    self.stats.stage1_rejected += 1;
                    continue;
                }
                self.stats.ctu_tested += 1;
                let m4 = self.subtile_mask(&sub, splat);
                // Map sub-tile-local mini-tiles to tile bits: tile mini-tile
                // grid is 4×4; sub-tile (sx,sy) holds cols 2sx..2sx+1, rows
                // 2sy..2sy+1.
                for m in 0..4u32 {
                    if m4 & (1 << m) != 0 {
                        let col = sx * 2 + (m % 2);
                        let row = sy * 2 + (m / 2);
                        out |= 1 << (row * 4 + col);
                    }
                }
            }
        }
        out
    }
}

impl MaskProvider for CatEngine {
    /// Full-tile mask: 16 bits, one per 4×4 mini-tile of a 16×16 tile,
    /// row-major as consumed by the rasterizer.
    fn mask(&mut self, tile: &Rect, splat: &Splat) -> u32 {
        self.tile_mask(tile, splat, 0xF)
    }

    /// Gated full-tile mask: sub-tiles whose quadrant the coarse gate
    /// killed are skipped, saving their Stage-1/CTU work. The caller ANDs
    /// the result with the surviving quadrants' mini-tile bits, so the
    /// blended pixels are identical to the ungated mask.
    fn mask_gated(&mut self, tile: &Rect, splat: &Splat, quad_live: u8) -> u32 {
        self.tile_mask(tile, splat, quad_live)
    }
}

/// A `CatConfig` is a thread-safe mask source: each tile worker gets its
/// own `CatEngine`, so CAT mask generation fans across the worker pool with
/// the tiles. Masks are a pure function of `(tile, splat)` — the engine's
/// cache and counters never change the bits — so tile-parallel CAT renders
/// are bit-identical to sequential ones.
impl MaskSource for CatConfig {
    fn tile_masks(&self) -> Box<dyn MaskProvider + '_> {
        Box::new(CatEngine::new(*self))
    }

    /// Adaptive-precision hook: the tile's engine runs at the classed
    /// precision instead of the config's global one. Everything else
    /// (sampling mode, stage 1) carries over, so a class equal to
    /// `self.precision` yields the identical provider.
    fn tile_masks_at(&self, class: Precision) -> Box<dyn MaskProvider + '_> {
        Box::new(CatEngine::new(CatConfig {
            precision: class,
            ..*self
        }))
    }
}

/// GSCore-style mask provider: OBB test per 8×8 sub-tile; every mini-tile of
/// a passing sub-tile processes the splat (no contribution awareness).
pub struct ObbSubtileMask {
    /// (gaussian, sub-tile) pairs passing — GSCore's duplicate metric.
    pub subtiles_passed: u64,
    /// (gaussian, sub-tile) pairs tested.
    pub subtiles_tested: u64,
}

impl ObbSubtileMask {
    /// New provider with zeroed counters.
    pub fn new() -> Self {
        ObbSubtileMask {
            subtiles_passed: 0,
            subtiles_tested: 0,
        }
    }
}

impl Default for ObbSubtileMask {
    fn default() -> Self {
        Self::new()
    }
}

impl MaskProvider for ObbSubtileMask {
    fn mask(&mut self, tile: &Rect, splat: &Splat) -> u32 {
        let mut out = 0u32;
        for sy in 0..2u32 {
            for sx in 0..2u32 {
                let sub = Rect {
                    x0: tile.x0 + (sx * 8) as f32,
                    y0: tile.y0 + (sy * 8) as f32,
                    x1: tile.x0 + (sx * 8 + 8) as f32,
                    y1: tile.y0 + (sy * 8 + 8) as f32,
                };
                self.subtiles_tested += 1;
                if intersects_obb(splat, &sub) {
                    self.subtiles_passed += 1;
                    for m in 0..4u32 {
                        let col = sx * 2 + (m % 2);
                        let row = sy * 2 + (m / 2);
                        out |= 1 << (row * 4 + col);
                    }
                }
            }
        }
        out
    }
}

/// Oracle provider: the exact continuous test per mini-tile (upper bound on
/// achievable skipping; CAT approximates this with finitely many leaders).
pub struct ExactMinitileMask;

impl MaskProvider for ExactMinitileMask {
    fn mask(&mut self, tile: &Rect, splat: &Splat) -> u32 {
        let mut out = 0u32;
        for row in 0..4u32 {
            for col in 0..4u32 {
                let mt = Rect {
                    x0: tile.x0 + (col * 4) as f32,
                    y0: tile.y0 + (row * 4) as f32,
                    x1: tile.x0 + (col * 4 + 4) as f32,
                    y1: tile.y0 + (row * 4 + 4) as f32,
                };
                if intersects_exact(splat, &mt, crate::render::project::ALPHA_MIN) {
                    out |= 1 << (row * 4 + col);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::{v3, Quat};
    use crate::render::project::project_one;
    use crate::scene::gaussian::Scene;

    fn splat(scale: crate::numeric::linalg::Vec3, mean_px: (f32, f32), opacity: f32) -> Splat {
        let cam = Camera::look_at(
            Intrinsics::from_fov(256, 256, 1.2),
            v3(0.0, 0.0, -6.0),
            v3(0.0, 0.0, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        let mut sc = Scene::with_capacity(1, "t");
        sc.push(v3(0.0, 0.0, 0.0), Quat::IDENTITY, scale, opacity, [1.0; 3], [[0.0; 3]; 3]);
        let mut s = project_one(&sc, 0, &cam).unwrap();
        s.mean = v2(mean_px.0, mean_px.1);
        s
    }

    fn tile_at(x: f32, y: f32) -> Rect {
        Rect { x0: x, y0: y, x1: x + 16.0, y1: y + 16.0 }
    }

    #[test]
    fn big_gaussian_lights_every_minitile() {
        let s = splat(v3(2.0, 2.0, 2.0), (104.0, 104.0), 0.95);
        let mut e = CatEngine::new(CatConfig::default());
        let m = e.mask(&tile_at(96.0, 96.0), &s);
        assert_eq!(m, 0xFFFF, "mask {m:#06x}");
    }

    #[test]
    fn distant_gaussian_lights_nothing() {
        let s = splat(v3(0.2, 0.2, 0.2), (400.0, 400.0), 0.95);
        let mut e = CatEngine::new(CatConfig::default());
        // Stage 1 rejects all sub-tiles.
        let m = e.mask(&tile_at(0.0, 0.0), &s);
        assert_eq!(m, 0);
        assert_eq!(e.stats.stage1_rejected, 4);
        assert_eq!(e.stats.ctu_tested, 0);
    }

    #[test]
    fn small_gaussian_lights_only_its_corner() {
        // Tiny splat near tile origin: top-left mini-tile(s) only.
        let s = splat(v3(0.08, 0.08, 0.08), (98.0, 98.0), 0.95);
        let mut e = CatEngine::new(CatConfig::default());
        let m = e.mask(&tile_at(96.0, 96.0), &s);
        assert!(m & 1 != 0, "top-left minitile must pass: {m:#06x}");
        // Bottom-right quadrant untouched.
        for row in 2..4 {
            for col in 2..4 {
                assert_eq!(m & (1 << (row * 4 + col)), 0, "bit {row},{col}");
            }
        }
    }

    #[test]
    fn dense_mask_superset_of_exact_center_hits() {
        // If the exact oracle says a mini-tile's *leader corner pixels*
        // contribute, dense CAT must catch it; globally CAT(dense) must hit
        // every mini-tile whose 4 corners include a contributing pixel.
        let s = splat(v3(0.6, 0.15, 0.15), (128.0, 120.0), 0.9);
        let cfg = CatConfig {
            mode: LeaderMode::UniformDense,
            precision: Precision::Fp32,
            stage1: false,
        };
        let mut e = CatEngine::new(cfg);
        let tile = tile_at(112.0, 112.0);
        let m = e.mask(&tile, &s);
        for row in 0..4u32 {
            for col in 0..4u32 {
                // Dense leader pixels of this minitile:
                let corners = [
                    (0.5f32, 0.5f32),
                    (3.5, 0.5),
                    (0.5, 3.5),
                    (3.5, 3.5),
                ];
                let any = corners.iter().any(|&(dx, dy)| {
                    s.alpha_at(
                        tile.x0 + (col * 4) as f32 + dx,
                        tile.y0 + (row * 4) as f32 + dy,
                    ) >= 1.0 / 255.0
                });
                if any {
                    assert!(
                        m & (1 << (row * 4 + col)) != 0,
                        "minitile {row},{col} corner contributes but mask missed"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_uses_fewer_prs() {
        let s = splat(v3(1.0, 1.0, 1.0), (104.0, 104.0), 0.9);
        let mut dense = CatEngine::new(CatConfig {
            mode: LeaderMode::UniformDense,
            precision: Precision::Fp32,
            stage1: false,
        });
        let mut sparse = CatEngine::new(CatConfig {
            mode: LeaderMode::UniformSparse,
            precision: Precision::Fp32,
            stage1: false,
        });
        dense.mask(&tile_at(96.0, 96.0), &s);
        sparse.mask(&tile_at(96.0, 96.0), &s);
        assert_eq!(dense.stats.prs, 16); // 4 sub-tiles × 4 PRs
        assert_eq!(sparse.stats.prs, 8); // 4 sub-tiles × 2 PRs
        assert!(sparse.stats.ops.total() < dense.stats.ops.total());
    }

    #[test]
    fn adaptive_splits_by_shape() {
        let smooth = splat(v3(0.5, 0.5, 0.5), (104.0, 104.0), 0.9);
        let spiky = splat(v3(1.5, 0.1, 0.1), (104.0, 104.0), 0.9);
        assert!(!smooth.is_spiky(3.0));
        assert!(spiky.is_spiky(3.0));
        let mut e = CatEngine::new(CatConfig {
            mode: LeaderMode::SmoothFocused,
            precision: Precision::Fp32,
            stage1: false,
        });
        e.mask(&tile_at(96.0, 96.0), &smooth);
        e.mask(&tile_at(96.0, 96.0), &spiky);
        assert_eq!(e.stats.dense_pairs, 4); // smooth → dense, 4 sub-tiles
        assert_eq!(e.stats.sparse_pairs, 4); // spiky → sparse
        assert!(e.stats.leader_saving_vs_dense() > 0.2);
    }

    #[test]
    fn obb_subtile_mask_quantized_to_subtiles() {
        let s = splat(v3(0.3, 0.3, 0.3), (98.0, 98.0), 0.9);
        let mut p = ObbSubtileMask::new();
        let m = p.mask(&tile_at(96.0, 96.0), &s);
        // Whole sub-tiles: the top-left 2×2 mini-tile block all set or none.
        let tl = (m & 1 != 0, m & 2 != 0, m & (1 << 4) != 0, m & (1 << 5) != 0);
        assert!(tl.0 == tl.1 && tl.1 == tl.2 && tl.2 == tl.3, "subtile not atomic: {m:#06x}");
        assert!(p.subtiles_tested == 4);
    }

    #[test]
    fn cat_mask_tighter_than_obb() {
        // For a spiky diagonal splat the CAT mask has fewer bits than the
        // OBB sub-tile mask.
        let cam = Camera::look_at(
            Intrinsics::from_fov(256, 256, 1.2),
            v3(0.0, 0.0, -6.0),
            v3(0.0, 0.0, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        let mut sc = Scene::with_capacity(1, "t");
        sc.push(
            v3(0.0, 0.0, 0.0),
            Quat::from_axis_angle(v3(0.0, 0.0, 1.0), 0.8),
            v3(1.2, 0.05, 0.05),
            0.9,
            [1.0; 3],
            [[0.0; 3]; 3],
        );
        let s = project_one(&sc, 0, &cam).unwrap();
        let tile = tile_at(120.0, 120.0);
        let mut cat = CatEngine::new(CatConfig::default());
        let mut obb = ObbSubtileMask::new();
        let mc = cat.mask(&tile, &s).count_ones();
        let mo = obb.mask(&tile, &s).count_ones();
        assert!(mc <= mo, "cat {mc} bits vs obb {mo}");
    }

    #[test]
    fn exact_oracle_subset_of_dense_superset_check() {
        // CAT can miss interior-only contributions but must never *add*
        // mini-tiles the oracle rejects (leaders are inside the mini-tile).
        let s = splat(v3(0.4, 0.12, 0.12), (130.0, 125.0), 0.9);
        let tile = tile_at(112.0, 112.0);
        let mut cat = CatEngine::new(CatConfig {
            mode: LeaderMode::UniformDense,
            precision: Precision::Fp32,
            stage1: false,
        });
        let mut oracle = ExactMinitileMask;
        let mc = cat.mask(&tile, &s);
        let mo = oracle.mask(&tile, &s);
        assert_eq!(mc & !mo, 0, "cat {mc:#06x} claims minitiles oracle rejects {mo:#06x}");
    }

    #[test]
    fn gated_mask_skips_dead_quadrants_without_adding_bits() {
        let s = splat(v3(2.0, 2.0, 2.0), (104.0, 104.0), 0.95);
        let tile = tile_at(96.0, 96.0);
        let mut full = CatEngine::new(CatConfig::default());
        let mut gated = CatEngine::new(CatConfig::default());
        let mf = full.mask(&tile, &s);
        // Only TL + BR quadrants live: the dead sub-tiles never reach
        // Stage 1, and the live quadrants' bits match the full mask.
        let mg = gated.tile_mask(&tile, &s, 0b1001);
        assert_eq!(gated.stats.gate_skipped_subtiles, 2);
        assert_eq!(gated.stats.stage1_tested, 2);
        let tl_bits: u32 = 1 | (1 << 1) | (1 << 4) | (1 << 5);
        let br_bits: u32 = (1 << 10) | (1 << 11) | (1 << 14) | (1 << 15);
        assert_eq!(mg & tl_bits, mf & tl_bits);
        assert_eq!(mg & br_bits, mf & br_bits);
        assert_eq!(mg & !(tl_bits | br_bits), 0, "dead quadrants contributed bits");
        // An all-live hint is exactly the ungated mask, with no skips.
        let mut all = CatEngine::new(CatConfig::default());
        assert_eq!(all.tile_mask(&tile, &s, 0xF), mf);
        assert_eq!(all.stats.gate_skipped_subtiles, 0);
    }

    #[test]
    fn rect_stitched_masks_match_single_engine_per_quadrant() {
        use crate::render::pyramid::TilePyramid;
        let cfg = CatConfig::default();
        let tile = tile_at(96.0, 96.0);
        let splats = [
            splat(v3(2.0, 2.0, 2.0), (104.0, 104.0), 0.95),
            splat(v3(0.4, 0.12, 0.12), (100.0, 108.0), 0.9),
            splat(v3(0.08, 0.08, 0.08), (110.0, 98.0), 0.95),
        ];
        let pyr = TilePyramid::new(&tile, 16);
        // Uniform map: stitching must reproduce the single-engine mask.
        let mut uniform = cfg.tile_masks_rect(16, [Precision::Fp16; 4]);
        let mut single = cfg.tile_masks_at(Precision::Fp16);
        for s in &splats {
            assert_eq!(uniform.mask(&tile, s), single.mask(&tile, s));
        }
        // Mixed map: each quadrant's bits come from an engine at that
        // quadrant's class, so per-quadrant they match a dedicated engine.
        let classes = [Precision::Fp32, Precision::Fp16, Precision::Fp16, Precision::Mixed];
        let mut stitched = cfg.tile_masks_rect(16, classes);
        for s in &splats {
            let m = stitched.mask(&tile, s);
            for q in 0..4 {
                let qbits = pyr.quad_minitile_mask(q);
                let mut at = cfg.tile_masks_at(classes[q]);
                assert_eq!(
                    m & qbits,
                    at.mask(&tile, s) & qbits,
                    "quadrant {q} bits diverge from a dedicated engine"
                );
            }
        }
    }

    #[test]
    fn cat_source_parallel_matches_sequential_engine() {
        use crate::render::plan::FramePlan;
        use crate::render::raster::{render_masked, RenderOptions};
        use crate::scene::synthetic::{generate_scaled, preset};
        let scene = generate_scaled(&preset("truck"), 0.01);
        let cam = Camera::look_at(
            Intrinsics::from_fov(96, 96, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        let cfg = CatConfig::default();
        let opts = RenderOptions::default();
        let mut engine = CatEngine::new(cfg);
        let seq = render_masked(&scene, &cam, &opts, &mut engine, None);
        let plan = FramePlan::build(&scene, &cam, &RenderOptions { workers: 4, ..opts });
        let par = plan.render(&cfg, None);
        assert_eq!(seq.image.data, par.image.data);
        assert_eq!(seq.stats.pairs_tested, par.stats.pairs_tested);
    }

    #[test]
    fn stage1_reduces_ctu_load_without_changing_mask() {
        // Small enough that its 3σ box misses the far sub-tiles.
        let s = splat(v3(0.05, 0.05, 0.05), (98.0, 98.0), 0.9);
        let tile = tile_at(96.0, 96.0);
        let mut with = CatEngine::new(CatConfig { stage1: true, ..Default::default() });
        let mut without = CatEngine::new(CatConfig { stage1: false, ..Default::default() });
        let mw = with.mask(&tile, &s);
        let mo = without.mask(&tile, &s);
        assert_eq!(mw, mo, "stage1 must be conservative");
        assert!(with.stats.ctu_tested < without.stats.ctu_tested);
    }
}
