//! Adaptive leader-pixel schemes (paper Sec. III-A) and the PR layout each
//! induces inside an 8×8 sub-tile (paper Fig. 3).
//!
//! A sub-tile holds 4 mini-tiles of 4×4 pixels. Leader pixels per mini-tile:
//! * **Dense** — the mini-tile's four corner pixels; they form one PR per
//!   mini-tile (4 PRs / sub-tile).
//! * **Sparse** — two diagonal corner pixels. Mini-tiles 0/3 use the main
//!   diagonal and 1/2 the anti-diagonal, so the sub-tile's 8 sparse leaders
//!   form exactly **two** PRs across mini-tiles: the outer PR
//!   {0,7}×{0,7} and the inner PR {3,4}×{3,4}.
//!
//! The adaptive modes pick Dense or Sparse *per Gaussian* from its projected
//! axis ratio (smooth < 3 ≤ spiky).

use crate::render::project::Splat;

/// Uniform or shape-adaptive sampling selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeaderMode {
    /// Every Gaussian gets Dense sampling (4 leader pixels per mini-tile).
    UniformDense,
    /// Every Gaussian gets Sparse sampling (2 leader pixels per mini-tile).
    UniformSparse,
    /// Smooth Gaussians get Dense sampling, spiky get Sparse (the paper's
    /// default adaptive mode).
    SmoothFocused,
    /// Inverse: spiky get Dense (for scenes whose detail lives in spiky
    /// Gaussians).
    SpikyFocused,
}

/// Sampling density chosen for one Gaussian.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sampling {
    /// Four corner leader pixels per mini-tile.
    Dense,
    /// Two diagonal leader pixels per mini-tile.
    Sparse,
}

/// Paper threshold: axis ratio ≥ 3 ⇒ spiky.
pub const SPIKY_AXIS_RATIO: f32 = 3.0;

impl LeaderMode {
    /// Pick the sampling for a splat.
    #[inline]
    pub fn sampling(self, splat: &Splat) -> Sampling {
        self.sampling_for(splat.is_spiky(SPIKY_AXIS_RATIO))
    }

    /// Pick the sampling from a precomputed spikiness classification.
    #[inline]
    pub fn sampling_for(self, spiky: bool) -> Sampling {
        match self {
            LeaderMode::UniformDense => Sampling::Dense,
            LeaderMode::UniformSparse => Sampling::Sparse,
            LeaderMode::SmoothFocused => {
                if spiky {
                    Sampling::Sparse
                } else {
                    Sampling::Dense
                }
            }
            LeaderMode::SpikyFocused => {
                if spiky {
                    Sampling::Dense
                } else {
                    Sampling::Sparse
                }
            }
        }
    }

    /// Parse a CLI/config mode name ("dense", "sparse", "adaptive", …).
    pub fn parse(s: &str) -> Option<LeaderMode> {
        Some(match s {
            "dense" | "uniform-dense" => LeaderMode::UniformDense,
            "sparse" | "uniform-sparse" => LeaderMode::UniformSparse,
            "adaptive" | "smooth-focused" => LeaderMode::SmoothFocused,
            "spiky-focused" => LeaderMode::SpikyFocused,
            _ => return None,
        })
    }
}

/// One PR inside a sub-tile: x/y coordinate pairs (sub-tile local, pixel
/// centers at +0.5) and, per corner, which mini-tile the corner's decision
/// feeds (0..4, row-major mini-tile index inside the sub-tile).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrLayout {
    /// Top corner x (sub-tile pixel coords, centers at +0.5).
    pub x_top: f32,
    /// Top corner y.
    pub y_top: f32,
    /// Bottom corner x.
    pub x_bot: f32,
    /// Bottom corner y.
    pub y_bot: f32,
    /// Mini-tile fed by corner k (order E0..E3 as in Alg. 1:
    /// (xt,yt), (xb,yt), (xt,yb), (xb,yb)).
    pub corner_minitile: [u8; 4],
}

/// Dense layout: one PR per mini-tile (4 PRs). Mini-tile m at (mx, my)
/// covers pixels [4mx, 4mx+3] × [4my, 4my+3]; its corner pixels are the PR.
pub const fn dense_layout() -> [PrLayout; 4] {
    let mut prs = [PrLayout {
        x_top: 0.0,
        y_top: 0.0,
        x_bot: 0.0,
        y_bot: 0.0,
        corner_minitile: [0; 4],
    }; 4];
    let mut m = 0;
    while m < 4 {
        let mx = (m % 2) as f32;
        let my = (m / 2) as f32;
        prs[m] = PrLayout {
            x_top: 4.0 * mx + 0.5,
            y_top: 4.0 * my + 0.5,
            x_bot: 4.0 * mx + 3.5,
            y_bot: 4.0 * my + 3.5,
            corner_minitile: [m as u8; 4],
        };
        m += 1;
    }
    prs
}

/// Sparse layout: two PRs spanning the sub-tile.
/// Outer PR corners (0,0),(7,0),(0,7),(7,7) feed mini-tiles 0,1,2,3;
/// inner PR corners (3,3),(4,3),(3,4),(4,4) feed mini-tiles 0,1,2,3.
/// Each mini-tile thus gets its two diagonal leader pixels.
pub const fn sparse_layout() -> [PrLayout; 2] {
    [
        PrLayout {
            x_top: 0.5,
            y_top: 0.5,
            x_bot: 7.5,
            y_bot: 7.5,
            corner_minitile: [0, 1, 2, 3],
        },
        PrLayout {
            x_top: 3.5,
            y_top: 3.5,
            x_bot: 4.5,
            y_bot: 4.5,
            corner_minitile: [0, 1, 2, 3],
        },
    ]
}

/// Leader pixels per Gaussian per sub-tile for a sampling mode.
pub fn leaders_per_subtile(s: Sampling) -> usize {
    match s {
        Sampling::Dense => 16, // 4 PRs × 4 corners
        Sampling::Sparse => 8, // 2 PRs × 4 corners
    }
}

/// PRs per Gaussian per sub-tile.
pub fn prs_per_subtile(s: Sampling) -> usize {
    match s {
        Sampling::Dense => 4,
        Sampling::Sparse => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_prs_cover_each_minitile() {
        let prs = dense_layout();
        for (m, pr) in prs.iter().enumerate() {
            assert_eq!(pr.corner_minitile, [m as u8; 4]);
            // Corners inside the mini-tile bounds.
            let mx = (m % 2) as f32 * 4.0;
            let my = (m / 2) as f32 * 4.0;
            assert!(pr.x_top >= mx && pr.x_bot < mx + 4.0);
            assert!(pr.y_top >= my && pr.y_bot < my + 4.0);
        }
    }

    #[test]
    fn sparse_gives_each_minitile_two_diagonal_leaders() {
        // Collect (minitile, pixel) pairs from the sparse layout.
        let mut per_mt: [Vec<(f32, f32)>; 4] = Default::default();
        for pr in sparse_layout() {
            let corners = [
                (pr.x_top, pr.y_top),
                (pr.x_bot, pr.y_top),
                (pr.x_top, pr.y_bot),
                (pr.x_bot, pr.y_bot),
            ];
            for (k, &(x, y)) in corners.iter().enumerate() {
                per_mt[pr.corner_minitile[k] as usize].push((x, y));
            }
        }
        for (m, leaders) in per_mt.iter().enumerate() {
            assert_eq!(leaders.len(), 2, "mini-tile {m}");
            // Both leaders inside the mini-tile.
            let mx = (m % 2) as f32 * 4.0;
            let my = (m / 2) as f32 * 4.0;
            for &(x, y) in leaders {
                assert!(x >= mx && x < mx + 4.0, "mt {m} leader x {x}");
                assert!(y >= my && y < my + 4.0, "mt {m} leader y {y}");
            }
            // Diagonal: the two leaders differ in both coordinates.
            assert!(leaders[0].0 != leaders[1].0);
            assert!(leaders[0].1 != leaders[1].1);
        }
    }

    #[test]
    fn sparse_halves_leader_count() {
        assert_eq!(leaders_per_subtile(Sampling::Dense), 16);
        assert_eq!(leaders_per_subtile(Sampling::Sparse), 8);
        assert_eq!(prs_per_subtile(Sampling::Dense), 4);
        assert_eq!(prs_per_subtile(Sampling::Sparse), 2);
    }

    #[test]
    fn mode_selection_logic() {
        assert_eq!(LeaderMode::UniformDense.sampling_for(true), Sampling::Dense);
        assert_eq!(LeaderMode::UniformSparse.sampling_for(false), Sampling::Sparse);
        assert_eq!(LeaderMode::SmoothFocused.sampling_for(false), Sampling::Dense);
        assert_eq!(LeaderMode::SmoothFocused.sampling_for(true), Sampling::Sparse);
        assert_eq!(LeaderMode::SpikyFocused.sampling_for(true), Sampling::Dense);
        assert_eq!(LeaderMode::SpikyFocused.sampling_for(false), Sampling::Sparse);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(LeaderMode::parse("dense"), Some(LeaderMode::UniformDense));
        assert_eq!(LeaderMode::parse("adaptive"), Some(LeaderMode::SmoothFocused));
        assert_eq!(LeaderMode::parse("spiky-focused"), Some(LeaderMode::SpikyFocused));
        assert_eq!(LeaderMode::parse("bogus"), None);
    }
}
