//! Pixel-Rectangle (PR) Gaussian-weight computation — paper Alg. 1.
//!
//! A PR is four leader pixels at the corners of an axis-aligned rectangle
//! {x_top, x_bot} × {y_top, y_bot}. The quadratic form
//! E(p) = ½ (p−μ)ᵀ Σ′⁻¹ (p−μ) decomposes into per-axis terms
//! sˣ = ½ Δx² Σ′⁻¹ₓₓ and sʸ = ½ Δy² Σ′⁻¹ᵧᵧ plus the cross term
//! t = Δx Δy Σ′⁻¹ₓᵧ. Because the four corners share the two Δx and two Δy
//! values, the PRTU computes 4 axis terms + 4 cross terms and assembles all
//! four E values — nearly half the multiplies of four independent
//! evaluations (the ACU baseline [7][17][18]).

use crate::numeric::linalg::{Sym2, Vec2};

/// Arithmetic-op counters (multiplies/adds dominate CTU area & energy; the
/// analysis behind Fig. 3(b) and the CTU throughput model).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCount {
    /// Multiplies.
    pub mul: u64,
    /// Additions.
    pub add: u64,
    /// Subtractions (coordinate deltas).
    pub sub: u64,
    /// Comparisons (threshold tests).
    pub cmp: u64,
}

impl OpCount {
    /// All operations combined.
    pub fn total(&self) -> u64 {
        self.mul + self.add + self.sub + self.cmp
    }

    /// Fold another counter into this one.
    pub fn accumulate(&mut self, o: OpCount) {
        self.mul += o.mul;
        self.add += o.add;
        self.sub += o.sub;
        self.cmp += o.cmp;
    }
}

/// Gaussian weights E at the four PR corners, in the paper's order:
/// E0 = (x_top, y_top), E1 = (x_bot, y_top), E2 = (x_top, y_bot),
/// E3 = (x_bot, y_bot).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrWeights {
    /// E at corners [E0, E1, E2, E3].
    pub e: [f32; 4],
}

/// Alg. 1 exactly as written (FP32 reference). `p_top` and `p_bot` are the
/// main-diagonal pixel coordinates (p0 and p3 of the PR).
pub fn pr_weights(mu: Vec2, conic: Sym2, p_top: Vec2, p_bot: Vec2) -> PrWeights {
    // line 1: deltas
    let d_top_x = p_top.x - mu.x;
    let d_top_y = p_top.y - mu.y;
    let d_bot_x = p_bot.x - mu.x;
    let d_bot_y = p_bot.y - mu.y;
    // lines 2–3: per-axis quadratic terms
    let s_top_x = 0.5 * d_top_x * d_top_x * conic.a;
    let s_top_y = 0.5 * d_top_y * d_top_y * conic.c;
    let s_bot_x = 0.5 * d_bot_x * d_bot_x * conic.a;
    let s_bot_y = 0.5 * d_bot_y * d_bot_y * conic.c;
    // lines 4–5: cross terms (Σ′⁻¹ₓᵧ = conic.b)
    let t0 = d_top_x * d_top_y * conic.b;
    let t1 = d_bot_x * d_top_y * conic.b;
    let t2 = d_top_x * d_bot_y * conic.b;
    let t3 = d_bot_x * d_bot_y * conic.b;
    // lines 6–7: assemble corners
    PrWeights {
        e: [
            s_top_x + s_top_y + t0,
            s_bot_x + s_top_y + t1,
            s_top_x + s_bot_y + t2,
            s_bot_x + s_bot_y + t3,
        ],
    }
}

/// Direct per-pixel evaluation (what the ACU computes): E for one pixel.
pub fn acu_weight(mu: Vec2, conic: Sym2, p: Vec2) -> f32 {
    let dx = p.x - mu.x;
    let dy = p.y - mu.y;
    0.5 * (conic.a * dx * dx + conic.c * dy * dy) + conic.b * dx * dy
}

/// Op cost of one PR through Alg. 1 (4 pixels).
/// line 1: 4 subs; lines 2–3: 4×3 muls; lines 4–5: 4×2 muls;
/// lines 6–7: 4×2 adds; plus 4 threshold compares.
pub fn pr_op_cost() -> OpCount {
    OpCount {
        sub: 4,
        mul: 12 + 8,
        add: 8,
        cmp: 4,
    }
}

/// Op cost of evaluating the same 4 pixels individually on an ACU.
/// Per pixel: 2 subs; E = ½(a·dx² + c·dy²) + b·dx·dy →
/// dx²,dy² (2) + ·a,·c (2) + ·½ (2, no shared factor in the per-pixel
/// datapath) + dx·dy (1) + ·b (1) = 8 muls; 2 adds; 1 compare.
pub fn acu_op_cost_4px() -> OpCount {
    OpCount {
        sub: 8,
        mul: 32,
        add: 8,
        cmp: 4,
    }
}

/// The shared left-hand side of Eq. 2: ln(255·o). One per Gaussian,
/// amortized over every leader pixel tested against it.
#[inline]
pub fn shared_threshold(opacity: f32) -> f32 {
    (255.0 * opacity).ln()
}

/// [`shared_threshold`] generalized to an arbitrary alpha cutoff:
/// ln(o / α_min) — a point with E at or above this value cannot reach
/// α ≥ α_min. `shared_threshold(o)` is the α_min = 1/255 case (up to
/// rounding). The clamp keeps zero-opacity splats finite (they reject
/// everywhere, as they should). The coarse gate (`render::pyramid`) uses
/// this as its per-level cutoff.
#[inline]
pub fn shared_threshold_at(opacity: f32, alpha_min: f32) -> f32 {
    (opacity / alpha_min).max(1e-12).ln()
}

/// Eq. 2 decision: does the pixel pass (contribute)?
/// α = o·e^{−E} ≥ 1/255  ⇔  ln(255·o) > E.
#[inline]
pub fn passes(threshold_lhs: f32, e: f32) -> bool {
    threshold_lhs > e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::linalg::v2;
    use crate::util::rng::Pcg32;

    fn random_conic(rng: &mut Pcg32) -> Sym2 {
        // Positive-definite: A = LLᵀ with L lower-triangular.
        let l11 = rng.range_f32(0.05, 1.0);
        let l21 = rng.range_f32(-0.5, 0.5);
        let l22 = rng.range_f32(0.05, 1.0);
        Sym2 {
            a: l11 * l11,
            b: l11 * l21,
            c: l21 * l21 + l22 * l22,
        }
    }

    #[test]
    fn pr_matches_acu_at_all_corners() {
        let mut rng = Pcg32::new(71);
        for _ in 0..500 {
            let mu = v2(rng.range_f32(0.0, 256.0), rng.range_f32(0.0, 256.0));
            let conic = random_conic(&mut rng);
            let p_top = v2(rng.range_f32(0.0, 256.0), rng.range_f32(0.0, 256.0));
            let p_bot = v2(p_top.x + rng.range_f32(1.0, 8.0), p_top.y + rng.range_f32(1.0, 8.0));
            let w = pr_weights(mu, conic, p_top, p_bot);
            let expect = [
                acu_weight(mu, conic, v2(p_top.x, p_top.y)),
                acu_weight(mu, conic, v2(p_bot.x, p_top.y)),
                acu_weight(mu, conic, v2(p_top.x, p_bot.y)),
                acu_weight(mu, conic, v2(p_bot.x, p_bot.y)),
            ];
            for k in 0..4 {
                assert!(
                    (w.e[k] - expect[k]).abs() <= 1e-3 * (1.0 + expect[k].abs()),
                    "corner {k}: {} vs {}",
                    w.e[k],
                    expect[k]
                );
            }
        }
    }

    #[test]
    fn weights_nonnegative_for_psd_conic() {
        let mut rng = Pcg32::new(72);
        for _ in 0..200 {
            let conic = random_conic(&mut rng);
            let mu = v2(100.0, 100.0);
            let w = pr_weights(mu, conic, v2(90.0, 95.0), v2(110.0, 105.0));
            for e in w.e {
                assert!(e >= -1e-4, "negative weight {e}");
            }
        }
    }

    #[test]
    fn weight_zero_at_mean() {
        let conic = Sym2 { a: 0.5, b: 0.1, c: 0.3 };
        let mu = v2(10.0, 20.0);
        let w = pr_weights(mu, conic, mu, v2(14.0, 24.0));
        assert!(w.e[0].abs() < 1e-6);
    }

    #[test]
    fn op_saving_is_nearly_half() {
        let pr = pr_op_cost();
        let acu = acu_op_cost_4px();
        let saving = 1.0 - pr.mul as f64 / acu.mul as f64;
        assert!(
            saving >= 0.35,
            "multiplier saving {saving} should be ~0.4–0.5"
        );
        assert!(pr.total() < acu.total());
    }

    #[test]
    fn threshold_equation_matches_alpha_test() {
        // ln(255·o) > E  ⇔  o·e^{−E} > 1/255.
        let mut rng = Pcg32::new(73);
        for _ in 0..1000 {
            let o = rng.range_f32(0.01, 1.0);
            let e = rng.range_f32(0.0, 12.0);
            let lhs = shared_threshold(o);
            let alpha = o * (-e).exp();
            assert_eq!(
                passes(lhs, e),
                alpha > 1.0 / 255.0 + 1e-9 || (alpha - 1.0 / 255.0).abs() < 1e-7 && lhs > e,
                "o={o} e={e} alpha={alpha}"
            );
        }
    }

    #[test]
    fn generalized_threshold_matches_specialized() {
        let mut rng = Pcg32::new(74);
        for _ in 0..200 {
            let o = rng.range_f32(0.01, 1.0);
            let a = shared_threshold(o);
            let b = shared_threshold_at(o, 1.0 / 255.0);
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            // A coarser (higher-alpha) cutoff lowers the E threshold.
            assert!(shared_threshold_at(o, 8.0 / 255.0) < b);
        }
        // Zero opacity stays finite and rejects even E = 0.
        let z = shared_threshold_at(0.0, 1.0 / 255.0);
        assert!(z.is_finite() && z < 0.0);
        assert!(!passes(z, 0.0));
    }

    #[test]
    fn low_opacity_never_passes() {
        // o < 1/255 ⇒ ln(255·o) < 0 ≤ E for all points.
        let lhs = shared_threshold(1.0 / 300.0);
        assert!(lhs < 0.0);
        assert!(!passes(lhs, 0.0));
    }
}
