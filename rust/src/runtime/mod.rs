//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from the Rust hot path.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax>=0.5's 64-bit
//! instruction-id protos; the text parser reassigns ids). Executables are
//! compiled once at startup and cached; Python never runs at frame time.

pub mod executor;

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json` (written by python/compile/aot.py).
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Gaussian batch size the artifacts are monomorphized to.
    pub n_gauss: usize,
    /// PR batch size.
    pub n_pr: usize,
    pub tile: usize,
    /// name -> artifact filename.
    pub files: HashMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let need =
            |k: &str| j.at(&[k]).and_then(Json::as_u64).ok_or_else(|| anyhow!("manifest: {k}"));
        let mut files = HashMap::new();
        let arts = j
            .at(&["artifacts"])
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: artifacts"))?;
        for (name, v) in arts.iter() {
            let file = v
                .at(&["file"])
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest: artifacts.{name}.file"))?;
            files.insert(name.clone(), file.to_string());
        }
        Ok(Manifest {
            n_gauss: need("n_gauss")? as usize,
            n_pr: need("n_pr")? as usize,
            tile: need("tile")? as usize,
            files,
        })
    }
}

/// A compiled PJRT runtime with all artifacts loaded.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client and compile every artifact in the manifest.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let manifest = Manifest::load(dir)?;
        let mut executables = HashMap::new();
        for (name, file) in &manifest.files {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Runtime {
            client,
            manifest,
            executables,
            dir: dir.to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute artifact `name` on f32 input tensors (data, dims). Returns
    /// the flattened f32 outputs (artifacts are lowered with
    /// `return_tuple=True`, so results arrive as one tuple literal).
    pub fn exec_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expect: i64 = dims.iter().product();
            if expect as usize != data.len() {
                bail!(
                    "{name}: input length {} != shape {:?} product",
                    data.len(),
                    dims
                );
            }
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))?;
            lits.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Locate the artifacts directory: $FLICKER_ARTIFACTS, ./artifacts, or the
/// crate-root artifacts dir (tests may run from target subdirs).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("FLICKER_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let local = Path::new("artifacts");
    if local.join("manifest.json").exists() {
        return local.to_path_buf();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        default_artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&default_artifact_dir()).unwrap();
        assert_eq!(m.tile, 16);
        assert!(m.n_gauss >= 128);
        for k in ["project", "pr_weight", "cat_masks", "render_tile"] {
            assert!(m.files.contains_key(k), "missing artifact {k}");
        }
    }

    #[test]
    fn runtime_loads_and_runs_pr_weight() {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&default_artifact_dir()).unwrap();
        let n = rt.manifest.n_gauss;
        let m = rt.manifest.n_pr;
        // One Gaussian at (10, 10) with a simple diagonal conic, rest far.
        let mut mu = vec![1e6f32; n * 2];
        mu[0] = 10.0;
        mu[1] = 10.0;
        let mut conic = vec![0.0f32; n * 3];
        for i in 0..n {
            conic[i * 3] = 0.5;
            conic[i * 3 + 2] = 0.5;
        }
        let mut p_top = vec![0.0f32; m * 2];
        let mut p_bot = vec![0.0f32; m * 2];
        for k in 0..m {
            p_top[k * 2] = 10.0;
            p_top[k * 2 + 1] = 10.0;
            p_bot[k * 2] = 13.0;
            p_bot[k * 2 + 1] = 13.0;
        }
        let out = rt
            .exec_f32(
                "pr_weight",
                &[
                    (&mu, &[n as i64, 2]),
                    (&conic, &[n as i64, 3]),
                    (&p_top, &[m as i64, 2]),
                    (&p_bot, &[m as i64, 2]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let e = &out[0]; // (M, N, 4)
        assert_eq!(e.len(), m * n * 4);
        // Corner 0 of PR 0 vs Gaussian 0 sits exactly on mu -> E = 0.
        assert!(e[0].abs() < 1e-4, "E00 = {}", e[0]);
        // Corner 3 at (13,13): E = 0.5*0.5*(9+9) = 4.5.
        let e3 = e[3];
        assert!((e3 - 4.5).abs() < 1e-3, "E03 = {e3}");
    }
}
