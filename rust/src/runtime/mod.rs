//! Artifact manifest + (feature-gated) PJRT runtime.
//!
//! The manifest layer (`Manifest`, [`default_artifact_dir`],
//! [`write_stub_artifacts`]) is pure Rust and always compiled: tests and
//! tooling can inspect `artifacts/manifest.json` (written by
//! python/compile/aot.py) without any XLA linkage. The PJRT execution path
//! ([`Runtime`], [`executor`]) loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and only exists under the `pjrt` cargo feature;
//! the default build is offline and dependency-free. With the default
//! in-tree `xla` stub, a runtime loaded from a [`write_stub_artifacts`]
//! directory executes through the stub's built-in reference kernels — the
//! offline backbone of the batched-execution differential test harness.

#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(feature = "pjrt")]
mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json` (written by python/compile/aot.py).
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Gaussian batch size the artifacts are monomorphized to.
    pub n_gauss: usize,
    /// PR batch size.
    pub n_pr: usize,
    /// Tile edge the artifacts are compiled for (pixels).
    pub tile: usize,
    /// Tile-batch width of the `render_tile_batched` artifact: one
    /// dispatch renders up to `n_batch` tiles stacked along its leading
    /// dim. Manifests predating the batched artifact omit the field and
    /// parse as 1 (single-tile dispatch only).
    pub n_batch: usize,
    /// name -> artifact filename.
    pub files: HashMap<String, String>,
}

impl Manifest {
    /// Parse `manifest.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| err!("manifest parse: {e}"))?;
        let need =
            |k: &str| j.at(&[k]).and_then(Json::as_u64).ok_or_else(|| err!("manifest: {k}"));
        let mut files = HashMap::new();
        let arts = j
            .at(&["artifacts"])
            .and_then(Json::as_obj)
            .ok_or_else(|| err!("manifest: artifacts"))?;
        for (name, v) in arts.iter() {
            let file = v
                .at(&["file"])
                .and_then(Json::as_str)
                .ok_or_else(|| err!("manifest: artifacts.{name}.file"))?;
            files.insert(name.clone(), file.to_string());
        }
        Ok(Manifest {
            n_gauss: need("n_gauss")? as usize,
            n_pr: need("n_pr")? as usize,
            tile: need("tile")? as usize,
            n_batch: j.at(&["n_batch"]).and_then(Json::as_u64).unwrap_or(1) as usize,
            files,
        })
    }
}

/// Names of the artifacts the AOT compiler emits (and the offline stub
/// can interpret): keep in sync with `python/compile/aot.py::entries`.
///
/// The `render_tile_batched_*` variants are the same batched blend kernel
/// monomorphized per CTU precision class — adaptive-precision waves
/// dispatch one class per call, so the per-class CAT gating is baked into
/// the artifact instead of branching inside it. `render_tile_batched`
/// (no suffix) remains the fp32-gated kernel global renders use.
pub const ARTIFACT_NAMES: [&str; 8] = [
    "project",
    "pr_weight",
    "cat_masks",
    "render_tile",
    "render_tile_batched",
    "render_tile_batched_fp16",
    "render_tile_batched_fp8",
    "render_tile_batched_mixed",
];

/// Synthesize a stub-interpretable artifact set: a `manifest.json` with
/// the given monomorphization plus placeholder `*.hlo.txt` files for
/// every artifact in [`ARTIFACT_NAMES`].
///
/// The offline `rust/xla-stub` fake does not parse HLO — it recognizes
/// artifacts by file stem and interprets them with built-in pure-Rust
/// reference kernels — so a runtime loaded from this directory executes
/// end to end with no jax, no network, and no native XLA. This is what
/// lets the PJRT differential/property harness (batched vs single-tile
/// execution, executor vs golden rasterizer) run in default CI. Against
/// the real `xla` crate the placeholders fail HLO parsing, so tests built
/// on this helper skip cleanly in the `xla-real` lane (which exercises
/// real artifacts via `make artifacts` instead).
///
/// Small `n_gauss` values keep chunk-boundary tests cheap; `tile` must be
/// 16 (the blend kernels are written for 16×16 tiles) and `n_pr` must be
/// 16 (the executor's dense PR layout covers exactly the tile's four
/// sub-tiles) — other values are rejected rather than silently
/// miscomposited or CAT-gated against regions outside the tile.
pub fn write_stub_artifacts(
    dir: &Path,
    n_gauss: usize,
    n_pr: usize,
    tile: usize,
    n_batch: usize,
) -> Result<()> {
    if tile != 16 {
        return Err(err!("stub artifacts are monomorphic at tile 16 (got {tile})"));
    }
    if n_pr != 16 {
        return Err(err!(
            "stub artifacts need n_pr 16 (dense PR coverage of the 16×16 tile; got {n_pr})"
        ));
    }
    if n_gauss == 0 || n_batch == 0 {
        return Err(err!(
            "stub artifact shapes must be positive (n_gauss {n_gauss}, n_batch {n_batch})"
        ));
    }
    std::fs::create_dir_all(dir)?;
    let mut arts = String::new();
    for (i, name) in ARTIFACT_NAMES.iter().enumerate() {
        let file = format!("{name}.hlo.txt");
        std::fs::write(
            dir.join(&file),
            "placeholder artifact: interpreted by rust/xla-stub's built-in kernels\n",
        )?;
        if i > 0 {
            arts.push_str(",\n");
        }
        arts.push_str(&format!("    \"{name}\": {{\"file\": \"{file}\"}}"));
    }
    let manifest = format!(
        "{{\n  \"n_gauss\": {n_gauss},\n  \"n_pr\": {n_pr},\n  \"tile\": {tile},\n  \
         \"n_batch\": {n_batch},\n  \"artifacts\": {{\n{arts}\n  }}\n}}\n"
    );
    std::fs::write(dir.join("manifest.json"), manifest)?;
    Ok(())
}

/// Locate the artifacts directory: $FLICKER_ARTIFACTS, ./artifacts, or the
/// crate-root artifacts dir (tests may run from target subdirs).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("FLICKER_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let local = Path::new("artifacts");
    if local.join("manifest.json").exists() {
        return local.to_path_buf();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        if !default_artifact_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&default_artifact_dir()).unwrap();
        assert_eq!(m.tile, 16);
        assert!(m.n_gauss >= 128);
        for k in ["project", "pr_weight", "cat_masks", "render_tile"] {
            assert!(m.files.contains_key(k), "missing artifact {k}");
        }
    }

    #[test]
    fn missing_manifest_is_a_clean_error() {
        let dir = std::env::temp_dir().join("flicker_no_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let e = Manifest::load(&dir).unwrap_err();
        assert!(e.to_string().contains("make artifacts"), "{e}");
    }

    #[test]
    fn stub_artifacts_roundtrip_through_the_manifest() {
        let dir = std::env::temp_dir().join("flicker_stubgen_test");
        write_stub_artifacts(&dir, 32, 16, 16, 8).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n_gauss, 32);
        assert_eq!(m.n_pr, 16);
        assert_eq!(m.tile, 16);
        assert_eq!(m.n_batch, 8);
        for name in ARTIFACT_NAMES {
            let file = m.files.get(name).expect(name);
            assert!(dir.join(file).is_file(), "missing placeholder {file}");
        }
    }

    #[test]
    fn stub_artifacts_reject_unsupported_geometry() {
        let dir = std::env::temp_dir().join("flicker_stubgen_reject");
        // The stub kernels are monomorphic at 16×16 tiles with dense
        // 16-PR coverage; anything else would miscomposite or CAT-gate
        // outside the tile, so the writer refuses up front.
        assert!(write_stub_artifacts(&dir, 32, 16, 8, 4).is_err());
        assert!(write_stub_artifacts(&dir, 32, 32, 16, 4).is_err());
        assert!(write_stub_artifacts(&dir, 0, 16, 16, 4).is_err());
        assert!(write_stub_artifacts(&dir, 32, 16, 16, 0).is_err());
    }

    #[test]
    fn manifests_without_n_batch_default_to_single_tile() {
        let dir = std::env::temp_dir().join("flicker_manifest_no_batch");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"n_gauss": 256, "n_pr": 16, "tile": 16, "artifacts": {}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n_batch, 1);
    }
}
