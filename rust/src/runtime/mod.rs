//! Artifact manifest + (feature-gated) PJRT runtime.
//!
//! The manifest layer (`Manifest`, [`default_artifact_dir`]) is pure Rust
//! and always compiled: tests and tooling can inspect
//! `artifacts/manifest.json` (written by python/compile/aot.py) without any
//! XLA linkage. The PJRT execution path ([`Runtime`], [`executor`]) loads
//! the AOT-compiled JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and only
//! exists under the `pjrt` cargo feature; the default build is offline and
//! dependency-free.

#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(feature = "pjrt")]
mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json` (written by python/compile/aot.py).
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Gaussian batch size the artifacts are monomorphized to.
    pub n_gauss: usize,
    /// PR batch size.
    pub n_pr: usize,
    /// Tile edge the artifacts are compiled for (pixels).
    pub tile: usize,
    /// name -> artifact filename.
    pub files: HashMap<String, String>,
}

impl Manifest {
    /// Parse `manifest.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| err!("manifest parse: {e}"))?;
        let need =
            |k: &str| j.at(&[k]).and_then(Json::as_u64).ok_or_else(|| err!("manifest: {k}"));
        let mut files = HashMap::new();
        let arts = j
            .at(&["artifacts"])
            .and_then(Json::as_obj)
            .ok_or_else(|| err!("manifest: artifacts"))?;
        for (name, v) in arts.iter() {
            let file = v
                .at(&["file"])
                .and_then(Json::as_str)
                .ok_or_else(|| err!("manifest: artifacts.{name}.file"))?;
            files.insert(name.clone(), file.to_string());
        }
        Ok(Manifest {
            n_gauss: need("n_gauss")? as usize,
            n_pr: need("n_pr")? as usize,
            tile: need("tile")? as usize,
            files,
        })
    }
}

/// Locate the artifacts directory: $FLICKER_ARTIFACTS, ./artifacts, or the
/// crate-root artifacts dir (tests may run from target subdirs).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("FLICKER_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let local = Path::new("artifacts");
    if local.join("manifest.json").exists() {
        return local.to_path_buf();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        if !default_artifact_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&default_artifact_dir()).unwrap();
        assert_eq!(m.tile, 16);
        assert!(m.n_gauss >= 128);
        for k in ["project", "pr_weight", "cat_masks", "render_tile"] {
            assert!(m.files.contains_key(k), "missing artifact {k}");
        }
    }

    #[test]
    fn missing_manifest_is_a_clean_error() {
        let dir = std::env::temp_dir().join("flicker_no_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let e = Manifest::load(&dir).unwrap_err();
        assert!(e.to_string().contains("make artifacts"), "{e}");
    }
}
