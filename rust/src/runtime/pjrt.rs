//! PJRT runtime (`pjrt` feature): compiles the AOT artifacts once at
//! startup and executes them from the Rust hot path.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax>=0.5's 64-bit
//! instruction-id protos; the text parser reassigns ids). Python never runs
//! at frame time. The default `xla` dependency is the in-tree functional
//! fake (`rust/xla-stub`): it does not parse HLO but recognizes each
//! artifact by file stem and interprets it with a built-in pure-Rust
//! reference kernel, so a runtime over real or synthesized
//! ([`crate::runtime::write_stub_artifacts`]) artifacts executes offline.
//! Callers still skip the PJRT path when [`Runtime::load`] errors (e.g. a
//! real-XLA build pointed at stub placeholder files, or missing
//! artifacts).

use super::Manifest;
use crate::err;
use crate::util::error::Result;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled PJRT runtime with all artifacts loaded.
pub struct Runtime {
    client: xla::PjRtClient,
    /// The parsed artifact manifest.
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client and compile every artifact in the manifest.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| err!("pjrt cpu client: {e:?}"))?;
        let manifest = Manifest::load(dir)?;
        let mut executables = HashMap::new();
        for (name, file) in &manifest.files {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
            )
            .map_err(|e| err!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| err!("compiling {name}: {e:?}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Runtime {
            client,
            manifest,
            executables,
            dir: dir.to_path_buf(),
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Directory the artifacts were loaded from.
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Is artifact `name` compiled and ready?
    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute artifact `name` on f32 input tensors (data, dims). Returns
    /// the flattened f32 outputs (artifacts are lowered with
    /// `return_tuple=True`, so results arrive as one tuple literal).
    pub fn exec_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| err!("unknown artifact '{name}'"))?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expect: i64 = dims.iter().product();
            if expect as usize != data.len() {
                return Err(err!(
                    "{name}: input length {} != shape {:?} product",
                    data.len(),
                    dims
                ));
            }
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| err!("reshape {dims:?}: {e:?}"))?;
            lits.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| err!("executing {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| err!("fetch {name}: {e:?}"))?;
        let parts = out.to_tuple().map_err(|e| err!("untuple {name}: {e:?}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| err!("to_vec: {e:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    /// Load the runtime, skipping (None) when artifacts are missing or the
    /// `xla` dependency is the offline stub.
    fn runtime() -> Option<Runtime> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        match Runtime::load(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: pjrt runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn runtime_loads_and_runs_pr_weight() {
        let Some(rt) = runtime() else { return };
        let n = rt.manifest.n_gauss;
        let m = rt.manifest.n_pr;
        // One Gaussian at (10, 10) with a simple diagonal conic, rest far.
        let mut mu = vec![1e6f32; n * 2];
        mu[0] = 10.0;
        mu[1] = 10.0;
        let mut conic = vec![0.0f32; n * 3];
        for i in 0..n {
            conic[i * 3] = 0.5;
            conic[i * 3 + 2] = 0.5;
        }
        let mut p_top = vec![0.0f32; m * 2];
        let mut p_bot = vec![0.0f32; m * 2];
        for k in 0..m {
            p_top[k * 2] = 10.0;
            p_top[k * 2 + 1] = 10.0;
            p_bot[k * 2] = 13.0;
            p_bot[k * 2 + 1] = 13.0;
        }
        let out = rt
            .exec_f32(
                "pr_weight",
                &[
                    (&mu, &[n as i64, 2]),
                    (&conic, &[n as i64, 3]),
                    (&p_top, &[m as i64, 2]),
                    (&p_bot, &[m as i64, 2]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let e = &out[0]; // (M, N, 4)
        assert_eq!(e.len(), m * n * 4);
        // Corner 0 of PR 0 vs Gaussian 0 sits exactly on mu -> E = 0.
        assert!(e[0].abs() < 1e-4, "E00 = {}", e[0]);
        // Corner 3 at (13,13): E = 0.5*0.5*(9+9) = 4.5.
        let e3 = e[3];
        assert!((e3 - 4.5).abs() < 1e-3, "E03 = {e3}");
    }
}
