//! Tile executor: the bridge between the coordinator's per-tile work units
//! and the fixed-shape PJRT artifacts.
//!
//! Artifacts are monomorphic (N_GAUSS splats, N_PR pixel-rectangles), so the
//! executor pads each tile's depth-sorted splat list with zero-opacity
//! entries (exact no-ops through CAT and blending — validated by
//! python/tests/test_model.py) and chunks lists longer than N_GAUSS,
//! carrying transmittance between chunks on the Rust side.

use super::Runtime;
use crate::cat::leader::dense_layout;
use crate::render::image::Image;
use crate::render::project::Splat;
use crate::render::tile::Rect;
use crate::util::error::Result;

/// Per-tile PJRT render statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// Tiles rendered.
    pub tiles: usize,
    /// Artifact invocations (tiles × list chunks).
    pub chunks: usize,
    /// Splats submitted across all chunks (after padding).
    pub splats_submitted: usize,
    /// Splats that passed the artifact's CAT filter.
    pub splats_passed_cat: usize,
}

/// Executes tile renders through the `render_tile` artifact.
pub struct TileExecutor<'rt> {
    rt: &'rt Runtime,
    /// Counters accumulated over this executor's lifetime.
    pub stats: ExecStats,
}

impl<'rt> TileExecutor<'rt> {
    /// New executor bound to a loaded runtime.
    pub fn new(rt: &'rt Runtime) -> Self {
        TileExecutor {
            rt,
            stats: ExecStats::default(),
        }
    }

    /// Render one 16×16 tile from its depth-sorted splats; writes pixels
    /// into `img`. Splat lists longer than the artifact batch are chunked;
    /// because the artifact restarts transmittance per call, chunk results
    /// are composited front-to-back on the host: out += T_acc · chunk_rgb,
    /// T_acc *= chunk_T.
    pub fn render_tile(
        &mut self,
        tile: &Rect,
        splats: &[Splat],
        order: &[u32],
        img: &mut Image,
        background: [f32; 3],
    ) -> Result<()> {
        let n = self.rt.manifest.n_gauss;
        let m = self.rt.manifest.n_pr;
        let t = self.rt.manifest.tile as u32;
        self.stats.tiles += 1;

        // Dense PR layout over the tile's 4 sub-tiles: M = 16 PRs cover the
        // whole tile (Uniform-Dense CAT; the golden-model engine remains the
        // reference for the adaptive modes).
        let mut p_top = vec![0.0f32; m * 2];
        let mut p_bot = vec![0.0f32; m * 2];
        let layouts = dense_layout();
        for k in 0..m {
            let sub = k / 4; // sub-tile ordinal, row-major 2×2
            let (sx, sy) = ((sub % 2) as f32 * 8.0, (sub / 2) as f32 * 8.0);
            let pr = &layouts[k % 4];
            p_top[k * 2] = tile.x0 + sx + pr.x_top;
            p_top[k * 2 + 1] = tile.y0 + sy + pr.y_top;
            p_bot[k * 2] = tile.x0 + sx + pr.x_bot;
            p_bot[k * 2 + 1] = tile.y0 + sy + pr.y_bot;
        }

        let mut acc_rgb = vec![[0.0f32; 3]; (t * t) as usize];
        let mut acc_t = vec![1.0f32; (t * t) as usize];

        for chunk in order.chunks(n) {
            self.stats.chunks += 1;
            self.stats.splats_submitted += chunk.len();
            let mut mu = vec![0.0f32; n * 2];
            let mut conic = vec![0.0f32; n * 3];
            let mut opacity = vec![0.0f32; n];
            let mut color = vec![0.0f32; n * 3];
            for (i, &si) in chunk.iter().enumerate() {
                let s = &splats[si as usize];
                mu[i * 2] = s.mean.x;
                mu[i * 2 + 1] = s.mean.y;
                conic[i * 3] = s.conic.a;
                conic[i * 3 + 1] = s.conic.b;
                conic[i * 3 + 2] = s.conic.c;
                opacity[i] = s.opacity;
                color[i * 3] = s.color[0];
                color[i * 3 + 1] = s.color[1];
                color[i * 3 + 2] = s.color[2];
            }
            // Padding rows keep conic PSD-ish to avoid NaNs (opacity 0
            // already guarantees no contribution).
            for i in chunk.len()..n {
                conic[i * 3] = 1.0;
                conic[i * 3 + 2] = 1.0;
            }
            let origin = [tile.x0, tile.y0];
            let out = self.rt.exec_f32(
                "render_tile",
                &[
                    (&mu, &[n as i64, 2]),
                    (&conic, &[n as i64, 3]),
                    (&opacity, &[n as i64]),
                    (&color, &[n as i64, 3]),
                    (&origin, &[2]),
                    (&p_top, &[m as i64, 2]),
                    (&p_bot, &[m as i64, 2]),
                ],
            )?;
            let rgb = &out[0]; // (16,16,3)
            let trans = &out[1]; // (16,16)
            let passes = &out[2]; // (N,)
            self.stats.splats_passed_cat +=
                passes.iter().take(chunk.len()).filter(|&&p| p > 0.5).count();
            for p in 0..(t * t) as usize {
                let ta = acc_t[p];
                acc_rgb[p][0] += ta * rgb[p * 3];
                acc_rgb[p][1] += ta * rgb[p * 3 + 1];
                acc_rgb[p][2] += ta * rgb[p * 3 + 2];
                acc_t[p] = ta * trans[p];
            }
            // All pixels saturated → later chunks contribute nothing.
            if acc_t.iter().all(|&tv| tv < 1e-4) {
                break;
            }
        }

        for py in 0..t {
            for px in 0..t {
                let gx = tile.x0 as u32 + px;
                let gy = tile.y0 as u32 + py;
                if gx >= img.width || gy >= img.height {
                    continue;
                }
                let p = (py * t + px) as usize;
                let tr = acc_t[p];
                img.set(
                    gx,
                    gy,
                    [
                        acc_rgb[p][0] + tr * background[0],
                        acc_rgb[p][1] + tr * background[1],
                        acc_rgb[p][2] + tr * background[2],
                    ],
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::{v3, Quat};
    use crate::render::project::project_scene;
    use crate::render::sort::sort_by_depth;
    use crate::render::tile::{build_tile_lists, Strategy, TileGrid};
    use crate::runtime::default_artifact_dir;
    use crate::scene::gaussian::Scene;

    #[test]
    fn executor_matches_golden_rasterizer() {
        if !default_artifact_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = match Runtime::load(&default_artifact_dir()) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: pjrt runtime unavailable ({e})");
                return;
            }
        };
        let cam = Camera::look_at(
            Intrinsics::from_fov(32, 32, 1.2),
            v3(0.0, 0.0, -6.0),
            v3(0.0, 0.0, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        let mut scene = Scene::with_capacity(3, "t");
        scene.push(v3(0.0, 0.0, 0.0), Quat::IDENTITY, v3(0.6, 0.6, 0.6), 0.9, [1.5, 0.0, 0.0], [[0.0; 3]; 3]);
        scene.push(v3(0.4, 0.2, 1.0), Quat::IDENTITY, v3(0.4, 0.4, 0.4), 0.7, [0.0, 1.5, 0.0], [[0.0; 3]; 3]);
        scene.push(v3(-0.4, -0.2, 2.0), Quat::IDENTITY, v3(0.5, 0.5, 0.5), 0.5, [0.0, 0.0, 1.5], [[0.0; 3]; 3]);

        // Golden render.
        let golden = crate::render::raster::render(
            &scene,
            &cam,
            &crate::render::raster::RenderOptions::default(),
        );

        // PJRT render.
        let splats = project_scene(&scene, &cam);
        let grid = TileGrid::new(32, 32, 16);
        let mut lists = build_tile_lists(&splats, &grid, Strategy::Aabb);
        for l in &mut lists {
            sort_by_depth(l, &splats);
        }
        let mut img = Image::new(32, 32);
        let mut ex = TileExecutor::new(&rt);
        for (t, list) in lists.iter().enumerate() {
            ex.render_tile(&grid.rect(t), &splats, list, &mut img, [0.0; 3])
                .unwrap();
        }
        // CAT gating in the artifact may drop marginal splats the golden
        // model blends, so compare with PSNR, not exactness.
        let p = crate::render::metrics::psnr(&golden.image, &img);
        assert!(p > 30.0, "PJRT vs golden PSNR {p}");
        assert!(ex.stats.tiles == 4);
        assert!(ex.stats.splats_passed_cat > 0);
    }
}
