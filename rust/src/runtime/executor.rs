//! Tile executor: the bridge between the coordinator's per-tile work units
//! and the fixed-shape PJRT artifacts.
//!
//! Artifacts are monomorphic (N_GAUSS splats, N_PR pixel-rectangles, and a
//! B = `n_batch` tile-batch dim on the batched artifact), so the executor
//! pads each tile's depth-sorted splat list with zero-opacity entries
//! (exact no-ops through CAT and blending — validated by
//! python/tests/test_model.py) and chunks lists longer than N_GAUSS,
//! carrying transmittance between chunks on the Rust side.
//!
//! [`TileExecutor::render_tiles`] is the batched path: it gathers up to B
//! tiles' splat chunks into one `render_tile_batched` invocation per wave,
//! padding ragged final batches with zero-opacity rows. Per-tile
//! front-to-back chunk compositing (and the all-pixels-saturated early
//! exit) happens on the host exactly as in the single-tile path, so the
//! batched render is **bit-identical** to looped [`TileExecutor::render_tile`]
//! calls for any batch size — enforced by the property suite in
//! `rust/tests/properties.rs` against the offline stub runtime.
//!
//! [`TileExecutor::render_tiles_coalesced`] generalizes the queue to tiles
//! from **multiple frames at once** (the render service's cross-client
//! coalescer): each job carries a source index selecting its splat array
//! and output image, so one client's padding slots carry another client's
//! real chunks and the aggregate fill rate stays high even when every
//! individual frame is ragged.

use super::Runtime;
use crate::cat::leader::dense_layout;
use crate::cat::Precision;
use crate::err;
use crate::render::image::Image;
use crate::render::precision::{class_index, TileClassMap, CLASSES};
use crate::render::project::Splat;
use crate::render::pyramid::quad_of_pixel;
use crate::render::tile::{Rect, TileGrid};
use crate::util::error::Result;

/// Per-tile PJRT render statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// Tile jobs rendered. A rect-mode mixed tile split across classes
    /// (see [`TileJob::for_grid_rect_classed`]) counts once per class
    /// wave it rode.
    pub tiles: usize,
    /// Tile-chunks submitted (a tile's splat list contributes
    /// `ceil(len / n_gauss)` chunks; empty lists contribute none). Counts
    /// are identical between the single-tile and batched paths.
    pub chunks: usize,
    /// Batched-artifact invocations (`render_tile_batched` dispatches).
    pub batches: usize,
    /// Batch slots carrying a real tile-chunk, summed over all batched
    /// invocations. `batches * n_batch - slots_filled` slots were pure
    /// zero-opacity padding.
    pub slots_filled: usize,
    /// Real (non-padding) splat rows submitted across all chunks. Padding
    /// rows — the zero-opacity tail of a short chunk, and entirely empty
    /// batch slots — are **not** counted here; see [`ExecStats::rows_submitted`].
    pub splats_submitted: usize,
    /// Total splat rows shipped to the device, padding included: every
    /// chunk ships `n_gauss` rows and every batched invocation ships
    /// `n_batch * n_gauss`.
    pub rows_submitted: usize,
    /// Real splats that passed the artifact's CAT filter.
    pub splats_passed_cat: usize,
    /// Tiles rendered per precision class, indexed by
    /// [`class_index`] in [`CLASSES`] order. Unclassed (global-precision)
    /// jobs never touch these buckets.
    pub tiles_by_class: [usize; 4],
    /// Batched dispatches per precision class.
    pub batches_by_class: [usize; 4],
    /// Real batch slots per precision class.
    pub slots_by_class: [usize; 4],
    /// Real (non-padding) splat rows submitted per precision class.
    pub splats_by_class: [usize; 4],
    /// Total splat rows shipped (padding included) per precision class.
    pub rows_by_class: [usize; 4],
}

impl ExecStats {
    /// Fraction of shipped splat rows that carried a real splat — the
    /// batching fill rate (1.0 = every row useful, low values mean the
    /// monomorphic shapes are mostly padding for this workload). An
    /// executor that shipped nothing (no tiles, or every list empty)
    /// reports 0.0 rather than dividing by zero.
    pub fn fill_rate(&self) -> f64 {
        if self.rows_submitted == 0 {
            return 0.0;
        }
        self.splats_submitted as f64 / self.rows_submitted as f64
    }

    /// Per-class batching fill rate — the padding cost of precision-pure
    /// waves (a rare class strands most of its dispatch slots). Classes
    /// that shipped no rows — including every class of an all-global
    /// render, and any empty wave — report 0.0 rather than dividing by
    /// zero.
    pub fn fill_rate_by_class(&self, class: Precision) -> f64 {
        let i = class_index(class);
        if self.rows_by_class[i] == 0 {
            return 0.0;
        }
        self.splats_by_class[i] as f64 / self.rows_by_class[i] as f64
    }
}

/// One unit of batched tile work: the tile's pixel rect, its depth-sorted
/// splat index list, and (under an adaptive policy) its precision class.
#[derive(Clone, Copy)]
pub struct TileJob<'a> {
    /// Tile rect in pixels.
    pub rect: Rect,
    /// Depth-sorted indices into the frame's splat array.
    pub order: &'a [u32],
    /// Precision class assigned by `FramePlan::tile_classes` (`None` for
    /// global-precision renders). Waves never mix classes: the executor
    /// partitions jobs by class before forming dispatch groups.
    pub class: Option<Precision>,
    /// Per-quadrant class map of a mixed-class (rect-mode) tile. A mixed
    /// tile is split into one job per distinct class it contains — each
    /// job runs the tile's full chunk sequence through its class's
    /// precision-pure wave, and the host compositor stitches only the
    /// pixels whose quadrant (`render::pyramid::quad_of_pixel`) carries
    /// `class`. `None` for uniform tiles (the single-class fast path).
    pub quads: Option<[Precision; 4]>,
}

impl<'a> TileJob<'a> {
    /// Build the tile-queue jobs for a whole frame: one job per tile of
    /// `grid`, in row-major tile order, borrowing the per-tile lists.
    /// This is the one place the (grid, lists) → jobs mapping lives — the
    /// `Pjrt` backend, benches, and the differential tests all share it.
    pub fn for_grid(grid: &TileGrid, lists: &'a [Vec<u32>]) -> Vec<TileJob<'a>> {
        lists
            .iter()
            .enumerate()
            .map(|(t, list)| TileJob {
                rect: grid.rect(t),
                order: list,
                class: None,
                quads: None,
            })
            .collect()
    }

    /// [`TileJob::for_grid`] with per-tile precision classes attached
    /// (`classes[t]` pairs with `lists[t]` — both row-major tile order,
    /// which `FramePlan::gated_lists` preserves).
    pub fn for_grid_classed(
        grid: &TileGrid,
        lists: &'a [Vec<u32>],
        classes: &[Precision],
    ) -> Vec<TileJob<'a>> {
        assert_eq!(lists.len(), classes.len(), "one class per tile list");
        lists
            .iter()
            .zip(classes)
            .enumerate()
            .map(|(t, (list, &class))| TileJob {
                rect: grid.rect(t),
                order: list,
                class: Some(class),
                quads: None,
            })
            .collect()
    }

    /// [`TileJob::for_grid`] with per-tile **rect-mode** class maps
    /// attached (`maps[t]` pairs with `lists[t]`, row-major tile order).
    /// Uniform tiles emit exactly the job [`TileJob::for_grid_classed`]
    /// would — so a rect plan whose maps all collapsed to `Uniform` forms
    /// bit-identical waves to the per-tile classed queue. A mixed tile
    /// emits one job per distinct class it contains, iterated in
    /// [`CLASSES`] order for determinism, every job sharing the tile's
    /// full depth order and carrying the quadrant map for output
    /// stitching.
    pub fn for_grid_rect_classed(
        grid: &TileGrid,
        lists: &'a [Vec<u32>],
        maps: &[TileClassMap],
    ) -> Vec<TileJob<'a>> {
        assert_eq!(lists.len(), maps.len(), "one class map per tile list");
        let mut jobs = Vec::new();
        for (t, (list, &map)) in lists.iter().zip(maps).enumerate() {
            match map {
                TileClassMap::Uniform(class) => jobs.push(TileJob {
                    rect: grid.rect(t),
                    order: list,
                    class: Some(class),
                    quads: None,
                }),
                TileClassMap::Mixed(quads) => {
                    for class in CLASSES {
                        if !quads.contains(&class) {
                            continue;
                        }
                        jobs.push(TileJob {
                            rect: grid.rect(t),
                            order: list,
                            class: Some(class),
                            quads: Some(quads),
                        });
                    }
                }
            }
        }
        jobs
    }
}

/// One frame's shared inputs in a coalesced cross-client tile queue: the
/// projected splat array every [`TileJob::order`] of that frame indexes
/// into, plus the frame's background color. See
/// [`TileExecutor::render_tiles_coalesced`].
#[derive(Clone, Copy)]
pub struct TileSource<'a> {
    /// The frame's projected, depth-sortable splat array.
    pub splats: &'a [Splat],
    /// Background composited under the residual transmittance.
    pub background: [f32; 3],
}

/// A tile job bound to one of several in-flight frames: `source` indexes
/// the `sources`/`images` arrays handed to
/// [`TileExecutor::render_tiles_coalesced`], so tiles from different
/// clients' frames can share the same precision-pure wave.
#[derive(Clone, Copy)]
pub struct SourcedJob<'a> {
    /// Index of the owning frame in the coalesced call's source/image
    /// arrays.
    pub source: usize,
    /// The tile job itself (rect, depth order, precision class).
    pub job: TileJob<'a>,
}

/// Per-tile host accumulator state for the batched wave loop.
struct TileAcc {
    acc_rgb: Vec<[f32; 3]>,
    acc_t: Vec<f32>,
    /// Start of the next un-submitted chunk in the tile's order list.
    next: usize,
    /// No more chunks: the list is drained or every pixel saturated.
    done: bool,
}

/// Executes tile renders through the `render_tile` /
/// `render_tile_batched` artifacts.
pub struct TileExecutor<'rt> {
    rt: &'rt Runtime,
    /// Effective tiles-per-dispatch for [`TileExecutor::render_tiles`]
    /// (0 = the artifact's full `n_batch`).
    batch: usize,
    /// Counters accumulated over this executor's lifetime.
    pub stats: ExecStats,
}

impl<'rt> TileExecutor<'rt> {
    /// New executor bound to a loaded runtime, batching up to the
    /// artifact's full `n_batch` tiles per dispatch.
    pub fn new(rt: &'rt Runtime) -> Self {
        TileExecutor {
            rt,
            batch: 0,
            stats: ExecStats::default(),
        }
    }

    /// Limit [`TileExecutor::render_tiles`] to `batch` tiles per dispatch
    /// (clamped to the artifact's `n_batch`; 0 restores the artifact
    /// maximum). The rendered pixels are bit-identical for every setting —
    /// the knob trades dispatch count against padding fill rate.
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Tiles gathered per `render_tile_batched` dispatch.
    pub fn effective_batch(&self) -> usize {
        let b_max = self.rt.manifest.n_batch.max(1);
        if self.batch == 0 {
            b_max
        } else {
            self.batch.min(b_max)
        }
    }

    /// Dense PR corner coordinates covering a tile's four sub-tiles:
    /// M = 16 PRs so the artifact's CAT gate covers the whole 16×16 tile
    /// (Uniform-Dense CAT; the golden-model engine remains the reference
    /// for the adaptive modes). Public so artifact-level tests build
    /// their PR inputs from the same layout the executor ships.
    pub fn dense_prs(&self, rect: &Rect) -> (Vec<f32>, Vec<f32>) {
        let m = self.rt.manifest.n_pr;
        let mut p_top = vec![0.0f32; m * 2];
        let mut p_bot = vec![0.0f32; m * 2];
        let layouts = dense_layout();
        for k in 0..m {
            let sub = k / 4; // sub-tile ordinal, row-major 2×2
            let (sx, sy) = ((sub % 2) as f32 * 8.0, (sub / 2) as f32 * 8.0);
            let pr = &layouts[k % 4];
            p_top[k * 2] = rect.x0 + sx + pr.x_top;
            p_top[k * 2 + 1] = rect.y0 + sy + pr.y_top;
            p_bot[k * 2] = rect.x0 + sx + pr.x_bot;
            p_bot[k * 2 + 1] = rect.y0 + sy + pr.y_bot;
        }
        (p_top, p_bot)
    }

    /// Write one tile's composited accumulators into the frame image,
    /// compositing the background under the residual transmittance.
    ///
    /// `stitch = Some((quads, class))` is the rect-mode path: only pixels
    /// whose quadrant carries `class` are written, so the per-class jobs
    /// of a mixed tile each own a disjoint pixel set and the stitched
    /// tile is independent of the order their waves dispatched in.
    fn write_tile(
        &self,
        rect: &Rect,
        acc_rgb: &[[f32; 3]],
        acc_t: &[f32],
        img: &mut Image,
        background: [f32; 3],
        stitch: Option<([Precision; 4], Precision)>,
    ) {
        let t = self.rt.manifest.tile as u32;
        for py in 0..t {
            for px in 0..t {
                let gx = rect.x0 as u32 + px;
                let gy = rect.y0 as u32 + py;
                if gx >= img.width || gy >= img.height {
                    continue;
                }
                if let Some((quads, class)) = stitch {
                    if quads[quad_of_pixel(rect, t, gx, gy)] != class {
                        continue;
                    }
                }
                let p = (py * t + px) as usize;
                let tr = acc_t[p];
                img.set(
                    gx,
                    gy,
                    [
                        acc_rgb[p][0] + tr * background[0],
                        acc_rgb[p][1] + tr * background[1],
                        acc_rgb[p][2] + tr * background[2],
                    ],
                );
            }
        }
    }

    /// Gather one chunk's splat data into flat input rows at `base`
    /// (element offsets are in splats, so `base = slot * n_gauss` targets
    /// a batch slot). Rows past `chunk.len()` keep opacity 0 and get a
    /// PSD-ish identity conic to avoid NaNs.
    fn fill_chunk(
        &self,
        chunk: &[u32],
        splats: &[Splat],
        base: usize,
        mu: &mut [f32],
        conic: &mut [f32],
        opacity: &mut [f32],
        color: &mut [f32],
    ) {
        let n = self.rt.manifest.n_gauss;
        for (i, &si) in chunk.iter().enumerate() {
            let s = &splats[si as usize];
            let r = base + i;
            mu[r * 2] = s.mean.x;
            mu[r * 2 + 1] = s.mean.y;
            conic[r * 3] = s.conic.a;
            conic[r * 3 + 1] = s.conic.b;
            conic[r * 3 + 2] = s.conic.c;
            opacity[r] = s.opacity;
            color[r * 3] = s.color[0];
            color[r * 3 + 1] = s.color[1];
            color[r * 3 + 2] = s.color[2];
        }
        // Padding rows keep conic PSD-ish (opacity 0 already guarantees
        // no contribution).
        for i in chunk.len()..n {
            let r = base + i;
            conic[r * 3] = 1.0;
            conic[r * 3 + 2] = 1.0;
        }
    }

    /// Composite one chunk's artifact output onto a tile accumulator:
    /// out += T_acc · chunk_rgb, T_acc *= chunk_T (the artifact restarts
    /// transmittance per call). Returns true when every pixel saturated —
    /// later chunks contribute nothing.
    fn composite_chunk(
        acc_rgb: &mut [[f32; 3]],
        acc_t: &mut [f32],
        rgb: &[f32],
        trans: &[f32],
    ) -> bool {
        for p in 0..acc_t.len() {
            let ta = acc_t[p];
            acc_rgb[p][0] += ta * rgb[p * 3];
            acc_rgb[p][1] += ta * rgb[p * 3 + 1];
            acc_rgb[p][2] += ta * rgb[p * 3 + 2];
            acc_t[p] = ta * trans[p];
        }
        acc_t.iter().all(|&tv| tv < 1e-4)
    }

    /// Render one 16×16 tile from its depth-sorted splats; writes pixels
    /// into `img`. Splat lists longer than the artifact batch are chunked;
    /// because the artifact restarts transmittance per call, chunk results
    /// are composited front-to-back on the host.
    pub fn render_tile(
        &mut self,
        tile: &Rect,
        splats: &[Splat],
        order: &[u32],
        img: &mut Image,
        background: [f32; 3],
    ) -> Result<()> {
        let n = self.rt.manifest.n_gauss;
        let m = self.rt.manifest.n_pr;
        let t = self.rt.manifest.tile as u32;
        self.stats.tiles += 1;

        let (p_top, p_bot) = self.dense_prs(tile);
        let mut acc_rgb = vec![[0.0f32; 3]; (t * t) as usize];
        let mut acc_t = vec![1.0f32; (t * t) as usize];

        for chunk in order.chunks(n) {
            self.stats.chunks += 1;
            self.stats.splats_submitted += chunk.len();
            self.stats.rows_submitted += n;
            let mut mu = vec![0.0f32; n * 2];
            let mut conic = vec![0.0f32; n * 3];
            let mut opacity = vec![0.0f32; n];
            let mut color = vec![0.0f32; n * 3];
            self.fill_chunk(chunk, splats, 0, &mut mu, &mut conic, &mut opacity, &mut color);
            let origin = [tile.x0, tile.y0];
            let out = self.rt.exec_f32(
                "render_tile",
                &[
                    (&mu, &[n as i64, 2]),
                    (&conic, &[n as i64, 3]),
                    (&opacity, &[n as i64]),
                    (&color, &[n as i64, 3]),
                    (&origin, &[2]),
                    (&p_top, &[m as i64, 2]),
                    (&p_bot, &[m as i64, 2]),
                ],
            )?;
            let rgb = &out[0]; // (16,16,3)
            let trans = &out[1]; // (16,16)
            let passes = &out[2]; // (N,)
            self.stats.splats_passed_cat +=
                passes.iter().take(chunk.len()).filter(|&&p| p > 0.5).count();
            if Self::composite_chunk(&mut acc_rgb, &mut acc_t, rgb, trans) {
                break;
            }
        }

        self.write_tile(tile, &acc_rgb, &acc_t, img, background, None);
        Ok(())
    }

    /// Render a queue of tiles, draining up to B = [`TileExecutor::effective_batch`]
    /// tiles per `render_tile_batched` dispatch instead of one `exec_f32`
    /// call per tile-chunk.
    ///
    /// Tiles are processed in groups of B. Within a group, each wave
    /// gathers the next un-submitted chunk of every still-active tile into
    /// the batch (ragged waves — a tile that drained its list or saturated
    /// every pixel stops contributing — are padded with zero-opacity
    /// rows), executes once, and composites each real slot onto its tile's
    /// host accumulator in the same order as the single-tile path. The
    /// output image and every real-work counter are **bit-identical** to
    /// looped [`TileExecutor::render_tile`] calls; only the
    /// dispatch-shape counters (`batches`, `slots_filled`,
    /// `rows_submitted`) differ. Falls back to the single-tile loop when
    /// the manifest has no batched artifact or the effective batch is 1
    /// (one real tile per B-wide dispatch would ship B× the work of the
    /// monomorphic single-tile artifact).
    /// For classed jobs (adaptive precision) waves are **precision-pure**:
    /// jobs are partitioned by class (preserving within-class order) and
    /// drained one class at a time in [`CLASSES`] order through that
    /// class's monomorphized artifact — a batched call never mixes
    /// classes. At effective batch 1 a classed queue still dispatches the
    /// class artifact, one filled slot per wave, so narrowing the batch
    /// reproduces the batched pixels bit for bit on the stub runtime.
    pub fn render_tiles(
        &mut self,
        jobs: &[TileJob],
        splats: &[Splat],
        img: &mut Image,
        background: [f32; 3],
    ) -> Result<()> {
        let sources = [TileSource { splats, background }];
        if jobs.iter().all(|j| j.class.is_none()) {
            let b_eff = self.effective_batch();
            if b_eff == 1 || !self.rt.has("render_tile_batched") {
                for job in jobs {
                    self.render_tile(&job.rect, splats, job.order, img, background)?;
                }
                return Ok(());
            }
            for group in jobs.chunks(b_eff) {
                let group: Vec<SourcedJob> =
                    group.iter().map(|&job| SourcedJob { source: 0, job }).collect();
                self.render_tile_group(&group, &sources, std::slice::from_mut(img))?;
            }
            return Ok(());
        }
        // Unclassed stragglers in a mixed queue drain first through the
        // single-tile artifact, then each class forms its own waves.
        for job in jobs.iter().filter(|j| j.class.is_none()) {
            self.render_tile(&job.rect, splats, job.order, img, background)?;
        }
        let b_eff = self.effective_batch();
        for class in CLASSES {
            let subset: Vec<SourcedJob> = jobs
                .iter()
                .filter(|j| j.class == Some(class))
                .map(|&job| SourcedJob { source: 0, job })
                .collect();
            if subset.is_empty() {
                continue;
            }
            let artifact = batched_artifact(Some(class));
            if !self.rt.has(artifact) {
                return Err(err!(
                    "runtime has no '{artifact}' artifact for the {class:?} precision class \
                     (regenerate artifacts: make artifacts)"
                ));
            }
            for group in subset.chunks(b_eff) {
                self.render_tile_group(group, &sources, std::slice::from_mut(img))?;
            }
        }
        Ok(())
    }

    /// Render tile queues from **multiple frames** (different clients'
    /// in-flight requests) through shared waves: `jobs[i].source` indexes
    /// `sources`/`images`, and tiles from different sources are packed into
    /// the same batched dispatch so one frame's padding slots carry another
    /// frame's real chunks. This is the render service's cross-client
    /// coalescer.
    ///
    /// Per-tile pixels are **bit-identical** to rendering each source's
    /// jobs separately through [`TileExecutor::render_tiles`]: a slot's
    /// artifact computation and the host chunk compositing depend only on
    /// its own tile, never on wave co-residents (the property suite pins
    /// this against the stub runtime). Within each precision class, jobs
    /// are ordered by **descending chunk count** (ties keep submission
    /// order) before grouping — longest-processing-time-first packing,
    /// which minimizes the total wave count Σ max(chunks in group) over
    /// contiguous groupings and therefore maximizes `fill_rate`: the
    /// coalesced fill rate is never below the aggregate of the separate
    /// per-source runs. Real-work counters (`chunks`, `splats_submitted`)
    /// are grouping-invariant; only dispatch-shape counters differ from
    /// the per-source runs.
    ///
    /// Mirrors [`TileExecutor::render_tiles`] in every mode: unclassed
    /// queues fall back to the single-tile artifact when the effective
    /// batch is 1 or no batched artifact exists; classed queues form
    /// precision-pure waves per class in [`CLASSES`] order and error on a
    /// missing class artifact.
    pub fn render_tiles_coalesced(
        &mut self,
        sources: &[TileSource],
        jobs: &[SourcedJob],
        images: &mut [Image],
    ) -> Result<()> {
        assert_eq!(sources.len(), images.len(), "one output image per source");
        assert!(
            jobs.iter().all(|j| j.source < sources.len()),
            "job source index out of range"
        );
        let n = self.rt.manifest.n_gauss.max(1);
        let waves = |j: &SourcedJob| j.job.order.len().div_ceil(n);
        let b_eff = self.effective_batch();
        if jobs.iter().all(|j| j.job.class.is_none()) {
            if b_eff == 1 || !self.rt.has("render_tile_batched") {
                for j in jobs {
                    let s = j.source;
                    self.render_tile(
                        &j.job.rect,
                        sources[s].splats,
                        j.job.order,
                        &mut images[s],
                        sources[s].background,
                    )?;
                }
                return Ok(());
            }
            let mut queue: Vec<SourcedJob> = jobs.to_vec();
            queue.sort_by(|a, b| waves(b).cmp(&waves(a))); // stable: ties keep order
            for group in queue.chunks(b_eff) {
                self.render_tile_group(group, sources, images)?;
            }
            return Ok(());
        }
        for j in jobs.iter().filter(|j| j.job.class.is_none()) {
            let s = j.source;
            self.render_tile(
                &j.job.rect,
                sources[s].splats,
                j.job.order,
                &mut images[s],
                sources[s].background,
            )?;
        }
        for class in CLASSES {
            let mut subset: Vec<SourcedJob> =
                jobs.iter().filter(|j| j.job.class == Some(class)).copied().collect();
            if subset.is_empty() {
                continue;
            }
            let artifact = batched_artifact(Some(class));
            if !self.rt.has(artifact) {
                return Err(err!(
                    "runtime has no '{artifact}' artifact for the {class:?} precision class \
                     (regenerate artifacts: make artifacts)"
                ));
            }
            subset.sort_by(|a, b| waves(b).cmp(&waves(a)));
            for group in subset.chunks(b_eff) {
                self.render_tile_group(group, sources, images)?;
            }
        }
        Ok(())
    }

    /// One group of ≤ B same-class tiles through the wave loop (see
    /// [`TileExecutor::render_tiles`]). Each group member carries its
    /// source index, so a wave may mix tiles from different frames — each
    /// slot gathers from its own source's splat array and composites into
    /// its own source's image. The group's class (uniform by construction —
    /// both entry points partition by class before grouping) picks the
    /// batched artifact and the per-class stat buckets.
    fn render_tile_group(
        &mut self,
        group: &[SourcedJob],
        sources: &[TileSource],
        images: &mut [Image],
    ) -> Result<()> {
        let n = self.rt.manifest.n_gauss;
        let m = self.rt.manifest.n_pr;
        let t = self.rt.manifest.tile as u32;
        let b = self.rt.manifest.n_batch;
        let px = (t * t) as usize;
        let class = group.first().and_then(|j| j.job.class);
        debug_assert!(
            group.iter().all(|j| j.job.class == class),
            "mixed-precision wave: the entry points must partition by class"
        );
        let artifact = batched_artifact(class);
        let ci = class.map(class_index);

        let mut states: Vec<TileAcc> = group
            .iter()
            .map(|_| TileAcc {
                acc_rgb: vec![[0.0f32; 3]; px],
                acc_t: vec![1.0f32; px],
                next: 0,
                done: false,
            })
            .collect();
        let prs: Vec<(Vec<f32>, Vec<f32>)> =
            group.iter().map(|j| self.dense_prs(&j.job.rect)).collect();

        loop {
            // Gather the next chunk of every still-active tile.
            let mut slots: Vec<(usize, &[u32])> = Vec::with_capacity(group.len());
            for (k, st) in states.iter_mut().enumerate() {
                if st.done {
                    continue;
                }
                let order = group[k].job.order;
                if st.next >= order.len() {
                    st.done = true;
                    continue;
                }
                let end = (st.next + n).min(order.len());
                slots.push((k, &order[st.next..end]));
                st.next = end;
            }
            if slots.is_empty() {
                break;
            }

            // Batched inputs: real slots first, zero-opacity padding after.
            let mut mu = vec![0.0f32; b * n * 2];
            let mut conic = vec![0.0f32; b * n * 3];
            let mut opacity = vec![0.0f32; b * n];
            let mut color = vec![0.0f32; b * n * 3];
            let mut origin = vec![0.0f32; b * 2];
            let mut p_top = vec![0.0f32; b * m * 2];
            let mut p_bot = vec![0.0f32; b * m * 2];
            for (s, &(k, chunk)) in slots.iter().enumerate() {
                let base = s * n;
                let splats = sources[group[k].source].splats;
                self.fill_chunk(chunk, splats, base, &mut mu, &mut conic, &mut opacity, &mut color);
                origin[s * 2] = group[k].job.rect.x0;
                origin[s * 2 + 1] = group[k].job.rect.y0;
                p_top[s * m * 2..(s + 1) * m * 2].copy_from_slice(&prs[k].0);
                p_bot[s * m * 2..(s + 1) * m * 2].copy_from_slice(&prs[k].1);
            }
            // Padding slots keep conics PSD-ish like padded rows do.
            for s in slots.len()..b {
                for i in 0..n {
                    conic[(s * n + i) * 3] = 1.0;
                    conic[(s * n + i) * 3 + 2] = 1.0;
                }
            }

            let out = self.rt.exec_f32(
                artifact,
                &[
                    (&mu, &[b as i64, n as i64, 2]),
                    (&conic, &[b as i64, n as i64, 3]),
                    (&opacity, &[b as i64, n as i64]),
                    (&color, &[b as i64, n as i64, 3]),
                    (&origin, &[b as i64, 2]),
                    (&p_top, &[b as i64, m as i64, 2]),
                    (&p_bot, &[b as i64, m as i64, 2]),
                ],
            )?;
            let rgb = &out[0]; // (B,16,16,3)
            let trans = &out[1]; // (B,16,16)
            let passes = &out[2]; // (B,N)

            self.stats.batches += 1;
            self.stats.slots_filled += slots.len();
            self.stats.rows_submitted += b * n;
            if let Some(i) = ci {
                self.stats.batches_by_class[i] += 1;
                self.stats.slots_by_class[i] += slots.len();
                self.stats.rows_by_class[i] += b * n;
            }
            for (s, &(k, chunk)) in slots.iter().enumerate() {
                self.stats.chunks += 1;
                self.stats.splats_submitted += chunk.len();
                if let Some(i) = ci {
                    self.stats.splats_by_class[i] += chunk.len();
                }
                self.stats.splats_passed_cat += passes[s * n..s * n + chunk.len()]
                    .iter()
                    .filter(|&&p| p > 0.5)
                    .count();
                let st = &mut states[k];
                if Self::composite_chunk(
                    &mut st.acc_rgb,
                    &mut st.acc_t,
                    &rgb[s * px * 3..(s + 1) * px * 3],
                    &trans[s * px..(s + 1) * px],
                ) {
                    st.done = true;
                }
            }
        }

        self.stats.tiles += group.len();
        if let Some(i) = ci {
            self.stats.tiles_by_class[i] += group.len();
        }
        for (k, st) in states.iter().enumerate() {
            let sj = &group[k];
            let stitch = sj.job.quads.map(|quads| {
                (quads, sj.job.class.expect("rect-stitched jobs are always classed"))
            });
            self.write_tile(
                &sj.job.rect,
                &st.acc_rgb,
                &st.acc_t,
                &mut images[sj.source],
                sources[sj.source].background,
                stitch,
            );
        }
        Ok(())
    }
}

/// The batched blend artifact serving a precision class. Unclassed and
/// fp32-classed waves share the original `render_tile_batched` (its CAT
/// gate is fp32), so an adaptive render whose thresholds force every tile
/// to fp32 forms exactly the dispatches a `Global(Fp32)` render forms.
pub fn batched_artifact(class: Option<Precision>) -> &'static str {
    match class {
        None | Some(Precision::Fp32) => "render_tile_batched",
        Some(Precision::Fp16) => "render_tile_batched_fp16",
        Some(Precision::Fp8) => "render_tile_batched_fp8",
        Some(Precision::Mixed) => "render_tile_batched_mixed",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::{v3, Quat};
    use crate::render::project::project_scene;
    use crate::render::sort::sort_by_depth;
    use crate::render::tile::{build_tile_lists, Strategy, TileGrid};
    use crate::runtime::{default_artifact_dir, write_stub_artifacts};
    use crate::scene::gaussian::Scene;

    fn test_scene() -> (Scene, Camera) {
        let cam = Camera::look_at(
            Intrinsics::from_fov(32, 32, 1.2),
            v3(0.0, 0.0, -6.0),
            v3(0.0, 0.0, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        let mut scene = Scene::with_capacity(3, "t");
        let sh0 = [[0.0; 3]; 3];
        scene.push(v3(0.0, 0.0, 0.0), Quat::IDENTITY, v3(0.6, 0.6, 0.6), 0.9, [1.5, 0.0, 0.0], sh0);
        scene.push(v3(0.4, 0.2, 1.0), Quat::IDENTITY, v3(0.4, 0.4, 0.4), 0.7, [0.0, 1.5, 0.0], sh0);
        scene.push(
            v3(-0.4, -0.2, 2.0),
            Quat::IDENTITY,
            v3(0.5, 0.5, 0.5),
            0.5,
            [0.0, 0.0, 1.5],
            sh0,
        );
        (scene, cam)
    }

    fn check_executor_matches_golden(rt: &Runtime) {
        let (scene, cam) = test_scene();

        // Golden render.
        let golden = crate::render::raster::render(
            &scene,
            &cam,
            &crate::render::raster::RenderOptions::default(),
        );

        // PJRT render, single-tile dispatches.
        let splats = project_scene(&scene, &cam);
        let grid = TileGrid::new(32, 32, 16);
        let mut lists = build_tile_lists(&splats, &grid, Strategy::Aabb);
        for l in &mut lists {
            sort_by_depth(l, &splats);
        }
        let mut img = Image::new(32, 32);
        let mut ex = TileExecutor::new(rt);
        for (t, list) in lists.iter().enumerate() {
            ex.render_tile(&grid.rect(t), &splats, list, &mut img, [0.0; 3])
                .unwrap();
        }
        // CAT gating in the artifact may drop marginal splats the golden
        // model blends, so compare with PSNR, not exactness.
        let p = crate::render::metrics::psnr(&golden.image, &img);
        assert!(p > 30.0, "PJRT vs golden PSNR {p}");
        assert!(ex.stats.tiles == 4);
        assert!(ex.stats.splats_passed_cat > 0);

        // Batched dispatches must reproduce the image bit for bit.
        let jobs = TileJob::for_grid(&grid, &lists);
        let mut batched = Image::new(32, 32);
        let mut exb = TileExecutor::new(rt);
        exb.render_tiles(&jobs, &splats, &mut batched, [0.0; 3]).unwrap();
        assert_eq!(img.data, batched.data, "batched != single-tile render");
        assert_eq!(exb.stats.tiles, ex.stats.tiles);
        assert_eq!(exb.stats.chunks, ex.stats.chunks);
        assert_eq!(exb.stats.splats_submitted, ex.stats.splats_submitted);
        assert_eq!(exb.stats.splats_passed_cat, ex.stats.splats_passed_cat);
    }

    #[test]
    fn executor_matches_golden_rasterizer() {
        if !default_artifact_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = match Runtime::load(&default_artifact_dir()) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: pjrt runtime unavailable ({e})");
                return;
            }
        };
        check_executor_matches_golden(&rt);
    }

    #[test]
    fn stub_executor_matches_golden_rasterizer_offline() {
        // Same contract as above, but against a synthesized stub artifact
        // set — runs in default CI with no jax and no real XLA.
        let dir = std::env::temp_dir().join("flicker_executor_stub_artifacts");
        write_stub_artifacts(&dir, 64, 16, 16, 4).unwrap();
        let rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                // Real-xla builds cannot parse the placeholder files.
                eprintln!("skipping: stub runtime unavailable ({e})");
                return;
            }
        };
        assert_eq!(rt.manifest.n_batch, 4);
        check_executor_matches_golden(&rt);
    }

    #[test]
    fn fill_rate_guards_the_empty_wave() {
        // A fresh executor (and every class of one) reports 0.0 — not NaN,
        // not a division panic — before any wave ships.
        let stats = ExecStats::default();
        assert_eq!(stats.fill_rate(), 0.0);
        for c in CLASSES {
            assert_eq!(stats.fill_rate_by_class(c), 0.0);
        }
        // One class shipping rows leaves the others at 0.0.
        let mut some = ExecStats::default();
        some.splats_by_class[class_index(Precision::Fp16)] = 3;
        some.rows_by_class[class_index(Precision::Fp16)] = 8;
        assert_eq!(some.fill_rate_by_class(Precision::Fp16), 3.0 / 8.0);
        assert_eq!(some.fill_rate_by_class(Precision::Fp8), 0.0);
    }

    #[test]
    fn empty_and_classed_empty_queues_ship_nothing() {
        let dir = std::env::temp_dir().join("flicker_emptywave_stub_artifacts");
        write_stub_artifacts(&dir, 8, 16, 16, 4).unwrap();
        let rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                return;
            }
        };
        let mut img = Image::new(32, 32);
        let mut ex = TileExecutor::new(&rt);
        ex.render_tiles(&[], &[], &mut img, [0.0; 3]).unwrap();
        assert_eq!(ex.stats.fill_rate(), 0.0);
        assert_eq!(ex.stats.batches, 0);
        // Classed tiles whose lists are all empty form no wave at all —
        // and the per-class fill rate stays on its 0.0 guard.
        let grid = TileGrid::new(32, 32, 16);
        let lists: Vec<Vec<u32>> = vec![Vec::new(); 4];
        let jobs = TileJob::for_grid_classed(&grid, &lists, &[Precision::Fp16; 4]);
        ex.render_tiles(&jobs, &[], &mut img, [0.0; 3]).unwrap();
        assert_eq!(ex.stats.batches, 0);
        assert_eq!(ex.stats.rows_submitted, 0);
        assert_eq!(ex.stats.tiles, 4);
        assert_eq!(ex.stats.tiles_by_class[class_index(Precision::Fp16)], 4);
        assert_eq!(ex.stats.fill_rate(), 0.0);
        assert_eq!(ex.stats.fill_rate_by_class(Precision::Fp16), 0.0);
    }

    #[test]
    fn classed_waves_are_precision_pure() {
        let dir = std::env::temp_dir().join("flicker_classed_stub_artifacts");
        write_stub_artifacts(&dir, 64, 16, 16, 4).unwrap();
        let rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                return;
            }
        };
        let (scene, cam) = test_scene();
        let splats = project_scene(&scene, &cam);
        let grid = TileGrid::new(32, 32, 16);
        let mut lists = build_tile_lists(&splats, &grid, Strategy::Aabb);
        for l in &mut lists {
            sort_by_depth(l, &splats);
        }
        let classes = [Precision::Fp32, Precision::Fp16, Precision::Fp16, Precision::Mixed];
        let jobs = TileJob::for_grid_classed(&grid, &lists, &classes);
        let mut img = Image::new(32, 32);
        let mut ex = TileExecutor::new(&rt);
        ex.render_tiles(&jobs, &splats, &mut img, [0.0; 3]).unwrap();
        // 4 tiles fit one n_batch=4 dispatch, but waves never mix classes:
        // each populated class formed its own dispatches.
        assert_eq!(ex.stats.tiles, 4);
        assert_eq!(ex.stats.tiles_by_class, [1, 2, 1, 0]);
        let populated = CLASSES
            .iter()
            .filter(|&&c| {
                lists
                    .iter()
                    .zip(&classes)
                    .any(|(l, &lc)| lc == c && !l.is_empty())
            })
            .count();
        assert!(populated >= 2, "test scene too sparse to exercise waves");
        assert!(ex.stats.batches >= populated, "waves mixed classes");
        assert_eq!(ex.stats.batches, ex.stats.batches_by_class.iter().sum::<usize>());
        assert_eq!(ex.stats.rows_submitted, ex.stats.rows_by_class.iter().sum::<usize>());
        assert_eq!(
            ex.stats.splats_submitted,
            ex.stats.splats_by_class.iter().sum::<usize>()
        );
        for (i, c) in CLASSES.iter().enumerate() {
            let fr = ex.stats.fill_rate_by_class(*c);
            if ex.stats.rows_by_class[i] == 0 {
                assert_eq!(fr, 0.0, "{c:?}");
            } else {
                assert!(fr > 0.0 && fr <= 1.0, "{c:?} fill rate {fr}");
            }
        }
        // Forcing every class to fp32 reproduces the unclassed batched
        // render bit for bit — same artifact, same groups, same waves.
        let fp32_jobs = TileJob::for_grid_classed(&grid, &lists, &[Precision::Fp32; 4]);
        let mut forced = Image::new(32, 32);
        let mut exf = TileExecutor::new(&rt);
        exf.render_tiles(&fp32_jobs, &splats, &mut forced, [0.0; 3]).unwrap();
        let plain_jobs = TileJob::for_grid(&grid, &lists);
        let mut plain = Image::new(32, 32);
        let mut exp = TileExecutor::new(&rt);
        exp.render_tiles(&plain_jobs, &splats, &mut plain, [0.0; 3]).unwrap();
        assert_eq!(forced.data, plain.data);
        assert_eq!(exf.stats.batches, exp.stats.batches);
        assert_eq!(exf.stats.splats_submitted, exp.stats.splats_submitted);
    }

    #[test]
    fn rect_split_jobs_stitch_per_quadrant_outputs() {
        // A mixed-class tile splits into one job per distinct class; each
        // quadrant's stitched pixels must equal a whole-tile render at
        // that quadrant's class, and uniform maps must form the exact
        // per-tile classed queue.
        let dir = std::env::temp_dir().join("flicker_rectjob_stub_artifacts");
        write_stub_artifacts(&dir, 64, 16, 16, 4).unwrap();
        let rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                return;
            }
        };
        let (scene, cam) = test_scene();
        let splats = project_scene(&scene, &cam);
        let grid = TileGrid::new(32, 32, 16);
        let mut lists = build_tile_lists(&splats, &grid, Strategy::Aabb);
        for l in &mut lists {
            sort_by_depth(l, &splats);
        }
        let quads = [Precision::Fp32, Precision::Fp16, Precision::Fp16, Precision::Mixed];
        let maps: Vec<TileClassMap> = (0..4)
            .map(|t| {
                if t == 0 {
                    TileClassMap::Mixed(quads)
                } else {
                    TileClassMap::Uniform(Precision::Fp16)
                }
            })
            .collect();
        let jobs = TileJob::for_grid_rect_classed(&grid, &lists, &maps);
        // Tile 0 rides three class waves; tiles 1..3 one job each.
        assert_eq!(jobs.len(), 6);
        let bg = [0.05, 0.0, 0.0];
        let mut img = Image::new(32, 32);
        let mut ex = TileExecutor::new(&rt);
        ex.render_tiles(&jobs, &splats, &mut img, bg).unwrap();
        assert_eq!(ex.stats.tiles, 6, "rect splits count once per class wave");
        let rect0 = grid.rect(0);
        for class in [Precision::Fp32, Precision::Fp16, Precision::Mixed] {
            let cjobs = TileJob::for_grid_classed(&grid, &lists, &[class; 4]);
            let mut whole = Image::new(32, 32);
            TileExecutor::new(&rt)
                .render_tiles(&cjobs, &splats, &mut whole, bg)
                .unwrap();
            for py in 0..16u32 {
                for px in 0..16u32 {
                    let q = crate::render::pyramid::quad_of_pixel(&rect0, 16, px, py);
                    if quads[q] == class {
                        assert_eq!(
                            img.get(px, py),
                            whole.get(px, py),
                            "pixel ({px},{py}) in quadrant {q} diverges from a \
                             whole-tile {class:?} render"
                        );
                    }
                }
            }
        }
        // All-uniform maps are the per-tile classed queue, bit for bit.
        let umaps = vec![TileClassMap::Uniform(Precision::Fp16); 4];
        let ujobs = TileJob::for_grid_rect_classed(&grid, &lists, &umaps);
        let mut uimg = Image::new(32, 32);
        TileExecutor::new(&rt).render_tiles(&ujobs, &splats, &mut uimg, bg).unwrap();
        let cjobs = TileJob::for_grid_classed(&grid, &lists, &[Precision::Fp16; 4]);
        let mut cimg = Image::new(32, 32);
        TileExecutor::new(&rt).render_tiles(&cjobs, &splats, &mut cimg, bg).unwrap();
        assert_eq!(uimg.data, cimg.data, "uniform rect maps != per-tile classed queue");
    }

    #[test]
    fn coalesced_waves_match_separate_renders_and_pack_tighter() {
        // Two clients view the same scene from different cameras; each
        // frame is ragged (4 tiles vs n_batch=4 is only full when both
        // queues merge into shared waves). The coalesced render must be
        // bit-identical per frame to separate render_tiles calls, and its
        // fill rate must be at least the aggregate of the separate runs.
        let dir = std::env::temp_dir().join("flicker_coalesce_stub_artifacts");
        write_stub_artifacts(&dir, 8, 16, 16, 4).unwrap();
        let rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                return;
            }
        };
        let (scene, cam_a) = test_scene();
        let cam_b = Camera::look_at(
            Intrinsics::from_fov(32, 32, 1.2),
            v3(0.5, 0.3, -6.0),
            v3(0.0, 0.0, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        let grid = TileGrid::new(32, 32, 16);
        let mut per_client: Vec<(Vec<Splat>, Vec<Vec<u32>>)> = Vec::new();
        for cam in [&cam_a, &cam_b] {
            let splats = project_scene(&scene, cam);
            let mut lists = build_tile_lists(&splats, &grid, Strategy::Aabb);
            for l in &mut lists {
                sort_by_depth(l, &splats);
            }
            per_client.push((splats, lists));
        }

        // Separate baseline: one render_tiles call per client, batch 3 so
        // each client's 4 tiles leave a ragged final group.
        let mut sep_imgs = vec![Image::new(32, 32), Image::new(32, 32)];
        let mut sep_stats = ExecStats::default();
        for (c, (splats, lists)) in per_client.iter().enumerate() {
            let jobs = TileJob::for_grid(&grid, lists);
            let mut ex = TileExecutor::new(&rt).with_batch(3);
            ex.render_tiles(&jobs, splats, &mut sep_imgs[c], [0.0; 3]).unwrap();
            sep_stats.splats_submitted += ex.stats.splats_submitted;
            sep_stats.rows_submitted += ex.stats.rows_submitted;
            sep_stats.chunks += ex.stats.chunks;
        }

        // Coalesced: both clients' jobs through shared waves.
        let sources: Vec<TileSource> = per_client
            .iter()
            .map(|(splats, _)| TileSource { splats, background: [0.0; 3] })
            .collect();
        let per_jobs: Vec<Vec<TileJob>> = per_client
            .iter()
            .map(|(_, lists)| TileJob::for_grid(&grid, lists))
            .collect();
        let jobs: Vec<SourcedJob> = per_jobs
            .iter()
            .enumerate()
            .flat_map(|(c, js)| js.iter().map(move |&job| SourcedJob { source: c, job }))
            .collect();
        let mut co_imgs = vec![Image::new(32, 32), Image::new(32, 32)];
        let mut exc = TileExecutor::new(&rt).with_batch(3);
        exc.render_tiles_coalesced(&sources, &jobs, &mut co_imgs).unwrap();

        for c in 0..2 {
            assert_eq!(
                sep_imgs[c].data, co_imgs[c].data,
                "client {c}: coalesced != separate render"
            );
        }
        // Real work is grouping-invariant; packing only reduces shipped rows.
        assert_eq!(exc.stats.splats_submitted, sep_stats.splats_submitted);
        assert_eq!(exc.stats.chunks, sep_stats.chunks);
        assert!(exc.stats.rows_submitted <= sep_stats.rows_submitted);
        assert!(
            exc.stats.fill_rate() >= sep_stats.fill_rate(),
            "coalesced fill {} < separate aggregate {}",
            exc.stats.fill_rate(),
            sep_stats.fill_rate()
        );
    }

    #[test]
    fn coalesced_single_tile_fallback_and_classes() {
        // Effective batch 1 routes every sourced job through the
        // single-tile artifact; classed queues stay precision-pure.
        let dir = std::env::temp_dir().join("flicker_coalesce1_stub_artifacts");
        write_stub_artifacts(&dir, 8, 16, 16, 4).unwrap();
        let rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                return;
            }
        };
        let (scene, cam) = test_scene();
        let splats = project_scene(&scene, &cam);
        let grid = TileGrid::new(32, 32, 16);
        let mut lists = build_tile_lists(&splats, &grid, Strategy::Aabb);
        for l in &mut lists {
            sort_by_depth(l, &splats);
        }
        let jobs1 = TileJob::for_grid(&grid, &lists);
        let sources = [TileSource { splats: &splats, background: [0.1, 0.0, 0.0] }];
        let sjobs: Vec<SourcedJob> =
            jobs1.iter().map(|&job| SourcedJob { source: 0, job }).collect();

        let mut base = Image::new(32, 32);
        let mut exb = TileExecutor::new(&rt).with_batch(1);
        exb.render_tiles(&jobs1, &splats, &mut base, [0.1, 0.0, 0.0]).unwrap();
        let mut co = vec![Image::new(32, 32)];
        let mut exc = TileExecutor::new(&rt).with_batch(1);
        exc.render_tiles_coalesced(&sources, &sjobs, &mut co).unwrap();
        assert_eq!(base.data, co[0].data);
        assert_eq!(exc.stats.batches, 0, "batch 1 must use the single-tile artifact");

        // Classed: same classes through both entries, identical pixels.
        let classes = [Precision::Fp32, Precision::Fp16, Precision::Fp16, Precision::Mixed];
        let cjobs = TileJob::for_grid_classed(&grid, &lists, &classes);
        let mut cbase = Image::new(32, 32);
        let mut excb = TileExecutor::new(&rt);
        excb.render_tiles(&cjobs, &splats, &mut cbase, [0.0; 3]).unwrap();
        let scjobs: Vec<SourcedJob> =
            cjobs.iter().map(|&job| SourcedJob { source: 0, job }).collect();
        let csources = [TileSource { splats: &splats, background: [0.0; 3] }];
        let mut cco = vec![Image::new(32, 32)];
        let mut excc = TileExecutor::new(&rt);
        excc.render_tiles_coalesced(&csources, &scjobs, &mut cco).unwrap();
        assert_eq!(cbase.data, cco[0].data);
        assert_eq!(excc.stats.batches, excc.stats.batches_by_class.iter().sum::<usize>());
    }

    #[test]
    fn exec_stats_count_real_splats_only() {
        // Padding — short chunks and empty batch slots — must not inflate
        // splats_submitted (regression: the padded rows of every chunk
        // used to be documented as counted).
        let dir = std::env::temp_dir().join("flicker_execstats_stub_artifacts");
        write_stub_artifacts(&dir, 8, 16, 16, 4).unwrap();
        let rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: stub runtime unavailable ({e})");
                return;
            }
        };
        let (scene, cam) = test_scene();
        let splats = project_scene(&scene, &cam);
        let grid = TileGrid::new(32, 32, 16);
        let mut lists = build_tile_lists(&splats, &grid, Strategy::Aabb);
        for l in &mut lists {
            sort_by_depth(l, &splats);
        }
        let real: usize = lists.iter().map(|l| l.len()).sum();
        let chunks: usize = lists.iter().map(|l| l.len().div_ceil(8)).sum();
        assert!(real > 0, "scene must bin something");

        let jobs = TileJob::for_grid(&grid, &lists);
        let mut img = Image::new(32, 32);
        let mut ex = TileExecutor::new(&rt).with_batch(3);
        ex.render_tiles(&jobs, &splats, &mut img, [0.0; 3]).unwrap();
        assert_eq!(ex.stats.splats_submitted, real, "padding counted as submitted");
        assert_eq!(ex.stats.chunks, chunks);
        assert_eq!(ex.stats.slots_filled, ex.stats.chunks);
        assert_eq!(ex.stats.rows_submitted, ex.stats.batches * 4 * 8);
        assert!(ex.stats.batches > 0);
        assert!(ex.stats.fill_rate() > 0.0 && ex.stats.fill_rate() <= 1.0);
        // The single-tile path obeys the same accounting.
        let mut ex1 = TileExecutor::new(&rt);
        let mut img1 = Image::new(32, 32);
        for (t, list) in lists.iter().enumerate() {
            ex1.render_tile(&grid.rect(t), &splats, list, &mut img1, [0.0; 3])
                .unwrap();
        }
        assert_eq!(ex1.stats.splats_submitted, real);
        assert_eq!(ex1.stats.rows_submitted, ex1.stats.chunks * 8);
        assert_eq!(ex1.stats.batches, 0);
    }
}
