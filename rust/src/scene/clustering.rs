//! Gaussian clustering into "big Gaussians" (PS-GS [18]).
//!
//! The paper reduces DDR traffic by grouping Gaussians into clusters and
//! frustum-culling the cluster's bounding sphere instead of each member
//! (Sec. IV-A "Memory Access Optimization"). We implement voxel-grid
//! clustering with a target mean cluster size, producing bounding spheres
//! consumed by the preprocessing-core model and the DRAM traffic model.

use super::gaussian::Scene;
use crate::camera::Camera;
use crate::numeric::linalg::{v3, Vec3};
use std::collections::HashMap;

/// One cluster ("big Gaussian"): bounding sphere + member indices.
#[derive(Clone, Debug)]
pub struct Cluster {
    /// Bounding sphere center.
    pub center: Vec3,
    /// Bounding sphere radius.
    pub radius: f32,
    /// Member Gaussian indices.
    pub members: Vec<u32>,
}

/// The clustered scene index.
#[derive(Clone, Debug, Default)]
pub struct Clustering {
    /// All clusters.
    pub clusters: Vec<Cluster>,
    /// Voxel edge used.
    pub cell: f32,
}

impl Clustering {
    /// Number of clusters.
    pub fn num_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// Mean members per cluster.
    pub fn mean_size(&self) -> f64 {
        if self.clusters.is_empty() {
            return 0.0;
        }
        self.clusters.iter().map(|c| c.members.len()).sum::<usize>() as f64
            / self.clusters.len() as f64
    }

    /// Indices of Gaussians surviving cluster-level frustum culling: all
    /// members of clusters whose sphere intersects the frustum.
    pub fn cull(&self, cam: &Camera) -> Vec<u32> {
        let mut out = Vec::new();
        for c in &self.clusters {
            if cam.sphere_in_frustum(c.center, c.radius) {
                out.extend_from_slice(&c.members);
            }
        }
        out
    }

    /// Count clusters visible from `cam` (metadata reads the DRAM model charges).
    pub fn visible_clusters(&self, cam: &Camera) -> usize {
        self.clusters
            .iter()
            .filter(|c| cam.sphere_in_frustum(c.center, c.radius))
            .count()
    }
}

/// Voxel-grid clustering with `target_size` mean members per cluster.
/// The voxel edge is derived from scene density so cluster occupancy is
/// roughly uniform regardless of scene scale.
pub fn cluster(scene: &Scene, target_size: usize) -> Clustering {
    assert!(target_size >= 1);
    if scene.is_empty() {
        return Clustering::default();
    }
    let (lo, hi) = scene.bounds();
    let extent = hi - lo;
    let volume = (extent.x.max(1e-3) * extent.y.max(1e-3) * extent.z.max(1e-3)) as f64;
    // cell³ · density ≈ target_size  →  cell = (target·V/N)^(1/3)
    let cell = ((target_size as f64 * volume / scene.len() as f64).cbrt() as f32).max(1e-3);

    let mut map: HashMap<(i32, i32, i32), Vec<u32>> = HashMap::new();
    for i in 0..scene.len() {
        let p = scene.pos[i];
        let key = (
            ((p.x - lo.x) / cell).floor() as i32,
            ((p.y - lo.y) / cell).floor() as i32,
            ((p.z - lo.z) / cell).floor() as i32,
        );
        map.entry(key).or_default().push(i as u32);
    }

    let mut clusters: Vec<Cluster> = map
        .into_values()
        .map(|members| {
            let mut c = v3(0.0, 0.0, 0.0);
            for &m in &members {
                c = c + scene.pos[m as usize];
            }
            let center = c / members.len() as f32;
            let mut radius = 0.0f32;
            for &m in &members {
                let r = (scene.pos[m as usize] - center).norm()
                    + scene.bounding_radius(m as usize);
                radius = radius.max(r);
            }
            Cluster {
                center,
                radius,
                members,
            }
        })
        .collect();
    // Deterministic order (HashMap iteration isn't).
    clusters.sort_by(|a, b| {
        (a.center.x, a.center.y, a.center.z)
            .partial_cmp(&(b.center.x, b.center.y, b.center.z))
            .unwrap()
    });
    Clustering { clusters, cell }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::render::project::project_one;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn test_cam() -> Camera {
        Camera::look_at(
            Intrinsics::from_fov(128, 128, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        )
    }

    #[test]
    fn every_gaussian_in_exactly_one_cluster() {
        let scene = generate_scaled(&preset("truck"), 0.02);
        let cl = cluster(&scene, 32);
        let mut seen = vec![false; scene.len()];
        for c in &cl.clusters {
            for &m in &c.members {
                assert!(!seen[m as usize], "duplicate member {m}");
                seen[m as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "missing members");
    }

    #[test]
    fn cluster_sphere_bounds_members() {
        let scene = generate_scaled(&preset("playroom"), 0.02);
        let cl = cluster(&scene, 16);
        for c in &cl.clusters {
            for &m in &c.members {
                let d = (scene.pos[m as usize] - c.center).norm()
                    + scene.bounding_radius(m as usize);
                assert!(d <= c.radius + 1e-4);
            }
        }
    }

    #[test]
    fn mean_size_near_target() {
        let scene = generate_scaled(&preset("garden"), 0.05);
        let cl = cluster(&scene, 32);
        // Voxel occupancy is lumpy; just require the right order of magnitude.
        assert!(cl.mean_size() > 4.0, "mean {}", cl.mean_size());
        assert!(cl.num_clusters() > 8);
    }

    #[test]
    fn cull_is_conservative() {
        // Every Gaussian that projects successfully must survive cluster culling.
        let scene = generate_scaled(&preset("truck"), 0.02);
        let cam = test_cam();
        let cl = cluster(&scene, 32);
        let survivors: std::collections::HashSet<u32> = cl.cull(&cam).into_iter().collect();
        for i in 0..scene.len() {
            if project_one(&scene, i, &cam).is_some() {
                assert!(
                    survivors.contains(&(i as u32)),
                    "visible gaussian {i} culled at cluster level"
                );
            }
        }
    }

    #[test]
    fn culling_reduces_metadata_reads() {
        // A camera looking at one corner shouldn't need every cluster.
        let scene = generate_scaled(&preset("bicycle"), 0.05);
        let intr = Intrinsics::from_fov(128, 128, 0.7);
        let cam = Camera::look_at(intr, v3(16.0, 2.0, 16.0), v3(20.0, 2.0, 20.0), v3(0.0, 1.0, 0.0));
        let cl = cluster(&scene, 32);
        assert!(
            cl.visible_clusters(&cam) < cl.num_clusters(),
            "expected some clusters culled"
        );
    }

    #[test]
    fn empty_scene() {
        let scene = Scene::with_capacity(0, "empty");
        let cl = cluster(&scene, 8);
        assert_eq!(cl.num_clusters(), 0);
    }
}
