//! Scene substrate: Gaussian container, procedural synthetic datasets,
//! contribution pruning, clustering into "big Gaussians", and binary IO.

pub mod clustering;
pub mod gaussian;
pub mod io;
pub mod pruning;
pub mod synthetic;
