//! Binary scene IO (`.gsz`): a small fixed-layout format so trained/pruned
//! scenes can be cached between runs and shared with the Python build path.
//!
//! Layout (little-endian):
//!   magic "GSZ1" | u32 count | u32 name_len | name bytes
//!   then per field, contiguous arrays: pos (3f32·n), rot (4f32·n),
//!   scale (3f32·n), opacity (f32·n), sh_dc (3f32·n), sh1 (9f32·n).

use super::gaussian::Scene;
use crate::numeric::linalg::{v3, Quat};
use crate::util::error::{Error, Result};
use std::path::Path;

const MAGIC: &[u8; 4] = b"GSZ1";

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize a scene to bytes.
pub fn to_bytes(scene: &Scene) -> Vec<u8> {
    let n = scene.len();
    let mut buf = Vec::with_capacity(16 + n * (3 + 4 + 3 + 1 + 3 + 9) * 4);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(n as u32).to_le_bytes());
    let name = scene.name.as_bytes();
    buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
    buf.extend_from_slice(name);
    for p in &scene.pos {
        push_f32s(&mut buf, &[p.x, p.y, p.z]);
    }
    for q in &scene.rot {
        push_f32s(&mut buf, &[q.w, q.x, q.y, q.z]);
    }
    for s in &scene.scale {
        push_f32s(&mut buf, &[s.x, s.y, s.z]);
    }
    push_f32s(&mut buf, &scene.opacity);
    for c in &scene.sh_dc {
        push_f32s(&mut buf, c);
    }
    for sh in &scene.sh1 {
        for ch in sh {
            push_f32s(&mut buf, ch);
        }
    }
    buf
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::msg("truncated gsz"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

/// Deserialize a scene from bytes.
pub fn from_bytes(bytes: &[u8]) -> Result<Scene> {
    let mut r = Reader { buf: bytes, pos: 0 };
    if r.take(4)? != MAGIC {
        return Err(Error::msg("bad gsz magic"));
    }
    let n = r.u32()? as usize;
    let name_len = r.u32()? as usize;
    let name = String::from_utf8_lossy(r.take(name_len)?).into_owned();
    let mut scene = Scene::with_capacity(n, &name);
    let mut pos = Vec::with_capacity(n);
    for _ in 0..n {
        pos.push(v3(r.f32()?, r.f32()?, r.f32()?));
    }
    let mut rot = Vec::with_capacity(n);
    for _ in 0..n {
        rot.push(Quat {
            w: r.f32()?,
            x: r.f32()?,
            y: r.f32()?,
            z: r.f32()?,
        });
    }
    let mut scale = Vec::with_capacity(n);
    for _ in 0..n {
        scale.push(v3(r.f32()?, r.f32()?, r.f32()?));
    }
    let mut opacity = Vec::with_capacity(n);
    for _ in 0..n {
        opacity.push(r.f32()?);
    }
    let mut sh_dc = Vec::with_capacity(n);
    for _ in 0..n {
        sh_dc.push([r.f32()?, r.f32()?, r.f32()?]);
    }
    let mut sh1 = Vec::with_capacity(n);
    for _ in 0..n {
        let mut v = [[0.0f32; 3]; 3];
        for ch in &mut v {
            for b in ch.iter_mut() {
                *b = r.f32()?;
            }
        }
        sh1.push(v);
    }
    scene.pos = pos;
    scene.rot = rot;
    scene.scale = scale;
    scene.opacity = opacity;
    scene.sh_dc = sh_dc;
    scene.sh1 = sh1;
    scene.name = name;
    Ok(scene)
}

/// Write a scene to a `.gsz` file.
pub fn save(scene: &Scene, path: &Path) -> Result<()> {
    Ok(std::fs::write(path, to_bytes(scene))?)
}

/// Read a scene from a `.gsz` file.
pub fn load(path: &Path) -> Result<Scene> {
    from_bytes(&std::fs::read(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::synthetic::{generate_scaled, preset};

    #[test]
    fn roundtrip_exact() {
        let scene = generate_scaled(&preset("truck"), 0.005);
        let bytes = to_bytes(&scene);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(back.len(), scene.len());
        assert_eq!(back.name, scene.name);
        assert_eq!(back.pos, scene.pos);
        assert_eq!(back.rot, scene.rot);
        assert_eq!(back.scale, scene.scale);
        assert_eq!(back.opacity, scene.opacity);
        assert_eq!(back.sh_dc, scene.sh_dc);
        assert_eq!(back.sh1, scene.sh1);
    }

    #[test]
    fn file_roundtrip() {
        let scene = generate_scaled(&preset("playroom"), 0.005);
        let dir = std::env::temp_dir().join("flicker_gsz");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.gsz");
        save(&scene, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), scene.len());
        assert_eq!(back.pos[3], scene.pos[3]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(from_bytes(b"NOPE____________").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let scene = generate_scaled(&preset("truck"), 0.005);
        let bytes = to_bytes(&scene);
        assert!(from_bytes(&bytes[..bytes.len() / 2]).is_err());
        assert!(from_bytes(&bytes[..6]).is_err());
    }

    #[test]
    fn empty_scene_roundtrip() {
        let scene = Scene::with_capacity(0, "void");
        let back = from_bytes(&to_bytes(&scene)).unwrap();
        assert_eq!(back.len(), 0);
        assert_eq!(back.name, "void");
    }
}
