//! Scene representation: a set of anisotropic 3D Gaussians (SoA layout).
//!
//! Parameters follow 3DGS [2]: position (3), rotation quaternion (4), scale
//! stdevs (3) — the 10 "geometric features" the paper's DRAM optimizer loads
//! during culling — plus opacity and spherical-harmonics color. We carry SH
//! degree 1 (DC + 3 linear coefficients per channel) which is enough for the
//! view-dependence the experiments exercise; the DRAM model accounts for the
//! paper's full 45-parameter color payload via `params::COLOR_F32S`.

use crate::numeric::linalg::{v3, Quat, Vec3};

/// Parameter-count constants used by the DRAM traffic model (paper Sec. IV-A).
pub mod params {
    /// Geometric features fetched during culling: μ(3) + q(4) + s(3).
    pub const GEOM_F32S: usize = 10;
    /// Color features fetched only for surviving Gaussians (SH deg-3 payload
    /// minus DC, as in the paper's "45 parameters").
    pub const COLOR_F32S: usize = 45;
    /// Opacity + DC color + misc fetched with color.
    pub const MISC_F32S: usize = 4;
    /// Bytes per Gaussian for the geometry fetch phase.
    pub const GEOM_BYTES: usize = GEOM_F32S * 4;
    /// Bytes per Gaussian for the color fetch phase.
    pub const COLOR_BYTES: usize = (COLOR_F32S + MISC_F32S) * 4;
}

/// SoA container for a Gaussian scene.
#[derive(Clone, Debug, Default)]
pub struct Scene {
    /// Gaussian centers in world space.
    pub pos: Vec<Vec3>,
    /// Orientation quaternions.
    pub rot: Vec<Quat>,
    /// Per-axis standard deviations (σ), not variances.
    pub scale: Vec<Vec3>,
    /// Opacity in [0, 1] (already sigmoid-activated).
    pub opacity: Vec<f32>,
    /// SH DC color term (RGB), linear space.
    pub sh_dc: Vec<[f32; 3]>,
    /// SH degree-1 coefficients: [channel][basis(x,y,z)].
    pub sh1: Vec<[[f32; 3]; 3]>,
    /// Human-readable name ("garden", "truck", …).
    pub name: String,
}

impl Scene {
    /// Empty scene with room for `n` Gaussians.
    pub fn with_capacity(n: usize, name: &str) -> Scene {
        Scene {
            pos: Vec::with_capacity(n),
            rot: Vec::with_capacity(n),
            scale: Vec::with_capacity(n),
            opacity: Vec::with_capacity(n),
            sh_dc: Vec::with_capacity(n),
            sh1: Vec::with_capacity(n),
            name: name.to_string(),
        }
    }

    /// Number of Gaussians.
    pub fn len(&self) -> usize {
        self.pos.len()
    }

    /// Is the scene empty?
    pub fn is_empty(&self) -> bool {
        self.pos.is_empty()
    }

    /// Append one Gaussian; returns its index.
    #[allow(clippy::too_many_arguments)]
    pub fn push(
        &mut self,
        pos: Vec3,
        rot: Quat,
        scale: Vec3,
        opacity: f32,
        sh_dc: [f32; 3],
        sh1: [[f32; 3]; 3],
    ) -> usize {
        debug_assert!((0.0..=1.0).contains(&opacity), "opacity {opacity}");
        debug_assert!(scale.x > 0.0 && scale.y > 0.0 && scale.z > 0.0);
        self.pos.push(pos);
        self.rot.push(rot.normalized());
        self.scale.push(scale);
        self.opacity.push(opacity);
        self.sh_dc.push(sh_dc);
        self.sh1.push(sh1);
        self.len() - 1
    }

    /// Retain only Gaussians whose index passes `keep` (used by pruning).
    pub fn retain_indices(&mut self, keep: &[bool]) {
        assert_eq!(keep.len(), self.len());
        let mut w = 0;
        for r in 0..self.len() {
            if keep[r] {
                self.pos.swap(w, r);
                self.rot.swap(w, r);
                self.scale.swap(w, r);
                self.opacity.swap(w, r);
                self.sh_dc.swap(w, r);
                self.sh1.swap(w, r);
                w += 1;
            }
        }
        self.pos.truncate(w);
        self.rot.truncate(w);
        self.scale.truncate(w);
        self.opacity.truncate(w);
        self.sh_dc.truncate(w);
        self.sh1.truncate(w);
    }

    /// 3D axis ratio of Gaussian `i`: max σ / min σ — the classifier input
    /// for the paper's smooth/spiky split (Sec. III-A uses the projected 2D
    /// ratio; this is the scene-space analogue used by the preprocessing
    /// core's quick classification).
    pub fn axis_ratio3d(&self, i: usize) -> f32 {
        let s = self.scale[i];
        let max = s.x.max(s.y).max(s.z);
        let min = s.x.min(s.y).min(s.z).max(1e-9);
        max / min
    }

    /// Bounding radius (3σ of the largest axis).
    pub fn bounding_radius(&self, i: usize) -> f32 {
        let s = self.scale[i];
        3.0 * s.x.max(s.y).max(s.z)
    }

    /// Evaluate view-dependent color for Gaussian `i` seen from direction
    /// `dir` (unit, camera→gaussian). SH degree 1.
    pub fn eval_color(&self, i: usize, dir: Vec3) -> [f32; 3] {
        // Real-valued SH basis: Y00 = 0.2820948, Y1{-1,0,1} ∝ (y, z, x).
        const C0: f32 = 0.282_094_8;
        const C1: f32 = 0.488_602_5;
        let dc = self.sh_dc[i];
        let sh1 = self.sh1[i];
        let mut rgb = [0.0f32; 3];
        for ch in 0..3 {
            let v = C0 * dc[ch]
                + C1 * (-dir.y * sh1[ch][0] + dir.z * sh1[ch][1] - dir.x * sh1[ch][2]);
            // 3DGS adds 0.5 and clamps at rasterization time.
            rgb[ch] = (v + 0.5).max(0.0);
        }
        rgb
    }

    /// Scene axis-aligned bounds (min, max).
    pub fn bounds(&self) -> (Vec3, Vec3) {
        let mut lo = v3(f32::INFINITY, f32::INFINITY, f32::INFINITY);
        let mut hi = v3(f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY);
        for (p, s) in self.pos.iter().zip(&self.scale) {
            let r = 3.0 * s.x.max(s.y).max(s.z);
            lo.x = lo.x.min(p.x - r);
            lo.y = lo.y.min(p.y - r);
            lo.z = lo.z.min(p.z - r);
            hi.x = hi.x.max(p.x + r);
            hi.y = hi.y.max(p.y + r);
            hi.z = hi.z.max(p.z + r);
        }
        (lo, hi)
    }

    /// Fraction of Gaussians classified spiky at the given threshold.
    pub fn spiky_fraction(&self, axis_ratio_threshold: f32) -> f32 {
        if self.is_empty() {
            return 0.0;
        }
        let n = (0..self.len())
            .filter(|&i| self.axis_ratio3d(i) >= axis_ratio_threshold)
            .count();
        n as f32 / self.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::linalg::v3;

    fn tiny_scene() -> Scene {
        let mut s = Scene::with_capacity(3, "test");
        s.push(
            v3(0.0, 0.0, 5.0),
            Quat::IDENTITY,
            v3(1.0, 1.0, 1.0),
            0.9,
            [1.0, 0.0, 0.0],
            [[0.0; 3]; 3],
        );
        s.push(
            v3(1.0, 0.0, 6.0),
            Quat::IDENTITY,
            v3(0.1, 0.5, 0.1),
            0.5,
            [0.0, 1.0, 0.0],
            [[0.0; 3]; 3],
        );
        s.push(
            v3(-1.0, 2.0, 7.0),
            Quat::IDENTITY,
            v3(2.0, 0.2, 0.2),
            0.2,
            [0.0, 0.0, 1.0],
            [[0.0; 3]; 3],
        );
        s
    }

    #[test]
    fn push_and_len() {
        let s = tiny_scene();
        assert_eq!(s.len(), 3);
        assert!(!s.is_empty());
    }

    #[test]
    fn axis_ratio() {
        let s = tiny_scene();
        assert!((s.axis_ratio3d(0) - 1.0).abs() < 1e-6);
        assert!((s.axis_ratio3d(1) - 5.0).abs() < 1e-6);
        assert!((s.axis_ratio3d(2) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn spiky_fraction_threshold3() {
        let s = tiny_scene();
        // Gaussian 0 smooth (ratio 1), 1 & 2 spiky (5, 10).
        assert!((s.spiky_fraction(3.0) - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn retain_keeps_order() {
        let mut s = tiny_scene();
        s.retain_indices(&[true, false, true]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.sh_dc[0], [1.0, 0.0, 0.0]);
        assert_eq!(s.sh_dc[1], [0.0, 0.0, 1.0]);
    }

    #[test]
    fn bounds_cover_all() {
        let s = tiny_scene();
        let (lo, hi) = s.bounds();
        assert!(lo.x <= -1.0 - 3.0 * 2.0);
        assert!(hi.z >= 7.0);
        assert!(lo.z <= 5.0 - 3.0);
    }

    #[test]
    fn color_dc_only() {
        let s = tiny_scene();
        let c = s.eval_color(0, v3(0.0, 0.0, 1.0));
        assert!((c[0] - (0.282_094_8 + 0.5)).abs() < 1e-5);
        assert!((c[1] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn color_view_dependence() {
        let mut s = tiny_scene();
        s.sh1[0][0] = [0.0, 0.0, 1.0]; // red varies with -x of dir
        let c_px = s.eval_color(0, v3(1.0, 0.0, 0.0));
        let c_nx = s.eval_color(0, v3(-1.0, 0.0, 0.0));
        assert!(c_px[0] < c_nx[0]);
    }

    #[test]
    fn bounding_radius_is_3sigma() {
        let s = tiny_scene();
        assert!((s.bounding_radius(2) - 6.0).abs() < 1e-6);
    }
}
