//! Contribution-based pruning ("Trimming the fat" [21]).
//!
//! The paper produces compact models by pruning Gaussians with negligible
//! rendering contribution, then fine-tuning for 3K iterations. We reproduce
//! the pruning signal exactly — accumulated blended weight Σ T·α over a set
//! of training views — and approximate the fine-tune with an opacity
//! renormalization that compensates lost transmittance (the part of
//! fine-tuning that matters for downstream workload shape).

use super::gaussian::Scene;
use crate::camera::Camera;
use crate::render::raster::{render_masked, AllOnes, RenderOptions};

/// Pruning configuration.
#[derive(Clone, Copy, Debug)]
pub struct PruneConfig {
    /// Fraction of Gaussians to remove (paper's technique prunes ~40–60%
    /// with little quality loss on trained scenes).
    pub prune_fraction: f32,
    /// Opacity boost factor applied as the fine-tune stand-in.
    pub finetune_opacity_gain: f32,
}

impl Default for PruneConfig {
    fn default() -> Self {
        PruneConfig {
            prune_fraction: 0.4,
            finetune_opacity_gain: 1.06,
        }
    }
}

/// Result of a pruning pass.
#[derive(Clone, Debug)]
pub struct PruneReport {
    pub before: usize,
    pub after: usize,
    /// Contribution score threshold used.
    pub threshold: f32,
}

/// Accumulate contribution scores over `views` and prune the lowest
/// `prune_fraction`. Returns the report; `scene` is modified in place.
pub fn prune(scene: &mut Scene, views: &[Camera], cfg: &PruneConfig) -> PruneReport {
    assert!(!views.is_empty(), "need at least one scoring view");
    let mut scores = vec![0.0f32; scene.len()];
    let opts = RenderOptions::default();
    for cam in views {
        render_masked(scene, cam, &opts, &mut AllOnes, Some(&mut scores));
    }

    let mut order: Vec<u32> = (0..scene.len() as u32).collect();
    order.sort_by(|&a, &b| {
        scores[a as usize]
            .partial_cmp(&scores[b as usize])
            .unwrap()
    });
    let cut = ((scene.len() as f32) * cfg.prune_fraction) as usize;
    let threshold = if cut > 0 && cut < order.len() {
        scores[order[cut] as usize]
    } else {
        0.0
    };
    let mut keep = vec![true; scene.len()];
    for &i in order.iter().take(cut) {
        keep[i as usize] = false;
    }
    let before = scene.len();
    scene.retain_indices(&keep);

    // Fine-tune stand-in: gently raise opacity to recover the removed haze's
    // aggregate transmittance.
    for o in &mut scene.opacity {
        *o = (*o * cfg.finetune_opacity_gain).min(0.999);
    }

    PruneReport {
        before,
        after: scene.len(),
        threshold,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{orbit_path, Intrinsics};
    use crate::numeric::linalg::v3;
    use crate::render::metrics::psnr;
    use crate::render::raster::render;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn views() -> Vec<Camera> {
        orbit_path(
            Intrinsics::from_fov(96, 96, 1.2),
            v3(0.0, 0.5, 0.0),
            12.0,
            3.0,
            4,
        )
    }

    #[test]
    fn prunes_requested_fraction() {
        let mut scene = generate_scaled(&preset("truck"), 0.02);
        let n0 = scene.len();
        let rep = prune(&mut scene, &views(), &PruneConfig::default());
        assert_eq!(rep.before, n0);
        let removed = n0 - rep.after;
        let expect = (n0 as f32 * 0.4) as usize;
        assert!(
            removed.abs_diff(expect) <= 1,
            "removed {removed}, expected ~{expect}"
        );
    }

    #[test]
    fn quality_loss_is_modest() {
        // Pruned render vs baseline render of the same scene — the Table I
        // "Prun." row mechanism. Low-contribution Gaussians go first, so the
        // image should stay close.
        let scene = generate_scaled(&preset("playroom"), 0.03);
        let cam = &views()[0];
        let gt = render(&scene, cam, &RenderOptions::default()).image;
        let mut pruned_scene = scene.clone();
        prune(&mut pruned_scene, &views(), &PruneConfig::default());
        let pr = render(&pruned_scene, cam, &RenderOptions::default()).image;
        let p = psnr(&gt, &pr);
        assert!(p > 24.0, "pruning destroyed the image: PSNR {p}");
    }

    #[test]
    fn pruning_reduces_workload() {
        let scene = generate_scaled(&preset("garden"), 0.02);
        let cam = &views()[0];
        let base = render(&scene, cam, &RenderOptions::default()).stats;
        let mut pruned_scene = scene.clone();
        prune(&mut pruned_scene, &views(), &PruneConfig::default());
        let after = render(&pruned_scene, cam, &RenderOptions::default()).stats;
        assert!(after.tile_pairs < base.tile_pairs);
        assert!(after.pairs_tested < base.pairs_tested);
    }

    #[test]
    fn zero_fraction_is_identity() {
        let mut scene = generate_scaled(&preset("truck"), 0.01);
        let n = scene.len();
        let cfg = PruneConfig {
            prune_fraction: 0.0,
            finetune_opacity_gain: 1.0,
        };
        prune(&mut scene, &views(), &cfg);
        assert_eq!(scene.len(), n);
    }
}
