//! Contribution-based pruning ("Trimming the fat" [21]).
//!
//! The paper produces compact models by pruning Gaussians with negligible
//! rendering contribution, then fine-tuning for 3K iterations. We reproduce
//! the pruning signal exactly — accumulated blended weight Σ T·α over a set
//! of training views — and approximate the fine-tune with an opacity
//! renormalization that compensates lost transmittance (the part of
//! fine-tuning that matters for downstream workload shape).
//!
//! **Determinism contract.** The scoring pass ([`score_views`]) builds a
//! [`FramePlan`] per view, then drains one flattened (view × tile) work
//! queue through the pool's atomic work-stealing counter — any worker can
//! score any tile of any view, so a few views on many cores still saturate
//! the machine (no views-first budget split to strand workers). The
//! reduction order stays fixed regardless of who computed what: per-tile
//! partials fold into a private per-view score buffer in ascending tile
//! index, and per-view buffers fold in ascending view index. The
//! accumulated scores — and therefore the pruning decision — are
//! bit-identical for any worker count.

use super::gaussian::Scene;
use crate::camera::Camera;
use crate::render::plan::FramePlan;
use crate::render::pyramid::GateConfig;
use crate::render::raster::{RenderOptions, RenderStats, VanillaMasks};
use crate::util::json::{jnum, Json};
use crate::util::pool;

/// Pruning configuration.
#[derive(Clone, Copy, Debug)]
pub struct PruneConfig {
    /// Fraction of Gaussians to remove (paper's technique prunes ~40–60%
    /// with little quality loss on trained scenes).
    pub prune_fraction: f32,
    /// Opacity boost factor applied as the fine-tune stand-in.
    pub finetune_opacity_gain: f32,
    /// Worker threads for the contribution-scoring pass (0 = auto, 1 =
    /// sequential). All tiles of all scoring views drain through one
    /// flattened work-stealing queue; scores are bit-identical for any
    /// value.
    pub workers: usize,
}

impl Default for PruneConfig {
    fn default() -> Self {
        PruneConfig {
            prune_fraction: 0.4,
            finetune_opacity_gain: 1.06,
            workers: 1,
        }
    }
}

/// Result of a pruning pass.
#[derive(Clone, Debug)]
pub struct PruneReport {
    /// Gaussian count before pruning.
    pub before: usize,
    /// Gaussian count after pruning.
    pub after: usize,
    /// Contribution score threshold used.
    pub threshold: f32,
    /// Number of scoring views accumulated.
    pub views: usize,
    /// Rasterizer workload counters absorbed across all scoring views.
    pub stats: RenderStats,
}

impl PruneReport {
    /// Provenance serialization: before/after counts, the score threshold,
    /// scoring-view count, and the pairs-per-pixel the scoring pass
    /// tested. `coordinator::report::Report::set_prune_provenance` embeds
    /// this in every report produced from a pruned session, so a result is
    /// never divorced from the pruning pass that shaped its scene.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.insert("before", jnum(self.before as f64));
        o.insert("after", jnum(self.after as f64));
        o.insert("threshold", jnum(self.threshold as f64));
        o.insert("views", jnum(self.views as f64));
        o.insert("pairs_per_px_tested", jnum(self.stats.per_pixel_tested()));
        Json::Obj(o)
    }
}

/// Accumulate per-Gaussian contribution scores (Σ T·α) over `views`,
/// fanning the scoring work across `workers` threads (0 = auto, 1 =
/// sequential). Returns the score array (indexed by Gaussian id) and the
/// [`RenderStats`] absorbed across all scoring views.
///
/// A [`FramePlan`] is built per view (projection, binning, and depth sort
/// run once per view, fanned across the pool), then **all tiles of all
/// views** drain through one flattened work queue: a single work-stealing
/// counter hands out `(view, tile)` pairs, so few views on many cores
/// still use every worker — the regime where a views-first budget split
/// would strand most of the machine. Scores are bit-identical for any
/// worker count: tile partials fold into a per-view buffer in ascending
/// tile index, and per-view buffers fold in ascending view index, no
/// matter which worker computed which tile.
///
/// When the caller's `opts.gate` is off, scoring runs under
/// [`GateConfig::on`] anyway: at the default threshold the coarse gate is
/// lossless for Σ T·α (bit-identical scores, verified by test), so the
/// pass skips dead (tile, splat) pairs for free. Caller-configured gates
/// are honored unchanged.
pub fn score_views(
    scene: &Scene,
    views: &[Camera],
    opts: &RenderOptions,
    workers: usize,
) -> (Vec<f32>, RenderStats) {
    assert!(!views.is_empty(), "need at least one scoring view");
    let total_workers = pool::resolve_workers(workers);

    // The scoring pass always runs the coarse-to-fine contribution gate
    // (`render::pyramid`): at the default threshold — exactly the blend
    // loop's α < 1/255 floor — a rejected (tile, splat) or (quadrant,
    // splat) pair contributes 0 to every pixel AND 0 to every Σ T·α
    // partial, so the scores are bit-identical to ungated scoring while
    // whole tiles of dead work are skipped before mask generation. A
    // caller that configured its own gate keeps those thresholds (a lossy
    // gate is then their scoring contract, as it is their render contract).
    let opts = if opts.gate.enabled {
        *opts
    } else {
        RenderOptions {
            gate: GateConfig::on(),
            ..*opts
        }
    };
    let opts = &opts;

    // Stage 1: one FramePlan per view (frame preparation fans over views).
    let plans: Vec<FramePlan> =
        pool::map_indexed(views.len(), total_workers.min(views.len()), |v| {
            FramePlan::build(scene, &views[v], opts)
        });

    // Stage 2: flatten (view × tile) into one queue, view-major so the
    // sequential (workers = 1) drain visits tiles in the reduce order.
    // Tiles complete out of order, so every tile's partial is retained
    // until the stage-3 fold — O(Σ tile-list lengths) f32s, the same
    // order of memory as the plans' tile lists themselves.
    let items: Vec<(u32, u32)> = plans
        .iter()
        .enumerate()
        .flat_map(|(v, p)| (0..p.num_tiles() as u32).map(move |t| (v as u32, t)))
        .collect();
    let partials: Vec<(Vec<f32>, RenderStats)> =
        pool::map_indexed(items.len(), total_workers, |i| {
            let (v, t) = items[i];
            plans[v as usize].score_tile(t as usize, &VanillaMasks)
        });

    // Stage 3: fold view-major then tile-major — the fixed reduce order
    // that makes the whole pass order-deterministic.
    let mut scores = vec![0.0f32; scene.len()];
    let mut stats = RenderStats::default();
    let mut k = 0;
    for plan in &plans {
        let mut view_scores = vec![0.0f32; scene.len()];
        let mut view_stats = plan.frame_stats();
        for t in 0..plan.num_tiles() {
            let (partial, tile_stats) = &partials[k];
            k += 1;
            plan.fold_scores(t, partial, &mut view_scores);
            view_stats.absorb(tile_stats);
        }
        for (acc, s) in scores.iter_mut().zip(&view_scores) {
            *acc += *s;
        }
        stats.absorb(&view_stats);
    }
    (scores, stats)
}

/// Ascending contribution order (lowest score first — the prune front).
///
/// Sorts with [`f32::total_cmp`], so degenerate scores can never panic the
/// pass: a NaN score (e.g. from a Gaussian with non-finite parameters)
/// orders after +∞ and is treated as highest contribution — kept, never
/// silently pruned.
fn contribution_order(scores: &[f32]) -> Vec<u32> {
    let mut order: Vec<u32> = (0..scores.len() as u32).collect();
    order.sort_by(|&a, &b| scores[a as usize].total_cmp(&scores[b as usize]));
    order
}

/// Accumulate contribution scores over `views` and prune the lowest
/// `prune_fraction`. Returns the report; `scene` is modified in place.
/// Scoring fans across `cfg.workers` threads with a bit-deterministic
/// reduction, so the pruning decision is identical for any worker count.
///
/// # Examples
///
/// ```
/// use flicker::camera::{orbit_path, Intrinsics};
/// use flicker::numeric::linalg::v3;
/// use flicker::scene::pruning::{prune, PruneConfig};
/// use flicker::scene::synthetic::{generate_scaled, preset};
///
/// let mut scene = generate_scaled(&preset("truck"), 0.01);
/// let views = orbit_path(
///     Intrinsics::from_fov(64, 64, 1.2),
///     v3(0.0, 0.5, 0.0),
///     12.0,
///     3.0,
///     2,
/// );
/// let before = scene.len();
/// let report = prune(&mut scene, &views, &PruneConfig::default());
/// assert_eq!(report.before, before);
/// assert_eq!(report.after, scene.len());
/// assert!(scene.len() < before, "the low-contribution tail is removed");
/// ```
pub fn prune(scene: &mut Scene, views: &[Camera], cfg: &PruneConfig) -> PruneReport {
    let opts = RenderOptions::default();
    let (scores, stats) = score_views(scene, views, &opts, cfg.workers);

    let order = contribution_order(&scores);
    let cut = ((scene.len() as f32) * cfg.prune_fraction) as usize;
    let threshold = if cut > 0 && cut < order.len() {
        scores[order[cut] as usize]
    } else {
        0.0
    };
    let mut keep = vec![true; scene.len()];
    for &i in order.iter().take(cut) {
        keep[i as usize] = false;
    }
    let before = scene.len();
    scene.retain_indices(&keep);

    // Fine-tune stand-in: gently raise opacity to recover the removed haze's
    // aggregate transmittance.
    for o in &mut scene.opacity {
        *o = (*o * cfg.finetune_opacity_gain).min(0.999);
    }

    PruneReport {
        before,
        after: scene.len(),
        threshold,
        views: views.len(),
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{orbit_path, Intrinsics};
    use crate::numeric::linalg::{v3, Quat};
    use crate::render::metrics::psnr;
    use crate::render::raster::render;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn views() -> Vec<Camera> {
        orbit_path(
            Intrinsics::from_fov(96, 96, 1.2),
            v3(0.0, 0.5, 0.0),
            12.0,
            3.0,
            4,
        )
    }

    #[test]
    fn prunes_requested_fraction() {
        let mut scene = generate_scaled(&preset("truck"), 0.02);
        let n0 = scene.len();
        let rep = prune(&mut scene, &views(), &PruneConfig::default());
        assert_eq!(rep.before, n0);
        let removed = n0 - rep.after;
        let expect = (n0 as f32 * 0.4) as usize;
        assert!(
            removed.abs_diff(expect) <= 1,
            "removed {removed}, expected ~{expect}"
        );
    }

    #[test]
    fn quality_loss_is_modest() {
        // Pruned render vs baseline render of the same scene — the Table I
        // "Prun." row mechanism. Low-contribution Gaussians go first, so the
        // image should stay close.
        let scene = generate_scaled(&preset("playroom"), 0.03);
        let cam = &views()[0];
        let gt = render(&scene, cam, &RenderOptions::default()).image;
        let mut pruned_scene = scene.clone();
        prune(&mut pruned_scene, &views(), &PruneConfig::default());
        let pr = render(&pruned_scene, cam, &RenderOptions::default()).image;
        let p = psnr(&gt, &pr);
        assert!(p > 24.0, "pruning destroyed the image: PSNR {p}");
    }

    #[test]
    fn pruning_reduces_workload() {
        let scene = generate_scaled(&preset("garden"), 0.02);
        let cam = &views()[0];
        let base = render(&scene, cam, &RenderOptions::default()).stats;
        let mut pruned_scene = scene.clone();
        prune(&mut pruned_scene, &views(), &PruneConfig::default());
        let after = render(&pruned_scene, cam, &RenderOptions::default()).stats;
        assert!(after.tile_pairs < base.tile_pairs);
        assert!(after.pairs_tested < base.pairs_tested);
    }

    #[test]
    fn zero_fraction_is_identity() {
        let mut scene = generate_scaled(&preset("truck"), 0.01);
        let n = scene.len();
        let cfg = PruneConfig {
            prune_fraction: 0.0,
            finetune_opacity_gain: 1.0,
            workers: 1,
        };
        prune(&mut scene, &views(), &cfg);
        assert_eq!(scene.len(), n);
    }

    #[test]
    fn prune_is_deterministic_across_workers() {
        let base = generate_scaled(&preset("truck"), 0.02);
        let mut seq = base.clone();
        let mut par = base.clone();
        let rep_seq = prune(&mut seq, &views(), &PruneConfig::default());
        let rep_par = prune(
            &mut par,
            &views(),
            &PruneConfig {
                workers: 4,
                ..Default::default()
            },
        );
        assert_eq!(rep_seq.after, rep_par.after);
        assert_eq!(rep_seq.threshold.to_bits(), rep_par.threshold.to_bits());
        assert_eq!(seq.len(), par.len());
        // The exact same Gaussians must survive.
        for (a, b) in seq.pos.iter().zip(&par.pos) {
            assert_eq!(a.x.to_bits(), b.x.to_bits());
            assert_eq!(a.y.to_bits(), b.y.to_bits());
            assert_eq!(a.z.to_bits(), b.z.to_bits());
        }
    }

    #[test]
    fn scoring_gate_is_bitwise_lossless() {
        // score_views substitutes the coarse gate for gate-off callers;
        // the Σ T·α scores must be bit-identical to ungated scoring (the
        // default threshold is exactly the blend floor), and the gate must
        // actually remove work.
        let scene = generate_scaled(&preset("garden"), 0.02);
        let vs = views();
        let opts = RenderOptions::default();
        assert!(!opts.gate.enabled, "test needs the gate-off default");
        let (scores, stats) = score_views(&scene, &vs, &opts, 1);
        assert!(stats.gate_tile_rejected > 0, "scoring gate never fired");
        // Manually accumulated ungated per-view scores, same fold order.
        let mut reference = vec![0.0f32; scene.len()];
        for cam in &vs {
            let plan = FramePlan::build(&scene, cam, &opts);
            let mut view_scores = vec![0.0f32; scene.len()];
            for t in 0..plan.num_tiles() {
                let (partial, _) = plan.score_tile(t, &VanillaMasks);
                plan.fold_scores(t, &partial, &mut view_scores);
            }
            for (acc, s) in reference.iter_mut().zip(&view_scores) {
                *acc += *s;
            }
        }
        for (i, (a, b)) in scores.iter().zip(&reference).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "score {i}: {a} vs {b}");
        }
    }

    #[test]
    fn scoring_stats_are_surfaced() {
        let mut scene = generate_scaled(&preset("truck"), 0.02);
        let rep = prune(&mut scene, &views(), &PruneConfig::default());
        assert_eq!(rep.views, 4);
        // Four 96×96 scoring views absorbed via RenderStats::absorb.
        assert_eq!(rep.stats.pixels, 4 * 96 * 96);
        assert!(rep.stats.pairs_blended > 0);
        assert!(rep.stats.splats > 0);
    }

    #[test]
    fn prune_report_serializes_provenance() {
        let mut scene = generate_scaled(&preset("truck"), 0.01);
        let rep = prune(&mut scene, &views(), &PruneConfig::default());
        let j = rep.to_json();
        assert_eq!(j.at(&["before"]).and_then(Json::as_f64), Some(rep.before as f64));
        assert_eq!(j.at(&["after"]).and_then(Json::as_f64), Some(rep.after as f64));
        assert_eq!(j.at(&["views"]).and_then(Json::as_f64), Some(4.0));
        assert!(j.at(&["pairs_per_px_tested"]).and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn nan_scores_do_not_panic_the_sort() {
        // Regression: the score sort used partial_cmp().unwrap(), which
        // panics on NaN. total_cmp gives NaN a fixed position instead
        // (after +inf — treated as highest contribution).
        let order = contribution_order(&[1.0, f32::NAN, 0.5, 0.0]);
        assert_eq!(order, vec![3, 2, 0, 1]);
    }

    #[test]
    fn degenerate_gaussians_do_not_panic_prune() {
        // A NaN-opacity Gaussian and a zero-opacity Gaussian must flow
        // through scoring + sorting without panicking. `Scene::push`
        // debug-asserts opacity ∈ [0, 1], so the NaN is injected directly
        // into the SoA field, the way a corrupt .gsz load would surface it.
        let mut scene = generate_scaled(&preset("truck"), 0.01);
        let nan_idx = scene.push(
            v3(0.0, 0.5, 0.0),
            Quat::IDENTITY,
            v3(0.5, 0.5, 0.5),
            0.9,
            [1.0, 1.0, 1.0],
            [[0.0; 3]; 3],
        );
        scene.opacity[nan_idx] = f32::NAN;
        scene.push(
            v3(0.5, 0.5, 0.0),
            Quat::IDENTITY,
            v3(0.5, 0.5, 0.5),
            0.0,
            [1.0, 1.0, 1.0],
            [[0.0; 3]; 3],
        );
        let n0 = scene.len();
        let rep = prune(&mut scene, &views(), &PruneConfig::default());
        assert_eq!(rep.before, n0);
        assert!(rep.after < n0, "pruning still removes the low tail");
        assert_eq!(scene.len(), rep.after);
    }
}
