//! Procedural synthetic scene generation — the dataset substitute.
//!
//! The paper evaluates on eight real trained-3DGS scenes (2× Tanks&Temples,
//! 4× Mip-NeRF360 outdoor, 2× Deep Blending indoor). We do not have those
//! assets, so we generate Gaussian clouds whose *statistics* match what the
//! experiments depend on: Gaussian count, spiky/smooth axis-ratio mix,
//! opacity distribution, scale distribution, and spatial clustering (objects
//! on a ground plane for outdoor scenes; room-bounded layouts for indoor).
//! Ground truth for quality metrics is the full-FP32 vanilla render of the
//! same scene, so PSNR/SSIM deltas measure exactly what the paper's Table I
//! measures: degradation introduced by pruning/CAT relative to the baseline
//! model.

use super::gaussian::Scene;
use crate::numeric::linalg::{v3, Quat, Vec3};
use crate::util::rng::Pcg32;

/// Scene category, mirroring the paper's three dataset sources.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SceneKind {
    /// Large-scale outdoor capture (Tanks & Temples): one dominant object.
    OutdoorObject,
    /// Unbounded outdoor (Mip-NeRF360): object + wide background shell.
    Outdoor360,
    /// Indoor (Deep Blending): room box with furniture blobs.
    Indoor,
}

/// Generation parameters for one synthetic scene.
#[derive(Clone, Debug)]
pub struct ScenePreset {
    /// Preset name ("garden", "truck", …).
    pub name: &'static str,
    /// Scene archetype driving the generator.
    pub kind: SceneKind,
    /// Gaussian count at "30K-iteration" quality (pre-pruning).
    pub count: usize,
    /// Target fraction of spiky (axis ratio ≥ 3) Gaussians.
    pub spiky_frac: f32,
    /// Log-normal μ of the base scale (world units).
    pub scale_mu: f32,
    /// Generation seed (fixed per preset for reproducibility).
    pub seed: u64,
}

/// The eight evaluation scenes (names mirror the real datasets').
pub fn presets() -> Vec<ScenePreset> {
    vec![
        // Tanks & Temples (2 outdoor scenes)
        ScenePreset { name: "truck", kind: SceneKind::OutdoorObject, count: 60_000, spiky_frac: 0.47, scale_mu: -3.4, seed: 1 },
        ScenePreset { name: "train", kind: SceneKind::OutdoorObject, count: 52_000, spiky_frac: 0.50, scale_mu: -3.3, seed: 2 },
        // Mip-NeRF360 outdoor (4 scenes)
        ScenePreset { name: "bicycle", kind: SceneKind::Outdoor360, count: 90_000, spiky_frac: 0.55, scale_mu: -3.6, seed: 3 },
        ScenePreset { name: "garden", kind: SceneKind::Outdoor360, count: 85_000, spiky_frac: 0.57, scale_mu: -3.7, seed: 4 },
        ScenePreset { name: "stump", kind: SceneKind::Outdoor360, count: 75_000, spiky_frac: 0.52, scale_mu: -3.5, seed: 5 },
        ScenePreset { name: "flowers", kind: SceneKind::Outdoor360, count: 80_000, spiky_frac: 0.58, scale_mu: -3.6, seed: 6 },
        // Deep Blending indoor (2 scenes)
        ScenePreset { name: "playroom", kind: SceneKind::Indoor, count: 45_000, spiky_frac: 0.40, scale_mu: -3.2, seed: 7 },
        ScenePreset { name: "drjohnson", kind: SceneKind::Indoor, count: 55_000, spiky_frac: 0.42, scale_mu: -3.2, seed: 8 },
    ]
}

/// Look up a preset by name (panics on unknown name — callers validate).
pub fn preset(name: &str) -> ScenePreset {
    presets()
        .into_iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("unknown scene '{name}'; known: {:?}",
            presets().iter().map(|p| p.name).collect::<Vec<_>>()))
}

/// Scale every preset's Gaussian count (CI runs use scale < 1).
pub fn generate_scaled(p: &ScenePreset, count_scale: f32) -> Scene {
    let mut p = p.clone();
    p.count = ((p.count as f32 * count_scale) as usize).max(100);
    generate(&p)
}

/// Generate the scene for a preset.
pub fn generate(p: &ScenePreset) -> Scene {
    let mut rng = Pcg32::new(0xF11C_E200 ^ p.seed);
    let mut scene = Scene::with_capacity(p.count, p.name);

    // Spatial layout: a set of anchor "surfaces" Gaussians cluster around.
    let anchors = layout_anchors(p, &mut rng);

    while scene.len() < p.count {
        let a = rng.pick(&anchors).clone();
        let (pos, normal) = a.sample_point(&mut rng);
        let spiky = rng.chance(p.spiky_frac);

        // Scale: log-normal base; spiky Gaussians stretch one axis.
        let base = rng.lognormal(p.scale_mu, 0.55) * a.scale_boost;
        let scale = if spiky {
            // ratio in [3, 12): elongated splinter (edges, thin structures).
            let ratio = rng.range_f32(3.0, 12.0);
            v3(base * ratio, base, base * rng.range_f32(0.5, 1.5))
        } else {
            // ratio in [1, 3): blobby surface element.
            v3(
                base * rng.range_f32(1.0, 2.8),
                base,
                base * rng.range_f32(0.8, 1.6),
            )
        };

        // Orientation: mostly tangent to the anchor surface (Gaussians in
        // trained scenes flatten against geometry), with jitter.
        let rot = orient_tangent(normal, &mut rng);

        // Opacity: trained-3DGS opacities are strongly bimodal: many near 1
        // (surface), a haze of low-opacity floaters.
        let opacity = if rng.chance(0.65) {
            rng.range_f32(0.55, 0.995)
        } else {
            rng.range_f32(0.02, 0.35)
        };

        // Color: per-anchor base hue + per-Gaussian variation; SH1 gives
        // mild view dependence (specular-ish).
        let mut sh_dc = [0.0f32; 3];
        for ch in 0..3 {
            sh_dc[ch] = (a.color[ch] + rng.normal_ms(0.0, 0.25)).clamp(-0.8, 2.5);
        }
        let mut sh1 = [[0.0f32; 3]; 3];
        for ch in 0..3 {
            for b in 0..3 {
                sh1[ch][b] = rng.normal_ms(0.0, 0.08);
            }
        }

        scene.push(pos, rot, scale, opacity, sh_dc, sh1);
    }
    scene
}

/// A surface patch Gaussians cluster on.
#[derive(Clone, Debug)]
struct Anchor {
    center: Vec3,
    /// Half-extents of the patch.
    extent: Vec3,
    /// Surface normal (Gaussians flatten along it).
    normal: Vec3,
    color: [f32; 3],
    scale_boost: f32,
    /// Sampling weight ∝ area.
    weight: f32,
}

impl Anchor {
    fn sample_point(&self, rng: &mut Pcg32) -> (Vec3, Vec3) {
        let jitter = 0.15 * self.extent.y.min(self.extent.x);
        let p = v3(
            self.center.x + rng.range_f32(-1.0, 1.0) * self.extent.x,
            self.center.y + rng.range_f32(-1.0, 1.0) * self.extent.y,
            self.center.z + rng.range_f32(-1.0, 1.0) * self.extent.z,
        ) + self.normal * rng.normal_ms(0.0, jitter.max(0.01));
        (p, self.normal)
    }
}

fn layout_anchors(p: &ScenePreset, rng: &mut Pcg32) -> Vec<Anchor> {
    let mut anchors = Vec::new();
    let up = v3(0.0, 1.0, 0.0);
    match p.kind {
        SceneKind::OutdoorObject | SceneKind::Outdoor360 => {
            // Ground plane.
            let ground_r = if p.kind == SceneKind::Outdoor360 { 14.0 } else { 9.0 };
            anchors.push(Anchor {
                center: v3(0.0, 0.0, 0.0),
                extent: v3(ground_r, 0.02, ground_r),
                normal: up,
                color: [0.25, 0.45, 0.18], // grass/dirt
                scale_boost: 1.6,
                weight: 2.5,
            });
            // Central object: a cluster of boxes/blobs.
            let nblobs = rng.range_u32(6, 12);
            for _ in 0..nblobs {
                let c = v3(
                    rng.normal_ms(0.0, 1.2),
                    rng.range_f32(0.2, 2.2),
                    rng.normal_ms(0.0, 1.2),
                );
                let n = v3(rng.normal(), rng.normal() * 0.3 + 0.5, rng.normal()).normalized();
                anchors.push(Anchor {
                    center: c,
                    extent: v3(
                        rng.range_f32(0.3, 1.2),
                        rng.range_f32(0.3, 1.0),
                        rng.range_f32(0.3, 1.2),
                    ),
                    normal: n,
                    color: [
                        rng.range_f32(0.1, 1.2),
                        rng.range_f32(0.1, 1.2),
                        rng.range_f32(0.1, 1.2),
                    ],
                    scale_boost: 1.0,
                    weight: 1.0,
                });
            }
            if p.kind == SceneKind::Outdoor360 {
                // Background shell: distant, large, fuzzy Gaussians (sky,
                // far vegetation) — these dominate tile lists at the edges.
                for k in 0..8 {
                    let theta = k as f32 / 8.0 * std::f32::consts::TAU;
                    anchors.push(Anchor {
                        center: v3(18.0 * theta.cos(), 4.0, 18.0 * theta.sin()),
                        extent: v3(5.0, 4.0, 5.0),
                        normal: v3(-theta.cos(), 0.0, -theta.sin()),
                        color: [0.4, 0.55, 0.9],
                        scale_boost: 4.0,
                        weight: 0.6,
                    });
                }
            }
        }
        SceneKind::Indoor => {
            // Room: floor, ceiling, 4 walls.
            let (hx, hy, hz) = (5.0, 2.6, 4.0);
            let faces: [(Vec3, Vec3, Vec3); 6] = [
                (v3(0.0, 0.0, 0.0), v3(hx, 0.02, hz), up),
                (v3(0.0, 2.0 * hy, 0.0), v3(hx, 0.02, hz), up * -1.0),
                (v3(-hx, hy, 0.0), v3(0.02, hy, hz), v3(1.0, 0.0, 0.0)),
                (v3(hx, hy, 0.0), v3(0.02, hy, hz), v3(-1.0, 0.0, 0.0)),
                (v3(0.0, hy, -hz), v3(hx, hy, 0.02), v3(0.0, 0.0, 1.0)),
                (v3(0.0, hy, hz), v3(hx, hy, 0.02), v3(0.0, 0.0, -1.0)),
            ];
            for (c, e, n) in faces {
                anchors.push(Anchor {
                    center: c,
                    extent: e,
                    normal: n,
                    color: [
                        rng.range_f32(0.5, 1.1),
                        rng.range_f32(0.45, 1.0),
                        rng.range_f32(0.4, 0.95),
                    ],
                    scale_boost: 1.8,
                    weight: 1.2,
                });
            }
            // Furniture blobs.
            for _ in 0..rng.range_u32(5, 9) {
                anchors.push(Anchor {
                    center: v3(
                        rng.range_f32(-hx * 0.7, hx * 0.7),
                        rng.range_f32(0.3, 1.4),
                        rng.range_f32(-hz * 0.7, hz * 0.7),
                    ),
                    extent: v3(
                        rng.range_f32(0.3, 0.9),
                        rng.range_f32(0.3, 0.8),
                        rng.range_f32(0.3, 0.9),
                    ),
                    normal: v3(rng.normal(), rng.normal(), rng.normal()).normalized(),
                    color: [
                        rng.range_f32(0.1, 1.2),
                        rng.range_f32(0.1, 1.2),
                        rng.range_f32(0.1, 1.2),
                    ],
                    scale_boost: 0.9,
                    weight: 1.0,
                });
            }
        }
    }
    // Expand by weight so `pick` approximates weighted sampling.
    let mut weighted = Vec::new();
    for a in anchors {
        let copies = (a.weight * 4.0).round().max(1.0) as usize;
        for _ in 0..copies {
            weighted.push(a.clone());
        }
    }
    weighted
}

/// Random rotation whose local z-axis roughly aligns with the surface normal
/// (so the smallest Gaussian axis points off-surface, as in trained scenes).
fn orient_tangent(normal: Vec3, rng: &mut Pcg32) -> Quat {
    // Rotation taking +z to `normal`, then random spin about the normal.
    let z = v3(0.0, 0.0, 1.0);
    let n = normal.normalized();
    let axis = z.cross(n);
    let dot = z.dot(n).clamp(-1.0, 1.0);
    let align = if axis.norm() < 1e-6 {
        if dot > 0.0 {
            Quat::IDENTITY
        } else {
            Quat::from_axis_angle(v3(1.0, 0.0, 0.0), std::f32::consts::PI)
        }
    } else {
        Quat::from_axis_angle(axis, dot.acos())
    };
    let spin = Quat::from_axis_angle(n, rng.range_f32(0.0, std::f32::consts::TAU));
    // Jitter to avoid perfectly coplanar splats.
    let jitter = Quat::from_axis_angle(
        v3(rng.normal(), rng.normal(), rng.normal()),
        rng.normal_ms(0.0, 0.15),
    );
    mul_quat(mul_quat(spin, align), jitter).normalized()
}

fn mul_quat(a: Quat, b: Quat) -> Quat {
    Quat {
        w: a.w * b.w - a.x * b.x - a.y * b.y - a.z * b.z,
        x: a.w * b.x + a.x * b.w + a.y * b.z - a.z * b.y,
        y: a.w * b.y - a.x * b.z + a.y * b.w + a.z * b.x,
        z: a.w * b.z + a.x * b.y - a.y * b.x + a.z * b.w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_cover_eight_scenes() {
        let ps = presets();
        assert_eq!(ps.len(), 8);
        let names: Vec<_> = ps.iter().map(|p| p.name).collect();
        assert!(names.contains(&"garden"));
        assert!(names.contains(&"truck"));
        assert!(names.contains(&"playroom"));
    }

    #[test]
    fn generation_is_deterministic() {
        let p = preset("garden");
        let a = generate_scaled(&p, 0.02);
        let b = generate_scaled(&p, 0.02);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.pos[10], b.pos[10]);
        assert_eq!(a.opacity[42], b.opacity[42]);
    }

    #[test]
    fn spiky_fraction_near_target() {
        let p = preset("garden");
        let s = generate_scaled(&p, 0.05);
        let f = s.spiky_fraction(3.0);
        assert!(
            (f - p.spiky_frac).abs() < 0.08,
            "target {} got {f}",
            p.spiky_frac
        );
    }

    #[test]
    fn scales_positive_opacity_in_range() {
        let s = generate_scaled(&preset("truck"), 0.02);
        for i in 0..s.len() {
            let sc = s.scale[i];
            assert!(sc.x > 0.0 && sc.y > 0.0 && sc.z > 0.0);
            assert!((0.0..=1.0).contains(&s.opacity[i]));
        }
    }

    #[test]
    fn indoor_scene_is_bounded() {
        // Check Gaussian *centers* stay room-bounded (bounds() also adds 3σ
        // radii, which a single large spiky splat can inflate arbitrarily).
        let s = generate_scaled(&preset("playroom"), 0.05);
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        let (mut lo_y, mut hi_y) = (f32::INFINITY, f32::NEG_INFINITY);
        for p in &s.pos {
            lo = lo.min(p.x);
            hi = hi.max(p.x);
            lo_y = lo_y.min(p.y);
            hi_y = hi_y.max(p.y);
        }
        assert!(hi - lo < 15.0, "indoor x spread {}", hi - lo);
        assert!(hi_y - lo_y < 10.0, "indoor y spread {}", hi_y - lo_y);
    }

    #[test]
    fn outdoor360_has_background_shell() {
        let s = generate_scaled(&preset("bicycle"), 0.05);
        let far = (0..s.len())
            .filter(|&i| (s.pos[i].x * s.pos[i].x + s.pos[i].z * s.pos[i].z).sqrt() > 10.0)
            .count();
        assert!(far > s.len() / 50, "expected distant background Gaussians");
    }

    #[test]
    fn count_scaling() {
        let p = preset("stump");
        let s = generate_scaled(&p, 0.01);
        assert!(s.len() >= (p.count as f32 * 0.01) as usize);
        assert!(s.len() < p.count / 50);
    }

    #[test]
    fn different_scenes_differ() {
        let a = generate_scaled(&preset("truck"), 0.02);
        let b = generate_scaled(&preset("train"), 0.02);
        assert_ne!(a.pos[0], b.pos[0]);
    }
}
