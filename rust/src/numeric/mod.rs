//! Numeric substrates: software FP16/FP8 (mixed-precision CTU emulation)
//! and the fixed-size linear algebra used by EWA splatting.

pub mod fp16;
pub mod fp8;
pub mod linalg;
