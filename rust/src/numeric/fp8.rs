//! FP8 software emulation: E4M3 (default) and E5M2.
//!
//! The mixed-precision PRTU converts FP16 coordinate deltas to FP8 for the
//! quadratic-form accumulation (paper Sec. IV-C, lines 2–7 of Alg. 1).
//! E4M3 follows the OCP FP8 spec: bias 7, no infinities, 0x7F = NaN,
//! max finite = 448. E5M2 is IEEE-like: bias 15, has infinities, max 57344.

/// FP8 format descriptor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fp8Format {
    /// OCP E4M3: bias 7, no infinities, max finite 448.
    E4M3,
    /// IEEE-like E5M2: bias 15, has infinities, max finite 57344.
    E5M2,
}

impl Fp8Format {
    fn mantissa_bits(self) -> u32 {
        match self {
            Fp8Format::E4M3 => 3,
            Fp8Format::E5M2 => 2,
        }
    }

    fn exp_bits(self) -> u32 {
        match self {
            Fp8Format::E4M3 => 4,
            Fp8Format::E5M2 => 5,
        }
    }

    fn bias(self) -> i32 {
        match self {
            Fp8Format::E4M3 => 7,
            Fp8Format::E5M2 => 15,
        }
    }

    /// Largest finite magnitude.
    pub fn max_finite(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 448.0,
            Fp8Format::E5M2 => 57344.0,
        }
    }

    /// Smallest positive subnormal.
    pub fn min_subnormal(self) -> f32 {
        match self {
            Fp8Format::E4M3 => 2.0f32.powi(-9),  // 2^-6 * 2^-3
            Fp8Format::E5M2 => 2.0f32.powi(-16), // 2^-14 * 2^-2
        }
    }
}

/// Encode f32 → 8-bit code, round-to-nearest-even, saturating at max finite
/// (saturation matches accelerator convert units; E4M3 has no Inf anyway).
pub fn encode(x: f32, fmt: Fp8Format) -> u8 {
    let mb = fmt.mantissa_bits();
    let bias = fmt.bias();
    let sign: u8 = if x.is_sign_negative() { 0x80 } else { 0 };
    if x.is_nan() {
        return match fmt {
            Fp8Format::E4M3 => sign | 0x7F,
            Fp8Format::E5M2 => sign | 0x7E,
        };
    }
    let ax = x.abs();
    if ax == 0.0 {
        return sign;
    }
    if ax >= fmt.max_finite() {
        // Saturate (hardware convert behaviour).
        return match fmt {
            Fp8Format::E4M3 => sign | 0x7E,                  // 448
            Fp8Format::E5M2 => sign | 0x7B,                  // 57344
        };
    }
    let bits = ax.to_bits();
    let e32 = ((bits >> 23) & 0xFF) as i32 - 127;
    let m32 = bits & 0x7F_FFFF;
    let e8 = e32 + bias;
    if e8 >= 1 {
        // Normal.
        let shift = 23 - mb;
        let half = (1u32 << (shift - 1)) - 1 + ((m32 >> shift) & 1);
        let m_r = m32 + half;
        let (e8, m_r) = if m_r & 0x80_0000 != 0 {
            (e8 + 1, 0)
        } else {
            (e8, m_r >> shift)
        };
        let max_exp = (1 << fmt.exp_bits()) - 1;
        // Check E4M3 top-of-range: exp=15 mantissa=7 is NaN, so 448=0x7E is max.
        let code = ((e8 as u32) << mb) | m_r;
        let max_code: u32 = match fmt {
            Fp8Format::E4M3 => 0x7E,
            Fp8Format::E5M2 => 0x7B,
        };
        if e8 > max_exp || code > max_code {
            return sign | max_code as u8;
        }
        sign | code as u8
    } else {
        // Subnormal: value = m * 2^(1-bias-mb).
        let min_sub = fmt.min_subnormal();
        let q = ax / min_sub;
        let m = q.round_ties_even() as u32;
        let max_sub = (1u32 << mb) - 1;
        if m > max_sub {
            // Rounds up into the smallest normal (exponent 1, mantissa 0).
            return sign | (1u8 << mb);
        }
        sign | m as u8
    }
}

/// Decode 8-bit code → f32 (exact).
pub fn decode(code: u8, fmt: Fp8Format) -> f32 {
    let mb = fmt.mantissa_bits();
    let bias = fmt.bias();
    let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((code >> mb) & ((1 << fmt.exp_bits()) - 1)) as i32;
    let m = (code & ((1 << mb) - 1)) as u32;
    match fmt {
        Fp8Format::E4M3 => {
            if e == 0xF && m == 0x7 {
                return f32::NAN * sign;
            }
        }
        Fp8Format::E5M2 => {
            if e == 0x1F {
                return if m == 0 {
                    sign * f32::INFINITY
                } else {
                    f32::NAN
                };
            }
        }
    }
    if e == 0 {
        sign * (m as f32) * fmt.min_subnormal()
    } else {
        let frac = 1.0 + (m as f32) / (1 << mb) as f32;
        sign * frac * 2.0f32.powi(e - bias)
    }
}

/// Round-trip through FP8 (the quantization primitive used by the
/// mixed-precision CAT model and the Pallas kernel emulation).
/// E4M3 uses a direct bit-level rounding (§Perf: the CAT hot loop calls
/// this ~12× per PR; the encode/decode pair was the profile leader).
/// Equivalence with the codec path is asserted by `fast_path_matches_codec`.
#[inline]
pub fn quantize_fp8(x: f32, fmt: Fp8Format) -> f32 {
    match fmt {
        Fp8Format::E4M3 => round_e4m3(x),
        Fp8Format::E5M2 => decode(encode(x, fmt), fmt),
    }
}

/// Branch-light round-to-nearest-even of an f32 to the E4M3 value set,
/// saturating at ±448 (hardware convert semantics).
#[inline]
fn round_e4m3(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let ax = x.abs();
    if ax >= 448.0 {
        return 448.0_f32.copysign(x);
    }
    const MIN_NORMAL: f32 = 0.015625; // 2⁻⁶
    if ax < MIN_NORMAL {
        // Subnormals: multiples of 2⁻⁹; RNE via round_ties_even.
        let q = (ax * 512.0).round_ties_even() * (1.0 / 512.0);
        return q.copysign(x);
    }
    // Normals: RNE the f32 mantissa down to 3 bits; carries propagate into
    // the exponent naturally through the integer add.
    const SHIFT: u32 = 23 - 3;
    let bits = ax.to_bits();
    let half = (1u32 << (SHIFT - 1)) - 1 + ((bits >> SHIFT) & 1);
    let r = (bits + half) & !((1u32 << SHIFT) - 1);
    let q = f32::from_bits(r).min(448.0);
    q.copysign(x)
}

/// FP8 multiply: quantize inputs, multiply, quantize result.
#[inline]
pub fn mul_fp8(a: f32, b: f32, fmt: Fp8Format) -> f32 {
    quantize_fp8(quantize_fp8(a, fmt) * quantize_fp8(b, fmt), fmt)
}

/// FP8-input multiply with wider (FP16) accumulate, as in the Quarda
/// Accumulation Unit: products formed from FP8 operands, accumulated at
/// FP16 precision.
#[inline]
pub fn qau_mac(acc: f32, a: f32, b: f32, fmt: Fp8Format) -> f32 {
    crate::numeric::fp16::quantize_f16(acc + quantize_fp8(a, fmt) * quantize_fp8(b, fmt))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_exact_values() {
        // All powers of two in normal range are exact.
        for p in -6..=8 {
            let x = 2.0f32.powi(p);
            assert_eq!(quantize_fp8(x, Fp8Format::E4M3), x, "2^{p}");
        }
        assert_eq!(quantize_fp8(448.0, Fp8Format::E4M3), 448.0);
        assert_eq!(quantize_fp8(1.5, Fp8Format::E4M3), 1.5);
        assert_eq!(quantize_fp8(-1.75, Fp8Format::E4M3), -1.75);
    }

    #[test]
    fn e4m3_saturates_not_inf() {
        assert_eq!(quantize_fp8(1e9, Fp8Format::E4M3), 448.0);
        assert_eq!(quantize_fp8(-1e9, Fp8Format::E4M3), -448.0);
        assert_eq!(quantize_fp8(500.0, Fp8Format::E4M3), 448.0);
    }

    #[test]
    fn e5m2_range() {
        assert_eq!(quantize_fp8(57344.0, Fp8Format::E5M2), 57344.0);
        assert_eq!(quantize_fp8(1e9, Fp8Format::E5M2), 57344.0);
        assert_eq!(quantize_fp8(2.0f32.powi(-14), Fp8Format::E5M2), 2.0f32.powi(-14));
    }

    #[test]
    fn subnormals_e4m3() {
        let s = Fp8Format::E4M3.min_subnormal();
        for k in 0..8 {
            let x = s * k as f32;
            assert_eq!(quantize_fp8(x, Fp8Format::E4M3), x, "k={k}");
        }
        // Tiny values flush toward zero/min-subnormal.
        assert_eq!(quantize_fp8(s * 0.4, Fp8Format::E4M3), 0.0);
        assert_eq!(quantize_fp8(s * 0.6, Fp8Format::E4M3), s);
    }

    #[test]
    fn all_codes_roundtrip() {
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            for code in 0u16..=255 {
                let code = code as u8;
                let x = decode(code, fmt);
                if x.is_nan() {
                    continue;
                }
                if fmt == Fp8Format::E5M2 && x.is_infinite() {
                    continue; // encode saturates, never emits Inf
                }
                let back = encode(x, fmt);
                // -0 and +0 both acceptable.
                if x == 0.0 {
                    assert_eq!(back & 0x7F, 0);
                } else {
                    assert_eq!(back, code, "fmt {fmt:?} code {code:#x} val {x}");
                }
            }
        }
    }

    #[test]
    fn rne_ties() {
        // Halfway between 1.0 and 1.125 (E4M3 step 1/8): 1.0625 → 1.0 (even).
        assert_eq!(quantize_fp8(1.0625, Fp8Format::E4M3), 1.0);
        // Halfway between 1.125 and 1.25: 1.1875 → 1.25 (even mantissa).
        assert_eq!(quantize_fp8(1.1875, Fp8Format::E4M3), 1.25);
    }

    #[test]
    fn relative_error_bound() {
        let mut rng = crate::util::rng::Pcg32::new(33);
        for _ in 0..10_000 {
            let x = rng.range_f32(0.02, 400.0);
            let q = quantize_fp8(x, Fp8Format::E4M3);
            let rel = ((q - x) / x).abs();
            assert!(rel <= 1.0 / 16.0 + 1e-6, "x={x} q={q}");
        }
    }

    #[test]
    fn qau_mac_behaves() {
        let acc = qau_mac(0.0, 1.5, 2.0, Fp8Format::E4M3);
        assert_eq!(acc, 3.0);
        // Inputs get quantized before multiply.
        let acc2 = qau_mac(0.0, 1.01, 1.0, Fp8Format::E4M3);
        assert_eq!(acc2, 1.0);
    }

    #[test]
    fn fast_path_matches_codec() {
        // round_e4m3 must agree with decode(encode(x)) everywhere.
        let mut rng = crate::util::rng::Pcg32::new(44);
        for _ in 0..200_000 {
            let x = match rng.below(4) {
                0 => rng.range_f32(-500.0, 500.0),
                1 => rng.range_f32(-1.0, 1.0),
                2 => rng.range_f32(-0.02, 0.02),
                _ => rng.range_f32(-0.002, 0.002),
            };
            let fast = quantize_fp8(x, Fp8Format::E4M3);
            let slow = decode(encode(x, Fp8Format::E4M3), Fp8Format::E4M3);
            assert_eq!(fast.to_bits(), slow.to_bits(), "x={x}");
        }
    }

    #[test]
    fn nan_handling() {
        assert!(decode(encode(f32::NAN, Fp8Format::E4M3), Fp8Format::E4M3).is_nan());
        assert!(decode(encode(f32::NAN, Fp8Format::E5M2), Fp8Format::E5M2).is_nan());
    }
}
