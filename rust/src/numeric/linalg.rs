//! Small fixed-size linear algebra for splatting math: Vec2/3/4, Mat2/3,
//! quaternions. Only what projection and CAT need — no generic dimensions.

use std::ops::{Add, Div, Mul, Neg, Sub};

/// 2D vector (pixel coordinates, conic axes).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec2 {
    /// x component.
    pub x: f32,
    /// y component.
    pub y: f32,
}

/// 3D vector (world/camera space positions and directions).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec3 {
    /// x component.
    pub x: f32,
    /// y component.
    pub y: f32,
    /// z component.
    pub z: f32,
}

/// 4D vector (homogeneous coordinates).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec4 {
    /// x component.
    pub x: f32,
    /// y component.
    pub y: f32,
    /// z component.
    pub z: f32,
    /// w component.
    pub w: f32,
}

/// Symmetric 2×2 matrix (covariance / conic): [[a, b], [b, c]].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Sym2 {
    /// Top-left entry.
    pub a: f32,
    /// Off-diagonal entry.
    pub b: f32,
    /// Bottom-right entry.
    pub c: f32,
}

/// Row-major 3×3.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat3(
    /// Row-major entries.
    pub [f32; 9],
);

/// Unit quaternion (w, x, y, z).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quat {
    /// Scalar part.
    pub w: f32,
    /// Vector part, x.
    pub x: f32,
    /// Vector part, y.
    pub y: f32,
    /// Vector part, z.
    pub z: f32,
}

/// Shorthand [`Vec2`] constructor.
pub const fn v2(x: f32, y: f32) -> Vec2 {
    Vec2 { x, y }
}

/// Shorthand [`Vec3`] constructor.
pub const fn v3(x: f32, y: f32, z: f32) -> Vec3 {
    Vec3 { x, y, z }
}

impl Vec2 {
    /// Dot product.
    pub fn dot(self, o: Vec2) -> f32 {
        self.x * o.x + self.y * o.y
    }

    /// Euclidean length.
    pub fn norm(self) -> f32 {
        self.dot(self).sqrt()
    }
}

impl Add for Vec2 {
    type Output = Vec2;
    fn add(self, o: Vec2) -> Vec2 {
        v2(self.x + o.x, self.y + o.y)
    }
}

impl Sub for Vec2 {
    type Output = Vec2;
    fn sub(self, o: Vec2) -> Vec2 {
        v2(self.x - o.x, self.y - o.y)
    }
}

impl Mul<f32> for Vec2 {
    type Output = Vec2;
    fn mul(self, s: f32) -> Vec2 {
        v2(self.x * s, self.y * s)
    }
}

impl Vec3 {
    /// Dot product.
    pub fn dot(self, o: Vec3) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    /// Cross product (right-handed).
    pub fn cross(self, o: Vec3) -> Vec3 {
        v3(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    /// Euclidean length.
    pub fn norm(self) -> f32 {
        self.dot(self).sqrt()
    }

    /// Unit vector in the same direction (zero stays zero).
    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        if n == 0.0 {
            self
        } else {
            self * (1.0 / n)
        }
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    fn add(self, o: Vec3) -> Vec3 {
        v3(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl Sub for Vec3 {
    type Output = Vec3;
    fn sub(self, o: Vec3) -> Vec3 {
        v3(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl Neg for Vec3 {
    type Output = Vec3;
    fn neg(self) -> Vec3 {
        v3(-self.x, -self.y, -self.z)
    }
}

impl Mul<f32> for Vec3 {
    type Output = Vec3;
    fn mul(self, s: f32) -> Vec3 {
        v3(self.x * s, self.y * s, self.z * s)
    }
}

impl Div<f32> for Vec3 {
    type Output = Vec3;
    fn div(self, s: f32) -> Vec3 {
        self * (1.0 / s)
    }
}

impl Sym2 {
    /// Determinant.
    pub fn det(self) -> f32 {
        self.a * self.c - self.b * self.b
    }

    /// Inverse of a symmetric 2×2 (the "conic" of a 2D covariance).
    pub fn inverse(self) -> Option<Sym2> {
        let d = self.det();
        if d.abs() < 1e-12 {
            return None;
        }
        let inv = 1.0 / d;
        Some(Sym2 {
            a: self.c * inv,
            b: -self.b * inv,
            c: self.a * inv,
        })
    }

    /// Quadratic form xᵀ M x.
    pub fn quad(self, p: Vec2) -> f32 {
        self.a * p.x * p.x + 2.0 * self.b * p.x * p.y + self.c * p.y * p.y
    }

    /// Eigenvalues (λmax, λmin); both real since symmetric.
    pub fn eigenvalues(self) -> (f32, f32) {
        let mid = 0.5 * (self.a + self.c);
        let d = (0.25 * (self.a - self.c) * (self.a - self.c) + self.b * self.b).sqrt();
        (mid + d, (mid - d).max(0.0))
    }

    /// Eigenvector of the larger eigenvalue (unit).
    pub fn major_axis(self) -> Vec2 {
        let (l1, _) = self.eigenvalues();
        // (M - λI) v = 0 → v ∝ (b, λ-a) or (λ-c, b)
        let v = if self.b.abs() > 1e-12 {
            v2(self.b, l1 - self.a)
        } else if self.a >= self.c {
            v2(1.0, 0.0)
        } else {
            v2(0.0, 1.0)
        };
        let n = v.norm();
        if n == 0.0 {
            v2(1.0, 0.0)
        } else {
            v * (1.0 / n)
        }
    }
}

impl Mat3 {
    /// The identity matrix.
    pub const IDENTITY: Mat3 = Mat3([1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);

    /// Entry at row `r`, column `c`.
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.0[r * 3 + c]
    }

    /// Matrix–vector product.
    pub fn mul_vec(&self, v: Vec3) -> Vec3 {
        v3(
            self.at(0, 0) * v.x + self.at(0, 1) * v.y + self.at(0, 2) * v.z,
            self.at(1, 0) * v.x + self.at(1, 1) * v.y + self.at(1, 2) * v.z,
            self.at(2, 0) * v.x + self.at(2, 1) * v.y + self.at(2, 2) * v.z,
        )
    }

    /// Matrix–matrix product.
    pub fn mul(&self, o: &Mat3) -> Mat3 {
        let mut out = [0.0f32; 9];
        for r in 0..3 {
            for c in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += self.at(r, k) * o.at(k, c);
                }
                out[r * 3 + c] = s;
            }
        }
        Mat3(out)
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat3 {
        let m = &self.0;
        Mat3([m[0], m[3], m[6], m[1], m[4], m[7], m[2], m[5], m[8]])
    }

    /// Diagonal scale matrix.
    pub fn scale(s: Vec3) -> Mat3 {
        Mat3([s.x, 0.0, 0.0, 0.0, s.y, 0.0, 0.0, 0.0, s.z])
    }
}

impl Quat {
    /// The identity rotation.
    pub const IDENTITY: Quat = Quat {
        w: 1.0,
        x: 0.0,
        y: 0.0,
        z: 0.0,
    };

    /// Unit quaternion in the same direction (zero becomes identity).
    pub fn normalized(self) -> Quat {
        let n = (self.w * self.w + self.x * self.x + self.y * self.y + self.z * self.z).sqrt();
        if n == 0.0 {
            return Quat::IDENTITY;
        }
        Quat {
            w: self.w / n,
            x: self.x / n,
            y: self.y / n,
            z: self.z / n,
        }
    }

    /// Axis-angle constructor (axis need not be unit).
    pub fn from_axis_angle(axis: Vec3, angle: f32) -> Quat {
        let a = axis.normalized();
        let (s, c) = (angle * 0.5).sin_cos();
        Quat {
            w: c,
            x: a.x * s,
            y: a.y * s,
            z: a.z * s,
        }
    }

    /// Rotation matrix of a (normalized) quaternion.
    pub fn to_mat3(self) -> Mat3 {
        let Quat { w, x, y, z } = self.normalized();
        Mat3([
            1.0 - 2.0 * (y * y + z * z),
            2.0 * (x * y - w * z),
            2.0 * (x * z + w * y),
            2.0 * (x * y + w * z),
            1.0 - 2.0 * (x * x + z * z),
            2.0 * (y * z - w * x),
            2.0 * (x * z - w * y),
            2.0 * (y * z + w * x),
            1.0 - 2.0 * (x * x + y * y),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn vec_ops() {
        assert_eq!(v3(1.0, 2.0, 3.0) + v3(4.0, 5.0, 6.0), v3(5.0, 7.0, 9.0));
        assert_eq!(v3(1.0, 0.0, 0.0).cross(v3(0.0, 1.0, 0.0)), v3(0.0, 0.0, 1.0));
        assert_close(v3(3.0, 4.0, 0.0).norm(), 5.0, 1e-6);
        assert_close(v2(1.0, 1.0).dot(v2(2.0, 3.0)), 5.0, 1e-6);
    }

    #[test]
    fn sym2_inverse_roundtrip() {
        let m = Sym2 { a: 4.0, b: 1.0, c: 3.0 };
        let inv = m.inverse().unwrap();
        // m * inv == I
        assert_close(m.a * inv.a + m.b * inv.b, 1.0, 1e-5);
        assert_close(m.a * inv.b + m.b * inv.c, 0.0, 1e-5);
        assert_close(m.b * inv.b + m.c * inv.c, 1.0, 1e-5);
    }

    #[test]
    fn sym2_singular_none() {
        assert!(Sym2 { a: 1.0, b: 1.0, c: 1.0 }.inverse().is_none());
    }

    #[test]
    fn sym2_quad_form() {
        let m = Sym2 { a: 2.0, b: 0.5, c: 1.0 };
        let q = m.quad(v2(1.0, 2.0));
        assert_close(q, 2.0 + 2.0 * 0.5 * 2.0 + 4.0, 1e-6);
    }

    #[test]
    fn eigen_diagonal() {
        let m = Sym2 { a: 9.0, b: 0.0, c: 1.0 };
        let (l1, l2) = m.eigenvalues();
        assert_close(l1, 9.0, 1e-6);
        assert_close(l2, 1.0, 1e-6);
        let ax = m.major_axis();
        assert_close(ax.x.abs(), 1.0, 1e-6);
    }

    #[test]
    fn eigen_rotated() {
        // 45°-rotated anisotropic covariance: eigenvalues preserved.
        let (l1, l2) = (16.0f32, 1.0f32);
        let c = std::f32::consts::FRAC_1_SQRT_2;
        // R diag(l) Rᵀ with R = rot(45°)
        let a = c * c * l1 + c * c * l2;
        let b = c * c * (l1 - l2);
        let m = Sym2 { a, b, c: a };
        let (e1, e2) = m.eigenvalues();
        assert_close(e1, l1, 1e-4);
        assert_close(e2, l2, 1e-4);
        let ax = m.major_axis();
        assert_close(ax.x.abs(), c, 1e-4);
        assert_close(ax.y.abs(), c, 1e-4);
    }

    #[test]
    fn quat_identity_rotation() {
        let m = Quat::IDENTITY.to_mat3();
        assert_eq!(m, Mat3::IDENTITY);
    }

    #[test]
    fn quat_z_rotation() {
        let q = Quat::from_axis_angle(v3(0.0, 0.0, 1.0), std::f32::consts::FRAC_PI_2);
        let r = q.to_mat3().mul_vec(v3(1.0, 0.0, 0.0));
        assert_close(r.x, 0.0, 1e-6);
        assert_close(r.y, 1.0, 1e-6);
        assert_close(r.z, 0.0, 1e-6);
    }

    #[test]
    fn mat3_mul_transpose() {
        let q = Quat::from_axis_angle(v3(1.0, 2.0, 3.0), 0.7);
        let r = q.to_mat3();
        let rrt = r.mul(&r.transpose());
        for i in 0..3 {
            for j in 0..3 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert_close(rrt.at(i, j), expect, 1e-5);
            }
        }
    }

    #[test]
    fn mat3_vec_identity() {
        let v = v3(1.0, -2.0, 3.0);
        assert_eq!(Mat3::IDENTITY.mul_vec(v), v);
    }
}
