//! IEEE 754 binary16 (half precision) software emulation.
//!
//! FLICKER's CTU computes pixel–Gaussian coordinate deltas in FP16 before
//! converting to FP8 (paper Sec. IV-C). We model the exact numerics in
//! software: round-to-nearest-even conversion, subnormals, infinities.

/// A 16-bit IEEE half-precision float stored as raw bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct F16(
    /// Raw binary16 bits.
    pub u16,
);

impl F16 {
    /// Positive zero.
    pub const ZERO: F16 = F16(0);
    /// One.
    pub const ONE: F16 = F16(0x3C00);
    /// Positive infinity.
    pub const INFINITY: F16 = F16(0x7C00);
    /// Negative infinity.
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    /// Largest finite value (65504).
    pub const MAX: F16 = F16(0x7BFF);
    /// Smallest positive normal (2^-14).
    pub const MIN_POSITIVE: F16 = F16(0x0400);

    /// Convert from f32 with round-to-nearest-even (matches hardware FCVT).
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x7F_FFFF;

        if exp == 0xFF {
            // Inf / NaN
            return if man == 0 {
                F16(sign | 0x7C00)
            } else {
                F16(sign | 0x7E00) // quiet NaN
            };
        }

        // Unbiased exponent, rebiased for half (bias 15).
        let e = exp - 127 + 15;
        if e >= 0x1F {
            // Overflow → infinity.
            return F16(sign | 0x7C00);
        }
        if e <= 0 {
            // Subnormal or underflow to zero.
            if e < -10 {
                return F16(sign);
            }
            // Implicit leading 1, shifted into subnormal position.
            let man = man | 0x80_0000;
            let shift = (14 - e) as u32; // 14..24
            let half_ulp = 1u32 << (shift - 1);
            let rounded = man + half_ulp - 1 + ((man >> shift) & 1);
            return F16(sign | (rounded >> shift) as u16);
        }
        // Normal: round mantissa from 23 to 10 bits, RNE.
        let half_ulp = 0x0FFF + ((man >> 13) & 1);
        let man_r = man + half_ulp;
        if man_r & 0x80_0000 != 0 {
            // Mantissa overflow bumps exponent.
            let e2 = e + 1;
            if e2 >= 0x1F {
                return F16(sign | 0x7C00);
            }
            return F16(sign | ((e2 as u16) << 10));
        }
        F16(sign | ((e as u16) << 10) | (man_r >> 13) as u16)
    }

    /// Exact widening conversion to f32.
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> 10) & 0x1F) as u32;
        let man = (self.0 & 0x3FF) as u32;
        let bits = if exp == 0 {
            if man == 0 {
                sign
            } else {
                // Subnormal: value = man · 2⁻²⁴, exact in f32.
                let v = man as f32 * 2.0f32.powi(-24);
                return if sign != 0 { -v } else { v };
            }
        } else if exp == 0x1F {
            sign | 0x7F80_0000 | (man << 13)
        } else {
            sign | ((exp + 127 - 15) << 23) | (man << 13)
        };
        f32::from_bits(bits)
    }

    /// Is this bit pattern a NaN?
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x3FF) != 0
    }

    /// Is this bit pattern ±∞?
    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }
}

/// Round-trip an f32 through FP16 (the "compute in FP16" primitive used by
/// the mixed-precision CAT model).
#[inline]
pub fn quantize_f16(x: f32) -> f32 {
    F16::from_f32(x).to_f32()
}

/// FP16 arithmetic = compute in f32, round result to FP16 (what an FP16 FPU
/// with RNE does for single ops).
#[inline]
pub fn add_f16(a: f32, b: f32) -> f32 {
    quantize_f16(quantize_f16(a) + quantize_f16(b))
}

/// FP16 multiply (see [`add_f16`]).
#[inline]
pub fn mul_f16(a: f32, b: f32) -> f32 {
    quantize_f16(quantize_f16(a) * quantize_f16(b))
}

/// FP16 subtract (see [`add_f16`]).
#[inline]
pub fn sub_f16(a: f32, b: f32) -> f32 {
    quantize_f16(quantize_f16(a) - quantize_f16(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers() {
        for i in -2048i32..=2048 {
            let x = i as f32;
            assert_eq!(quantize_f16(x), x, "{i}");
        }
    }

    #[test]
    fn one_and_simple_fractions() {
        assert_eq!(F16::ONE.to_f32(), 1.0);
        assert_eq!(quantize_f16(0.5), 0.5);
        assert_eq!(quantize_f16(0.25), 0.25);
        assert_eq!(quantize_f16(1.5), 1.5);
    }

    #[test]
    fn max_and_overflow() {
        assert_eq!(F16::MAX.to_f32(), 65504.0);
        assert_eq!(quantize_f16(65504.0), 65504.0);
        assert!(F16::from_f32(1e6).is_infinite());
        assert!(F16::from_f32(-1e6).is_infinite());
        assert_eq!(F16::from_f32(-1e6), F16::NEG_INFINITY);
    }

    #[test]
    fn subnormals() {
        let min_sub = 2.0f32.powi(-24);
        assert_eq!(quantize_f16(min_sub), min_sub);
        assert_eq!(quantize_f16(min_sub * 3.0), min_sub * 3.0);
        // Below half of min subnormal → flush to zero (RNE).
        assert_eq!(quantize_f16(min_sub * 0.4), 0.0);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10: rounds to even (1.0).
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(quantize_f16(x), 1.0);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9: rounds to even (1+2^-9).
        let y = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(quantize_f16(y), 1.0 + 2.0f32.powi(-9));
    }

    #[test]
    fn nan_propagates() {
        assert!(F16::from_f32(f32::NAN).is_nan());
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn sign_preserved() {
        assert_eq!(quantize_f16(-1.5), -1.5);
        assert_eq!(F16::from_f32(-0.0).0, 0x8000);
    }

    #[test]
    fn roundtrip_is_idempotent() {
        let mut rng = crate::util::rng::Pcg32::new(21);
        for _ in 0..10_000 {
            let x = rng.range_f32(-100.0, 100.0);
            let q = quantize_f16(x);
            assert_eq!(quantize_f16(q), q);
        }
    }

    #[test]
    fn relative_error_bound_normals() {
        let mut rng = crate::util::rng::Pcg32::new(22);
        for _ in 0..10_000 {
            let x = rng.range_f32(0.001, 1000.0);
            let q = quantize_f16(x);
            let rel = ((q - x) / x).abs();
            assert!(rel <= 1.0 / 1024.0, "x={x} q={q} rel={rel}");
        }
    }

    #[test]
    fn fp16_ops_quantize_inputs_and_result() {
        // (a+b) computed in fp16 differs from f32 when the sum needs >11 bits.
        let a = 2048.0f32;
        let b = 1.0f32;
        assert_eq!(add_f16(a, b), 2048.0); // 2049 not representable
        assert_eq!(mul_f16(3.0, 0.5), 1.5);
        assert_eq!(sub_f16(5.0, 2.0), 3.0);
    }
}
