//! Pinhole camera model, view frustum, and evaluation trajectories.

use crate::numeric::linalg::{v2, v3, Mat3, Vec2, Vec3};

/// Pinhole intrinsics in pixels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Intrinsics {
    /// Focal length, x (pixels).
    pub fx: f32,
    /// Focal length, y (pixels).
    pub fy: f32,
    /// Principal point, x (pixels).
    pub cx: f32,
    /// Principal point, y (pixels).
    pub cy: f32,
    /// Image width (pixels).
    pub width: u32,
    /// Image height (pixels).
    pub height: u32,
}

impl Intrinsics {
    /// Square image with the given horizontal FoV (radians).
    pub fn from_fov(width: u32, height: u32, fov_x: f32) -> Intrinsics {
        let fx = width as f32 / (2.0 * (fov_x * 0.5).tan());
        Intrinsics {
            fx,
            fy: fx,
            cx: width as f32 * 0.5,
            cy: height as f32 * 0.5,
            width,
            height,
        }
    }
}

/// Camera pose: world→camera rotation and camera position in world space.
#[derive(Clone, Copy, Debug)]
pub struct Camera {
    /// Pinhole intrinsics.
    pub intr: Intrinsics,
    /// Rotation world→camera (camera looks down +z in camera space).
    pub r_wc: Mat3,
    /// Camera position in world space.
    pub position: Vec3,
    /// Near clip distance.
    pub near: f32,
    /// Far clip distance.
    pub far: f32,
}

impl Camera {
    /// Look-at constructor: camera at `eye` looking toward `target`, with
    /// approximate up vector `up`.
    pub fn look_at(intr: Intrinsics, eye: Vec3, target: Vec3, up: Vec3) -> Camera {
        let fwd = (target - eye).normalized(); // camera +z
        let right = fwd.cross(up).normalized(); // camera +x
        let down = fwd.cross(right); // camera +y (y grows downward in image)
        // Rows of world→camera rotation are camera basis vectors in world.
        let r_wc = Mat3([
            right.x, right.y, right.z, //
            down.x, down.y, down.z, //
            fwd.x, fwd.y, fwd.z,
        ]);
        Camera {
            intr,
            r_wc,
            position: eye,
            near: 0.05,
            far: 1000.0,
        }
    }

    /// World → camera-space point.
    #[inline]
    pub fn to_camera(&self, p: Vec3) -> Vec3 {
        self.r_wc.mul_vec(p - self.position)
    }

    /// Camera-space point → pixel coordinates.
    #[inline]
    pub fn project_cam(&self, t: Vec3) -> Vec2 {
        v2(
            self.intr.fx * t.x / t.z + self.intr.cx,
            self.intr.fy * t.y / t.z + self.intr.cy,
        )
    }

    /// Unit direction from camera to world point.
    #[inline]
    pub fn view_dir(&self, p: Vec3) -> Vec3 {
        (p - self.position).normalized()
    }

    /// Conservative sphere-vs-frustum test (used for frustum culling,
    /// both per-Gaussian and per-cluster "big Gaussian").
    pub fn sphere_in_frustum(&self, center: Vec3, radius: f32) -> bool {
        let t = self.to_camera(center);
        if t.z + radius < self.near || t.z - radius > self.far {
            return false;
        }
        // Tangent-plane test against the four image-border planes,
        // written via the half-FoV tangents.
        let tan_x = self.intr.width as f32 * 0.5 / self.intr.fx;
        let tan_y = self.intr.height as f32 * 0.5 / self.intr.fy;
        // Margin: 3DGS uses a 1.3× guard band so splats straddling the edge
        // still rasterize.
        let guard = 1.3;
        let zx = t.z.max(self.near);
        let lim_x = guard * tan_x * zx + radius / (1.0 + tan_x * tan_x).sqrt() * 2.0;
        let lim_y = guard * tan_y * zx + radius / (1.0 + tan_y * tan_y).sqrt() * 2.0;
        t.x.abs() <= lim_x && t.y.abs() <= lim_y
    }
}

/// Circular orbit around a center point — the evaluation trajectory used by
/// the experiment harness (stand-in for the datasets' held-out test views).
pub fn orbit_path(
    intr: Intrinsics,
    center: Vec3,
    radius: f32,
    height: f32,
    frames: usize,
) -> Vec<Camera> {
    (0..frames)
        .map(|i| {
            let theta = i as f32 / frames as f32 * std::f32::consts::TAU;
            let eye = v3(
                center.x + radius * theta.cos(),
                center.y + height,
                center.z + radius * theta.sin(),
            );
            Camera::look_at(intr, eye, center, v3(0.0, 1.0, 0.0))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cam() -> Camera {
        let intr = Intrinsics::from_fov(640, 480, 1.2);
        Camera::look_at(intr, v3(0.0, 0.0, -5.0), v3(0.0, 0.0, 0.0), v3(0.0, 1.0, 0.0))
    }

    #[test]
    fn center_projects_to_principal_point() {
        let c = cam();
        let t = c.to_camera(v3(0.0, 0.0, 0.0));
        assert!((t.z - 5.0).abs() < 1e-5);
        let px = c.project_cam(t);
        assert!((px.x - 320.0).abs() < 1e-3);
        assert!((px.y - 240.0).abs() < 1e-3);
    }

    #[test]
    fn rotation_is_orthonormal() {
        let c = cam();
        let rrt = c.r_wc.mul(&c.r_wc.transpose());
        for i in 0..3 {
            for j in 0..3 {
                let e = if i == j { 1.0 } else { 0.0 };
                assert!((rrt.at(i, j) - e).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn image_y_grows_downward_for_lower_points() {
        let c = cam();
        // A point below the camera axis (negative world y) should appear at
        // larger pixel y than the center.
        let t = c.to_camera(v3(0.0, -1.0, 0.0));
        let px = c.project_cam(t);
        assert!(px.y > 240.0);
    }

    #[test]
    fn frustum_accepts_visible_rejects_behind() {
        let c = cam();
        assert!(c.sphere_in_frustum(v3(0.0, 0.0, 0.0), 0.5));
        assert!(!c.sphere_in_frustum(v3(0.0, 0.0, -20.0), 0.5)); // behind camera
    }

    #[test]
    fn frustum_rejects_far_off_axis() {
        let c = cam();
        assert!(!c.sphere_in_frustum(v3(100.0, 0.0, 0.0), 0.5));
        // ...but accepts it when the radius is big enough to overlap.
        assert!(c.sphere_in_frustum(v3(7.0, 0.0, 0.0), 7.0));
    }

    #[test]
    fn orbit_all_frames_see_center() {
        let intr = Intrinsics::from_fov(320, 240, 1.2);
        let path = orbit_path(intr, v3(0.0, 0.0, 0.0), 8.0, 2.0, 12);
        assert_eq!(path.len(), 12);
        for c in &path {
            assert!(c.sphere_in_frustum(v3(0.0, 0.0, 0.0), 1.0));
            let px = c.project_cam(c.to_camera(v3(0.0, 0.0, 0.0)));
            assert!((px.x - 160.0).abs() < 1.0);
            assert!((px.y - 120.0).abs() < 1.0);
        }
    }

    #[test]
    fn view_dir_unit() {
        let c = cam();
        let d = c.view_dir(v3(3.0, 4.0, 0.0));
        assert!((d.norm() - 1.0).abs() < 1e-5);
    }
}
