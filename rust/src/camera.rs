//! Pinhole camera model, view frustum, and evaluation trajectories.

use crate::numeric::linalg::{v2, v3, Mat3, Vec2, Vec3};

/// Pinhole intrinsics in pixels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Intrinsics {
    /// Focal length, x (pixels).
    pub fx: f32,
    /// Focal length, y (pixels).
    pub fy: f32,
    /// Principal point, x (pixels).
    pub cx: f32,
    /// Principal point, y (pixels).
    pub cy: f32,
    /// Image width (pixels).
    pub width: u32,
    /// Image height (pixels).
    pub height: u32,
}

impl Intrinsics {
    /// Square image with the given horizontal FoV (radians).
    pub fn from_fov(width: u32, height: u32, fov_x: f32) -> Intrinsics {
        let fx = width as f32 / (2.0 * (fov_x * 0.5).tan());
        Intrinsics {
            fx,
            fy: fx,
            cx: width as f32 * 0.5,
            cy: height as f32 * 0.5,
            width,
            height,
        }
    }
}

/// Quantized camera pose: a hashable cell identifier for plan caches and
/// neighbor lookup.
///
/// Two cameras share a `PoseKey` exactly when every pose component rounds to
/// the same lattice cell at the chosen quantum *and* their intrinsics / clip
/// planes are bit-identical (plans are never shared across different image
/// geometry, so those components are not quantized). Collisions between
/// *distinct* poses inside one cell are by design — a cache that keys on
/// `PoseKey` must verify the exact pose on a key hit and treat a mismatch as
/// a near-miss (a delta-advance candidate), never as a servable entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PoseKey {
    /// Rounded world-space position cell: `round(position / quantum)`.
    pub cell: [i64; 3],
    /// Rounded world→camera rotation entries: `round(r_wc / quantum)`.
    pub rot: [i64; 9],
    /// Intrinsics, bit-exact: `fx`/`fy`/`cx`/`cy` as `f32` bit patterns,
    /// then `width` and `height`.
    pub intr: [u32; 6],
    /// Near/far clip distances as `f32` bit patterns.
    pub clip: [u32; 2],
}

/// Camera pose: world→camera rotation and camera position in world space.
#[derive(Clone, Copy, Debug)]
pub struct Camera {
    /// Pinhole intrinsics.
    pub intr: Intrinsics,
    /// Rotation world→camera (camera looks down +z in camera space).
    pub r_wc: Mat3,
    /// Camera position in world space.
    pub position: Vec3,
    /// Near clip distance.
    pub near: f32,
    /// Far clip distance.
    pub far: f32,
}

impl Camera {
    /// Look-at constructor: camera at `eye` looking toward `target`, with
    /// approximate up vector `up`.
    pub fn look_at(intr: Intrinsics, eye: Vec3, target: Vec3, up: Vec3) -> Camera {
        let fwd = (target - eye).normalized(); // camera +z
        let right = fwd.cross(up).normalized(); // camera +x
        let down = fwd.cross(right); // camera +y (y grows downward in image)
        // Rows of world→camera rotation are camera basis vectors in world.
        let r_wc = Mat3([
            right.x, right.y, right.z, //
            down.x, down.y, down.z, //
            fwd.x, fwd.y, fwd.z,
        ]);
        Camera {
            intr,
            r_wc,
            position: eye,
            near: 0.05,
            far: 1000.0,
        }
    }

    /// World → camera-space point.
    #[inline]
    pub fn to_camera(&self, p: Vec3) -> Vec3 {
        self.r_wc.mul_vec(p - self.position)
    }

    /// Camera-space point → pixel coordinates.
    #[inline]
    pub fn project_cam(&self, t: Vec3) -> Vec2 {
        v2(
            self.intr.fx * t.x / t.z + self.intr.cx,
            self.intr.fy * t.y / t.z + self.intr.cy,
        )
    }

    /// Unit direction from camera to world point.
    #[inline]
    pub fn view_dir(&self, p: Vec3) -> Vec3 {
        (p - self.position).normalized()
    }

    /// Quantize this pose onto a lattice with cell size `quantum`.
    ///
    /// `quantum` is in world units for the position and dimensionless for
    /// the rotation entries (which live in `[-1, 1]`); rounding (not
    /// flooring) keeps the key stable under tiny float jitter around zero.
    /// The cell distance between two keys is bounded by the pose distance:
    /// `|cell_a - cell_b| <= |Δposition| / quantum + 1` per axis, so keys
    /// never jump more than the camera moved. See [`PoseKey`] for the
    /// collision contract.
    pub fn pose_key(&self, quantum: f32) -> PoseKey {
        let q = quantum.max(1e-9);
        let qi = |x: f32| (x / q).round() as i64;
        let mut rot = [0i64; 9];
        for (k, slot) in rot.iter_mut().enumerate() {
            *slot = qi(self.r_wc.0[k]);
        }
        PoseKey {
            cell: [qi(self.position.x), qi(self.position.y), qi(self.position.z)],
            rot,
            intr: [
                self.intr.fx.to_bits(),
                self.intr.fy.to_bits(),
                self.intr.cx.to_bits(),
                self.intr.cy.to_bits(),
                self.intr.width,
                self.intr.height,
            ],
            clip: [self.near.to_bits(), self.far.to_bits()],
        }
    }

    /// Bitwise pose equality: every float component of the two cameras has
    /// the identical bit pattern. This is the exact-match verification a
    /// [`PoseKey`]-keyed cache runs on a key hit.
    pub fn same_pose(&self, other: &Camera) -> bool {
        let fb = |a: f32, b: f32| a.to_bits() == b.to_bits();
        (0..9).all(|k| fb(self.r_wc.0[k], other.r_wc.0[k]))
            && fb(self.position.x, other.position.x)
            && fb(self.position.y, other.position.y)
            && fb(self.position.z, other.position.z)
            && fb(self.near, other.near)
            && fb(self.far, other.far)
            && self.intr == other.intr
    }

    /// Conservative sphere-vs-frustum test (used for frustum culling,
    /// both per-Gaussian and per-cluster "big Gaussian").
    pub fn sphere_in_frustum(&self, center: Vec3, radius: f32) -> bool {
        let t = self.to_camera(center);
        if t.z + radius < self.near || t.z - radius > self.far {
            return false;
        }
        // Tangent-plane test against the four image-border planes,
        // written via the half-FoV tangents.
        let tan_x = self.intr.width as f32 * 0.5 / self.intr.fx;
        let tan_y = self.intr.height as f32 * 0.5 / self.intr.fy;
        // Margin: 3DGS uses a 1.3× guard band so splats straddling the edge
        // still rasterize.
        let guard = 1.3;
        let zx = t.z.max(self.near);
        let lim_x = guard * tan_x * zx + radius / (1.0 + tan_x * tan_x).sqrt() * 2.0;
        let lim_y = guard * tan_y * zx + radius / (1.0 + tan_y * tan_y).sqrt() * 2.0;
        t.x.abs() <= lim_x && t.y.abs() <= lim_y
    }
}

/// Circular orbit around a center point — the evaluation trajectory used by
/// the experiment harness (stand-in for the datasets' held-out test views).
pub fn orbit_path(
    intr: Intrinsics,
    center: Vec3,
    radius: f32,
    height: f32,
    frames: usize,
) -> Vec<Camera> {
    (0..frames)
        .map(|i| {
            let theta = i as f32 / frames as f32 * std::f32::consts::TAU;
            let eye = v3(
                center.x + radius * theta.cos(),
                center.y + height,
                center.z + radius * theta.sin(),
            );
            Camera::look_at(intr, eye, center, v3(0.0, 1.0, 0.0))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cam() -> Camera {
        let intr = Intrinsics::from_fov(640, 480, 1.2);
        Camera::look_at(intr, v3(0.0, 0.0, -5.0), v3(0.0, 0.0, 0.0), v3(0.0, 1.0, 0.0))
    }

    #[test]
    fn center_projects_to_principal_point() {
        let c = cam();
        let t = c.to_camera(v3(0.0, 0.0, 0.0));
        assert!((t.z - 5.0).abs() < 1e-5);
        let px = c.project_cam(t);
        assert!((px.x - 320.0).abs() < 1e-3);
        assert!((px.y - 240.0).abs() < 1e-3);
    }

    #[test]
    fn rotation_is_orthonormal() {
        let c = cam();
        let rrt = c.r_wc.mul(&c.r_wc.transpose());
        for i in 0..3 {
            for j in 0..3 {
                let e = if i == j { 1.0 } else { 0.0 };
                assert!((rrt.at(i, j) - e).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn image_y_grows_downward_for_lower_points() {
        let c = cam();
        // A point below the camera axis (negative world y) should appear at
        // larger pixel y than the center.
        let t = c.to_camera(v3(0.0, -1.0, 0.0));
        let px = c.project_cam(t);
        assert!(px.y > 240.0);
    }

    #[test]
    fn frustum_accepts_visible_rejects_behind() {
        let c = cam();
        assert!(c.sphere_in_frustum(v3(0.0, 0.0, 0.0), 0.5));
        assert!(!c.sphere_in_frustum(v3(0.0, 0.0, -20.0), 0.5)); // behind camera
    }

    #[test]
    fn frustum_rejects_far_off_axis() {
        let c = cam();
        assert!(!c.sphere_in_frustum(v3(100.0, 0.0, 0.0), 0.5));
        // ...but accepts it when the radius is big enough to overlap.
        assert!(c.sphere_in_frustum(v3(7.0, 0.0, 0.0), 7.0));
    }

    #[test]
    fn orbit_all_frames_see_center() {
        let intr = Intrinsics::from_fov(320, 240, 1.2);
        let path = orbit_path(intr, v3(0.0, 0.0, 0.0), 8.0, 2.0, 12);
        assert_eq!(path.len(), 12);
        for c in &path {
            assert!(c.sphere_in_frustum(v3(0.0, 0.0, 0.0), 1.0));
            let px = c.project_cam(c.to_camera(v3(0.0, 0.0, 0.0)));
            assert!((px.x - 160.0).abs() < 1.0);
            assert!((px.y - 120.0).abs() < 1.0);
        }
    }

    #[test]
    fn view_dir_unit() {
        let c = cam();
        let d = c.view_dir(v3(3.0, 4.0, 0.0));
        assert!((d.norm() - 1.0).abs() < 1e-5);
    }

    fn orbit24() -> Vec<Camera> {
        let intr = Intrinsics::from_fov(320, 240, 1.2);
        orbit_path(intr, v3(0.0, 0.0, 0.0), 12.0, 2.5, 24)
    }

    #[test]
    fn pose_key_is_stable_for_the_same_camera() {
        let c = cam();
        for q in [1e-4, 1e-3, 1e-1, 1.0, 1e4] {
            assert_eq!(c.pose_key(q), c.pose_key(q));
        }
        assert!(c.same_pose(&c));
    }

    #[test]
    fn pose_key_separates_orbit_views_at_the_default_quantum() {
        // At the plan-cache default quantum (1e-3 world units) every view of
        // the standard 24-step orbit lands in its own cell: orbit steps move
        // the camera by ~3 world units and the rotation rows by ~0.25, both
        // thousands of quanta.
        let path = orbit24();
        let keys: Vec<PoseKey> = path.iter().map(|c| c.pose_key(1e-3)).collect();
        for i in 0..keys.len() {
            for j in (i + 1)..keys.len() {
                assert_ne!(keys[i], keys[j], "orbit views {i} and {j} collided");
            }
        }
    }

    #[test]
    fn pose_key_cell_distance_is_monotone_in_orbit_step_size() {
        // Chord length on the orbit circle grows monotonically up to the
        // half-orbit, and at q = 1e-3 each doubling of the step size moves
        // the camera by thousands of cells — far beyond the ±1 rounding
        // noise — so the L1 cell distance from view 0 must strictly grow
        // through steps 1, 2, 4, 8.
        let path = orbit24();
        let base = path[0].pose_key(1e-3);
        let l1 = |k: &PoseKey| -> i64 {
            (0..3).map(|a| (k.cell[a] - base.cell[a]).abs()).sum()
        };
        let mut prev = 0i64;
        for step in [1usize, 2, 4, 8] {
            let d = l1(&path[step].pose_key(1e-3));
            assert!(d > prev, "step {step}: cell distance {d} <= {prev}");
            prev = d;
        }
    }

    #[test]
    fn pose_key_collides_under_quantum_and_splits_above_it() {
        // A sub-quantum nudge keeps the key (collision: the cache must then
        // verify the exact pose — same_pose distinguishes the two), while a
        // many-quanta nudge splits it.
        let intr = Intrinsics::from_fov(640, 480, 1.2);
        let a = Camera::look_at(intr, v3(0.2, 2.5, -12.0), v3(0.0, 0.0, 0.0), v3(0.0, 1.0, 0.0));
        let mut b = a;
        b.position.x += 1e-9; // far below q=1.0, and 0.2 is far from a cell edge
        assert_eq!(a.pose_key(1.0), b.pose_key(1.0));
        assert!(!a.same_pose(&b), "distinct poses must fail exact verification");
        let mut c = a;
        c.position.x += 10.0; // ten cells at q=1.0
        assert_ne!(a.pose_key(1.0), c.pose_key(1.0));
    }

    #[test]
    fn pose_key_pins_image_geometry_bit_exactly() {
        let a = cam();
        let mut b = a;
        b.intr.width = 321;
        assert_ne!(a.pose_key(1e-3), b.pose_key(1e-3));
        let mut c = a;
        c.near = 0.06;
        // Clip planes are not quantized: any change forks the key.
        assert_ne!(a.pose_key(1e4), c.pose_key(1e4));
    }
}
