//! # FLICKER — fine-grained contribution-aware 3DGS accelerator (reproduction)
//!
//! Full-system reproduction of *FLICKER: A Fine-Grained Contribution-Aware
//! Accelerator for Real-Time 3D Gaussian Splatting* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * [`scene`], [`camera`], [`render`] — the 3DGS substrate: synthetic
//!   datasets, EWA projection, tiling/intersection, depth sort, the
//!   reference rasterizer (golden model), and quality metrics.
//! * [`cat`] — the paper's algorithmic contribution (Sec. III): Mini-Tile
//!   CAT with adaptive leader pixels, pixel-rectangle grouping (Alg. 1),
//!   and the mixed-precision FP16→FP8 test path.
//! * [`sim`] — the paper's hardware contribution (Sec. IV): cycle-accurate
//!   simulator of the FLICKER accelerator (preprocessing cores, sorters,
//!   CTUs, rendering cores with VRUs and feature FIFOs, LPDDR4 DRAM,
//!   energy and area models) plus the GSCore and edge-GPU baselines.
//! * [`runtime`], [`coordinator`] — the Layer-3 driver: the artifact
//!   manifest plus (behind the `pjrt` cargo feature) the PJRT client that
//!   loads the AOT-compiled JAX/Pallas artifacts (`artifacts/*.hlo.txt`),
//!   and the [`coordinator::Session`] rendering API — one prepared
//!   session per experiment, a cached `FramePlan` per view, frames
//!   streamed across [`coordinator::frame::RenderBackend`]
//!   implementations on the worker pool.
//! * [`util`], [`numeric`] — in-tree substrates (RNG, JSON, CLI, errors,
//!   bench harness, property tests, FP16/FP8 emulation, linear algebra).

#![warn(missing_docs)]

pub mod camera;
pub mod cat;
pub mod config;
pub mod coordinator;
pub mod numeric;
pub mod render;
pub mod runtime;
pub mod scene;
pub mod sim;
pub mod util;
