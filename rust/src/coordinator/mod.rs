//! Layer-3 frame coordinator: schedules per-tile work across backends,
//! collects frame metrics, and drives multi-frame evaluation runs.
//!
//! Backends:
//! * **Golden** — the in-process Rust rasterizer (reference numerics), with
//!   any `MaskProvider` (vanilla / OBB / Mini-Tile CAT).
//! * **Pjrt** — the AOT JAX/Pallas artifacts through the PJRT runtime
//!   (`runtime::executor`), proving the three layers compose.
//!
//! The per-frame flow mirrors the accelerator's: project → tile-bin →
//! depth-sort → (CAT-mask) → blend, with tiles fanned across the worker
//! pool.

pub mod frame;
pub mod report;

pub use frame::{render_frame, Backend, FrameMetrics, FrameRequest};
