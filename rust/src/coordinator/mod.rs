//! Layer-3 frame coordinator: builds one `FramePlan` per frame, schedules
//! per-tile work across backends, collects frame metrics, and drives
//! multi-frame evaluation runs.
//!
//! Backends implement the [`frame::RenderBackend`] trait and consume a
//! prepared `render::plan::FramePlan` (they never re-derive splats or tile
//! lists):
//! * [`frame::Golden`] — the in-process Rust rasterizer (reference
//!   numerics) with vanilla masks.
//! * [`frame::GoldenCat`] — the golden rasterizer driven by Mini-Tile CAT
//!   masks at a given `CatConfig`.
//! * `frame::Pjrt` — the AOT JAX/Pallas artifacts through the PJRT runtime
//!   (`runtime::executor`), proving the three layers compose. Only
//!   compiled with `--features pjrt`.
//!
//! The per-frame flow mirrors the accelerator's: project → tile-bin →
//! depth-sort (the plan, built once) → (CAT-mask) → blend (per render),
//! with tiles fanned across the worker pool (`RenderOptions::workers`) and
//! orbits fanned across frames (`ExperimentConfig::workers`). Sweeps that
//! re-render one view reuse the plan through [`frame::render_planned`].

pub mod frame;
pub mod report;

pub use frame::{
    render_frame, render_orbit, render_planned, FrameMetrics, FrameRequest, Golden, GoldenCat,
    RenderBackend,
};

#[cfg(feature = "pjrt")]
pub use frame::Pjrt;
