//! Layer-3 frame coordinator: the [`session::Session`] rendering API, the
//! [`frame::RenderBackend`] execution-engine trait, and the report writer.
//!
//! A session is built once from an `ExperimentConfig` (scene prep,
//! optional pruning, camera orbit, worker-budget split) and owns a
//! per-view `FramePlan` cache shared across backends:
//!
//! * `session.frame(i, &backend)` — render one view from the cached plan.
//! * `session.sweep(i, &backends)` — many backends, one plan build.
//! * `session.stream(&backend)` — a [`session::FrameStream`] that fans
//!   frames across the worker pool and yields them in completion order
//!   (`.ordered()` restores orbit order, bit-identical to sequential).
//!
//! Backends implement [`frame::RenderBackend`] and consume a prepared
//! `render::plan::FramePlan` (they never re-derive splats or tile lists):
//! * [`frame::Golden`] — the in-process Rust rasterizer (reference
//!   numerics) with vanilla masks.
//! * [`frame::GoldenCat`] — the golden rasterizer driven by Mini-Tile CAT
//!   masks at a given `CatConfig`.
//! * `frame::Pjrt` — the AOT JAX/Pallas artifacts through the PJRT runtime
//!   (`runtime::executor`), proving the three layers compose. Only
//!   compiled with `--features pjrt`.
//!
//! The per-frame flow mirrors the accelerator's: project → tile-bin →
//! depth-sort (the plan, built once per view) → (CAT-mask) → blend (per
//! render), with tiles fanned across the worker pool
//! (`RenderOptions::workers`) and streamed orbits fanned across frames
//! (the session's budget split).
//!
//! Above the session sits the multi-tenant [`service::RenderService`]: a
//! shared scene store, a cross-session plan cache keyed by quantized
//! camera pose, a bounded request queue, and (under `--features pjrt`) the
//! cross-client tile coalescer that merges many clients' frames into
//! shared precision-pure waves.

pub mod frame;
pub mod report;
pub mod service;
pub mod session;

pub use frame::{render_planned, FrameMetrics, Golden, GoldenCat, RenderBackend};
pub use service::{
    RenderRequest, RenderService, SceneId, ServiceConfig, ServiceFrame, ServiceStats,
};
pub use session::{FrameStream, PlanCacheStats, Session, SessionBuilder};

#[cfg(feature = "pjrt")]
pub use frame::Pjrt;
