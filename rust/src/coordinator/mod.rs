//! Layer-3 frame coordinator: schedules per-tile work across backends,
//! collects frame metrics, and drives multi-frame evaluation runs.
//!
//! Backends implement the [`frame::RenderBackend`] trait:
//! * [`frame::Golden`] — the in-process Rust rasterizer (reference
//!   numerics) with vanilla masks.
//! * [`frame::GoldenCat`] — the golden rasterizer driven by Mini-Tile CAT
//!   masks at a given `CatConfig`.
//! * `frame::Pjrt` — the AOT JAX/Pallas artifacts through the PJRT runtime
//!   (`runtime::executor`), proving the three layers compose. Only
//!   compiled with `--features pjrt`.
//!
//! The per-frame flow mirrors the accelerator's: project → tile-bin →
//! depth-sort → (CAT-mask) → blend, with tiles fanned across the worker
//! pool (`RenderOptions::workers`) and orbits fanned across frames
//! (`ExperimentConfig::workers`).

pub mod frame;
pub mod report;

pub use frame::{
    render_frame, render_orbit, FrameMetrics, FrameRequest, Golden, GoldenCat, RenderBackend,
};

#[cfg(feature = "pjrt")]
pub use frame::Pjrt;
