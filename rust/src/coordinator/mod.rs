//! Layer-3 frame coordinator: the [`session::Session`] rendering API, the
//! [`frame::RenderBackend`] execution-engine trait, and the report writer.
//!
//! A session is built once from an `ExperimentConfig` (scene prep,
//! optional pruning, camera orbit, worker-budget split) and owns a
//! per-view `FramePlan` cache shared across backends:
//!
//! * `session.frame(i, &backend)` — render one view from the cached plan.
//! * `session.sweep(i, &backends)` — many backends, one plan build.
//! * `session.stream(&backend)` — a [`session::FrameStream`] that fans
//!   frames across the worker pool and yields them in completion order
//!   (`.ordered()` restores orbit order, bit-identical to sequential).
//!
//! Backends implement [`frame::RenderBackend`] and consume a prepared
//! `render::plan::FramePlan` (they never re-derive splats or tile lists):
//! * [`frame::Golden`] — the in-process Rust rasterizer (reference
//!   numerics) with vanilla masks.
//! * [`frame::GoldenCat`] — the golden rasterizer driven by Mini-Tile CAT
//!   masks at a given `CatConfig`.
//! * `frame::Pjrt` — the AOT JAX/Pallas artifacts through the PJRT runtime
//!   (`runtime::executor`), proving the three layers compose. Only
//!   compiled with `--features pjrt`.
//!
//! The per-frame flow mirrors the accelerator's: project → tile-bin →
//! depth-sort (the plan, built once per view) → (CAT-mask) → blend (per
//! render), with tiles fanned across the worker pool
//! (`RenderOptions::workers`) and streamed orbits fanned across frames
//! (the session's budget split). The legacy free functions
//! `render_frame`/`render_orbit` survive as deprecated shims over the
//! session.

pub mod frame;
pub mod report;
pub mod session;

#[allow(deprecated)]
pub use frame::{
    render_frame, render_orbit, render_planned, FrameMetrics, FrameRequest, Golden, GoldenCat,
    RenderBackend,
};
pub use session::{FrameStream, PlanCacheStats, Session, SessionBuilder};

#[cfg(feature = "pjrt")]
pub use frame::Pjrt;
