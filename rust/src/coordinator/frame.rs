//! Per-frame rendering coordination.

use crate::camera::Camera;
use crate::cat::{CatConfig, CatEngine};
use crate::config::ExperimentConfig;
use crate::render::image::Image;
use crate::render::project::project_scene;
use crate::render::raster::{render_lists, AllOnes, MaskProvider, RenderOptions, RenderStats};
use crate::render::sort::sort_by_depth;
use crate::render::tile::{build_tile_lists, TileGrid};
use crate::runtime::executor::TileExecutor;
use crate::runtime::Runtime;
use crate::scene::gaussian::Scene;
use anyhow::Result;
use std::time::Instant;

/// Which execution engine renders the frame's tiles.
pub enum Backend<'rt> {
    /// Pure-Rust golden rasterizer, vanilla masks.
    Golden,
    /// Golden rasterizer with Mini-Tile CAT masks at the given config.
    GoldenCat(CatConfig),
    /// AOT JAX/Pallas artifacts through PJRT.
    Pjrt(&'rt Runtime),
}

/// A frame to render.
pub struct FrameRequest<'a> {
    pub scene: &'a Scene,
    pub camera: &'a Camera,
    pub options: RenderOptions,
}

/// What came back.
pub struct FrameMetrics {
    pub image: Image,
    pub stats: RenderStats,
    pub wall_ms: f64,
    pub backend: &'static str,
}

/// Render one frame through the chosen backend.
pub fn render_frame(req: &FrameRequest, backend: &mut Backend) -> Result<FrameMetrics> {
    let t0 = Instant::now();
    let (image, stats, name) = match backend {
        Backend::Golden => {
            let out = crate::render::raster::render(req.scene, req.camera, &req.options);
            (out.image, out.stats, "golden")
        }
        Backend::GoldenCat(cfg) => {
            let mut engine = CatEngine::new(*cfg);
            let out = crate::render::raster::render_masked(
                req.scene,
                req.camera,
                &req.options,
                &mut engine,
                None,
            );
            (out.image, out.stats, "golden+cat")
        }
        Backend::Pjrt(rt) => {
            let splats = project_scene(req.scene, req.camera);
            let grid = TileGrid::new(
                req.camera.intr.width,
                req.camera.intr.height,
                req.options.tile_size,
            );
            let mut lists = build_tile_lists(&splats, &grid, req.options.strategy);
            for l in &mut lists {
                sort_by_depth(l, &splats);
            }
            let mut img = Image::new(grid.width, grid.height);
            let mut ex = TileExecutor::new(rt);
            for (t, list) in lists.iter().enumerate() {
                ex.render_tile(
                    &grid.rect(t),
                    &splats,
                    list,
                    &mut img,
                    req.options.background,
                )?;
            }
            let stats = RenderStats {
                splats: splats.len(),
                tile_pairs: lists.iter().map(|l| l.len()).sum(),
                pixels: (grid.width * grid.height) as u64,
                ..Default::default()
            };
            (img, stats, "pjrt")
        }
    };
    Ok(FrameMetrics {
        image,
        stats,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        backend: name,
    })
}

/// Render an experiment's whole camera orbit through the golden backend,
/// returning per-frame metrics (the multi-frame evaluation driver used by
/// examples and benches).
pub fn render_orbit(cfg: &ExperimentConfig, backend: &mut Backend) -> Result<Vec<FrameMetrics>> {
    let scene = cfg.build_scene()?;
    let cams = cfg.build_cameras();
    let mut out = Vec::with_capacity(cams.len());
    for cam in &cams {
        let req = FrameRequest {
            scene: &scene,
            camera: cam,
            options: RenderOptions::default(),
        };
        out.push(render_frame(&req, backend)?);
    }
    Ok(out)
}

/// Convenience: render the same frame through Golden and a mask provider,
/// returning (golden, masked) images — the quality-delta primitive used by
/// Table I / Fig. 3 / Fig. 7 experiments.
pub fn golden_vs_masked(
    scene: &Scene,
    cam: &Camera,
    opts: &RenderOptions,
    masks: &mut dyn MaskProvider,
) -> (Image, Image) {
    let golden = crate::render::raster::render(scene, cam, opts);
    let splats = project_scene(scene, cam);
    let grid = TileGrid::new(cam.intr.width, cam.intr.height, opts.tile_size);
    let mut lists = build_tile_lists(&splats, &grid, opts.strategy);
    for l in &mut lists {
        sort_by_depth(l, &splats);
    }
    let masked = render_lists(&splats, &lists, &grid, opts, masks, None);
    let _ = AllOnes; // referenced for doc purposes
    (golden.image, masked.image)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::Intrinsics;
    use crate::cat::{LeaderMode, Precision};
    use crate::numeric::linalg::v3;
    use crate::render::metrics::psnr;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn setup() -> (Scene, Camera) {
        let scene = generate_scaled(&preset("truck"), 0.02);
        let cam = Camera::look_at(
            Intrinsics::from_fov(96, 96, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        (scene, cam)
    }

    #[test]
    fn golden_and_cat_agree_visually() {
        let (scene, cam) = setup();
        let req = FrameRequest {
            scene: &scene,
            camera: &cam,
            options: RenderOptions::default(),
        };
        let golden = render_frame(&req, &mut Backend::Golden).unwrap();
        let cat = render_frame(
            &req,
            &mut Backend::GoldenCat(CatConfig {
                mode: LeaderMode::UniformDense,
                precision: Precision::Fp32,
                stage1: true,
            }),
        )
        .unwrap();
        let p = psnr(&golden.image, &cat.image);
        assert!(p > 30.0, "CAT vs golden PSNR {p}");
        // CAT must reduce tested work.
        assert!(cat.stats.pairs_tested < golden.stats.pairs_tested);
    }

    #[test]
    fn orbit_runs_all_frames() {
        let cfg = ExperimentConfig {
            scene: "truck".into(),
            scene_scale: 0.01,
            resolution: 64,
            frames: 2,
            ..Default::default()
        };
        let frames = render_orbit(&cfg, &mut Backend::Golden).unwrap();
        assert_eq!(frames.len(), 2);
        for f in frames {
            assert_eq!(f.backend, "golden");
            assert!(f.wall_ms > 0.0);
        }
    }

    #[test]
    fn pjrt_backend_composes_if_artifacts_present() {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::load(&dir).unwrap();
        let (scene, cam) = setup();
        let req = FrameRequest {
            scene: &scene,
            camera: &cam,
            options: RenderOptions::default(),
        };
        let golden = render_frame(&req, &mut Backend::Golden).unwrap();
        let pjrt = render_frame(&req, &mut Backend::Pjrt(&rt)).unwrap();
        let p = psnr(&golden.image, &pjrt.image);
        assert!(p > 28.0, "PJRT vs golden PSNR {p}");
    }
}
