//! Per-frame rendering coordination.
//!
//! [`RenderBackend`] is the extension point: a backend turns a
//! [`FrameRequest`] into an image + stats, and new execution engines slot
//! in without touching `render_frame`/`render_orbit` callers. Backends must
//! be `Sync` so [`render_orbit`] can fan frames across the worker pool.

use crate::camera::Camera;
use crate::cat::CatConfig;
use crate::config::ExperimentConfig;
use crate::render::image::Image;
use crate::render::raster::{RenderOptions, RenderOutput, RenderStats};
use crate::scene::gaussian::Scene;
use crate::util::error::Result;
use crate::util::pool;
use std::time::Instant;

/// A frame to render.
pub struct FrameRequest<'a> {
    /// The scene to render.
    pub scene: &'a Scene,
    /// The viewpoint.
    pub camera: &'a Camera,
    /// Rasterization settings (tile size, strategy, workers, …).
    pub options: RenderOptions,
}

/// What came back.
#[derive(Clone)]
pub struct FrameMetrics {
    /// The rendered frame.
    pub image: Image,
    /// Workload counters.
    pub stats: RenderStats,
    /// Wall-clock render time in milliseconds.
    pub wall_ms: f64,
    /// Name of the backend that rendered the frame.
    pub backend: &'static str,
}

/// An execution engine for a frame's tiles.
pub trait RenderBackend: Sync {
    /// Short stable name recorded in [`FrameMetrics`].
    fn name(&self) -> &'static str;

    /// Render the frame. Implementations honor `req.options.workers` for
    /// their internal tile fan-out where parallelism is safe.
    fn render(&self, req: &FrameRequest) -> Result<RenderOutput>;
}

/// Pure-Rust golden rasterizer, vanilla masks.
pub struct Golden;

impl RenderBackend for Golden {
    fn name(&self) -> &'static str {
        "golden"
    }

    fn render(&self, req: &FrameRequest) -> Result<RenderOutput> {
        Ok(crate::render::raster::render(req.scene, req.camera, &req.options))
    }
}

/// Golden rasterizer with Mini-Tile CAT masks at the given config.
pub struct GoldenCat(
    /// The CAT configuration driving mask generation.
    pub CatConfig,
);

impl RenderBackend for GoldenCat {
    fn name(&self) -> &'static str {
        "golden+cat"
    }

    fn render(&self, req: &FrameRequest) -> Result<RenderOutput> {
        Ok(crate::render::raster::render_with_source(
            req.scene,
            req.camera,
            &req.options,
            &self.0,
        ))
    }
}

/// AOT JAX/Pallas artifacts through PJRT (only with `--features pjrt`).
/// Tiles run sequentially, and whole frames serialize through an internal
/// gate: the executor chunks splat lists and carries transmittance on the
/// host, and PJRT executable thread-safety is owned by the runtime, so
/// concurrent frames (the `render_orbit` fan-out) queue rather than enter
/// `exec_f32` in parallel.
#[cfg(feature = "pjrt")]
pub struct Pjrt<'rt> {
    rt: &'rt crate::runtime::Runtime,
    gate: std::sync::Mutex<()>,
}

#[cfg(feature = "pjrt")]
impl<'rt> Pjrt<'rt> {
    /// New PJRT backend over a loaded runtime.
    pub fn new(rt: &'rt crate::runtime::Runtime) -> Self {
        Pjrt {
            rt,
            gate: std::sync::Mutex::new(()),
        }
    }
}

#[cfg(feature = "pjrt")]
impl RenderBackend for Pjrt<'_> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn render(&self, req: &FrameRequest) -> Result<RenderOutput> {
        use crate::render::project::project_scene;
        use crate::render::sort::sort_by_depth;
        use crate::render::tile::{build_tile_lists, TileGrid};
        use crate::runtime::executor::TileExecutor;

        let _serial = self
            .gate
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let splats = project_scene(req.scene, req.camera);
        let grid = TileGrid::new(
            req.camera.intr.width,
            req.camera.intr.height,
            req.options.tile_size,
        );
        let mut lists = build_tile_lists(&splats, &grid, req.options.strategy);
        for l in &mut lists {
            sort_by_depth(l, &splats);
        }
        let mut img = Image::new(grid.width, grid.height);
        let mut ex = TileExecutor::new(self.rt);
        for (t, list) in lists.iter().enumerate() {
            ex.render_tile(
                &grid.rect(t),
                &splats,
                list,
                &mut img,
                req.options.background,
            )?;
        }
        let stats = RenderStats {
            splats: splats.len(),
            tile_pairs: lists.iter().map(|l| l.len()).sum(),
            pixels: (grid.width * grid.height) as u64,
            ..Default::default()
        };
        Ok(RenderOutput { image: img, stats })
    }
}

/// Render one frame through the chosen backend.
pub fn render_frame(req: &FrameRequest, backend: &dyn RenderBackend) -> Result<FrameMetrics> {
    let t0 = Instant::now();
    let out = backend.render(req)?;
    Ok(FrameMetrics {
        image: out.image,
        stats: out.stats,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        backend: backend.name(),
    })
}

/// Render an experiment's whole camera orbit, fanning frames across the
/// worker pool (`cfg.workers`; 0 = auto, 1 = sequential). Frames are
/// independent, so any worker count returns bit-identical images in orbit
/// order. The worker budget is split: up to one thread per frame, and each
/// frame spends the remainder on its tile fan-out, so short orbits on wide
/// machines still use the whole allotment without oversubscribing.
pub fn render_orbit(
    cfg: &ExperimentConfig,
    backend: &dyn RenderBackend,
) -> Result<Vec<FrameMetrics>> {
    let scene = cfg.build_scene()?;
    let cams = cfg.build_cameras();
    let total_workers = pool::resolve_workers(cfg.workers);
    let frame_workers = total_workers.min(cams.len().max(1));
    let tile_workers = (total_workers / frame_workers.max(1)).max(1);
    let frames: Vec<Option<Result<FrameMetrics>>> =
        pool::map_indexed(cams.len(), frame_workers, |i| {
            let req = FrameRequest {
                scene: &scene,
                camera: &cams[i],
                options: RenderOptions {
                    workers: tile_workers,
                    ..RenderOptions::default()
                },
            };
            Some(render_frame(&req, backend))
        });
    frames
        .into_iter()
        .map(|f| f.expect("pool fills every frame slot"))
        .collect()
}

/// Convenience: render the same frame through Golden and a mask provider,
/// returning (golden, masked) images — the quality-delta primitive used by
/// Table I / Fig. 3 / Fig. 7 experiments.
pub fn golden_vs_masked(
    scene: &Scene,
    cam: &Camera,
    opts: &RenderOptions,
    masks: &mut dyn crate::render::raster::MaskProvider,
) -> (Image, Image) {
    use crate::render::project::project_scene;
    use crate::render::sort::sort_by_depth;
    use crate::render::tile::{build_tile_lists, TileGrid};

    let golden = crate::render::raster::render(scene, cam, opts);
    let splats = project_scene(scene, cam);
    let grid = TileGrid::new(cam.intr.width, cam.intr.height, opts.tile_size);
    let mut lists = build_tile_lists(&splats, &grid, opts.strategy);
    for l in &mut lists {
        sort_by_depth(l, &splats);
    }
    let masked = crate::render::raster::render_lists(&splats, &lists, &grid, opts, masks, None);
    (golden.image, masked.image)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::Intrinsics;
    use crate::cat::{LeaderMode, Precision};
    use crate::numeric::linalg::v3;
    use crate::render::metrics::psnr;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn setup() -> (Scene, Camera) {
        let scene = generate_scaled(&preset("truck"), 0.02);
        let cam = Camera::look_at(
            Intrinsics::from_fov(96, 96, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        (scene, cam)
    }

    #[test]
    fn golden_and_cat_agree_visually() {
        let (scene, cam) = setup();
        let req = FrameRequest {
            scene: &scene,
            camera: &cam,
            options: RenderOptions::default(),
        };
        let golden = render_frame(&req, &Golden).unwrap();
        let cat = render_frame(
            &req,
            &GoldenCat(CatConfig {
                mode: LeaderMode::UniformDense,
                precision: Precision::Fp32,
                stage1: true,
            }),
        )
        .unwrap();
        let p = psnr(&golden.image, &cat.image);
        assert!(p > 30.0, "CAT vs golden PSNR {p}");
        // CAT must reduce tested work.
        assert!(cat.stats.pairs_tested < golden.stats.pairs_tested);
    }

    #[test]
    fn orbit_runs_all_frames() {
        let cfg = ExperimentConfig {
            scene: "truck".into(),
            scene_scale: 0.01,
            resolution: 64,
            frames: 2,
            ..Default::default()
        };
        let frames = render_orbit(&cfg, &Golden).unwrap();
        assert_eq!(frames.len(), 2);
        for f in frames {
            assert_eq!(f.backend, "golden");
            assert!(f.wall_ms > 0.0);
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_backend_composes_if_artifacts_present() {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = match crate::runtime::Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: pjrt runtime unavailable ({e})");
                return;
            }
        };
        let (scene, cam) = setup();
        let req = FrameRequest {
            scene: &scene,
            camera: &cam,
            options: RenderOptions::default(),
        };
        let golden = render_frame(&req, &Golden).unwrap();
        let pjrt = render_frame(&req, &Pjrt::new(&rt)).unwrap();
        let p = psnr(&golden.image, &pjrt.image);
        assert!(p > 28.0, "PJRT vs golden PSNR {p}");
    }
}
