//! Per-frame rendering coordination.
//!
//! [`RenderBackend`] is the extension point: a backend turns a prepared
//! [`FramePlan`] into an image + stats, and new execution engines slot in
//! without touching callers. The preferred driver is
//! [`super::session::Session`] — it owns the prepared scene, the camera
//! orbit, and a per-view plan cache, and every backend renders from the
//! same cached intermediates. This module keeps the backend trait, the
//! per-frame types, and [`render_planned`] (the caller-held-plan primitive
//! the session and the multi-tenant [`super::service`] are built on).
//! Backends must be `Sync` so frame streams can fan across the worker
//! pool.

use crate::cat::CatConfig;
use crate::render::image::Image;
use crate::render::plan::FramePlan;
use crate::render::raster::{RenderOutput, RenderStats, VanillaMasks};
use crate::util::error::Result;
use std::time::Instant;

/// What came back.
#[derive(Clone)]
pub struct FrameMetrics {
    /// The rendered frame.
    pub image: Image,
    /// Workload counters.
    pub stats: RenderStats,
    /// Wall-clock render time in milliseconds.
    pub wall_ms: f64,
    /// Name of the backend that rendered the frame.
    pub backend: &'static str,
    /// Orbit/view index the frame was rendered from (0 for one-shot
    /// renders outside a session). `FrameStream` consumers use this to
    /// re-sort completion-order results into orbit order.
    pub view: usize,
    /// Owning client in a multi-tenant drain (0 outside the render
    /// service). Together with `view` this re-joins coalesced
    /// completion-order output into per-client frame sequences.
    pub client: usize,
}

/// An execution engine for a prepared frame's tiles.
pub trait RenderBackend: Sync {
    /// Short stable name recorded in [`FrameMetrics`].
    fn name(&self) -> &'static str;

    /// Render a prepared [`FramePlan`]. Implementations honor
    /// `plan.opts.workers` for their internal tile fan-out where
    /// parallelism is safe, and must not re-derive splats or tile lists —
    /// the plan is the single source of frame-preparation truth, which is
    /// what lets callers reuse it across backends and configs.
    fn render_plan(&self, plan: &FramePlan) -> Result<RenderOutput>;
}

/// Pure-Rust golden rasterizer, vanilla masks.
pub struct Golden;

impl RenderBackend for Golden {
    fn name(&self) -> &'static str {
        "golden"
    }

    fn render_plan(&self, plan: &FramePlan) -> Result<RenderOutput> {
        Ok(plan.render(&VanillaMasks, None))
    }
}

/// Golden rasterizer with Mini-Tile CAT masks at the given config.
pub struct GoldenCat(
    /// The CAT configuration driving mask generation.
    pub CatConfig,
);

impl RenderBackend for GoldenCat {
    fn name(&self) -> &'static str {
        "golden+cat"
    }

    fn render_plan(&self, plan: &FramePlan) -> Result<RenderOutput> {
        Ok(plan.render(&self.0, None))
    }
}

/// AOT JAX/Pallas artifacts through PJRT (only with `--features pjrt`).
/// Consumes the coordinator's [`FramePlan`] directly — no host-side
/// re-projection or re-binning. The tile queue drains through the batched
/// `render_tile_batched` artifact, up to `RenderOptions::batch` tiles per
/// dispatch (0 = the artifact's full `n_batch`; ragged final batches are
/// padded with zero-opacity rows), instead of serializing one `exec_f32`
/// call per tile — images are identical for every batch setting
/// (bit-identical under the stub-interpreted artifacts, enforced in CI).
/// Whole frames still serialize through an internal gate: the executor
/// chunks splat lists and carries transmittance on the host, and PJRT
/// executable thread-safety is owned by the runtime, so concurrent frames
/// (a session's stream fan-out) queue rather than enter `exec_f32` in
/// parallel.
#[cfg(feature = "pjrt")]
pub struct Pjrt<'rt> {
    rt: &'rt crate::runtime::Runtime,
    gate: std::sync::Mutex<()>,
}

#[cfg(feature = "pjrt")]
impl<'rt> Pjrt<'rt> {
    /// New PJRT backend over a loaded runtime.
    pub fn new(rt: &'rt crate::runtime::Runtime) -> Self {
        Pjrt {
            rt,
            gate: std::sync::Mutex::new(()),
        }
    }
}

#[cfg(feature = "pjrt")]
impl RenderBackend for Pjrt<'_> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn render_plan(&self, plan: &FramePlan) -> Result<RenderOutput> {
        use crate::runtime::executor::{TileExecutor, TileJob};

        let _serial = self
            .gate
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut img = Image::new(plan.grid.width, plan.grid.height);
        let mut ex = TileExecutor::new(self.rt).with_batch(plan.opts.batch);
        // Coarse tile-level gate: the device kernel zeroes α < 1/255
        // itself, so dropping whole-tile rejects from the job lists is
        // lossless (the gate rejects exactly the pairs whose max in-tile
        // α is below the blend floor).
        let gated = plan.gated_lists();
        let lists = gated.as_ref().map(|(l, _)| l).unwrap_or(&plan.lists);
        // Adaptive precision: classify tiles from the plan (the gate keeps
        // per-tile index alignment, so classes stay valid for gated lists)
        // and dispatch precision-pure waves through the per-class
        // monomorphized artifacts. Rect mode refines mid/high-energy tiles
        // to per-quadrant classes; mixed tiles split into one job per
        // distinct class and the executor stitches quadrant outputs.
        let classes = plan.tile_classes();
        let rect_maps = plan.tile_rect_classes();
        let jobs = match (&rect_maps, &classes) {
            (Some(m), _) => TileJob::for_grid_rect_classed(&plan.grid, lists, m),
            (None, Some(c)) => TileJob::for_grid_classed(&plan.grid, lists, c),
            (None, None) => TileJob::for_grid(&plan.grid, lists),
        };
        ex.render_tiles(&jobs, &plan.splats, &mut img, plan.opts.background)?;
        let mut stats = plan.frame_stats();
        match &gated {
            Some((_, rejected)) => {
                stats.gate_tile_tested = stats.tile_pairs as u64;
                stats.gate_tile_rejected = *rejected;
                stats.splats_submitted = stats.tile_pairs as u64 - *rejected;
            }
            None => stats.splats_submitted = stats.tile_pairs as u64,
        }
        Ok(RenderOutput {
            image: img,
            stats,
        })
    }
}

/// Render a **prebuilt** plan through the chosen backend — the primitive
/// `Session::frame`/`Session::sweep` are built on: build the plan once per
/// view, then render it under many backends/configs. The wall-clock covers
/// only the render; `view` is 0 (sessions stamp the real index).
pub fn render_planned(plan: &FramePlan, backend: &dyn RenderBackend) -> Result<FrameMetrics> {
    let t0 = Instant::now();
    let out = backend.render_plan(plan)?;
    Ok(FrameMetrics {
        image: out.image,
        stats: out.stats,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        backend: backend.name(),
        view: 0,
        client: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::cat::{LeaderMode, Precision};
    use crate::config::ExperimentConfig;
    use crate::coordinator::session::Session;
    use crate::numeric::linalg::v3;
    use crate::render::metrics::psnr;
    use crate::render::raster::RenderOptions;
    use crate::scene::gaussian::Scene;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn setup() -> (Scene, Camera) {
        let scene = generate_scaled(&preset("truck"), 0.02);
        let cam = Camera::look_at(
            Intrinsics::from_fov(96, 96, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        (scene, cam)
    }

    fn session() -> Session {
        let (scene, cam) = setup();
        Session::builder(ExperimentConfig::default())
            .scene(scene)
            .cameras(vec![cam])
            .build()
            .unwrap()
    }

    #[test]
    fn golden_and_cat_agree_visually() {
        let s = session();
        let golden = s.frame(0, &Golden).unwrap();
        let cat = s
            .frame(
                0,
                &GoldenCat(CatConfig {
                    mode: LeaderMode::UniformDense,
                    precision: Precision::Fp32,
                    stage1: true,
                }),
            )
            .unwrap();
        let p = psnr(&golden.image, &cat.image);
        assert!(p > 30.0, "CAT vs golden PSNR {p}");
        // CAT must reduce tested work.
        assert!(cat.stats.pairs_tested < golden.stats.pairs_tested);
        // Both renders shared one cached plan.
        assert_eq!(s.plan_cache_stats().builds, 1);
    }

    #[test]
    fn planned_render_matches_session_frame() {
        // render_planned over a caller-held plan must reproduce the
        // session's cached-plan render bit for bit.
        let (scene, cam) = setup();
        let opts = RenderOptions::default();
        let plan = FramePlan::build(&scene, &cam, &opts);
        let a = render_planned(&plan, &Golden).unwrap();
        let b = render_planned(&plan, &Golden).unwrap();
        assert_eq!(a.image.data, b.image.data, "plan reuse must be stable");
        assert_eq!(a.backend, "golden");
        let s = Session::builder(ExperimentConfig::default())
            .scene(scene)
            .cameras(vec![cam])
            .build()
            .unwrap();
        let m = s.frame(0, &Golden).unwrap();
        assert_eq!(m.image.data, a.image.data);
        assert_eq!(m.view, 0);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_backend_composes_if_artifacts_present() {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = match crate::runtime::Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: pjrt runtime unavailable ({e})");
                return;
            }
        };
        let s = session();
        let pjrt = Pjrt::new(&rt);
        let outs = s.sweep(0, &[&Golden, &pjrt]).unwrap();
        let p = psnr(&outs[0].image, &outs[1].image);
        assert!(p > 28.0, "PJRT vs golden PSNR {p}");
        assert_eq!(s.plan_cache_stats().builds, 1, "sweep shares one plan");
    }
}
