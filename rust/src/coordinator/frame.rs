//! Per-frame rendering coordination.
//!
//! [`RenderBackend`] is the extension point: a backend turns a prepared
//! [`FramePlan`] into an image + stats, and new execution engines slot in
//! without touching `render_frame`/`render_orbit` callers. The coordinator
//! builds the plan (project → tile-bin → depth-sort) exactly once per
//! frame and hands every backend the same intermediates — sweeps that
//! re-render one view through many backends or configs reuse the plan via
//! [`render_planned`]. Backends must be `Sync` so [`render_orbit`] can fan
//! frames across the worker pool.

use crate::camera::Camera;
use crate::cat::CatConfig;
use crate::config::ExperimentConfig;
use crate::render::image::Image;
use crate::render::plan::FramePlan;
use crate::render::raster::{RenderOptions, RenderOutput, RenderStats, VanillaMasks};
use crate::scene::gaussian::Scene;
use crate::util::error::Result;
use crate::util::pool;
use std::time::Instant;

/// A frame to render.
pub struct FrameRequest<'a> {
    /// The scene to render.
    pub scene: &'a Scene,
    /// The viewpoint.
    pub camera: &'a Camera,
    /// Rasterization settings (tile size, strategy, workers, …).
    pub options: RenderOptions,
}

/// What came back.
#[derive(Clone)]
pub struct FrameMetrics {
    /// The rendered frame.
    pub image: Image,
    /// Workload counters.
    pub stats: RenderStats,
    /// Wall-clock render time in milliseconds.
    pub wall_ms: f64,
    /// Name of the backend that rendered the frame.
    pub backend: &'static str,
}

/// An execution engine for a prepared frame's tiles.
pub trait RenderBackend: Sync {
    /// Short stable name recorded in [`FrameMetrics`].
    fn name(&self) -> &'static str;

    /// Render a prepared [`FramePlan`]. Implementations honor
    /// `plan.opts.workers` for their internal tile fan-out where
    /// parallelism is safe, and must not re-derive splats or tile lists —
    /// the plan is the single source of frame-preparation truth, which is
    /// what lets callers reuse it across backends and configs.
    fn render_plan(&self, plan: &FramePlan) -> Result<RenderOutput>;
}

/// Pure-Rust golden rasterizer, vanilla masks.
pub struct Golden;

impl RenderBackend for Golden {
    fn name(&self) -> &'static str {
        "golden"
    }

    fn render_plan(&self, plan: &FramePlan) -> Result<RenderOutput> {
        Ok(plan.render(&VanillaMasks, None))
    }
}

/// Golden rasterizer with Mini-Tile CAT masks at the given config.
pub struct GoldenCat(
    /// The CAT configuration driving mask generation.
    pub CatConfig,
);

impl RenderBackend for GoldenCat {
    fn name(&self) -> &'static str {
        "golden+cat"
    }

    fn render_plan(&self, plan: &FramePlan) -> Result<RenderOutput> {
        Ok(plan.render(&self.0, None))
    }
}

/// AOT JAX/Pallas artifacts through PJRT (only with `--features pjrt`).
/// Consumes the coordinator's [`FramePlan`] directly — no host-side
/// re-projection or re-binning. Tiles run sequentially, and whole frames
/// serialize through an internal gate: the executor chunks splat lists and
/// carries transmittance on the host, and PJRT executable thread-safety is
/// owned by the runtime, so concurrent frames (the `render_orbit` fan-out)
/// queue rather than enter `exec_f32` in parallel.
#[cfg(feature = "pjrt")]
pub struct Pjrt<'rt> {
    rt: &'rt crate::runtime::Runtime,
    gate: std::sync::Mutex<()>,
}

#[cfg(feature = "pjrt")]
impl<'rt> Pjrt<'rt> {
    /// New PJRT backend over a loaded runtime.
    pub fn new(rt: &'rt crate::runtime::Runtime) -> Self {
        Pjrt {
            rt,
            gate: std::sync::Mutex::new(()),
        }
    }
}

#[cfg(feature = "pjrt")]
impl RenderBackend for Pjrt<'_> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn render_plan(&self, plan: &FramePlan) -> Result<RenderOutput> {
        use crate::runtime::executor::TileExecutor;

        let _serial = self
            .gate
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut img = Image::new(plan.grid.width, plan.grid.height);
        let mut ex = TileExecutor::new(self.rt);
        for (t, list) in plan.lists.iter().enumerate() {
            ex.render_tile(
                &plan.grid.rect(t),
                &plan.splats,
                list,
                &mut img,
                plan.opts.background,
            )?;
        }
        Ok(RenderOutput {
            image: img,
            stats: plan.frame_stats(),
        })
    }
}

/// Render one frame through the chosen backend: build the [`FramePlan`]
/// and render it once. The wall-clock covers build + render — the
/// one-shot cost a sweep amortizes away via [`render_planned`].
pub fn render_frame(req: &FrameRequest, backend: &dyn RenderBackend) -> Result<FrameMetrics> {
    let t0 = Instant::now();
    let plan = FramePlan::build(req.scene, req.camera, &req.options);
    let out = backend.render_plan(&plan)?;
    Ok(FrameMetrics {
        image: out.image,
        stats: out.stats,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        backend: backend.name(),
    })
}

/// Render a **prebuilt** plan through the chosen backend — the sweep
/// primitive: build the plan once per view, then render it under many
/// backends/configs. The wall-clock covers only the render.
pub fn render_planned(plan: &FramePlan, backend: &dyn RenderBackend) -> Result<FrameMetrics> {
    let t0 = Instant::now();
    let out = backend.render_plan(plan)?;
    Ok(FrameMetrics {
        image: out.image,
        stats: out.stats,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        backend: backend.name(),
    })
}

/// Render an experiment's whole camera orbit, fanning frames across the
/// worker pool (`cfg.workers`; 0 = auto, 1 = sequential). Frames are
/// independent, so any worker count returns bit-identical images in orbit
/// order. The worker budget is split: up to one thread per frame, and each
/// frame spends the remainder on its tile fan-out, so short orbits on wide
/// machines still use the whole allotment without oversubscribing.
pub fn render_orbit(
    cfg: &ExperimentConfig,
    backend: &dyn RenderBackend,
) -> Result<Vec<FrameMetrics>> {
    let scene = cfg.build_scene()?;
    let cams = cfg.build_cameras();
    let total_workers = pool::resolve_workers(cfg.workers);
    let frame_workers = total_workers.min(cams.len().max(1));
    let tile_workers = (total_workers / frame_workers.max(1)).max(1);
    let frames: Vec<Result<FrameMetrics>> =
        pool::map_indexed(cams.len(), frame_workers, |i| {
            let req = FrameRequest {
                scene: &scene,
                camera: &cams[i],
                options: RenderOptions {
                    workers: tile_workers,
                    ..RenderOptions::default()
                },
            };
            render_frame(&req, backend)
        });
    frames.into_iter().collect()
}

/// Convenience: render the same frame through Golden and a mask provider,
/// returning (golden, masked) images — the quality-delta primitive used by
/// Table I / Fig. 3 / Fig. 7 experiments. Both renders share one
/// [`FramePlan`], so frame preparation runs once.
pub fn golden_vs_masked(
    scene: &Scene,
    cam: &Camera,
    opts: &RenderOptions,
    masks: &mut dyn crate::render::raster::MaskProvider,
) -> (Image, Image) {
    let plan = FramePlan::build(scene, cam, opts);
    let golden = plan.render(&VanillaMasks, None);
    let masked = plan.render_with(masks, None);
    (golden.image, masked.image)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::Intrinsics;
    use crate::cat::{LeaderMode, Precision};
    use crate::numeric::linalg::v3;
    use crate::render::metrics::psnr;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn setup() -> (Scene, Camera) {
        let scene = generate_scaled(&preset("truck"), 0.02);
        let cam = Camera::look_at(
            Intrinsics::from_fov(96, 96, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        (scene, cam)
    }

    #[test]
    fn golden_and_cat_agree_visually() {
        let (scene, cam) = setup();
        let req = FrameRequest {
            scene: &scene,
            camera: &cam,
            options: RenderOptions::default(),
        };
        let golden = render_frame(&req, &Golden).unwrap();
        let cat = render_frame(
            &req,
            &GoldenCat(CatConfig {
                mode: LeaderMode::UniformDense,
                precision: Precision::Fp32,
                stage1: true,
            }),
        )
        .unwrap();
        let p = psnr(&golden.image, &cat.image);
        assert!(p > 30.0, "CAT vs golden PSNR {p}");
        // CAT must reduce tested work.
        assert!(cat.stats.pairs_tested < golden.stats.pairs_tested);
    }

    #[test]
    fn planned_render_matches_oneshot() {
        // render_planned over a reused plan must reproduce render_frame.
        let (scene, cam) = setup();
        let opts = RenderOptions::default();
        let req = FrameRequest {
            scene: &scene,
            camera: &cam,
            options: opts,
        };
        let oneshot = render_frame(&req, &Golden).unwrap();
        let plan = FramePlan::build(&scene, &cam, &opts);
        let a = render_planned(&plan, &Golden).unwrap();
        let b = render_planned(&plan, &Golden).unwrap();
        assert_eq!(oneshot.image.data, a.image.data);
        assert_eq!(a.image.data, b.image.data, "plan reuse must be stable");
        assert_eq!(a.backend, "golden");
    }

    #[test]
    fn orbit_runs_all_frames() {
        let cfg = ExperimentConfig {
            scene: "truck".into(),
            scene_scale: 0.01,
            resolution: 64,
            frames: 2,
            ..Default::default()
        };
        let frames = render_orbit(&cfg, &Golden).unwrap();
        assert_eq!(frames.len(), 2);
        for f in frames {
            assert_eq!(f.backend, "golden");
            assert!(f.wall_ms > 0.0);
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_backend_composes_if_artifacts_present() {
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = match crate::runtime::Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: pjrt runtime unavailable ({e})");
                return;
            }
        };
        let (scene, cam) = setup();
        let req = FrameRequest {
            scene: &scene,
            camera: &cam,
            options: RenderOptions::default(),
        };
        let golden = render_frame(&req, &Golden).unwrap();
        let pjrt = render_frame(&req, &Pjrt::new(&rt)).unwrap();
        let p = psnr(&golden.image, &pjrt.image);
        assert!(p > 28.0, "PJRT vs golden PSNR {p}");
    }
}
