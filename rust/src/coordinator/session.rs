//! `Session`: the typed, plan-cached, streaming rendering API.
//!
//! FLICKER's contribution-aware pipeline amortizes its win by testing once
//! and reusing everywhere; the host-side analog is the `FramePlan`, and a
//! [`Session`] is the object that owns the reuse. Built once from an
//! [`ExperimentConfig`] via [`SessionBuilder`], it holds:
//!
//! * the prepared scene (optionally pruned, with the [`PruneReport`] kept
//!   for provenance instead of printed and lost),
//! * the camera orbit and the **full** resolved [`RenderOptions`]
//!   (strategy, tile size, worker budget — nothing silently dropped),
//! * the resolved worker-budget split (frames × tiles), and
//! * a lazily-built **per-view [`FramePlan`] cache** shared across
//!   backends, with build/hit counters.
//!
//! ```text
//!   ExperimentConfig ─► SessionBuilder ─► Session
//!                                          ├─ frame(i, backend)   one view, cached plan
//!                                          ├─ sweep(i, backends)  many backends, ONE plan
//!                                          └─ stream(backend)     FrameStream: frames fan
//!                                                                 across the pool, yielded
//!                                                                 in completion order per
//!                                                                 dispatch window
//!                                                                 (.ordered() = orbit order)
//! ```
//!
//! **Determinism.** Plans are immutable after build and every consumer
//! shares the one blending loop, so `frame`, `sweep`, and `stream` (in any
//! completion order, re-sorted by [`FrameMetrics::view`] or drained
//! through [`FrameStream::ordered`]) are bit-identical to sequential
//! rendering for any worker count — enforced by
//! `rust/tests/determinism.rs`.

use crate::camera::Camera;
use crate::config::ExperimentConfig;
use crate::coordinator::frame::{render_planned, FrameMetrics, RenderBackend};
use crate::coordinator::report::Report;
use crate::err;
use crate::render::delta::pose_angle;
use crate::render::plan::FramePlan;
use crate::render::raster::RenderOptions;
use crate::scene::gaussian::Scene;
use crate::scene::pruning::{prune, PruneConfig, PruneReport};
use crate::util::error::Result;
use crate::util::pool;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Builder for a [`Session`]: start from an [`ExperimentConfig`] with
/// [`Session::builder`], optionally override the scene, cameras, render
/// options, or pruning, then [`SessionBuilder::build`].
pub struct SessionBuilder {
    cfg: ExperimentConfig,
    scene: Option<Scene>,
    cameras: Option<Vec<Camera>>,
    options: Option<RenderOptions>,
    prune: Option<PruneConfig>,
}

impl SessionBuilder {
    /// Use this scene instead of building one from the config
    /// (`cfg.build_scene()`). Pruning, if requested, still applies.
    pub fn scene(mut self, scene: Scene) -> SessionBuilder {
        self.scene = Some(scene);
        self
    }

    /// Use these evaluation cameras instead of the config's orbit
    /// (`cfg.build_cameras()`). They are also the scoring views when
    /// pruning is requested.
    pub fn cameras(mut self, cams: Vec<Camera>) -> SessionBuilder {
        self.cameras = Some(cams);
        self
    }

    /// Use these render options **verbatim** for every plan, instead of
    /// the config-derived options with the frames×tiles budget split.
    /// `options.workers` then drives each frame's tile fan-out directly,
    /// and [`Session::stream`] still fans frames across up to
    /// `min(resolve(options.workers), frames)` workers — callers that both
    /// stream and set explicit options own the oversubscription trade-off
    /// (outputs are bit-identical regardless).
    pub fn options(mut self, options: RenderOptions) -> SessionBuilder {
        self.options = Some(options);
        self
    }

    /// Prune the scene with this config before any rendering, even if the
    /// experiment config's `prune` flag is off. Without this override,
    /// pruning runs when `cfg.prune` is set, using `PruneConfig::default()`
    /// with the config's worker budget.
    pub fn prune(mut self, cfg: PruneConfig) -> SessionBuilder {
        self.prune = Some(cfg);
        self
    }

    /// Prepare the session: build (or take) the scene and cameras, run the
    /// pruning pass if requested, resolve the worker-budget split, and set
    /// up the (empty) per-view plan cache. No `FramePlan` is built here —
    /// plans materialize lazily on first use of each view.
    pub fn build(self) -> Result<Session> {
        let SessionBuilder {
            cfg,
            scene,
            cameras,
            options,
            prune: prune_override,
        } = self;
        let mut scene = match scene {
            Some(s) => s,
            None => cfg.build_scene()?,
        };
        let cams = cameras.unwrap_or_else(|| cfg.build_cameras());
        if cams.is_empty() {
            return Err(err!("session needs at least one camera"));
        }
        let prune_report = if prune_override.is_some() || cfg.prune {
            let pcfg = prune_override.unwrap_or_else(|| PruneConfig {
                workers: cfg.workers,
                ..PruneConfig::default()
            });
            Some(prune(&mut scene, &cams, &pcfg))
        } else {
            None
        };

        // Worker-budget split: up to one worker per frame for streaming,
        // the remainder to each frame's tile fan-out — short orbits on
        // wide machines still use the whole allotment without
        // oversubscribing. Explicit options are taken verbatim.
        let explicit = options.is_some();
        let base = match options {
            Some(o) => o,
            None => cfg.render_options()?,
        };
        let total = pool::resolve_workers(base.workers);
        let frame_workers = total.min(cams.len());
        let opts = if explicit {
            base
        } else {
            RenderOptions {
                workers: (total / frame_workers.max(1)).max(1),
                ..base
            }
        };

        let plans = (0..cams.len()).map(|_| OnceLock::new()).collect();
        Ok(Session {
            cfg,
            scene,
            cams,
            opts,
            frame_workers,
            prune_report,
            plans,
            plan_builds: AtomicUsize::new(0),
            plan_requests: AtomicUsize::new(0),
            delta_builds: AtomicUsize::new(0),
            delta_splats: AtomicUsize::new(0),
            delta_tiles: AtomicUsize::new(0),
        })
    }
}

/// Plan-cache counters (see [`Session::plan_cache_stats`]).
///
/// Invariant for any interleaving of `frame`/`sweep`/`stream` calls:
/// `builds + delta_builds + hits == requests` — every `plan()` call is
/// exactly one cold build, one delta advance, or one cache hit.
#[derive(Clone, Copy, Debug)]
pub struct PlanCacheStats {
    /// Cold cache misses: `FramePlan`s constructed from scratch (including
    /// delta attempts that fell back). A config sweep over one view builds
    /// exactly one plan regardless of backend count.
    pub builds: usize,
    /// Cache misses served by advancing an already-built neighbor view's
    /// plan (`RenderOptions::plan_delta`; bitwise identical to a cold
    /// build). Zero when the delta path is disabled.
    pub delta_builds: usize,
    /// Requests served from the cache without rebuilding.
    pub hits: usize,
    /// Total `plan()` calls (`builds + delta_builds + hits`).
    pub requests: usize,
    /// Splats the delta advances re-binned (newly visible or moved across
    /// tile boundaries), summed over all `delta_builds`.
    pub delta_splats_reprojected: usize,
    /// Tiles whose lists changed membership, summed over all
    /// `delta_builds`.
    pub delta_tiles_patched: usize,
}

/// A prepared rendering session: scene + orbit + options + per-view
/// [`FramePlan`] cache, shared across any number of backends. See the
/// [module docs](self) for the surface and the determinism contract.
pub struct Session {
    cfg: ExperimentConfig,
    scene: Scene,
    cams: Vec<Camera>,
    opts: RenderOptions,
    frame_workers: usize,
    prune_report: Option<PruneReport>,
    plans: Vec<OnceLock<FramePlan>>,
    plan_builds: AtomicUsize,
    plan_requests: AtomicUsize,
    delta_builds: AtomicUsize,
    delta_splats: AtomicUsize,
    delta_tiles: AtomicUsize,
}

impl Session {
    /// Start building a session from an experiment config.
    pub fn builder(cfg: ExperimentConfig) -> SessionBuilder {
        SessionBuilder {
            cfg,
            scene: None,
            cameras: None,
            options: None,
            prune: None,
        }
    }

    /// The experiment config the session was built from (report
    /// provenance, hardware presets).
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The prepared (possibly pruned) scene.
    pub fn scene(&self) -> &Scene {
        &self.scene
    }

    /// All evaluation cameras, in orbit order.
    pub fn cameras(&self) -> &[Camera] {
        &self.cams
    }

    /// Camera of view `i`.
    ///
    /// # Panics
    /// If `i >= num_frames()` (like slice indexing).
    pub fn camera(&self, i: usize) -> &Camera {
        &self.cams[i]
    }

    /// Number of views in the orbit.
    pub fn num_frames(&self) -> usize {
        self.cams.len()
    }

    /// The resolved render options every plan is built with. When derived
    /// from the config, `workers` holds the per-frame tile budget after
    /// the frames×tiles split.
    pub fn options(&self) -> &RenderOptions {
        &self.opts
    }

    /// The pruning pass that shaped the scene, if one ran. Feed it to
    /// [`Report::set_prune_provenance`] (done automatically by
    /// [`Session::report`]).
    pub fn prune_report(&self) -> Option<&PruneReport> {
        self.prune_report.as_ref()
    }

    /// This session's orbit as a multi-tenant request stream: one
    /// [`RenderRequest`](crate::coordinator::service::RenderRequest) per
    /// view (in orbit order, `view` = the orbit index) against `scene` in
    /// a [`RenderService`](crate::coordinator::service::RenderService)
    /// store, tagged with `client` and carrying this session's resolved
    /// options verbatim. Submitting these (interleaved with any other
    /// clients) and re-joining the drained frames by
    /// `(metrics.client, metrics.view)` reproduces `self.frame(i, ...)`
    /// bit for bit — the service harness's bridge from single-tenant
    /// sessions to the shared daemon. The caller registers the scene
    /// (`service.register_scene(session.scene().clone())`) because the
    /// store owns its copy.
    pub fn service_requests(
        &self,
        client: usize,
        scene: crate::coordinator::service::SceneId,
    ) -> Vec<crate::coordinator::service::RenderRequest> {
        self.cams
            .iter()
            .enumerate()
            .map(|(view, &camera)| crate::coordinator::service::RenderRequest {
                client,
                view,
                scene,
                camera,
                options: self.opts,
            })
            .collect()
    }

    /// The cached [`FramePlan`] for view `i`, building it on first access.
    /// Concurrent callers for the same view block on one build; different
    /// views build independently.
    ///
    /// With `RenderOptions::plan_delta` enabled, a first access tries to
    /// **advance** the nearest already-built neighbor view's plan (poses
    /// within `plan_delta.max_angle`) instead of cold-building — bitwise
    /// identical output, counted in [`PlanCacheStats::delta_builds`].
    /// Under concurrent streaming the cold/delta *split* depends on which
    /// neighbors happen to be finished, but the rendered output and the
    /// counter invariant (`builds + delta_builds + hits == requests`) do
    /// not.
    ///
    /// # Panics
    /// If `i >= num_frames()` (like slice indexing).
    pub fn plan(&self, i: usize) -> &FramePlan {
        self.plan_requests.fetch_add(1, Ordering::Relaxed);
        self.plans[i].get_or_init(|| {
            let dcfg = self.opts.plan_delta;
            if dcfg.enabled {
                if let Some(prev) = self.nearest_built_neighbor(i, dcfg.max_angle) {
                    let out = prev.advance_detailed(&self.scene, &self.cams[i], &self.opts);
                    if out.stats.fell_back {
                        self.plan_builds.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.delta_builds.fetch_add(1, Ordering::Relaxed);
                        self.delta_splats
                            .fetch_add(out.stats.splats_reprojected, Ordering::Relaxed);
                        self.delta_tiles
                            .fetch_add(out.stats.tiles_patched, Ordering::Relaxed);
                    }
                    return out.plan;
                }
            }
            self.plan_builds.fetch_add(1, Ordering::Relaxed);
            FramePlan::build(&self.scene, &self.cams[i], &self.opts)
        })
    }

    /// The already-built plan whose camera pose is nearest to view `i`'s,
    /// if any is within `max_angle` radians. Non-blocking: views still
    /// mid-build elsewhere are simply not candidates.
    fn nearest_built_neighbor(&self, i: usize, max_angle: f32) -> Option<&FramePlan> {
        let mut best: Option<(&FramePlan, f32)> = None;
        for (j, slot) in self.plans.iter().enumerate() {
            if j == i {
                continue;
            }
            if let Some(plan) = slot.get() {
                let a = pose_angle(&self.cams[j], &self.cams[i]);
                if a.is_finite() && a <= max_angle && best.map_or(true, |(_, ba)| a < ba) {
                    best = Some((plan, a));
                }
            }
        }
        best.map(|(p, _)| p)
    }

    /// Plan-cache counters: `builds` + `delta_builds` = plans constructed
    /// (≤ one per view for the session's lifetime), `hits` = requests
    /// served from the cache; `builds + delta_builds + hits == requests`
    /// always. The acceptance contract for sweeps: one build per view
    /// regardless of backend count.
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        let builds = self.plan_builds.load(Ordering::Relaxed);
        let delta_builds = self.delta_builds.load(Ordering::Relaxed);
        let requests = self.plan_requests.load(Ordering::Relaxed);
        PlanCacheStats {
            builds,
            delta_builds,
            hits: requests.saturating_sub(builds + delta_builds),
            requests,
            delta_splats_reprojected: self.delta_splats.load(Ordering::Relaxed),
            delta_tiles_patched: self.delta_tiles.load(Ordering::Relaxed),
        }
    }

    /// Render view `i` through `backend` from the cached plan. The
    /// wall-clock covers only the render (the plan build, if this was the
    /// view's first use, is amortized session state). `FrameMetrics::view`
    /// carries `i`.
    pub fn frame(&self, i: usize, backend: &dyn RenderBackend) -> Result<FrameMetrics> {
        if i >= self.cams.len() {
            return Err(err!("frame index {i} out of range ({} views)", self.cams.len()));
        }
        let mut m = render_planned(self.plan(i), backend)?;
        m.view = i;
        Ok(m)
    }

    /// Render view `i` through **many** backends from one cached plan —
    /// the sweep primitive: frame preparation runs at most once no matter
    /// how many backends re-render the view. Results are in backend order.
    pub fn sweep(&self, i: usize, backends: &[&dyn RenderBackend]) -> Result<Vec<FrameMetrics>> {
        if i >= self.cams.len() {
            return Err(err!("frame index {i} out of range ({} views)", self.cams.len()));
        }
        let plan = self.plan(i);
        backends
            .iter()
            .map(|b| {
                let mut m = render_planned(plan, *b)?;
                m.view = i;
                Ok(m)
            })
            .collect()
    }

    /// Stream the whole orbit through `backend`: frames fan across the
    /// frame-worker budget and are yielded as `Result<FrameMetrics>` in
    /// **completion order within each dispatch window** — the
    /// serving-scale primitive frame-level sharding builds on. Frames are
    /// dispatched in windows of the frame-worker budget (the in-flight
    /// set): memory stays bounded by the window, not the orbit, and
    /// `next()` joins the current window before yielding its results (the
    /// safe-borrow trade-off for a pool that borrows the session; a full
    /// drain should use [`FrameStream::ordered`], which skips the
    /// windowing entirely). Re-sorting everything yielded by
    /// [`FrameMetrics::view`] — or draining through `ordered()` — is
    /// bit-identical to calling [`Session::frame`] sequentially.
    pub fn stream<'s>(&'s self, backend: &'s dyn RenderBackend) -> FrameStream<'s> {
        FrameStream {
            session: self,
            backend,
            dispatched: 0,
            buf: VecDeque::new(),
        }
    }

    /// A [`Report`] pre-wired with this session's provenance: the
    /// experiment config and, when the session pruned, the
    /// [`PruneReport`].
    pub fn report(&self, id: &str, title: &str) -> Report {
        let mut r = Report::new(id, title);
        r.set_provenance(self.cfg.to_json());
        if let Some(rep) = &self.prune_report {
            r.set_prune_provenance(rep);
        }
        r
    }
}

/// Streaming frame iterator returned by [`Session::stream`]: yields
/// `Result<FrameMetrics>` in completion order, windowed by the session's
/// frame-worker budget. Dropping the stream mid-orbit abandons the
/// remaining (not yet dispatched) frames without rendering them.
pub struct FrameStream<'s> {
    session: &'s Session,
    backend: &'s dyn RenderBackend,
    dispatched: usize,
    buf: VecDeque<Result<FrameMetrics>>,
}

impl FrameStream<'_> {
    /// Render the next window of frames across the pool and buffer the
    /// results in completion order (ties broken by completion sequence).
    fn fill(&mut self) {
        let n = self.session.cams.len();
        if self.dispatched >= n {
            return;
        }
        let window = self.session.frame_workers.max(1).min(n - self.dispatched);
        let start = self.dispatched;
        self.dispatched += window;
        let session = self.session;
        let backend = self.backend;
        let seq = AtomicUsize::new(0);
        let mut chunk: Vec<(usize, Result<FrameMetrics>)> =
            pool::map_indexed(window, window, |k| {
                let m = session.frame(start + k, backend);
                (seq.fetch_add(1, Ordering::Relaxed), m)
            });
        chunk.sort_by_key(|(done, _)| *done);
        self.buf.extend(chunk.into_iter().map(|(_, m)| m));
    }

    /// Drain the **remaining** frames and return them in orbit order — on
    /// a fresh stream that is the whole orbit, bit-identical to sequential
    /// `session.frame(i)` for any worker count. Frames already consumed
    /// via `next()` are not re-rendered and do not reappear; call
    /// `ordered()` on a fresh stream for a complete orbit. Fails on the
    /// first frame error.
    ///
    /// A full drain has no reason to window: everything not yet dispatched
    /// renders through one continuous work-stealing fan-out (the whole
    /// frame-worker budget stays saturated until the orbit is done),
    /// rather than `next()`'s bounded in-flight windows.
    pub fn ordered(mut self) -> Result<Vec<FrameMetrics>> {
        let mut frames: Vec<FrameMetrics> = Vec::with_capacity(self.session.cams.len());
        for m in self.buf.drain(..) {
            frames.push(m?);
        }
        let n = self.session.cams.len();
        let start = self.dispatched;
        self.dispatched = n;
        if start < n {
            let session = self.session;
            let backend = self.backend;
            let rest = pool::map_indexed(n - start, session.frame_workers, |k| {
                session.frame(start + k, backend)
            });
            for m in rest {
                frames.push(m?);
            }
        }
        frames.sort_by_key(|m| m.view);
        Ok(frames)
    }
}

impl Iterator for FrameStream<'_> {
    type Item = Result<FrameMetrics>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.buf.is_empty() {
            self.fill();
        }
        self.buf.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::frame::Golden;

    fn cfg(frames: usize, workers: usize) -> ExperimentConfig {
        ExperimentConfig {
            scene: "truck".into(),
            scene_scale: 0.01,
            resolution: 64,
            frames,
            workers,
            ..Default::default()
        }
    }

    #[test]
    fn builder_splits_the_worker_budget() {
        // 8-thread budget over 2 frames: 2 frame workers × 4 tile workers.
        let s = Session::builder(cfg(2, 8)).build().unwrap();
        assert_eq!(s.frame_workers, 2);
        assert_eq!(s.options().workers, 4);
        // Explicit options are verbatim.
        let s = Session::builder(cfg(2, 8))
            .options(RenderOptions {
                workers: 8,
                ..RenderOptions::default()
            })
            .build()
            .unwrap();
        assert_eq!(s.options().workers, 8);
    }

    #[test]
    fn plan_cache_counts_builds_and_hits() {
        let s = Session::builder(cfg(2, 1)).build().unwrap();
        let a = s.frame(0, &Golden).unwrap();
        let b = s.frame(0, &Golden).unwrap();
        assert_eq!(a.image.data, b.image.data);
        let st = s.plan_cache_stats();
        assert_eq!(st.builds, 1);
        assert_eq!(st.hits, 1);
        assert_eq!(st.requests, 2);
        assert_eq!(st.delta_builds, 0, "delta path is off by default");
        s.frame(1, &Golden).unwrap();
        let st = s.plan_cache_stats();
        assert_eq!(st.builds, 2);
        assert_eq!(st.builds + st.delta_builds + st.hits, st.requests);
    }

    #[test]
    fn delta_plan_path_is_bit_identical_and_counted() {
        use crate::render::delta::DeltaConfig;
        // 24-view orbit: adjacent poses ~0.26 rad apart, inside the
        // default delta step, so sequential access advances each view
        // from its predecessor.
        let opts = RenderOptions {
            plan_delta: DeltaConfig::on(),
            ..RenderOptions::default()
        };
        let s = Session::builder(cfg(24, 1)).options(opts).build().unwrap();
        let cold = Session::builder(cfg(24, 1)).build().unwrap();
        for i in 0..24 {
            let a = s.frame(i, &Golden).unwrap();
            let b = cold.frame(i, &Golden).unwrap();
            assert_eq!(a.image.data, b.image.data, "view {i}");
        }
        let st = s.plan_cache_stats();
        assert_eq!(st.builds + st.delta_builds, 24, "one construction per view");
        assert!(st.delta_builds >= 20, "delta path barely used: {st:?}");
        assert_eq!(st.builds + st.delta_builds + st.hits, st.requests);
        assert!(st.delta_tiles_patched > 0 || st.delta_splats_reprojected == 0);
    }

    #[test]
    fn gate_config_threads_into_session_plans() {
        // A gate-enabled config must reach the session's resolved options
        // and, through them, every cached plan — and the gated render must
        // stay bit-identical to the ungated one (lossless default
        // threshold) while cutting submitted work.
        let s = Session::builder(ExperimentConfig {
            gate: Some(true),
            ..cfg(1, 1)
        })
        .build()
        .unwrap();
        assert!(s.options().gate.enabled);
        let gated = s.frame(0, &Golden).unwrap();
        assert!(gated.stats.gate_tile_tested > 0);
        assert_eq!(
            gated.stats.splats_submitted + gated.stats.gate_tile_rejected,
            gated.stats.gate_tile_tested
        );
        let plain = Session::builder(cfg(1, 1)).build().unwrap();
        assert!(!plain.options().gate.enabled);
        let base = plain.frame(0, &Golden).unwrap();
        assert_eq!(gated.image.data, base.image.data);
        assert_eq!(base.stats.gate_tile_tested, 0);
        assert!(base.stats.splats_submitted <= base.stats.tile_pairs as u64);
    }

    #[test]
    fn frame_out_of_range_is_an_error_not_a_panic() {
        let s = Session::builder(cfg(1, 1)).build().unwrap();
        assert!(s.frame(1, &Golden).is_err());
        assert!(s.sweep(1, &[&Golden]).is_err());
    }

    #[test]
    fn empty_cameras_is_an_error() {
        assert!(Session::builder(cfg(1, 1)).cameras(Vec::new()).build().is_err());
    }

    #[test]
    fn pruned_session_keeps_the_report() {
        let pruned = Session::builder(ExperimentConfig {
            prune: true,
            ..cfg(2, 1)
        })
        .build()
        .unwrap();
        let rep = pruned.prune_report().expect("prune ran");
        assert!(rep.after < rep.before);
        assert_eq!(rep.views, 2);
        assert_eq!(pruned.scene().len(), rep.after);
        // The session report carries the prune provenance.
        let j = pruned.report("t", "t").to_json();
        assert!(j.at(&["prune", "before"]).is_some());
        // An unpruned session has neither.
        let plain = Session::builder(cfg(2, 1)).build().unwrap();
        assert!(plain.prune_report().is_none());
        assert!(plain.report("t", "t").to_json().at(&["prune"]).is_none());
    }

    #[test]
    fn stream_yields_every_frame_once() {
        let s = Session::builder(cfg(3, 2)).build().unwrap();
        let mut views: Vec<usize> = s.stream(&Golden).map(|m| m.unwrap().view).collect();
        views.sort_unstable();
        assert_eq!(views, vec![0, 1, 2]);
    }
}
