//! Report writer: turns experiment results into the JSON sidecars and
//! human tables the benches and EXPERIMENTS.md consume.

use crate::util::json::{jarr, jnum, jstr, Json, JsonObj};
use std::path::Path;

/// A generic experiment report: named scalar rows plus provenance.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Stable id (JSON sidecar filename).
    pub id: String,
    /// Human-readable title.
    pub title: String,
    rows: Vec<(String, Vec<(String, f64)>)>,
    provenance: Option<Json>,
    prune: Option<Json>,
}

impl Report {
    /// Empty report with an id and title.
    pub fn new(id: &str, title: &str) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            ..Default::default()
        }
    }

    /// Attach the experiment config that produced this report.
    pub fn set_provenance(&mut self, j: Json) {
        self.provenance = Some(j);
    }

    /// Record the pruning pass that shaped this report's scene. The
    /// `PruneReport` (before/after counts, threshold, scoring views,
    /// pairs/px tested) is emitted under the `"prune"` key next to the
    /// config provenance — previously the prune summary was printed to
    /// stdout and lost.
    pub fn set_prune_provenance(&mut self, rep: &crate::scene::pruning::PruneReport) {
        self.prune = Some(rep.to_json());
    }

    /// Add a row with (metric, value) pairs.
    pub fn row(&mut self, name: &str, metrics: &[(&str, f64)]) {
        self.rows.push((
            name.to_string(),
            metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        ));
    }

    /// All rows, in insertion order.
    pub fn rows(&self) -> &[(String, Vec<(String, f64)>)] {
        &self.rows
    }

    /// Find a value.
    pub fn get(&self, row: &str, metric: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|(n, _)| n == row)?
            .1
            .iter()
            .find(|(k, _)| k == metric)
            .map(|(_, v)| *v)
    }

    /// Render as an aligned text table.
    pub fn table(&self) -> String {
        if self.rows.is_empty() {
            return format!("== {} ==\n(empty)\n", self.title);
        }
        // Column set = union of metric names in insertion order.
        let mut cols: Vec<String> = Vec::new();
        for (_, ms) in &self.rows {
            for (k, _) in ms {
                if !cols.contains(k) {
                    cols.push(k.clone());
                }
            }
        }
        let name_w = self
            .rows
            .iter()
            .map(|(n, _)| n.len())
            .max()
            .unwrap_or(4)
            .max(4);
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&format!("{:<name_w$}", ""));
        for c in &cols {
            out.push_str(&format!("  {:>12}", c));
        }
        out.push('\n');
        for (name, ms) in &self.rows {
            out.push_str(&format!("{:<name_w$}", name));
            for c in &cols {
                match ms.iter().find(|(k, _)| k == c) {
                    Some((_, v)) => {
                        if v.abs() >= 1000.0 || (*v != 0.0 && v.abs() < 0.01) {
                            out.push_str(&format!("  {:>12.3e}", v));
                        } else {
                            out.push_str(&format!("  {:>12.3}", v));
                        }
                    }
                    None => out.push_str(&format!("  {:>12}", "-")),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Serialize the report (id, title, provenance, rows) to JSON.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("id", jstr(&self.id));
        o.insert("title", jstr(&self.title));
        if let Some(p) = &self.provenance {
            o.insert("provenance", p.clone());
        }
        if let Some(p) = &self.prune {
            o.insert("prune", p.clone());
        }
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(name, ms)| {
                let mut r = JsonObj::new();
                r.insert("name", jstr(name));
                for (k, v) in ms {
                    r.insert(k.clone(), jnum(*v));
                }
                Json::Obj(r)
            })
            .collect();
        o.insert("rows", jarr(rows));
        Json::Obj(o)
    }

    /// Print the table and write `target/bench-reports/<id>.json`.
    pub fn emit(&self) {
        print!("{}", self.table());
        let dir = Path::new("target/bench-reports");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.id));
        match std::fs::write(&path, self.to_json().pretty()) {
            Ok(()) => println!("(report: {})\n", path.display()),
            Err(e) => eprintln!("warn: {}: {e}", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_union_of_columns() {
        let mut r = Report::new("t", "Test");
        r.row("a", &[("x", 1.0), ("y", 2.0)]);
        r.row("b", &[("y", 3.0), ("z", 4.0)]);
        let t = r.table();
        assert!(t.contains("x"));
        assert!(t.contains("z"));
        assert!(t.contains('-'), "missing metric shown as dash");
    }

    #[test]
    fn get_retrieves_values() {
        let mut r = Report::new("t", "Test");
        r.row("speedup", &[("flicker", 1.5)]);
        assert_eq!(r.get("speedup", "flicker"), Some(1.5));
        assert_eq!(r.get("speedup", "nope"), None);
        assert_eq!(r.get("nope", "flicker"), None);
    }

    #[test]
    fn prune_provenance_is_emitted() {
        use crate::render::raster::RenderStats;
        use crate::scene::pruning::PruneReport;
        let mut r = Report::new("t", "Test");
        r.set_prune_provenance(&PruneReport {
            before: 100,
            after: 60,
            threshold: 0.5,
            views: 3,
            stats: RenderStats {
                pairs_tested: 500,
                pixels: 100,
                ..Default::default()
            },
        });
        let j = r.to_json();
        assert_eq!(j.at(&["prune", "before"]).and_then(Json::as_f64), Some(100.0));
        assert_eq!(j.at(&["prune", "after"]).and_then(Json::as_f64), Some(60.0));
        assert_eq!(
            j.at(&["prune", "pairs_per_px_tested"]).and_then(Json::as_f64),
            Some(5.0)
        );
    }

    #[test]
    fn json_shape() {
        let mut r = Report::new("fig9", "FIFO sweep");
        r.row("depth=16", &[("speedup", 1.3)]);
        let j = r.to_json();
        assert_eq!(j.at(&["id"]).unwrap().as_str(), Some("fig9"));
        assert_eq!(j.at(&["rows"]).unwrap().as_arr().unwrap().len(), 1);
    }
}
