//! Multi-tenant render service: many clients, one accelerator.
//!
//! [`RenderService`] promotes the one-experiment [`super::session::Session`]
//! into a serving daemon:
//!
//! * **Scene store** — scenes register once ([`RenderService::register_scene`])
//!   and are shared immutably (`Arc`) across every client; refcounts
//!   ([`RenderService::retain_scene`] / [`RenderService::release_scene`])
//!   decide eviction, which also purges the scene's cached plans.
//! * **Cross-session plan cache** — `FramePlan`s are keyed by
//!   `(scene, resolved options, quantized camera pose)` using
//!   [`Camera::pose_key`], replacing the per-session `Vec<OnceLock<_>>`:
//!   two clients orbiting the same scene share every plan. A key hit is
//!   verified against the exact pose ([`Camera::same_pose`] — quantization
//!   collisions are near-misses, never servable entries); on a miss the
//!   cache delta-advances from the nearest cached pose (same-cell
//!   neighbors first, then a `pose_angle` scan within the request's
//!   `plan_delta.max_angle`) via `FramePlan::advance`, which is
//!   bit-identical to a cold build.
//! * **Request queue** — [`RenderService::submit`] applies admission
//!   control (bounded queue, rejects counted) ahead of
//!   [`RenderService::drain`], which renders windows of requests across
//!   the one shared [`WorkerPool`] and yields frames in completion order,
//!   `FrameStream`-style.
//! * **Cross-client tile coalescer** (`--features pjrt`) —
//!   [`RenderService::drain_coalesced`] merges every in-flight frame's
//!   tile jobs into shared precision-pure waves through
//!   `TileExecutor::render_tiles_coalesced`, so batch padding amortizes
//!   across tenants and the aggregate `fill_rate` stays near 1.0 even
//!   when each individual frame is ragged.
//!
//! Determinism contract: every frame a drain returns is bit-identical to
//! the same (scene, camera, options) rendered through an isolated
//! `Session` — for any pool size, window, executor batch, interleaving of
//! clients, and cache state (hit, delta-advance, or cold build). Frames
//! re-join their clients via [`FrameMetrics::client`] + `view`; per-client
//! totals re-separate with [`stats_by_client`] (`RenderStats::absorb`).

use crate::camera::{Camera, PoseKey};
use crate::coordinator::frame::{render_planned, FrameMetrics, RenderBackend};
use crate::err;
use crate::render::delta::pose_angle;
use crate::render::plan::FramePlan;
use crate::render::precision::{class_index, PrecisionMode};
use crate::render::raster::{RenderOptions, RenderStats};
use crate::render::tile::Strategy;
use crate::scene::gaussian::Scene;
use crate::util::error::Result;
use crate::util::pool::WorkerPool;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Handle to a scene resident in the service's shared store.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SceneId(u64);

/// Service tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Shared worker-pool size (0 = auto). One pool serves every client —
    /// steady-state serving spawns no threads per request.
    pub workers: usize,
    /// Admission bound: [`RenderService::submit`] rejects once this many
    /// requests are queued.
    pub max_queue: usize,
    /// Frames in flight per [`RenderService::drain`] window (0 = the pool
    /// size). Purely a scheduling knob — output is bit-identical for
    /// every setting.
    pub window: usize,
    /// Pose-quantization cell size for the plan-cache key (world units
    /// for position, dimensionless for rotation entries). See
    /// [`Camera::pose_key`].
    pub pose_quantum: f32,
    /// Cached plans per `(scene, options)` bucket; the oldest entry is
    /// evicted first.
    pub max_plans: usize,
    /// Tiles per coalesced PJRT dispatch (0 = the artifact's full
    /// `n_batch`). Only [`RenderService::drain_coalesced`] reads it;
    /// rendered pixels are identical for every setting.
    pub batch: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 0,
            max_queue: 64,
            window: 0,
            pose_quantum: 1e-3,
            max_plans: 64,
            batch: 0,
        }
    }
}

/// One client frame request: which scene, from where, rendered how.
#[derive(Clone, Copy, Debug)]
pub struct RenderRequest {
    /// Requesting client (tag only — the service does not authenticate).
    pub client: usize,
    /// The client's own frame sequence number, echoed into
    /// [`FrameMetrics::view`] so completion-order output re-joins per
    /// client.
    pub view: usize,
    /// Scene to render, previously registered in the store.
    pub scene: SceneId,
    /// The viewpoint.
    pub camera: Camera,
    /// Resolved render options. Options are part of the plan-cache key:
    /// requests share a cached plan only when every field matches.
    pub options: RenderOptions,
}

/// A completed service frame: the admission ticket plus the rendered
/// metrics (tagged with the owning client and its view index).
#[derive(Clone)]
pub struct ServiceFrame {
    /// Ticket returned by [`RenderService::submit`] for this request.
    pub ticket: u64,
    /// The rendered frame, `client`/`view`-tagged.
    pub metrics: FrameMetrics,
}

/// Aggregate service counters (see [`RenderService::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Scenes resident in the store.
    pub scenes: usize,
    /// Plans currently cached across all buckets.
    pub cached_plans: usize,
    /// Plan lookups served (hits + builds + delta builds).
    pub plan_requests: usize,
    /// Cold `FramePlan::build` calls.
    pub plan_builds: usize,
    /// Plans advanced from a cached neighbor pose (`FramePlan::advance`).
    pub plan_delta_builds: usize,
    /// Exact-pose cache hits.
    pub plan_hits: usize,
    /// Requests admitted by [`RenderService::submit`].
    pub submitted: u64,
    /// Requests rejected by admission control (queue full).
    pub rejected: u64,
    /// Frames delivered by drains.
    pub completed: u64,
    /// Requests currently queued.
    pub pending: usize,
}

struct SceneEntry {
    scene: Arc<Scene>,
    refs: usize,
}

struct PlanEntry {
    pose: PoseKey,
    cam: Camera,
    plan: Arc<FramePlan>,
}

struct Queued {
    ticket: u64,
    req: RenderRequest,
}

/// Bucket key: scene id + the injectively-encoded resolved options (see
/// [`options_words`]) — comparing keys compares options exactly, so two
/// requests share a bucket iff every option field matches bit for bit.
type BucketKey = (u64, Vec<u64>);

/// The multi-tenant serving daemon. See the module docs for the
/// architecture; `&self` methods are safe to call from multiple threads.
pub struct RenderService {
    cfg: ServiceConfig,
    pool: WorkerPool,
    scenes: Mutex<HashMap<u64, SceneEntry>>,
    next_scene: AtomicU64,
    plans: Mutex<HashMap<BucketKey, Vec<PlanEntry>>>,
    queue: Mutex<VecDeque<Queued>>,
    next_ticket: AtomicU64,
    submitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    plan_requests: AtomicUsize,
    plan_builds: AtomicUsize,
    plan_delta_builds: AtomicUsize,
    plan_hits: AtomicUsize,
}

impl RenderService {
    /// Start a service (and its shared worker pool) with the given config.
    pub fn new(cfg: ServiceConfig) -> RenderService {
        RenderService {
            pool: WorkerPool::new(cfg.workers),
            cfg,
            scenes: Mutex::new(HashMap::new()),
            next_scene: AtomicU64::new(0),
            plans: Mutex::new(HashMap::new()),
            queue: Mutex::new(VecDeque::new()),
            next_ticket: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            plan_requests: AtomicUsize::new(0),
            plan_builds: AtomicUsize::new(0),
            plan_delta_builds: AtomicUsize::new(0),
            plan_hits: AtomicUsize::new(0),
        }
    }

    /// The service configuration.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Register a scene in the shared store (refcount 1) and get its
    /// handle. The scene is immutable from here on — every client renders
    /// from the same `Arc`.
    pub fn register_scene(&self, scene: Scene) -> SceneId {
        let id = self.next_scene.fetch_add(1, Ordering::Relaxed) + 1;
        lock(&self.scenes).insert(
            id,
            SceneEntry {
                scene: Arc::new(scene),
                refs: 1,
            },
        );
        SceneId(id)
    }

    /// Shared handle to a stored scene (`None` once evicted).
    pub fn scene(&self, id: SceneId) -> Option<Arc<Scene>> {
        lock(&self.scenes).get(&id.0).map(|e| e.scene.clone())
    }

    /// Add a reference to a stored scene (a second client attaching).
    /// Returns `false` if the scene is unknown.
    pub fn retain_scene(&self, id: SceneId) -> bool {
        match lock(&self.scenes).get_mut(&id.0) {
            Some(e) => {
                e.refs += 1;
                true
            }
            None => false,
        }
    }

    /// Drop a reference to a stored scene. When the last reference goes,
    /// the scene is evicted and every cached plan for it is purged.
    /// Returns `true` if this release evicted the scene.
    pub fn release_scene(&self, id: SceneId) -> bool {
        let evicted = {
            let mut scenes = lock(&self.scenes);
            match scenes.get_mut(&id.0) {
                Some(e) if e.refs > 1 => {
                    e.refs -= 1;
                    false
                }
                Some(_) => {
                    scenes.remove(&id.0);
                    true
                }
                None => false,
            }
        };
        if evicted {
            lock(&self.plans).retain(|(sid, _), _| *sid != id.0);
        }
        evicted
    }

    /// Submit a request. Fails when the scene is unknown or the queue is
    /// at `max_queue` (the rejection is counted — backpressure is the
    /// caller's signal to slow down, not a crash). Returns the admission
    /// ticket, unique per accepted request.
    pub fn submit(&self, req: RenderRequest) -> Result<u64> {
        if self.scene(req.scene).is_none() {
            return Err(err!(
                "service: request for unknown scene (client {}, view {})",
                req.client,
                req.view
            ));
        }
        let mut queue = lock(&self.queue);
        if queue.len() >= self.cfg.max_queue {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(err!(
                "service: queue full ({} pending >= max_queue {})",
                queue.len(),
                self.cfg.max_queue
            ));
        }
        let ticket = self.next_ticket.fetch_add(1, Ordering::Relaxed) + 1;
        queue.push_back(Queued { ticket, req });
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(ticket)
    }

    /// Requests currently queued.
    pub fn pending(&self) -> usize {
        lock(&self.queue).len()
    }

    /// Aggregate counters.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            scenes: lock(&self.scenes).len(),
            cached_plans: lock(&self.plans).values().map(Vec::len).sum(),
            plan_requests: self.plan_requests.load(Ordering::Relaxed),
            plan_builds: self.plan_builds.load(Ordering::Relaxed),
            plan_delta_builds: self.plan_delta_builds.load(Ordering::Relaxed),
            plan_hits: self.plan_hits.load(Ordering::Relaxed),
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            pending: self.pending(),
        }
    }

    /// Drain the queue through `backend`: windows of up to
    /// `ServiceConfig::window` requests fan across the shared pool, and
    /// frames are delivered in completion order within each window
    /// (`FrameStream`-style; sort the result by
    /// `(metrics.client, metrics.view)` or by ticket for a stable order).
    /// The first failed request aborts the drain.
    pub fn drain(&self, backend: &dyn RenderBackend) -> Result<Vec<ServiceFrame>> {
        let window = if self.cfg.window == 0 {
            self.pool.workers()
        } else {
            self.cfg.window
        }
        .max(1);
        let mut out = Vec::new();
        loop {
            let batch: Vec<Queued> = {
                let mut queue = lock(&self.queue);
                let take = window.min(queue.len());
                queue.drain(..take).collect()
            };
            if batch.is_empty() {
                break;
            }
            let seq = AtomicUsize::new(0);
            let mut results: Vec<(usize, Result<ServiceFrame>)> =
                self.pool.map_indexed(batch.len(), |k| {
                    let r = self.render_one(&batch[k], backend);
                    (seq.fetch_add(1, Ordering::Relaxed), r)
                });
            results.sort_by_key(|(s, _)| *s);
            for (_, r) in results {
                out.push(r?);
            }
            self.completed.fetch_add(batch.len() as u64, Ordering::Relaxed);
        }
        Ok(out)
    }

    fn render_one(&self, q: &Queued, backend: &dyn RenderBackend) -> Result<ServiceFrame> {
        let plan = self.plan_for(&q.req)?;
        let mut metrics = render_planned(&plan, backend)?;
        metrics.view = q.req.view;
        metrics.client = q.req.client;
        Ok(ServiceFrame {
            ticket: q.ticket,
            metrics,
        })
    }

    /// Resolve a request's `FramePlan` through the cross-session cache:
    /// exact-pose hit → shared `Arc`; near miss → delta-advance from the
    /// nearest cached pose; otherwise a cold build. Every path yields
    /// bit-identical plans, so which one fires is a pure performance
    /// question (visible in [`ServiceStats`]).
    fn plan_for(&self, req: &RenderRequest) -> Result<Arc<FramePlan>> {
        let scene = self
            .scene(req.scene)
            .ok_or_else(|| err!("service: scene evicted mid-request (client {})", req.client))?;
        self.plan_requests.fetch_add(1, Ordering::Relaxed);
        let key: BucketKey = (req.scene.0, options_words(&req.options));
        let pose = req.camera.pose_key(self.cfg.pose_quantum);

        let neighbor: Option<Arc<FramePlan>> = {
            let map = lock(&self.plans);
            if let Some(bucket) = map.get(&key) {
                if let Some(e) = Self::exact_entry(bucket, &pose, &req.camera) {
                    self.plan_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(e.plan.clone());
                }
                if req.options.plan_delta.enabled {
                    // Same-cell entries are sub-quantum neighbors — the
                    // cheapest delta bases — so the pose-key prefilter
                    // goes first; otherwise scan for the nearest pose
                    // within the delta radius.
                    let radius = req.options.plan_delta.max_angle;
                    let nearest = |es: &mut dyn Iterator<Item = &PlanEntry>| {
                        es.map(|e| (pose_angle(&e.cam, &req.camera), e))
                            .filter(|(a, _)| a.is_finite() && *a <= radius)
                            .min_by(|(a, _), (b, _)| a.partial_cmp(b).expect("finite angles"))
                            .map(|(_, e)| e.plan.clone())
                    };
                    nearest(&mut bucket.iter().filter(|e| e.pose == pose))
                        .or_else(|| nearest(&mut bucket.iter()))
                } else {
                    None
                }
            } else {
                None
            }
        };

        // Build outside the cache lock: plan construction is the expensive
        // path and must not serialize unrelated lookups.
        let (plan, was_delta) = match &neighbor {
            Some(base) => {
                let outcome = base.advance_detailed(&scene, &req.camera, &req.options);
                let was_delta = !outcome.stats.fell_back;
                (Arc::new(outcome.plan), was_delta)
            }
            None => (
                Arc::new(FramePlan::build(&scene, &req.camera, &req.options)),
                false,
            ),
        };
        if was_delta {
            self.plan_delta_builds.fetch_add(1, Ordering::Relaxed);
        } else {
            self.plan_builds.fetch_add(1, Ordering::Relaxed);
        }

        let mut map = lock(&self.plans);
        let bucket = map.entry(key).or_default();
        if let Some(e) = Self::exact_entry(bucket, &pose, &req.camera) {
            // Raced with another builder of the same pose; both plans are
            // bit-identical, keep the resident one.
            return Ok(e.plan.clone());
        }
        if bucket.len() >= self.cfg.max_plans.max(1) {
            bucket.remove(0);
        }
        bucket.push(PlanEntry {
            pose,
            cam: req.camera,
            plan: plan.clone(),
        });
        Ok(plan)
    }

    fn exact_entry<'b>(
        bucket: &'b [PlanEntry],
        pose: &PoseKey,
        cam: &Camera,
    ) -> Option<&'b PlanEntry> {
        bucket
            .iter()
            .find(|e| e.pose == *pose && e.cam.same_pose(cam))
    }

    /// Drain **every** queued request through the cross-client tile
    /// coalescer: all in-flight frames' tile jobs merge into shared
    /// precision-pure waves (`TileExecutor::render_tiles_coalesced`), so
    /// one client's padding slots carry another client's real chunks.
    /// Returns the frames (ticket order) plus the aggregate `ExecStats`
    /// of the shared waves — per-frame `RenderStats` stay separated
    /// exactly as the per-client `Pjrt` backend reports them, and every
    /// image is bit-identical to an isolated `Session` render. Frame
    /// `wall_ms` is the whole coalesced drain (frames complete together
    /// by construction).
    #[cfg(feature = "pjrt")]
    pub fn drain_coalesced(
        &self,
        rt: &crate::runtime::Runtime,
    ) -> Result<(Vec<ServiceFrame>, crate::runtime::executor::ExecStats)> {
        use crate::cat::Precision;
        use crate::render::image::Image;
        use crate::runtime::executor::{SourcedJob, TileExecutor, TileJob, TileSource};

        let t0 = std::time::Instant::now();
        let batch: Vec<Queued> = lock(&self.queue).drain(..).collect();
        if batch.is_empty() {
            return Ok((Vec::new(), Default::default()));
        }
        let plans: Vec<Arc<FramePlan>> = self
            .pool
            .map_indexed(batch.len(), |k| self.plan_for(&batch[k].req))
            .into_iter()
            .collect::<Result<Vec<_>>>()?;
        let gated: Vec<Option<(Vec<Vec<u32>>, u64)>> =
            plans.iter().map(|p| p.gated_lists()).collect();
        let classes: Vec<Option<Vec<Precision>>> =
            plans.iter().map(|p| p.tile_classes()).collect();
        let rect_maps: Vec<Option<Vec<crate::render::precision::TileClassMap>>> =
            plans.iter().map(|p| p.tile_rect_classes()).collect();
        let mut sources: Vec<TileSource> = Vec::with_capacity(plans.len());
        let mut per_jobs: Vec<Vec<TileJob>> = Vec::with_capacity(plans.len());
        for (r, plan) in plans.iter().enumerate() {
            let lists = gated[r].as_ref().map(|(l, _)| l).unwrap_or(&plan.lists);
            per_jobs.push(match (&rect_maps[r], &classes[r]) {
                (Some(m), _) => TileJob::for_grid_rect_classed(&plan.grid, lists, m),
                (None, Some(c)) => TileJob::for_grid_classed(&plan.grid, lists, c),
                (None, None) => TileJob::for_grid(&plan.grid, lists),
            });
            sources.push(TileSource {
                splats: &plan.splats,
                background: plan.opts.background,
            });
        }
        let jobs: Vec<SourcedJob> = per_jobs
            .iter()
            .enumerate()
            .flat_map(|(r, tj)| tj.iter().map(move |&job| SourcedJob { source: r, job }))
            .collect();
        let mut images: Vec<Image> = plans
            .iter()
            .map(|p| Image::new(p.grid.width, p.grid.height))
            .collect();
        let mut ex = TileExecutor::new(rt).with_batch(self.cfg.batch);
        ex.render_tiles_coalesced(&sources, &jobs, &mut images)?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

        let mut out = Vec::with_capacity(batch.len());
        for (r, q) in batch.iter().enumerate() {
            let mut stats = plans[r].frame_stats();
            match &gated[r] {
                Some((_, rejected)) => {
                    stats.gate_tile_tested = stats.tile_pairs as u64;
                    stats.gate_tile_rejected = *rejected;
                    stats.splats_submitted = stats.tile_pairs as u64 - *rejected;
                }
                None => stats.splats_submitted = stats.tile_pairs as u64,
            }
            out.push(ServiceFrame {
                ticket: q.ticket,
                metrics: FrameMetrics {
                    image: std::mem::replace(&mut images[r], Image::new(0, 0)),
                    stats,
                    wall_ms,
                    backend: "pjrt+coalesced",
                    view: q.req.view,
                    client: q.req.client,
                },
            });
        }
        self.completed.fetch_add(out.len() as u64, Ordering::Relaxed);
        Ok((out, ex.stats))
    }
}

/// Per-client totals from a drained frame set, summed via
/// `RenderStats::absorb` — the re-separation half of the coalescing
/// contract (waves mix clients; stats never do).
pub fn stats_by_client(frames: &[ServiceFrame]) -> BTreeMap<usize, RenderStats> {
    let mut out: BTreeMap<usize, RenderStats> = BTreeMap::new();
    for f in frames {
        out.entry(f.metrics.client)
            .or_default()
            .absorb(&f.metrics.stats);
    }
    out
}

/// Injective fixed-layout encoding of every [`RenderOptions`] field into
/// `u64` words — the options half of the plan-cache key. Comparing two
/// encodings compares the options exactly (floats by bit pattern), with no
/// hash-collision risk. Scheduling-only knobs (`workers`, `batch`) are
/// included too: a cached plan carries its options verbatim into the
/// backends, so the cache never substitutes a plan whose embedded options
/// differ in any way from the request's.
pub fn options_words(o: &RenderOptions) -> Vec<u64> {
    let mut w: Vec<u64> = Vec::with_capacity(16);
    w.push(o.tile_size as u64);
    w.push(match o.strategy {
        Strategy::Aabb => 0,
        Strategy::Obb => 1,
    });
    w.push(o.t_min.to_bits() as u64);
    for c in o.background {
        w.push(c.to_bits() as u64);
    }
    w.push(o.workers as u64);
    w.push(o.batch as u64);
    w.push(o.gate.enabled as u64);
    w.push(o.gate.levels as u64);
    w.push(o.gate.threshold.to_bits() as u64);
    w.push(o.plan_delta.enabled as u64);
    w.push(o.plan_delta.max_angle.to_bits() as u64);
    match o.precision.mode {
        PrecisionMode::Global(p) => {
            w.push(1);
            w.push(class_index(p) as u64);
        }
        PrecisionMode::Adaptive { thresholds, floor } => {
            w.push(2);
            w.push(thresholds.fp32_min.to_bits() as u64);
            w.push(thresholds.fp16_min.to_bits() as u64);
            w.push(class_index(floor) as u64);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{orbit_path, Intrinsics};
    use crate::coordinator::frame::Golden;
    use crate::numeric::linalg::v3;
    use crate::render::delta::DeltaConfig;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn small_scene() -> Scene {
        generate_scaled(&preset("truck"), 0.01)
    }

    fn cams(frames: usize) -> Vec<Camera> {
        let intr = Intrinsics::from_fov(64, 64, 1.2);
        orbit_path(intr, v3(0.0, 0.5, 0.0), 12.0, 2.5, frames)
    }

    fn requests(
        client: usize,
        scene: SceneId,
        cams: &[Camera],
        opts: RenderOptions,
    ) -> Vec<RenderRequest> {
        cams.iter()
            .enumerate()
            .map(|(view, &camera)| RenderRequest {
                client,
                view,
                scene,
                camera,
                options: opts,
            })
            .collect()
    }

    #[test]
    fn admission_control_bounds_the_queue() {
        let svc = RenderService::new(ServiceConfig {
            workers: 1,
            max_queue: 2,
            ..Default::default()
        });
        let id = svc.register_scene(small_scene());
        let reqs = requests(0, id, &cams(3), RenderOptions::default());
        assert!(svc.submit(reqs[0]).is_ok());
        assert!(svc.submit(reqs[1]).is_ok());
        let err = svc.submit(reqs[2]);
        assert!(err.is_err(), "third submit must bounce off max_queue=2");
        let st = svc.stats();
        assert_eq!(st.submitted, 2);
        assert_eq!(st.rejected, 1);
        assert_eq!(st.pending, 2);
        // Draining makes room again.
        let frames = svc.drain(&Golden).unwrap();
        assert_eq!(frames.len(), 2);
        assert!(svc.submit(reqs[2]).is_ok());
        assert_eq!(svc.stats().completed, 2);
    }

    #[test]
    fn unknown_scene_is_rejected_at_submit() {
        let svc = RenderService::new(ServiceConfig {
            workers: 1,
            ..Default::default()
        });
        let id = svc.register_scene(small_scene());
        assert!(svc.release_scene(id), "sole reference: release evicts");
        let req = requests(0, id, &cams(1), RenderOptions::default())[0];
        assert!(svc.submit(req).is_err());
        assert_eq!(svc.stats().scenes, 0);
    }

    #[test]
    fn scene_refcounts_gate_eviction_and_purge_plans() {
        let svc = RenderService::new(ServiceConfig {
            workers: 1,
            ..Default::default()
        });
        let id = svc.register_scene(small_scene());
        assert!(svc.retain_scene(id), "second client attaches");
        for req in requests(0, id, &cams(2), RenderOptions::default()) {
            svc.submit(req).unwrap();
        }
        svc.drain(&Golden).unwrap();
        assert_eq!(svc.stats().cached_plans, 2);
        assert!(!svc.release_scene(id), "one ref left: no eviction");
        assert_eq!(svc.stats().cached_plans, 2);
        assert!(svc.release_scene(id), "last ref: evicted");
        assert_eq!(svc.stats().cached_plans, 0, "eviction purges cached plans");
        assert!(!svc.retain_scene(id), "evicted scenes cannot be retained");
    }

    #[test]
    fn plan_cache_shares_across_clients_and_counts_each_path() {
        // Two clients on the same orbit: client 1's drains hit client 0's
        // cached plans exactly (pose-key + exact-pose verification), and
        // the counter invariant hits + builds + deltas == requests holds.
        // A 24-view orbit steps 15° ≈ 0.26 rad, inside the default delta
        // radius (0.35), so client 0's views 1..24 all delta-advance from
        // the previously cached neighbor.
        let svc = RenderService::new(ServiceConfig {
            workers: 1,
            max_queue: 64,
            ..Default::default()
        });
        let id = svc.register_scene(small_scene());
        let path = cams(24);
        let opts = RenderOptions {
            plan_delta: DeltaConfig::on(),
            ..Default::default()
        };
        for req in requests(0, id, &path, opts) {
            svc.submit(req).unwrap();
        }
        let a = svc.drain(&Golden).unwrap();
        let st = svc.stats();
        assert_eq!(st.plan_requests, 24);
        assert_eq!(st.plan_hits, 0);
        assert_eq!(st.plan_builds, 1, "only view 0 is a cold build: {st:?}");
        assert_eq!(st.plan_delta_builds, 23);

        for req in requests(1, id, &path, opts) {
            svc.submit(req).unwrap();
        }
        let b = svc.drain(&Golden).unwrap();
        let st = svc.stats();
        assert_eq!(st.plan_requests, 48);
        assert_eq!(st.plan_hits, 24, "client 1 rides client 0's plans");
        assert_eq!(st.cached_plans, 24);
        // Shared plans render identically for both clients.
        for (fa, fb) in a.iter().zip(&b) {
            assert_eq!(fa.metrics.image.data, fb.metrics.image.data);
            assert_eq!(fa.metrics.client, 0);
            assert_eq!(fb.metrics.client, 1);
            assert_eq!(fa.metrics.view, fb.metrics.view);
        }
    }

    #[test]
    fn options_fork_the_cache_key() {
        let svc = RenderService::new(ServiceConfig {
            workers: 1,
            ..Default::default()
        });
        let id = svc.register_scene(small_scene());
        let cam = cams(1);
        let a = RenderOptions::default();
        let b = RenderOptions {
            background: [0.5, 0.0, 0.0],
            ..RenderOptions::default()
        };
        assert_ne!(options_words(&a), options_words(&b));
        svc.submit(requests(0, id, &cam, a)[0]).unwrap();
        svc.submit(requests(1, id, &cam, b)[0]).unwrap();
        let frames = svc.drain(&Golden).unwrap();
        assert_eq!(svc.stats().plan_builds, 2, "different options never share plans");
        assert_ne!(
            frames[0].metrics.image.data, frames[1].metrics.image.data,
            "the backgrounds differ, so the frames must too"
        );
    }

    #[test]
    fn stats_by_client_reseparates_totals() {
        let svc = RenderService::new(ServiceConfig {
            workers: 1,
            ..Default::default()
        });
        let id = svc.register_scene(small_scene());
        let path = cams(2);
        for c in 0..2 {
            for req in requests(c, id, &path, RenderOptions::default()) {
                svc.submit(req).unwrap();
            }
        }
        let frames = svc.drain(&Golden).unwrap();
        let by_client = stats_by_client(&frames);
        assert_eq!(by_client.len(), 2);
        let total: u64 = frames.iter().map(|f| f.metrics.stats.pixels).sum();
        let reseparated: u64 = by_client.values().map(|s| s.pixels).sum();
        assert_eq!(total, reseparated);
        // Symmetric clients (same orbit, same options) absorb to equal totals.
        assert_eq!(by_client[&0].pixels, by_client[&1].pixels);
        assert_eq!(by_client[&0].pairs_blended, by_client[&1].pairs_blended);
    }
}
