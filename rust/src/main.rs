//! `flicker` CLI — the Layer-3 entry point.
//!
//! Subcommands:
//!   render    Render an orbit through a backend (golden | golden-cat | pjrt)
//!             and write PPM frames + metrics.
//!   simulate  Run the cycle-accurate simulator on a scene/hardware preset.
//!   sweep     FIFO-depth sweep (Fig. 9 style) on one scene.
//!   quality   PSNR/SSIM of CAT modes vs the vanilla render (Table I style).
//!   area      Print the area model breakdown (Table II style).
//!   info      Print scene/workload statistics.

use flicker::camera::Camera;
use flicker::cat::{CatConfig, LeaderMode, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::report::Report;
use flicker::coordinator::{render_frame, FrameRequest, Golden, GoldenCat, RenderBackend};
use flicker::render::metrics::{psnr, ssim};
use flicker::render::raster::RenderOptions;
use flicker::sim::area::{area, AreaParams};
use flicker::sim::top::simulate_frame;
use flicker::sim::HwConfig;
use flicker::util::cli::Args;
use flicker::util::error::Result;
use flicker::{bail, err};

const USAGE: &str = "\
flicker — contribution-aware 3DGS accelerator (paper reproduction)

USAGE: flicker <command> [options]

COMMANDS
  render    --scene S --resolution N --backend golden|golden-cat|pjrt
            [--out-dir D] [--frames K] [--cat-mode M] [--precision P]
  simulate  --scene S --hardware H [--fifo-depth D] [--frames K] [--prune]
  sweep     --scene S --depths 1,2,4,...  FIFO-depth sweep
  quality   --scene S [--prune]           PSNR/SSIM of CAT modes
  area      [--hardware H]                area model breakdown
  info      --scene S                     scene & workload statistics

COMMON OPTIONS
  --scene        garden|truck|train|bicycle|stump|flowers|playroom|drjohnson
                 or a path to a .gsz file              (default garden)
  --scene-scale  fraction of full scene size           (default 0.05, env FLICKER_SCENE_SCALE)
  --resolution   square render size in px              (default 256)
  --workers      tile/frame/prune-scoring worker threads, 0 = auto
                 (default 1; output — images and pruning decisions — is
                 bit-identical for any worker count)
  --hardware     flicker32|flicker32-sparse|simplified32|simplified64|gscore64

The pjrt backend requires a build with `--features pjrt` and AOT artifacts
(`make artifacts`).
";

fn main() {
    let args = Args::from_env(&["prune", "help", "verbose"]);
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    if args.flag("help") || args.command.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.command.as_deref().unwrap() {
        "render" => cmd_render(args),
        "simulate" => cmd_simulate(args),
        "sweep" => cmd_sweep(args),
        "quality" => cmd_quality(args),
        "area" => cmd_area(args),
        "info" => cmd_info(args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn prepared_scene(cfg: &ExperimentConfig) -> Result<flicker::scene::gaussian::Scene> {
    let mut scene = cfg.build_scene()?;
    if cfg.prune {
        let views = cfg.build_cameras();
        // Contribution scoring honors the CLI worker budget; the pruning
        // decision is bit-identical for any --workers value.
        let rep = flicker::scene::pruning::prune(
            &mut scene,
            &views,
            &flicker::scene::pruning::PruneConfig {
                workers: cfg.workers,
                ..Default::default()
            },
        );
        println!(
            "pruned {} → {} gaussians ({} scoring views, {:.1} pairs/px tested)",
            rep.before,
            rep.after,
            rep.views,
            rep.stats.per_pixel_tested()
        );
    }
    Ok(scene)
}

fn cmd_render(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let scene = prepared_scene(&cfg)?;
    let cams = cfg.build_cameras();
    let backend_name = args.str_or("backend", "golden");

    match backend_name.as_str() {
        "golden" => render_orbit_to_disk(args, &cfg, &scene, &cams, &Golden),
        "golden-cat" => {
            let mode = LeaderMode::parse(&args.str_or("cat-mode", "adaptive"))
                .ok_or_else(|| err!("bad --cat-mode"))?;
            let precision = Precision::parse(&args.str_or("precision", "mixed"))
                .ok_or_else(|| err!("bad --precision"))?;
            let backend = GoldenCat(CatConfig {
                mode,
                precision,
                stage1: true,
            });
            render_orbit_to_disk(args, &cfg, &scene, &cams, &backend)
        }
        "pjrt" => cmd_render_pjrt(args, &cfg, &scene, &cams),
        other => bail!("unknown backend '{other}'"),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_render_pjrt(
    args: &Args,
    cfg: &ExperimentConfig,
    scene: &flicker::scene::gaussian::Scene,
    cams: &[Camera],
) -> Result<()> {
    let rt = flicker::runtime::Runtime::load(&flicker::runtime::default_artifact_dir())?;
    println!("pjrt platform: {}", rt.platform());
    render_orbit_to_disk(args, cfg, scene, cams, &flicker::coordinator::Pjrt::new(&rt))
}

#[cfg(not(feature = "pjrt"))]
fn cmd_render_pjrt(
    _args: &Args,
    _cfg: &ExperimentConfig,
    _scene: &flicker::scene::gaussian::Scene,
    _cams: &[Camera],
) -> Result<()> {
    bail!("this build has no PJRT runtime; rebuild with `cargo build --features pjrt`")
}

/// Shared render-command loop: render every orbit camera through `backend`,
/// write PPM frames, and emit the metrics report.
fn render_orbit_to_disk(
    args: &Args,
    cfg: &ExperimentConfig,
    scene: &flicker::scene::gaussian::Scene,
    cams: &[Camera],
    backend: &dyn RenderBackend,
) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.str_or("out-dir", "target/frames"));
    std::fs::create_dir_all(&out_dir)?;
    let mut report = Report::new(
        "render",
        &format!("render {} ({})", scene.name, backend.name()),
    );
    report.set_provenance(cfg.to_json());
    for (i, cam) in cams.iter().enumerate() {
        let req = FrameRequest {
            scene,
            camera: cam,
            options: RenderOptions {
                workers: cfg.workers,
                ..RenderOptions::default()
            },
        };
        let m = render_frame(&req, backend)?;
        let path = out_dir.join(format!("{}_{i:03}.ppm", scene.name));
        m.image.write_ppm(&path)?;
        println!(
            "frame {i}: {:.1} ms, {} splats, {} tile-pairs → {}",
            m.wall_ms,
            m.stats.splats,
            m.stats.tile_pairs,
            path.display()
        );
        report.row(
            &format!("frame{i}"),
            &[
                ("wall_ms", m.wall_ms),
                ("splats", m.stats.splats as f64),
                ("tile_pairs", m.stats.tile_pairs as f64),
                ("pp_tested", m.stats.per_pixel_tested()),
            ],
        );
    }
    report.emit();
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let scene = prepared_scene(&cfg)?;
    let cams = cfg.build_cameras();
    let hw = cfg.build_hw()?;
    let mut report = Report::new(
        "simulate",
        &format!("simulate {} on {}", scene.name, hw.name),
    );
    report.set_provenance(cfg.to_json());
    for (i, cam) in cams.iter().enumerate() {
        let r = simulate_frame(&scene, cam, &hw);
        println!(
            "frame {i}: {} render-cycles, {:.2} ms, {:.1} fps, stall {:.1}%, {:.1} µJ",
            r.render_cycles,
            r.frame_ms,
            r.fps,
            r.pipe.stall_rate() * 100.0,
            r.energy.total_uj()
        );
        report.row(
            &format!("frame{i}"),
            &[
                ("render_cycles", r.render_cycles as f64),
                ("frame_ms", r.frame_ms),
                ("fps", r.fps),
                ("stall_rate", r.pipe.stall_rate()),
                ("energy_uj", r.energy.total_uj()),
                ("dram_mb", r.traffic.total() as f64 / 1e6),
            ],
        );
    }
    report.emit();
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let scene = prepared_scene(&cfg)?;
    let cam = &cfg.build_cameras()[0];
    let depths = args.u64_list_or("depths", &[1, 2, 4, 8, 16, 32, 64, 128])?;
    let base_hw = cfg.build_hw()?;
    let wl = flicker::sim::workload::extract(&scene, cam, &base_hw);
    let mut report = Report::new("sweep", &format!("FIFO sweep on {}", scene.name));
    report.set_provenance(cfg.to_json());
    let mut base_cycles = None;
    for d in depths {
        let hw = HwConfig {
            fifo_depth: d as usize,
            ..base_hw.clone()
        };
        let r = flicker::sim::top::simulate_workload(&scene, cam, &hw, wl.clone());
        let base = *base_cycles.get_or_insert(r.render_cycles as f64);
        report.row(
            &format!("depth={d}"),
            &[
                ("speedup", base / r.render_cycles as f64),
                ("stall_rate", r.pipe.stall_rate()),
                ("cycles", r.render_cycles as f64),
            ],
        );
    }
    report.emit();
    Ok(())
}

fn cmd_quality(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let scene = prepared_scene(&cfg)?;
    let cam = &cfg.build_cameras()[0];
    let opts = RenderOptions {
        workers: cfg.workers,
        ..RenderOptions::default()
    };
    // One FramePlan for the whole sweep: projection, tile binning, and
    // depth sorting run once; every CAT config re-renders from the same
    // prepared intermediates.
    let plan = flicker::render::plan::FramePlan::build(&scene, cam, &opts);
    let golden = plan.render(&flicker::render::raster::VanillaMasks, None);
    let mut report = Report::new("quality", &format!("CAT quality on {}", scene.name));
    report.set_provenance(cfg.to_json());
    for (name, mode, precision) in [
        ("uniform-dense", LeaderMode::UniformDense, Precision::Fp32),
        ("uniform-sparse", LeaderMode::UniformSparse, Precision::Fp32),
        ("adaptive", LeaderMode::SmoothFocused, Precision::Fp32),
        ("adaptive-mixed", LeaderMode::SmoothFocused, Precision::Mixed),
        ("adaptive-fp8", LeaderMode::SmoothFocused, Precision::Fp8),
    ] {
        let cat = CatConfig {
            mode,
            precision,
            stage1: true,
        };
        let out = plan.render(&cat, None);
        report.row(
            name,
            &[
                ("psnr", psnr(&golden.image, &out.image)),
                ("ssim", ssim(&golden.image, &out.image)),
                ("pp_tested", out.stats.per_pixel_tested()),
            ],
        );
    }
    report.emit();
    Ok(())
}

fn cmd_area(args: &Args) -> Result<()> {
    let name = args.str_or("hardware", "flicker32");
    let hw = HwConfig::by_name(&name).ok_or_else(|| err!("unknown hardware '{name}'"))?;
    let r = area(&hw, &AreaParams::default());
    let mut report = Report::new("area", &format!("area breakdown: {}", hw.name));
    for (component, mm2, share) in r.rows() {
        report.row(component, &[("mm2", mm2), ("share", share)]);
    }
    report.row("TOTAL", &[("mm2", r.total_mm2()), ("share", 1.0)]);
    report.emit();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let scene = cfg.build_scene()?;
    let cam: &Camera = &cfg.build_cameras()[0];
    let hw = cfg.build_hw()?;
    let wl = flicker::sim::workload::extract(&scene, cam, &hw);
    println!("scene {}: {} gaussians", scene.name, scene.len());
    println!("  spiky fraction (ratio≥3): {:.2}", scene.spiky_fraction(3.0));
    println!("  visible splats: {}", wl.visible_splats);
    println!("  tile pairs: {}", wl.tile_pairs);
    println!("  stage1 pairs: {} → stage2: {}", wl.stage1_pairs, wl.stage2_pairs);
    println!("  minitile pairs: {}", wl.minitile_pairs);
    println!("  per-pixel processed: {:.2}", wl.per_pixel_processed());
    Ok(())
}
