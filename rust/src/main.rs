//! `flicker` CLI — the Layer-3 entry point.
//!
//! Subcommands:
//!   render    Render an orbit through a backend (golden | golden-cat | pjrt)
//!             and write PPM frames + metrics.
//!   simulate  Run the cycle-accurate simulator on a scene/hardware preset.
//!   sweep     FIFO-depth sweep (Fig. 9 style) on one scene.
//!   quality   PSNR/SSIM of CAT modes vs the vanilla render (Table I style).
//!   area      Print the area model breakdown (Table II style).
//!   info      Print scene/workload statistics.
//!   serve     Multi-client render-service demo: a shared scene store, the
//!             cross-session plan cache, bounded admission, and (pjrt) the
//!             cross-client tile coalescer.
//!
//! Every rendering subcommand drives one `coordinator::Session`: scene
//! prep (with `--prune` recorded as report provenance), the full
//! `RenderOptions` from the config (`--strategy`, `--tile-size`,
//! `--workers`), and a per-view `FramePlan` cache shared across backends.

use flicker::cat::{CatConfig, LeaderMode, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::report::Report;
use flicker::coordinator::{
    Golden, GoldenCat, RenderBackend, RenderRequest, RenderService, Session, ServiceConfig,
    ServiceFrame,
};
use flicker::render::metrics::{latency_summary, psnr, ssim};
use flicker::render::precision::{PrecisionMode, PrecisionPolicy};
use flicker::sim::area::{area, AreaParams};
use flicker::sim::top::simulate_frame;
use flicker::sim::workload::{extract_for, FrameWorkload};
use flicker::sim::HwConfig;
use flicker::util::cli::Args;
use flicker::util::error::Result;
use flicker::{bail, err};

const USAGE: &str = "\
flicker — contribution-aware 3DGS accelerator (paper reproduction)

USAGE: flicker <command> [options]

COMMANDS
  render    --scene S --resolution N --backend golden|golden-cat|pjrt
            [--out-dir D] [--frames K] [--cat-mode M] [--precision P]
  simulate  --scene S --hardware H [--fifo-depth D] [--frames K] [--prune]
  sweep     --scene S --depths 1,2,4,...  FIFO-depth sweep
  quality   --scene S [--prune]           PSNR/SSIM of CAT modes
  area      [--hardware H]                area model breakdown
  info      --scene S                     scene & workload statistics
  serve     --scene S [--clients N] [--queue Q] [--window W]
            [--backend golden|pjrt]       multi-client service demo:
            one shared scene, N interleaved ragged orbits through the
            cross-session plan cache and bounded queue; the pjrt backend
            drains all clients through coalesced precision-pure waves and
            reports the aggregate fill rate

COMMON OPTIONS
  --scene        garden|truck|train|bicycle|stump|flowers|playroom|drjohnson
                 or a path to a .gsz file              (default garden)
  --scene-scale  fraction of full scene size           (default 0.05, env FLICKER_SCENE_SCALE)
  --resolution   square render size in px              (default 256)
  --workers      tile/frame/prune-scoring worker threads, 0 = auto
                 (default 1; output — images and pruning decisions — is
                 bit-identical for any worker count)
  --strategy     tile intersection: aabb|obb           (default aabb)
  --tile-size    tile edge in pixels                   (default 16)
  --batch        tiles per PJRT dispatch (0 = the batched artifact's
                 full n_batch, 1 = single-tile-artifact dispatches;
                 pjrt backend only — output is bit-identical across
                 values on the offline stub, tolerance-equal on real XLA)
  --hardware     flicker32|flicker32-sparse|simplified32|simplified64|gscore64
  --gate         coarse-to-fine contribution gate: on|off  (default off;
                 at the default threshold the gate is lossless — output is
                 bit-identical to ungated rendering)
  --gate-levels  pyramid depth: 1 = whole-tile test only, 2 = + 2×2
                 quadrant tests                         (default 2)
  --gate-threshold  min peak alpha a pair must reach to survive the gate
                 (default 1/255 — the blend floor, i.e. lossless; raise
                 for lossy extra culling)
  --plan-delta   temporal plan deltas: on|off  (default off; advance each
                 view's FramePlan from the nearest already-built neighbor
                 view instead of cold-building — output is bit-identical)
  --plan-delta-angle  largest pose step in radians the delta path accepts
                 before falling back to a cold build  (default 0.35)
  --precision    CTU precision: fp32|fp16|fp8|mixed|adaptive|rect
                 (default mixed; case-insensitive). `adaptive` classes
                 each tile by its contribution bound — low-energy tiles
                 run the cheap mixed/fp8 datapath, leader tiles keep
                 fp32. `rect` refines mid/high-energy tiles one level
                 further, classing each 2×2 quadrant-rectangle from its
                 own energy share. Both are deterministic for any worker
                 count or batch width, but not bitwise-equal to a global
                 mode.
  --precision-thresholds  split points 'FP32MIN,FP16MIN[,FLOOR]'
                 (default 0.6,0.25 with floor mixed; requires
                 --precision adaptive or rect)

The pjrt backend requires a build with `--features pjrt` and AOT artifacts
(`make artifacts`, or any directory written by
runtime::write_stub_artifacts when running against the offline xla stub).
";

fn main() {
    let args = Args::from_env(&["prune", "help", "verbose"]);
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    if args.flag("help") || args.command.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.command.as_deref().unwrap() {
        "render" => cmd_render(args),
        "simulate" => cmd_simulate(args),
        "sweep" => cmd_sweep(args),
        "quality" => cmd_quality(args),
        "area" => cmd_area(args),
        "info" => cmd_info(args),
        "serve" => cmd_serve(args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Echo the session's pruning pass to the console (it is also recorded as
/// report provenance by `session.report`).
fn announce_prune(session: &Session) {
    if let Some(rep) = session.prune_report() {
        println!(
            "pruned {} → {} gaussians ({} scoring views, {:.1} pairs/px tested)",
            rep.before,
            rep.after,
            rep.views,
            rep.stats.per_pixel_tested()
        );
    }
}

/// Workload trace for view 0, reusing the session's cached plan when its
/// geometry is extractor-compatible (the rule lives in
/// `sim::workload::extract_for`; with incompatible options the plan is
/// never built).
fn workload_for(session: &Session, hw: &HwConfig) -> FrameWorkload {
    extract_for(
        session.scene(),
        session.camera(0),
        session.options(),
        || session.plan(0),
        hw,
    )
}

fn cmd_render(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let session = Session::builder(cfg).build()?;
    announce_prune(&session);
    let backend_name = args.str_or("backend", "golden");

    match backend_name.as_str() {
        "golden" => orbit_to_disk(args, &session, &Golden),
        "golden-cat" => {
            let mode = LeaderMode::parse(&args.str_or("cat-mode", "adaptive"))
                .ok_or_else(|| err!("bad --cat-mode"))?;
            let spec = args.str_or("precision", "mixed");
            let policy = PrecisionPolicy::parse(&spec).ok_or_else(|| {
                err!("unknown --precision '{spec}' (valid: fp32|fp16|fp8|mixed|adaptive|rect)")
            })?;
            let precision = match policy.mode {
                PrecisionMode::Global(p) => p,
                // Adaptive/rect: the per-tile (or per-quadrant) class
                // threaded through the session's RenderOptions overrides
                // this base engine precision at every tile; the floor is
                // the inert default.
                PrecisionMode::Adaptive { floor, .. } | PrecisionMode::Rect { floor, .. } => floor,
            };
            let backend = GoldenCat(CatConfig {
                mode,
                precision,
                stage1: true,
            });
            orbit_to_disk(args, &session, &backend)
        }
        "pjrt" => cmd_render_pjrt(args, &session),
        other => bail!("unknown backend '{other}'"),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_render_pjrt(args: &Args, session: &Session) -> Result<()> {
    let rt = flicker::runtime::Runtime::load(&flicker::runtime::default_artifact_dir())?;
    println!("pjrt platform: {}", rt.platform());
    let backend = flicker::coordinator::Pjrt::new(&rt);
    orbit_to_disk(args, session, &backend)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_render_pjrt(_args: &Args, _session: &Session) -> Result<()> {
    bail!("this build has no PJRT runtime; rebuild with `cargo build --features pjrt`")
}

/// Shared render-command loop: stream the session's orbit through
/// `backend` (frames fan across the worker budget) and write each PPM as
/// its frame completes — memory stays bounded by the stream's dispatch
/// window, not the orbit. Only the small report rows are buffered, then
/// sorted into orbit order so the emitted report is deterministic.
fn orbit_to_disk(args: &Args, session: &Session, backend: &dyn RenderBackend) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.str_or("out-dir", "target/frames"));
    std::fs::create_dir_all(&out_dir)?;
    let scene_name = session.scene().name.clone();
    let mut report = session.report(
        "render",
        &format!("render {} ({})", scene_name, backend.name()),
    );
    let mut rows = Vec::with_capacity(session.num_frames());
    for m in session.stream(backend) {
        let m = m?;
        let i = m.view;
        let path = out_dir.join(format!("{scene_name}_{i:03}.ppm"));
        m.image.write_ppm(&path)?;
        println!(
            "frame {i}: {:.1} ms, {} splats, {} tile-pairs, {} submitted → {}",
            m.wall_ms,
            m.stats.splats,
            m.stats.tile_pairs,
            m.stats.splats_submitted,
            path.display()
        );
        rows.push((
            i,
            [
                ("wall_ms", m.wall_ms),
                ("splats", m.stats.splats as f64),
                ("tile_pairs", m.stats.tile_pairs as f64),
                ("splats_submitted", m.stats.splats_submitted as f64),
                ("gate_tile_rejected", m.stats.gate_tile_rejected as f64),
                ("gate_quad_rejected", m.stats.gate_quad_rejected as f64),
                ("pp_tested", m.stats.per_pixel_tested()),
            ],
        ));
    }
    rows.sort_by_key(|(i, _)| *i);
    for (i, metrics) in &rows {
        report.row(&format!("frame{i}"), metrics);
    }
    report.emit();
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let session = Session::builder(cfg).build()?;
    announce_prune(&session);
    let hw = session.config().build_hw()?;
    let mut report = session.report(
        "simulate",
        &format!("simulate {} on {}", session.scene().name, hw.name),
    );
    for (i, cam) in session.cameras().iter().enumerate() {
        let r = simulate_frame(session.scene(), cam, &hw);
        println!(
            "frame {i}: {} render-cycles, {:.2} ms, {:.1} fps, stall {:.1}%, {:.1} µJ",
            r.render_cycles,
            r.frame_ms,
            r.fps,
            r.pipe.stall_rate() * 100.0,
            r.energy.total_uj()
        );
        report.row(
            &format!("frame{i}"),
            &[
                ("render_cycles", r.render_cycles as f64),
                ("frame_ms", r.frame_ms),
                ("fps", r.fps),
                ("stall_rate", r.pipe.stall_rate()),
                ("energy_uj", r.energy.total_uj()),
                ("dram_mb", r.traffic.total() as f64 / 1e6),
            ],
        );
    }
    report.emit();
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let session = Session::builder(cfg).build()?;
    announce_prune(&session);
    let depths = args.u64_list_or("depths", &[1, 2, 4, 8, 16, 32, 64, 128])?;
    let base_hw = session.config().build_hw()?;
    let wl = workload_for(&session, &base_hw);
    let mut report = session.report(
        "sweep",
        &format!("FIFO sweep on {}", session.scene().name),
    );
    let mut base_cycles = None;
    for d in depths {
        let hw = HwConfig {
            fifo_depth: d as usize,
            ..base_hw.clone()
        };
        let r = flicker::sim::top::simulate_workload(
            session.scene(),
            session.camera(0),
            &hw,
            wl.clone(),
        );
        let base = *base_cycles.get_or_insert(r.render_cycles as f64);
        report.row(
            &format!("depth={d}"),
            &[
                ("speedup", base / r.render_cycles as f64),
                ("stall_rate", r.pipe.stall_rate()),
                ("cycles", r.render_cycles as f64),
            ],
        );
    }
    report.emit();
    Ok(())
}

fn cmd_quality(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    // One swept view — no frame fan-out, so hand the whole worker budget
    // to the tile loop via explicit options.
    let opts = cfg.render_options()?;
    let session = Session::builder(cfg).options(opts).build()?;
    announce_prune(&session);
    // One FramePlan for the whole sweep: projection, tile binning, and
    // depth sorting run once; the golden reference and every CAT config
    // re-render from the same cached intermediates.
    let golden = session.frame(0, &Golden)?;
    let mut report = session.report(
        "quality",
        &format!("CAT quality on {}", session.scene().name),
    );
    let configs = [
        ("uniform-dense", LeaderMode::UniformDense, Precision::Fp32),
        ("uniform-sparse", LeaderMode::UniformSparse, Precision::Fp32),
        ("adaptive", LeaderMode::SmoothFocused, Precision::Fp32),
        ("adaptive-mixed", LeaderMode::SmoothFocused, Precision::Mixed),
        ("adaptive-fp8", LeaderMode::SmoothFocused, Precision::Fp8),
    ];
    let backends: Vec<GoldenCat> = configs
        .iter()
        .map(|(_, mode, precision)| {
            GoldenCat(CatConfig {
                mode: *mode,
                precision: *precision,
                stage1: true,
            })
        })
        .collect();
    let refs: Vec<&dyn RenderBackend> =
        backends.iter().map(|b| b as &dyn RenderBackend).collect();
    let outs = session.sweep(0, &refs)?;
    for ((name, _, _), out) in configs.into_iter().zip(&outs) {
        report.row(
            name,
            &[
                ("psnr", psnr(&golden.image, &out.image)),
                ("ssim", ssim(&golden.image, &out.image)),
                ("pp_tested", out.stats.per_pixel_tested()),
            ],
        );
    }
    let cache = session.plan_cache_stats();
    println!(
        "plan cache: {} build, {} hits across {} renders",
        cache.builds,
        cache.hits,
        outs.len() + 1
    );
    report.emit();
    Ok(())
}

fn cmd_area(args: &Args) -> Result<()> {
    let name = args.str_or("hardware", "flicker32");
    let hw = HwConfig::by_name(&name).ok_or_else(|| err!("unknown hardware '{name}'"))?;
    let r = area(&hw, &AreaParams::default());
    let mut report = Report::new("area", &format!("area breakdown: {}", hw.name));
    for (component, mm2, share) in r.rows() {
        report.row(component, &[("mm2", mm2), ("share", share)]);
    }
    report.row("TOTAL", &[("mm2", r.total_mm2()), ("share", 1.0)]);
    report.emit();
    Ok(())
}

/// Multi-client service demo: one session prepares the scene and resolved
/// options, the service stores the scene once, and `--clients` synthetic
/// tenants submit ragged interleaved orbits (client `c` starts `c` views
/// into the orbit and renders `c` fewer frames, so workloads differ).
/// Submission rides the queue's backpressure — a rejected submit triggers
/// a drain, then retries — and the drained frames re-join per client.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let clients = args.usize_or("clients", 3)?.max(1);
    let backend_name = args.str_or("backend", "golden");
    let session = Session::builder(cfg).build()?;
    announce_prune(&session);
    let svc = RenderService::new(ServiceConfig {
        workers: session.options().workers,
        max_queue: args.usize_or("queue", 64)?.max(1),
        window: args.usize_or("window", 0)?,
        batch: session.options().batch,
        ..Default::default()
    });
    let scene_id = svc.register_scene(session.scene().clone());
    let base = session.cameras();
    let opts = *session.options();
    let per_client: Vec<Vec<RenderRequest>> = (0..clients)
        .map(|c| {
            let take = base.len().saturating_sub(c).max(1);
            (0..take)
                .map(|i| RenderRequest {
                    client: c,
                    view: i,
                    scene: scene_id,
                    camera: base[(i + c) % base.len()],
                    options: opts,
                })
                .collect()
        })
        .collect();

    let mut frames: Vec<ServiceFrame> = Vec::new();
    // Aggregate (real rows, shipped rows) across coalesced drains.
    let mut fill: (u64, u64) = (0, 0);
    let mut drain_all = |frames: &mut Vec<ServiceFrame>, fill: &mut (u64, u64)| -> Result<()> {
        match backend_name.as_str() {
            "golden" => frames.extend(svc.drain(&Golden)?),
            "pjrt" => serve_drain_pjrt(&svc, frames, fill)?,
            other => bail!("unknown backend '{other}' (serve supports golden|pjrt)"),
        }
        Ok(())
    };
    let longest = per_client.iter().map(Vec::len).max().unwrap_or(0);
    for v in 0..longest {
        for reqs in &per_client {
            let Some(&req) = reqs.get(v) else { continue };
            loop {
                match svc.submit(req) {
                    Ok(_) => break,
                    Err(_) if svc.pending() > 0 => {
                        // Queue full: drain the backlog, then retry.
                        drain_all(&mut frames, &mut fill)?;
                    }
                    Err(e) => return Err(e),
                }
            }
        }
    }
    drain_all(&mut frames, &mut fill)?;

    let lat: Vec<f64> = frames.iter().map(|f| f.metrics.wall_ms).collect();
    let l = latency_summary(&lat);
    let st = svc.stats();
    println!(
        "serve: {clients} clients, {} frames via {backend_name}",
        frames.len()
    );
    println!(
        "  latency ms: p50 {:.2}  p99 {:.2}  mean {:.2}  max {:.2}",
        l.p50, l.p99, l.mean, l.max
    );
    println!(
        "  plans: {} cached — {} hits, {} delta, {} cold of {} lookups",
        st.cached_plans, st.plan_hits, st.plan_delta_builds, st.plan_builds, st.plan_requests
    );
    println!(
        "  queue: {} admitted, {} rejected (drained on backpressure)",
        st.submitted, st.rejected
    );
    if fill.1 > 0 {
        println!(
            "  coalesced fill rate: {:.3} ({} real rows / {} shipped)",
            fill.0 as f64 / fill.1 as f64,
            fill.0,
            fill.1
        );
    }
    let mut report = session.report(
        "serve",
        &format!("{clients}-client service on {}", session.scene().name),
    );
    report.row(
        "aggregate",
        &[
            ("frames", frames.len() as f64),
            ("p50_ms", l.p50),
            ("p99_ms", l.p99),
            ("plan_hits", st.plan_hits as f64),
            ("plan_delta_builds", st.plan_delta_builds as f64),
            ("plan_builds", st.plan_builds as f64),
            ("rejected", st.rejected as f64),
        ],
    );
    for (c, s) in flicker::coordinator::service::stats_by_client(&frames) {
        let n = frames.iter().filter(|f| f.metrics.client == c).count();
        println!(
            "  client {c}: {n} frames, {} tile-pairs, {} blended pairs",
            s.tile_pairs, s.pairs_blended
        );
        report.row(
            &format!("client{c}"),
            &[
                ("frames", n as f64),
                ("tile_pairs", s.tile_pairs as f64),
                ("pairs_blended", s.pairs_blended as f64),
            ],
        );
    }
    report.emit();
    Ok(())
}

/// Coalesced drain for `serve --backend pjrt`: every queued frame's tiles
/// merge into shared precision-pure waves. The runtime is (re)loaded per
/// drain — cheap against the stub artifacts this demo targets.
#[cfg(feature = "pjrt")]
fn serve_drain_pjrt(
    svc: &RenderService,
    frames: &mut Vec<ServiceFrame>,
    fill: &mut (u64, u64),
) -> Result<()> {
    let rt = flicker::runtime::Runtime::load(&flicker::runtime::default_artifact_dir())?;
    let (fs, ex) = svc.drain_coalesced(&rt)?;
    fill.0 += ex.splats_submitted as u64;
    fill.1 += ex.rows_submitted as u64;
    frames.extend(fs);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn serve_drain_pjrt(
    _svc: &RenderService,
    _frames: &mut Vec<ServiceFrame>,
    _fill: &mut (u64, u64),
) -> Result<()> {
    bail!("this build has no PJRT runtime; rebuild with `cargo build --features pjrt`")
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let session = Session::builder(cfg).build()?;
    announce_prune(&session);
    let hw = session.config().build_hw()?;
    let scene = session.scene();
    let wl = workload_for(&session, &hw);
    println!("scene {}: {} gaussians", scene.name, scene.len());
    println!("  spiky fraction (ratio≥3): {:.2}", scene.spiky_fraction(3.0));
    println!("  visible splats: {}", wl.visible_splats);
    println!("  tile pairs: {}", wl.tile_pairs);
    println!("  stage1 pairs: {} → stage2: {}", wl.stage1_pairs, wl.stage2_pairs);
    println!("  minitile pairs: {}", wl.minitile_pairs);
    println!("  per-pixel processed: {:.2}", wl.per_pixel_processed());
    Ok(())
}
