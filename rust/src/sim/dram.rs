//! LPDDR4 DRAM traffic + timing model (paper Sec. IV-A memory optimization).
//!
//! Traffic accounting follows the paper's two-phase fetch: during frustum
//! culling only *geometric* features are read (10 f32 per Gaussian, or one
//! cluster descriptor per "big Gaussian" when clustering is enabled); color
//! payloads (45+ parameters) are fetched only for Gaussians that survive
//! culling. Tile-list duplication adds on-chip-buffered feature writes that
//! spill to DRAM when lists exceed the feature buffer.

use super::workload::FrameWorkload;
use super::HwConfig;
use crate::scene::gaussian::params;

/// DRAM traffic breakdown for one frame, in bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct DramTraffic {
    /// Cluster descriptors (center+radius+range: 8 f32) or per-Gaussian
    /// geometric reads during culling.
    pub cull_bytes: u64,
    /// Geometric features of Gaussians in visible clusters.
    pub geom_bytes: u64,
    /// Color payloads of surviving Gaussians.
    pub color_bytes: u64,
    /// Per-tile list spill traffic (duplicates × compact feature record).
    pub list_bytes: u64,
    /// Framebuffer writeout.
    pub framebuffer_bytes: u64,
}

impl DramTraffic {
    /// Total bytes moved per frame.
    pub fn total(&self) -> u64 {
        self.cull_bytes + self.geom_bytes + self.color_bytes + self.list_bytes
            + self.framebuffer_bytes
    }
}

/// Cluster statistics the traffic model needs (from `scene::clustering`).
#[derive(Clone, Copy, Debug)]
pub struct ClusterInfo {
    /// Total clusters in the scene.
    pub num_clusters: usize,
    /// Clusters whose sphere intersects the frustum.
    pub visible_clusters: usize,
    /// Gaussians inside visible clusters.
    pub gaussians_in_visible: usize,
}

/// Compute frame traffic.
pub fn frame_traffic(wl: &FrameWorkload, hw: &HwConfig, clusters: Option<ClusterInfo>) -> DramTraffic {
    const CLUSTER_DESC_BYTES: u64 = 32;
    /// Compact per-duplicate record in the tile lists (id + depth key).
    const LIST_RECORD_BYTES: u64 = 8;

    let mut t = DramTraffic::default();
    match (hw.clustering, clusters) {
        (true, Some(ci)) => {
            // Read every cluster descriptor, then geometry only for visible
            // clusters' members.
            t.cull_bytes = ci.num_clusters as u64 * CLUSTER_DESC_BYTES;
            t.geom_bytes = ci.gaussians_in_visible as u64 * params::GEOM_BYTES as u64;
        }
        _ => {
            // No clustering: geometry of *every* Gaussian streams through
            // the frustum-culling unit.
            t.cull_bytes = 0;
            t.geom_bytes = wl.scene_gaussians as u64 * params::GEOM_BYTES as u64;
        }
    }
    t.color_bytes = wl.visible_splats as u64 * params::COLOR_BYTES as u64;
    t.list_bytes = wl.tile_pairs as u64 * LIST_RECORD_BYTES;
    t.framebuffer_bytes = (wl.width as u64) * (wl.height as u64) * 4;
    t
}

/// Transfer time in seconds at the configured bandwidth (with a fixed 85%
/// efficiency factor for LPDDR4 row-activation overhead).
pub fn transfer_seconds(bytes: u64, hw: &HwConfig) -> f64 {
    const EFFICIENCY: f64 = 0.85;
    bytes as f64 / (hw.dram_gbps * 1e9 * EFFICIENCY)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::v3;
    use crate::scene::clustering::cluster;
    use crate::scene::synthetic::{generate_scaled, preset};
    use crate::sim::workload::extract;

    fn setup() -> (FrameWorkload, ClusterInfo) {
        let scene = generate_scaled(&preset("garden"), 0.01);
        // Camera facing *away* from the scene core: most clusters fall
        // outside the frustum, which is where cluster-level culling pays.
        let cam = Camera::look_at(
            Intrinsics::from_fov(128, 128, 0.5),
            v3(0.0, 2.5, -6.0),
            v3(0.0, 2.5, -40.0),
            v3(0.0, 1.0, 0.0),
        );
        let wl = extract(&scene, &cam, &HwConfig::flicker32());
        let cl = cluster(&scene, 32);
        let visible = cl.cull(&cam);
        let ci = ClusterInfo {
            num_clusters: cl.num_clusters(),
            visible_clusters: cl.visible_clusters(&cam),
            gaussians_in_visible: visible.len(),
        };
        (wl, ci)
    }

    #[test]
    fn clustering_reduces_cull_traffic() {
        let (wl, ci) = setup();
        let hw_c = HwConfig::flicker32();
        let hw_n = HwConfig {
            clustering: false,
            ..HwConfig::flicker32()
        };
        let with = frame_traffic(&wl, &hw_c, Some(ci));
        let without = frame_traffic(&wl, &hw_n, None);
        assert!(
            with.cull_bytes + with.geom_bytes < without.geom_bytes,
            "clustered {} vs flat {}",
            with.cull_bytes + with.geom_bytes,
            without.geom_bytes
        );
        // Color traffic identical (same survivors).
        assert_eq!(with.color_bytes, without.color_bytes);
    }

    #[test]
    fn color_fetched_only_for_survivors() {
        let (wl, ci) = setup();
        let t = frame_traffic(&wl, &HwConfig::flicker32(), Some(ci));
        let full = wl.scene_gaussians as u64 * crate::scene::gaussian::params::COLOR_BYTES as u64;
        assert!(t.color_bytes < full, "color must be gated by culling");
    }

    #[test]
    fn transfer_time_linear() {
        let hw = HwConfig::flicker32();
        let t1 = transfer_seconds(1_000_000, &hw);
        let t2 = transfer_seconds(2_000_000, &hw);
        assert!((t2 / t1 - 2.0).abs() < 1e-12);
        // 51.2 GB/s × 0.85 → ~43.5 GB/s effective.
        assert!((transfer_seconds(43_520_000_000, &hw) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn totals_sum() {
        let (wl, ci) = setup();
        let t = frame_traffic(&wl, &HwConfig::flicker32(), Some(ci));
        assert_eq!(
            t.total(),
            t.cull_bytes + t.geom_bytes + t.color_bytes + t.list_bytes + t.framebuffer_bytes
        );
        assert!(t.total() > 0);
    }
}
