//! Analytic GPU model for the profiling study (Fig. 1) and the Fig. 10
//! normalization baseline (Jetson Xavier NX) / desktop reference (RTX 3090).
//!
//! The model captures the two effects the paper's Nsight profile isolates:
//! high SM-issue ("CU") utilization but low achieved-FP32 utilization caused
//! by warp divergence in the rasterization loop — lanes whose pixel already
//! saturated or whose α falls below 1/255 idle while their warp iterates.

use super::workload::FrameWorkload;

/// GPU device parameters.
#[derive(Clone, Debug)]
pub struct GpuParams {
    /// Device name ("Orin NX"-class, desktop-class, …).
    pub name: String,
    /// Peak FP32 throughput (GFLOP/s).
    pub peak_gflops: f64,
    /// Memory bandwidth GB/s.
    pub mem_gbps: f64,
    /// Per-frame fixed kernel-launch overhead (ms).
    pub fixed_ms: f64,
    /// Whole-pipeline factor over the raster kernel: preprocessing +
    /// sorting + compositing take ~40–70% extra on top of rasterization
    /// (the paper cites rendering as >60% of kernel time [7][17][18]).
    pub pipeline_factor: f64,
    /// Board power (W) for energy estimates.
    pub power_w: f64,
}

impl GpuParams {
    /// Jetson Xavier NX (edge): 21 TOPS class, ~1.3 TFLOPS FP32 (384-core
    /// Volta @ ~1.1 GHz), 59.7 GB/s LPDDR4x, 15 W mode.
    pub fn xavier_nx() -> GpuParams {
        GpuParams {
            name: "jetson-xnx".into(),
            peak_gflops: 1_300.0,
            mem_gbps: 59.7,
            fixed_ms: 1.0,
            pipeline_factor: 1.6,
            power_w: 15.0,
        }
    }

    /// RTX 3090: 35.6 TFLOPS FP32, 936 GB/s, 350 W.
    pub fn rtx3090() -> GpuParams {
        GpuParams {
            name: "rtx3090".into(),
            peak_gflops: 35_600.0,
            mem_gbps: 936.0,
            fixed_ms: 0.15,
            pipeline_factor: 1.6,
            power_w: 350.0,
        }
    }
}

/// Per-frame GPU estimate.
#[derive(Clone, Copy, Debug)]
pub struct GpuEstimate {
    /// Estimated frame time (ms).
    pub frame_ms: f64,
    /// Estimated frames per second.
    pub fps: f64,
    /// Issue-level ("CU") utilization: fraction of cycles a warp was
    /// resident and issuing (includes divergent-lane waste).
    pub cu_util: f64,
    /// Achieved-FP32 fraction of peak: only lanes doing useful blends.
    pub fp_util: f64,
    /// Estimated energy per frame (mJ).
    pub energy_mj_per_frame: f64,
}

/// FLOPs per (pixel, Gaussian) pair in the rasterization inner loop
/// (Eq. 1 + blend ≈ 30 FLOPs incl. exp expansion).
const FLOPS_PER_PAIR: f64 = 30.0;

/// Estimate the rasterization-dominated frame time on a GPU.
///
/// Divergence model: warps cover 32 contiguous pixels of a tile row-pair;
/// every listed Gaussian is *iterated* by every warp of the tile, issuing
/// for all 32 lanes, but only `useful` lanes (α ≥ 1/255 and unsaturated)
/// retire useful FP work. CU utilization stays high (issue slots busy);
/// achieved FP32 = useful / issued.
pub fn estimate(wl: &FrameWorkload, dev: &GpuParams) -> GpuEstimate {
    // Issued lane-iterations: every (gaussian, tile) pair runs on every
    // pixel lane of the tile (16×16 = 256 lanes in 8 warps).
    let issued = wl.tile_pairs as f64 * 256.0;
    // Useful lane-iterations: the pairs that actually blended.
    let useful = wl.blended_pairs as f64;
    let fp_util_raw = useful / issued.max(1.0);

    // Occupancy/scheduling ceiling: even perfectly coherent 3DGS kernels
    // reach ~65% of peak FP32 due to sort/fetch interleave.
    const SCHED_CEIL: f64 = 0.65;
    let fp_util = fp_util_raw * SCHED_CEIL;

    let flops = issued * FLOPS_PER_PAIR;
    let compute_s = flops / (dev.peak_gflops * 1e9 * SCHED_CEIL);

    // Memory: feature fetches per (gaussian, tile) (64 B record cached in
    // shared memory, one fetch per warp) + framebuffer.
    let bytes = wl.tile_pairs as f64 * 64.0 * 8.0
        + (wl.width as f64 * wl.height as f64) * 16.0;
    let mem_s = bytes / (dev.mem_gbps * 1e9 * 0.75);

    let frame_s = compute_s.max(mem_s) * dev.pipeline_factor + dev.fixed_ms * 1e-3;
    let fps = 1.0 / frame_s;

    // CU utilization: issue slots busy during the raster kernel — high by
    // construction when compute-bound, reduced by memory waits.
    let cu_util = (compute_s / frame_s * 0.97).clamp(0.0, 1.0).max(0.55);

    GpuEstimate {
        frame_ms: frame_s * 1e3,
        fps,
        cu_util,
        fp_util,
        energy_mj_per_frame: dev.power_w * frame_s * 1e3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::v3;
    use crate::scene::synthetic::{generate_scaled, preset};
    use crate::sim::workload::extract;
    use crate::sim::HwConfig;

    fn workload(scale: f32, px: u32) -> FrameWorkload {
        let scene = generate_scaled(&preset("garden"), scale);
        let cam = Camera::look_at(
            Intrinsics::from_fov(px, px, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        extract(&scene, &cam, &HwConfig::simplified32())
    }

    #[test]
    fn desktop_much_faster_than_edge() {
        let wl = workload(0.02, 128);
        let d = estimate(&wl, &GpuParams::rtx3090());
        let e = estimate(&wl, &GpuParams::xavier_nx());
        assert!(d.fps > e.fps * 5.0, "3090 {} vs XNX {}", d.fps, e.fps);
    }

    #[test]
    fn fp_util_much_lower_than_cu_util() {
        // The Fig. 1(b) signature.
        let wl = workload(0.02, 128);
        let e = estimate(&wl, &GpuParams::xavier_nx());
        assert!(e.cu_util > 0.5, "cu {}", e.cu_util);
        assert!(e.fp_util < 0.45, "fp {}", e.fp_util);
        assert!(e.fp_util < e.cu_util * 0.6);
    }

    #[test]
    fn more_work_lower_fps() {
        let small = workload(0.01, 128);
        let big = workload(0.04, 128);
        let dev = GpuParams::xavier_nx();
        assert!(estimate(&big, &dev).fps < estimate(&small, &dev).fps);
    }

    #[test]
    fn energy_positive_and_scales_with_power() {
        let wl = workload(0.01, 128);
        let e = estimate(&wl, &GpuParams::xavier_nx());
        assert!(e.energy_mj_per_frame > 0.0);
    }
}
